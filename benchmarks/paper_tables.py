"""One benchmark per paper table (proxy scale; see DESIGN.md §8).

Table 1  BERT-Base: ours vs from-scratch vs the 5 baselines (FLOPs saving).
Table 2  GPT-Base:  ours vs from-scratch (+ growth baselines).
Table 3  DeiT-B:    ours vs from-scratch on the vision proxy.
Table 4  BERT-Large proxy: 2-level vs 3-level V-cycle (more levels help).
Table 5  Ablations: E_a (A), E_small (B), alpha incl. 1.0 (C), coalesced size (D).
App. F   Removing Coalescing (random small init) hurts.

Beyond the paper, ``bench_family`` (benchmarks/family_tables.py) runs the same
arena protocol over every model family -- dense / MoE / SSM / hybrid / ViT --
and prices the pinned FLOPs numbers in joules and kgCO2e (DESIGN.md §7).
"""
from __future__ import annotations

import time
from typing import Dict

from benchmarks.common import Arena, emit, proxy_tc, save_json
from repro.config import MultiLevelConfig
from repro.configs import paper_models
from repro.core.baselines import BASELINES
from repro.core.vcycle import run_vcycle

ML_BERT = MultiLevelConfig(n_levels=2, alpha=0.5, e_a_frac=0.05, e_small_frac=0.5)
ML_GPT = MultiLevelConfig(n_levels=2, alpha=0.25, e_a_frac=0.05, e_small_frac=0.5)


def _clear():
    import jax

    jax.clear_caches()  # long bench runs accumulate jit dylibs -> LLVM ENOMEM


def bench_family(quick: bool = False) -> Dict:
    """Per-family FLOPs + energy table (delegates to family_tables.py)."""
    from benchmarks import family_tables

    return family_tables.bench_family(quick)


def _run_ours(arena: Arena, ml: MultiLevelConfig, tag: str, results: Dict,
              final_steps=None) -> None:
    _clear()
    t0 = time.time()
    out = run_vcycle(arena.cfg, ml, arena.tc, arena.batch_fn, seed=0,
                     target_loss=arena.target, final_steps=final_steps)
    s = arena.saving(out.history)
    results[tag] = {**s, "history": out.history.to_dict()}
    emit(tag, (time.time() - t0) * 1e6 / max(len(out.history.step), 1),
         f"flops_saving={s['flops_saving']:.3f}@loss{s['target_loss']:.3f}")


def bench_table1_bert(quick: bool = False) -> Dict:
    cfg = paper_models.bert_proxy(d_model=64, n_layers=4)
    tc = proxy_tc(quick)
    arena = Arena(cfg, tc)
    results: Dict = {"scratch": {"target_loss": arena.target,
                                 "history": arena.baseline.to_dict()}}
    emit("table1/bert/scratch", arena.step_us, f"final_loss={arena.target:.3f}")
    _run_ours(arena, ML_BERT, "table1/bert/ours", results)
    for name, fn in BASELINES.items():
        _clear()
        t0 = time.time()
        kw = dict(small_steps=tc.steps // 2, final_steps=tc.steps,
                  target_loss=arena.target)
        if quick and name in ("ligo",):
            kw["fit_steps"] = 10
        hist = fn(cfg, ML_BERT, tc, arena.batch_fn, **kw)
        s = arena.saving(hist)
        results[name] = {**s, "history": hist.to_dict()}
        emit(f"table1/bert/{name}", (time.time() - t0) * 1e6 / max(len(hist.step), 1),
             f"flops_saving={s['flops_saving']:.3f}")
    save_json("table1_bert", results)
    return results


def bench_table2_gpt(quick: bool = False) -> Dict:
    cfg = paper_models.gpt_proxy(d_model=64, n_layers=4)
    tc = proxy_tc(quick)
    arena = Arena(cfg, tc)
    results: Dict = {"scratch": {"target_loss": arena.target}}
    emit("table2/gpt/scratch", arena.step_us, f"final_loss={arena.target:.3f}")
    _run_ours(arena, ML_GPT, "table2/gpt/ours", results)
    for name in ("stackbert", "bert2bert"):
        _clear()
        t0 = time.time()
        hist = BASELINES[name](cfg, ML_GPT, tc, arena.batch_fn,
                               target_loss=arena.target)
        s = arena.saving(hist)
        results[name] = s
        emit(f"table2/gpt/{name}", (time.time() - t0) * 1e6 / max(len(hist.step), 1),
             f"flops_saving={s['flops_saving']:.3f}")
    save_json("table2_gpt", results)
    return results


def bench_table3_deit(quick: bool = False) -> Dict:
    cfg = paper_models.deit_proxy(d_model=64, n_layers=4)
    tc = proxy_tc(quick, seq_len=0 or 24)
    arena = Arena(cfg, tc)
    results: Dict = {"scratch": {"target_loss": arena.target}}
    emit("table3/deit/scratch", arena.step_us, f"final_loss={arena.target:.3f}")
    _run_ours(arena, ML_GPT, "table3/deit/ours", results)
    save_json("table3_deit", results)
    return results


def bench_table4_levels(quick: bool = False) -> Dict:
    cfg = paper_models.bert_proxy(d_model=96, n_layers=8).replace(name="bert-large-proxy")
    tc = proxy_tc(quick)
    arena = Arena(cfg, tc)
    results: Dict = {"scratch": {"target_loss": arena.target}}
    emit("table4/bert-large/scratch", arena.step_us, f"final_loss={arena.target:.3f}")
    for k in (2, 3):
        ml = MultiLevelConfig(n_levels=k, alpha=0.5, e_a_frac=0.05,
                              e_small_frac=0.5 if k == 2 else 0.35)
        _run_ours(arena, ml, f"table4/bert-large/levels{k}", results)
    save_json("table4_levels", results)
    return results


def bench_table5_ablations(quick: bool = False) -> Dict:
    cfg = paper_models.bert_proxy(d_model=64, n_layers=4)
    tc = proxy_tc(quick)
    arena = Arena(cfg, tc)
    results: Dict = {"scratch": {"target_loss": arena.target}}
    emit("table5/scratch", arena.step_us, f"final_loss={arena.target:.3f}")
    # (A) E_a too large kills the effect; (B) E_small; (C) alpha incl. 1.0
    arms = {
        "Ea0.05": MultiLevelConfig(2, alpha=0.5, e_a_frac=0.05, e_small_frac=0.5),
        "Ea0.33": MultiLevelConfig(2, alpha=0.5, e_a_frac=0.33, e_small_frac=0.5),
        "Esmall0.17": MultiLevelConfig(2, alpha=0.5, e_a_frac=0.05, e_small_frac=0.17),
        "Esmall1.0": MultiLevelConfig(2, alpha=0.5, e_a_frac=0.05, e_small_frac=1.0),
        "alpha0.05": MultiLevelConfig(2, alpha=0.05, e_a_frac=0.05, e_small_frac=0.5),
        "alpha1.0": MultiLevelConfig(2, alpha=1.0, e_a_frac=0.05, e_small_frac=0.5),
        "adjF": MultiLevelConfig(2, alpha=0.5, e_a_frac=0.05, e_small_frac=0.5,
                                 width_variant="adj"),
    }
    if quick:
        for key in ("Esmall0.17", "Esmall1.0", "adjF"):
            arms.pop(key)
    for tag, ml in arms.items():
        _run_ours(arena, ml, f"table5/{tag}", results)
    save_json("table5_ablations", results)
    return results


def bench_appendixF_no_coalesce(quick: bool = False) -> Dict:
    """Random small-model init inside the V-cycle (coalescing removed)."""
    import jax

    from repro.core import operators as ops
    from repro.core.vcycle import History, train_segment
    from repro.models.api import build_model

    cfg = paper_models.bert_proxy(d_model=64, n_layers=4)
    tc = proxy_tc(quick)
    arena = Arena(cfg, tc)
    ml = ML_BERT
    results: Dict = {}
    # with coalescing
    _run_ours(arena, ml, "appF/with_coalesce", results)
    # without: random-init small model, then de-coalesce + interpolate as usual
    small_cfg = ops.coalesce_config(cfg, ml)
    small = build_model(small_cfg)
    model = build_model(cfg)
    hist = History()
    E_a = max(int(round(tc.steps * ml.e_a_frac)), 1)
    E_s = max(int(round(tc.steps * ml.e_small_frac)), 1)
    p0, _, hist, cum, g = train_segment(model, tc, arena.batch_fn, E_a, history=hist, level=0)
    ps, _, hist, cum, g = train_segment(small, tc, arena.batch_fn, E_s,
                                        params=small.init(jax.random.PRNGKey(99)),
                                        history=hist, start_flops=cum, start_step=g, level=1)
    de = ops.make_decoalesce_fn(model.specs(), cfg, ml)(ps)
    p1 = ops.make_interpolate_fn(ml.alpha)(p0, de)
    _, _, hist, cum, g = train_segment(model, tc, arena.batch_fn, tc.steps, params=p1,
                                       history=hist, start_flops=cum, start_step=g,
                                       level=0, target_loss=arena.target)
    s = arena.saving(hist)
    results["appF/random_small_init"] = s
    emit("appF/random_small_init", arena.step_us, f"flops_saving={s['flops_saving']:.3f}")
    save_json("appendixF", results)
    return results
