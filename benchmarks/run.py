"""Benchmark entry point: one function per paper table + kernel micro-bench +
the roofline report.  Prints ``name,us_per_call,derived`` CSV.

Usage:
  PYTHONPATH=src python -m benchmarks.run [--only table1,kernels] [--quick]
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all",
                    help="comma list of: table1,table2,table3,table4,table5,family,appF,kernels,roofline")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only != "all" else {
        "table1", "table2", "table3", "table4", "table5", "family", "appF",
        "kernels", "roofline"}

    from benchmarks import kernel_bench, paper_tables, roofline

    t0 = time.time()
    print("name,us_per_call,derived")
    if "kernels" in want:
        kernel_bench.bench_kernels(args.quick)
    if "roofline" in want:
        roofline.bench_roofline(args.quick)
    if "table1" in want:
        paper_tables.bench_table1_bert(args.quick)
    if "table2" in want:
        paper_tables.bench_table2_gpt(args.quick)
    if "table3" in want:
        paper_tables.bench_table3_deit(args.quick)
    if "table4" in want:
        paper_tables.bench_table4_levels(args.quick)
    if "table5" in want:
        paper_tables.bench_table5_ablations(args.quick)
    if "family" in want:
        paper_tables.bench_family(args.quick)
    if "appF" in want:
        paper_tables.bench_appendixF_no_coalesce(args.quick)
    print(f"# total {time.time()-t0:.0f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
