"""Hillclimb helper: lower+compile selected (arch:shape) pairs on the single-pod
mesh and print/store their roofline terms (used for the EXPERIMENTS.md SPerf
iteration log without touching the main dryrun.json).

  PYTHONPATH=src python benchmarks/measure_pairs.py [arch:shape ...]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, json
from repro.config import SHAPES
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_production_mesh

mesh = make_production_mesh()
cells = sys.argv[1:] or ["jamba-1.5-large-398b:train_4k", "deepseek-v3-671b:decode_32k", "qwen3-14b:train_4k"]
out = {}
for c in cells:
    arch, shape = c.split(":")
    print(f"== {c} ==", flush=True)
    rec = lower_cell(arch, SHAPES[shape], mesh)
    out[c] = rec
json.dump(out, open("/tmp/pairs_latest.json", "w"), indent=1, default=float)
