"""Serving benchmark: paged KV engine vs the slot oracle, as an APPEND-ONLY
perf trajectory (``benchmarks/results/BENCH_serve.json``).

Fixed request mixes (deterministic seeds):

  * ``uniform``       -- same-length prompts, no shareable prefix: isolates
                         the block-table decode + admission path against the
                         slot engine's dense-cache splice/decode.
  * ``shared_prefix`` -- a cohort sharing one long prompt stem: measures
                         prefix-reuse (prefill tokens saved) on top of tok/s.

On top of the engine comparison, a **speculative** point measures
self-speculative decoding (``SpeculativePolicy``): the serving weights are
made projection-consistent (``decoalesce(width-only)`` of a level-1 init, the
exactly function-preserving direction pinned in tests/test_operators.py) so
the coalesced draft agrees with the full model and the accept rate is a
hardware-independent property of the projection, not of noise.  The point
records tok/s, accept rate and the draft/verify wall-time split, and asserts
losslessness (token streams identical to greedy on the same weights).

A second **speculative_trained** point answers the production question the
projection-consistent one deliberately dodges: what accept rate does the
coalesced draft get on weights that have actually been TRAINED through the
V-cycle (where coalesce(params) is no longer function-identical to the full
model)?  A tiny V-cycle runs in-process, both greedy and speculative servers
serve its final params, and the point records the trained accept rate --
gated by ``--trained-accept-floor`` (losslessness stays exact either way).

Each invocation appends one trajectory point; ``--check-regression`` compares
the *ratios* (paged/slots and speculative/greedy tok/s on the uniform mix)
against the last committed point and fails (exit 1) on a >20% drop, plus an
absolute accept-rate floor for the speculative point -- ratios and accept
rate are hardware-independent, so a laptop, CI runner and TPU host share one
trajectory file.

Smoke scale by default: runs on CPU in a couple of minutes (the CI
``serve-drill`` job runs exactly this).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import RESULTS_DIR, emit
from repro.config import MultiLevelConfig
from repro.configs import get_config
from repro.core import operators as ops
from repro.launch.serve import (PagedServer, Request, SpeculativePolicy,
                                make_server)
from repro.models.api import build_model

BENCH_PATH = os.path.join(RESULTS_DIR, "BENCH_serve.json")


def _uniform_mix(vocab: int, n: int, prompt_len: int, max_new: int) -> List[Request]:
    rng = np.random.default_rng(11)
    return [Request(rid=i, prompt=rng.integers(0, vocab, size=prompt_len),
                    max_new=max_new) for i in range(n)]


def _shared_prefix_mix(vocab: int, n: int, stem_len: int, max_new: int) -> List[Request]:
    rng = np.random.default_rng(13)
    stem = rng.integers(0, vocab, size=stem_len)
    return [Request(rid=i,
                    prompt=np.concatenate([stem, rng.integers(0, vocab, size=5 + (i % 6))]),
                    max_new=max_new) for i in range(n)]


def _timed_run(srv, make_reqs, reps: int = 3) -> Dict[str, float]:
    """Best-of-``reps`` drain (reset before each): smoke drains are ~100ms on
    CPU, so a single sample is dominated by scheduler jitter; min-time is the
    standard de-noiser and the token stream is deterministic across reps."""
    best = None
    for _ in range(reps):
        srv.reset()
        t0 = time.time()
        done = srv.run(make_reqs())
        dt = time.time() - t0
        toks = sum(len(r.out) for r in done)
        out = {"requests": len(done), "tokens": toks, "seconds": dt,
               "tok_s": toks / max(dt, 1e-9)}
        if isinstance(srv, PagedServer):
            out.update(srv.stats())
        if best is None or out["tok_s"] > best["tok_s"]:
            best = out
    return best


def _load_trajectory() -> List[Dict]:
    if not os.path.exists(BENCH_PATH):
        return []
    with open(BENCH_PATH) as f:
        return json.load(f)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--draft-k", type=int, default=4,
                    help="speculative draft length")
    ap.add_argument("--accept-floor", type=float, default=0.60,
                    help="minimum speculative accept rate on the "
                         "projection-consistent workload (--check-regression)")
    ap.add_argument("--trained-accept-floor", type=float, default=0.15,
                    help="minimum speculative accept rate on trained V-cycle "
                         "weights (--check-regression); trained weights break "
                         "projection-consistency, so this floor is far below "
                         "--accept-floor")
    ap.add_argument("--train-steps", type=int, default=192,
                    help="V-cycle steps behind the speculative_trained point "
                         "(enough to learn the Markov chain; fewer steps "
                         "leave argmax at chance and accept near zero)")
    ap.add_argument("--check-regression", action="store_true",
                    help="fail on >tol drop of the paged/slots or "
                         "speculative/greedy uniform tok/s ratios vs the last "
                         "committed trajectory point, or on an accept rate "
                         "below --accept-floor")
    ap.add_argument("--regression-tol", type=float, default=0.20)
    args = ap.parse_args()

    baseline = _load_trajectory()  # read BEFORE appending
    cfg = get_config(args.arch, smoke=args.smoke)
    uniform = lambda: _uniform_mix(cfg.vocab_size, args.requests, 16, args.max_new)
    shared = lambda: _shared_prefix_mix(cfg.vocab_size, args.requests, 32,
                                        max(4, args.max_new // 2))

    results: Dict[str, Dict] = {"uniform": {}, "shared_prefix": {}}
    for engine in ("slots", "paged"):
        srv = make_server(cfg, engine=engine, batch=args.batch,
                          max_seq=args.max_seq, page_size=args.page_size)
        srv.run(uniform())  # warmup: compile prefill/decode/extend paths
        srv.run(shared())
        results["uniform"][engine] = _timed_run(srv, uniform)
        results["shared_prefix"][engine] = _timed_run(srv, shared)
        for mix in results:
            emit(f"serve/{mix}/{engine}", 1e6 / max(results[mix][engine]["tok_s"], 1e-9),
                 f"tok_s={results[mix][engine]['tok_s']:.1f}")

    ratio = (results["uniform"]["paged"]["tok_s"]
             / max(results["uniform"]["slots"]["tok_s"], 1e-9))

    # -- speculative point: self-drafted decode from the width-coalesced
    # level-1 model.  Serving weights are decoalesce(width-only)(small init)
    # so the draft is function-identical to the full model (the exactly
    # preserving direction): accept rate then measures the speculation
    # machinery itself, hardware- and seed-independently.  This section runs
    # in float32 -- the same discipline as the paged-vs-slots equivalence
    # tests: greedy argmax streams are only bit-stable across batch shapes
    # (S=1 decode vs S=k+1 verify) when the compute dtype has the headroom,
    # and the losslessness assert below is exact, not approximate.
    ml = MultiLevelConfig()
    cfg32 = dataclasses.replace(cfg, compute_dtype=jnp.float32)
    model = build_model(cfg32)
    small_cfg = ops.coalesce_config(cfg32, ml, width=True, depth=False)
    p_serve = ops.make_decoalesce_fn(model.specs(), cfg32, ml,
                                     width=True, depth=False)(
        build_model(small_cfg).init(jax.random.PRNGKey(0)))
    gsrv = make_server(cfg32, engine="paged", batch=args.batch,
                       max_seq=args.max_seq, page_size=args.page_size)
    gsrv.set_params(p_serve)
    gsrv.run(uniform())  # warmup with the projection-consistent weights
    greedy_res = _timed_run(gsrv, uniform)
    gsrv.reset()
    greedy_toks = {r.rid: r.out for r in gsrv.run(uniform())}

    spec_pol = SpeculativePolicy(k=args.draft_k, ml=ml,
                                 draft_width=True, draft_depth=False)
    spec_srv = make_server(cfg32, engine="paged", batch=args.batch,
                           max_seq=args.max_seq, page_size=args.page_size,
                           policy=spec_pol)
    spec_srv.set_params(p_serve)
    spec_srv.run(uniform())  # warmup: compile draft/verify paths
    spec_res = _timed_run(spec_srv, uniform)
    spec_srv.reset()
    spec_toks = {r.rid: r.out for r in spec_srv.run(uniform())}
    lossless = spec_toks == greedy_toks
    spec_ratio = spec_res["tok_s"] / max(greedy_res["tok_s"], 1e-9)
    emit("serve/uniform/speculative", 1e6 / max(spec_res["tok_s"], 1e-9),
         f"tok_s={spec_res['tok_s']:.1f} accept={spec_res['accept_rate']:.2f}")

    # -- speculative_trained point: the same speculative machinery, but on
    # params that really went through the V-cycle (ROADMAP item 2 follow-on).
    # Trained weights are NOT projection-consistent -- coalesce(params) is an
    # approximation of the full model, so the accept rate below is the
    # production number: what the draft actually buys on served checkpoints.
    # Losslessness is unconditional (acceptance only ever commits full-model
    # argmaxes), so the stream equality assert holds at ANY accept rate.
    from repro.launch.train import train_vcycle_ckpt
    from repro.config import TrainConfig

    # lr 1e-2 is deliberate: at smoke scale the draft only ever agrees with
    # the full model where logit margins beat the projection error, so the
    # chain must actually be learned (loss well under ln(vocab)) within a
    # CI-sized step budget.  6e-4 leaves the model near-uniform and the
    # accept rate at chance (~1/vocab).
    tc = TrainConfig(steps=args.train_steps,
                     warmup_steps=max(args.train_steps // 8, 1),
                     peak_lr=1e-2, batch_size=8, seq_len=32, log_every=1000)
    out = train_vcycle_ckpt(cfg32, ml, tc, ckpt=None, ckpt_every=0,
                            verbose=False)
    p_trained = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32), out.params)
    gsrv.set_params(p_trained)
    gsrv.run(uniform())  # warmup on the trained weights
    tr_greedy_res = _timed_run(gsrv, uniform)
    gsrv.reset()
    tr_greedy_toks = {r.rid: r.out for r in gsrv.run(uniform())}
    spec_srv.set_params(p_trained)  # re-projects the draft from trained params
    spec_srv.run(uniform())
    tr_spec_res = _timed_run(spec_srv, uniform)
    spec_srv.reset()
    tr_spec_toks = {r.rid: r.out for r in spec_srv.run(uniform())}
    tr_lossless = tr_spec_toks == tr_greedy_toks
    tr_ratio = tr_spec_res["tok_s"] / max(tr_greedy_res["tok_s"], 1e-9)
    emit("serve/uniform/speculative_trained",
         1e6 / max(tr_spec_res["tok_s"], 1e-9),
         f"tok_s={tr_spec_res['tok_s']:.1f} "
         f"accept={tr_spec_res['accept_rate']:.2f}")

    entry = {
        "ts": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "platform": jax.default_backend(),
        "arch": args.arch,
        "smoke": bool(args.smoke),
        "batch": args.batch,
        "max_seq": args.max_seq,
        "page_size": args.page_size,
        "uniform": results["uniform"],
        "shared_prefix": results["shared_prefix"],
        "paged_over_slots_uniform": ratio,
        "speculative": {
            "draft_k": args.draft_k,
            "uniform": spec_res,
            "greedy_uniform_tok_s": greedy_res["tok_s"],
            "spec_over_greedy_uniform": spec_ratio,
            "accept_rate": spec_res["accept_rate"],
            "draft_time_s": spec_res["draft_time_s"],
            "verify_time_s": spec_res["verify_time_s"],
            "lossless": bool(lossless),
        },
        "speculative_trained": {
            "draft_k": args.draft_k,
            "train_steps": args.train_steps,
            "uniform": tr_spec_res,
            "greedy_uniform_tok_s": tr_greedy_res["tok_s"],
            "spec_over_greedy_uniform": tr_ratio,
            "accept_rate": tr_spec_res["accept_rate"],
            "lossless": bool(tr_lossless),
        },
    }
    saved = results["shared_prefix"]["paged"].get("prefill_tokens_saved", 0)
    print(f"[serve_bench] uniform paged/slots tok/s ratio: {ratio:.2f}")
    print(f"[serve_bench] shared-prefix prefill tokens saved: {saved}")
    print(f"[serve_bench] speculative: {spec_res['tok_s']:.1f} tok/s "
          f"({spec_ratio:.2f}x greedy), accept={spec_res['accept_rate']:.2f}, "
          f"draft/verify = {spec_res['draft_time_s']:.3f}s/"
          f"{spec_res['verify_time_s']:.3f}s, lossless={lossless}")
    print(f"[serve_bench] speculative_trained ({args.train_steps} V-cycle "
          f"steps): {tr_spec_res['tok_s']:.1f} tok/s ({tr_ratio:.2f}x greedy), "
          f"accept={tr_spec_res['accept_rate']:.2f}, lossless={tr_lossless}")

    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(BENCH_PATH, "w") as f:
        json.dump(baseline + [entry], f, indent=1, default=float)
    print(f"[serve_bench] appended trajectory point #{len(baseline) + 1} -> {BENCH_PATH}")

    rc = 0
    if saved <= 0:
        print("[serve_bench] FAIL: shared-prefix mix saved no prefill tokens")
        rc = 1
    if not lossless:
        print("[serve_bench] FAIL: speculative token stream diverged from "
              "greedy decode (losslessness broken)")
        rc = 1
    if not tr_lossless:
        print("[serve_bench] FAIL: speculative token stream diverged from "
              "greedy decode on trained V-cycle weights")
        rc = 1
    if args.check_regression:
        if spec_res["accept_rate"] < args.accept_floor:
            print(f"[serve_bench] FAIL: speculative accept rate "
                  f"{spec_res['accept_rate']:.2f} below floor "
                  f"{args.accept_floor:.2f} on the projection-consistent "
                  f"workload")
            rc = 1
        else:
            print(f"[serve_bench] accept-rate gate OK: "
                  f"{spec_res['accept_rate']:.2f} >= {args.accept_floor:.2f}")
        if tr_spec_res["accept_rate"] < args.trained_accept_floor:
            print(f"[serve_bench] FAIL: trained-weights accept rate "
                  f"{tr_spec_res['accept_rate']:.2f} below floor "
                  f"{args.trained_accept_floor:.2f}")
            rc = 1
        else:
            print(f"[serve_bench] trained accept-rate gate OK: "
                  f"{tr_spec_res['accept_rate']:.2f} >= "
                  f"{args.trained_accept_floor:.2f}")
    if args.check_regression and baseline:
        prev = baseline[-1]["paged_over_slots_uniform"]
        floor = prev * (1.0 - args.regression_tol)
        if ratio < floor:
            print(f"[serve_bench] FAIL: paged/slots ratio {ratio:.2f} regressed "
                  f">{args.regression_tol:.0%} below committed {prev:.2f}")
            rc = 1
        else:
            print(f"[serve_bench] regression gate OK: {ratio:.2f} >= {floor:.2f} "
                  f"(committed {prev:.2f} - {args.regression_tol:.0%})")
        spec_pts = [b["speculative"]["spec_over_greedy_uniform"]
                    for b in baseline if "speculative" in b]
        if spec_pts:
            sfloor = spec_pts[-1] * (1.0 - args.regression_tol)
            if spec_ratio < sfloor:
                print(f"[serve_bench] FAIL: speculative/greedy ratio "
                      f"{spec_ratio:.2f} regressed >{args.regression_tol:.0%} "
                      f"below committed {spec_pts[-1]:.2f}")
                rc = 1
            else:
                print(f"[serve_bench] speculative gate OK: {spec_ratio:.2f} "
                      f">= {sfloor:.2f} (committed {spec_pts[-1]:.2f} - "
                      f"{args.regression_tol:.0%})")
    return rc


if __name__ == "__main__":
    sys.exit(main())
