"""Serving benchmark: paged KV engine vs the slot oracle, as an APPEND-ONLY
perf trajectory (``benchmarks/results/BENCH_serve.json``).

Fixed request mixes (deterministic seeds):

  * ``uniform``       -- same-length prompts, no shareable prefix: isolates
                         the block-table decode + admission path against the
                         slot engine's dense-cache splice/decode.
  * ``shared_prefix`` -- a cohort sharing one long prompt stem: measures
                         prefix-reuse (prefill tokens saved) on top of tok/s.

Each invocation appends one trajectory point; ``--check-regression`` compares
the *ratio* paged/slots tok/s on the uniform mix against the last committed
point and fails (exit 1) on a >20% drop -- the ratio is hardware-independent,
so a laptop, CI runner and TPU host share one trajectory file.

Smoke scale by default: runs on CPU in a couple of minutes (the CI
``serve-drill`` job runs exactly this).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import RESULTS_DIR, emit
from repro.configs import get_config
from repro.launch.serve import PagedServer, Request, make_server

BENCH_PATH = os.path.join(RESULTS_DIR, "BENCH_serve.json")


def _uniform_mix(vocab: int, n: int, prompt_len: int, max_new: int) -> List[Request]:
    rng = np.random.default_rng(11)
    return [Request(rid=i, prompt=rng.integers(0, vocab, size=prompt_len),
                    max_new=max_new) for i in range(n)]


def _shared_prefix_mix(vocab: int, n: int, stem_len: int, max_new: int) -> List[Request]:
    rng = np.random.default_rng(13)
    stem = rng.integers(0, vocab, size=stem_len)
    return [Request(rid=i,
                    prompt=np.concatenate([stem, rng.integers(0, vocab, size=5 + (i % 6))]),
                    max_new=max_new) for i in range(n)]


def _timed_run(srv, make_reqs, reps: int = 3) -> Dict[str, float]:
    """Best-of-``reps`` drain (reset before each): smoke drains are ~100ms on
    CPU, so a single sample is dominated by scheduler jitter; min-time is the
    standard de-noiser and the token stream is deterministic across reps."""
    best = None
    for _ in range(reps):
        srv.reset()
        t0 = time.time()
        done = srv.run(make_reqs())
        dt = time.time() - t0
        toks = sum(len(r.out) for r in done)
        out = {"requests": len(done), "tokens": toks, "seconds": dt,
               "tok_s": toks / max(dt, 1e-9)}
        if isinstance(srv, PagedServer):
            out.update(srv.stats())
        if best is None or out["tok_s"] > best["tok_s"]:
            best = out
    return best


def _load_trajectory() -> List[Dict]:
    if not os.path.exists(BENCH_PATH):
        return []
    with open(BENCH_PATH) as f:
        return json.load(f)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--check-regression", action="store_true",
                    help="fail on >tol drop of the paged/slots uniform tok/s "
                         "ratio vs the last committed trajectory point")
    ap.add_argument("--regression-tol", type=float, default=0.20)
    args = ap.parse_args()

    baseline = _load_trajectory()  # read BEFORE appending
    cfg = get_config(args.arch, smoke=args.smoke)
    uniform = lambda: _uniform_mix(cfg.vocab_size, args.requests, 16, args.max_new)
    shared = lambda: _shared_prefix_mix(cfg.vocab_size, args.requests, 32,
                                        max(4, args.max_new // 2))

    results: Dict[str, Dict] = {"uniform": {}, "shared_prefix": {}}
    for engine in ("slots", "paged"):
        srv = make_server(cfg, engine=engine, batch=args.batch,
                          max_seq=args.max_seq, page_size=args.page_size)
        srv.run(uniform())  # warmup: compile prefill/decode/extend paths
        srv.run(shared())
        results["uniform"][engine] = _timed_run(srv, uniform)
        results["shared_prefix"][engine] = _timed_run(srv, shared)
        for mix in results:
            emit(f"serve/{mix}/{engine}", 1e6 / max(results[mix][engine]["tok_s"], 1e-9),
                 f"tok_s={results[mix][engine]['tok_s']:.1f}")

    ratio = (results["uniform"]["paged"]["tok_s"]
             / max(results["uniform"]["slots"]["tok_s"], 1e-9))
    entry = {
        "ts": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "platform": jax.default_backend(),
        "arch": args.arch,
        "smoke": bool(args.smoke),
        "batch": args.batch,
        "max_seq": args.max_seq,
        "page_size": args.page_size,
        "uniform": results["uniform"],
        "shared_prefix": results["shared_prefix"],
        "paged_over_slots_uniform": ratio,
    }
    saved = results["shared_prefix"]["paged"].get("prefill_tokens_saved", 0)
    print(f"[serve_bench] uniform paged/slots tok/s ratio: {ratio:.2f}")
    print(f"[serve_bench] shared-prefix prefill tokens saved: {saved}")

    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(BENCH_PATH, "w") as f:
        json.dump(baseline + [entry], f, indent=1, default=float)
    print(f"[serve_bench] appended trajectory point #{len(baseline) + 1} -> {BENCH_PATH}")

    rc = 0
    if saved <= 0:
        print("[serve_bench] FAIL: shared-prefix mix saved no prefill tokens")
        rc = 1
    if args.check_regression and baseline:
        prev = baseline[-1]["paged_over_slots_uniform"]
        floor = prev * (1.0 - args.regression_tol)
        if ratio < floor:
            print(f"[serve_bench] FAIL: paged/slots ratio {ratio:.2f} regressed "
                  f">{args.regression_tol:.0%} below committed {prev:.2f}")
            rc = 1
        else:
            print(f"[serve_bench] regression gate OK: {ratio:.2f} >= {floor:.2f} "
                  f"(committed {prev:.2f} - {args.regression_tol:.0%})")
    return rc


if __name__ == "__main__":
    sys.exit(main())
