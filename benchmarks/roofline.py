"""Roofline report: reads the dry-run artifact JSON and emits the per-cell
three-term table (compute / memory / collective seconds, bottleneck, useful
FLOPs ratio, roofline fraction) + a markdown table for EXPERIMENTS.md."""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

from benchmarks.common import RESULTS_DIR, emit

DRYRUN = os.path.join(RESULTS_DIR, "dryrun.json")


def load(path: str = DRYRUN) -> Dict:
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        return json.load(f)


def rows(results: Dict, mesh: Optional[str] = "16x16") -> List[Dict]:
    out = []
    for key, rec in sorted(results.items()):
        if rec.get("status") != "ok":
            continue
        arch, shape, m = key.split("|")
        if mesh and m != mesh:
            continue
        rl = rec["roofline"]
        out.append({
            "arch": arch, "shape": shape, "mesh": m,
            "t_compute": rl["t_compute_s"], "t_memory": rl["t_memory_s"],
            "t_collective": rl["t_collective_s"], "bottleneck": rl["bottleneck"],
            "useful": rl["useful_flops_ratio"], "fraction": rl["roofline_fraction"],
            "params": rec.get("params", 0),
            "bytes_per_dev": rec["memory"]["peak_bytes_est"],
            "collectives": rec["collectives"].get("total", {}),
        })
    return out


def markdown_table(rws: List[Dict]) -> str:
    hdr = ("| arch | shape | t_compute | t_memory | t_coll | bound | "
           "useful(6ND/HLO) | roofline frac | bytes/dev |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rws:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']*1e3:.1f}ms | "
            f"{r['t_memory']*1e3:.1f}ms | {r['t_collective']*1e3:.1f}ms | "
            f"{r['bottleneck']} | {r['useful']:.2f} | {r['fraction']:.3f} | "
            f"{r['bytes_per_dev']/2**30:.2f}GiB |")
    return hdr + "\n".join(lines) + "\n"


def bench_roofline(quick: bool = False) -> List[Dict]:
    results = load()
    if not results:
        emit("roofline/missing", 0.0, "run repro.launch.dryrun first")
        return []
    all_rows = []
    for mesh in ("16x16", "2x16x16"):
        rws = rows(results, mesh)
        all_rows += rws
        for r in rws:
            step = max(r["t_compute"], r["t_memory"], r["t_collective"])
            emit(f"roofline/{mesh}/{r['arch']}/{r['shape']}", step * 1e6,
                 f"bound={r['bottleneck']};frac={r['fraction']:.3f};useful={r['useful']:.2f}")
        if rws:
            os.makedirs(RESULTS_DIR, exist_ok=True)
            with open(os.path.join(RESULTS_DIR, f"roofline_{mesh}.md"), "w") as f:
                f.write(markdown_table(rws))
    return all_rows
