"""DCN gradient-reduction benchmark: dense vs hierarchical int8+EF all-reduce,
as an APPEND-ONLY perf trajectory (``benchmarks/results/BENCH_dcn.json``).

A single process forces 2 host devices and runs the full V-cycle twice over a
("pod", "data", "model") = (2, 1, 1) mesh -- the pod axis standing in for the
DCN (between-pods) dimension where bandwidth dominates:

  * ``dense``   -- the explicit shard_map reduction, f32 pmean over pod+data.
  * ``int8_ef`` -- hierarchical reduction: the DCN hop carries the packed
                   int8 error-feedback payload (``ef_int8_psum``).

Each invocation appends one trajectory point recording:

  * **bytes-on-wire per step over the DCN axis**, analytic, per V-cycle level
    (the gradient tree is level-shaped, so the coalesced levels ship fewer
    bytes twice over): f32 elements vs int8 elements + one f32 scale per
    leaf.  The schedule-weighted overall ratio is the headline number --
    dtype-exact arithmetic, so it is hardware-independent.
  * **the trace probe**: how many compiled steps actually contain
    ``ef_int8_psum`` (acceptance is "asserted via call probe, not config").
  * **loss-trajectory deviation** between the two runs: int8+EF must track
    dense within quantization noise or the compression is eating signal.

``--check-regression`` gates the invariants (exit 1 on violation): probe > 0,
overall wire ratio >= --min-ratio (default 3x), max loss deviation <=
--loss-tol.  All three are hardware-independent, so a laptop, CI runner and
TPU host share one trajectory file.

Smoke scale by default: runs on CPU in about a minute (the CI ``dcn-drill``
job runs exactly this).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time
from typing import Dict, List

BENCH_PATH = os.path.join(os.path.dirname(__file__), "results", "BENCH_dcn.json")


def _load_trajectory() -> List[Dict]:
    if not os.path.exists(BENCH_PATH):
        return []
    with open(BENCH_PATH) as f:
        return json.load(f)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=12,
                    help="top-level V-cycle step budget (smoke scale)")
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="kept for CLI symmetry with the other benches")
    ap.add_argument("--min-ratio", type=float, default=3.0,
                    help="required DCN bytes-on-wire reduction (dense/int8)")
    ap.add_argument("--loss-tol", type=float, default=5e-2,
                    help="max allowed |dense - int8_ef| loss deviation")
    ap.add_argument("--check-regression", action="store_true",
                    help="fail (exit 1) when the probe never fires, the wire "
                         "ratio is < --min-ratio, or the int8_ef loss "
                         "trajectory drifts > --loss-tol from dense")
    args = ap.parse_args()

    # 2 host devices BEFORE the backend initializes: the pod axis needs rank 2
    from repro.launch.mesh import ensure_host_devices

    ensure_host_devices(2)

    import jax
    import numpy as np

    from benchmarks.common import batch_fn_for
    from repro.config import (BlockSpec, ModelConfig, MultiLevelConfig,
                              TrainConfig, uniform_stages)
    from repro.core.vcycle import VCycleRunner
    from repro.distributed.compression import (dense_wire_bytes,
                                               ef_psum_calls,
                                               int8_wire_bytes,
                                               reset_ef_psum_probe)

    baseline = _load_trajectory()  # read BEFORE appending

    import jax.numpy as jnp

    cfg = ModelConfig(name="dcn-bench", family="dense", d_model=32, n_heads=4,
                      n_kv_heads=2, d_ff=64, vocab_size=128,
                      stages=uniform_stages(3, BlockSpec("attn", "dense")),
                      qk_norm=True, remat="none", attn_impl="plain",
                      compute_dtype=jnp.float32)
    tc = TrainConfig(steps=args.steps, warmup_steps=1, peak_lr=3e-4,
                     batch_size=4, seq_len=16, log_every=2)
    ml = MultiLevelConfig(n_levels=2, alpha=0.25, e_a_frac=0.25,
                          e_small_frac=0.5)
    mesh = jax.make_mesh((2, 1, 1), ("pod", "data", "model"))
    bf = batch_fn_for(cfg, tc)

    runs: Dict[str, Dict] = {}
    outs = {}
    for mode in ("dense", "int8_ef"):
        reset_ef_psum_probe()
        runner = VCycleRunner(
            cfg, ml, dataclasses.replace(tc, grad_compression=mode),
            bf, seed=0, mesh=mesh)
        t0 = time.time()
        outs[mode] = runner.run()
        runs[mode] = {"seconds": time.time() - t0,
                      "final_loss": float(outs[mode].history.loss[-1]),
                      "probe_traced_steps": ef_psum_calls()}
        print(f"[dcn_bench] {mode}: {runs[mode]['seconds']:.1f}s "
              f"final_loss={runs[mode]['final_loss']:.4f} "
              f"probe={runs[mode]['probe_traced_steps']}", flush=True)

    probe = runs["int8_ef"]["probe_traced_steps"]
    max_dev = float(np.max(np.abs(
        np.asarray(outs["dense"].history.loss)
        - np.asarray(outs["int8_ef"].history.loss))))

    # analytic DCN bytes-on-wire per step, per level (grad tree == param tree)
    plan = runner.plan
    levels: Dict[int, Dict] = {}
    for level in sorted({p.level for p in plan}):
        shapes = jax.eval_shape(runner.models[level].init, jax.random.PRNGKey(0))
        d, c = dense_wire_bytes(shapes), int8_wire_bytes(shapes)
        levels[level] = {"dense_bytes_per_step": int(d),
                         "int8_bytes_per_step": int(c),
                         "ratio": d / c}
    total_d = sum(p.steps * levels[p.level]["dense_bytes_per_step"] for p in plan)
    total_c = sum(p.steps * levels[p.level]["int8_bytes_per_step"] for p in plan)
    overall = total_d / total_c
    per_level = ", ".join(f"l{k}={v['ratio']:.2f}x"
                          for k, v in sorted(levels.items()))
    print(f"[dcn_bench] wire ratio overall={overall:.2f}x ({per_level}) "
          f"max_loss_dev={max_dev:.4f}", flush=True)

    entry = {
        "ts": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "platform": jax.default_backend(),
        "mesh": list(mesh.devices.shape),
        "steps": args.steps,
        "runs": runs,
        "max_loss_dev": max_dev,
        "wire": {"levels": {str(k): v for k, v in levels.items()},
                 "schedule_dense_bytes": int(total_d),
                 "schedule_int8_bytes": int(total_c),
                 "overall_ratio": overall},
    }
    os.makedirs(os.path.dirname(BENCH_PATH), exist_ok=True)
    with open(BENCH_PATH, "w") as f:
        json.dump(baseline + [entry], f, indent=1, default=float)
    print(f"[dcn_bench] appended trajectory point #{len(baseline) + 1} "
          f"-> {BENCH_PATH}", flush=True)

    if args.check_regression:
        failures = []
        if probe <= 0:
            failures.append("ef_int8_psum never traced into a compiled step")
        if runs["dense"]["probe_traced_steps"] != 0:
            failures.append("dense run touched the compressed path")
        if overall < args.min_ratio:
            failures.append(f"wire ratio {overall:.2f} < {args.min_ratio}")
        if max_dev > args.loss_tol:
            failures.append(f"loss deviation {max_dev:.4f} > {args.loss_tol}")
        if failures:
            for msg in failures:
                print(f"[dcn_bench] REGRESSION: {msg}", flush=True)
            return 1
        print("[dcn_bench] regression gate passed", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
