"""Per-family V-cycle benchmark: every model family on ONE accounting basis
(FLOPs saving at matched quality, exactly as tests/test_baselines.py pins it)
PLUS the energy/CO2 conversion (core/flops.py EnergyModel; DESIGN.md §7).

One arena per family -- dense LM, MoE (``coalesce_experts=True``: pairwise
expert merging with router-consistent carried scalars), SSM (xLSTM), hybrid
(jamba-style mamba+attn+MoE) and ViT -- each running from-scratch vs the
2-level V-cycle on the same deterministic data stream.  The table reports,
per family:

  * FLOPs to the scratch arm's final quality for both arms + the saving,
  * the same FLOPs priced in joules / kWh / kgCO2e on a named device
    envelope (the saving carries over verbatim: energy is linear in FLOPs
    on a fixed device+utilization basis, which is the point of keeping ONE
    basis),
  * the level configs the ProjectionPlan derived, so the table is
    self-describing about what actually coalesced.

Smoke scale: CPU-runnable; only relative numbers matter, as everywhere else
in benchmarks/.
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Dict

from benchmarks.common import Arena, emit, proxy_tc, save_json
from repro.config import MultiLevelConfig
from repro.configs import get_config, paper_models
from repro.core import flops as flops_lib
from repro.core import plans as plans_lib
from repro.core.vcycle import run_vcycle

ML_FAMILY = MultiLevelConfig(n_levels=2, alpha=0.25, e_a_frac=0.05,
                             e_small_frac=0.5)


def family_configs(quick: bool = False) -> Dict:
    """The five family arms.  Smoke registry configs, trimmed so the table
    stays CPU-runnable; MoE/hybrid turn on expert coalescing (the beyond-paper
    extension this table exists to exercise)."""
    dense = get_config("tinyllama-1.1b", smoke=True)
    moe = get_config("phi3.5-moe-42b-a6.6b", smoke=True).replace(
        coalesce_experts=True)
    ssm = get_config("xlstm-125m", smoke=True)
    hybrid = get_config("jamba-1.5-large-398b", smoke=True).replace(
        coalesce_experts=True)
    vit = paper_models.deit_proxy(d_model=64, n_layers=4)
    out = {"dense": dense, "moe": moe, "ssm": ssm, "hybrid": hybrid, "vit": vit}
    if quick:
        out.pop("hybrid")  # the slowest compile; --quick keeps one MoE arm
    return out


def _clear():
    import jax

    jax.clear_caches()  # long bench runs accumulate jit dylibs -> LLVM ENOMEM


def bench_family(quick: bool = False, *, device: str = "tpu-v4",
                 utilization: float = 0.4) -> Dict:
    results: Dict = {"basis": {"device": device, "utilization": utilization,
                               "note": "energy = EnergyModel(device, util) "
                                       "applied to the SAME pinned FLOPs "
                                       "accounting as every other table"}}
    for fam, cfg in family_configs(quick).items():
        _clear()
        tc = proxy_tc(quick, seq_len=16 if cfg.family != "vit" else 24,
                      batch_size=4)
        plan = plans_lib.build_plan(cfg, ML_FAMILY)
        arena = Arena(cfg, tc)
        t0 = time.time()
        out = run_vcycle(cfg, ML_FAMILY, tc, arena.batch_fn, seed=0,
                         target_loss=arena.target)
        saving = arena.saving(out.history)
        row = {
            "config": cfg.name,
            "hooks": list(plan.hooks),
            "width_axes": {k: int(v) for k, v in plan.width_axes.items()},
            "protected_axes": list(plan.protected_axes),
            "carried": {k: float(v) for k, v in plan.carried.items()},
            "saving": saving,
            # the SAME flops numbers, priced in joules/kgCO2e (linear, so the
            # saving fraction is identical by construction -- one basis)
            "energy": {
                "scratch": flops_lib.energy_report(
                    saving["base_flops"], device, utilization=utilization),
                "ours": flops_lib.energy_report(
                    saving["ours_flops"], device, utilization=utilization)
                if saving["ours_flops"] == saving["ours_flops"] else None,
            },
            "history": out.history.to_dict(),
        }
        results[fam] = row
        e = row["energy"]["scratch"]
        emit(f"family/{fam}", (time.time() - t0) * 1e6
             / max(len(out.history.step), 1),
             f"flops_saving={saving['flops_saving']:.3f} "
             f"scratch_kwh={e['kwh']:.3e} kgco2e={e['kgco2e']:.3e}")
    # quick runs keep their own file so they never clobber the committed
    # full 5-family table
    save_json("table_family_quick" if quick else "table_family", results)
    return results


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--device", default="tpu-v4",
                    choices=sorted(flops_lib.DEVICES))
    ap.add_argument("--utilization", type=float, default=0.4)
    args = ap.parse_args()
    bench_family(args.quick, device=args.device, utilization=args.utilization)
    return 0


if __name__ == "__main__":
    sys.exit(main())
