"""Shared benchmark scaffolding: proxy-scale experiment arena + CSV emission.

All paper-table benchmarks share one deterministic Markov-LM arena per model
family so "Saving (FLOPs)" is computed against the same from-scratch reference
exactly as the paper does (target = baseline's final quality; saving =
1 - FLOPs_method/FLOPs_baseline at that quality).
"""
from __future__ import annotations

import functools
import json
import os
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.config import ModelConfig, MultiLevelConfig, TrainConfig
from repro.core.vcycle import History, run_scratch, saving_vs_baseline
from repro.data import MarkovLM, lm_batch, masked_lm_batch, vision_batch

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def save_json(name: str, payload) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


def proxy_tc(quick: bool = False, **kw) -> TrainConfig:
    base = dict(steps=90 if quick else 150, warmup_steps=8, peak_lr=3e-3,
                batch_size=8, seq_len=24, log_every=3)
    base.update(kw)
    return TrainConfig(**base)


def batch_fn_for(cfg: ModelConfig, tc: TrainConfig) -> Callable[[int], Dict]:
    if cfg.family == "vit":
        from repro.models.vit import n_patches, patch_dim

        return lambda step: vision_batch(tc.seed, step, tc.batch_size, n_patches(cfg),
                                         patch_dim(cfg), cfg.n_classes)
    chain = MarkovLM(cfg.vocab_size)
    if cfg.family == "encoder":
        return lambda step: masked_lm_batch(chain, tc.seed, step, tc.batch_size,
                                            tc.seq_len, mask_id=cfg.vocab_size - 1)
    return lambda step: lm_batch(chain, tc.seed, step, tc.batch_size, tc.seq_len)


class Arena:
    """One model family's benchmark arena with a cached scratch baseline."""

    def __init__(self, cfg: ModelConfig, tc: TrainConfig):
        self.cfg = cfg
        self.tc = tc
        self.batch_fn = batch_fn_for(cfg, tc)
        self._base: Optional[History] = None
        self._step_us: float = 0.0

    @property
    def baseline(self) -> History:
        if self._base is None:
            t0 = time.time()
            _, self._base = run_scratch(self.cfg, self.tc, self.batch_fn, seed=0)
            self._step_us = (time.time() - t0) / self.tc.steps * 1e6
        return self._base

    @property
    def target(self) -> float:
        return float(self.baseline.smoothed(5)[1][-1])

    @property
    def step_us(self) -> float:
        self.baseline
        return self._step_us

    def saving(self, hist: History) -> Dict[str, float]:
        return saving_vs_baseline(self.baseline, hist)


def time_call(fn, *args, reps: int = 5, **kw) -> float:
    """Wall-time per call in microseconds (after one warmup)."""
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6
