"""Kernel micro-benchmarks: correctness (max|err| vs oracle) + wall time of
the pure-jnp oracle path on this host (the Pallas kernel itself targets TPU;
interpret-mode timing is not meaningful and is reported only as a check)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.kernels import ops, ref


def bench_kernels(quick: bool = False) -> None:
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    shapes = [(1, 4, 256, 64)] if quick else [(1, 4, 256, 64), (2, 8, 512, 64)]
    for (B, H, S, D) in shapes:
        q = jax.random.normal(ks[0], (B, H, S, D), jnp.bfloat16)
        k = jax.random.normal(ks[1], (B, H, S, D), jnp.bfloat16)
        v = jax.random.normal(ks[2], (B, H, S, D), jnp.bfloat16)
        fn = jax.jit(lambda q, k, v: ref.naive_attention(q, k, v, causal=True))
        us = time_call(fn, q, k, v, reps=3)
        got = ops.flash_attention(q, k, v, causal=True, block_q=128, block_k=128)
        err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                    - fn(q, k, v).astype(jnp.float32))))
        emit(f"kernels/flash_attention/B{B}H{H}S{S}D{D}", us, f"max_err={err:.2e}")

    w = jax.random.normal(ks[0], (4096, 2048), jnp.float32)
    fn = jax.jit(lambda w: ref.coalesce_pair_ref(w, axis=0, w0=0.5))
    us = time_call(fn, w, reps=5)
    got = ops.coalesce_pair(w, axis=0, w0=0.5)
    err = float(jnp.max(jnp.abs(got - fn(w))))
    emit("kernels/coalesce_pair/4096x2048", us, f"max_err={err:.2e}")

    a = jax.random.normal(ks[0], (2048, 2048), jnp.float32)
    b = jax.random.normal(ks[1], (2048, 2048), jnp.float32)
    fn = jax.jit(lambda a, b: ref.interp_axpy_ref(a, b, 0.25))
    us = time_call(fn, a, b, reps=5)
    err = float(jnp.max(jnp.abs(ops.interp_axpy(a, b, 0.25) - fn(a, b))))
    emit("kernels/interp_axpy/2048x2048", us, f"max_err={err:.2e}")
