"""Kernel micro-benchmarks: every op x backend through the dispatch registry.

For each registered implementation we report wall time and max|err| vs the
kernels/ref.py oracle, then write one ``BENCH_kernels_<backend>.json`` per
backend under benchmarks/results/ so the per-backend perf trajectory
populates over time.  Off-TPU the "pallas" backend resolves to the
interpreter: its numbers are a correctness check, not a performance claim
(the flag in the JSON records which executable actually ran).
"""
from __future__ import annotations

import functools
from typing import Dict, List

import jax
import jax.numpy as jnp

from benchmarks.common import emit, save_json, time_call
from repro.kernels import dispatch, ref


def _err(got, want) -> float:
    return float(jnp.max(jnp.abs(got.astype(jnp.float32) - want.astype(jnp.float32))))


def _sweep_backend(backend: str, quick: bool) -> List[Dict]:
    rows: List[Dict] = []
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    # the sweep only runs backends that resolve to themselves, so "pallas"
    # here implies real Mosaic; only the interpret backend needs small shapes
    interpreted = backend == "pallas-interpret"
    # interpret-mode timing on big shapes is pointlessly slow; shrink the sweep
    small = quick or interpreted

    # -- flash_attention (fwd + bwd through the custom VJP) ------------------
    shapes = [(1, 4, 256, 64)] if small else [(1, 4, 256, 64), (2, 8, 512, 64)]
    impl = dispatch.get_impl("flash_attention", backend)
    for (B, H, S, D) in shapes:
        q = jax.random.normal(ks[0], (B, H, S, D), jnp.bfloat16)
        k = jax.random.normal(ks[1], (B, H, S, D), jnp.bfloat16)
        v = jax.random.normal(ks[2], (B, H, S, D), jnp.bfloat16)
        fwd = jax.jit(functools.partial(impl, causal=True, block_q=128, block_k=128))
        us = time_call(fwd, q, k, v, reps=1 if interpreted else 3)
        err = _err(fwd(q, k, v), ref.naive_attention(q, k, v, causal=True))
        name = f"kernels/flash_attention/{backend}/B{B}H{H}S{S}D{D}"
        emit(name, us, f"max_err={err:.2e}")
        rows.append({"op": "flash_attention", "shape": f"B{B}H{H}S{S}D{D}",
                     "us": us, "max_err": err})
        grad = jax.jit(jax.grad(lambda q, k, v: jnp.sum(
            fwd(q, k, v).astype(jnp.float32)), argnums=(0, 1, 2)))
        us_b = time_call(grad, q, k, v, reps=1 if interpreted else 3)
        emit(name + "/bwd", us_b, "grad")
        rows.append({"op": "flash_attention_bwd", "shape": f"B{B}H{H}S{S}D{D}",
                     "us": us_b, "max_err": None})

    # -- coalesce_pair -------------------------------------------------------
    shape = (1024, 512) if small else (4096, 2048)
    w = jax.random.normal(ks[0], shape, jnp.float32)
    impl = dispatch.get_impl("coalesce_pair", backend)
    fn = jax.jit(functools.partial(impl, axis=0, w0=0.5))
    us = time_call(fn, w, reps=1 if interpreted else 5)
    err = _err(fn(w), ref.coalesce_pair_ref(w, axis=0, w0=0.5))
    name = f"kernels/coalesce_pair/{backend}/{shape[0]}x{shape[1]}"
    emit(name, us, f"max_err={err:.2e}")
    rows.append({"op": "coalesce_pair", "shape": f"{shape[0]}x{shape[1]}",
                 "us": us, "max_err": err})

    # -- interp_axpy ---------------------------------------------------------
    shape = (1024, 1024) if small else (2048, 2048)
    a = jax.random.normal(ks[0], shape, jnp.float32)
    b = jax.random.normal(ks[1], shape, jnp.float32)
    impl = dispatch.get_impl("interp_axpy", backend)
    fn = jax.jit(lambda a, b: impl(a, b, 0.25))
    us = time_call(fn, a, b, reps=1 if interpreted else 5)
    err = _err(fn(a, b), ref.interp_axpy_ref(a, b, 0.25))
    name = f"kernels/interp_axpy/{backend}/{shape[0]}x{shape[1]}"
    emit(name, us, f"max_err={err:.2e}")
    rows.append({"op": "interp_axpy", "shape": f"{shape[0]}x{shape[1]}",
                 "us": us, "max_err": err})
    return rows


def bench_kernels(quick: bool = False) -> None:
    for backend in dispatch.BACKENDS:
        resolved = dispatch.resolve_backend("flash_attention", backend)
        if resolved != backend:
            # off-TPU "pallas" downgrades to the interpreter; skip the
            # duplicate sweep and let the pallas-interpret row speak
            emit(f"kernels/{backend}", 0.0, f"resolved_to={resolved}")
            continue
        rows = _sweep_backend(backend, quick)
        save_json(f"BENCH_kernels_{backend}", {
            "backend": backend,
            "platform": jax.default_backend(),
            "interpreted": backend == "pallas-interpret",
            "entries": rows,
        })
