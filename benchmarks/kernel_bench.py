"""Kernel micro-benchmarks as APPEND-ONLY per-backend perf trajectories
(``benchmarks/results/BENCH_kernels_<backend>.json``), matching the
``BENCH_serve.json`` / ``BENCH_dcn.json`` discipline.

Every op runs at ONE canonical fixed shape per op (the shapes the very first
committed points used), so the microsecond numbers stay comparable across the
whole trajectory -- a point appended today diffs cleanly against the first
one.  Each invocation appends one point per self-resolving backend:

  {ts, platform, interpreted, entries: [{op, shape, us, max_err}]}

Legacy single-dict files (the pre-trajectory schema) are transparently
migrated: the old dict becomes the trajectory's first point.

``--check-regression`` gates (exit 1 on violation), per backend:

  * coverage  -- every (op, shape) present in the last committed point must
                 be present in the new one (a silently dropped kernel is a
                 regression, not a cleanup),
  * accuracy  -- max|err| vs the kernels/ref.py oracle within the per-op
                 tolerance (hardware-independent),
  * speed     -- us <= --max-slowdown x the last committed point's us, but
                 ONLY when that point ran on the same jax platform (a laptop
                 point must not gate a TPU run; cross-platform points simply
                 extend the trajectory).

Off-TPU the "pallas" backend resolves to the interpreter: its numbers are a
correctness check, not a performance claim (the ``interpreted`` flag records
which executable actually ran; interpreted timing is exempt from the speed
gate -- interpreter wall time tracks Python, not the kernel).
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import sys
import time
from typing import Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

# ONE canonical shape per op -- frozen since the first committed points; new
# shapes mean a new op name, not a silent redefinition of an existing row.
CANONICAL_SHAPES = {
    "flash_attention": dict(B=1, H=4, S=256, D=64),
    "flash_attention_bwd": dict(B=1, H=4, S=256, D=64),
    "coalesce_pair": (1024, 512),
    "interp_axpy": (1024, 1024),
}

# hardware-independent max|err| gates vs the kernels/ref.py oracles
ERR_TOL = {
    "flash_attention": 5e-2,   # bf16 accumulation differences
    "coalesce_pair": 1e-4,
    "interp_axpy": 1e-4,
}


def _bench_path(backend: str) -> str:
    return os.path.join(RESULTS_DIR, f"BENCH_kernels_{backend}.json")


def _load_trajectory(backend: str) -> List[Dict]:
    path = _bench_path(backend)
    if not os.path.exists(path):
        return []
    with open(path) as f:
        data = json.load(f)
    if isinstance(data, dict):  # legacy single-point schema -> first point
        data.setdefault("ts", None)
        return [data]
    return data


def _err(got, want) -> float:
    import jax.numpy as jnp

    return float(jnp.max(jnp.abs(got.astype(jnp.float32) - want.astype(jnp.float32))))


def _bench_backend(backend: str) -> List[Dict]:
    """One trajectory point's entries: every op at its canonical shape."""
    import jax
    import jax.numpy as jnp

    from benchmarks.common import emit, time_call
    from repro.kernels import dispatch, ref

    rows: List[Dict] = []
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    interpreted = backend == "pallas-interpret"

    # -- flash_attention (fwd + bwd through the custom VJP) ------------------
    s = CANONICAL_SHAPES["flash_attention"]
    B, H, S, D = s["B"], s["H"], s["S"], s["D"]
    q = jax.random.normal(ks[0], (B, H, S, D), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, H, S, D), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, H, S, D), jnp.bfloat16)
    impl = dispatch.get_impl("flash_attention", backend)
    fwd = jax.jit(functools.partial(impl, causal=True, block_q=128, block_k=128))
    us = time_call(fwd, q, k, v, reps=1 if interpreted else 3)
    err = _err(fwd(q, k, v), ref.naive_attention(q, k, v, causal=True))
    shape = f"B{B}H{H}S{S}D{D}"
    emit(f"kernels/flash_attention/{backend}/{shape}", us, f"max_err={err:.2e}")
    rows.append({"op": "flash_attention", "shape": shape, "us": us, "max_err": err})
    grad = jax.jit(jax.grad(lambda q, k, v: jnp.sum(
        fwd(q, k, v).astype(jnp.float32)), argnums=(0, 1, 2)))
    us_b = time_call(grad, q, k, v, reps=1 if interpreted else 3)
    emit(f"kernels/flash_attention/{backend}/{shape}/bwd", us_b, "grad")
    rows.append({"op": "flash_attention_bwd", "shape": shape, "us": us_b,
                 "max_err": None})

    # -- coalesce_pair -------------------------------------------------------
    shp = CANONICAL_SHAPES["coalesce_pair"]
    w = jax.random.normal(ks[0], shp, jnp.float32)
    impl = dispatch.get_impl("coalesce_pair", backend)
    fn = jax.jit(functools.partial(impl, axis=0, w0=0.5))
    us = time_call(fn, w, reps=1 if interpreted else 5)
    err = _err(fn(w), ref.coalesce_pair_ref(w, axis=0, w0=0.5))
    shape = f"{shp[0]}x{shp[1]}"
    emit(f"kernels/coalesce_pair/{backend}/{shape}", us, f"max_err={err:.2e}")
    rows.append({"op": "coalesce_pair", "shape": shape, "us": us, "max_err": err})

    # -- interp_axpy ---------------------------------------------------------
    shp = CANONICAL_SHAPES["interp_axpy"]
    a = jax.random.normal(ks[0], shp, jnp.float32)
    b = jax.random.normal(ks[1], shp, jnp.float32)
    impl = dispatch.get_impl("interp_axpy", backend)
    fn = jax.jit(lambda a, b: impl(a, b, 0.25))
    us = time_call(fn, a, b, reps=1 if interpreted else 5)
    err = _err(fn(a, b), ref.interp_axpy_ref(a, b, 0.25))
    shape = f"{shp[0]}x{shp[1]}"
    emit(f"kernels/interp_axpy/{backend}/{shape}", us, f"max_err={err:.2e}")
    rows.append({"op": "interp_axpy", "shape": shape, "us": us, "max_err": err})
    return rows


def _check_point(backend: str, baseline: List[Dict], entry: Dict,
                 max_slowdown: float) -> List[str]:
    """Regression messages for the freshly appended ``entry`` vs the LAST
    committed trajectory point (empty list = gate passed)."""
    failures: List[str] = []
    new = {(r["op"], r["shape"]): r for r in entry["entries"]}
    for (op, _shape), r in new.items():
        tol = ERR_TOL.get(op)
        if tol is not None and r["max_err"] is not None and r["max_err"] > tol:
            failures.append(f"{backend}/{op}: max_err {r['max_err']:.3e} > {tol}")
    if not baseline:
        return failures
    last = baseline[-1]
    old = {(r["op"], r["shape"]): r for r in last.get("entries", [])}
    for key in old:
        if key not in new:
            failures.append(f"{backend}/{key[0]}@{key[1]}: dropped from sweep")
    # interpreted timing tracks Python, not the kernel; and a point from a
    # different platform must not gate this machine's wall clock
    if entry["interpreted"] or last.get("platform") != entry["platform"]:
        return failures
    for key, r_old in old.items():
        r_new = new.get(key)
        if r_new is None or not r_old.get("us"):
            continue
        ratio = r_new["us"] / r_old["us"]
        if ratio > max_slowdown:
            failures.append(
                f"{backend}/{key[0]}@{key[1]}: {r_new['us']:.0f}us is "
                f"{ratio:.2f}x the last committed {r_old['us']:.0f}us "
                f"(limit {max_slowdown}x)")
    return failures


def bench_kernels(quick: bool = False, *, check_regression: bool = False,
                  max_slowdown: float = 4.0) -> int:
    """Append one trajectory point per self-resolving backend; returns the
    number of regression failures (0 = gate passed).  ``quick`` is accepted
    for driver symmetry -- the canonical shapes are already smoke-sized."""
    del quick
    import jax

    from benchmarks.common import emit
    from repro.kernels import dispatch

    all_failures: List[str] = []
    for backend in dispatch.BACKENDS:
        resolved = dispatch.resolve_backend("flash_attention", backend)
        if resolved != backend:
            # off-TPU "pallas" downgrades to the interpreter; skip the
            # duplicate sweep and let the pallas-interpret row speak
            emit(f"kernels/{backend}", 0.0, f"resolved_to={resolved}")
            continue
        baseline = _load_trajectory(backend)  # read BEFORE appending
        entry = {
            "ts": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            "backend": backend,
            "platform": jax.default_backend(),
            "interpreted": backend == "pallas-interpret",
            "entries": _bench_backend(backend),
        }
        os.makedirs(RESULTS_DIR, exist_ok=True)
        with open(_bench_path(backend), "w") as f:
            json.dump(baseline + [entry], f, indent=1, default=float)
        print(f"[kernel_bench] appended trajectory point #{len(baseline) + 1} "
              f"-> {_bench_path(backend)}", flush=True)
        if check_regression:
            all_failures += _check_point(backend, baseline, entry, max_slowdown)
    if check_regression:
        for msg in all_failures:
            print(f"[kernel_bench] REGRESSION: {msg}", flush=True)
        if not all_failures:
            print("[kernel_bench] regression gate passed", flush=True)
    return len(all_failures)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="kept for CLI symmetry with the other benches")
    ap.add_argument("--max-slowdown", type=float, default=4.0,
                    help="allowed us ratio vs the last committed same-platform "
                         "point (CI runners are noisy; 4x flags real cliffs)")
    ap.add_argument("--check-regression", action="store_true",
                    help="fail (exit 1) on dropped ops, accuracy outside the "
                         "per-op tolerance, or a same-platform slowdown > "
                         "--max-slowdown vs the last committed point")
    args = ap.parse_args()
    n = bench_kernels(args.quick, check_regression=args.check_regression,
                      max_slowdown=args.max_slowdown)
    return 1 if (args.check_regression and n) else 0


if __name__ == "__main__":
    sys.exit(main())
