#!/usr/bin/env bash
# End-to-end preemption drill for the V-cycle launcher:
#   1. start a real `python -m repro.launch.train --vcycle` run,
#   2. SIGKILL it as soon as the first checkpoint is published,
#   3. restart with identical args,
#   4. require the "[vcycle] resumed at phase=... level=... seg_step=..." line.
# Exercises the whole path -- CLI, CheckpointManager atomic publish, VCycleState
# restore -- not just the library functions (see also
# tests/test_system.py::test_vcycle_launcher_sigkill_resume).
set -euo pipefail
cd "$(dirname "$0")/.."

CKPT=$(mktemp -d)
LOG=$(mktemp)
trap 'rm -rf "$CKPT" "$LOG"' EXIT
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

ARGS=(--arch tinyllama-1.1b --smoke --vcycle --levels 2 --steps 40
      --batch 2 --seq 16 --ckpt-dir "$CKPT" --ckpt-every 3)

python -m repro.launch.train "${ARGS[@]}" >"$LOG" 2>&1 &
PID=$!

# wait (up to ~4 min) for the first atomic checkpoint publish
for _ in $(seq 1 2400); do
  [ -f "$CKPT/manifest.json" ] && break
  kill -0 "$PID" 2>/dev/null || break
  sleep 0.1
done

if kill -0 "$PID" 2>/dev/null; then
  kill -9 "$PID"
  wait "$PID" 2>/dev/null || true
  echo "[smoke] SIGKILLed training after first checkpoint"
else
  echo "[smoke] WARNING: training exited before the kill; resume not exercised" >&2
fi

[ -f "$CKPT/manifest.json" ] || { echo "FAIL: no checkpoint was written"; tail -20 "$LOG"; exit 1; }

OUT=$(python -m repro.launch.train "${ARGS[@]}")
LINE=$(echo "$OUT" | grep -m1 "resumed at phase=") || {
  echo "FAIL: restart did not print the resume line"; echo "$OUT" | tail -20; exit 1; }
echo "PASS: $LINE"
