#!/usr/bin/env bash
# End-to-end preemption drills for the launcher, two acts:
#
# Act 1 -- SIGKILL (no notice):
#   1. start a real `python -m repro.launch.train --vcycle` run,
#   2. SIGKILL it as soon as the first checkpoint is published,
#   3. restart with identical args,
#   4. require the "[vcycle] resumed at phase=... level=... seg_step=..." line.
#
# Act 2 -- SIGTERM (preemption notice):
#   1. start a plain run whose --ckpt-every cadence can never fire,
#   2. SIGTERM it mid-training,
#   3. require exit 0, the "[preempt]" final BLOCKING checkpoint, and a
#      restart that resumes from exactly that save.
#
# Exercises the whole path -- CLI, CheckpointManager atomic publish, VCycleState
# restore, PreemptionGuard -- not just the library functions (see also
# tests/test_system.py::test_vcycle_launcher_sigkill_resume and
# ::test_vcycle_launcher_sigterm_checkpoints).
set -euo pipefail
cd "$(dirname "$0")/.."

CKPT=$(mktemp -d)
LOG=$(mktemp)
CKPT2=$(mktemp -d)
LOG2=$(mktemp)
trap 'rm -rf "$CKPT" "$LOG" "$CKPT2" "$LOG2"' EXIT
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

ARGS=(--arch tinyllama-1.1b --smoke --vcycle --levels 2 --steps 40
      --batch 2 --seq 16 --ckpt-dir "$CKPT" --ckpt-every 3)

python -m repro.launch.train "${ARGS[@]}" >"$LOG" 2>&1 &
PID=$!

# wait (up to ~4 min) for the first atomic checkpoint publish
for _ in $(seq 1 2400); do
  [ -f "$CKPT/manifest.json" ] && break
  kill -0 "$PID" 2>/dev/null || break
  sleep 0.1
done

if kill -0 "$PID" 2>/dev/null; then
  kill -9 "$PID"
  wait "$PID" 2>/dev/null || true
  echo "[smoke] SIGKILLed training after first checkpoint"
else
  echo "[smoke] WARNING: training exited before the kill; resume not exercised" >&2
fi

[ -f "$CKPT/manifest.json" ] || { echo "FAIL: no checkpoint was written"; tail -20 "$LOG"; exit 1; }

OUT=$(python -m repro.launch.train "${ARGS[@]}")
LINE=$(echo "$OUT" | grep -m1 "resumed at phase=") || {
  echo "FAIL: restart did not print the resume line"; echo "$OUT" | tail -20; exit 1; }
echo "PASS (act 1): $LINE"

# ----- Act 2: SIGTERM preemption-aware checkpoint ---------------------------
# cadence (10000) never fires within 300 steps: the ONLY way a checkpoint can
# exist is the SIGTERM handler's final blocking save
ARGS2=(--arch tinyllama-1.1b --smoke --steps 300 --batch 2 --seq 16
       --ckpt-dir "$CKPT2" --ckpt-every 10000)

python -m repro.launch.train "${ARGS2[@]}" >"$LOG2" 2>&1 &
PID2=$!

# wait (up to ~4 min) until training is demonstrably stepping
for _ in $(seq 1 2400); do
  grep -q "\[train\] step" "$LOG2" 2>/dev/null && break
  kill -0 "$PID2" 2>/dev/null || break
  sleep 0.1
done

kill -0 "$PID2" 2>/dev/null || {
  echo "FAIL: training exited before SIGTERM could be delivered"; tail -20 "$LOG2"; exit 1; }
kill -TERM "$PID2"
RC=0; wait "$PID2" || RC=$?
[ "$RC" -eq 0 ] || { echo "FAIL: SIGTERM exit code $RC (want clean 0)"; tail -20 "$LOG2"; exit 1; }
grep -q "\[preempt\] SIGTERM: final checkpoint" "$LOG2" || {
  echo "FAIL: no preemption checkpoint line"; tail -20 "$LOG2"; exit 1; }
[ -f "$CKPT2/manifest.json" ] || { echo "FAIL: SIGTERM wrote no checkpoint"; exit 1; }

OUT2=$(python -m repro.launch.train "${ARGS2[@]}")
LINE2=$(echo "$OUT2" | grep -m1 "resumed from step") || {
  echo "FAIL: restart did not resume from the preemption save"; echo "$OUT2" | tail -20; exit 1; }
echo "PASS (act 2): $LINE2"
