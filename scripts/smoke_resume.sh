#!/usr/bin/env bash
# End-to-end preemption drills for the launcher, three acts:
#
# Act 1 -- SIGKILL (no notice):
#   1. start a real `python -m repro.launch.train --vcycle` run,
#   2. SIGKILL it as soon as the first checkpoint is published,
#   3. restart with identical args,
#   4. require the "[vcycle] resumed at phase=... level=... seg_step=..." line.
#
# Act 2 -- SIGTERM (preemption notice):
#   1. start a plain run whose --ckpt-every cadence can never fire,
#   2. SIGTERM it mid-training,
#   3. require exit 0, the "[preempt]" final BLOCKING checkpoint, and a
#      restart that resumes from exactly that save.
#
# Act 3 -- multi-process SIGTERM drain (cross-host preemption):
#   1. start a 2-process jax.distributed V-cycle run (localhost coordinator,
#      --mesh 2x1 spanning both processes, coordinated sharded checkpoints),
#   2. SIGTERM process 1 ONLY,
#   3. require BOTH processes to exit 0 with a "[preempt]" drain save at the
#      SAME global step (the notice propagates via an all-reduced flag),
#   4. restart as a SINGLE process and require the mid-V-cycle resume line
#      (checkpoints are process-count-elastic).
#
# Act 4 -- content-addressed local-dir store through the CLI:
#   1. run with --ckpt-local-dir (v3 object pool + manifests in a per-host
#      dir), SIGKILL after the first publish,
#   2. restart with identical args and require the mid-V-cycle resume line,
#   3. require the objects/ pool and a step manifest to actually exist.
#
# Exercises the whole path -- CLI, CheckpointManager atomic publish, VCycleState
# restore, PreemptionGuard -- not just the library functions (see also
# tests/test_system.py::test_vcycle_launcher_sigkill_resume,
# ::test_vcycle_launcher_sigterm_checkpoints and tests/test_multiprocess.py).
set -euo pipefail
cd "$(dirname "$0")/.."

CKPT=$(mktemp -d)
LOG=$(mktemp)
CKPT2=$(mktemp -d)
LOG2=$(mktemp)
CKPT3=$(mktemp -d)
LOG3A=$(mktemp)
LOG3B=$(mktemp)
CKPT4=$(mktemp -d)
LOG4=$(mktemp)
trap 'rm -rf "$CKPT" "$LOG" "$CKPT2" "$LOG2" "$CKPT3" "$LOG3A" "$LOG3B" "$CKPT4" "$LOG4"' EXIT
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

ARGS=(--arch tinyllama-1.1b --smoke --vcycle --levels 2 --steps 40
      --batch 2 --seq 16 --ckpt-dir "$CKPT" --ckpt-every 3)

python -m repro.launch.train "${ARGS[@]}" >"$LOG" 2>&1 &
PID=$!

# wait (up to ~4 min) for the first atomic checkpoint publish
for _ in $(seq 1 2400); do
  [ -f "$CKPT/manifest.json" ] && break
  kill -0 "$PID" 2>/dev/null || break
  sleep 0.1
done

if kill -0 "$PID" 2>/dev/null; then
  kill -9 "$PID"
  wait "$PID" 2>/dev/null || true
  echo "[smoke] SIGKILLed training after first checkpoint"
else
  echo "[smoke] WARNING: training exited before the kill; resume not exercised" >&2
fi

[ -f "$CKPT/manifest.json" ] || { echo "FAIL: no checkpoint was written"; tail -20 "$LOG"; exit 1; }

OUT=$(python -m repro.launch.train "${ARGS[@]}")
LINE=$(echo "$OUT" | grep -m1 "resumed at phase=") || {
  echo "FAIL: restart did not print the resume line"; echo "$OUT" | tail -20; exit 1; }
echo "PASS (act 1): $LINE"

# ----- Act 2: SIGTERM preemption-aware checkpoint ---------------------------
# cadence (10000) never fires within 300 steps: the ONLY way a checkpoint can
# exist is the SIGTERM handler's final blocking save
ARGS2=(--arch tinyllama-1.1b --smoke --steps 300 --batch 2 --seq 16
       --ckpt-dir "$CKPT2" --ckpt-every 10000)

python -m repro.launch.train "${ARGS2[@]}" >"$LOG2" 2>&1 &
PID2=$!

# wait (up to ~4 min) until training is demonstrably stepping
for _ in $(seq 1 2400); do
  grep -q "\[train\] step" "$LOG2" 2>/dev/null && break
  kill -0 "$PID2" 2>/dev/null || break
  sleep 0.1
done

kill -0 "$PID2" 2>/dev/null || {
  echo "FAIL: training exited before SIGTERM could be delivered"; tail -20 "$LOG2"; exit 1; }
kill -TERM "$PID2"
RC=0; wait "$PID2" || RC=$?
[ "$RC" -eq 0 ] || { echo "FAIL: SIGTERM exit code $RC (want clean 0)"; tail -20 "$LOG2"; exit 1; }
grep -q "\[preempt\] SIGTERM: final checkpoint" "$LOG2" || {
  echo "FAIL: no preemption checkpoint line"; tail -20 "$LOG2"; exit 1; }
[ -f "$CKPT2/manifest.json" ] || { echo "FAIL: SIGTERM wrote no checkpoint"; exit 1; }

OUT2=$(python -m repro.launch.train "${ARGS2[@]}")
LINE2=$(echo "$OUT2" | grep -m1 "resumed from step") || {
  echo "FAIL: restart did not resume from the preemption save"; echo "$OUT2" | tail -20; exit 1; }
echo "PASS (act 2): $LINE2"

# ----- Act 3: 2-process coordinated SIGTERM drain + 1-process resume --------
PORT=$(python -c "import socket; s=socket.socket(); s.bind(('127.0.0.1',0)); print(s.getsockname()[1]); s.close()")
ARGS3=(--arch tinyllama-1.1b --smoke --vcycle --levels 2 --steps 40
       --batch 4 --seq 16 --f32 --ckpt-dir "$CKPT3" --ckpt-every 1000)
MP=(--mesh 2x1 --coordinator "127.0.0.1:$PORT" --num-processes 2)

python -m repro.launch.train "${ARGS3[@]}" "${MP[@]}" --process-id 0 >"$LOG3A" 2>&1 &
PID3A=$!
python -m repro.launch.train "${ARGS3[@]}" "${MP[@]}" --process-id 1 >"$LOG3B" 2>&1 &
PID3B=$!

# wait (up to ~4 min) until the cycle is demonstrably past the first segment
for _ in $(seq 1 2400); do
  grep -q "coalescing" "$LOG3A" 2>/dev/null && break
  kill -0 "$PID3A" 2>/dev/null || break
  sleep 0.1
done
kill -0 "$PID3A" 2>/dev/null && kill -0 "$PID3B" 2>/dev/null || {
  echo "FAIL: a process died before SIGTERM could be delivered"
  tail -20 "$LOG3A"; tail -20 "$LOG3B"; exit 1; }

kill -TERM "$PID3B"  # ONE process gets the preemption notice...
RCA=0; RCB=0
wait "$PID3A" || RCA=$?
wait "$PID3B" || RCB=$?
[ "$RCA" -eq 0 ] && [ "$RCB" -eq 0 ] || {
  echo "FAIL: drain exits were rc=$RCA/rc=$RCB (want 0/0)"
  tail -20 "$LOG3A"; tail -20 "$LOG3B"; exit 1; }
# ...and BOTH drain through the same final-save step
STEP_A=$(grep -o "blocking V-cycle checkpoint at global_step [0-9]*" "$LOG3A" | grep -o "[0-9]*$")
STEP_B=$(grep -o "blocking V-cycle checkpoint at global_step [0-9]*" "$LOG3B" | grep -o "[0-9]*$")
[ -n "$STEP_A" ] && [ "$STEP_A" = "$STEP_B" ] || {
  echo "FAIL: drain steps disagree ('$STEP_A' vs '$STEP_B')"
  tail -20 "$LOG3A"; tail -20 "$LOG3B"; exit 1; }
[ -f "$CKPT3/manifest.json" ] || { echo "FAIL: drain wrote no checkpoint"; exit 1; }

OUT3=$(python -m repro.launch.train "${ARGS3[@]}")   # single process, no mesh
LINE3=$(echo "$OUT3" | grep -m1 "resumed at phase=") || {
  echo "FAIL: single-process restart did not resume the 2-process save"
  echo "$OUT3" | tail -20; exit 1; }
echo "PASS (act 3): both processes drained at step $STEP_A; $LINE3"

# ----- Act 4: --ckpt-local-dir (content-addressed per-host store) -----------
ARGS4=(--arch tinyllama-1.1b --smoke --vcycle --levels 2 --steps 40
       --batch 2 --seq 16 --ckpt-local-dir "$CKPT4" --ckpt-every 3)

python -m repro.launch.train "${ARGS4[@]}" >"$LOG4" 2>&1 &
PID4=$!

for _ in $(seq 1 2400); do
  [ -f "$CKPT4/manifest.json" ] && break
  kill -0 "$PID4" 2>/dev/null || break
  sleep 0.1
done

if kill -0 "$PID4" 2>/dev/null; then
  kill -9 "$PID4"
  wait "$PID4" 2>/dev/null || true
  echo "[smoke] SIGKILLed local-dir training after first checkpoint"
fi
[ -f "$CKPT4/manifest.json" ] || { echo "FAIL: local-dir wrote no checkpoint"; tail -20 "$LOG4"; exit 1; }
[ -d "$CKPT4/objects" ] || { echo "FAIL: no content-addressed object pool"; ls "$CKPT4"; exit 1; }
ls "$CKPT4"/step_*/objects.json >/dev/null 2>&1 || {
  echo "FAIL: no v3 step manifest"; ls -R "$CKPT4" | head -30; exit 1; }

OUT4=$(python -m repro.launch.train "${ARGS4[@]}")
LINE4=$(echo "$OUT4" | grep -m1 "resumed at phase=") || {
  echo "FAIL: restart did not resume from the local-dir store"; echo "$OUT4" | tail -20; exit 1; }
echo "PASS (act 4): $LINE4"
