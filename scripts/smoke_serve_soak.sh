#!/usr/bin/env bash
# Train->serve soak drill through the real CLIs:
#
#   1. start a `python -m repro.launch.train --vcycle` run publishing a
#      checkpoint every 2 global steps,
#   2. wait for the first atomic manifest publish,
#   3. run `python -m repro.launch.serve --reload-from <ckpt-dir>` under
#      continuous traffic while the trainer keeps publishing,
#   4. require at least one live weight reload (the "[serve] reloads=N"
#      summary line) and ZERO dropped requests ("[serve] rejected req"
#      must not appear).
#
# Exercises the whole hand-off path -- trainer CLI, CheckpointManager atomic
# publish, ManifestWatcher digest-diff poll, EngineCore tick-boundary swap --
# not just the library functions (see also
# tests/test_system.py::test_serve_soak_live_trainer_reloads and
# tests/test_reload.py).
set -euo pipefail
cd "$(dirname "$0")/.."

CKPT=$(mktemp -d)
TLOG=$(mktemp)
SLOG=$(mktemp)
TPID=""
cleanup() {
  if [ -n "$TPID" ] && kill -0 "$TPID" 2>/dev/null; then
    kill -9 "$TPID" 2>/dev/null || true
    wait "$TPID" 2>/dev/null || true
  fi
  rm -rf "$CKPT" "$TLOG" "$SLOG"
}
trap cleanup EXIT
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

python -m repro.launch.train --arch tinyllama-1.1b --smoke --vcycle \
  --levels 2 --steps 40 --batch 2 --seq 16 \
  --ckpt-dir "$CKPT" --ckpt-every 2 >"$TLOG" 2>&1 &
TPID=$!

# wait (up to ~4 min) for the first atomic checkpoint publish
for _ in $(seq 1 2400); do
  [ -f "$CKPT/manifest.json" ] && break
  kill -0 "$TPID" 2>/dev/null || break
  sleep 0.1
done
[ -f "$CKPT/manifest.json" ] || {
  echo "FAIL: trainer never published a checkpoint"; tail -20 "$TLOG"; exit 1; }

# serve under traffic while the trainer keeps publishing into the same dir
python -m repro.launch.serve --arch tinyllama-1.1b --requests 24 --batch 4 \
  --max-new 8 --reload-from "$CKPT" >"$SLOG" 2>&1 || {
  echo "FAIL: serve exited nonzero"; tail -20 "$SLOG"; exit 1; }

if grep -q "rejected req" "$SLOG"; then
  echo "FAIL: server dropped requests during the soak"; tail -20 "$SLOG"; exit 1
fi
if ! grep -Eq "reloads=[1-9]" "$SLOG"; then
  echo "FAIL: no live weight reload happened"; tail -20 "$SLOG"; exit 1
fi
echo "PASS (serve soak): $(grep -m1 'reloads=' "$SLOG")"
