"""End-to-end driver: pre-train a ~100M-parameter GPT for a few hundred steps
with the V-cycle schedule, fault-tolerant checkpointing and auto-resume.

This is the deliverable-(b) end-to-end example; it runs the production
launcher code path (repro.launch.train).  On this CPU container the default
invocation uses a reduced width so a few hundred steps finish in minutes; pass
--full-100m to run the real ~100M config (slower).

    PYTHONPATH=src python examples/vcycle_pretrain.py [--steps 200] [--full-100m]
"""
import argparse

from repro.config import BlockSpec, ModelConfig, MultiLevelConfig, TrainConfig, uniform_stages
from repro.core.flops import total_params
from repro.launch.train import train_vcycle_ckpt
from repro.checkpoint import CheckpointManager
from repro.models.api import build_model


def gpt_100m() -> ModelConfig:
    # ~100M params: 12L, d=768 (GPT-Base shape), vocab 8192 synthetic
    return ModelConfig(name="gpt-100m", family="dense", d_model=768, n_heads=12,
                       n_kv_heads=12, d_ff=3072, vocab_size=8192,
                       stages=uniform_stages(12, BlockSpec("attn", "dense")),
                       act="gelu", norm="layernorm", use_bias=True, remat="none")


def gpt_small() -> ModelConfig:
    return gpt_100m().replace(name="gpt-12m", d_model=256, n_heads=4, n_kv_heads=4,
                              d_ff=1024, stages=uniform_stages(8, BlockSpec("attn", "dense")))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/vcycle_pretrain_ckpt")
    args = ap.parse_args()

    cfg = gpt_100m() if args.full_100m else gpt_small()
    n = total_params(build_model(cfg).specs())
    print(f"model {cfg.name}: {n/1e6:.1f}M params, {cfg.n_layers} layers")
    tc = TrainConfig(steps=args.steps, warmup_steps=max(args.steps // 20, 1),
                     peak_lr=6e-4, batch_size=8, seq_len=128, log_every=10)
    ml = MultiLevelConfig(n_levels=2, alpha=0.25, e_a_frac=0.05, e_small_frac=0.5)
    ckpt = CheckpointManager(args.ckpt_dir)
    out = train_vcycle_ckpt(cfg, ml, tc, ckpt=ckpt, ckpt_every=50)
    print(f"done; final loss {out.history.loss[-1]:.4f}; "
          f"checkpoint in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
