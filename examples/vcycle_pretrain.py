"""End-to-end driver: pre-train with the V-cycle schedule, fault-tolerant
checkpointing and auto-resume -- for ANY model family.

This is the deliverable-(b) end-to-end example; it runs the production
launcher code path (repro.launch.train).  On this CPU container the default
invocation uses a reduced width so a few hundred steps finish in minutes; pass
--full-100m to run the real ~100M config (slower).

``--config`` picks the model family: a tiny same-family config runs the SAME
V-cycle end-to-end -- the family's ProjectionPlan (printed at startup) decides
what coalesces, what is protected, and which scalars carry across levels:

    PYTHONPATH=src python examples/vcycle_pretrain.py [--steps 200] [--full-100m]
    PYTHONPATH=src python examples/vcycle_pretrain.py --config moe --steps 40
    PYTHONPATH=src python examples/vcycle_pretrain.py --config ssm --steps 40
"""
import argparse

from repro.config import BlockSpec, ModelConfig, MultiLevelConfig, TrainConfig, uniform_stages
from repro.core.flops import total_params
from repro.launch.train import train_vcycle_ckpt
from repro.checkpoint import CheckpointManager
from repro.models.api import build_model

FAMILIES = ("dense", "moe", "ssm", "hybrid", "vit")


def gpt_100m() -> ModelConfig:
    # ~100M params: 12L, d=768 (GPT-Base shape), vocab 8192 synthetic
    return ModelConfig(name="gpt-100m", family="dense", d_model=768, n_heads=12,
                       n_kv_heads=12, d_ff=3072, vocab_size=8192,
                       stages=uniform_stages(12, BlockSpec("attn", "dense")),
                       act="gelu", norm="layernorm", use_bias=True, remat="none")


def gpt_small() -> ModelConfig:
    return gpt_100m().replace(name="gpt-12m", d_model=256, n_heads=4, n_kv_heads=4,
                              d_ff=1024, stages=uniform_stages(8, BlockSpec("attn", "dense")))


def family_config(name: str) -> ModelConfig:
    """A tiny same-family config per ``--config`` choice.  MoE and hybrid turn
    on expert coalescing so the router-consistent merge path is exercised."""
    from repro.configs import get_config, paper_models

    if name == "dense":
        return gpt_small()
    if name == "moe":
        return get_config("phi3.5-moe-42b-a6.6b", smoke=True).replace(
            coalesce_experts=True)
    if name == "ssm":
        return get_config("xlstm-125m", smoke=True)
    if name == "hybrid":
        return get_config("jamba-1.5-large-398b", smoke=True).replace(
            coalesce_experts=True)
    if name == "vit":
        return paper_models.deit_proxy(d_model=64, n_layers=4)
    raise SystemExit(f"unknown --config {name!r} (choose from {FAMILIES})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--config", default="dense", choices=FAMILIES,
                    help="model family to pre-train (tiny same-family config)")
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/vcycle_pretrain_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50,
                    help="checkpoint every N global steps; a live server "
                         "polling --ckpt-dir (serve --reload-from) swaps "
                         "each published step in by digest diff")
    args = ap.parse_args()

    if args.full_100m:
        cfg = gpt_100m()
    else:
        cfg = family_config(args.config)
    model = build_model(cfg)
    n = total_params(model.specs())
    print(f"model {cfg.name}: {n/1e6:.1f}M params, {cfg.n_layers} layers")
    ml = MultiLevelConfig(n_levels=2, alpha=0.25, e_a_frac=0.05, e_small_frac=0.5)
    print(model.projection_plan(ml).describe())
    # registry smoke configs are narrower than gpt_small: shorter sequences
    # keep the non-dense families CPU-fast without changing the schedule
    seq = 128 if args.config == "dense" or args.full_100m else 32
    tc = TrainConfig(steps=args.steps, warmup_steps=max(args.steps // 20, 1),
                     peak_lr=6e-4, batch_size=8, seq_len=seq, log_every=10)
    ckpt = CheckpointManager(args.ckpt_dir)
    out = train_vcycle_ckpt(cfg, ml, tc, ckpt=ckpt, ckpt_every=args.ckpt_every)
    print(f"done; final loss {out.history.loss[-1]:.4f}; "
          f"checkpoint in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
