"""Fault-tolerance demo, three acts:

1. plain training: checkpoint, simulate preemption, resume on a DIFFERENT
   mesh layout (elastic re-shard on restore);
2. V-cycle training: SIGKILL-style preemption in the middle of the upward
   sweep, then auto-resume at the exact (phase, level, step) -- the pending
   de-coalesce/interpolate transition replays deterministically, with the
   resumed run re-sharded onto a mesh (elastic mid-V-cycle re-shard);
3. multi-process: a real 2-process `jax.distributed` V-cycle run (localhost
   coordinator, ("data","model") mesh spanning both processes, coordinated
   per-process checkpoint shards), preempted by a SIGTERM to ONE process --
   the drain flag all-reduces so both save the same step and exit 0 -- then
   resumed by a SINGLE process (checkpoints are process-count-elastic).

For the real CLI versions: `--mesh DxM` + SIGKILL/SIGTERM drills live in
scripts/smoke_resume.sh and tests/test_system.py / test_multiprocess.py.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import os
import shutil
import signal
import socket
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.config import MultiLevelConfig, TrainConfig
from repro.configs import get_config
from repro.core.vcycle import VCycleRunner
from repro.launch.train import make_batch_fn, make_vcycle_save_cb, train_vcycle_ckpt
from repro.models.api import build_model, init_train_state, make_train_step

CKPT = "/tmp/elastic_demo_ckpt"
CKPT_VCYCLE = "/tmp/elastic_demo_vcycle_ckpt"
CKPT_MP = "/tmp/elastic_demo_mp_ckpt"


class Preempted(RuntimeError):
    """Stand-in for a SIGKILL: aborts the process mid-training."""


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    cfg = get_config("tinyllama-1.1b", smoke=True)
    tc = TrainConfig(steps=12, warmup_steps=1, batch_size=4, seq_len=32, log_every=2)
    model = build_model(cfg)
    batch_fn = make_batch_fn(cfg, tc)
    step = jax.jit(make_train_step(model, tc))
    cm = CheckpointManager(CKPT)

    params, opt = init_train_state(model, tc, jax.random.PRNGKey(0))
    print("== phase 1: train 6 steps on 'mesh A' then checkpoint ==")
    for i in range(6):
        params, opt, m = step(params, opt, batch_fn(i))
    cm.save(6, {"params": params, "opt": opt}, meta={"step": 6})
    print(f"checkpointed at step 6 (loss {float(m['loss']):.4f})")

    print("== simulated preemption: process state dropped ==")
    del params, opt

    print("== phase 2: resume onto a different mesh layout ==")
    # container has 1 CPU device; the mechanism is identical for any topology:
    # pass target NamedShardings and restore() re-shards with device_put.
    mesh_b = jax.make_mesh((1, 1), ("data", "model"))
    p0, o0 = init_train_state(model, tc, jax.random.PRNGKey(0))
    sh = {
        "params": jax.tree.map(lambda _: NamedSharding(mesh_b, P()), p0),
        "opt": jax.tree.map(lambda _: NamedSharding(mesh_b, P()), o0),
    }
    restored, meta = cm.restore({"params": p0, "opt": o0}, shardings=sh)
    params, opt = restored["params"], restored["opt"]
    print(f"resumed from step {meta['step']} onto mesh {dict(mesh_b.shape)}")
    for i in range(meta["step"], tc.steps):
        params, opt, m = step(params, opt, batch_fn(i))
    print(f"finished at step {tc.steps} (loss {float(m['loss']):.4f}) -- "
          "deterministic data sharding made the resumed stream identical")


def main_vcycle():
    shutil.rmtree(CKPT_VCYCLE, ignore_errors=True)
    cfg = get_config("tinyllama-1.1b", smoke=True)
    tc = TrainConfig(steps=12, warmup_steps=1, batch_size=2, seq_len=16, log_every=4)
    ml = MultiLevelConfig(n_levels=2, alpha=0.25, e_a_frac=0.25, e_small_frac=0.5)
    cm = CheckpointManager(CKPT_VCYCLE)

    print("== phase 1: V-cycle, checkpoint every 2 steps, die mid-upward-sweep ==")
    runner = VCycleRunner(cfg, ml, tc, make_batch_fn(cfg, tc), seed=0, verbose=True)
    save_cb = make_vcycle_save_cb(cm, schedule=runner.plan)

    def killing_cb(state, params, opt_state):
        save_cb(state, params, opt_state)
        if state.phase == "up":
            raise Preempted(f"preempted at global step {state.global_step}")

    try:
        runner.run(ckpt_cb=killing_cb, ckpt_every=2)
    except Preempted as e:
        cm.wait()  # a real SIGKILL relies on atomic publish instead
        print(f"== {e}; restarting fresh ==")

    print("== phase 2: auto-resume picks up inside the upward sweep, and "
          "re-shards onto a mesh while doing it ==")
    # elastic mid-V-cycle re-shard: the checkpoint was written UNSHARDED, but
    # the resumed run is mesh-parallel -- params, opt and the stashed
    # params_before_* trees all land on the mesh layouts (the container has 1
    # CPU device, so 1x1; the mechanism is identical for any DxM -- the
    # launcher's `--mesh 2x1` does exactly this after a `--mesh 1x2` save)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    out = train_vcycle_ckpt(cfg, ml, tc, ckpt=cm, ckpt_every=4, mesh=mesh)
    print(f"finished: final loss {out.history.loss[-1]:.4f}, "
          f"total FLOPs {out.total_flops:.3e}")


def main_multiprocess():
    shutil.rmtree(CKPT_MP, ignore_errors=True)
    print("== phase 1: 2-process V-cycle (localhost coordinator), SIGTERM "
          "delivered to process 1 only ==")
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    args = [sys.executable, "-m", "repro.launch.train", "--arch",
            "tinyllama-1.1b", "--smoke", "--vcycle", "--levels", "2",
            "--steps", "40", "--batch", "4", "--seq", "16", "--f32",
            "--ckpt-dir", CKPT_MP, "--ckpt-every", "1000"]
    mp = ["--mesh", "2x1", "--coordinator", f"127.0.0.1:{port}",
          "--num-processes", "2"]
    env = dict(os.environ, PYTHONPATH="src")
    logs = [f"{CKPT_MP}.rank{i}.log" for i in (0, 1)]
    os.makedirs(CKPT_MP, exist_ok=True)
    procs = []
    for i in (0, 1):
        with open(logs[i], "w") as lf:
            procs.append(subprocess.Popen(
                args + mp + ["--process-id", str(i)], env=env, stdout=lf,
                stderr=subprocess.STDOUT))
    # wait until training is demonstrably stepping (past the first segment),
    # so the SIGTERM lands mid-cycle with the preemption handler installed
    try:
        deadline = time.time() + 240
        while time.time() < deadline and all(p.poll() is None for p in procs):
            if "coalescing" in open(logs[0]).read():
                break
            time.sleep(0.2)
        procs[1].send_signal(signal.SIGTERM)  # ONE process gets the notice...
        for p in procs:
            p.wait(timeout=240)
    finally:
        for p in procs:  # a wedged drain must not leave orphans training
            if p.poll() is None:
                p.kill()
                p.wait()
    # ...and the all-reduced drain flag makes BOTH save the same step + exit 0
    for i, p in enumerate(procs):
        out = open(logs[i]).read()
        drain = [l for l in out.splitlines() if "[preempt]" in l]
        print(f"process {i}: exit {p.returncode}; " +
              (drain[-1] if drain else "(no drain line)"))

    print("== phase 2: the 2-process checkpoint resumes under ONE process ==")
    out = subprocess.run(args, env=env, capture_output=True, text=True,
                         timeout=480).stdout
    for l in out.splitlines():
        if "resumed at phase=" in l or "total training FLOPs" in l:
            print(l)


if __name__ == "__main__":
    main()
    main_vcycle()
    main_multiprocess()
