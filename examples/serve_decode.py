"""Serve a small model with batched requests + continuous batching.

Exercises the production decode path (prefill -> per-slot KV splice -> batched
serve_step) that the decode_32k / long_500k dry-run cells compile at scale.

    PYTHONPATH=src python examples/serve_decode.py --arch tinyllama-1.1b
    PYTHONPATH=src python examples/serve_decode.py --arch jamba-1.5-large-398b
"""
import argparse
import time

import numpy as np

from repro.configs import get_config
from repro.launch.serve import Request, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    print(f"serving {cfg.name} (smoke config), continuous batch={args.batch}")
    srv = Server(cfg, batch=args.batch, max_seq=96)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=rng.integers(4, 16)),
                    max_new=args.max_new) for i in range(args.requests)]
    t0 = time.time()
    done = srv.run(reqs)
    dt = time.time() - t0
    tok = sum(len(r.out) for r in done)
    print(f"{len(done)}/{args.requests} requests served, {tok} tokens, "
          f"{tok/dt:.1f} tok/s on CPU")
    for r in done[:4]:
        print(f"  req {r.rid}: {len(r.prompt)} prompt toks -> {r.out[:10]}")


if __name__ == "__main__":
    main()
