"""Serve a small model with batched requests + continuous batching.

Exercises the production decode path at smoke scale: paged KV cache with
block tables and prefix reuse (default), or the dense-slot oracle engine
(--engine slots; required for SSM/hybrid mixers like jamba).  With
--policy speculative the paged engine self-drafts k tokens per tick from
the coalesced level-1 projection of its own weights and verifies them in
one batched full-model step (lossless for greedy decode).  --mesh DxM
shards the paged decode step (model-sharded K/V page pools), and
--reload-from polls a trainer's checkpoint dir for live weight reloads --
swaps land at tick boundaries, never dropping in-flight requests.

    PYTHONPATH=src python examples/serve_decode.py --arch tinyllama-1.1b
    PYTHONPATH=src python examples/serve_decode.py --arch tinyllama-1.1b --engine slots
    PYTHONPATH=src python examples/serve_decode.py --arch tinyllama-1.1b --policy speculative
    PYTHONPATH=src python examples/serve_decode.py --arch tinyllama-1.1b --mesh 1x2
    PYTHONPATH=src python examples/serve_decode.py --reload-from /tmp/vcycle_pretrain_ckpt
    PYTHONPATH=src python examples/serve_decode.py --arch jamba-1.5-large-398b --engine slots
"""
import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--engine", choices=["paged", "slots"], default="paged")
    ap.add_argument("--policy", choices=["greedy", "speculative"], default="greedy")
    ap.add_argument("--draft-k", type=int, default=4)
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--mesh", default="",
                    help="DxM serving mesh, e.g. 1x2 (paged engine only; "
                         "host CPU devices are forced at smoke scale)")
    ap.add_argument("--reload-from", default="",
                    help="checkpoint dir to poll for live weight reloads "
                         "(a trainer's --ckpt-dir)")
    ap.add_argument("--poll-every", type=int, default=1)
    args = ap.parse_args()

    # the mesh must exist before anything touches the backend: forcing host
    # devices is env-var-only and silently too late after jax initializes
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_cli_mesh

        mesh = make_cli_mesh(args.mesh)
    from repro.checkpoint import CheckpointManager
    from repro.configs import get_config
    from repro.launch.serve import (ManifestWatcher, PagedServer, Request,
                                    make_server)

    cfg = get_config(args.arch, smoke=True)
    print(f"serving {cfg.name} (smoke config), engine={args.engine}, "
          f"policy={args.policy}, continuous batch={args.batch}"
          + (f", mesh={args.mesh}" if args.mesh else ""))
    srv = make_server(cfg, engine=args.engine, batch=args.batch, max_seq=96,
                      page_size=args.page_size, policy=args.policy,
                      draft_k=args.draft_k, mesh=mesh)
    watcher = None
    if args.reload_from:
        mgr = CheckpointManager(args.reload_from)
        watcher = ManifestWatcher(mgr, like=srv.params,
                                  shardings=getattr(srv, "_param_shardings", None))
        srv.attach_watcher(watcher, poll_every=args.poll_every)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=rng.integers(4, 16)),
                    max_new=args.max_new) for i in range(args.requests)]
    t0 = time.time()
    done = srv.run(reqs)
    dt = time.time() - t0
    tok = sum(len(r.out) for r in done)
    print(f"{len(done)}/{args.requests} requests served, {tok} tokens, "
          f"{tok/dt:.1f} tok/s on CPU")
    if isinstance(srv, PagedServer):
        print(f"  pages: peak {srv.pages_in_use_peak}/{srv.alloc.pool.capacity}, "
              f"prefill tokens saved by prefix reuse: {srv.prefill_tokens_saved}")
        if args.policy == "speculative":
            st = srv.stats()
            print(f"  speculative: accept={st['accept_rate']:.2f} over "
                  f"{st['drafted_tokens']} drafted tokens "
                  f"(draft {st['draft_time_s']:.2f}s / verify {st['verify_time_s']:.2f}s)")
    if watcher is not None:
        print(f"  reloads: {srv.reloads} swaps, steps_seen={watcher.steps_seen}, "
              f"skipped={watcher.steps_skipped}, last={watcher.last_reload_stats}")
    for r in done[:4]:
        print(f"  req {r.rid}: {len(r.prompt)} prompt toks -> {r.out[:10]}")


if __name__ == "__main__":
    main()
