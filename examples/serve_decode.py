"""Serve a small model with batched requests + continuous batching.

Exercises the production decode path at smoke scale: paged KV cache with
block tables and prefix reuse (default), or the dense-slot oracle engine
(--engine slots; required for SSM/hybrid mixers like jamba).  With
--policy speculative the paged engine self-drafts k tokens per tick from
the coalesced level-1 projection of its own weights and verifies them in
one batched full-model step (lossless for greedy decode).

    PYTHONPATH=src python examples/serve_decode.py --arch tinyllama-1.1b
    PYTHONPATH=src python examples/serve_decode.py --arch tinyllama-1.1b --engine slots
    PYTHONPATH=src python examples/serve_decode.py --arch tinyllama-1.1b --policy speculative
    PYTHONPATH=src python examples/serve_decode.py --arch jamba-1.5-large-398b --engine slots
"""
import argparse
import time

import numpy as np

from repro.configs import get_config
from repro.launch.serve import PagedServer, Request, make_server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--engine", choices=["paged", "slots"], default="paged")
    ap.add_argument("--policy", choices=["greedy", "speculative"], default="greedy")
    ap.add_argument("--draft-k", type=int, default=4)
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--page-size", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    print(f"serving {cfg.name} (smoke config), engine={args.engine}, "
          f"policy={args.policy}, continuous batch={args.batch}")
    srv = make_server(cfg, engine=args.engine, batch=args.batch, max_seq=96,
                      page_size=args.page_size, policy=args.policy,
                      draft_k=args.draft_k)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=rng.integers(4, 16)),
                    max_new=args.max_new) for i in range(args.requests)]
    t0 = time.time()
    done = srv.run(reqs)
    dt = time.time() - t0
    tok = sum(len(r.out) for r in done)
    print(f"{len(done)}/{args.requests} requests served, {tok} tokens, "
          f"{tok/dt:.1f} tok/s on CPU")
    if isinstance(srv, PagedServer):
        print(f"  pages: peak {srv.pages_in_use_peak}/{srv.alloc.pool.capacity}, "
              f"prefill tokens saved by prefix reuse: {srv.prefill_tokens_saved}")
        if args.policy == "speculative":
            st = srv.stats()
            print(f"  speculative: accept={st['accept_rate']:.2f} over "
                  f"{st['drafted_tokens']} drafted tokens "
                  f"(draft {st['draft_time_s']:.2f}s / verify {st['verify_time_s']:.2f}s)")
    for r in done[:4]:
        print(f"  req {r.rid}: {len(r.prompt)} prompt toks -> {r.out[:10]}")


if __name__ == "__main__":
    main()
