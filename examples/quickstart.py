"""Quickstart: train a small GPT with the multi-level V-cycle and compare its
FLOPs-to-quality against from-scratch training.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.config import BlockSpec, ModelConfig, MultiLevelConfig, TrainConfig, uniform_stages
from repro.core.vcycle import run_scratch, run_vcycle, saving_vs_baseline
from repro.data import MarkovLM, lm_batch


def main():
    cfg = ModelConfig(
        name="quickstart-gpt", family="dense", d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab_size=256, stages=uniform_stages(4, BlockSpec("attn", "dense")),
        remat="none", attn_impl="plain")
    tc = TrainConfig(steps=120, warmup_steps=10, peak_lr=3e-3, batch_size=16,
                     seq_len=32, log_every=5)
    chain = MarkovLM(cfg.vocab_size)
    batch_fn = lambda step: lm_batch(chain, 0, step, tc.batch_size, tc.seq_len)

    print(f"== from-scratch baseline ({tc.steps} steps) ==")
    _, base = run_scratch(cfg, tc, batch_fn, seed=0)
    print(f"final loss {base.loss[-1]:.3f} (chain entropy floor {chain.entropy():.3f})")

    print("== 2-level V-cycle (paper Algorithm 1) ==")
    ml = MultiLevelConfig(n_levels=2, alpha=0.25, e_a_frac=0.05, e_small_frac=0.5)
    target = float(base.smoothed(5)[1][-1])
    out = run_vcycle(cfg, ml, tc, batch_fn, seed=0, target_loss=target, verbose=True)
    s = saving_vs_baseline(base, out.history)
    print(f"V-cycle reached loss {s['target_loss']:.3f} with "
          f"{s['flops_saving']*100:.1f}% fewer training FLOPs "
          f"({s['ours_flops']:.2e} vs {s['base_flops']:.2e})")


if __name__ == "__main__":
    main()
