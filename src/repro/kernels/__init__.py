# Compute hot-spot kernels (Pallas TPU) + the backend registry.
#
# Import ``repro.kernels.dispatch`` to resolve an op ("flash_attention",
# "coalesce_pair", "interp_axpy") to a backend ("pallas", "pallas-interpret",
# "xla"); see kernels/README.md for selection rules and the
# REPRO_KERNEL_BACKEND override.  ``ops.py`` keeps jit'd direct wrappers,
# ``ref.py`` the pure-jnp oracles used as test/bench ground truth.
