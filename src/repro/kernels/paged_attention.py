"""Paged-attention decode kernel (Pallas TPU): block-table K/V gather.

Serving keeps each sequence's KV cache as a list of fixed-size *pages* drawn
from a shared ``[n_pages, page_size, ...]`` pool instead of one dense
``[batch, max_seq, ...]`` strip (vLLM/flashinfer block-table layout).  Decode
attention then reads K/V *through* the block table, so per-step cost scales
with the number of pages a sequence actually occupies -- not with the
server-wide ``max_seq``.

The kernel uses ``PrefetchScalarGridSpec``: the block table and per-sequence
lengths are scalar-prefetched so the K/V BlockSpec index maps can chase page
ids at grid-issue time (``k_pages[bt[b, m]]`` is a DMA program, not a gather
op).  Grid is ``(batch, kv_head, page)`` with the page axis innermost and
sequential; fp32 online-softmax state (m, l, acc) for the G query heads of
one kv head lives in VMEM scratch across pages, exactly like the flash
forward kernel in ``flash_attention.py``.  Pages past ``ceil(len/P)`` are
skipped with ``pl.when`` -- no MXU issue for table padding.

A sequence of length 0 (an idle decode slot) produces an all-zero output row;
the XLA reference (``ref.paged_attention_ref``) pins the same convention so
backends agree bit-for-bit on masked rows.

Validated in interpret mode against the gather reference and against dense
attention over a contiguously reassembled cache (tests/test_kernels.py,
tests/test_dispatch.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _paged_decode_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_scr, l_scr, acc_scr, *,
                         scale: float, page_size: int, n_tables: int):
    b = pl.program_id(0)
    m = pl.program_id(2)

    @pl.when(m == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)        # [G, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # [P, D]
        v = v_ref[0, :, 0, :].astype(jnp.float32)  # [P, Dv]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        tp = m * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(tp < length, s, -jnp.inf)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        corr = jnp.where(jnp.isfinite(corr), corr, 0.0)  # -inf -> -inf carry
        l_scr[...] = corr * l_scr[...] + jnp.sum(p, axis=-1)
        acc_scr[...] = corr[:, None] * acc_scr[...] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    # skip pages holding no valid token (table padding / short sequences)
    pl.when(m * page_size < length)(_compute)

    @pl.when(m == n_tables - 1)
    def _out():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def paged_attention_decode(
    q: jax.Array,             # [B, KH, G, D]  one query token per sequence
    k_pages: jax.Array,       # [N, P, KH, D]  shared page pool
    v_pages: jax.Array,       # [N, P, KH, Dv]
    block_tables: jax.Array,  # [B, M] int32 page ids (padding entries: 0)
    lengths: jax.Array,       # [B] int32 valid tokens per sequence
    *,
    scale: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    """Decode attention through a block table; returns [B, KH, G, Dv]."""
    B, KH, G, D = q.shape
    N, P, _, Dv = v_pages.shape
    M = block_tables.shape[1]
    scale = D ** -0.5 if scale is None else scale

    kern = functools.partial(_paged_decode_kernel, scale=float(scale),
                             page_size=P, n_tables=M)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KH, M),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, m, bt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, P, 1, D), lambda b, h, m, bt, ln: (bt[b, m], 0, h, 0)),
            pl.BlockSpec((1, P, 1, Dv), lambda b, h, m, bt, ln: (bt[b, m], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dv), lambda b, h, m, bt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, Dv), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KH, G, Dv), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32), q, k_pages, v_pages)
