"""Kernel backend registry + dispatch layer.

Every compute hot-spot the paper optimizes (``flash_attention``,
``coalesce_pair``, ``interp_axpy``) plus the serving-side
``paged_attention_decode`` (block-table KV gather) is registered under three
backends:

  * ``pallas``           -- the real Mosaic TPU kernel (TPU hardware only)
  * ``pallas-interpret`` -- the same kernel body executed by the Pallas
                            interpreter (CPU validation; bit-exact semantics,
                            not a performance path)
  * ``xla``              -- a matrix-free pure-jnp reference that lowers for
                            any backend

Selection order (first hit wins):

  1. an explicit ``backend=`` argument (``ModelConfig.kernel_backend`` is
     threaded here by the layers and operators),
  2. the ``REPRO_KERNEL_BACKEND`` environment variable,
  3. the platform default: ``pallas`` on TPU, ``xla`` elsewhere.

Requesting ``pallas`` off-TPU auto-downgrades to ``pallas-interpret`` (Mosaic
cannot compile on CPU); everything else resolves exactly as asked.  Resolution
happens at trace time, so a jitted caller bakes the chosen backend into its
executable -- no host round-trips inside ``vcycle`` level transitions.
"""
from __future__ import annotations

import os
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.coalesce_pair import coalesce_pair, divisor_block
from repro.kernels.flash_attention import flash_attention_with_vjp
from repro.kernels.interp_axpy import interp_axpy
from repro.kernels.paged_attention import paged_attention_decode

BACKENDS = ("pallas", "pallas-interpret", "xla")
ENV_VAR = "REPRO_KERNEL_BACKEND"

_REGISTRY: Dict[str, Dict[str, Callable]] = {}


def register(op: str, backend: str, fn: Callable, *, override: bool = False) -> None:
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
    impls = _REGISTRY.setdefault(op, {})
    if backend in impls and not override:
        raise ValueError(f"{op}/{backend} already registered")
    impls[backend] = fn


def ops() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def backends(op: str) -> Tuple[str, ...]:
    if op not in _REGISTRY:
        raise KeyError(f"unknown op {op!r}; registered: {ops()}")
    return tuple(b for b in BACKENDS if b in _REGISTRY[op])


def validate_backend(backend: str) -> str:
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown kernel backend {backend!r}; expected one of {BACKENDS}")
    return backend


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_backend() -> str:
    return "pallas" if on_tpu() else "xla"


def resolve_backend(op: str, backend: Optional[str] = None,
                    default: Optional[str] = None) -> str:
    """Resolve the backend name for ``op`` (argument > env > default >
    platform).  ``default`` lets a caller state its own preference (e.g.
    ``attn_impl="pallas"`` prefers pallas) without shadowing the user's
    explicit config/env choice."""
    b = backend or os.environ.get(ENV_VAR) or default or default_backend()
    validate_backend(b)
    if b == "pallas" and not on_tpu():
        b = "pallas-interpret"
    if b not in _REGISTRY.get(op, {}):
        raise KeyError(f"op {op!r} has no {b!r} implementation "
                       f"(available: {backends(op)})")
    return b


def get_impl(op: str, backend: str) -> Callable:
    if op not in _REGISTRY or backend not in _REGISTRY[op]:
        raise KeyError(f"no implementation for {op!r}/{backend!r}")
    return _REGISTRY[op][backend]


def dispatch(op: str, *args, backend: Optional[str] = None, **kw):
    """Resolve and call ``op``.  Safe inside jit: resolution is trace-time."""
    return get_impl(op, resolve_backend(op, backend))(*args, **kw)


# ---------------------------------------------------------------------------
# registered implementations
#
# All backends of one op share a single keyword signature so callers (layers,
# operators, benchmarks, tests) can swap backends without code changes.


def _flash_attention_pallas(q, k, v, *, causal=True, scale=None,
                            block_q=128, block_k=128, interpret=False):
    return flash_attention_with_vjp(q, k, v, causal=causal, scale=scale,
                                    block_q=block_q, block_k=block_k,
                                    interpret=interpret)


def _flash_attention_interpret(q, k, v, *, causal=True, scale=None,
                               block_q=128, block_k=128):
    return _flash_attention_pallas(q, k, v, causal=causal, scale=scale,
                                   block_q=block_q, block_k=block_k,
                                   interpret=True)


def _flash_attention_xla(q, k, v, *, causal=True, scale=None,
                         block_q=0, block_k=0):
    return ref.naive_attention(q, k, v, causal=causal, scale=scale)


def coalesce_pair_xla(w, *, axis: int, w0: float = 0.5, block: int = 0):
    """Matrix-free XLA reference: one fused slice+add pass, any ndim."""
    n = w.shape[axis]
    if n % 2:
        raise ValueError(f"axis {axis} size {n} must be even")
    half = n // 2
    a = jax.lax.slice_in_dim(w, 0, half, axis=axis)
    b = jax.lax.slice_in_dim(w, half, n, axis=axis)
    return (w0 * (a.astype(jnp.float32) + b.astype(jnp.float32))).astype(w.dtype)


def _coalesce_pair_degenerate(w, axis: int, block: int) -> bool:
    """True when ``divisor_block`` would collapse a tile dimension to 1
    (odd/prime or size-1 dims): the Pallas tiles then waste almost the whole
    lane/sublane register or degenerate to per-element grid programs, so the
    XLA backend is the right tool."""
    if w.ndim != 2:
        return True
    half = w.shape[axis] // 2
    other = w.shape[1 - axis]
    return divisor_block(half, block) == 1 or divisor_block(other, block) == 1


def _coalesce_pair_pallas(w, *, axis, w0=0.5, block=256, interpret=False):
    if _coalesce_pair_degenerate(w, axis, block):
        return coalesce_pair_xla(w, axis=axis, w0=w0)
    return coalesce_pair(w, axis=axis, w0=w0, block=block, interpret=interpret)


def _coalesce_pair_interpret(w, *, axis, w0=0.5, block=256):
    return _coalesce_pair_pallas(w, axis=axis, w0=w0, block=block, interpret=True)


def _interp_axpy_pallas(a, b, alpha, *, block=1024, interpret=False):
    return interp_axpy(a, b, alpha, block=block, interpret=interpret)


def _interp_axpy_interpret(a, b, alpha, *, block=1024):
    return _interp_axpy_pallas(a, b, alpha, block=block, interpret=True)


def _interp_axpy_xla(a, b, alpha, *, block=0):
    return ref.interp_axpy_ref(a, b, alpha)


def _paged_attention_pallas(q, k_pages, v_pages, block_tables, lengths, *,
                            scale=None, interpret=False):
    return paged_attention_decode(q, k_pages, v_pages, block_tables, lengths,
                                  scale=scale, interpret=interpret)


def _paged_attention_interpret(q, k_pages, v_pages, block_tables, lengths, *,
                               scale=None):
    return _paged_attention_pallas(q, k_pages, v_pages, block_tables, lengths,
                                   scale=scale, interpret=True)


def _paged_attention_xla(q, k_pages, v_pages, block_tables, lengths, *,
                         scale=None):
    return ref.paged_attention_ref(q, k_pages, v_pages, block_tables, lengths,
                                   scale=scale)


register("flash_attention", "pallas", _flash_attention_pallas)
register("flash_attention", "pallas-interpret", _flash_attention_interpret)
register("flash_attention", "xla", _flash_attention_xla)

register("coalesce_pair", "pallas", _coalesce_pair_pallas)
register("coalesce_pair", "pallas-interpret", _coalesce_pair_interpret)
register("coalesce_pair", "xla", coalesce_pair_xla)

register("interp_axpy", "pallas", _interp_axpy_pallas)
register("interp_axpy", "pallas-interpret", _interp_axpy_interpret)
register("interp_axpy", "xla", _interp_axpy_xla)

register("paged_attention_decode", "pallas", _paged_attention_pallas)
register("paged_attention_decode", "pallas-interpret", _paged_attention_interpret)
register("paged_attention_decode", "xla", _paged_attention_xla)
