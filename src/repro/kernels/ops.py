"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the TPU is
the TARGET) -- interpret mode executes the kernel body for correctness while
``interpret=False`` emits the real Mosaic TPU kernel on hardware.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.coalesce_pair import coalesce_pair as _coalesce_pair
from repro.kernels.flash_attention import flash_attention as _flash_attention
from repro.kernels.flash_attention import flash_attention_with_vjp as _flash_attention_vjp
from repro.kernels.interp_axpy import interp_axpy as _interp_axpy
from repro.kernels.paged_attention import paged_attention_decode as _paged_attention_decode


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "scale", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, scale=None, block_q=128, block_k=128,
                    interpret=None):
    interp = (not _on_tpu()) if interpret is None else interpret
    return _flash_attention(q, k, v, causal=causal, scale=scale,
                            block_q=block_q, block_k=block_k, interpret=interp)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "block_q", "block_k", "interpret"))
def flash_attention_vjp(q, k, v, *, causal=True, scale=None, block_q=128,
                        block_k=128, interpret=None):
    """Differentiable variant: Pallas forward and backward kernels."""
    interp = (not _on_tpu()) if interpret is None else interpret
    return _flash_attention_vjp(q, k, v, causal=causal, scale=scale,
                                block_q=block_q, block_k=block_k, interpret=interp)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_attention_decode(q, k_pages, v_pages, block_tables, lengths, *,
                           scale=None, interpret=None):
    """Decode attention through per-sequence block tables (paged KV serving)."""
    interp = (not _on_tpu()) if interpret is None else interpret
    return _paged_attention_decode(q, k_pages, v_pages, block_tables, lengths,
                                   scale=scale, interpret=interp)


@functools.partial(jax.jit, static_argnames=("axis", "w0", "block", "interpret"))
def coalesce_pair(w, *, axis, w0=0.5, block=256, interpret=None):
    interp = (not _on_tpu()) if interpret is None else interpret
    return _coalesce_pair(w, axis=axis, w0=w0, block=block, interpret=interp)


@functools.partial(jax.jit, static_argnames=("alpha", "block", "interpret"))
def interp_axpy(a, b, alpha, *, block=1024, interpret=None):
    interp = (not _on_tpu()) if interpret is None else interpret
    return _interp_axpy(a, b, alpha, block=block, interpret=interp)
