"""Flash attention kernels (Pallas TPU): forward + recompute-based backward.

TPU-native adaptation: MXU-aligned [block_q x block_k] tiles streamed through
VMEM, online softmax with fp32 (m, l, acc) VMEM scratch carried across the
innermost (sequential) grid dimension, causal blocks skipped with ``pl.when``
(no wasted MXU issue on fully-masked tiles -- the FLOP-exactness the pure-XLA
path only gets from the pairs-scan).

Grid: (batch*heads, n_q_blocks, n_k_blocks); the k-block axis is innermost so
scratch accumulators persist per (bh, qi) like the reference TPU kernel.

The backward follows the flash-attention recipe (same as the XLA-level
``_flash_xla_bwd`` in layers/attention.py): save only (q, k, v, out, lse),
recompute the probabilities per tile from the saved log-sum-exp, and run two
kernels -- one accumulating dq over k-blocks, one accumulating (dk, dv) over
q-blocks -- so no O(S^2) intermediate ever touches HBM.
``flash_attention_with_vjp`` packages fwd+bwd behind ``jax.custom_vjp``.

Validated in interpret mode against ref.naive_attention, values and grads
(tests/test_kernels.py, tests/test_dispatch.py).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, bq: int, bk: int, nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _compute():
        q = q_ref[0].astype(jnp.float32)  # [bq, D]
        k = k_ref[0].astype(jnp.float32)  # [bk, D]
        v = v_ref[0].astype(jnp.float32)  # [bk, Dv]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, -jnp.inf)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = corr * l_scr[...] + jnp.sum(p, axis=-1)
        acc_scr[...] = corr[:, None] * acc_scr[...] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    if causal:
        # skip tiles strictly above the diagonal band
        pl.when(ki * bk <= (qi + 1) * bq - 1)(_compute)
    else:
        _compute()

    @pl.when(ki == nk - 1)
    def _out():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0] = m_scr[...] + jnp.log(l)


def _fwd_call(q, k, v, *, causal: bool, scale: float, bq: int, bk: int,
              interpret: bool) -> Tuple[jax.Array, jax.Array]:
    """Flattened [B*H, S, D] forward; returns (out, lse)."""
    BH, S, D = q.shape
    T = k.shape[1]
    Dv = v.shape[2]
    nq, nk = S // bq, T // bk
    kern = functools.partial(_flash_kernel, scale=scale, causal=causal,
                             bq=bq, bk=bk, nk=nk)
    out, lse = pl.pallas_call(
        kern,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, Dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, Dv), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, Dv), q.dtype),
            jax.ShapeDtypeStruct((BH, S), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out, lse


def _resolve_blocks(S: int, T: int, block_q: int, block_k: int) -> Tuple[int, int]:
    bq = min(block_q, S)
    bk = min(block_k, T)
    if S % bq or T % bk:
        raise ValueError(f"S={S} T={T} must divide block sizes ({bq},{bk})")
    return bq, bk


def flash_attention(
    q: jax.Array,  # [B, H, S, D]
    k: jax.Array,  # [B, H, T, D]
    v: jax.Array,  # [B, H, T, Dv]
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Forward-only flash attention (no custom gradient)."""
    B, H, S, D = q.shape
    T = k.shape[2]
    Dv = v.shape[3]
    scale = D ** -0.5 if scale is None else scale
    bq, bk = _resolve_blocks(S, T, block_q, block_k)
    out, _ = _fwd_call(q.reshape(B * H, S, D), k.reshape(B * H, T, D),
                       v.reshape(B * H, T, Dv), causal=causal, scale=scale,
                       bq=bq, bk=bk, interpret=interpret)
    return out.reshape(B, H, S, Dv)


# ---------------------------------------------------------------------------
# backward kernels (recompute p from saved lse; flash-attention recipe)


def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
                   dq_scr, *, scale: float, causal: bool, bq: int, bk: int, nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    def _compute():
        q = q_ref[0].astype(jnp.float32)  # [bq, D]
        k = k_ref[0].astype(jnp.float32)  # [bk, D]
        v = v_ref[0].astype(jnp.float32)  # [bk, Dv]
        do = do_ref[0].astype(jnp.float32)  # [bq, Dv]
        lse = lse_ref[0]  # [bq]
        delta = delta_ref[0]  # [bq]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, -jnp.inf)
        p = jnp.exp(s - lse[:, None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [bq, bk]
        ds = p * (dp - delta[:, None]) * scale
        dq_scr[...] += jax.lax.dot(ds, k, preferred_element_type=jnp.float32)

    if causal:
        pl.when(ki * bk <= (qi + 1) * bq - 1)(_compute)
    else:
        _compute()

    @pl.when(ki == nk - 1)
    def _out():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref,
                    dv_ref, dk_scr, dv_scr, *, scale: float, causal: bool,
                    bq: int, bk: int, nq: int):
    ki = pl.program_id(1)
    qi = pl.program_id(2)

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    def _compute():
        q = q_ref[0].astype(jnp.float32)  # [bq, D]
        k = k_ref[0].astype(jnp.float32)  # [bk, D]
        v = v_ref[0].astype(jnp.float32)  # [bk, Dv]
        do = do_ref[0].astype(jnp.float32)  # [bq, Dv]
        lse = lse_ref[0]  # [bq]
        delta = delta_ref[0]  # [bq]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, -jnp.inf)
        p = jnp.exp(s - lse[:, None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)  # [bq, bk]
        dv_scr[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [bq, bk]
        ds = p * (dp - delta[:, None]) * scale
        dk_scr[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)

    if causal:
        pl.when((qi + 1) * bq - 1 >= ki * bk)(_compute)
    else:
        _compute()

    @pl.when(qi == nq - 1)
    def _out():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def _bwd_call(q, k, v, out, lse, do, *, causal: bool, scale: float, bq: int,
              bk: int, interpret: bool):
    """Flattened [B*H, S, D] backward; returns (dq, dk, dv)."""
    BH, S, D = q.shape
    T = k.shape[1]
    Dv = v.shape[2]
    nq, nk = S // bq, T // bk
    # rowwise correction term D_i = sum_v do*out (cheap elementwise pass)
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)

    q_spec_i = pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0))
    k_spec_j = pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0))
    v_spec_j = pl.BlockSpec((1, bk, Dv), lambda b, i, j: (b, j, 0))
    do_spec_i = pl.BlockSpec((1, bq, Dv), lambda b, i, j: (b, i, 0))
    row_spec_i = pl.BlockSpec((1, bq), lambda b, i, j: (b, i))

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, scale=scale, causal=causal,
                          bq=bq, bk=bk, nk=nk),
        grid=(BH, nq, nk),
        in_specs=[q_spec_i, k_spec_j, v_spec_j, do_spec_i, row_spec_i, row_spec_i],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    # (dk, dv) grid transposes the block roles: k-block outer, q-block inner
    q_spec_j = pl.BlockSpec((1, bq, D), lambda b, i, j: (b, j, 0))
    k_spec_i = pl.BlockSpec((1, bk, D), lambda b, i, j: (b, i, 0))
    v_spec_i = pl.BlockSpec((1, bk, Dv), lambda b, i, j: (b, i, 0))
    do_spec_j = pl.BlockSpec((1, bq, Dv), lambda b, i, j: (b, j, 0))
    row_spec_j = pl.BlockSpec((1, bq), lambda b, i, j: (b, j))
    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, scale=scale, causal=causal,
                          bq=bq, bk=bk, nq=nq),
        grid=(BH, nk, nq),
        in_specs=[q_spec_j, k_spec_i, v_spec_i, do_spec_j, row_spec_j, row_spec_j],
        out_specs=[
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, Dv), lambda b, i, j: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, D), k.dtype),
            jax.ShapeDtypeStruct((BH, T, Dv), v.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bk, D), jnp.float32),
                        pltpu.VMEM((bk, Dv), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# custom-VJP packaging


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_vjp(q, k, v, causal: bool, scale: float, bq: int, bk: int,
               interpret: bool):
    B, H, S, D = q.shape
    T, Dv = k.shape[2], v.shape[3]
    out, _ = _fwd_call(q.reshape(B * H, S, D), k.reshape(B * H, T, D),
                       v.reshape(B * H, T, Dv), causal=causal, scale=scale,
                       bq=bq, bk=bk, interpret=interpret)
    return out.reshape(B, H, S, Dv)


def _flash_vjp_fwd(q, k, v, causal, scale, bq, bk, interpret):
    B, H, S, D = q.shape
    T, Dv = k.shape[2], v.shape[3]
    out, lse = _fwd_call(q.reshape(B * H, S, D), k.reshape(B * H, T, D),
                         v.reshape(B * H, T, Dv), causal=causal, scale=scale,
                         bq=bq, bk=bk, interpret=interpret)
    return out.reshape(B, H, S, Dv), (q, k, v, out, lse)


def _flash_vjp_bwd(causal, scale, bq, bk, interpret, res, do):
    q, k, v, out, lse = res
    B, H, S, D = q.shape
    T, Dv = k.shape[2], v.shape[3]
    dq, dk, dv = _bwd_call(
        q.reshape(B * H, S, D), k.reshape(B * H, T, D), v.reshape(B * H, T, Dv),
        out, lse, do.reshape(B * H, S, Dv), causal=causal, scale=scale,
        bq=bq, bk=bk, interpret=interpret)
    return (dq.reshape(B, H, S, D), dk.reshape(B, H, T, D),
            dv.reshape(B, H, T, Dv))


_flash_vjp.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def flash_attention_with_vjp(
    q: jax.Array,  # [B, H, S, D]
    k: jax.Array,  # [B, H, T, D]
    v: jax.Array,  # [B, H, T, Dv]
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Differentiable flash attention: Pallas forward AND backward kernels.

    Heads must match between q and k/v -- GQA callers broadcast KV over the
    query groups first so the group-sum of dk/dv falls out of the broadcast's
    own VJP (see layers/attention.py).
    """
    B, H, S, D = q.shape
    T = k.shape[2]
    scale = D ** -0.5 if scale is None else scale
    bq, bk = _resolve_blocks(S, T, block_q, block_k)
    return _flash_vjp(q, k, v, bool(causal), float(scale), bq, bk,
                      bool(interpret))
