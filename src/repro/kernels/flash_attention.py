"""Flash attention forward kernel (Pallas TPU).

TPU-native adaptation: MXU-aligned [block_q x block_k] tiles streamed through
VMEM, online softmax with fp32 (m, l, acc) VMEM scratch carried across the
innermost (sequential) grid dimension, causal blocks skipped with ``pl.when``
(no wasted MXU issue on fully-masked tiles -- the FLOP-exactness the pure-XLA
path only gets from the pairs-scan).

Grid: (batch*heads, n_q_blocks, n_k_blocks); the k-block axis is innermost so
scratch accumulators persist per (bh, qi) like the reference TPU kernel.
Validated in interpret mode against ref.naive_attention (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, bq: int, bk: int, nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _compute():
        q = q_ref[0].astype(jnp.float32)  # [bq, D]
        k = k_ref[0].astype(jnp.float32)  # [bk, D]
        v = v_ref[0].astype(jnp.float32)  # [bk, Dv]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, -jnp.inf)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = corr * l_scr[...] + jnp.sum(p, axis=-1)
        acc_scr[...] = corr[:, None] * acc_scr[...] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    if causal:
        # skip tiles strictly above the diagonal band
        pl.when(ki * bk <= (qi + 1) * bq - 1)(_compute)
    else:
        _compute()

    @pl.when(ki == nk - 1)
    def _out():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # [B, H, S, D]
    k: jax.Array,  # [B, H, T, D]
    v: jax.Array,  # [B, H, T, Dv]
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, S, D = q.shape
    T = k.shape[2]
    Dv = v.shape[3]
    scale = D ** -0.5 if scale is None else scale
    bq = min(block_q, S)
    bk = min(block_k, T)
    if S % bq or T % bk:
        raise ValueError(f"S={S} T={T} must divide block sizes ({bq},{bk})")
    nq, nk = S // bq, T // bk
    qf = q.reshape(B * H, S, D)
    kf = k.reshape(B * H, T, D)
    vf = v.reshape(B * H, T, Dv)

    kern = functools.partial(_flash_kernel, scale=scale, causal=causal,
                             bq=bq, bk=bk, nk=nk)
    out = pl.pallas_call(
        kern,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, Dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, Dv)
