"""Width-coalescing kernel (Pallas TPU): the paper's averaging F applied to a
weight matrix as a single fused pass.

For the "stack" variant F_out = [I/2; I/2] the column ("out"-role) projection
is  Y[:, j] = w0 * (W[:, j] + W[:, j + m])  and the row ("in"-role) projection
(F_in, weight 1.0 after the paper's normalization) is
Y[i, :] = w0 * (W[i, :] + W[i + n2, :]).

Instead of materializing F and running a [n x m] matmul (the naive path -- and
the ref.py oracle), the kernel reads the two paired tiles of W via two
BlockSpec views of the same array and writes one fused output tile: one pass
over HBM, no F matrix, no MXU occupancy.  De-coalescing's T_out duplication is
a gather (no kernel needed); T_in halves are this same kernel with w0=0.5.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pair_kernel(a_ref, b_ref, o_ref, *, w0: float):
    o_ref[...] = (w0 * (a_ref[...].astype(jnp.float32)
                        + b_ref[...].astype(jnp.float32))).astype(o_ref.dtype)


def divisor_block(n: int, pref: int) -> int:
    """Largest divisor of n that is <= pref (keeps tiles HW-aligned when the
    dim allows, and always valid).  On odd/prime dims this collapses to 1 --
    per-element grid programs; the dispatch layer detects that degenerate case
    and falls back to the XLA backend instead of calling this kernel."""
    b = min(pref, n)
    while n % b:
        b -= 1
    return b


def coalesce_pair(
    w: jax.Array,  # [n, c] (axis=0) or [r, n] (axis=1); n even
    *,
    axis: int,
    w0: float = 0.5,
    block: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Merge index pairs (i, i + n/2) along ``axis`` with weight ``w0``."""
    if w.ndim != 2:
        raise ValueError("coalesce_pair expects a 2D weight (fold other dims first)")
    n = w.shape[axis]
    if n % 2:
        raise ValueError(f"axis {axis} size {n} must be even")
    half = n // 2
    r, c = w.shape
    if axis == 0:
        br = divisor_block(half, block)
        bc = divisor_block(c, block)
        grid = (half // br, c // bc)
        a_spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))
        b_spec = pl.BlockSpec((br, bc), lambda i, j: (i + half // br, j))
        out_shape = jax.ShapeDtypeStruct((half, c), w.dtype)
    else:
        br = divisor_block(r, block)
        bc = divisor_block(half, block)
        grid = (r // br, half // bc)
        a_spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))
        b_spec = pl.BlockSpec((br, bc), lambda i, j: (i, j + half // bc))
        out_shape = jax.ShapeDtypeStruct((r, half), w.dtype)

    return pl.pallas_call(
        functools.partial(_pair_kernel, w0=w0),
        grid=grid,
        in_specs=[a_spec, b_spec],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=out_shape,
        interpret=interpret,
    )(w, w)
