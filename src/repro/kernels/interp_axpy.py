"""Interpolation kernel (Pallas TPU): the paper's Eq. 13
``out = (1 - alpha) * a + alpha * b`` fused over parameter tiles.

Memory-bound by construction (reads a, b once, writes out once); the fused
form avoids the two-pass scale+add XLA can emit for mixed-dtype trees at
level-transition time on 100B+ parameter models.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _axpy_kernel(a_ref, b_ref, o_ref, *, alpha: float):
    af = a_ref[...].astype(jnp.float32)
    bf = b_ref[...].astype(jnp.float32)
    o_ref[...] = ((1.0 - alpha) * af + alpha * bf).astype(o_ref.dtype)


def interp_axpy(a: jax.Array, b: jax.Array, alpha: float, *,
                block: int = 1024, interpret: bool = False) -> jax.Array:
    """Tiled (1-alpha)*a + alpha*b over a flattened parameter tensor."""
    if a.shape != b.shape:
        raise ValueError(f"shape mismatch {a.shape} vs {b.shape}")
    orig_shape = a.shape
    flat = a.reshape(-1)
    n = flat.shape[0]
    blk = min(block, n)
    pad = (-n) % blk
    af = jnp.pad(a.reshape(-1), (0, pad)).reshape(-1, blk)
    bf = jnp.pad(b.reshape(-1), (0, pad)).reshape(-1, blk)
    rows = af.shape[0]
    out = pl.pallas_call(
        functools.partial(_axpy_kernel, alpha=alpha),
        grid=(rows,),
        in_specs=[pl.BlockSpec((1, blk), lambda i: (i, 0)),
                  pl.BlockSpec((1, blk), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, blk), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, blk), a.dtype),
        interpret=interpret,
    )(af, bf)
    return out.reshape(-1)[:n].reshape(orig_shape)
