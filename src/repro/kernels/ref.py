"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def naive_attention(q, k, v, *, causal: bool = True, scale: Optional[float] = None):
    """q: [B,H,S,D], k: [B,H,T,D], v: [B,H,T,Dv] -> [B,H,S,Dv]; fp32 softmax."""
    B, H, S, D = q.shape
    T = k.shape[2]
    scale = D ** -0.5 if scale is None else scale
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(T)[None, :] <= jnp.arange(S)[:, None]
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtv->bhsv", p, v.astype(jnp.float32)).astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths, *,
                        scale: Optional[float] = None):
    """Gather-based paged decode attention (the block-table oracle).

    q: [B,KH,G,D], k_pages: [N,P,KH,D], v_pages: [N,P,KH,Dv],
    block_tables: [B,M] int32, lengths: [B] int32 -> [B,KH,G,Dv].

    Reassembles each sequence's K/V by indexing the page pool through its
    block table, masks positions >= length, and runs one fp32 softmax.  Work
    scales with M*P (the pages a batch actually spans), not max_seq.  A
    length-0 row (idle slot) yields zeros -- the Pallas kernel pins the same
    convention, so idle rows stay backend-invariant.
    """
    B, KH, G, D = q.shape
    N, P, _, Dv = v_pages.shape
    M = block_tables.shape[1]
    scale = D ** -0.5 if scale is None else scale
    k = k_pages[block_tables].reshape(B, M * P, KH, D)
    v = v_pages[block_tables].reshape(B, M * P, KH, Dv)
    s = jnp.einsum("bkgd,btkd->bkgt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    valid = jnp.arange(M * P)[None, :] < lengths[:, None]  # [B, T]
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(jnp.isfinite(s), p, 0.0)  # empty rows -> all-zero p
    l = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bkgt,btkv->bkgv", p / l, v.astype(jnp.float32))
    return out.astype(q.dtype)


def coalesce_pair_ref(w, *, axis: int, w0: float = 0.5):
    """Dense F-matrix oracle: F = [w0*I ; w0*I] contraction along ``axis``."""
    n = w.shape[axis]
    half = n // 2
    F = np.zeros((n, half), np.float32)
    F[np.arange(half), np.arange(half)] = w0
    F[np.arange(half) + half, np.arange(half)] = w0
    F = jnp.asarray(F)
    if axis == 0:
        return jnp.einsum("nm,nc->mc", F, w.astype(jnp.float32)).astype(w.dtype)
    return jnp.einsum("rn,nm->rm", w.astype(jnp.float32), F).astype(w.dtype)


def interp_axpy_ref(a, b, alpha: float):
    return ((1.0 - alpha) * a.astype(jnp.float32) + alpha * b.astype(jnp.float32)).astype(a.dtype)
