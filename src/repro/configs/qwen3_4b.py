"""qwen3-4b [dense]: 36L d2560 32H (GQA kv=8) d_ff=9728 vocab=151936,
qk_norm, explicit head_dim=128.  [hf:Qwen/Qwen3-8B; hf]"""
from repro.config import BlockSpec, ModelConfig, uniform_stages

FULL = ModelConfig(
    name="qwen3-4b",
    family="dense",
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151936,
    stages=uniform_stages(36, BlockSpec("attn", "dense")),
    qk_norm=True,
    rope_theta=1e6,
    tie_embeddings=True,
    remat="full",
)


def smoke() -> ModelConfig:
    return FULL.replace(
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=192, vocab_size=512,
        stages=uniform_stages(3, BlockSpec("attn", "dense")), remat="none")
