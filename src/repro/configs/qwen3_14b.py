"""qwen3-14b [dense]: 40L d5120 40H (GQA kv=8) d_ff=17408 vocab=151936,
qk_norm, explicit head_dim=128.  [hf:Qwen/Qwen3-8B; hf]

Note: 40 query heads do not divide the 16-way "model" mesh axis; the sharding
rules therefore replicate the head axis and tensor-parallelism carries via the
FFN/vocab axes (visible in the roofline as a memory-heavier attention term).
"""
from repro.config import BlockSpec, ModelConfig, uniform_stages

FULL = ModelConfig(
    name="qwen3-14b",
    family="dense",
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    stages=uniform_stages(40, BlockSpec("attn", "dense")),
    qk_norm=True,
    rope_theta=1e6,
    tie_embeddings=False,
    remat="full",
    attn_seq_shard=True,  # 40/20 heads don't divide model=16: context-parallel attn
)


def smoke() -> ModelConfig:
    return FULL.replace(
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=192, vocab_size=512,
        stages=uniform_stages(3, BlockSpec("attn", "dense")), remat="none")
