"""xlstm-125m [ssm]: 12L d768 4H d_ff=0 vocab=50304 — sLSTM + mLSTM blocks.
[arXiv:2405.04517; unverified]

Blocks carry their own up/down projections (d_ff=0 per the assignment: no
separate FFN).  Pattern approximates xLSTM[7:1]: one sLSTM per 6-block period.
Pure recurrent state => runs long_500k with O(1) decode state.
"""
from repro.config import BlockSpec, ModelConfig, Stage

_PATTERN = (
    BlockSpec("mlstm", "none"), BlockSpec("mlstm", "none"), BlockSpec("mlstm", "none"),
    BlockSpec("slstm", "none"), BlockSpec("mlstm", "none"), BlockSpec("mlstm", "none"),
)

FULL = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    stages=(Stage(_PATTERN, 2),),
    xlstm_proj_factor=2.0,
    tie_embeddings=True,
    remat="full",
)


def smoke() -> ModelConfig:
    return FULL.replace(d_model=64, n_heads=4, n_kv_heads=4, vocab_size=512,
                        stages=(Stage(_PATTERN[:2], 2),), remat="none")
