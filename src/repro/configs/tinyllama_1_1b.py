"""tinyllama-1.1b [dense]: 22L d2048 32H (GQA kv=4) d_ff=5632 vocab=32000
llama2-arch small.  [arXiv:2401.02385; hf]"""
from repro.config import BlockSpec, ModelConfig, uniform_stages

FULL = ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=5632,
    vocab_size=32000,
    stages=uniform_stages(22, BlockSpec("attn", "dense")),
    tie_embeddings=False,
    remat="full",
)


def smoke() -> ModelConfig:
    return FULL.replace(
        d_model=64, n_heads=4, n_kv_heads=2, d_ff=176, vocab_size=512,
        stages=uniform_stages(3, BlockSpec("attn", "dense")), remat="none")
