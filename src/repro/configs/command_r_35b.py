"""command-r-35b [dense]: 40L d8192 64H (GQA kv=8) d_ff=22528 vocab=256000,
GQA, no-bias.  [hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.config import BlockSpec, ModelConfig, uniform_stages

FULL = ModelConfig(
    name="command-r-35b",
    family="dense",
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    stages=uniform_stages(40, BlockSpec("attn", "dense")),
    use_bias=False,
    tie_embeddings=True,
    rope_theta=8e6,
    remat="full",
)


def smoke() -> ModelConfig:
    return FULL.replace(
        d_model=64, n_heads=4, n_kv_heads=2, d_ff=176, vocab_size=512,
        stages=uniform_stages(3, BlockSpec("attn", "dense")), remat="none")
