"""whisper-large-v3 [audio]: enc-dec, 32L+32L d1280 20H d_ff=5120 vocab=51866.
[arXiv:2212.04356; unverified]

The conv frontend is a STUB per the assignment: ``input_specs()`` provides the
precomputed post-conv frame embeddings [batch, 1500, d_model].  Decoder-side
shapes follow the assigned seq_len abstractly (the backbone is what is
exercised).  vocab is padded to 51968 (multiple of 128) for TP divisibility.

Note: 20 heads do not divide the 16-way model axis -> head axis replicated,
TP carries via FFN/vocab (see qwen3-14b note).
"""
from repro.config import BlockSpec, ModelConfig, uniform_stages

FULL = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    stages=uniform_stages(32, BlockSpec("dec_attn", "dense")),
    n_encoder_layers=32,
    encoder_seq=1500,
    act="gelu",
    norm="layernorm",
    use_bias=True,
    tie_embeddings=True,
    remat="full",
    attn_seq_shard=True,  # 40/20 heads don't divide model=16: context-parallel attn
)


def smoke() -> ModelConfig:
    return FULL.replace(
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=176, vocab_size=512,
        stages=uniform_stages(2, BlockSpec("dec_attn", "dense")),
        n_encoder_layers=2, encoder_seq=16, remat="none")
