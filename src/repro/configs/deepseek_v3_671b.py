"""deepseek-v3-671b [moe]: 61L d7168 128H MLA d_ff(expert)=2048 vocab=129280,
MoE 1 shared + 256 routed top-8, MTP.  [arXiv:2412.19437; hf]

Structure: first 3 layers dense-FFN (d_ff 18432, per the HF config), remaining
58 layers MoE.  MLA dims from the paper: q_lora 1536, kv_lora 512,
qk_nope 128 + qk_rope 64, v_head 128.
"""
from repro.config import BlockSpec, ModelConfig, Stage

FULL = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=18432,
    vocab_size=129280,
    stages=(
        Stage((BlockSpec("attn", "dense"),), 3),
        Stage((BlockSpec("attn", "moe"),), 58),
    ),
    attn_type="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    n_experts=256,
    n_shared_experts=1,
    moe_top_k=8,
    moe_d_ff=2048,
    tie_embeddings=False,
    mtp_depth=1,
    rope_theta=10000.0,
    remat="full",
)


def smoke() -> ModelConfig:
    return FULL.replace(
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=160, vocab_size=512,
        q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16, qk_rope_head_dim=8,
        v_head_dim=16, n_experts=4, moe_top_k=2, moe_d_ff=32,
        stages=(Stage((BlockSpec("attn", "dense"),), 1),
                Stage((BlockSpec("attn", "moe"),), 2)),
        remat="none",
    )
