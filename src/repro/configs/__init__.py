"""Architecture registry: ``--arch <id>`` resolution for launcher/dry-run.

Ten assigned architectures + the paper's own models.  ``get_config(id)``
returns the exact full-size config; ``get_config(id, smoke=True)`` a reduced
same-family config for CPU smoke tests.
"""
from __future__ import annotations

from typing import Dict, List

from repro.config import ModelConfig

from repro.configs import (  # noqa: E402
    command_r_35b,
    deepseek_v3_671b,
    jamba_1_5_large_398b,
    llama32_vision_11b,
    paper_models,
    phi35_moe_42b,
    qwen3_14b,
    qwen3_4b,
    tinyllama_1_1b,
    whisper_large_v3,
    xlstm_125m,
)

_MODULES = {
    "deepseek-v3-671b": deepseek_v3_671b,
    "phi3.5-moe-42b-a6.6b": phi35_moe_42b,
    "tinyllama-1.1b": tinyllama_1_1b,
    "qwen3-4b": qwen3_4b,
    "qwen3-14b": qwen3_14b,
    "command-r-35b": command_r_35b,
    "jamba-1.5-large-398b": jamba_1_5_large_398b,
    "xlstm-125m": xlstm_125m,
    "llama-3.2-vision-11b": llama32_vision_11b,
    "whisper-large-v3": whisper_large_v3,
}

ASSIGNED: List[str] = list(_MODULES)

PAPER_CONFIGS = {
    "bert-base": paper_models.BERT_BASE,
    "bert-large": paper_models.BERT_LARGE,
    "gpt-base": paper_models.GPT_BASE,
    "deit-b": paper_models.DEIT_B,
}

# architectures with sub-quadratic sequence mixing: the only ones that run the
# long_500k cell (assignment rule; skips documented in DESIGN.md §4)
LONG_CONTEXT_CAPABLE = ("jamba-1.5-large-398b", "xlstm-125m")


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    if name in _MODULES:
        return _MODULES[name].smoke() if smoke else _MODULES[name].FULL
    if name in PAPER_CONFIGS:
        return PAPER_CONFIGS[name]
    raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES) + sorted(PAPER_CONFIGS)}")


def cell_is_skipped(arch: str, shape_name: str) -> str:
    """Returns a reason string if (arch, shape) is skipped, else ''."""
    if shape_name == "long_500k" and arch not in LONG_CONTEXT_CAPABLE:
        return "long_500k needs sub-quadratic attention (pure full-attention arch)"
    return ""
