"""jamba-1.5-large-398b [hybrid]: 72L d8192 64H (GQA kv=8) expert d_ff=24576
vocab=65536, MoE 16e top-2, Mamba:attn 1:7 interleave.  [arXiv:2403.19887; hf]

Period-8 super-block (Jamba block): attention at position 3, Mamba elsewhere;
MoE on every second layer.  72 layers = 9 super-blocks, scanned.
Hybrid => runs the long_500k cell (Mamba state is O(1); the 9 attention layers
use the sequence-sharded KV cache).
"""
from repro.config import BlockSpec, ModelConfig, Stage

_PATTERN = tuple(
    BlockSpec(mixer=("attn" if i == 3 else "mamba"), ffn=("moe" if i % 2 == 1 else "dense"))
    for i in range(8)
)

FULL = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    stages=(Stage(_PATTERN, 9),),
    n_experts=16,
    moe_top_k=2,
    moe_d_ff=24576,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    tie_embeddings=False,
    remat="full",
)


def smoke() -> ModelConfig:
    return FULL.replace(
        d_model=64, n_heads=4, n_kv_heads=2, d_ff=96, vocab_size=512,
        n_experts=4, moe_top_k=2, moe_d_ff=96,
        stages=(Stage(_PATTERN[:4], 2),), remat="none")
