"""The paper's own experimental models (BERT, GPT, DeiT) — full-size configs
plus proxy-scale variants used by the reproduction benchmarks (the container
is CPU-only; relative FLOPs-saving claims are scale-free, see DESIGN.md §8).

The proxies keep the paper's setup where it matters for the technique:
pre-LN transformer, biases enabled (the operator algorithms explicitly handle
biases), GELU, tied embeddings, MLM for BERT / causal LM for GPT / patch
classification for DeiT.
"""
from repro.config import BlockSpec, ModelConfig, uniform_stages

BERT_BASE = ModelConfig(
    name="bert-base", family="encoder", d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab_size=30522,
    stages=uniform_stages(12, BlockSpec("enc_attn", "dense")),
    causal=False, act="gelu", norm="layernorm", use_bias=True, tie_embeddings=True)

BERT_LARGE = BERT_BASE.replace(
    name="bert-large", d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    stages=uniform_stages(24, BlockSpec("enc_attn", "dense")))

GPT_BASE = ModelConfig(
    name="gpt-base", family="dense", d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab_size=50257,
    stages=uniform_stages(12, BlockSpec("attn", "dense")),
    act="gelu", norm="layernorm", use_bias=True, tie_embeddings=True)

DEIT_B = ModelConfig(
    name="deit-b", family="vit", d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab_size=1, stages=uniform_stages(12, BlockSpec("enc_attn", "dense")),
    act="gelu", norm="layernorm", use_bias=True,
    image_size=224, patch_size=16, n_classes=1000)


def bert_proxy(d_model=128, n_layers=8, vocab=512) -> ModelConfig:
    return BERT_BASE.replace(
        name="bert-proxy", d_model=d_model, n_heads=4, n_kv_heads=4,
        d_ff=4 * d_model, vocab_size=vocab,
        stages=uniform_stages(n_layers, BlockSpec("enc_attn", "dense")),
        remat="none", attn_impl="plain")


def bert_large_proxy() -> ModelConfig:
    return bert_proxy(d_model=192, n_layers=12).replace(name="bert-large-proxy")


def gpt_proxy(d_model=128, n_layers=8, vocab=512) -> ModelConfig:
    return GPT_BASE.replace(
        name="gpt-proxy", d_model=d_model, n_heads=4, n_kv_heads=4,
        d_ff=4 * d_model, vocab_size=vocab,
        stages=uniform_stages(n_layers, BlockSpec("attn", "dense")),
        remat="none", attn_impl="plain")


def deit_proxy(d_model=128, n_layers=8, n_classes=16) -> ModelConfig:
    return DEIT_B.replace(
        name="deit-proxy", d_model=d_model, n_heads=4, n_kv_heads=4,
        d_ff=4 * d_model, image_size=32, patch_size=8, n_classes=n_classes,
        stages=uniform_stages(n_layers, BlockSpec("enc_attn", "dense")),
        remat="none", attn_impl="plain")
