"""llama-3.2-vision-11b [vlm]: 40L d4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — gated cross-attn image layers every 5th layer.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

The modality frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings [batch, 1601, vision_dim] (vision_dim pinned at
4096 so coalesced levels keep consuming the same frontend features).
"""
from repro.config import BlockSpec, ModelConfig, Stage

_PATTERN = (BlockSpec("cross_attn", "dense"),) + (BlockSpec("attn", "dense"),) * 4

FULL = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    stages=(Stage(_PATTERN, 8),),
    n_image_tokens=1601,
    vision_dim=4096,
    rope_theta=5e5,
    tie_embeddings=False,
    remat="full",
)


def smoke() -> ModelConfig:
    return FULL.replace(
        d_model=64, n_heads=4, n_kv_heads=2, d_ff=176, vocab_size=512,
        n_image_tokens=8, vision_dim=64,
        stages=(Stage(_PATTERN[:2], 2),), remat="none")
