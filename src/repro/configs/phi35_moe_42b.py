"""phi3.5-moe-42b-a6.6b [moe]: 32L d4096 32H (GQA kv=8) expert d_ff=6400
vocab=32064, 16 experts top-2.  [hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from repro.config import BlockSpec, ModelConfig, uniform_stages

FULL = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    stages=uniform_stages(32, BlockSpec("attn", "moe")),
    n_experts=16,
    moe_top_k=2,
    moe_d_ff=6400,
    tie_embeddings=False,
    remat="full",
)


def smoke() -> ModelConfig:
    return FULL.replace(
        d_model=64, n_heads=4, n_kv_heads=2, d_ff=96, vocab_size=512,
        n_experts=4, moe_top_k=2, moe_d_ff=96,
        stages=uniform_stages(2, BlockSpec("attn", "moe")), remat="none")
