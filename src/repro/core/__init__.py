"""The paper's primary contribution: multi-level V-cycle training.

operators.py    Coalescing / De-coalescing / Interpolation (Eqs. 1-13)
projections.py  F/R/G/T matrix builders (stack & adj variants, App. E)
vcycle.py       Algorithm 1 + FLOPs-indexed training histories
baselines.py    StackBERT / bert2BERT / LiGO / Network Expansion / KI
flops.py        analytic FLOPs accounting (evaluation axis + roofline ref)
"""
from repro.core.operators import (  # noqa: F401
    build_level_maps,
    coalesce,
    coalesce_config,
    decoalesce,
    interpolate,
    make_coalesce_fn,
    make_decoalesce_fn,
    make_interpolate_fn,
)
from repro.core.vcycle import (  # noqa: F401
    History,
    SegmentPlan,
    VCycleOutput,
    VCycleRunner,
    VCycleState,
    flops_to_reach,
    run_scratch,
    run_vcycle,
    saving_vs_baseline,
    segments,
    train_segment,
)
