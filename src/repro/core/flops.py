"""Analytic FLOPs accounting + the energy/CO2 layer on top of it.

Used for (a) the paper's evaluation axis -- FLOPs-to-quality comparisons
between V-cycle / baselines / from-scratch (only *relative* numbers matter, so
a single consistent formula is applied to every arm), (b) the roofline's
MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) reference term, and (c) the
energy accounting (:class:`EnergyModel`): the paper's pitch is cutting
training *cost*, so the per-family benchmark tables report the same pinned
FLOPs numbers converted to joules and kgCO2e (DESIGN.md §7).

The FLOPs functions are pinned to 1e-9 relative tolerance by
``tests/test_baselines.py`` -- the energy layer is strictly additive and
must never change their outputs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import numpy as np

from repro.config import ModelConfig
from repro.param import Spec, is_spec


def _walk(tree, path=()):
    if is_spec(tree):
        yield path, tree
        return
    for k, v in tree.items():
        yield from _walk(v, path + (k,))


def active_matmul_params(cfg: ModelConfig, specs) -> float:
    """Parameters participating in per-token matmuls, with MoE expert weights
    scaled by top_k / n_experts (active fraction) and the embedding table
    counted once iff tied (the unembed matmul)."""
    total = 0.0
    moe_frac = (cfg.moe_top_k / cfg.n_experts) if cfg.n_experts else 1.0
    for path, s in _walk(specs):
        if len(s.shape) < 2:
            continue
        n = float(np.prod(s.shape))
        name = "/".join(path)
        if "experts" in s.axes:
            n *= moe_frac
        if name.endswith("embed/tok"):
            pass  # tied unembed matmul: count once
        total += n
    return total


def total_params(specs) -> float:
    return float(sum(np.prod(s.shape) for _, s in _walk(specs)))


def _attn_layers(cfg: ModelConfig):
    n_self = sum(1 for st in cfg.stages for b in st.pattern
                 if b.mixer in ("attn", "dec_attn", "enc_attn")) and \
             sum(st.repeats * sum(1 for b in st.pattern if b.mixer in ("attn", "dec_attn", "enc_attn"))
                 for st in cfg.stages)
    n_cross = sum(st.repeats * sum(1 for b in st.pattern if b.mixer in ("cross_attn", "dec_attn"))
                  for st in cfg.stages)
    n_rec = sum(st.repeats * sum(1 for b in st.pattern if b.mixer in ("mamba", "mlstm", "slstm"))
                for st in cfg.stages)
    return n_self or 0, n_cross, n_rec


def forward_flops(cfg: ModelConfig, specs, batch: int, seq: int) -> float:
    """Forward-pass FLOPs for a [batch, seq] input (2 FLOPs per MAC)."""
    tokens = batch * seq
    f = 2.0 * active_matmul_params(cfg, specs) * tokens
    n_self, n_cross, n_rec = _attn_layers(cfg)
    if cfg.attn_type == "mla":
        dqk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        dv = cfg.v_head_dim
    else:
        dqk = dv = cfg.resolved_head_dim
    t_avg = seq / 2 if cfg.causal else seq
    f += tokens * n_self * 2.0 * cfg.n_heads * (dqk + dv) * t_avg
    n_kv = cfg.n_image_tokens or cfg.encoder_seq
    if n_cross and n_kv:
        f += tokens * n_cross * 2.0 * cfg.n_heads * 2 * cfg.resolved_head_dim * n_kv
    if n_rec:  # recurrent state updates (mamba: d_in*d_state; xlstm: NH*dh^2)
        di, ds = cfg.mamba_d_inner, cfg.mamba_d_state
        f += tokens * n_rec * 6.0 * di * ds
    if cfg.n_encoder_layers:  # encoder runs on encoder_seq tokens
        enc_tokens = batch * cfg.encoder_seq
        per_layer = 2.0 * (4 * cfg.d_model ** 2 + 2 * cfg.d_model * cfg.d_ff)
        f += enc_tokens * cfg.n_encoder_layers * per_layer
        f += enc_tokens * cfg.n_encoder_layers * 2.0 * cfg.n_heads * 2 * cfg.resolved_head_dim * cfg.encoder_seq
    return f


def train_step_flops(cfg: ModelConfig, specs, batch: int, seq: int) -> float:
    """fwd + bwd ~= 3x fwd (standard convention)."""
    return 3.0 * forward_flops(cfg, specs, batch, seq)


def model_flops_reference(cfg: ModelConfig, specs, tokens: float, train: bool = True) -> float:
    """Roofline reference: 6*N*D (dense) / 6*N_active*D (MoE), N = matmul params."""
    n = active_matmul_params(cfg, specs)
    return (6.0 if train else 2.0) * n * tokens


# ---------------------------------------------------------------------------
# energy / CO2 accounting (DESIGN.md §7)
#
# The model follows Patterson et al., "Carbon Emissions and Large Neural
# Network Training": Energy = runtime x device power x PUE, CO2e = kWh x grid
# intensity -- with runtime and power derived from the roofline utilization
# fraction (the roofline-inspired scaling model in PAPERS.md):
#
#   seconds = flops / (utilization * peak_flops)
#   watts   = tdp * (idle_frac + (1 - idle_frac) * utilization)
#   joules  = seconds * watts * PUE
#   kgCO2e  = kWh * grid_kgco2_per_kwh
#
# ``utilization`` is the achieved fraction of peak (MFU / the roofline
# fraction ``benchmarks/roofline.py`` reports); power scales linearly between
# the idle floor and TDP with it.  Only *relative* numbers matter between
# arms (same device, same utilization on both sides of a comparison), exactly
# like the FLOPs basis -- the absolute numbers are envelope estimates.


@dataclasses.dataclass(frozen=True)
class DevicePower:
    """One accelerator's power envelope (peak compute + TDP)."""

    name: str
    peak_flops: float   # peak FLOP/s at the training precision (bf16-class)
    tdp_watts: float    # board power at full utilization
    idle_frac: float    # fraction of TDP drawn at ~zero utilization

    def __post_init__(self):
        if self.peak_flops <= 0 or self.tdp_watts <= 0:
            raise ValueError(f"{self.name}: peak_flops and tdp_watts must be > 0")
        if not 0.0 <= self.idle_frac < 1.0:
            raise ValueError(f"{self.name}: idle_frac must be in [0, 1)")


# datasheet-level envelopes (peak bf16-class FLOP/s, board TDP); idle
# fractions are the ~30% floor Patterson et al. report for accelerators at
# low utilization.  "cpu-proxy" prices this container's smoke runs.
DEVICES: Dict[str, DevicePower] = {
    "tpu-v4": DevicePower("tpu-v4", peak_flops=275e12, tdp_watts=192.0,
                          idle_frac=0.28),
    "a100": DevicePower("a100", peak_flops=312e12, tdp_watts=400.0,
                        idle_frac=0.3),
    "h100": DevicePower("h100", peak_flops=989e12, tdp_watts=700.0,
                        idle_frac=0.3),
    "cpu-proxy": DevicePower("cpu-proxy", peak_flops=1e11, tdp_watts=65.0,
                             idle_frac=0.5),
}

# kgCO2e per kWh: US average grid intensity used by Patterson et al.
US_GRID_KGCO2_PER_KWH = 0.429


@dataclasses.dataclass(frozen=True)
class EnergyModel:
    """flops -> (seconds, joules, kgCO2e) on one device envelope.

    ``utilization`` is the achieved roofline fraction (MFU); ``pue`` the
    datacenter power-usage effectiveness (Google fleet ~1.1, Patterson et
    al.); ``grid_kgco2_per_kwh`` the grid carbon intensity.
    """

    device: DevicePower
    utilization: float = 0.4
    pue: float = 1.1
    grid_kgco2_per_kwh: float = US_GRID_KGCO2_PER_KWH

    def __post_init__(self):
        if not 0.0 < self.utilization <= 1.0:
            raise ValueError("utilization must be in (0, 1]")
        if self.pue < 1.0:
            raise ValueError("PUE is >= 1 by definition")
        if self.grid_kgco2_per_kwh < 0:
            raise ValueError("grid intensity must be >= 0")

    def seconds(self, flops: float) -> float:
        """Device-seconds to execute ``flops`` at the achieved fraction of
        peak (divide by the device count for wall-clock)."""
        return flops / (self.utilization * self.device.peak_flops)

    def watts(self) -> float:
        """Average board power: linear between the idle floor and TDP with
        utilization (the roofline-inspired power scaling)."""
        d = self.device
        return d.tdp_watts * (d.idle_frac + (1.0 - d.idle_frac) * self.utilization)

    def joules(self, flops: float) -> float:
        """Facility energy: device-seconds x average watts x PUE."""
        return self.seconds(flops) * self.watts() * self.pue

    def kgco2e(self, flops: float) -> float:
        return self.joules(flops) / 3.6e6 * self.grid_kgco2_per_kwh

    def report(self, flops: float) -> Dict[str, float]:
        """The full accounting for one arm, on one basis (benchmark tables)."""
        j = self.joules(flops)
        return {"flops": float(flops),
                "device": self.device.name,
                "utilization": self.utilization,
                "seconds": self.seconds(flops),
                "watts": self.watts(),
                "joules": j,
                "kwh": j / 3.6e6,
                "kgco2e": j / 3.6e6 * self.grid_kgco2_per_kwh}


def energy_report(flops: float, device: str = "tpu-v4", *,
                  utilization: float = 0.4, pue: float = 1.1,
                  grid_kgco2_per_kwh: float = US_GRID_KGCO2_PER_KWH) -> Dict[str, float]:
    """One-call convenience: ``energy_report(total_flops)`` -> the table row."""
    return EnergyModel(DEVICES[device], utilization=utilization, pue=pue,
                       grid_kgco2_per_kwh=grid_kgco2_per_kwh).report(flops)
