"""Analytic FLOPs accounting.

Used for (a) the paper's evaluation axis -- FLOPs-to-quality comparisons
between V-cycle / baselines / from-scratch (only *relative* numbers matter, so
a single consistent formula is applied to every arm), and (b) the roofline's
MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) reference term.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import numpy as np

from repro.config import ModelConfig
from repro.param import Spec, is_spec


def _walk(tree, path=()):
    if is_spec(tree):
        yield path, tree
        return
    for k, v in tree.items():
        yield from _walk(v, path + (k,))


def active_matmul_params(cfg: ModelConfig, specs) -> float:
    """Parameters participating in per-token matmuls, with MoE expert weights
    scaled by top_k / n_experts (active fraction) and the embedding table
    counted once iff tied (the unembed matmul)."""
    total = 0.0
    moe_frac = (cfg.moe_top_k / cfg.n_experts) if cfg.n_experts else 1.0
    for path, s in _walk(specs):
        if len(s.shape) < 2:
            continue
        n = float(np.prod(s.shape))
        name = "/".join(path)
        if "experts" in s.axes:
            n *= moe_frac
        if name.endswith("embed/tok"):
            pass  # tied unembed matmul: count once
        total += n
    return total


def total_params(specs) -> float:
    return float(sum(np.prod(s.shape) for _, s in _walk(specs)))


def _attn_layers(cfg: ModelConfig):
    n_self = sum(1 for st in cfg.stages for b in st.pattern
                 if b.mixer in ("attn", "dec_attn", "enc_attn")) and \
             sum(st.repeats * sum(1 for b in st.pattern if b.mixer in ("attn", "dec_attn", "enc_attn"))
                 for st in cfg.stages)
    n_cross = sum(st.repeats * sum(1 for b in st.pattern if b.mixer in ("cross_attn", "dec_attn"))
                  for st in cfg.stages)
    n_rec = sum(st.repeats * sum(1 for b in st.pattern if b.mixer in ("mamba", "mlstm", "slstm"))
                for st in cfg.stages)
    return n_self or 0, n_cross, n_rec


def forward_flops(cfg: ModelConfig, specs, batch: int, seq: int) -> float:
    """Forward-pass FLOPs for a [batch, seq] input (2 FLOPs per MAC)."""
    tokens = batch * seq
    f = 2.0 * active_matmul_params(cfg, specs) * tokens
    n_self, n_cross, n_rec = _attn_layers(cfg)
    if cfg.attn_type == "mla":
        dqk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        dv = cfg.v_head_dim
    else:
        dqk = dv = cfg.resolved_head_dim
    t_avg = seq / 2 if cfg.causal else seq
    f += tokens * n_self * 2.0 * cfg.n_heads * (dqk + dv) * t_avg
    n_kv = cfg.n_image_tokens or cfg.encoder_seq
    if n_cross and n_kv:
        f += tokens * n_cross * 2.0 * cfg.n_heads * 2 * cfg.resolved_head_dim * n_kv
    if n_rec:  # recurrent state updates (mamba: d_in*d_state; xlstm: NH*dh^2)
        di, ds = cfg.mamba_d_inner, cfg.mamba_d_state
        f += tokens * n_rec * 6.0 * di * ds
    if cfg.n_encoder_layers:  # encoder runs on encoder_seq tokens
        enc_tokens = batch * cfg.encoder_seq
        per_layer = 2.0 * (4 * cfg.d_model ** 2 + 2 * cfg.d_model * cfg.d_ff)
        f += enc_tokens * cfg.n_encoder_layers * per_layer
        f += enc_tokens * cfg.n_encoder_layers * 2.0 * cfg.n_heads * 2 * cfg.resolved_head_dim * cfg.encoder_seq
    return f


def train_step_flops(cfg: ModelConfig, specs, batch: int, seq: int) -> float:
    """fwd + bwd ~= 3x fwd (standard convention)."""
    return 3.0 * forward_flops(cfg, specs, batch, seq)


def model_flops_reference(cfg: ModelConfig, specs, tokens: float, train: bool = True) -> float:
    """Roofline reference: 6*N*D (dense) / 6*N_active*D (MoE), N = matmul params."""
    n = active_matmul_params(cfg, specs)
    return (6.0 if train else 2.0) * n * tokens
