"""V-cycle training process (paper Algorithm 1) + generic training loop with
FLOPs-indexed loss history (the paper's evaluation axis).

The runner is production-shaped: per-level compiled steps are built once and
cached; level transitions are jitted and host-round-trip-free, with the
"stack"-variant width projections and the interpolation running matrix-free
through the kernel registry (repro.kernels.dispatch: Pallas on TPU, fused XLA
elsewhere); the optimizer is re-initialized at transitions (paper §Discussion
/ App. C); and
the whole V-cycle state (level, phase, step) is checkpointable via
``repro.checkpoint`` (see launch/train.py).

The runner is an explicit state machine, not a straight-line script:

* ``segments(cfg, ml, tc)`` materializes Algorithm 1 as a deterministic
  schedule of :class:`SegmentPlan` entries -- the downward sweep (init-train
  ``E_a`` per level, then coalesce), the upward sweep (train ``E_small``, then
  de-coalesce + interpolate) and the final full-size segment.
* :class:`VCycleState` carries everything needed to re-enter training at an
  arbitrary (phase, level, step): segment index, step-within-segment, global
  step, cumulative FLOPs, the :class:`History`, and the ``params_before``
  stash consumed by Interpolation on the way back up.  Together with the
  deterministic ``batch_fn(global_step)`` data order this makes mid-cycle
  checkpoint/resume bit-identical to an uninterrupted run (see
  ``launch/train.py`` for the save/restore wiring and ``tests/test_resume.py``
  for the equivalence proof).
* :class:`VCycleRunner` owns the per-level compiled-step cache: each level's
  train step is ``jax.jit``-compiled at most once per run even though every
  level below the top is visited twice (down + up sweep); ``n_compiles``
  exposes the count for tests.  Built with a ``mesh``, the runner shards the
  whole cycle: per-level explicit ``in_shardings``/``out_shardings`` train
  steps and sharded-in/sharded-out level transitions (the launcher's
  ``--mesh`` flag feeds this; checkpoints stay mesh-agnostic, so restores
  may re-shard).
"""
from __future__ import annotations

import bisect
import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, MultiLevelConfig, TrainConfig
from repro.core import flops as flops_lib
from repro.core import operators as ops
from repro.core import plans as plans_lib
from repro.models.api import Model, build_model, make_train_step
from repro.optim import adamw_init


@dataclasses.dataclass
class History:
    """Loss trace indexed by cumulative training FLOPs."""

    flops: List[float] = dataclasses.field(default_factory=list)
    loss: List[float] = dataclasses.field(default_factory=list)
    step: List[int] = dataclasses.field(default_factory=list)
    level: List[int] = dataclasses.field(default_factory=list)

    def log(self, f: float, l: float, s: int, lv: int):
        self.flops.append(float(f))
        self.loss.append(float(l))
        self.step.append(int(s))
        self.level.append(int(lv))

    def smoothed(self, window: int = 5) -> Tuple[np.ndarray, np.ndarray]:
        lo = np.asarray(self.loss)
        fl = np.asarray(self.flops)
        if len(lo) < window:
            return fl, lo
        kernel = np.ones(window) / window
        sm = np.convolve(lo, kernel, mode="valid")
        return fl[window - 1:], sm

    def to_dict(self) -> Dict[str, list]:
        # copies, not views: async checkpoint writers serialize this dict on a
        # background thread while the training loop keeps appending
        return {"flops": list(self.flops), "loss": list(self.loss),
                "step": list(self.step), "level": list(self.level)}


def flops_to_reach(hist: History, target: float, window: int = 5) -> Optional[float]:
    """First cumulative-FLOPs point where the smoothed loss crosses ``target``."""
    fl, sm = hist.smoothed(window)
    idx = np.nonzero(sm <= target)[0]
    return float(fl[idx[0]]) if len(idx) else None


def saving_vs_baseline(base: History, ours: History, window: int = 5) -> Dict[str, float]:
    """The paper's headline metric: FLOPs saving at the baseline's final quality."""
    _, sm = base.smoothed(window)
    target = float(sm[-1])
    f_base = flops_to_reach(base, target, window) or base.flops[-1]
    f_ours = flops_to_reach(ours, target, window)
    if f_ours is None:
        return {"target_loss": target, "flops_saving": float("nan"),
                "base_flops": f_base, "ours_flops": float("nan")}
    return {"target_loss": target, "flops_saving": 1.0 - f_ours / f_base,
            "base_flops": f_base, "ours_flops": f_ours}


# ---------------------------------------------------------------------------
# generic training segment


def _train_loop(step_fn, batch_fn, steps: int, start_in_seg: int, params,
                opt_state, history: History, cum: float, g: int, level: int,
                fps: float, log_every: int, target_loss: Optional[float],
                on_step=None, sync_every_step: bool = False):
    """The one segment inner loop (shared by ``train_segment`` and
    ``VCycleRunner``, so log cadence, FLOPs accounting and the smoothed
    target-loss early stop cannot drift apart between the baselines and the
    V-cycle).

    ``g`` is the global step (keys the deterministic ``batch_fn``); ``i``
    indexes within the segment (keys the log cadence), starting at
    ``start_in_seg`` when resuming.  ``on_step(i, params, opt_state, cum, g,
    stop, dt)`` fires after each step's bookkeeping with the step's measured
    wall time -- the runner hangs state mirroring, checkpoint hooks and the
    watchdog heartbeat there (``stop`` is the target-loss early exit, which a
    checkpoint must not capture: the stop decision is not part of the
    persisted state, so resuming from the stopping step would train past it).
    ``sync_every_step`` blocks on the loss each step so dt is an honest step
    time (same rationale as ``train_plain``: a straggler on a non-log step
    must be seen, and dt must not absorb checkpoint snapshots) -- callers
    without a dt consumer leave it off and keep async-dispatch pipelining.

    The target-loss window covers the CURRENT segment's entries only -- the
    global history mixes in the previous (smaller) level's losses, and
    smoothing across a level boundary can fire a spurious early exit.
    Segment membership is recovered from ``history.step`` (entries newer than
    the segment's starting global step), so a mid-segment resume sees the
    same window as an uninterrupted run.  The original >=5-total-entries
    noise gate is kept, so a fresh run still never stops on its first noisy
    losses; within a V-cycle the window right after a level boundary may
    hold fewer than 5 in-segment samples, and firing on the available mean
    is the pre-existing pinned behavior (tests/test_resume.py).
    """
    seg_base = bisect.bisect_right(history.step, g - start_in_seg)
    for i in range(start_in_seg, steps):
        batch = batch_fn(g)
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if sync_every_step:
            jax.block_until_ready(metrics["loss"])
        dt = time.time() - t0
        cum += fps
        g += 1
        stop = False
        if i % log_every == 0 or i == steps - 1:
            loss = float(metrics["loss"])
            history.log(cum, loss, g, level)
            if target_loss is not None and len(history.loss) >= 5:
                seg_loss = np.asarray(history.loss[seg_base:])
                w = min(5, len(seg_loss))
                if w and float(seg_loss[-w:].mean()) <= target_loss:
                    stop = True
        if on_step is not None:
            on_step(i, params, opt_state, cum, g, stop, dt)
        if stop:
            break
    return params, opt_state, cum, g


def train_segment(
    model: Model,
    tc: TrainConfig,
    batch_fn: Callable[[int], Dict[str, jax.Array]],
    steps: int,
    *,
    params=None,
    opt_state=None,
    history: Optional[History] = None,
    start_flops: float = 0.0,
    start_step: int = 0,
    level: int = 0,
    seed: int = 0,
    target_loss: Optional[float] = None,
    step_fn=None,
):
    """Train ``model`` for ``steps`` optimizer steps, logging (flops, loss)."""
    history = history if history is not None else History()
    if params is None:
        params = model.init(jax.random.PRNGKey(seed))
    if opt_state is None:
        opt_state = adamw_init(params, tc)
    if step_fn is None:
        step_fn = jax.jit(make_train_step(model, tc), donate_argnums=(0, 1))
    specs = model.specs()
    fps = flops_lib.train_step_flops(model.cfg, specs, tc.batch_size, tc.seq_len)
    params, opt_state, cum, g = _train_loop(
        step_fn, batch_fn, steps, 0, params, opt_state, history,
        start_flops, start_step, level, fps, tc.log_every, target_loss)
    return params, opt_state, history, cum, g


# ---------------------------------------------------------------------------
# the V-cycle (Algorithm 1) as an explicit, checkpointable state machine


@dataclasses.dataclass
class VCycleOutput:
    params: Any
    history: History
    configs: List[ModelConfig]
    total_flops: float


@dataclasses.dataclass(frozen=True)
class SegmentPlan:
    """One training segment of Algorithm 1.

    The transition *after* a segment is implied by its phase: ``down`` stashes
    ``params_before[level]`` and coalesces to ``level + 1``; ``up``
    de-coalesces to ``level - 1`` and interpolates with the stash; ``final``
    has no successor.
    """

    phase: str  # "down" | "up" | "final"
    level: int
    steps: int


def segments(cfg: ModelConfig, ml: MultiLevelConfig, tc: TrainConfig,
             *, final_steps: Optional[int] = None) -> List[SegmentPlan]:
    """Deterministic segment schedule for Algorithm 1.

    Step budgets follow the paper: E_a = warmup-sized init segment per level
    before coalescing; E_small = one half of the full cycle for every level
    below the top; the top level then trains until convergence (``tc.steps``
    or ``final_steps``, optionally cut short by a target loss).  ``cfg`` is
    part of the signature so per-architecture budget rules can slot in without
    changing call sites.
    """
    del cfg  # schedule currently depends only on (ml, tc)
    K = ml.n_levels
    E_a = max(int(round(tc.steps * ml.e_a_frac)), 1)
    E_small = max(int(round(tc.steps * ml.e_small_frac)), 1)
    plan = [SegmentPlan("down", l, E_a) for l in range(K - 1)]
    plan += [SegmentPlan("up", l, E_small) for l in range(K - 1, 0, -1)]
    plan.append(SegmentPlan("final", 0,
                            final_steps if final_steps is not None else tc.steps))
    return plan


@dataclasses.dataclass
class VCycleState:
    """Everything needed to re-enter ``VCycleRunner.run`` at an arbitrary
    (phase, level, step).

    ``seg_index``/``seg_step`` address the position in the segment schedule
    (``seg_step`` counts completed optimizer steps *within* the current
    segment, so logging cadence and the post-segment transition replay
    identically on resume); ``params_before`` maps level -> the stashed
    pre-coalesce params that Interpolation consumes on the upward sweep.
    ``phase``/``level`` duplicate the schedule entry for checkpoint metadata
    and log lines.
    """

    phase: str = "down"
    level: int = 0
    seg_index: int = 0
    seg_step: int = 0
    global_step: int = 0
    cum_flops: float = 0.0
    history: History = dataclasses.field(default_factory=History)
    params_before: Dict[int, Any] = dataclasses.field(default_factory=dict)
    # carried gradient-reduction state (EF residuals) for the CURRENT level's
    # shapes; None when the strategy is stateless or not yet initialized.
    # Reset (not re-projected) at level transitions: the residual is bounded
    # by half a quantization step and the optimizer re-initializes there
    # anyway, so dropping it introduces no bias -- re-projecting sub-ULP
    # noise through the coalesce operators would be complexity for nothing.
    ef: Any = None


class VCycleRunner:
    """Checkpointable driver for Algorithm 1.

    Owns the per-level model stack and a per-level compiled train-step cache:
    each level's step is built and ``jax.jit``-compiled at most once per run
    even though levels below the top are visited twice (down + up sweep).
    ``run`` may be entered fresh or from a restored :class:`VCycleState`; a
    ``ckpt_cb(state, params, opt_state)`` hook fires every ``ckpt_every``
    global steps (the launcher plugs ``repro.checkpoint`` in there), and an
    ``on_step(state, params, opt_state, stopping, dt)`` hook fires on EVERY
    step with the measured step time (the launcher hangs its watchdog
    heartbeat and SIGTERM preemption check there).

    With ``mesh`` set, the runner is mesh-parallel end to end: each level's
    train step jits with explicit ``in_shardings``/``out_shardings`` (params
    and optimizer from the level's Spec tree via the logical-axis rules, the
    batch data-sharded over the data axes) plus donation, and the level
    transitions (coalesce / de-coalesce+interpolate) run sharded-in,
    sharded-out onto the TARGET level's layout.  Because checkpoints store
    logical (unsharded) arrays, a state saved under one mesh restores onto a
    runner built with another (see ``launch/train.py``).
    """

    def __init__(self, cfg: ModelConfig, ml: MultiLevelConfig, tc: TrainConfig,
                 batch_fn: Callable[[int], Dict[str, jax.Array]], *,
                 seed: int = 0, target_loss: Optional[float] = None,
                 final_steps: Optional[int] = None, verbose: bool = False,
                 mesh=None, drain_flag=None, grad_reduce=None):
        self.ml, self.tc, self.batch_fn = ml, tc, batch_fn
        self.seed, self.target_loss, self.verbose = seed, target_loss, verbose
        self.mesh = mesh
        # a distributed.FusedDrainFlag: the preemption drain OR is computed
        # INSIDE each level's compiled step (one extra tiny input + metrics
        # scalar) instead of a dedicated per-step process_allgather
        self.drain_flag = drain_flag if mesh is not None else None
        # pluggable gradient reduction (distributed/reduce.py): pass a strategy
        # explicitly or let tc.grad_compression name one; either way the
        # per-level steps become shard_map'd with the reduction injected
        if grad_reduce is None and mesh is not None:
            from repro.distributed import make_grad_reduce

            grad_reduce = make_grad_reduce(tc.grad_compression, mesh)
        if grad_reduce is not None and mesh is None:
            raise ValueError("grad_reduce requires a mesh")
        self.grad_reduce = grad_reduce
        # one ProjectionPlan per level transition: proj_plans[l] is the
        # explicit family contract for level l <-> l+1 (which axes halve,
        # which are protected, the role overrides, the carried MoE scalars).
        # self.cfgs derives from the plans so config halving and the maps the
        # transitions apply can never disagree.  NB ``self.plan`` (no s) is
        # the *segment schedule* -- a different thing, and external consumers
        # (benchmarks) read it by that name.
        self.cfgs = [cfg]
        self.proj_plans = []
        for _ in range(ml.n_levels - 1):
            p = plans_lib.build_plan(self.cfgs[-1], ml)
            self.proj_plans.append(p)
            self.cfgs.append(p.small_cfg)
        self.models = [build_model(c) for c in self.cfgs]
        self.specs = [m.specs() for m in self.models]
        self.plan = segments(cfg, ml, tc, final_steps=final_steps)
        if verbose:
            for p in self.proj_plans:
                print("[vcycle] " + p.describe().replace("\n", "\n[vcycle] "))
        self.state: Optional[VCycleState] = None
        self._step_fns: Dict[int, Callable] = {}
        self._shardings: Dict[int, Tuple[Any, Any]] = {}
        self._batch_sh = None
        self.n_compiles = 0  # probe: must end up == #levels visited

    def level_shardings(self, level: int) -> Tuple[Any, Any]:
        """(param, opt) NamedSharding trees for ``level``; (None, None) when
        the runner has no mesh.  Cached: layouts are pure functions of the
        level's Spec tree and the mesh."""
        if self.mesh is None:
            return None, None
        got = self._shardings.get(level)
        if got is None:
            from repro.models.api import train_state_shardings

            got = train_state_shardings(self.models[level], self.tc, self.mesh)
            self._shardings[level] = got
        return got

    def ef_shardings(self, level: int):
        """NamedSharding tree for the grad-reduce carried state at ``level``
        (None when the strategy is absent or stateless)."""
        gr = self.grad_reduce
        if gr is None or not gr.stateful or self.mesh is None:
            return None
        psh, _ = self.level_shardings(level)
        return gr.state_shardings(psh, self.mesh)

    def batch_shardings(self):
        """Data-parallel shardings for ``batch_fn``'s pytree (None w/o mesh)."""
        if self.mesh is None:
            return None
        if self._batch_sh is None:
            from repro.distributed import batch_like, batch_shardings

            # batch_like honors a GlobalBatchFn's precomputed .like: the
            # multi-process host->global batch conversion cannot be traced
            # by jax.eval_shape
            self._batch_sh = batch_shardings(batch_like(self.batch_fn),
                                             self.mesh)
        return self._batch_sh

    def step_fn(self, level: int) -> Callable:
        """The compiled train step for ``level`` (built once, then cached).

        With a ``grad_reduce`` strategy the underlying step is the 4-ary
        shard_map'd one (params, opt, ef, batch); the runner wraps it back to
        the loop's 3-ary shape by threading ``self.state.ef`` through, so the
        segment loop, logging and checkpoint cadence stay strategy-agnostic.
        """
        fn = self._step_fns.get(level)
        if fn is None:
            if self.grad_reduce is not None:
                step = make_train_step(self.models[level], self.tc,
                                       grad_reduce=self.grad_reduce,
                                       mesh=self.mesh)
            else:
                step = make_train_step(self.models[level], self.tc)
            if self.mesh is None:
                fn = jax.jit(step, donate_argnums=(0, 1))
            else:
                from jax.sharding import NamedSharding, PartitionSpec

                psh, osh = self.level_shardings(level)
                # metrics are explicitly replicated: the host loss fetch
                # (float()) must work on every process of a multi-process mesh
                rep = NamedSharding(self.mesh, PartitionSpec())
                if self.grad_reduce is not None:
                    efsh = self.ef_shardings(level)
                    if self.drain_flag is not None:
                        fn4 = self.drain_flag.wrap_step(
                            step,
                            in_shardings=(psh, osh, efsh, self.batch_shardings()),
                            out_shardings=(psh, osh, efsh, rep),
                            donate_argnums=(0, 1, 2))
                    else:
                        fn4 = jax.jit(
                            step,
                            in_shardings=(psh, osh, efsh, self.batch_shardings()),
                            out_shardings=(psh, osh, efsh, rep),
                            donate_argnums=(0, 1, 2))

                    def fn(p, o, b, _fn4=fn4):
                        st = self.state
                        p, o, st.ef, m = _fn4(p, o, st.ef, b)
                        return p, o, m
                elif self.drain_flag is not None:
                    fn = self.drain_flag.wrap_step(
                        step,
                        in_shardings=(psh, osh, self.batch_shardings()),
                        out_shardings=(psh, osh, rep))
                else:
                    fn = jax.jit(step,
                                 in_shardings=(psh, osh, self.batch_shardings()),
                                 out_shardings=(psh, osh, rep),
                                 donate_argnums=(0, 1))
            self._step_fns[level] = fn
            self.n_compiles += 1
        return fn

    def init_state(self) -> Tuple[VCycleState, Any]:
        """Fresh (state, params) for an uninterrupted run.  The init is
        deterministic, so on a multi-process mesh every process computes the
        same full value and keeps only its addressable shards."""
        from repro.distributed import put_global_tree

        params = self.models[0].init(jax.random.PRNGKey(self.seed))
        psh, _ = self.level_shardings(0)
        if psh is not None:
            params = put_global_tree(params, psh)
        return VCycleState(), params

    def _init_opt(self, level: int, params):
        """Fresh optimizer state for ``level`` (re-init at transitions, paper
        App. C), laid out on the mesh when there is one."""
        from repro.distributed import put_global_tree

        _, osh = self.level_shardings(level)
        if osh is None:
            return adamw_init(params, self.tc)
        # zeros are built from shapes (host-local), then landed shard-wise --
        # adamw_init on global params would otherwise try a cross-process
        # device_put
        like = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype),
                            params)
        return put_global_tree(adamw_init(like, self.tc), osh)

    def _init_ef(self, level: int, params):
        """Zero grad-reduce state for ``level`` (None for stateless/absent
        strategies), laid out on the mesh shard-wise like ``_init_opt``."""
        gr = self.grad_reduce
        if gr is None or not gr.stateful:
            return None
        from repro.distributed import put_global_tree

        return put_global_tree(gr.init_state(params), self.ef_shardings(level))

    def _transition(self, state: VCycleState, plan: SegmentPlan, params):
        """Apply the post-segment operator (Alg. 1 lines 3-4 / 7-9); with a
        mesh the projection lands directly on the target level's layout."""
        l = plan.level
        if plan.phase == "down":
            state.params_before[l] = params
            if self.verbose:
                print(f"[vcycle] level {l} init-trained {plan.steps} steps, coalescing")
            return ops.make_coalesce_fn(
                self.specs[l], self.cfgs[l], self.ml,
                out_shardings=self.level_shardings(l + 1)[0],
                plan=self.proj_plans[l])(params)
        if plan.phase == "up":
            if self.verbose:
                print(f"[vcycle] level {l} trained {plan.steps} steps, de-coalescing")
            target_sh = self.level_shardings(l - 1)[0]
            de = ops.make_decoalesce_fn(self.specs[l - 1], self.cfgs[l - 1],
                                        self.ml, out_shardings=target_sh,
                                        plan=self.proj_plans[l - 1])(params)
            # pop, don't read: the stash is consumed here, and dropping it
            # keeps later checkpoints from re-serializing dead full-size trees
            before = state.params_before.pop(l - 1)
            return ops.make_interpolate_fn(
                self.ml.alpha, backend=self.cfgs[l - 1].kernel_backend or None,
                out_shardings=target_sh)(before, de)
        return params

    def run(self, *, state: Optional[VCycleState] = None, params=None,
            opt_state=None, ckpt_cb=None, ckpt_every: int = 0,
            on_step=None) -> VCycleOutput:
        """Run (or resume) the V-cycle to completion.

        Fresh run: call with no arguments.  Resume: pass the restored
        ``state`` + ``params`` (+ ``opt_state`` when mid-segment).  Data
        order is keyed on ``state.global_step``, checkpoints always capture
        the in-segment, pre-transition view, and transitions are
        deterministically replayed from it -- so a resumed run is equivalent
        to an uninterrupted one.  ``on_step(state, params, opt_state,
        stopping, dt)`` fires after every step's bookkeeping (after any
        ``ckpt_cb``) with the step's measured wall time -- it may raise to
        abort the run.
        """
        if state is None:
            state, params = self.init_state()
        elif params is None:
            raise ValueError("resuming from a VCycleState requires params")
        self.state = state
        tc = self.tc
        while state.seg_index < len(self.plan):
            plan = self.plan[state.seg_index]
            state.phase, state.level = plan.phase, plan.level
            fn = self.step_fn(plan.level)
            if opt_state is None:  # re-init at transitions (paper App. C)
                opt_state = self._init_opt(plan.level, params)
            if state.ef is None:  # fresh zeros per level (see VCycleState.ef)
                state.ef = self._init_ef(plan.level, params)
            fps = flops_lib.train_step_flops(
                self.cfgs[plan.level], self.specs[plan.level],
                tc.batch_size, tc.seq_len)

            def _on_step(i, p, o, cum, g, stopping, dt):
                state.cum_flops, state.global_step = cum, g
                state.seg_step = i + 1
                # never checkpoint the stopping step: a restart from it would
                # resume into training the early exit already cut off
                if (ckpt_cb is not None and ckpt_every and not stopping
                        and g % ckpt_every == 0):
                    ckpt_cb(state, p, o)
                if on_step is not None:
                    on_step(state, p, o, stopping, dt)

            params, opt_state, state.cum_flops, state.global_step = _train_loop(
                fn, self.batch_fn, plan.steps, state.seg_step, params,
                opt_state, state.history, state.cum_flops, state.global_step,
                plan.level, fps, tc.log_every,
                self.target_loss if plan.phase == "final" else None,
                on_step=_on_step,
                # honest per-step dt only when someone consumes it; library
                # callers without a hook keep async-dispatch pipelining
                sync_every_step=on_step is not None)
            params = self._transition(state, plan, params)
            state.seg_index += 1
            state.seg_step = 0
            opt_state = None
            # EF residuals are level-shaped; reset across the transition (the
            # next segment re-zeros them -- see the VCycleState.ef rationale)
            state.ef = None
        return VCycleOutput(params=params, history=state.history,
                            configs=self.cfgs, total_flops=state.cum_flops)


def run_vcycle(
    cfg: ModelConfig,
    ml: MultiLevelConfig,
    tc: TrainConfig,
    batch_fn: Callable[[int], Dict[str, jax.Array]],
    *,
    seed: int = 0,
    target_loss: Optional[float] = None,
    final_steps: Optional[int] = None,
    verbose: bool = False,
) -> VCycleOutput:
    """Paper Algorithm 1 (thin wrapper over :class:`VCycleRunner`).

    Step budgets follow the paper: E_a = warmup-sized init segment per level
    before coalescing; E_small = one half of the full cycle for every level
    below the top; the top level then trains until convergence (here: until
    ``target_loss`` or ``final_steps``/``tc.steps``).
    """
    runner = VCycleRunner(cfg, ml, tc, batch_fn, seed=seed,
                          target_loss=target_loss, final_steps=final_steps,
                          verbose=verbose)
    return runner.run()


def run_scratch(
    cfg: ModelConfig,
    tc: TrainConfig,
    batch_fn: Callable[[int], Dict[str, jax.Array]],
    *,
    seed: int = 0,
    steps: Optional[int] = None,
) -> Tuple[Any, History]:
    model = build_model(cfg)
    params, _, hist, _, _ = train_segment(
        model, tc, batch_fn, steps or tc.steps, seed=seed, level=0)
    return params, hist
