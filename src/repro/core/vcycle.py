"""V-cycle training process (paper Algorithm 1) + generic training loop with
FLOPs-indexed loss history (the paper's evaluation axis).

The runner is production-shaped: per-level compiled steps are built once and
cached; level transitions are jitted and host-round-trip-free, with the
"stack"-variant width projections and the interpolation running matrix-free
through the kernel registry (repro.kernels.dispatch: Pallas on TPU, fused XLA
elsewhere); the optimizer is re-initialized at transitions (paper §Discussion
/ App. C); and
the whole V-cycle state (level, phase, step) is checkpointable via
``repro.checkpoint`` (see launch/train.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, MultiLevelConfig, TrainConfig
from repro.core import flops as flops_lib
from repro.core import operators as ops
from repro.models.api import Model, build_model, make_train_step
from repro.optim import adamw_init


@dataclasses.dataclass
class History:
    """Loss trace indexed by cumulative training FLOPs."""

    flops: List[float] = dataclasses.field(default_factory=list)
    loss: List[float] = dataclasses.field(default_factory=list)
    step: List[int] = dataclasses.field(default_factory=list)
    level: List[int] = dataclasses.field(default_factory=list)

    def log(self, f: float, l: float, s: int, lv: int):
        self.flops.append(float(f))
        self.loss.append(float(l))
        self.step.append(int(s))
        self.level.append(int(lv))

    def smoothed(self, window: int = 5) -> Tuple[np.ndarray, np.ndarray]:
        lo = np.asarray(self.loss)
        fl = np.asarray(self.flops)
        if len(lo) < window:
            return fl, lo
        kernel = np.ones(window) / window
        sm = np.convolve(lo, kernel, mode="valid")
        return fl[window - 1:], sm

    def to_dict(self) -> Dict[str, list]:
        return {"flops": self.flops, "loss": self.loss, "step": self.step, "level": self.level}


def flops_to_reach(hist: History, target: float, window: int = 5) -> Optional[float]:
    """First cumulative-FLOPs point where the smoothed loss crosses ``target``."""
    fl, sm = hist.smoothed(window)
    idx = np.nonzero(sm <= target)[0]
    return float(fl[idx[0]]) if len(idx) else None


def saving_vs_baseline(base: History, ours: History, window: int = 5) -> Dict[str, float]:
    """The paper's headline metric: FLOPs saving at the baseline's final quality."""
    _, sm = base.smoothed(window)
    target = float(sm[-1])
    f_base = flops_to_reach(base, target, window) or base.flops[-1]
    f_ours = flops_to_reach(ours, target, window)
    if f_ours is None:
        return {"target_loss": target, "flops_saving": float("nan"),
                "base_flops": f_base, "ours_flops": float("nan")}
    return {"target_loss": target, "flops_saving": 1.0 - f_ours / f_base,
            "base_flops": f_base, "ours_flops": f_ours}


# ---------------------------------------------------------------------------
# generic training segment


def train_segment(
    model: Model,
    tc: TrainConfig,
    batch_fn: Callable[[int], Dict[str, jax.Array]],
    steps: int,
    *,
    params=None,
    opt_state=None,
    history: Optional[History] = None,
    start_flops: float = 0.0,
    start_step: int = 0,
    level: int = 0,
    seed: int = 0,
    target_loss: Optional[float] = None,
    step_fn=None,
):
    """Train ``model`` for ``steps`` optimizer steps, logging (flops, loss)."""
    history = history if history is not None else History()
    if params is None:
        params = model.init(jax.random.PRNGKey(seed))
    if opt_state is None:
        opt_state = adamw_init(params, tc)
    if step_fn is None:
        step_fn = jax.jit(make_train_step(model, tc), donate_argnums=(0, 1))
    specs = model.specs()
    fps = flops_lib.train_step_flops(model.cfg, specs, tc.batch_size, tc.seq_len)
    cum = start_flops
    g = start_step
    for i in range(steps):
        batch = batch_fn(g)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        cum += fps
        g += 1
        if i % tc.log_every == 0 or i == steps - 1:
            loss = float(metrics["loss"])
            history.log(cum, loss, g, level)
            if target_loss is not None and len(history.loss) >= 5:
                _, sm = history.smoothed(5)
                if len(sm) and sm[-1] <= target_loss:
                    break
    return params, opt_state, history, cum, g


# ---------------------------------------------------------------------------
# the V-cycle (Algorithm 1)


@dataclasses.dataclass
class VCycleOutput:
    params: Any
    history: History
    configs: List[ModelConfig]
    total_flops: float


def run_vcycle(
    cfg: ModelConfig,
    ml: MultiLevelConfig,
    tc: TrainConfig,
    batch_fn: Callable[[int], Dict[str, jax.Array]],
    *,
    seed: int = 0,
    target_loss: Optional[float] = None,
    final_steps: Optional[int] = None,
    verbose: bool = False,
) -> VCycleOutput:
    """Paper Algorithm 1.

    Step budgets follow the paper: E_a = warmup-sized init segment per level
    before coalescing; E_small = one half of the full cycle for every level
    below the top; the top level then trains until convergence (here: until
    ``target_loss`` or ``final_steps``/``tc.steps``).
    """
    K = ml.n_levels
    cfgs = [cfg]
    for _ in range(K - 1):
        cfgs.append(ops.coalesce_config(cfgs[-1], ml))
    models = [build_model(c) for c in cfgs]
    specs = [m.specs() for m in models]
    E_a = max(int(round(tc.steps * ml.e_a_frac)), 1)
    E_small = max(int(round(tc.steps * ml.e_small_frac)), 1)

    hist = History()
    cum, g = 0.0, 0
    params_before: List[Any] = [None] * K

    # ---- downward sweep: init-train E_a then coalesce (Alg. 1 lines 1-4)
    params = models[0].init(jax.random.PRNGKey(seed))
    for l in range(K - 1):
        params, _, hist, cum, g = train_segment(
            models[l], tc, batch_fn, E_a, params=params, history=hist,
            start_flops=cum, start_step=g, level=l, seed=seed)
        params_before[l] = params
        if verbose:
            print(f"[vcycle] level {l} init-trained {E_a} steps, coalescing")
        params = ops.make_coalesce_fn(specs[l], cfgs[l], ml)(params)

    # ---- upward sweep: train E_small, de-coalesce, interpolate (lines 5-9)
    for l in range(K - 1, 0, -1):
        params, _, hist, cum, g = train_segment(
            models[l], tc, batch_fn, E_small, params=params, history=hist,
            start_flops=cum, start_step=g, level=l, seed=seed)
        if verbose:
            print(f"[vcycle] level {l} trained {E_small} steps, de-coalescing")
        de = ops.make_decoalesce_fn(specs[l - 1], cfgs[l - 1], ml)(params)
        params = ops.make_interpolate_fn(
            ml.alpha, backend=cfgs[l - 1].kernel_backend or None)(
            params_before[l - 1], de)

    # ---- final: train M_1 until convergence (line 10)
    fs = final_steps if final_steps is not None else tc.steps
    params, _, hist, cum, g = train_segment(
        models[0], tc, batch_fn, fs, params=params, history=hist,
        start_flops=cum, start_step=g, level=0, seed=seed, target_loss=target_loss)
    return VCycleOutput(params=params, history=hist, configs=cfgs, total_flops=cum)


def run_scratch(
    cfg: ModelConfig,
    tc: TrainConfig,
    batch_fn: Callable[[int], Dict[str, jax.Array]],
    *,
    seed: int = 0,
    steps: Optional[int] = None,
) -> Tuple[Any, History]:
    model = build_model(cfg)
    params, _, hist, _, _ = train_segment(
        model, tc, batch_fn, steps or tc.steps, seed=seed, level=0)
    return params, hist
