"""The paper's three operators on arbitrary models (Coalescing, De-coalescing,
Interpolation), driven entirely by the per-leaf logical-axis metadata.

For every width-coalescible logical axis (embed, mlp, heads, kv_heads, lora
ranks, expert dims, ...) one shared set of projection matrices is built --
which *is* the Appendix-A constraint structure: residual stream, Q/K alignment
and norm scales automatically share their F.  The "layers" axis is handled by
the depth matrices R/G per stage.  Protected axes (head_dim, rope dims,
d_state, conv taps, vocab, per-head recurrent memories) are never projected;
see DESIGN.md §4.

Execution: for the paper's main "stack" width variant the F/T contractions are
pair merges and duplications, so the leaves route through the matrix-free
fused kernels behind ``repro.kernels.dispatch`` (``coalesce_pair`` /
``interp_axpy``; one HBM pass, no F matrix, no MXU) -- the "adj" variant,
``embed_cat2`` block-diagonal matrices and depth R/G keep the dense-matrix
``tensordot`` path.  All of it stays jit-compatible: backend resolution is
trace-time, so ``vcycle`` level transitions remain host-round-trip-free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, MultiLevelConfig, Stage
from repro.core import projections as proj
from repro.kernels import dispatch as kdispatch
from repro.param import Spec, is_spec

# logical axes subject to width coalescing, with the config field giving their size
WIDTH_AXES = (
    "embed", "mlp", "heads", "kv_heads", "q_lora", "kv_lora",
    "moe_mlp", "shared_mlp", "mamba_inner", "dt_rank", "experts", "embed_cat2",
)


def axis_sizes(cfg: ModelConfig) -> Dict[str, int]:
    """Current size of every width-coalescible axis present in this model."""
    s: Dict[str, int] = {"embed": cfg.d_model, "heads": cfg.n_heads,
                         "kv_heads": cfg.n_kv_heads, "embed_cat2": 2 * cfg.d_model}
    if cfg.d_ff:
        s["mlp"] = cfg.d_ff
    if cfg.attn_type == "mla":
        s["q_lora"] = cfg.q_lora_rank
        s["kv_lora"] = cfg.kv_lora_rank
    if cfg.n_experts:
        s["moe_mlp"] = cfg.moe_d_ff or cfg.d_ff
        if cfg.n_shared_experts:
            s["shared_mlp"] = cfg.n_shared_experts * (cfg.moe_d_ff or cfg.d_ff)
        if cfg.coalesce_experts:
            s["experts"] = cfg.n_experts
    if any(b.mixer == "mamba" for st in cfg.stages for b in st.pattern):
        s["mamba_inner"] = cfg.mamba_d_inner
        s["dt_rank"] = cfg.resolved_dt_rank
    return s


def coalesce_config(cfg: ModelConfig, ml: Optional[MultiLevelConfig] = None,
                    *, width: bool = True, depth: bool = True) -> ModelConfig:
    """The next-level (smaller) model config: width and depth halved.

    A dimension is halved iff it is even -- exactly the condition under which
    ``build_level_maps`` constructs its width matrices, so config and
    projected parameter shapes stay consistent for any architecture.
    ``width``/``depth`` switches support the single-direction baselines
    (StackBERT = depth-only, bert2BERT = width-only).
    """
    halve = (lambda x: x // 2 if (x and x % 2 == 0) else x) if width else (lambda x: x)
    if depth:
        new_stages = tuple(Stage(st.pattern, (st.repeats + 1) // 2) for st in cfg.stages)
    else:
        new_stages = cfg.stages
    kw: Dict[str, Any] = dict(
        d_model=halve(cfg.d_model),
        n_heads=halve(cfg.n_heads),
        n_kv_heads=halve(cfg.n_kv_heads),
        d_ff=halve(cfg.d_ff),
        stages=new_stages,
        head_dim=cfg.resolved_head_dim,  # head width preserved; heads merge whole
    )
    if cfg.attn_type == "mla":
        kw.update(q_lora_rank=halve(cfg.q_lora_rank), kv_lora_rank=halve(cfg.kv_lora_rank))
    if cfg.n_experts:
        kw.update(moe_d_ff=halve(cfg.moe_d_ff))
        if cfg.coalesce_experts:
            kw.update(n_experts=halve(cfg.n_experts),
                      moe_top_k=min(cfg.moe_top_k, halve(cfg.n_experts)))
    if any(b.mixer == "mamba" for st in cfg.stages for b in st.pattern):
        kw.update(mamba_dt_rank=halve(cfg.resolved_dt_rank))
    if cfg.n_encoder_layers and depth:
        kw.update(n_encoder_layers=(cfg.n_encoder_layers + 1) // 2)
    if any(b.mixer == "cross_attn" for st in cfg.stages for b in st.pattern):
        # the stub frontend's feature dim is fixed; pin it before halving d_model
        kw.update(vision_dim=cfg.vision_dim or cfg.d_model)
    return cfg.replace(**kw)


@dataclasses.dataclass
class LevelMaps:
    """Projection matrices between a (large cfg, small cfg) level pair."""

    width: Dict[str, proj.WidthMats]
    depth: Dict[str, proj.DepthMats]  # per stage name + "encoder"

    def as_jnp(self, dtype=jnp.float32) -> "LevelMaps":
        width = {k: dataclasses.replace(
                     v, **{f: jnp.asarray(getattr(v, f), dtype)
                           for f in proj.MAT_FIELDS})
                 for k, v in self.width.items()}
        depth = {k: proj.DepthMats(R=jnp.asarray(v.R, dtype), G=jnp.asarray(v.G, dtype))
                 for k, v in self.depth.items()}
        return LevelMaps(width=width, depth=depth)


def build_level_maps(cfg: ModelConfig, ml: MultiLevelConfig,
                     *, width: bool = True, depth: bool = True) -> LevelMaps:
    wmats: Dict[str, proj.WidthMats] = {}
    if width:
        sizes = axis_sizes(cfg)
        for ax, n in sizes.items():
            if ax == "embed_cat2":
                continue
            if n >= 2 and n % 2 == 0:
                wmats[ax] = proj.width_mats(n, ml.width_variant)
        if "embed" in wmats:
            wmats["embed_cat2"] = proj.block_diag_width(wmats["embed"], 2)
    dmats: Dict[str, proj.DepthMats] = {}
    if depth:
        for i, st in enumerate(cfg.stages):
            dmats[f"stage_{i}"] = proj.depth_mats(st.repeats, ml.depth_variant)
        if cfg.n_encoder_layers:
            dmats["encoder"] = proj.depth_mats(cfg.n_encoder_layers, ml.depth_variant)
    return LevelMaps(width=wmats, depth=dmats)


# ---------------------------------------------------------------------------
# applying the projections to a parameter tree


def _contract(w: jax.Array, dim: int, mat: jax.Array, mat_axis: int) -> jax.Array:
    """Contract w's ``dim`` with mat's ``mat_axis``; result axis moved back."""
    out = jnp.tensordot(w, mat, axes=([dim], [mat_axis]))
    return jnp.moveaxis(out, -1, dim)


def _stack_coalesce(w: jax.Array, dim: int, w0: float, backend) -> jax.Array:
    """Matrix-free "stack"-variant coalescing of ``dim``: fold the leaf to 2D
    and merge pairs (i, i + n/2) in one fused pass (no F matrix, no matmul)."""
    n = w.shape[dim]
    rest = tuple(s for i, s in enumerate(w.shape) if i != dim)
    w2 = jnp.moveaxis(w, dim, 0).reshape(n, -1)
    out = kdispatch.dispatch("coalesce_pair", w2, axis=0, w0=w0, backend=backend)
    return jnp.moveaxis(out.reshape((n // 2,) + rest), 0, dim)


def _stack_decoalesce(w: jax.Array, dim: int, w0: float) -> jax.Array:
    """Matrix-free "stack"-variant de-coalescing: T duplication is a pure
    gather -- tile the halved axis twice, scaled by the paper's normalization
    weight (T_out rows are 1.0, T_in rows 0.5).

    Duplication is broadcast+reshape, NOT ``concatenate([w, w])``: XLA's SPMD
    partitioner miscompiles a concat whose operands alias the same *sharded*
    tensor (the halves get summed -- jaxlib 0.4.37 CPU/GSPMD), and the
    aliasing survives a ``w + 0.0`` copy via CSE.  Broadcast lowers cleanly
    under any sharding and is the same single HBM pass."""
    lead = jnp.moveaxis(w, dim, 0)
    dup = jnp.broadcast_to(lead[None], (2,) + lead.shape)
    dup = dup.reshape((2 * lead.shape[0],) + lead.shape[1:])
    dup = jnp.moveaxis(dup, 0, dim)
    if w0 == 1.0:
        return dup
    return (w0 * dup.astype(jnp.float32)).astype(w.dtype)


def _width_leaf(w, spec: Spec, width: Dict[str, proj.WidthMats], direction: str,
                coalesce_experts: bool, backend=None, fused: bool = True):
    for d, (ax, role) in enumerate(zip(spec.axes, spec.roles)):
        if ax == "experts" and coalesce_experts and "experts" in width:
            role = "out"  # expert pair-averaging (beyond-paper extension)
        if ax not in width or role not in ("in", "out"):
            continue
        m = width[ax]
        if fused and getattr(m, "variant", None) == "stack":
            # the "stack" averaging matrices ARE pair merges/duplications:
            # route through the fused kernels instead of materializing F
            # (F_out weights 0.5, F_in 1.0; T_out 1.0, T_in 0.5 -- the
            # paper's normalization, pinned by kernels/ref.py oracles)
            if direction == "coalesce":
                w = _stack_coalesce(w, d, 0.5 if role == "out" else 1.0, backend)
            else:
                w = _stack_decoalesce(w, d, 1.0 if role == "out" else 0.5)
        elif direction == "coalesce":
            w = _contract(w, d, m.F_out, 0) if role == "out" else _contract(w, d, m.F_in, 1)
        else:
            w = _contract(w, d, m.T_out, 0) if role == "out" else _contract(w, d, m.T_in, 1)
    return w


def _depth_leaf(w, spec: Spec, dm: proj.DepthMats, direction: str):
    if not spec.axes or spec.axes[0] != "layers":
        return w
    if direction == "coalesce":
        return jnp.einsum("l...,lj->j...", w, dm.R)  # R: [L, L2]
    return jnp.einsum("l...,lj->j...", w, dm.G)  # G: [L2, L]


def _project_tree(params, specs, maps: LevelMaps, direction: str,
                  coalesce_experts: bool, depth_key: Optional[str] = None,
                  backend: Optional[str] = None, fused: bool = True):
    """Recurse through the tree, tracking which stage we are under so the right
    depth matrices apply."""

    def rec(p, s, dkey):
        if is_spec(s):
            w = _width_leaf(p, s, maps.width, direction, coalesce_experts,
                            backend=backend, fused=fused)
            if dkey is not None and dkey in maps.depth:
                w = _depth_leaf(w, s, maps.depth[dkey], direction)
            return w
        out = {}
        for k in s:
            sub_dkey = dkey
            if k.startswith("stage_"):
                sub_dkey = k
            elif k == "encoder":
                sub_dkey = "encoder"
            out[k] = rec(p[k], s[k], sub_dkey)
        return out

    return rec(params, specs, depth_key)


def coalesce(params, specs, cfg: ModelConfig, ml: MultiLevelConfig,
             maps: Optional[LevelMaps] = None, *, fused: bool = True):
    """Paper Algorithm 2: width then depth (they commute on disjoint axes)."""
    maps = (maps or build_level_maps(cfg, ml)).as_jnp()
    return _project_tree(params, specs, maps, "coalesce", cfg.coalesce_experts,
                         backend=cfg.kernel_backend or None, fused=fused)


def decoalesce(params_small, specs, cfg: ModelConfig, ml: MultiLevelConfig,
               maps: Optional[LevelMaps] = None, *, fused: bool = True):
    """Paper Algorithm 3: depth then width.  ``specs``/``cfg`` are the LARGE
    level's; ``params_small`` the small level's parameters."""
    maps = (maps or build_level_maps(cfg, ml)).as_jnp()
    return _project_tree(params_small, specs, maps, "decoalesce",
                         cfg.coalesce_experts,
                         backend=cfg.kernel_backend or None, fused=fused)


def interpolate(params_large, params_decoalesced, alpha: float,
                backend: Optional[str] = None):
    """Paper Algorithm 4 / Eq. 13: M <- (1-a) M + a D(M_small).

    Each leaf runs through the fused ``interp_axpy`` kernel (one read of a and
    b, one write -- the memory-bound pass the Pallas kernel targets at scale)."""
    return jax.tree.map(
        lambda a, b: kdispatch.dispatch("interp_axpy", a, b, alpha,
                                        backend=backend),
        params_large, params_decoalesced)


def make_coalesce_fn(specs, cfg: ModelConfig, ml: MultiLevelConfig,
                     *, width: bool = True, depth: bool = True,
                     fused: bool = True, out_shardings=None):
    """jit'd level-transition.  "stack"-variant width axes route through the
    matrix-free fused kernels (repro.kernels.dispatch); everything else runs
    as sharded einsums.  ``fused=False`` forces the dense-matrix path (the
    equivalence oracle for tests/benchmarks).  ``out_shardings`` (a
    NamedSharding tree for the TARGET level's params) makes the projection
    sharded-in, sharded-out under a mesh -- no host round trip, no gather."""
    maps = build_level_maps(cfg, ml, width=width, depth=depth).as_jnp()
    backend = cfg.kernel_backend or None
    return jax.jit(lambda p: _project_tree(p, specs, maps, "coalesce",
                                           cfg.coalesce_experts,
                                           backend=backend, fused=fused),
                   out_shardings=out_shardings)


def make_decoalesce_fn(specs, cfg: ModelConfig, ml: MultiLevelConfig,
                       *, width: bool = True, depth: bool = True,
                       fused: bool = True, out_shardings=None):
    maps = build_level_maps(cfg, ml, width=width, depth=depth).as_jnp()
    backend = cfg.kernel_backend or None
    return jax.jit(lambda p: _project_tree(p, specs, maps, "decoalesce",
                                           cfg.coalesce_experts,
                                           backend=backend, fused=fused),
                   out_shardings=out_shardings)


def make_interpolate_fn(alpha: float, backend: Optional[str] = None,
                        out_shardings=None):
    return jax.jit(lambda a, b: interpolate(a, b, alpha, backend=backend),
                   out_shardings=out_shardings)


def make_draft_projection(specs, cfg: ModelConfig,
                          ml: Optional[MultiLevelConfig] = None,
                          *, width: bool = True, depth: bool = True,
                          out_shardings=None) -> Tuple[ModelConfig, Any]:
    """Serving-time self-speculative draft: ``(draft_cfg, project_fn)``.

    The level-1 coalesced model is a deterministic *projection* of the
    serving params -- a free, always-in-sync draft model for speculative
    decoding: no separate training run, no second checkpoint to distribute.
    ``project_fn(params) -> draft_params`` is the jit'd Coalescing transition
    (sharded-in/sharded-out when ``out_shardings`` is given); re-invoke it
    whenever the serving params change (hot weight reload) and the draft
    stays in sync by construction.

    ``width``/``depth`` pick the projection direction: width-only drafts
    track the full model most closely (width de-coalescing is exactly
    function-preserving for untied embeddings, see tests/test_operators.py),
    full level-1 (both) is the cheapest draft the paper defines.
    """
    ml = ml or MultiLevelConfig()
    draft_cfg = coalesce_config(cfg, ml, width=width, depth=depth)
    project = make_coalesce_fn(specs, cfg, ml, width=width, depth=depth,
                               out_shardings=out_shardings)
    return draft_cfg, project
