"""The paper's three operators on arbitrary models (Coalescing, De-coalescing,
Interpolation), driven by per-family :class:`~repro.core.plans.ProjectionPlan`
objects over the per-leaf logical-axis metadata.

For every width-coalescible logical axis (embed, mlp, heads, kv_heads, lora
ranks, expert dims, ...) one shared set of projection matrices is built --
which *is* the Appendix-A constraint structure: residual stream, Q/K alignment
and norm scales automatically share their F.  The "layers" axis is handled by
the depth matrices R/G per stage.  Protected axes (head_dim, rope dims,
d_state, conv taps, vocab, per-head recurrent memories) are never projected;
see DESIGN.md §4.

Which axes coalesce, which are protected, and which per-leaf roles get
rewritten (e.g. the MoE "experts" axis under expert merging) is decided by
``repro.core.plans.build_plan`` -- ``coalesce_config`` / ``build_level_maps``
here are thin compatibility wrappers over it, and every ``make_*_fn`` accepts
an explicit ``plan=`` so callers that already built one (the V-cycle runner)
don't re-derive it.

Execution: for the paper's main "stack" width variant the F/T contractions are
pair merges and duplications, so the leaves route through the matrix-free
fused kernels behind ``repro.kernels.dispatch`` (``coalesce_pair`` /
``interp_axpy``; one HBM pass, no F matrix, no MXU) -- the "adj" variant,
``embed_cat2`` block-diagonal matrices and depth R/G keep the dense-matrix
``tensordot`` path.  All of it stays jit-compatible: backend resolution is
trace-time, so ``vcycle`` level transitions remain host-round-trip-free.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, MultiLevelConfig
from repro.core import projections as proj
from repro.core.plans import (LevelMaps, ProjectionPlan, WIDTH_AXES,
                              axis_sizes, build_plan, normalize_overrides)
from repro.kernels import dispatch as kdispatch
from repro.param import Spec, is_spec


def coalesce_config(cfg: ModelConfig, ml: Optional[MultiLevelConfig] = None,
                    *, width: bool = True, depth: bool = True) -> ModelConfig:
    """The next-level (smaller) model config: width and depth halved.

    Compatibility wrapper over ``plans.build_plan(...).small_cfg`` -- the
    halving rules live in the per-family hooks now, so config derivation and
    map construction cannot drift apart.  ``width``/``depth`` switches support
    the single-direction baselines (StackBERT = depth-only, bert2BERT =
    width-only).
    """
    return build_plan(cfg, ml, width=width, depth=depth).small_cfg


def build_level_maps(cfg: ModelConfig, ml: MultiLevelConfig,
                     *, width: bool = True, depth: bool = True) -> LevelMaps:
    """Compatibility wrapper over ``plans.build_plan(...).build_maps()``."""
    return build_plan(cfg, ml, width=width, depth=depth).build_maps()


# ---------------------------------------------------------------------------
# applying the projections to a parameter tree


def _contract(w: jax.Array, dim: int, mat: jax.Array, mat_axis: int) -> jax.Array:
    """Contract w's ``dim`` with mat's ``mat_axis``; result axis moved back."""
    out = jnp.tensordot(w, mat, axes=([dim], [mat_axis]))
    return jnp.moveaxis(out, -1, dim)


def _stack_coalesce(w: jax.Array, dim: int, w0: float, backend) -> jax.Array:
    """Matrix-free "stack"-variant coalescing of ``dim``: fold the leaf to 2D
    and merge pairs (i, i + n/2) in one fused pass (no F matrix, no matmul)."""
    n = w.shape[dim]
    rest = tuple(s for i, s in enumerate(w.shape) if i != dim)
    w2 = jnp.moveaxis(w, dim, 0).reshape(n, -1)
    out = kdispatch.dispatch("coalesce_pair", w2, axis=0, w0=w0, backend=backend)
    return jnp.moveaxis(out.reshape((n // 2,) + rest), 0, dim)


def _stack_decoalesce(w: jax.Array, dim: int, w0: float) -> jax.Array:
    """Matrix-free "stack"-variant de-coalescing: T duplication is a pure
    gather -- tile the halved axis twice, scaled by the paper's normalization
    weight (T_out rows are 1.0, T_in rows 0.5).

    Duplication is broadcast+reshape, NOT ``concatenate([w, w])``: XLA's SPMD
    partitioner miscompiles a concat whose operands alias the same *sharded*
    tensor (the halves get summed -- jaxlib 0.4.37 CPU/GSPMD), and the
    aliasing survives a ``w + 0.0`` copy via CSE.  Broadcast lowers cleanly
    under any sharding and is the same single HBM pass."""
    lead = jnp.moveaxis(w, dim, 0)
    dup = jnp.broadcast_to(lead[None], (2,) + lead.shape)
    dup = dup.reshape((2 * lead.shape[0],) + lead.shape[1:])
    dup = jnp.moveaxis(dup, 0, dim)
    if w0 == 1.0:
        return dup
    return (w0 * dup.astype(jnp.float32)).astype(w.dtype)


def _width_leaf(w, spec: Spec, width: Dict[str, proj.WidthMats], direction: str,
                role_overrides, backend=None, fused: bool = True):
    overrides = normalize_overrides(role_overrides)
    for d, (ax, role) in enumerate(zip(spec.axes, spec.roles)):
        if ax in overrides and ax in width:
            # plan-level role rewrite, e.g. expert pair-averaging: the leaf
            # declares "experts" protected, the MoE plan flips it to "out"
            role = overrides[ax]
        if ax not in width or role not in ("in", "out"):
            continue
        m = width[ax]
        if fused and getattr(m, "variant", None) == "stack":
            # the "stack" averaging matrices ARE pair merges/duplications:
            # route through the fused kernels instead of materializing F
            # (F_out weights 0.5, F_in 1.0; T_out 1.0, T_in 0.5 -- the
            # paper's normalization, pinned by kernels/ref.py oracles)
            if direction == "coalesce":
                w = _stack_coalesce(w, d, 0.5 if role == "out" else 1.0, backend)
            else:
                w = _stack_decoalesce(w, d, 1.0 if role == "out" else 0.5)
        elif direction == "coalesce":
            w = _contract(w, d, m.F_out, 0) if role == "out" else _contract(w, d, m.F_in, 1)
        else:
            w = _contract(w, d, m.T_out, 0) if role == "out" else _contract(w, d, m.T_in, 1)
    return w


def _depth_leaf(w, spec: Spec, dm: proj.DepthMats, direction: str):
    if not spec.axes or spec.axes[0] != "layers":
        return w
    if direction == "coalesce":
        return jnp.einsum("l...,lj->j...", w, dm.R)  # R: [L, L2]
    return jnp.einsum("l...,lj->j...", w, dm.G)  # G: [L2, L]


def _project_tree(params, specs, maps: LevelMaps, direction: str,
                  role_overrides=None, depth_key: Optional[str] = None,
                  backend: Optional[str] = None, fused: bool = True):
    """Recurse through the tree, tracking which stage we are under so the right
    depth matrices apply.  ``role_overrides`` is the plan's per-axis role
    rewrite dict (a bare bool is accepted for pre-plan call sites, meaning
    ``cfg.coalesce_experts``)."""
    role_overrides = normalize_overrides(role_overrides)

    def rec(p, s, dkey):
        if is_spec(s):
            w = _width_leaf(p, s, maps.width, direction, role_overrides,
                            backend=backend, fused=fused)
            if dkey is not None and dkey in maps.depth:
                w = _depth_leaf(w, s, maps.depth[dkey], direction)
            return w
        out = {}
        for k in s:
            sub_dkey = dkey
            if k.startswith("stage_"):
                sub_dkey = k
            elif k == "encoder":
                sub_dkey = "encoder"
            out[k] = rec(p[k], s[k], sub_dkey)
        return out

    return rec(params, specs, depth_key)


def coalesce(params, specs, cfg: ModelConfig, ml: MultiLevelConfig,
             maps: Optional[LevelMaps] = None, *, fused: bool = True,
             plan: Optional[ProjectionPlan] = None):
    """Paper Algorithm 2: width then depth (they commute on disjoint axes)."""
    plan = plan or build_plan(cfg, ml)
    maps = (maps or plan.build_maps()).as_jnp()
    return _project_tree(params, specs, maps, "coalesce", plan.role_overrides,
                         backend=cfg.kernel_backend or None, fused=fused)


def decoalesce(params_small, specs, cfg: ModelConfig, ml: MultiLevelConfig,
               maps: Optional[LevelMaps] = None, *, fused: bool = True,
               plan: Optional[ProjectionPlan] = None):
    """Paper Algorithm 3: depth then width.  ``specs``/``cfg`` are the LARGE
    level's; ``params_small`` the small level's parameters."""
    plan = plan or build_plan(cfg, ml)
    maps = (maps or plan.build_maps()).as_jnp()
    return _project_tree(params_small, specs, maps, "decoalesce",
                         plan.role_overrides,
                         backend=cfg.kernel_backend or None, fused=fused)


def interpolate(params_large, params_decoalesced, alpha: float,
                backend: Optional[str] = None):
    """Paper Algorithm 4 / Eq. 13: M <- (1-a) M + a D(M_small).

    Each leaf runs through the fused ``interp_axpy`` kernel (one read of a and
    b, one write -- the memory-bound pass the Pallas kernel targets at scale)."""
    return jax.tree.map(
        lambda a, b: kdispatch.dispatch("interp_axpy", a, b, alpha,
                                        backend=backend),
        params_large, params_decoalesced)


def make_coalesce_fn(specs, cfg: ModelConfig, ml: MultiLevelConfig,
                     *, width: bool = True, depth: bool = True,
                     fused: bool = True, out_shardings=None,
                     plan: Optional[ProjectionPlan] = None):
    """jit'd level-transition.  "stack"-variant width axes route through the
    matrix-free fused kernels (repro.kernels.dispatch); everything else runs
    as sharded einsums.  ``fused=False`` forces the dense-matrix path (the
    equivalence oracle for tests/benchmarks).  ``out_shardings`` (a
    NamedSharding tree for the TARGET level's params) makes the projection
    sharded-in, sharded-out under a mesh -- no host round trip, no gather.
    Pass ``plan`` when one is already built (the V-cycle runner does); it must
    match ``(cfg, ml, width, depth)``."""
    plan = plan or build_plan(cfg, ml, width=width, depth=depth)
    maps = plan.build_maps().as_jnp()
    backend = cfg.kernel_backend or None
    return jax.jit(lambda p: _project_tree(p, specs, maps, "coalesce",
                                           plan.role_overrides,
                                           backend=backend, fused=fused),
                   out_shardings=out_shardings)


def make_decoalesce_fn(specs, cfg: ModelConfig, ml: MultiLevelConfig,
                       *, width: bool = True, depth: bool = True,
                       fused: bool = True, out_shardings=None,
                       plan: Optional[ProjectionPlan] = None):
    plan = plan or build_plan(cfg, ml, width=width, depth=depth)
    maps = plan.build_maps().as_jnp()
    backend = cfg.kernel_backend or None
    return jax.jit(lambda p: _project_tree(p, specs, maps, "decoalesce",
                                           plan.role_overrides,
                                           backend=backend, fused=fused),
                   out_shardings=out_shardings)


def make_interpolate_fn(alpha: float, backend: Optional[str] = None,
                        out_shardings=None):
    return jax.jit(lambda a, b: interpolate(a, b, alpha, backend=backend),
                   out_shardings=out_shardings)


def make_draft_projection(specs, cfg: ModelConfig,
                          ml: Optional[MultiLevelConfig] = None,
                          *, width: bool = True, depth: bool = True,
                          out_shardings=None) -> Tuple[ModelConfig, Any]:
    """Serving-time self-speculative draft: ``(draft_cfg, project_fn)``.

    The level-1 coalesced model is a deterministic *projection* of the
    serving params -- a free, always-in-sync draft model for speculative
    decoding: no separate training run, no second checkpoint to distribute.
    ``project_fn(params) -> draft_params`` is the jit'd Coalescing transition
    (sharded-in/sharded-out when ``out_shardings`` is given); re-invoke it
    whenever the serving params change (hot weight reload) and the draft
    stays in sync by construction.

    ``width``/``depth`` pick the projection direction: width-only drafts
    track the full model most closely (width de-coalescing is exactly
    function-preserving for untied embeddings, see tests/test_operators.py),
    full level-1 (both) is the cheapest draft the paper defines.
    """
    ml = ml or MultiLevelConfig()
    plan = build_plan(cfg, ml, width=width, depth=depth)
    project = make_coalesce_fn(specs, cfg, ml, width=width, depth=depth,
                               out_shardings=out_shardings, plan=plan)
    return plan.small_cfg, project
