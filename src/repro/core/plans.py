"""Per-family projection plans: the explicit contract between a model family
and the three operators (DESIGN.md §2).

Historically ``core/operators.py`` derived everything implicitly from the
per-leaf axis metadata in one monolithic walk.  That works, but it leaves the
family-specific decisions -- which axes coalesce, which are protected, which
scalar config fields must follow a merge -- scattered and undocumented.  A
:class:`ProjectionPlan` is that contract made explicit: built once per level
transition from a :class:`ModelConfig`, it names

* ``width_axes``    -- the logical axes this transition halves (and their
                       current sizes); one shared F/T pair per axis *is* the
                       paper's Appendix-A constraint structure,
* ``protected_axes``-- axes the operators must never mix (head_dim, conv
                       taps, SSM state, vocab, patches, ...; DESIGN.md §4),
* ``role_overrides``-- per-axis role rewrites applied before projection (the
                       MoE expert axis is declared "-"/protected in the leaf
                       specs and flipped to "out" here when expert coalescing
                       is on -- pairwise expert merging is a plan decision,
                       not a leaf property),
* ``depth_groups``  -- the per-stage layer counts the depth R/G matrices act
                       on,
* ``carried``       -- scalar config fields that follow the merge *unchanged
                       by construction* (MoE capacity factor / aux-loss
                       coefficient; see the MoE hook), recorded so tests can
                       pin the reasoning,
* ``small_cfg``     -- the next-level config, derived by the same hooks.

Plans are assembled by composable **family hooks**: feature-detected
contributors (dense attention/FFN, MLA, MoE, Mamba, xLSTM, encoder-decoder,
vision adapters, ViT) that each add their axes + config halvings.  A hybrid
like jamba simply matches several hooks (dense + moe + ssm) -- there is no
"jamba hook", which is the point: a new family declares its axes once and
every operator, baseline, benchmark and sharding rule follows.

``operators.coalesce_config`` / ``operators.build_level_maps`` are thin
wrappers over :func:`build_plan`, so all pre-plan call sites keep working
and -- crucially -- config halving and map construction can no longer drift
apart: both read the same plan.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.config import ModelConfig, MultiLevelConfig, Stage
from repro.core import projections as proj

# logical axes subject to width coalescing, with the config field giving their
# size (canonical list; re-exported by core.operators for compatibility)
WIDTH_AXES = (
    "embed", "mlp", "heads", "kv_heads", "q_lora", "kv_lora",
    "moe_mlp", "shared_mlp", "mamba_inner", "dt_rank", "experts", "embed_cat2",
)


@dataclasses.dataclass
class LevelMaps:
    """Projection matrices between a (large cfg, small cfg) level pair."""

    width: Dict[str, proj.WidthMats]
    depth: Dict[str, proj.DepthMats]  # per stage name + "encoder"

    def as_jnp(self, dtype=None) -> "LevelMaps":
        import jax.numpy as jnp

        dtype = dtype or jnp.float32
        width = {k: dataclasses.replace(
                     v, **{f: jnp.asarray(getattr(v, f), dtype)
                           for f in proj.MAT_FIELDS})
                 for k, v in self.width.items()}
        depth = {k: proj.DepthMats(R=jnp.asarray(v.R, dtype), G=jnp.asarray(v.G, dtype))
                 for k, v in self.depth.items()}
        return LevelMaps(width=width, depth=depth)


def _halve(x: int) -> int:
    """A dimension is halved iff it is even -- exactly the condition under
    which width matrices are constructed, so config and projected parameter
    shapes stay consistent for any architecture."""
    return x // 2 if (x and x % 2 == 0) else x


@dataclasses.dataclass
class _Draft:
    """Mutable scratch a family hook writes into while a plan is built."""

    sizes: Dict[str, int] = dataclasses.field(default_factory=dict)
    protected: List[str] = dataclasses.field(default_factory=list)
    overrides: Dict[str, str] = dataclasses.field(default_factory=dict)
    carried: Dict[str, Any] = dataclasses.field(default_factory=dict)
    kw: Dict[str, Any] = dataclasses.field(default_factory=dict)
    notes: List[str] = dataclasses.field(default_factory=list)
    hooks: List[str] = dataclasses.field(default_factory=list)

    def protect(self, *axes: str):
        for ax in axes:
            if ax not in self.protected:
                self.protected.append(ax)


@dataclasses.dataclass(frozen=True)
class FamilyHook:
    """One feature-detected contributor to a projection plan."""

    name: str
    applies: Callable[[ModelConfig], bool]
    contribute: Callable[[_Draft, ModelConfig, MultiLevelConfig, bool, bool], None]


def _has_mixer(cfg: ModelConfig, *mixers: str) -> bool:
    return any(b.mixer in mixers for st in cfg.stages for b in st.pattern)


def _hook_dense(d: _Draft, cfg: ModelConfig, ml, width: bool, depth: bool):
    """Residual stream + attention heads + dense FFN: every family has these
    (ViT included); the shared ``embed`` F *is* the residual constraint group."""
    d.sizes.update(embed=cfg.d_model, heads=cfg.n_heads,
                   kv_heads=cfg.n_kv_heads, embed_cat2=2 * cfg.d_model)
    if cfg.d_ff:
        d.sizes["mlp"] = cfg.d_ff
    d.protect("head_dim", "vocab", "seq", "mtp")
    halve = _halve if width else (lambda x: x)
    if depth:
        d.kw["stages"] = tuple(Stage(st.pattern, (st.repeats + 1) // 2)
                               for st in cfg.stages)
    d.kw.update(d_model=halve(cfg.d_model), n_heads=halve(cfg.n_heads),
                n_kv_heads=halve(cfg.n_kv_heads), d_ff=halve(cfg.d_ff),
                # head width preserved; heads merge whole
                head_dim=cfg.resolved_head_dim)
    d.notes.append("heads merge whole: head_dim pinned to the resolved value")


def _hook_mla(d: _Draft, cfg: ModelConfig, ml, width: bool, depth: bool):
    d.sizes.update(q_lora=cfg.q_lora_rank, kv_lora=cfg.kv_lora_rank)
    d.protect("rope_dim", "v_head_dim")
    halve = _halve if width else (lambda x: x)
    d.kw.update(q_lora_rank=halve(cfg.q_lora_rank),
                kv_lora_rank=halve(cfg.kv_lora_rank))


def _hook_moe(d: _Draft, cfg: ModelConfig, ml, width: bool, depth: bool):
    """MoE: expert-inner width always coalesces; the expert *count* only when
    ``cfg.coalesce_experts`` flips the leaf-protected "experts" axis to "out"
    (pairwise expert merging, beyond-paper; DESIGN.md §3).

    Router consistency under an expert merge (X -> X/2) is structural:

    * router columns: the router leaf carries the "experts" axis, so the same
      role override pair-averages its columns -- the merged expert's logit is
      the mean of its parents' logits.  No special case, pinned by tests.
    * ``capacity_factor`` carries UNCHANGED: per-expert capacity is
      C = ceil(S * k * cf / X), so halving X doubles each expert's slots and
      the *total* slot count X * C is preserved exactly.
    * ``router_aux_coef`` carries UNCHANGED: the Switch aux loss
      X * sum_e(m_e * c_e) is scale-invariant in X at uniform routing (its
      value is 1.0 for any X), so the load-balancing pressure is comparable
      across levels without retuning.
    """
    F = cfg.moe_d_ff or cfg.d_ff
    d.sizes["moe_mlp"] = F
    if cfg.n_shared_experts:
        d.sizes["shared_mlp"] = cfg.n_shared_experts * F
    halve = _halve if width else (lambda x: x)
    d.kw["moe_d_ff"] = halve(cfg.moe_d_ff)
    if cfg.coalesce_experts:
        d.sizes["experts"] = cfg.n_experts
        d.overrides["experts"] = "out"
        d.kw.update(n_experts=halve(cfg.n_experts),
                    moe_top_k=min(cfg.moe_top_k, halve(cfg.n_experts)))
        d.notes.append("expert merge: router columns pair-average via the "
                       "'experts'->'out' override")
        d.notes.append("capacity_factor / router_aux_coef carry unchanged: "
                       "per-expert capacity ceil(S*k*cf/X) doubles as X "
                       "halves (total slots preserved); the aux loss "
                       "X*sum(m_e*c_e) is scale-invariant in X")
    else:
        d.protect("experts")
    d.carried.update(capacity_factor=cfg.capacity_factor,
                     router_aux_coef=cfg.router_aux_coef)


def _hook_mamba(d: _Draft, cfg: ModelConfig, ml, width: bool, depth: bool):
    """Mamba mixers: the inner stream and dt rank coalesce; the recurrent
    state (d_state) and conv taps are function-defining and protected
    (DESIGN.md §4)."""
    d.sizes.update(mamba_inner=cfg.mamba_d_inner, dt_rank=cfg.resolved_dt_rank)
    d.protect("conv_k", "mamba_state")
    halve = _halve if width else (lambda x: x)
    d.kw["mamba_dt_rank"] = halve(cfg.resolved_dt_rank)


def _hook_xlstm(d: _Draft, cfg: ModelConfig, ml, width: bool, depth: bool):
    """xLSTM mixers: heads coalesce whole (the dense hook already names the
    "heads" axis); the per-head recurrent memories are protected."""
    d.protect("xlstm_head", "slstm_head")


def _hook_encoder(d: _Draft, cfg: ModelConfig, ml, width: bool, depth: bool):
    if depth:
        d.kw["n_encoder_layers"] = (cfg.n_encoder_layers + 1) // 2


def _hook_vision_adapter(d: _Draft, cfg: ModelConfig, ml, width: bool, depth: bool):
    # the stub frontend's feature dim is fixed; pin it before halving d_model
    d.kw["vision_dim"] = cfg.vision_dim or cfg.d_model
    d.notes.append("cross-attn frontend feature dim pinned (vision_dim)")


def _hook_vit(d: _Draft, cfg: ModelConfig, ml, width: bool, depth: bool):
    """ViT: patch pixels, sequence positions and class logits are data-defined
    dims -- protected; only the transformer trunk coalesces."""
    d.protect("patch", "classes")


FAMILY_HOOKS: Tuple[FamilyHook, ...] = (
    FamilyHook("dense", lambda c: True, _hook_dense),
    FamilyHook("mla", lambda c: c.attn_type == "mla", _hook_mla),
    FamilyHook("moe", lambda c: bool(c.n_experts), _hook_moe),
    FamilyHook("mamba", lambda c: _has_mixer(c, "mamba"), _hook_mamba),
    FamilyHook("xlstm", lambda c: _has_mixer(c, "mlstm", "slstm"), _hook_xlstm),
    FamilyHook("encoder", lambda c: bool(c.n_encoder_layers), _hook_encoder),
    FamilyHook("vision_adapter", lambda c: _has_mixer(c, "cross_attn"),
               _hook_vision_adapter),
    FamilyHook("vit", lambda c: c.family == "vit", _hook_vit),
)


@dataclasses.dataclass(frozen=True)
class ProjectionPlan:
    """The explicit per-family contract for one level transition.

    ``cfg`` is the LARGE level, ``small_cfg`` the coalesced one.  All the
    operator entry points (``make_coalesce_fn`` / ``make_decoalesce_fn`` /
    the baselines / the V-cycle runner) accept a plan; building one yourself
    is only needed for introspection -- the wrappers build it on demand.
    """

    family: str                      # cfg.family label of the large model
    hooks: Tuple[str, ...]           # contributing family hooks, in order
    cfg: ModelConfig
    small_cfg: ModelConfig
    ml: MultiLevelConfig
    width: bool
    depth: bool
    width_axes: Dict[str, int]       # axis -> LARGE size, only axes that halve
    protected_axes: Tuple[str, ...]
    role_overrides: Dict[str, str]   # axis -> forced role (e.g. experts->out)
    depth_groups: Dict[str, Tuple[int, int]]  # group -> (large, small) layers
    carried: Dict[str, Any]          # scalar fields carried across the merge
    notes: Tuple[str, ...]

    def axis_sizes(self) -> Dict[str, int]:
        """Every width-coalescible axis present (halvable or not)."""
        return dict(self._all_sizes)

    # populated by build_plan; excluded from the frozen public fields above
    _all_sizes: Dict[str, int] = dataclasses.field(default_factory=dict,
                                                   repr=False, compare=False)

    def build_maps(self) -> LevelMaps:
        """The F/T/R/G matrices this plan's transition applies (numpy; call
        ``.as_jnp()`` before tracing)."""
        wmats: Dict[str, proj.WidthMats] = {}
        if self.width:
            for ax, n in self.width_axes.items():
                if ax == "embed_cat2":
                    continue
                wmats[ax] = proj.width_mats(n, self.ml.width_variant)
            if "embed" in wmats:
                wmats["embed_cat2"] = proj.block_diag_width(wmats["embed"], 2)
        dmats: Dict[str, proj.DepthMats] = {}
        if self.depth:
            for name, (large, _small) in self.depth_groups.items():
                dmats[name] = proj.depth_mats(large, self.ml.depth_variant)
        return LevelMaps(width=wmats, depth=dmats)

    def describe(self) -> str:
        """Human-readable plan summary (verbose V-cycle logs, docs, tests)."""
        lines = [f"ProjectionPlan[{self.family}] "
                 f"{self.cfg.name or '?'} -> {self.small_cfg.name or '?'} "
                 f"(hooks: {', '.join(self.hooks)})"]
        if self.width:
            ax = ", ".join(f"{a}:{n}->{n // 2}"
                           for a, n in sorted(self.width_axes.items()))
            lines.append(f"  width axes   : {ax or '(none halvable)'}")
        if self.depth:
            dg = ", ".join(f"{k}:{a}->{b}"
                           for k, (a, b) in sorted(self.depth_groups.items()))
            lines.append(f"  depth groups : {dg or '(none)'}")
        lines.append(f"  protected    : {', '.join(self.protected_axes)}")
        if self.role_overrides:
            ov = ", ".join(f"{a}->{r}" for a, r in self.role_overrides.items())
            lines.append(f"  overrides    : {ov}")
        if self.carried:
            ca = ", ".join(f"{k}={v}" for k, v in sorted(self.carried.items()))
            lines.append(f"  carried      : {ca}")
        for n in self.notes:
            lines.append(f"  note: {n}")
        return "\n".join(lines)


def axis_sizes(cfg: ModelConfig) -> Dict[str, int]:
    """Current size of every width-coalescible axis present in this model
    (the pre-plan ``operators.axis_sizes`` contract, now hook-derived)."""
    d = _Draft()
    for h in FAMILY_HOOKS:
        if h.applies(cfg):
            h.contribute(d, cfg, MultiLevelConfig(), True, True)
    return d.sizes


def build_plan(cfg: ModelConfig, ml: Optional[MultiLevelConfig] = None,
               *, width: bool = True, depth: bool = True) -> ProjectionPlan:
    """Assemble the :class:`ProjectionPlan` for one level transition.

    ``width``/``depth`` switches support the single-direction baselines
    (StackBERT = depth-only, bert2BERT = width-only).
    """
    ml = ml or MultiLevelConfig()
    d = _Draft()
    for h in FAMILY_HOOKS:
        if h.applies(cfg):
            h.contribute(d, cfg, ml, width, depth)
            d.hooks.append(h.name)
    if not width:
        # single-direction baselines keep width fields untouched
        for k in ("d_model", "n_heads", "n_kv_heads", "d_ff", "q_lora_rank",
                  "kv_lora_rank", "moe_d_ff", "n_experts", "moe_top_k",
                  "mamba_dt_rank"):
            d.kw.pop(k, None)
        d.kw["head_dim"] = cfg.resolved_head_dim
    small_cfg = cfg.replace(**d.kw)
    halvable = {ax: n for ax, n in d.sizes.items()
                if ax != "embed_cat2" and n >= 2 and n % 2 == 0} if width else {}
    if "embed" in halvable:
        halvable["embed_cat2"] = d.sizes["embed_cat2"]
    depth_groups: Dict[str, Tuple[int, int]] = {}
    if depth:
        for i, st in enumerate(cfg.stages):
            depth_groups[f"stage_{i}"] = (st.repeats, small_cfg.stages[i].repeats)
        if cfg.n_encoder_layers:
            depth_groups["encoder"] = (cfg.n_encoder_layers,
                                       small_cfg.n_encoder_layers)
    return ProjectionPlan(
        family=cfg.family, hooks=tuple(d.hooks), cfg=cfg, small_cfg=small_cfg,
        ml=ml, width=width, depth=depth, width_axes=halvable,
        protected_axes=tuple(d.protected), role_overrides=dict(d.overrides),
        depth_groups=depth_groups, carried=dict(d.carried),
        notes=tuple(d.notes), _all_sizes=dict(d.sizes))


def normalize_overrides(arg) -> Dict[str, str]:
    """Back-compat shim: pre-plan call sites pass ``cfg.coalesce_experts`` as
    a bool where the operators now take a role-override dict."""
    if isinstance(arg, dict):
        return arg
    return {"experts": "out"} if arg else {}
