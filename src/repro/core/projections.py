"""Projection-matrix builders for the three operators (paper Eqs. 1-12, App. E).

Width:  F_out in R^{n x m} (full column rank).  Variants:
          "stack": pairs (i, i+m)   -- the paper's main choice, Eq. 15
          "adj":   pairs (2i, 2i+1) -- Eq. 17
        Derived (Algorithm 2/3 "Preparation", the appendix fixes the Eq. 2/9
        transposition typos):
          F_in  = F_out^T diag(1/colsum(F_out F_out^T))          [m,n]
          T_out = F_out^T diag(1/colsum(F_out F_out^T)) (= F_in) [m,n]
          T_in  = diag(1/rowsum(F_in^T F_in)) F_in^T             [n,m]

Depth:  R in R^{L x L2}.  Variants:
          "adj":   merge adjacent layers (2i, 2i+1)  -- Eq. 16
          "stack": inverse of progressive stacking (i, i+L2) -- Eq. 18
        G = R^T diag(1/colsum(R R^T))  [L2, L]

Invariants (tested): T_out F_out = I, F_in T_in = I, colsum(R G) = 1, and for
the averaging matrices C(D(w)) == w exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

MAT_FIELDS = ("F_out", "F_in", "T_out", "T_in")


@dataclasses.dataclass(frozen=True)
class WidthMats:
    F_out: np.ndarray  # [n, m]
    F_in: np.ndarray  # [m, n]
    T_out: np.ndarray  # [m, n]
    T_in: np.ndarray  # [n, m]
    # which pair structure generated F_out ("stack" | "adj" | None).  "stack"
    # marks the matrices whose contraction is exactly the matrix-free
    # coalesce_pair / duplication kernels (core/operators.py fused path);
    # None (e.g. block_diag_width, hand-built F) keeps the dense-matrix path.
    variant: Optional[str] = None


def pair_merge_matrix(n: int, m: int, variant: str) -> np.ndarray:
    """F_out [n, m].  Requires n == 2m (even halving) for both variants."""
    if n != 2 * m:
        raise ValueError(f"width coalescing needs n == 2m, got n={n} m={m}")
    F = np.zeros((n, m), np.float64)
    idx = np.arange(m)
    if variant == "stack":
        F[idx, idx] = 0.5
        F[idx + m, idx] = 0.5
    elif variant == "adj":
        F[2 * idx, idx] = 0.5
        F[2 * idx + 1, idx] = 0.5
    else:
        raise ValueError(variant)
    return F


def derive_width(F_out: np.ndarray, variant: Optional[str] = None) -> WidthMats:
    """Apply the paper's normalization formulas to an arbitrary full-column-rank
    F_out (works for non-averaging choices too)."""
    FFt = F_out @ F_out.T  # [n,n]
    col = FFt.sum(axis=0)  # colsum -> [n]
    F_in = F_out.T * (1.0 / np.where(col == 0, 1.0, col))[None, :]  # [m,n]
    T_out = F_in.copy()
    M = F_in.T @ F_in  # [n,n]
    row = M.sum(axis=1)
    T_in = (1.0 / np.where(row == 0, 1.0, row))[:, None] * F_in.T  # [n,m]
    return WidthMats(F_out=F_out, F_in=F_in, T_out=T_out, T_in=T_in,
                     variant=variant)


def width_mats(n: int, variant: str = "stack") -> WidthMats:
    return derive_width(pair_merge_matrix(n, n // 2, variant), variant)


def block_diag_width(mats: WidthMats, blocks: int) -> WidthMats:
    """Width matrices for a concatenation of ``blocks`` copies of the same axis
    (e.g. the MTP projection input [h_t ; emb_{t+1}] of size 2*d_model)."""

    def bd(a: np.ndarray) -> np.ndarray:
        out = np.zeros((a.shape[0] * blocks, a.shape[1] * blocks), a.dtype)
        for b in range(blocks):
            out[b * a.shape[0]:(b + 1) * a.shape[0], b * a.shape[1]:(b + 1) * a.shape[1]] = a
        return out

    return WidthMats(F_out=bd(mats.F_out), F_in=bd(mats.F_in),
                     T_out=bd(mats.T_out), T_in=bd(mats.T_in))


@dataclasses.dataclass(frozen=True)
class DepthMats:
    R: np.ndarray  # [L, L2]
    G: np.ndarray  # [L2, L]


def depth_merge_matrix(L: int, variant: str = "adj") -> np.ndarray:
    """R [L, ceil(L/2)].  Odd L: the last layer maps alone with weight 1."""
    L2 = (L + 1) // 2
    R = np.zeros((L, L2), np.float64)
    if variant == "adj":
        for j in range(L2):
            lo = 2 * j
            if lo + 1 < L:
                R[lo, j] = 0.5
                R[lo + 1, j] = 0.5
            else:
                R[lo, j] = 1.0
    elif variant == "stack":
        half = L2
        for j in range(L2):
            if j + half < L:
                R[j, j] = 0.5
                R[j + half, j] = 0.5
            else:
                R[j, j] = 1.0
    else:
        raise ValueError(variant)
    return R


def derive_depth(R: np.ndarray) -> DepthMats:
    RRt = R @ R.T
    col = RRt.sum(axis=0)
    G = R.T * (1.0 / np.where(col == 0, 1.0, col))[None, :]
    return DepthMats(R=R, G=G)


def depth_mats(L: int, variant: str = "adj") -> DepthMats:
    return derive_depth(depth_merge_matrix(L, variant))
