"""The paper's five comparison baselines (Table 1/2/3), implemented at proxy
scale against the same FLOPs-indexed History so savings are computed
identically for every method.  All "grow" methods include the small-model
training cost, as the paper does for fairness (§4.1 Baselines).

* scratch            -- plain training of the target model (the reference).
* StackBERT          -- depth-only: train an L/2 model, progressively stack.
* bert2BERT          -- width-only: function-preserving expansion (our width
                        de-coalescing matrices ARE the averaged Net2Net FPI).
* LiGO               -- learn the (width x depth) linear growth operator by
                        SGD on the mapped-model loss, then continue training.
* Network Expansion  -- expand the EMA of the small model's parameters.
* KI                 -- knowledge inheritance: train the large model with a
                        distillation term from the trained small teacher.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, MultiLevelConfig, TrainConfig
from repro.core import flops as flops_lib
from repro.core import operators as ops
from repro.core import plans as plans_lib
from repro.core.vcycle import History, train_segment
from repro.models.api import build_model, make_train_step
from repro.optim import adamw_init, adamw_update


def _grow_then_train(cfg, ml, tc, batch_fn, *, width: bool, depth: bool,
                     small_steps: int, final_steps: int, seed: int,
                     target_loss=None, ema_decay: Optional[float] = None,
                     depth_variant: Optional[str] = None) -> History:
    """Shared scaffold: train small -> expand -> train large."""
    if depth_variant is not None:
        ml = dataclasses.replace(ml, depth_variant=depth_variant)
    plan = plans_lib.build_plan(cfg, ml, width=width, depth=depth)
    small_cfg = plan.small_cfg
    small = build_model(small_cfg)
    hist = History()
    params_s = small.init(jax.random.PRNGKey(seed))

    ema = params_s
    if ema_decay is None:
        params_s, _, hist, cum, g = train_segment(
            small, tc, batch_fn, small_steps, params=params_s, history=hist,
            level=1, seed=seed)
    else:  # Network Expansion: maintain EMA during small training
        step_fn = jax.jit(make_train_step(small, tc))
        opt = adamw_init(params_s, tc)
        fps = flops_lib.train_step_flops(small_cfg, small.specs(), tc.batch_size, tc.seq_len)
        cum, g = 0.0, 0
        ema_fn = jax.jit(lambda e, p: jax.tree.map(
            lambda a, b: ema_decay * a + (1 - ema_decay) * b, e, p))
        for i in range(small_steps):
            params_s, opt, metrics = step_fn(params_s, opt, batch_fn(g))
            ema = ema_fn(ema, params_s)
            cum += fps
            g += 1
            if i % tc.log_every == 0:
                hist.log(cum, float(metrics["loss"]), g, 1)
        params_s = ema

    grow = ops.make_decoalesce_fn(build_model(cfg).specs(), cfg, ml,
                                  width=width, depth=depth, plan=plan)
    params = grow(params_s)
    model = build_model(cfg)
    _, _, hist, cum, g = train_segment(
        model, tc, batch_fn, final_steps, params=params, history=hist,
        start_flops=cum, start_step=g, level=0, seed=seed, target_loss=target_loss)
    return hist


def run_stackbert(cfg, ml, tc, batch_fn, *, small_steps=None, final_steps=None,
                  seed=0, target_loss=None) -> History:
    return _grow_then_train(
        cfg, ml, tc, batch_fn, width=False, depth=True, depth_variant="stack",
        small_steps=small_steps or tc.steps // 2, final_steps=final_steps or tc.steps,
        seed=seed, target_loss=target_loss)


def run_bert2bert(cfg, ml, tc, batch_fn, *, small_steps=None, final_steps=None,
                  seed=0, target_loss=None) -> History:
    return _grow_then_train(
        cfg, ml, tc, batch_fn, width=True, depth=False,
        small_steps=small_steps or tc.steps // 2, final_steps=final_steps or tc.steps,
        seed=seed, target_loss=target_loss)


def run_network_expansion(cfg, ml, tc, batch_fn, *, small_steps=None, final_steps=None,
                          seed=0, target_loss=None) -> History:
    return _grow_then_train(
        cfg, ml, tc, batch_fn, width=True, depth=True, ema_decay=0.999,
        small_steps=small_steps or tc.steps // 2, final_steps=final_steps or tc.steps,
        seed=seed, target_loss=target_loss)


# ---------------------------------------------------------------------------
# LiGO: learned linear growth operator


def run_ligo(cfg, ml, tc, batch_fn, *, small_steps=None, final_steps=None,
             fit_steps: int = 30, fit_lr: float = 1e-2, seed=0,
             target_loss=None) -> History:
    plan = plans_lib.build_plan(cfg, ml)
    small = build_model(plan.small_cfg)
    model = build_model(cfg)
    specs = model.specs()
    hist = History()
    params_s, _, hist, cum, g = train_segment(
        small, tc, batch_fn, small_steps or tc.steps // 2, history=hist, level=1, seed=seed)

    # trainable expansion: start from the plan's analytic de-coalescing matrices
    maps0 = plan.build_maps().as_jnp()
    theta = {
        "width": {ax: {"T_out": m.T_out, "T_in": m.T_in} for ax, m in maps0.width.items()},
        "depth": {k: {"G": d.G} for k, d in maps0.depth.items()},
    }

    def project(theta, p_small):
        import repro.core.projections as proj

        width = {ax: proj.WidthMats(F_out=None, F_in=None, T_out=t["T_out"], T_in=t["T_in"])
                 for ax, t in theta["width"].items()}
        depth = {k: proj.DepthMats(R=None, G=d["G"]) for k, d in theta["depth"].items()}
        maps = ops.LevelMaps(width=width, depth=depth)
        return ops._project_tree(p_small, specs, maps, "decoalesce",
                                 plan.role_overrides)

    def fit_loss(theta, batch):
        return model.loss(project(theta, params_s), batch)[0]

    fit_grad = jax.jit(jax.value_and_grad(fit_loss))
    fit_fps = flops_lib.train_step_flops(cfg, specs, tc.batch_size, tc.seq_len)
    for i in range(fit_steps):  # SGD on the growth operator (LiGO's inner loop)
        loss, gr = fit_grad(theta, batch_fn(g))
        theta = jax.tree.map(lambda t, d: t - fit_lr * d, theta, gr)
        cum += fit_fps
        g += 1
        if i % tc.log_every == 0:
            hist.log(cum, float(loss), g, 0)

    params = jax.jit(lambda th: project(th, params_s))(theta)
    _, _, hist, cum, g = train_segment(
        model, tc, batch_fn, final_steps or tc.steps, params=params, history=hist,
        start_flops=cum, start_step=g, level=0, seed=seed, target_loss=target_loss)
    return hist


# ---------------------------------------------------------------------------
# KI: knowledge inheritance (distill small teacher into the large student)


def run_ki(cfg, ml, tc, batch_fn, *, small_steps=None, final_steps=None,
           seed=0, target_loss=None, kd_weight: float = 0.5) -> History:
    small_cfg = plans_lib.build_plan(cfg, ml).small_cfg
    small = build_model(small_cfg)
    model = build_model(cfg)
    hist = History()
    teacher, _, hist, cum, g = train_segment(
        small, tc, batch_fn, small_steps or tc.steps // 2, history=hist, level=1, seed=seed)

    fs = final_steps or tc.steps

    def kd_loss(params, batch, step_frac):
        loss, metrics = model.loss(params, batch)
        t_logits = jax.lax.stop_gradient(small.forward_logits(teacher, batch))
        s_logits = model.forward_logits(params, batch)
        t_lp = jax.nn.log_softmax(t_logits.astype(jnp.float32), -1)
        s_lp = jax.nn.log_softmax(s_logits.astype(jnp.float32), -1)
        kl = jnp.mean(jnp.sum(jnp.exp(t_lp) * (t_lp - s_lp), -1))
        w = kd_weight * (1.0 - step_frac)  # decay the inheritance term
        return (1 - w) * loss + w * kl, metrics

    grad_fn = jax.jit(jax.value_and_grad(kd_loss, has_aux=True))
    params = model.init(jax.random.PRNGKey(seed))
    opt = adamw_init(params, tc)
    # student pays its own cost + the teacher forward
    fps = (flops_lib.train_step_flops(cfg, model.specs(), tc.batch_size, tc.seq_len)
           + flops_lib.forward_flops(cfg, model.specs(), tc.batch_size, tc.seq_len)  # extra student fwd
           + flops_lib.forward_flops(small_cfg, small.specs(), tc.batch_size, tc.seq_len))
    upd = jax.jit(lambda p, gr, o: adamw_update(p, gr, o, tc))
    for i in range(fs):
        (_, metrics), gr = grad_fn(params, batch_fn(g), i / fs)
        params, opt, _ = upd(params, gr, opt)
        cum += fps
        g += 1
        if i % tc.log_every == 0 or i == fs - 1:
            hist.log(cum, float(metrics["loss"]), g, 0)
            if target_loss is not None:
                _, sm = hist.smoothed(5)
                if len(sm) and sm[-1] <= target_loss:
                    break
    return hist


BASELINES: Dict[str, Callable] = {
    "stackbert": run_stackbert,
    "bert2bert": run_bert2bert,
    "ligo": run_ligo,
    "network_expansion": run_network_expansion,
    "ki": run_ki,
}
