from repro.optim.adamw import (  # noqa: F401
    adamw_init_specs,
    adamw_init,
    adamw_update,
    lr_at,
)
