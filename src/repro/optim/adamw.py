"""AdamW with warmup-cosine/linear schedules, global-norm clipping, decoupled
weight decay masked to >=2D weight matrices (norm scales / biases undecayed).

Optimizer state mirrors the parameter Spec tree (same logical axes), so it
shards identically (FSDP over data-like axes) and the dry-run can build
ShapeDtypeStructs for the full (params, m, v) triple without allocation.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig
from repro.param import Spec, is_spec


def lr_at(step: jax.Array, tc: TrainConfig) -> jax.Array:
    """Warmup then cosine/linear/constant decay (matches the paper's setup)."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(tc.warmup_steps, 1), 1.0)
    total = max(tc.steps - tc.warmup_steps, 1)
    frac = jnp.clip((step - tc.warmup_steps) / total, 0.0, 1.0)
    if tc.schedule == "cosine":
        decay = tc.end_lr_frac + (1 - tc.end_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    elif tc.schedule == "linear":
        decay = 1.0 - (1.0 - tc.end_lr_frac) * frac
    else:
        decay = jnp.ones_like(frac)
    return tc.peak_lr * warm * decay


def adamw_init_specs(param_specs, tc: TrainConfig):
    """Spec tree for (m, v) mirroring the parameter specs (same logical axes)."""

    def one(s: Spec) -> Spec:
        return Spec(s.shape, s.axes, s.roles, init="zeros", dtype=tc.opt_dtype)

    return {
        "m": jax.tree.map(one, param_specs, is_leaf=is_spec),
        "v": jax.tree.map(one, param_specs, is_leaf=is_spec),
        "count": Spec((), (), (), init="zeros", dtype=jnp.int32),
    }


def adamw_init(params, tc: TrainConfig):
    zeros = lambda p: jnp.zeros(p.shape, tc.opt_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def _clip_by_global_norm(grads, max_norm: float):
    g2 = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    gn = jnp.sqrt(g2)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def adamw_update(
    params, grads, opt_state, tc: TrainConfig
) -> Tuple[Any, Any, Dict[str, jax.Array]]:
    grads, gnorm = _clip_by_global_norm(grads, tc.grad_clip)
    count = opt_state["count"] + 1
    cf = count.astype(jnp.float32)
    b1, b2 = tc.b1, tc.b2
    lr = lr_at(count, tc)
    bc1 = 1 - b1 ** cf
    bc2 = 1 - b2 ** cf

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * b1 + gf * (1 - b1)
        vf = v.astype(jnp.float32) * b2 + jnp.square(gf) * (1 - b2)
        step = (mf / bc1) / (jnp.sqrt(vf / bc2) + tc.eps)
        if p.ndim >= 2 and tc.weight_decay:
            step = step + tc.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * step
        return newp.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

    flat_p, td = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(td, [o[0] for o in out])
    new_m = jax.tree.unflatten(td, [o[1] for o in out])
    new_v = jax.tree.unflatten(td, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics
