"""Parameter spec system: the single source of truth for shapes, logical axes,
coalescing roles and initialization.

Every model module declares its parameters as a pytree of :class:`Spec`.  From the
spec tree we derive, without ever materializing weights:

* ``init_tree``          -> concrete parameters (only for small/smoke models),
* ``axes_tree``          -> logical-axis names per dim (drives sharding rules),
* ``roles_tree``         -> coalescing role per dim ("in"/"out"/"-"; drives the
                            paper's width Coalescing/De-coalescing operators),
* ``struct_tree``        -> jax.ShapeDtypeStruct stand-ins (drives the multi-pod
                            dry-run: 671B-parameter models are never allocated).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Spec:
    """Declaration of one parameter tensor.

    Attributes:
      shape: global (unsharded) shape.
      axes:  logical axis name per dim, e.g. ("layers", "embed", "mlp").
      roles: coalescing role per dim: "in" (axis consumed by the op), "out"
             (axis produced), "-" (protected / not width-coalesced).  The
             "layers" axis is depth-coalesced regardless of role.
      init:  "normal" | "zeros" | "ones" | "fan_in" | "embed" | "mamba_A" |
             "mamba_dt".
      scale: stddev override for "normal"; ignored otherwise.
    """

    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    roles: Tuple[str, ...] = ()
    init: str = "normal"
    scale: Optional[float] = None
    dtype: Optional[Any] = None

    def __post_init__(self):
        if len(self.axes) != len(self.shape):
            raise ValueError(f"axes {self.axes} do not match shape {self.shape}")
        if self.roles and len(self.roles) != len(self.shape):
            raise ValueError(f"roles {self.roles} do not match shape {self.shape}")
        if not self.roles:
            object.__setattr__(self, "roles", ("-",) * len(self.shape))


def is_spec(x) -> bool:
    return isinstance(x, Spec)


def _init_leaf(key, spec: Spec, dtype) -> jax.Array:
    dt = spec.dtype or dtype
    sh = spec.shape
    if spec.init == "zeros":
        return jnp.zeros(sh, dt)
    if spec.init == "ones":
        return jnp.ones(sh, dt)
    if spec.init == "normal":
        sd = 0.02 if spec.scale is None else spec.scale
        return (jax.random.normal(key, sh, jnp.float32) * sd).astype(dt)
    if spec.init == "embed":
        sd = 0.02 if spec.scale is None else spec.scale
        return (jax.random.normal(key, sh, jnp.float32) * sd).astype(dt)
    if spec.init == "fan_in":
        # stddev = scale / sqrt(prod of "in"-role dims); fallback: first dim.
        fan = 1
        got = False
        for n, r in zip(sh, spec.roles):
            if r == "in":
                fan *= n
                got = True
        if not got:
            fan = sh[0]
        sd = (spec.scale or 1.0) / math.sqrt(max(fan, 1))
        return (jax.random.normal(key, sh, jnp.float32) * sd).astype(dt)
    if spec.init == "mamba_A":
        # A = -exp(A_log); init A_log = log(1..d_state) broadcast over the
        # leading (layers, d_inner) dims.
        d_state = sh[-1]
        a = jnp.broadcast_to(jnp.log(jnp.arange(1, d_state + 1, dtype=jnp.float32)), sh)
        return a.astype(dt)
    if spec.init == "mamba_dt":
        # dt bias init so that softplus(dt) spans [1e-3, 1e-1].
        lo, hi = 1e-3, 1e-1
        u = jax.random.uniform(key, sh, jnp.float32)
        tvals = jnp.exp(u * (math.log(hi) - math.log(lo)) + math.log(lo))
        inv = tvals + jnp.log(-jnp.expm1(-tvals))  # inverse softplus
        return inv.astype(dt)
    raise ValueError(f"unknown init {spec.init!r}")


def init_tree(key: jax.Array, specs, dtype=jnp.float32):
    """Materialize parameters for a spec tree (used for smoke/proxy scale only)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def axes_tree(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def roles_tree(specs):
    return jax.tree.map(lambda s: s.roles, specs, is_leaf=is_spec)


def struct_tree(specs, dtype=jnp.bfloat16):
    """ShapeDtypeStruct stand-ins (weak-type-correct, shardable, no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or dtype), specs, is_leaf=is_spec
    )


def count_params(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return sum(int(np.prod(s.shape)) for s in leaves)


def param_bytes(specs, dtype=jnp.bfloat16) -> int:
    itemsize = jnp.dtype(dtype).itemsize
    return count_params(specs) * itemsize


# ---------------------------------------------------------------------------
# small tree helpers


def tree_axpy(a: float, x, y):
    """a*x + (1-a)*y  elementwise over two matching pytrees."""
    return jax.tree.map(lambda u, v: a * u + (1.0 - a) * v, x, y)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def tree_cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def flatten_with_paths(tree, is_leaf=None) -> Dict[str, Any]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)[0]:
        out[jax.tree_util.keystr(path)] = leaf
    return out
