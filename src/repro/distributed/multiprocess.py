"""Multi-process (multi-host) coordination primitives.

Everything here degrades to a no-op / identity in single-process runs, so the
exact same driver code paths serve CPU smoke tests and real multi-host
launches (``jax.distributed.initialize`` lives in ``repro.launch.mesh`` --
see ``init_distributed`` -- because it must run before backend init).

Three multi-process facts the rest of the codebase leans on:

* **Non-addressable arrays cannot be device_put from host data.**  A global
  array sharded (or even just replicated) across processes must be built with
  ``jax.make_array_from_callback`` from each process's addressable slices --
  :func:`put_global` and :func:`GlobalBatchFn` wrap that.
* **Collectives must be called symmetrically.**  Every process must reach the
  same collective in the same order, so coordinated decisions (the preemption
  drain flag) are polled unconditionally once per step on every process --
  :func:`any_process_flag`.
* **Checkpoint publish needs a barrier.**  :func:`barrier` prefers the
  coordination-service barrier (pure RPC, no device computation -- safe to
  call between training steps without interleaving extra collectives) and
  falls back to ``sync_global_devices``.
"""
from __future__ import annotations

import json
from typing import Any, Optional

import jax
import numpy as np

_BARRIER_TIMEOUT_MS = 10 * 60 * 1000


def process_count() -> int:
    return int(jax.process_count())


def process_index() -> int:
    return int(jax.process_index())


def is_primary() -> bool:
    """True on the process that owns logging / watchdog / manifest publish."""
    return process_index() == 0


def _coordination_client():
    try:  # private but stable across the 0.4.x line; None when not distributed
        from jax._src import distributed as _dist

        return _dist.global_state.client
    except Exception:
        return None


def barrier(name: str) -> None:
    """Block until every process reaches this barrier (no-op single-process).

    ``name`` must be unique per synchronization point (the checkpoint manager
    keys it on a per-save sequence number).  Uses the distributed
    coordination-service barrier when available -- a pure RPC, so it cannot
    interleave device collectives with a training step that is still flushing
    -- and falls back to ``multihost_utils.sync_global_devices``.
    """
    if process_count() == 1:
        return
    client = _coordination_client()
    if client is not None:
        client.wait_at_barrier(f"repro:{name}", timeout_in_ms=_BARRIER_TIMEOUT_MS)
        return
    from jax.experimental import multihost_utils

    multihost_utils.sync_global_devices(name)


def _require_client():
    client = _coordination_client()
    if client is None:
        raise RuntimeError(
            "the coordination-service KV store needs jax.distributed "
            "(repro.launch.mesh.init_distributed) -- single-process runs "
            "have no peers to exchange with")
    return client


def kv_put(key: str, payload: bytes) -> None:
    """Publish bytes under ``key`` in the coordination-service KV store.

    Keys must be unique per run (callers scope them with per-instance
    sequence counters); values ride the same gRPC channel as barriers, so
    keep them modest (the checkpoint gather moves one leaf chunk at a time).
    """
    _require_client().key_value_set_bytes(f"repro:{key}", payload)


def kv_fetch(key: str, timeout_ms: int = _BARRIER_TIMEOUT_MS) -> bytes:
    """Block until some process ``kv_put``s ``key``; returns its bytes."""
    return _require_client().blocking_key_value_get_bytes(
        f"repro:{key}", timeout_ms)


def kv_delete(key: str) -> None:
    """Best-effort delete of a KV entry.

    The coordinator holds every key in RAM for the life of the job, so
    producers MUST clean up once all consumers are provably past their
    fetches (i.e. after a barrier) -- a days-long run checkpointing on a
    cadence would otherwise grow coordinator memory without bound.  Failures
    are swallowed: a leaked key is a leak, not a correctness problem.
    """
    try:
        _require_client().key_value_delete(f"repro:{key}")
    except Exception:
        pass


def _kv_chunk_bytes() -> int:
    """Max bytes per KV message (env-tunable; tests shrink it to force
    multi-part streams)."""
    import os

    return max(1, int(os.environ.get("REPRO_KV_CHUNK_BYTES", 2 * 1024 * 1024)))


# Every stream message is prefixed so it can never be shorter than 2 bytes:
# this jaxlib's coordination service SEGFAULTS the whole job on a blocking
# get of a 1-byte value (empirically: 1-byte crashes, >=2 bytes are fine).
_STREAM_PREFIX = b"P:"


def kv_put_stream(key: str, payload: bytes) -> None:
    """Publish arbitrarily large bytes under ``key`` as bounded chunks.

    The coordination service rides gRPC, whose default message cap is ~4MB --
    one-message-per-leaf-chunk (`kv_put`) breaks on large checkpoint leaves.
    Payloads are split into ``REPRO_KV_CHUNK_BYTES``-sized parts
    (``{key}/part{i}``); the part count lands LAST under ``{key}/meta``, so a
    blocked :func:`kv_fetch_stream` that sees the meta is guaranteed every
    part is already published.
    """
    chunk = _kv_chunk_bytes()
    n = max(1, -(-len(payload) // chunk))
    for i in range(n):
        kv_put(f"{key}/part{i}",
               _STREAM_PREFIX + payload[i * chunk:(i + 1) * chunk])
    kv_put(f"{key}/meta", f"n={n}".encode())


def kv_fetch_stream(key: str, timeout_ms: int = _BARRIER_TIMEOUT_MS) -> bytes:
    """Block until :func:`kv_put_stream` publishes ``key``; reassembles the
    parts in order."""
    meta = kv_fetch(f"{key}/meta", timeout_ms)
    n = int(meta.decode().split("=", 1)[1])
    return b"".join(kv_fetch(f"{key}/part{i}", timeout_ms)[len(_STREAM_PREFIX):]
                    for i in range(n))


def kv_delete_stream(key: str) -> None:
    """Best-effort cleanup of a streamed key (same contract as
    :func:`kv_delete`: call only after consumers are provably past their
    fetches)."""
    try:
        meta = kv_fetch(f"{key}/meta", timeout_ms=1000)
        n = int(meta.decode().split("=", 1)[1])
    except Exception:
        return
    for i in range(n):
        kv_delete(f"{key}/part{i}")
    kv_delete(f"{key}/meta")


def kv_allgather(tag: str, payload: bytes,
                 timeout_ms: int = _BARRIER_TIMEOUT_MS) -> list:
    """Every process contributes ``payload`` under ``tag``; returns the list
    of all processes' payloads, rank-ordered and identical everywhere.

    Holds the exchange choreography in ONE place: put, fetch-all, barrier
    (proving every consumer is past its fetches), then a rank-0 cleanup sweep
    so the coordinator's RAM is reclaimed.  ``tag`` must be unique per
    exchange (callers scope it with per-instance sequence counters), and the
    call is a collective -- every process must reach it with the same tag.
    """
    pid, n = process_index(), process_count()
    kv_put(f"{tag}-{pid}", payload)
    out = [kv_fetch(f"{tag}-{r}", timeout_ms) for r in range(n)]
    barrier(f"{tag}-ag")
    if pid == 0:
        for r in range(n):
            kv_delete(f"{tag}-{r}")
    return out


def kv_json_allgather(tag: str, obj: Any,
                      timeout_ms: int = _BARRIER_TIMEOUT_MS) -> list:
    """:func:`kv_allgather` for JSON-serializable objects.

    Every process contributes ``obj``; returns all processes' decoded
    objects, rank-ordered and identical everywhere.  The checkpoint manager's
    control-plane exchanges (latest-candidate election, per-host manifest
    index merge, have/want object negotiation) all ride this.
    """
    return [json.loads(p) for p in
            kv_allgather(tag, json.dumps(obj).encode(), timeout_ms)]


def any_process_flag(flag: bool) -> bool:
    """Cross-process OR of a host-side flag (identity single-process).

    This is a collective: in multi-process runs EVERY process must call it at
    the same point (the drivers poll it exactly once per training step), which
    is also what makes the result well-defined -- all processes see the same
    answer at the same step, so e.g. a SIGTERM delivered to one process drains
    the whole job at one agreed step boundary.
    """
    if process_count() == 1:
        return bool(flag)
    from jax.experimental import multihost_utils

    got = multihost_utils.process_allgather(
        np.asarray([1 if flag else 0], np.int32))
    return bool(np.asarray(got).sum() > 0)


def put_global(x: Any, sharding) -> jax.Array:
    """``jax.device_put`` that also works when ``sharding`` spans processes.

    The caller must hold the FULL logical value on every process (true for
    deterministic inits, host-regenerated batches and reassembled checkpoint
    leaves); each process materializes only its addressable shards.
    """
    if sharding is None:
        return x
    if getattr(sharding, "is_fully_addressable", True):
        return jax.device_put(x, sharding)
    host = np.asarray(jax.device_get(x))
    return jax.make_array_from_callback(
        host.shape, sharding, lambda idx: host[idx])


def put_global_tree(tree, shardings):
    """Tree version of :func:`put_global` (``shardings=None`` -> identity)."""
    if shardings is None:
        return tree
    return jax.tree.map(put_global, tree, shardings)


class GlobalBatchFn:
    """Wrap a host-batch fn for a mesh that spans processes.

    The global batch is process-count-invariant: every process regenerates THE
    canonical batch for a step deterministically (``data/synthetic``: batches
    are pure functions of (seed, step, shard), so any host can do this) and
    materializes only the rows its data-axis coordinate addresses
    (``distributed.data_shard_index`` names that slice).  A 2-process
    ``--mesh 2x1`` run therefore consumes exactly the same data stream as a
    1-process run -- which is what makes cross-process-count resume and the
    equivalence tests well-posed.

    ``like`` exposes the batch's ShapeDtypeStruct tree without tracing through
    the host->global conversion (``jax.eval_shape`` cannot, because the
    conversion calls ``device_get``).
    """

    def __init__(self, batch_fn, mesh, rules=None):
        from repro.distributed.sharding import batch_shardings

        self.inner = batch_fn
        self.mesh = mesh
        self.like = jax.eval_shape(batch_fn, 0)
        self.shardings = batch_shardings(self.like, mesh, rules)

    def __call__(self, step):
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                            self.inner(step))
        return jax.tree.map(
            lambda x, s: jax.make_array_from_callback(
                x.shape, s, lambda idx, x=x: x[idx]),
            host, self.shardings)


def as_global_batch_fn(batch_fn, mesh: Optional[Any], rules=None):
    """Multi-process-safe batch fn (identity when one process or no mesh)."""
    if mesh is None or process_count() == 1:
        return batch_fn
    return GlobalBatchFn(batch_fn, mesh, rules)


class FusedDrainFlag:
    """Preemption drain flag fused into the compiled train step.

    The dedicated per-step ``process_allgather`` of the SIGTERM flag (a tiny
    host-side gloo round-trip between every step) is replaced by one extra
    input/output on the step itself: each process authors one int32 element
    per device it owns in a mesh-shaped array (``device_flag``), the step
    reduces it with ``jnp.max`` into a replicated ``metrics["drain"]`` scalar,
    and the cross-process OR therefore rides the step's existing collective
    schedule -- XLA fuses and overlaps it with the step's other reductions
    instead of a separate synchronous RPC.

    Wiring (see ``launch/train.py`` / ``core/vcycle.py``): the driver attaches
    an instance to its ``PreemptionGuard``; every step feeds
    ``device_flag()`` in and hands ``metrics["drain"]`` to ``observe``;
    ``PreemptionGuard.should_stop`` then reads ``last()`` instead of
    all-gathering.  Each element is single-authored by the process owning its
    device, so every process computes the identical ``max`` at the identical
    step -- a notice delivered to ANY ONE process still drains the whole job
    at one agreed step boundary (pinned by tests/test_multiprocess.py).
    """

    def __init__(self, mesh, guard=None):
        from jax.sharding import NamedSharding, PartitionSpec

        self.mesh = mesh
        self.guard = guard  # anything with a host-side ``triggered`` bool
        self.shape = tuple(np.shape(mesh.devices))
        # fully partitioned over every mesh axis: one element per device,
        # each authored only by the process that owns that device (a
        # replicated spec would let processes disagree about replica values)
        self.sharding = NamedSharding(mesh, PartitionSpec(*mesh.axis_names))
        self._last = None

    def device_flag(self) -> jax.Array:
        """This step's flag input: my devices' elements carry MY flag."""
        v = 1 if (self.guard is not None
                  and getattr(self.guard, "triggered", False)) else 0

        def shard(idx):
            dims = [len(range(*sl.indices(dim)))
                    for sl, dim in zip(idx, self.shape)]
            return np.full(dims, v, np.int32)

        return jax.make_array_from_callback(self.shape, self.sharding, shard)

    @staticmethod
    def reduce(flag: jax.Array) -> jax.Array:
        """The in-step cross-device OR (inside jit, alongside the metrics)."""
        import jax.numpy as jnp

        return jnp.max(flag)

    def wrap_step(self, step, *, in_shardings, out_shardings,
                  donate_argnums=(0, 1)):
        """jit an n-ary ``step(*state, batch) -> (*state, metrics)`` with the
        drain flag fused in: the compiled step takes the flag as an extra
        input, emits the replicated ``metrics["drain"]`` scalar, and the
        returned wrapper feeds/observes it transparently -- call sites keep
        the step's own signature.  Both drivers share this wiring (the
        classic step is 3-ary; the grad-reduce step threads its EF state as a
        4th state leg)."""

        def fused(*args):
            *inputs, flag = args
            *outs, m = step(*inputs)
            m = dict(m)
            # the cross-process preemption OR rides the step's own
            # collective schedule (no dedicated per-step allgather)
            m["drain"] = self.reduce(flag)
            return (*outs, m)

        compiled = jax.jit(fused,
                           in_shardings=(*in_shardings, self.sharding),
                           out_shardings=out_shardings,
                           donate_argnums=donate_argnums)

        def fn(*args):
            out = compiled(*args, self.device_flag())
            self.observe(out[-1]["drain"])
            return out

        return fn

    def observe(self, drain) -> None:
        """Record the step's replicated drain scalar (device value; the host
        read is deferred to ``last`` so pipelining is preserved)."""
        self._last = drain

    def last(self) -> bool:
        """True when any process's flag was set as of the last observed step."""
        return self._last is not None and int(jax.device_get(self._last)) > 0


def batch_like(batch_fn):
    """ShapeDtypeStruct tree for ``batch_fn`` -- honors a precomputed
    ``.like`` (set by :class:`GlobalBatchFn`, whose host->global conversion
    cannot be traced by ``jax.eval_shape``)."""
    like = getattr(batch_fn, "like", None)
    return like if like is not None else jax.eval_shape(batch_fn, 0)
