"""Multi-process (multi-host) coordination primitives.

Everything here degrades to a no-op / identity in single-process runs, so the
exact same driver code paths serve CPU smoke tests and real multi-host
launches (``jax.distributed.initialize`` lives in ``repro.launch.mesh`` --
see ``init_distributed`` -- because it must run before backend init).

Three multi-process facts the rest of the codebase leans on:

* **Non-addressable arrays cannot be device_put from host data.**  A global
  array sharded (or even just replicated) across processes must be built with
  ``jax.make_array_from_callback`` from each process's addressable slices --
  :func:`put_global` and :func:`GlobalBatchFn` wrap that.
* **Collectives must be called symmetrically.**  Every process must reach the
  same collective in the same order, so coordinated decisions (the preemption
  drain flag) are polled unconditionally once per step on every process --
  :func:`any_process_flag`.
* **Checkpoint publish needs a barrier.**  :func:`barrier` prefers the
  coordination-service barrier (pure RPC, no device computation -- safe to
  call between training steps without interleaving extra collectives) and
  falls back to ``sync_global_devices``.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np

_BARRIER_TIMEOUT_MS = 10 * 60 * 1000


def process_count() -> int:
    return int(jax.process_count())


def process_index() -> int:
    return int(jax.process_index())


def is_primary() -> bool:
    """True on the process that owns logging / watchdog / manifest publish."""
    return process_index() == 0


def _coordination_client():
    try:  # private but stable across the 0.4.x line; None when not distributed
        from jax._src import distributed as _dist

        return _dist.global_state.client
    except Exception:
        return None


def barrier(name: str) -> None:
    """Block until every process reaches this barrier (no-op single-process).

    ``name`` must be unique per synchronization point (the checkpoint manager
    keys it on a per-save sequence number).  Uses the distributed
    coordination-service barrier when available -- a pure RPC, so it cannot
    interleave device collectives with a training step that is still flushing
    -- and falls back to ``multihost_utils.sync_global_devices``.
    """
    if process_count() == 1:
        return
    client = _coordination_client()
    if client is not None:
        client.wait_at_barrier(f"repro:{name}", timeout_in_ms=_BARRIER_TIMEOUT_MS)
        return
    from jax.experimental import multihost_utils

    multihost_utils.sync_global_devices(name)


def any_process_flag(flag: bool) -> bool:
    """Cross-process OR of a host-side flag (identity single-process).

    This is a collective: in multi-process runs EVERY process must call it at
    the same point (the drivers poll it exactly once per training step), which
    is also what makes the result well-defined -- all processes see the same
    answer at the same step, so e.g. a SIGTERM delivered to one process drains
    the whole job at one agreed step boundary.
    """
    if process_count() == 1:
        return bool(flag)
    from jax.experimental import multihost_utils

    got = multihost_utils.process_allgather(
        np.asarray([1 if flag else 0], np.int32))
    return bool(np.asarray(got).sum() > 0)


def put_global(x: Any, sharding) -> jax.Array:
    """``jax.device_put`` that also works when ``sharding`` spans processes.

    The caller must hold the FULL logical value on every process (true for
    deterministic inits, host-regenerated batches and reassembled checkpoint
    leaves); each process materializes only its addressable shards.
    """
    if sharding is None:
        return x
    if getattr(sharding, "is_fully_addressable", True):
        return jax.device_put(x, sharding)
    host = np.asarray(jax.device_get(x))
    return jax.make_array_from_callback(
        host.shape, sharding, lambda idx: host[idx])


def put_global_tree(tree, shardings):
    """Tree version of :func:`put_global` (``shardings=None`` -> identity)."""
    if shardings is None:
        return tree
    return jax.tree.map(put_global, tree, shardings)


class GlobalBatchFn:
    """Wrap a host-batch fn for a mesh that spans processes.

    The global batch is process-count-invariant: every process regenerates THE
    canonical batch for a step deterministically (``data/synthetic``: batches
    are pure functions of (seed, step, shard), so any host can do this) and
    materializes only the rows its data-axis coordinate addresses
    (``distributed.data_shard_index`` names that slice).  A 2-process
    ``--mesh 2x1`` run therefore consumes exactly the same data stream as a
    1-process run -- which is what makes cross-process-count resume and the
    equivalence tests well-posed.

    ``like`` exposes the batch's ShapeDtypeStruct tree without tracing through
    the host->global conversion (``jax.eval_shape`` cannot, because the
    conversion calls ``device_get``).
    """

    def __init__(self, batch_fn, mesh, rules=None):
        from repro.distributed.sharding import batch_shardings

        self.inner = batch_fn
        self.mesh = mesh
        self.like = jax.eval_shape(batch_fn, 0)
        self.shardings = batch_shardings(self.like, mesh, rules)

    def __call__(self, step):
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                            self.inner(step))
        return jax.tree.map(
            lambda x, s: jax.make_array_from_callback(
                x.shape, s, lambda idx, x=x: x[idx]),
            host, self.shardings)


def as_global_batch_fn(batch_fn, mesh: Optional[Any], rules=None):
    """Multi-process-safe batch fn (identity when one process or no mesh)."""
    if mesh is None or process_count() == 1:
        return batch_fn
    return GlobalBatchFn(batch_fn, mesh, rules)


def batch_like(batch_fn):
    """ShapeDtypeStruct tree for ``batch_fn`` -- honors a precomputed
    ``.like`` (set by :class:`GlobalBatchFn`, whose host->global conversion
    cannot be traced by ``jax.eval_shape``)."""
    like = getattr(batch_fn, "like", None)
    return like if like is not None else jax.eval_shape(batch_fn, 0)
