"""Error-feedback int8 gradient compression for the data-parallel all-reduce.

At 1000+ node scale the DP gradient reduction crosses DCN (between pods) where
bandwidth, not latency, dominates; int8 quantization cuts those bytes 4x
vs f32 (2x vs bf16).  Error feedback keeps the quantization noise unbiased
over time (the residual is carried and re-added next step), which preserves
convergence (Karimireddy et al., 2019).

Usage inside a shard_map'd train step:
    g_sum, ef = ef_int8_psum(grads, ef, axis_name="pod")
Off by default (TrainConfig.grad_compression="none"); the pure-pjit path keeps
XLA's native reductions.  The pluggable strategy layer that decides *which*
axes get this treatment lives in ``distributed/reduce.py``.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

# Trace-time call probe: incremented every time ``ef_int8_psum`` is traced
# into a computation.  Lets drivers/tests assert the compressed path actually
# executes inside the compiled step (acceptance is "asserted via a call probe,
# not just config") -- jit tracing runs this module-level code exactly once
# per compilation.
_EF_PSUM_CALLS = 0


def ef_psum_calls() -> int:
    """How many times ``ef_int8_psum`` has been traced in this process."""
    return _EF_PSUM_CALLS


def reset_ef_psum_probe() -> None:
    global _EF_PSUM_CALLS
    _EF_PSUM_CALLS = 0


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress(x: jax.Array, ef: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Compress (x + carried error); returns (q, scale, new_error)."""
    target = x.astype(jnp.float32) + ef
    q, scale = quantize_int8(target)
    new_ef = target - dequantize_int8(q, scale)
    return q, scale, new_ef


def ef_int8_psum(grads, ef_state, axis_name: str):
    """Packed int8 EF compression + ONE psum over ``axis_name`` (in shard_map).

    All leaves are quantized against their shared (pmax'd) per-leaf scale,
    flattened and concatenated into a single int8 payload, and reduced with a
    single int32 psum -- one latency-bound collective per step instead of two
    per leaf.  Quantizing directly at the shared scale (rather than requantizing
    a locally-quantized payload) keeps the EF identity exact:
    ``sent + new_ef == grad + ef`` to f32 roundoff.

    The int8 payload is summed in int32 (lossless across <=2^23 ranks) and
    de-quantized with the shared max scale.  Returns ``(reduced, new_ef)``
    where ``reduced`` is the *sum* over the axis, cast back to each leaf's
    dtype, and ``new_ef`` is the carried f32 residual.
    """
    global _EF_PSUM_CALLS
    _EF_PSUM_CALLS += 1

    flat_g, td = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef_state)
    targets = [g.astype(jnp.float32) + e for g, e in zip(flat_g, flat_e)]

    # one pmax over the stacked per-leaf scale vector
    scales = jnp.stack(
        [jnp.maximum(jnp.max(jnp.abs(t)), 1e-12) / 127.0 for t in targets])
    smax = jax.lax.pmax(scales, axis_name)

    # quantize each leaf at the shared scale; smax >= local scale so no value
    # exceeds 127 in magnitude (the clip is pure safety)
    qs, new_es = [], []
    for i, t in enumerate(targets):
        q = jnp.clip(jnp.round(t / smax[i]), -127, 127)
        new_es.append(t - q * smax[i])
        qs.append(q.astype(jnp.int8).ravel())

    # one packed int32 psum for every leaf's payload
    packed = jnp.concatenate(qs) if len(qs) > 1 else qs[0]
    total = jax.lax.psum(packed.astype(jnp.int32), axis_name)

    out, off = [], 0
    for i, g in enumerate(flat_g):
        n = g.size
        leaf = total[off:off + n].reshape(g.shape)
        out.append((leaf.astype(jnp.float32) * smax[i]).astype(g.dtype))
        off += n
    return jax.tree.unflatten(td, out), jax.tree.unflatten(td, new_es)


def init_ef_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


# ---------------------------------------------------------------------------
# bytes-on-wire accounting (analytic; what BENCH_dcn.json reports)


def dense_wire_bytes(tree) -> int:
    """Per-step all-reduce payload bytes for the uncompressed gradient tree."""
    return sum(leaf.size * jnp.dtype(getattr(leaf, "dtype", jnp.float32)).itemsize
               for leaf in jax.tree.leaves(tree))


def int8_wire_bytes(tree) -> int:
    """Per-step payload bytes for the packed int8+EF path: 1 byte/element plus
    one f32 scale per leaf (the pmax'd scale vector)."""
    leaves = jax.tree.leaves(tree)
    return sum(leaf.size for leaf in leaves) + 4 * len(leaves)
