"""Error-feedback int8 gradient compression for the data-parallel all-reduce.

At 1000+ node scale the DP gradient reduction crosses DCN (between pods) where
bandwidth, not latency, dominates; int8 quantization cuts those bytes 4x
vs f32 (2x vs bf16).  Error feedback keeps the quantization noise unbiased
over time (the residual is carried and re-added next step), which preserves
convergence (Karimireddy et al., 2019).

Usage inside a shard_map'd train step:
    g_sum, ef = ef_int8_psum(grads, ef, axis_name="data")
Off by default (TrainConfig.grad_compression="none"); the pure-pjit path keeps
XLA's native reductions.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress(x: jax.Array, ef: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Compress (x + carried error); returns (q, scale, new_error)."""
    target = x.astype(jnp.float32) + ef
    q, scale = quantize_int8(target)
    new_ef = target - dequantize_int8(q, scale)
    return q, scale, new_ef


def ef_int8_psum(grads, ef_state, axis_name: str):
    """Per-leaf int8 EF compression + psum over ``axis_name`` (inside shard_map).

    The int8 payload is summed in int32 (lossless across <=2^23 ranks) and
    de-quantized with the max participating scale.
    """

    def one(g, e):
        q, scale, new_e = ef_compress(g, e)
        # all ranks share the max scale so the int8 sum is consistent
        smax = jax.lax.pmax(scale, axis_name)
        q = jnp.clip(jnp.round((dequantize_int8(q, scale)) / smax), -127, 127)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return (total.astype(jnp.float32) * smax).astype(g.dtype), new_e

    flat_g, td = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(td, [o[0] for o in out]),
            jax.tree.unflatten(td, [o[1] for o in out]))


def init_ef_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
