"""Pluggable gradient-reduction strategies for the shard_map'd train step.

The data-parallel gradient all-reduce is the one cost the V-cycle itself
cannot shrink: at the 1000+-node scale the ROADMAP targets it crosses DCN
(between pods) where bandwidth dominates.  This module makes the reduction an
explicit, injectable layer instead of an implicit XLA pjit detail:

- ``DenseReduce``      -- full-precision mean over every data-like mesh axis
                          (exactly what pjit's implicit reduction does today).
- ``HierarchicalInt8EF`` -- full-precision mean within the fast ICI sub-axis
                          ("data"), then int8 + error-feedback psum across the
                          slow DCN axis ("pod") via ``ef_int8_psum``.  The EF
                          residual keeps the quantization noise unbiased over
                          time (Karimireddy et al., 2019).

A strategy owns its carried state: ``init_state`` / ``state_shardings`` give
the EF residual tree its global layout (leading ``[n_dcn]`` axis, one residual
per DCN rank), and ``reduce`` runs INSIDE the shard_map body where mesh axes
are bound.  ``models/api.py::make_train_step`` injects the strategy; the
V-cycle threads the state through checkpoints and resets it at level
transitions (shapes change with the level).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.compression import (dense_wire_bytes, ef_int8_psum,
                                           int8_wire_bytes)


def _data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


@dataclasses.dataclass(frozen=True)
class GradReduce:
    """Base strategy: mean-reduce microbatch-mean gradients over the data-like
    mesh axes inside a shard_map body.

    ``reduce(grads, ef)`` takes the local gradient tree plus the carried state
    (``None`` for stateless strategies) and returns the reduced tree plus the
    new state.  ``wire_bytes(grads)`` reports the analytic per-step all-reduce
    payload this strategy puts on the slowest (DCN) link.
    """

    data_axes: Tuple[str, ...]

    name = "dense"
    stateful = False

    def init_state(self, params) -> Any:
        return None

    def state_shardings(self, params_shardings, mesh: Mesh) -> Any:
        return None

    def reduce(self, grads, ef):
        raise NotImplementedError

    def wire_bytes(self, grads) -> int:
        raise NotImplementedError


class DenseReduce(GradReduce):
    """Today's behavior, made explicit: one full-precision pmean over every
    data-like axis."""

    name = "dense"
    stateful = False

    def reduce(self, grads, ef):
        grads = jax.tree.map(
            lambda g: jax.lax.pmean(g, self.data_axes), grads)
        return grads, None

    def wire_bytes(self, grads) -> int:
        return dense_wire_bytes(grads)


@dataclasses.dataclass(frozen=True)
class HierarchicalInt8EF(GradReduce):
    """Dense within ICI, int8+error-feedback across DCN.

    The mean over the DCN axis is folded into the compression: each DCN rank
    pre-divides its (ICI-reduced) gradients by ``dcn_size`` and the int8
    payloads are *summed* -- so the EF residual is carried in mean-units and
    the reduced gradient matches ``DenseReduce`` up to quantization noise.
    """

    dcn_axis: str = "pod"
    ici_axes: Tuple[str, ...] = ()
    dcn_size: int = 1

    name = "int8_ef"
    stateful = True

    def init_state(self, params) -> Any:
        """Global EF-residual tree: f32, one residual per DCN rank, stacked on
        a leading ``[n_dcn]`` axis so it checkpoints/restores like any other
        state tree."""
        return jax.tree.map(
            lambda p: jnp.zeros((self.dcn_size,) + tuple(p.shape), jnp.float32),
            params)

    def state_shardings(self, params_shardings, mesh: Mesh) -> Any:
        sh = NamedSharding(mesh, P(self.dcn_axis))
        return jax.tree.map(lambda _: sh, params_shardings)

    def state_specs(self) -> P:
        """In/out PartitionSpec for the EF tree entering the shard_map body
        (sharded over the DCN axis on dim 0, replicated over ICI/model)."""
        return P(self.dcn_axis)

    def reduce(self, grads, ef):
        # full-precision mean within the fast ICI sub-axis first
        if self.ici_axes:
            grads = jax.tree.map(
                lambda g: jax.lax.pmean(g, self.ici_axes), grads)
        # inside shard_map each DCN rank holds the [1, *shape] block of the
        # global [n_dcn, *shape] residual
        ef_local = jax.tree.map(lambda e: e[0], ef)
        inv = 1.0 / self.dcn_size
        pre = jax.tree.map(lambda g: g * inv, grads)
        reduced, new_ef = ef_int8_psum(pre, ef_local, self.dcn_axis)
        reduced = jax.tree.map(lambda r, g: r.astype(g.dtype), reduced, grads)
        new_ef = jax.tree.map(lambda e: e[None], new_ef)
        return reduced, new_ef

    def wire_bytes(self, grads) -> int:
        return int8_wire_bytes(grads)


def make_grad_reduce(name: str, mesh: Mesh) -> Optional[GradReduce]:
    """Build a strategy from a ``TrainConfig.grad_compression`` name.

    - "none"    -> None (legacy pjit step; XLA's implicit reduction)
    - "dense"   -> DenseReduce over every data-like axis (explicit shard_map)
    - "int8_ef" -> HierarchicalInt8EF: the DCN axis is "pod" when the mesh has
      one (ICI = "data"), otherwise the whole "data" axis is treated as DCN.
    """
    if name in (None, "", "none"):
        return None
    data_axes = _data_axes(mesh)
    if not data_axes:
        raise ValueError(f"mesh {mesh.axis_names} has no data-like axis to reduce over")
    if name == "dense":
        return DenseReduce(data_axes=data_axes)
    if name == "int8_ef":
        dcn_axis = "pod" if "pod" in mesh.axis_names else data_axes[0]
        ici_axes = tuple(a for a in data_axes if a != dcn_axis)
        return HierarchicalInt8EF(
            data_axes=data_axes, dcn_axis=dcn_axis, ici_axes=ici_axes,
            dcn_size=int(mesh.shape[dcn_axis]))
    raise ValueError(f"unknown grad_compression {name!r} (none | dense | int8_ef)")
