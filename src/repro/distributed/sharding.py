"""Logical-axis -> mesh-axis sharding rules.

A single rules table maps every logical axis name (the same names used by the
coalescing operators) to mesh axes.  ``spec_for`` drops any mapping whose size
does not divide the mesh axis product (e.g. 40 heads on a 16-way model axis,
batch=1 decode) so every architecture lowers cleanly; what gets dropped is
visible in the roofline report as a replicated (memory-heavier) term.

Layers call ``shard_l(x, axes)`` which is a no-op outside a mesh context, so
smoke tests on CPU run the exact same model code.
"""
from __future__ import annotations

import contextlib
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import param as pm

AxisMap = Union[None, str, Tuple[str, ...]]

# fsdp axes: the data-like axes used for parameter (ZeRO-3 style) sharding.
# They are resolved per-mesh: ("pod","data") when a "pod" axis exists.
FSDP = "__fsdp__"
DP = "__dp__"  # all data-like axes, for activation batch dims

RULES: Dict[str, AxisMap] = {
    # --- parameter axes ---
    "embed": FSDP,           # residual stream width: FSDP-sharded on params
    "embed_cat2": FSDP,
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",      # expert parallelism
    "moe_mlp": None,
    "shared_mlp": "model",
    "q_lora": None,
    "kv_lora": None,
    "head_dim": None,
    "v_head_dim": None,
    "rope_dim": None,
    "layers": None,
    "mamba_inner": "model",
    "mamba_state": None,
    "dt_rank": None,
    "conv_k": None,
    "xlstm_inner": "model",
    "vision_embed": None,
    "classes": None,
    "patch": None,
    "mtp": None,
    # --- activation axes ---
    "batch": DP,
    "seq": None,
    "act_embed": None,       # residual activations replicated over "model" (TP)
    "act_heads": "model",
    "act_kv_heads": "model",
    "act_mlp": "model",
    "act_experts": "model",
    "act_experts_mid": "model",  # intermediate hop for the EP reshard (serving)
    "moe_batch": DP,         # batch dim inside expert compute (None when serving)
    "act_vocab": "model",
    "act_mamba": "model",
    "act_xlstm": "model",
    "cache_seq": "model",    # decode KV/latent caches: sequence-sharded (flash-decode CP)
    "attn_seq": "model",     # context-parallel attention activations (opt-in)
    "cache_kv_heads": None,
    "capacity": None,
    "img_seq": None,
    "enc_seq": None,
}

# Serving-time overrides: parameters are read-only (no optimizer state), so
# FSDP gathering them every decode step is pure waste.  Experts spread over
# the FULL device set (256-way EP: DeepSeek-V3 fits at ~88MB/expert/device)
# and the remaining weights replicate over the data axis, ending the
# per-token parameter all-gathers (EXPERIMENTS.md §Perf deepseek iter.2).
SERVE_RULES: Dict[str, AxisMap] = {
    # model-major expert placement: the (batch:data -> experts:data) reshard
    # then factors as a clean all-to-all over "data" instead of GSPMD's
    # replicate-and-repartition fallback (measured: 2x1.9GB AG per MoE layer)
    "experts": ("model", "data"),
    "act_experts": ("model", "data"),  # expert compute spread over ALL devices
    "moe_batch": None,  # ...with the token dim replicated inside the a2a region
    # few-expert models (jamba/phi: 16 experts -> the progressive drop lands
    # them on "data") shard the expert HIDDEN dim over the leftover "model"
    # axis -- without this jamba-1.5-large serving holds 44 GB of expert FFNs
    # per device; deepseek (256-way expert sharding) drops this mapping.
    "moe_mlp": "model",
    "embed": None,
    "embed_cat2": None,
}

_CTX: dict = {"mesh": None, "rules": None, "extra": None}


def _resolve(rules: Dict[str, AxisMap], mesh: Mesh, name: str) -> Tuple[str, ...]:
    m = rules.get(name, None)
    if m is None:
        return ()
    if m == FSDP:
        return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if m == DP:
        return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if isinstance(m, str):
        return (m,) if m in mesh.axis_names else ()
    return tuple(a for a in m if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def logical_spec(
    shape: Sequence[int],
    axes: Sequence[str],
    mesh: Mesh,
    rules: Optional[Dict[str, AxisMap]] = None,
) -> P:
    """PartitionSpec for a tensor with logical axes; drops non-divisible mappings
    and never assigns the same mesh axis twice."""
    rules = rules or RULES
    used: set = set()
    entries = []
    for dim, name in zip(shape, axes):
        cand = _resolve(rules, mesh, name)
        cand = tuple(a for a in cand if a not in used)
        # progressively drop leading axes until the dim divides (e.g. 16
        # experts on a ("data","model") 256-way serving map -> ("model",))
        while cand and dim % _axis_size(mesh, cand) != 0:
            cand = cand[1:]
        if cand:
            used.update(cand)
            entries.append(cand if len(cand) > 1 else cand[0])
        else:
            entries.append(None)
    return P(*entries)


def set_mesh_ctx(mesh: Mesh, rules: Optional[Dict[str, AxisMap]] = None) -> None:
    _CTX["mesh"] = mesh
    _CTX["rules"] = dict(RULES, **(rules or {}))


def clear_mesh_ctx() -> None:
    _CTX["mesh"] = None
    _CTX["rules"] = None


@contextlib.contextmanager
def mesh_ctx(mesh: Mesh, rules: Optional[Dict[str, AxisMap]] = None):
    """Enter mesh: layer-level ``shard_l`` constraints become active."""
    prev = (_CTX["mesh"], _CTX["rules"])
    set_mesh_ctx(mesh, rules)
    # jax >= 0.5 scopes the mesh with use_mesh; on older jax the Mesh object
    # itself is the context manager that binds its axis names
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    try:
        with (use_mesh(mesh) if use_mesh is not None else mesh):
            yield mesh
    finally:
        _CTX["mesh"], _CTX["rules"] = prev


def current_mesh() -> Optional[Mesh]:
    return _CTX["mesh"]


@contextlib.contextmanager
def no_constraints():
    """Suspend ``shard_l`` constraints (trace-time).

    Inside a ``shard_map`` body the mesh axes are already bound manually, so
    GSPMD sharding constraints are meaningless (and jax rejects
    with_sharding_constraint against the same mesh's axes there).  The
    shard_map'd train step wraps its forward/backward in this.
    """
    prev = (_CTX["mesh"], _CTX["rules"])
    _CTX["mesh"], _CTX["rules"] = None, None
    try:
        yield
    finally:
        _CTX["mesh"], _CTX["rules"] = prev


def shard_l(x: jax.Array, axes: Sequence[str], overrides: Optional[Dict] = None) -> jax.Array:
    """Apply a logical sharding constraint; no-op outside a mesh context."""
    mesh = _CTX["mesh"]
    if mesh is None:
        return x
    rules = dict(_CTX["rules"], **overrides) if overrides else _CTX["rules"]
    spec = logical_spec(x.shape, axes, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def param_shardings(specs, mesh: Mesh, rules=None):
    """NamedSharding tree for a Spec tree (params / optimizer / cache)."""

    def one(s: pm.Spec):
        return NamedSharding(mesh, logical_spec(s.shape, s.axes, mesh, rules))

    return jax.tree.map(one, specs, is_leaf=pm.is_spec)


def activation_spec(shape, axes, mesh, rules=None) -> NamedSharding:
    return NamedSharding(mesh, logical_spec(shape, axes, mesh, rules))


def batch_shardings(batch_like, mesh: Mesh, rules=None):
    """Data-parallel NamedSharding tree for a batch pytree.

    Every leaf's leading dim is the logical "batch" axis (sharded over the
    data-like mesh axes when divisible, replicated otherwise -- same
    progressive-drop rule as parameters); trailing dims replicate.  Accepts
    concrete arrays or ShapeDtypeStructs (e.g. ``jax.eval_shape(batch_fn, 0)``).
    """

    def one(x):
        axes = ("batch",) + ("seq",) * (len(x.shape) - 1)
        return NamedSharding(mesh, logical_spec(x.shape, axes, mesh, rules))

    return jax.tree.map(one, batch_like)


def data_shard_index(mesh: Optional[Mesh] = None) -> int:
    """Deterministic data-shard id for THIS process (feeds ``make_batch_fn``).

    Without a mesh this is ``jax.process_index()``.  With a mesh it is the
    coordinate of the process's first local device along the data-like
    ("pod", "data") axes, flattened -- model-parallel co-hosts share a shard
    while data-parallel hosts get distinct ones.  Single-process runs (CPU
    tests, smoke) always map to shard 0, keeping batches identical across
    mesh shapes so cross-mesh resume equivalence is well-posed.
    """
    if mesh is None:
        return int(jax.process_index())
    if jax.process_count() == 1:
        return 0
    local = {d.id for d in jax.local_devices()}
    dev = np.asarray(mesh.devices)
    data_dims = [i for i, a in enumerate(mesh.axis_names) if a in ("pod", "data")]
    for idx in np.ndindex(dev.shape):
        if dev[idx].id in local:
            shard = 0
            for i in data_dims:
                shard = shard * dev.shape[i] + idx[i]
            return shard
    return int(jax.process_index())
