from repro.distributed.sharding import (  # noqa: F401
    RULES,
    activation_spec,
    clear_mesh_ctx,
    logical_spec,
    mesh_ctx,
    param_shardings,
    set_mesh_ctx,
    shard_l,
)
