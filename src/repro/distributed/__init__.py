from repro.distributed.multiprocess import (  # noqa: F401
    any_process_flag,
    as_global_batch_fn,
    barrier,
    batch_like,
    is_primary,
    put_global,
    put_global_tree,
)
from repro.distributed.sharding import (  # noqa: F401
    RULES,
    activation_spec,
    batch_shardings,
    clear_mesh_ctx,
    data_shard_index,
    logical_spec,
    mesh_ctx,
    param_shardings,
    set_mesh_ctx,
    shard_l,
)
