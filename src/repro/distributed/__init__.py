from repro.distributed.sharding import (  # noqa: F401
    RULES,
    activation_spec,
    batch_shardings,
    clear_mesh_ctx,
    data_shard_index,
    logical_spec,
    mesh_ctx,
    param_shardings,
    set_mesh_ctx,
    shard_l,
)
