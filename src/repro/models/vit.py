"""ViT / DeiT-style classifier on (stub) patch embeddings — used by the paper's
DeiT-B reproduction benchmarks (Table 3) and as an encoder-family exemplar."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config import BlockSpec, ModelConfig
from repro.distributed import shard_l
from repro.layers.basic import norm_apply, norm_specs
from repro.models.lm import _stack, block_specs, run_stages
from repro.param import Spec


def n_patches(cfg: ModelConfig) -> int:
    return (cfg.image_size // cfg.patch_size) ** 2


def patch_dim(cfg: ModelConfig) -> int:
    return cfg.patch_size * cfg.patch_size * 3


def vit_specs(cfg: ModelConfig) -> Dict[str, Any]:
    N = n_patches(cfg)
    return {
        "patch_proj": Spec((patch_dim(cfg), cfg.d_model), ("patch", "embed"), ("-", "out"),
                           init="fan_in"),
        "cls": Spec((1, cfg.d_model), ("seq", "embed"), ("-", "out"), init="normal", scale=0.02),
        "pos": Spec((N + 1, cfg.d_model), ("seq", "embed"), ("-", "out"), init="normal", scale=0.02),
        "stages": {
            f"stage_{i}": {
                f"b{j}": _stack(block_specs(cfg, bsj), st.repeats)
                for j, bsj in enumerate(st.pattern)
            }
            for i, st in enumerate(cfg.stages)
        },
        "final_norm": norm_specs(cfg),
        "head": Spec((cfg.d_model, cfg.n_classes), ("embed", "classes"), ("in", "-"),
                     init="fan_in"),
    }


def vit_forward(params: Dict, patches: jax.Array, cfg: ModelConfig) -> jax.Array:
    """patches: [B, N, patch_dim] -> logits [B, n_classes]."""
    B, N, _ = patches.shape
    cdt = cfg.compute_dtype
    x = jnp.einsum("bnp,pe->bne", patches.astype(cdt), params["patch_proj"].astype(cdt))
    cls = jnp.broadcast_to(params["cls"].astype(cdt), (B, 1, cfg.d_model))
    x = jnp.concatenate([cls, x], axis=1)
    x = x + params["pos"].astype(cdt)[None, : N + 1]
    x = shard_l(x, ("batch", "seq", "act_embed"))
    positions = jnp.broadcast_to(jnp.arange(N + 1)[None], (B, N + 1))
    x, _, _ = run_stages(params["stages"], cfg.stages, x, cfg,
                         positions=positions, mode="train")
    x = norm_apply(params["final_norm"], x, cfg)
    logits = jnp.einsum("be,ec->bc", x[:, 0], params["head"].astype(cdt))
    return logits.astype(jnp.float32)


def vit_loss(logits: jax.Array, labels: jax.Array):
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(lse - ll)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, {"loss": loss, "acc": acc}
