"""Public model facade + step builders (train / prefill / decode).

``Model`` wraps a ModelConfig with spec/init/loss/forward entry points used by
the V-cycle runner, the baselines, the launcher and the dry-run.  Step builders
return pure functions suitable for ``jax.jit`` (and ``.lower().compile()``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, TrainConfig
from repro.models import lm as lm_lib
from repro.models import vit as vit_lib
from repro.optim import adamw_init, adamw_init_specs, adamw_update
from repro.param import init_tree


@dataclasses.dataclass
class Model:
    cfg: ModelConfig

    # -- specs / init ------------------------------------------------------
    def specs(self):
        if self.cfg.family == "vit":
            return vit_lib.vit_specs(self.cfg)
        return lm_lib.lm_specs(self.cfg)

    def cache_specs(self, batch: int, max_seq: int):
        return lm_lib.cache_specs(self.cfg, batch, max_seq)

    def paged_cache_specs(self, n_pages: int, page_size: int):
        return lm_lib.paged_cache_specs(self.cfg, n_pages, page_size)

    def init(self, key: jax.Array):
        return init_tree(key, self.specs(), dtype=self.cfg.param_dtype)

    def projection_plan(self, ml=None, *, width: bool = True,
                        depth: bool = True):
        """This model's :class:`~repro.core.plans.ProjectionPlan` for one
        level transition: the family contract the V-cycle, baselines and the
        serving draft projection all share (coalescible axes, protected axes,
        role overrides, carried MoE scalars, ``small_cfg``)."""
        from repro.core.plans import build_plan

        return build_plan(self.cfg, ml, width=width, depth=depth)

    # -- losses ------------------------------------------------------------
    def loss(self, params, batch: Dict[str, jax.Array], z_loss: float = 0.0):
        cfg = self.cfg
        if cfg.family == "vit":
            logits = vit_lib.vit_forward(params, batch["patches"], cfg)
            return vit_lib.vit_loss(logits, batch["labels"])
        out = lm_lib.lm_forward(
            params, batch["tokens"], cfg, mode="train",
            img_embeds=batch.get("img_embeds"), enc_frames=batch.get("enc_frames"))
        mtp_labels = None
        if cfg.mtp_depth:
            lbl = batch["labels"]
            mtp_labels = jnp.concatenate(
                [lbl[:, 1:], jnp.full_like(lbl[:, :1], -1)], axis=1)
        return lm_lib.lm_loss(out["logits"], batch["labels"], cfg, out["aux"],
                              out.get("mtp_logits"), mtp_labels, z_loss)

    def forward_logits(self, params, batch):
        if self.cfg.family == "vit":
            return vit_lib.vit_forward(params, batch["patches"], self.cfg)
        return lm_lib.lm_forward(params, batch["tokens"], self.cfg, mode="train",
                                 img_embeds=batch.get("img_embeds"),
                                 enc_frames=batch.get("enc_frames"))["logits"]


def build_model(cfg: ModelConfig) -> Model:
    if cfg.kernel_backend:
        # fail fast on a typo'd backend instead of mid-training at trace time
        from repro.kernels import dispatch as kdispatch

        kdispatch.validate_backend(cfg.kernel_backend)
    return Model(cfg)


# ---------------------------------------------------------------------------
# step builders


def make_train_step(model: Model, tc: TrainConfig, *, grad_reduce=None,
                    mesh=None) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    With ``tc.grad_accum > 1`` the batch leaves must have a leading microbatch
    axis of size grad_accum; gradients are accumulated with a scan (activation
    memory divided by grad_accum — the standard TPU pipelining lever).

    With a ``grad_reduce`` strategy (``distributed/reduce.py``) and a ``mesh``,
    the step is instead built as a ``shard_map`` over the mesh with gradient
    reduction an explicit, pluggable layer, and the signature becomes 4-ary:
    ``train_step(params, opt_state, ef, batch) -> (params, opt_state, ef,
    metrics)`` where ``ef`` is the strategy's carried state (the EF residual
    tree for int8, ``None``-leaved zeros tree for stateless strategies).
    """
    if grad_reduce is not None:
        if mesh is None:
            raise ValueError("grad_reduce requires a mesh")
        return _make_shardmap_train_step(model, tc, grad_reduce, mesh)

    def loss_fn(params, micro):
        loss, metrics = model.loss(params, micro, z_loss=tc.z_loss)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    # Per-step FSDP weight pre-gather (MaxText-style): cast the f32 master
    # params to compute dtype ONCE per step with the data-axis sharding
    # dropped -- the all-gather then happens outside the grad-accum loop
    # instead of once per layer *per microbatch* (EXPERIMENTS.md §Perf
    # qwen3-14b iter).  The VJP of the constraint+cast is exactly the f32
    # gradient reduce-scatter back onto the FSDP layout.  Opt-in per arch:
    # the per-device gathered copy is total_bf16/model_shard, too large for
    # the 400B+ models (they keep per-layer gathering).
    if tc.pregather_params:
        from repro.distributed import shard_l
        from repro.param import axes_tree

        p_axes = axes_tree(model.specs())
        no_fsdp = {"embed": None, "embed_cat2": None}

        def pregather(params):
            return jax.tree.map(
                lambda p, ax: shard_l(p.astype(model.cfg.compute_dtype), ax, no_fsdp),
                params, p_axes)
    else:
        pregather = lambda params: params

    def train_step(params, opt_state, batch):
        if tc.pregather_params:
            p_use, pull = jax.vjp(pregather, params)
        else:
            p_use, pull = params, None

        if tc.grad_accum > 1:
            def acc_body(carry, micro):
                g_acc, m_acc = carry
                (_, metrics), grads = grad_fn(p_use, micro)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, grads)
                m_acc = jax.tree.map(lambda a, b: a + b, m_acc, metrics)
                return (g_acc, m_acc), None

            (_, m0), g0 = grad_fn(p_use, jax.tree.map(lambda x: x[0], batch))
            g0 = jax.tree.map(lambda g: g.astype(jnp.float32), g0)
            rest = jax.tree.map(lambda x: x[1:], batch)
            (g_sum, m_sum), _ = jax.lax.scan(acc_body, (g0, m0), rest)
            inv = 1.0 / tc.grad_accum
            grads = jax.tree.map(lambda g: g * inv, g_sum)
            metrics = jax.tree.map(lambda m: m * inv, m_sum)
        else:
            (_, metrics), grads = grad_fn(p_use, batch)
        if pull is not None:
            # one reduce-scatter back onto the FSDP layout per step
            grads = pull(jax.tree.map(
                lambda g, p: g.astype(p.dtype), grads, p_use))[0]
        params, opt_state, om = adamw_update(params, grads, opt_state, tc)
        return params, opt_state, {**metrics, **om}

    return train_step


def _make_shardmap_train_step(model: Model, tc: TrainConfig, grad_reduce, mesh):
    """The explicit-reduction train step: grad accumulation + reduction run
    inside a ``shard_map`` over ``mesh`` with the strategy injected.

    Params/opt enter the body replicated (in_specs P()): under ``jit`` with
    FSDP in_shardings this inserts exactly one all-gather per step — the same
    pattern ``tc.pregather_params`` opts into on the pjit path, so that flag is
    ignored here.  The optimizer update runs redundantly per rank on the
    replicated reduced gradients (identical values everywhere, so the
    global-norm clip stays consistent); jit out_shardings re-shard the result
    back onto the FSDP layout, keeping the external train-state layout — and
    hence checkpoints and V-cycle level transitions — unchanged.  Compute over
    the "model" axis is replicated inside the body (tensor parallelism stays a
    pjit concern; this path targets the data/DCN reduction).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed import no_constraints
    from repro.distributed.sharding import logical_spec

    def loss_fn(params, micro):
        loss, metrics = model.loss(params, micro, z_loss=tc.z_loss)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    data_axes = grad_reduce.data_axes

    def body(params, opt_state, ef, batch):
        with no_constraints():
            if tc.grad_accum > 1:
                def acc_body(carry, micro):
                    g_acc, m_acc = carry
                    (_, metrics), grads = grad_fn(params, micro)
                    g_acc = jax.tree.map(
                        lambda a, b: a + b.astype(a.dtype), g_acc, grads)
                    m_acc = jax.tree.map(lambda a, b: a + b, m_acc, metrics)
                    return (g_acc, m_acc), None

                (_, m0), g0 = grad_fn(params, jax.tree.map(lambda x: x[0], batch))
                g0 = jax.tree.map(lambda g: g.astype(jnp.float32), g0)
                rest = jax.tree.map(lambda x: x[1:], batch)
                (g_sum, m_sum), _ = jax.lax.scan(acc_body, (g0, m0), rest)
                inv = 1.0 / tc.grad_accum
                grads = jax.tree.map(lambda g: g * inv, g_sum)
                metrics = jax.tree.map(lambda m: m * inv, m_sum)
            else:
                (_, metrics), grads = grad_fn(params, batch)
        grads, ef = grad_reduce.reduce(grads, ef)
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, data_axes), metrics)
        params, opt_state, om = adamw_update(params, grads, opt_state, tc)
        return params, opt_state, ef, {**metrics, **om}

    ef_spec = grad_reduce.state_specs() if grad_reduce.stateful else P()

    def train_step(params, opt_state, ef, batch):
        # specs are computed at trace time from the actual abstract shapes so
        # the batch specs agree leaf-for-leaf with ``batch_shardings`` (same
        # progressive-drop divisibility logic)
        pspec = jax.tree.map(lambda _: P(), params)
        ospec = jax.tree.map(lambda _: P(), opt_state)
        efspec = jax.tree.map(lambda _: ef_spec, ef)

        def bspec_one(x):
            axes = ("batch",) + ("seq",) * (len(x.shape) - 1)
            return logical_spec(x.shape, axes, mesh)

        bspec = jax.tree.map(bspec_one, batch)
        f = shard_map(
            body, mesh=mesh,
            in_specs=(pspec, ospec, efspec, bspec),
            out_specs=(pspec, ospec, efspec, P()),
            check_rep=False)
        return f(params, opt_state, ef, batch)

    return train_step


def make_eval_loss(model: Model) -> Callable:
    def eval_loss(params, batch):
        loss, metrics = model.loss(params, batch)
        return metrics

    return eval_loss


def make_prefill_step(model: Model) -> Callable:
    """prefill_step(params, tokens, [extras]) -> (last_logits, caches)."""
    cfg = model.cfg

    def prefill_step(params, tokens, img_embeds=None, enc_frames=None):
        out = lm_lib.lm_forward(params, tokens, cfg, mode="prefill",
                                img_embeds=img_embeds, enc_frames=enc_frames)
        return out["logits"][:, -1, :], out["caches"]

    return prefill_step


def make_serve_step(model: Model) -> Callable:
    """serve_step(params, caches, tokens [B,1], pos [B]) -> (logits, caches).

    One new token against a KV/state cache of ``max_seq`` (the decode_* and
    long_* assigned shapes lower exactly this function).
    """
    cfg = model.cfg

    def serve_step(params, caches, tokens, pos):
        positions = pos[:, None]
        out = lm_lib.lm_forward(params, tokens, cfg, positions=positions,
                                mode="decode", caches=caches)
        return out["logits"][:, -1, :], out["caches"]

    return serve_step


def make_paged_decode_step(model: Model) -> Callable:
    """step(params, pages, tokens [B,S], positions [B,S], block_tables [B,M])
    -> (last_logits, pages).

    Decode/extend against the shared page pool: each batch row reads and
    writes K/V through its block-table row, so cost scales with the pages a
    request actually occupies, not ``max_seq``.  S==1 is the batched decode
    step; S>1 is the prefix-reuse "extend" step (left-padded rows carry
    positions == -1, which ``paged_write`` routes to the reserved null page).
    """
    cfg = model.cfg

    def paged_decode_step(params, pages, tokens, positions, block_tables):
        out = lm_lib.lm_forward(params, tokens, cfg, positions=positions,
                                mode="decode", caches=pages,
                                block_tables=block_tables)
        return out["logits"][:, -1, :], out["caches"]

    return paged_decode_step


def make_verify_step(model: Model) -> Callable:
    """verify_step(params, pages, tokens [B,S], positions [B,S], block_tables
    [B,M]) -> (logits [B,S,V], pages).

    The speculative-decode verifier: identical forward to
    ``make_paged_decode_step`` (same paged reads/writes through the block
    table) but returning logits at *every* position, so one batched
    full-model step scores a drafted token run d_0..d_k written at positions
    p..p+k.  ``logits[:, i]`` is the full model's next-token distribution
    after the token at ``positions[:, i]`` -- the acceptance rule compares
    ``argmax(logits[:, i])`` against the draft's proposal for position
    ``p+i+1``, and the first disagreement's argmax doubles as the correction
    token, which is what makes greedy speculative decoding lossless.
    Right-padded rows carry ``positions == -1`` (writes routed to the null
    page, attention fully masked); their logits are garbage and unread.
    """
    cfg = model.cfg

    def verify_step(params, pages, tokens, positions, block_tables):
        out = lm_lib.lm_forward(params, tokens, cfg, positions=positions,
                                mode="decode", caches=pages,
                                block_tables=block_tables)
        return out["logits"], out["caches"]

    return verify_step


def init_train_state(model: Model, tc: TrainConfig, key: jax.Array):
    params = model.init(key)
    opt_state = adamw_init(params, tc)
    return params, opt_state


def train_state_specs(model: Model, tc: TrainConfig):
    ps = model.specs()
    return ps, adamw_init_specs(ps, tc)


def serve_shardings(model: Model, mesh, *, n_pages=None, page_size=None,
                    rules=None):
    """(params, page-pool) NamedSharding trees + merged rules for mesh-sharded
    serving on ``mesh``.

    Layout: the training ``RULES`` overlaid with ``SERVE_RULES`` (read-only
    params spread over every device, no FSDP/DP gather per step) plus
    ``cache_kv_heads -> "model"``, so a GQA page pool shards its K/V heads
    over the model axis while MLA's latent ``ckv``/``kpe`` pools (no head
    axis) and the block tables stay replicated.  The page-pool tree is None
    unless ``n_pages``/``page_size`` are given.  The merged rule dict is
    returned too so callers can enter ``mesh_ctx`` with the identical layout
    (the serve step is then the same sharded function the ``decode_*``
    dry-run cells compile).
    """
    from repro.distributed import param_shardings
    from repro.distributed.sharding import RULES, SERVE_RULES

    merged = dict(RULES)
    merged.update(SERVE_RULES)
    merged["cache_kv_heads"] = "model"
    merged.update(rules or {})
    psh = param_shardings(model.specs(), mesh, merged)
    csh = None
    if n_pages is not None:
        csh = param_shardings(
            model.paged_cache_specs(n_pages, page_size), mesh, merged)
    return psh, csh, merged


def train_state_shardings(model: Model, tc: TrainConfig, mesh, rules=None,
                          grad_reduce=None):
    """(param, opt) NamedSharding trees for a model's train state on ``mesh``.

    Derived from the Spec trees (the optimizer mirrors the parameter logical
    axes), so every V-cycle level gets its own layout and a checkpoint written
    under one mesh can be restored onto another by passing these to
    ``CheckpointManager.restore(shardings=...)``.

    With a stateful ``grad_reduce`` strategy a third tree is returned: the
    sharding of the strategy's carried state (EF residuals, DCN-axis sharded
    on their leading dim).
    """
    from repro.distributed import param_shardings

    ps, opt_specs = train_state_specs(model, tc)
    psh = param_shardings(ps, mesh, rules)
    osh = param_shardings(opt_specs, mesh, rules)
    if grad_reduce is None:
        return psh, osh
    efsh = (grad_reduce.state_shardings(psh, mesh)
            if grad_reduce.stateful else None)
    return psh, osh, efsh


def zero_train_state(model: Model, tc: TrainConfig, grad_reduce=None):
    """Zero-filled (params, opt_state) with the exact structure/shape/dtype of
    ``init_train_state`` -- cheap "like" trees for checkpoint restore (no RNG,
    no init math, no model trace).  With a stateful ``grad_reduce`` strategy a
    third tree (the zero EF-residual state) is returned."""
    from repro.param import is_spec

    ps, opt_specs = train_state_specs(model, tc)
    params = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype or model.cfg.param_dtype),
        ps, is_leaf=is_spec)
    opt_state = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), opt_specs, is_leaf=is_spec)
    if grad_reduce is None:
        return params, opt_state
    ef = grad_reduce.init_state(params) if grad_reduce.stateful else None
    return params, opt_state, ef
