"""Public model facade + step builders (train / prefill / decode).

``Model`` wraps a ModelConfig with spec/init/loss/forward entry points used by
the V-cycle runner, the baselines, the launcher and the dry-run.  Step builders
return pure functions suitable for ``jax.jit`` (and ``.lower().compile()``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, TrainConfig
from repro.models import lm as lm_lib
from repro.models import vit as vit_lib
from repro.optim import adamw_init, adamw_init_specs, adamw_update
from repro.param import init_tree


@dataclasses.dataclass
class Model:
    cfg: ModelConfig

    # -- specs / init ------------------------------------------------------
    def specs(self):
        if self.cfg.family == "vit":
            return vit_lib.vit_specs(self.cfg)
        return lm_lib.lm_specs(self.cfg)

    def cache_specs(self, batch: int, max_seq: int):
        return lm_lib.cache_specs(self.cfg, batch, max_seq)

    def paged_cache_specs(self, n_pages: int, page_size: int):
        return lm_lib.paged_cache_specs(self.cfg, n_pages, page_size)

    def init(self, key: jax.Array):
        return init_tree(key, self.specs(), dtype=self.cfg.param_dtype)

    # -- losses ------------------------------------------------------------
    def loss(self, params, batch: Dict[str, jax.Array], z_loss: float = 0.0):
        cfg = self.cfg
        if cfg.family == "vit":
            logits = vit_lib.vit_forward(params, batch["patches"], cfg)
            return vit_lib.vit_loss(logits, batch["labels"])
        out = lm_lib.lm_forward(
            params, batch["tokens"], cfg, mode="train",
            img_embeds=batch.get("img_embeds"), enc_frames=batch.get("enc_frames"))
        mtp_labels = None
        if cfg.mtp_depth:
            lbl = batch["labels"]
            mtp_labels = jnp.concatenate(
                [lbl[:, 1:], jnp.full_like(lbl[:, :1], -1)], axis=1)
        return lm_lib.lm_loss(out["logits"], batch["labels"], cfg, out["aux"],
                              out.get("mtp_logits"), mtp_labels, z_loss)

    def forward_logits(self, params, batch):
        if self.cfg.family == "vit":
            return vit_lib.vit_forward(params, batch["patches"], self.cfg)
        return lm_lib.lm_forward(params, batch["tokens"], self.cfg, mode="train",
                                 img_embeds=batch.get("img_embeds"),
                                 enc_frames=batch.get("enc_frames"))["logits"]


def build_model(cfg: ModelConfig) -> Model:
    if cfg.kernel_backend:
        # fail fast on a typo'd backend instead of mid-training at trace time
        from repro.kernels import dispatch as kdispatch

        kdispatch.validate_backend(cfg.kernel_backend)
    return Model(cfg)


# ---------------------------------------------------------------------------
# step builders


def make_train_step(model: Model, tc: TrainConfig) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    With ``tc.grad_accum > 1`` the batch leaves must have a leading microbatch
    axis of size grad_accum; gradients are accumulated with a scan (activation
    memory divided by grad_accum — the standard TPU pipelining lever).
    """

    def loss_fn(params, micro):
        loss, metrics = model.loss(params, micro, z_loss=tc.z_loss)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    # Per-step FSDP weight pre-gather (MaxText-style): cast the f32 master
    # params to compute dtype ONCE per step with the data-axis sharding
    # dropped -- the all-gather then happens outside the grad-accum loop
    # instead of once per layer *per microbatch* (EXPERIMENTS.md §Perf
    # qwen3-14b iter).  The VJP of the constraint+cast is exactly the f32
    # gradient reduce-scatter back onto the FSDP layout.  Opt-in per arch:
    # the per-device gathered copy is total_bf16/model_shard, too large for
    # the 400B+ models (they keep per-layer gathering).
    if tc.pregather_params:
        from repro.distributed import shard_l
        from repro.param import axes_tree

        p_axes = axes_tree(model.specs())
        no_fsdp = {"embed": None, "embed_cat2": None}

        def pregather(params):
            return jax.tree.map(
                lambda p, ax: shard_l(p.astype(model.cfg.compute_dtype), ax, no_fsdp),
                params, p_axes)
    else:
        pregather = lambda params: params

    def train_step(params, opt_state, batch):
        if tc.pregather_params:
            p_use, pull = jax.vjp(pregather, params)
        else:
            p_use, pull = params, None

        if tc.grad_accum > 1:
            def acc_body(carry, micro):
                g_acc, m_acc = carry
                (_, metrics), grads = grad_fn(p_use, micro)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, grads)
                m_acc = jax.tree.map(lambda a, b: a + b, m_acc, metrics)
                return (g_acc, m_acc), None

            (_, m0), g0 = grad_fn(p_use, jax.tree.map(lambda x: x[0], batch))
            g0 = jax.tree.map(lambda g: g.astype(jnp.float32), g0)
            rest = jax.tree.map(lambda x: x[1:], batch)
            (g_sum, m_sum), _ = jax.lax.scan(acc_body, (g0, m0), rest)
            inv = 1.0 / tc.grad_accum
            grads = jax.tree.map(lambda g: g * inv, g_sum)
            metrics = jax.tree.map(lambda m: m * inv, m_sum)
        else:
            (_, metrics), grads = grad_fn(p_use, batch)
        if pull is not None:
            # one reduce-scatter back onto the FSDP layout per step
            grads = pull(jax.tree.map(
                lambda g, p: g.astype(p.dtype), grads, p_use))[0]
        params, opt_state, om = adamw_update(params, grads, opt_state, tc)
        return params, opt_state, {**metrics, **om}

    return train_step


def make_eval_loss(model: Model) -> Callable:
    def eval_loss(params, batch):
        loss, metrics = model.loss(params, batch)
        return metrics

    return eval_loss


def make_prefill_step(model: Model) -> Callable:
    """prefill_step(params, tokens, [extras]) -> (last_logits, caches)."""
    cfg = model.cfg

    def prefill_step(params, tokens, img_embeds=None, enc_frames=None):
        out = lm_lib.lm_forward(params, tokens, cfg, mode="prefill",
                                img_embeds=img_embeds, enc_frames=enc_frames)
        return out["logits"][:, -1, :], out["caches"]

    return prefill_step


def make_serve_step(model: Model) -> Callable:
    """serve_step(params, caches, tokens [B,1], pos [B]) -> (logits, caches).

    One new token against a KV/state cache of ``max_seq`` (the decode_* and
    long_* assigned shapes lower exactly this function).
    """
    cfg = model.cfg

    def serve_step(params, caches, tokens, pos):
        positions = pos[:, None]
        out = lm_lib.lm_forward(params, tokens, cfg, positions=positions,
                                mode="decode", caches=caches)
        return out["logits"][:, -1, :], out["caches"]

    return serve_step


def make_paged_decode_step(model: Model) -> Callable:
    """step(params, pages, tokens [B,S], positions [B,S], block_tables [B,M])
    -> (last_logits, pages).

    Decode/extend against the shared page pool: each batch row reads and
    writes K/V through its block-table row, so cost scales with the pages a
    request actually occupies, not ``max_seq``.  S==1 is the batched decode
    step; S>1 is the prefix-reuse "extend" step (left-padded rows carry
    positions == -1, which ``paged_write`` routes to the reserved null page).
    """
    cfg = model.cfg

    def paged_decode_step(params, pages, tokens, positions, block_tables):
        out = lm_lib.lm_forward(params, tokens, cfg, positions=positions,
                                mode="decode", caches=pages,
                                block_tables=block_tables)
        return out["logits"][:, -1, :], out["caches"]

    return paged_decode_step


def make_verify_step(model: Model) -> Callable:
    """verify_step(params, pages, tokens [B,S], positions [B,S], block_tables
    [B,M]) -> (logits [B,S,V], pages).

    The speculative-decode verifier: identical forward to
    ``make_paged_decode_step`` (same paged reads/writes through the block
    table) but returning logits at *every* position, so one batched
    full-model step scores a drafted token run d_0..d_k written at positions
    p..p+k.  ``logits[:, i]`` is the full model's next-token distribution
    after the token at ``positions[:, i]`` -- the acceptance rule compares
    ``argmax(logits[:, i])`` against the draft's proposal for position
    ``p+i+1``, and the first disagreement's argmax doubles as the correction
    token, which is what makes greedy speculative decoding lossless.
    Right-padded rows carry ``positions == -1`` (writes routed to the null
    page, attention fully masked); their logits are garbage and unread.
    """
    cfg = model.cfg

    def verify_step(params, pages, tokens, positions, block_tables):
        out = lm_lib.lm_forward(params, tokens, cfg, positions=positions,
                                mode="decode", caches=pages,
                                block_tables=block_tables)
        return out["logits"], out["caches"]

    return verify_step


def init_train_state(model: Model, tc: TrainConfig, key: jax.Array):
    params = model.init(key)
    opt_state = adamw_init(params, tc)
    return params, opt_state


def train_state_specs(model: Model, tc: TrainConfig):
    ps = model.specs()
    return ps, adamw_init_specs(ps, tc)


def train_state_shardings(model: Model, tc: TrainConfig, mesh, rules=None):
    """(param, opt) NamedSharding trees for a model's train state on ``mesh``.

    Derived from the Spec trees (the optimizer mirrors the parameter logical
    axes), so every V-cycle level gets its own layout and a checkpoint written
    under one mesh can be restored onto another by passing these to
    ``CheckpointManager.restore(shardings=...)``.
    """
    from repro.distributed import param_shardings

    ps, opt_specs = train_state_specs(model, tc)
    return param_shardings(ps, mesh, rules), param_shardings(opt_specs, mesh, rules)


def zero_train_state(model: Model, tc: TrainConfig):
    """Zero-filled (params, opt_state) with the exact structure/shape/dtype of
    ``init_train_state`` -- cheap "like" trees for checkpoint restore (no RNG,
    no init math, no model trace)."""
    from repro.param import is_spec

    ps, opt_specs = train_state_specs(model, tc)
    params = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype or model.cfg.param_dtype),
        ps, is_leaf=is_spec)
    opt_state = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), opt_specs, is_leaf=is_spec)
    return params, opt_state
