"""Unified stage-based transformer covering every assigned family:

dense / MoE decoder LMs, hybrid Mamba+attention (Jamba), xLSTM, VLM decoders
with gated cross-attention (Llama-3.2-Vision), and encoder-decoder audio
(Whisper).  Encoder-only (BERT proxy) and ViT reuse the same blocks.

Parameters are stacked per stage-pattern position with a leading "layers"
axis and the forward scans over ``repeats`` -- compact HLO at 61-72 layers and
the axis the paper's depth-coalescing operator acts on.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import BlockSpec, ModelConfig, Stage
from repro.distributed import shard_l
from repro.layers import attention as attn
from repro.layers import ffn as ffn_lib
from repro.layers import ssm
from repro.layers.basic import embed_specs, embed_tokens, norm_apply, norm_specs, unembed
from repro.param import Spec

# ---------------------------------------------------------------------------
# per-block specs


def _stack(tree, n: int):
    def one(s: Spec) -> Spec:
        return Spec((n,) + s.shape, ("layers",) + s.axes, ("-",) + s.roles,
                    init=s.init, scale=s.scale, dtype=s.dtype)

    return jax.tree.map(one, tree, is_leaf=lambda x: isinstance(x, Spec))


def block_specs(cfg: ModelConfig, bs: BlockSpec) -> Dict[str, Any]:
    s: Dict[str, Any] = {}
    mixer = bs.mixer
    if mixer in ("attn", "enc_attn", "dec_attn"):
        s["norm1"] = norm_specs(cfg)
        s["mixer"] = attn.mla_specs(cfg) if cfg.attn_type == "mla" else attn.gqa_specs(cfg)
        if mixer == "dec_attn":
            s["norm_x"] = norm_specs(cfg)
            s["cross"] = attn.cross_attn_specs(cfg, kv_axis="embed")
    elif mixer == "cross_attn":
        s["norm1"] = norm_specs(cfg)
        s["mixer"] = attn.cross_attn_specs(cfg, kv_axis="vision_embed",
                                           kv_dim=cfg.vision_dim or cfg.d_model)
    elif mixer == "mamba":
        s["norm1"] = norm_specs(cfg)
        s["mixer"] = ssm.mamba_specs(cfg)
    elif mixer == "mlstm":
        s["norm1"] = norm_specs(cfg)
        s["mixer"] = ssm.mlstm_specs(cfg)
    elif mixer == "slstm":
        s["norm1"] = norm_specs(cfg)
        s["mixer"] = ssm.slstm_specs(cfg)
    else:
        raise ValueError(f"unknown mixer {mixer}")
    if bs.ffn == "dense":
        s["norm2"] = norm_specs(cfg)
        s["ffn"] = ffn_lib.ffn_specs(cfg)
    elif bs.ffn == "moe":
        s["norm2"] = norm_specs(cfg)
        s["ffn"] = ffn_lib.moe_specs(cfg)
    return s


def block_cache_specs(cfg: ModelConfig, bs: BlockSpec, batch: int, max_seq: int,
                      n_cross_tokens: int = 0) -> Dict[str, Any]:
    c: Dict[str, Any] = {}
    mixer = bs.mixer
    if mixer in ("attn", "dec_attn"):
        c["self"] = (attn.mla_cache_specs(cfg, batch, max_seq) if cfg.attn_type == "mla"
                     else attn.gqa_cache_specs(cfg, batch, max_seq))
        if mixer == "dec_attn":
            c["cross"] = attn.cross_kv_cache_specs(cfg, batch, n_cross_tokens)
    elif mixer == "cross_attn":
        c["cross"] = attn.cross_kv_cache_specs(cfg, batch, n_cross_tokens)
    elif mixer == "mamba":
        c["ssm"] = ssm.mamba_cache_specs(cfg, batch)
    elif mixer == "mlstm":
        c["ssm"] = ssm.mlstm_cache_specs(cfg, batch)
    elif mixer == "slstm":
        c["ssm"] = ssm.slstm_cache_specs(cfg, batch)
    return c


def paged_block_cache_specs(cfg: ModelConfig, bs: BlockSpec, n_pages: int,
                            page_size: int) -> Dict[str, Any]:
    """Block-table layout for the serving page pool.  Only pure self-attention
    blocks page cleanly: SSM state is O(1) (nothing to page) and cross/enc-dec
    K/V is request-global, so those families stay on the slot engine."""
    if bs.mixer != "attn":
        raise NotImplementedError(
            f"paged KV serving supports mixer 'attn' only, got {bs.mixer!r} "
            "(use --engine slots)")
    return {"self": (attn.mla_paged_cache_specs(cfg, n_pages, page_size)
                     if cfg.attn_type == "mla"
                     else attn.gqa_paged_cache_specs(cfg, n_pages, page_size))}


# ---------------------------------------------------------------------------
# per-block apply


def block_apply(
    p: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    bs: BlockSpec,
    *,
    positions: jax.Array,
    mode: str,  # train | prefill | decode
    cache: Optional[Dict] = None,  # required for decode; ignored otherwise
    cross_src: Optional[jax.Array] = None,  # image embeds / encoder output
    block_tables: Optional[jax.Array] = None,  # [B,M]: decode cache is paged
) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """Returns (x, new_cache, moe_aux).  new_cache is None in train mode,
    freshly created in prefill mode, updated in decode mode."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}
    mixer = bs.mixer
    decode = mode == "decode"
    prefill = mode == "prefill"

    if mixer in ("attn", "enc_attn", "dec_attn"):
        h = norm_apply(p["norm1"], x, cfg)
        causal = mixer != "enc_attn"
        self_cache = cache.get("self") if decode else None
        if cfg.attn_type == "mla":
            y, c_new = attn.mla_apply(p["mixer"], h, cfg, positions=positions,
                                      causal=causal, cache=self_cache,
                                      block_tables=block_tables)
        else:
            y, c_new = attn.gqa_apply(p["mixer"], h, cfg, positions=positions,
                                      causal=causal, cache=self_cache,
                                      block_tables=block_tables)
        x = x + y
        if prefill:
            new_cache["self"] = _prefill_self_cache(p["mixer"], h, cfg, positions)
        elif decode:
            new_cache["self"] = c_new
        if mixer == "dec_attn":
            hx = norm_apply(p["norm_x"], x, cfg)
            kv_cache = cache.get("cross") if decode else None
            y = attn.cross_attn_apply(p["cross"], hx, cfg, kv_src=cross_src,
                                      kv_cache=kv_cache, gated=False)
            x = x + y
            if prefill:
                new_cache["cross"] = attn.cross_attn_precompute(p["cross"], cross_src, cfg)
            elif decode:
                new_cache["cross"] = cache["cross"]
    elif mixer == "cross_attn":
        h = norm_apply(p["norm1"], x, cfg)
        kv_cache = cache.get("cross") if decode else None
        y = attn.cross_attn_apply(p["mixer"], h, cfg, kv_src=cross_src,
                                  kv_cache=kv_cache, gated=True)
        x = x + y
        if prefill:
            new_cache["cross"] = attn.cross_attn_precompute(p["mixer"], cross_src, cfg)
        elif decode:
            new_cache["cross"] = cache["cross"]
    elif mixer in ("mamba", "mlstm", "slstm"):
        h = norm_apply(p["norm1"], x, cfg)
        fn = {"mamba": ssm.mamba_apply, "mlstm": ssm.mlstm_apply, "slstm": ssm.slstm_apply}[mixer]
        ssm_cache = cache.get("ssm") if decode else None
        y, c_new = fn(p["mixer"], h, cfg, cache=ssm_cache, return_state=prefill)
        if prefill or decode:
            new_cache["ssm"] = c_new
        x = x + y
    else:
        raise ValueError(mixer)

    if bs.ffn == "dense":
        h = norm_apply(p["norm2"], x, cfg)
        x = x + ffn_lib.ffn_apply(p["ffn"], h, cfg)
    elif bs.ffn == "moe":
        h = norm_apply(p["norm2"], x, cfg)
        y, a = ffn_lib.moe_apply(p["ffn"], h, cfg)
        x = x + y
        aux = aux + a
    return x, (new_cache if (prefill or decode) else None), aux


def _prefill_self_cache(p: Dict, h: jax.Array, cfg: ModelConfig, positions) -> Dict:
    """Recompute the (cheap, linear) K/V projections to fill the decode cache
    after a prefill forward.  For MLA this is the compressed latent cache."""
    from repro.layers.basic import apply_rope, rms_norm

    cdt = cfg.compute_dtype
    if cfg.attn_type == "mla":
        ckv = rms_norm(jnp.einsum("bse,el->bsl", h, p["wkv_a"].astype(cdt)),
                       p["kv_norm"], cfg.norm_eps)
        kpe = apply_rope(jnp.einsum("bse,er->bsr", h, p["wk_rope"].astype(cdt))[:, :, None, :],
                         positions, cfg.rope_theta)[:, :, 0, :]
        return {"ckv": shard_l(ckv, ("batch", "cache_seq", "kv_lora")),
                "kpe": shard_l(kpe, ("batch", "cache_seq", "rope_dim"))}
    k = jnp.einsum("bse,ehd->bshd", h, p["wk"].astype(cdt))
    v = jnp.einsum("bse,ehd->bshd", h, p["wv"].astype(cdt))
    if cfg.use_bias:
        k = k + p["bk"].astype(cdt)
        v = v + p["bv"].astype(cdt)
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    k = apply_rope(k, positions, cfg.rope_theta)
    return {"k": shard_l(k, ("batch", "cache_seq", "cache_kv_heads", "head_dim")),
            "v": shard_l(v, ("batch", "cache_seq", "cache_kv_heads", "head_dim"))}


# ---------------------------------------------------------------------------
# whole-model specs


def encoder_stages(cfg: ModelConfig) -> Tuple[Stage, ...]:
    if not cfg.n_encoder_layers:
        return ()
    return (Stage((BlockSpec("enc_attn", "dense"),), cfg.n_encoder_layers),)


def lm_specs(cfg: ModelConfig) -> Dict[str, Any]:
    s: Dict[str, Any] = {"embed": embed_specs(cfg)}
    s["stages"] = {
        f"stage_{i}": {
            f"b{j}": _stack(block_specs(cfg, bsj), st.repeats)
            for j, bsj in enumerate(st.pattern)
        }
        for i, st in enumerate(cfg.stages)
    }
    s["final_norm"] = norm_specs(cfg)
    if cfg.n_encoder_layers:
        s["encoder"] = {
            "stages": {
                f"stage_{i}": {
                    f"b{j}": _stack(block_specs(cfg, bsj), st.repeats)
                    for j, bsj in enumerate(st.pattern)
                }
                for i, st in enumerate(encoder_stages(cfg))
            },
            "final_norm": norm_specs(cfg),
        }
    if cfg.mtp_depth:
        s["mtp"] = {
            "proj": Spec((2 * cfg.d_model, cfg.d_model), ("embed_cat2", "embed"), ("in", "out"),
                         init="fan_in"),
            "norm_h": norm_specs(cfg),
            "norm_e": norm_specs(cfg),
            "block": block_specs(cfg, BlockSpec("attn", "dense")),
            "final_norm": norm_specs(cfg),
        }
    return s


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> Dict[str, Any]:
    n_cross = cfg.n_image_tokens or cfg.encoder_seq
    return {
        f"stage_{i}": {
            f"b{j}": _stack(block_cache_specs(cfg, bsj, batch, max_seq, n_cross), st.repeats)
            for j, bsj in enumerate(st.pattern)
        }
        for i, st in enumerate(cfg.stages)
    }


def paged_cache_specs(cfg: ModelConfig, n_pages: int, page_size: int) -> Dict[str, Any]:
    """Whole-model page-pool specs: one ``[n_pages, page_size, ...]`` pool per
    stacked layer leaf, shared across requests via per-request block tables.

    Works for any config with attention-only mixers -- including the
    *coalesced* level-1 config, which is how the speculative decode policy
    builds its draft cache: ``paged_cache_specs(coalesce_config(cfg, ml),
    ...)`` gives the half-width pool the drafted tokens stream through
    (``launch/serve.py::SpeculativePolicy``)."""
    return {
        f"stage_{i}": {
            f"b{j}": _stack(paged_block_cache_specs(cfg, bsj, n_pages, page_size),
                            st.repeats)
            for j, bsj in enumerate(st.pattern)
        }
        for i, st in enumerate(cfg.stages)
    }


# ---------------------------------------------------------------------------
# forward


def _remat_wrap(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def run_stages(
    params: Dict,
    stages: Tuple[Stage, ...],
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    mode: str,
    caches: Optional[Dict] = None,  # decode: input caches; prefill: created fresh
    cross_src: Optional[jax.Array] = None,
    block_tables: Optional[jax.Array] = None,  # [B,M]: caches are page pools
) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: Dict[str, Any] = {}
    want_cache = mode in ("prefill", "decode")
    for i, st in enumerate(stages):
        p_st = params[f"stage_{i}"]
        c_st = caches.get(f"stage_{i}") if (caches is not None and mode == "decode") else None

        def body(carry, xs, st=st):
            xx, aux = carry
            p_sl, c_sl = xs
            c_out = {}
            for j, bsj in enumerate(st.pattern):
                cj = c_sl.get(f"b{j}") if c_sl is not None else None
                xx, c_new, a = block_apply(p_sl[f"b{j}"], xx, cfg, bsj,
                                           positions=positions, mode=mode,
                                           cache=cj, cross_src=cross_src,
                                           block_tables=block_tables)
                if c_new is not None:
                    c_out[f"b{j}"] = c_new
                aux = aux + a
            return (xx, aux), (c_out if c_out else 0)

        body = _remat_wrap(body, cfg)
        (x, aux_total), ys = jax.lax.scan(body, (x, aux_total), (p_st, c_st))
        if want_cache:
            new_caches[f"stage_{i}"] = ys
    return x, (new_caches if want_cache else None), aux_total


def lm_forward(
    params: Dict,
    tokens: jax.Array,  # [B,S] int32
    cfg: ModelConfig,
    *,
    positions: Optional[jax.Array] = None,  # [B,S]; default arange
    mode: str = "train",
    caches: Optional[Dict] = None,
    img_embeds: Optional[jax.Array] = None,  # [B,N,E] (vlm stub frontend)
    enc_frames: Optional[jax.Array] = None,  # [B,T,E] (audio stub frontend)
    enc_out: Optional[jax.Array] = None,  # precomputed encoder output (decode)
    # [B,M]: decode caches are paged.  S==1 is batched decode; S>1 with
    # explicit positions is the multi-token paged step shared by the
    # prefix-reuse "extend" path and the speculative verify step (logits at
    # every position score a drafted run; positions == -1 mark padding --
    # writes land on the null page and attention is fully masked).
    block_tables: Optional[jax.Array] = None,
) -> Dict[str, Any]:
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = embed_tokens(params["embed"], tokens, cfg)
    x = shard_l(x, ("batch", "seq", "act_embed"))

    cross_src = None if img_embeds is None else img_embeds.astype(cfg.compute_dtype)
    if cfg.n_encoder_layers and mode != "decode":  # decode reads cross K/V from cache
        if enc_out is None:
            assert enc_frames is not None, "encoder-decoder needs enc_frames or enc_out"
            e = shard_l(enc_frames.astype(cfg.compute_dtype), ("batch", "enc_seq", "act_embed"))
            e_pos = jnp.broadcast_to(jnp.arange(e.shape[1])[None], (B, e.shape[1]))
            e, _, _ = run_stages(params["encoder"]["stages"], encoder_stages(cfg), e, cfg,
                                 positions=e_pos, mode="train")
            enc_out = norm_apply(params["encoder"]["final_norm"], e, cfg)
        cross_src = enc_out

    x, new_caches, aux = run_stages(params["stages"], cfg.stages, x, cfg,
                                    positions=positions, mode=mode, caches=caches,
                                    cross_src=cross_src, block_tables=block_tables)
    x = norm_apply(params["final_norm"], x, cfg)
    logits = unembed(params["embed"], x, cfg)
    logits = shard_l(logits, ("batch", "seq", "act_vocab"))
    out = {"logits": logits, "aux": aux, "caches": new_caches, "enc_out": enc_out}

    if cfg.mtp_depth and mode == "train":
        # DeepSeek-V3 multi-token prediction: one extra block predicting t+2
        # from [h_t ; emb(token_{t+1})].
        mp = params["mtp"]
        emb_next = embed_tokens(params["embed"], jnp.roll(tokens, -1, axis=1), cfg)
        hcat = jnp.concatenate([norm_apply(mp["norm_h"], x, cfg),
                                norm_apply(mp["norm_e"], emb_next, cfg)], axis=-1)
        h2 = jnp.einsum("bsf,fe->bse", hcat, mp["proj"].astype(cfg.compute_dtype))
        h2, _, _ = block_apply(mp["block"], h2, cfg, BlockSpec("attn", "dense"),
                               positions=positions, mode="train")
        h2 = norm_apply(mp["final_norm"], h2, cfg)
        out["mtp_logits"] = unembed(params["embed"], h2, cfg)
    return out


# ---------------------------------------------------------------------------
# losses


def lm_loss(
    logits: jax.Array,  # [B,S,V]
    labels: jax.Array,  # [B,S] int32, -1 = ignore
    cfg: ModelConfig,
    aux: jax.Array = 0.0,
    mtp_logits: Optional[jax.Array] = None,
    mtp_labels: Optional[jax.Array] = None,
    z_loss: float = 0.0,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    def ce(lg, lb):
        # vocab-sharding-friendly CE: take_along_axis over the model-sharded
        # vocab axis would force an f32 logits all-gather (GBs per device at
        # 152k vocab; EXPERIMENTS.md §Perf iter.3).  A one-hot contraction
        # keeps the vocab axis sharded end-to-end (Megatron-style loss).
        lg = lg.astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        onehot = jax.nn.one_hot(jnp.maximum(lb, 0), lg.shape[-1], dtype=jnp.float32)
        onehot = shard_l(onehot, ("batch", "seq", "act_vocab"))
        ll = jnp.einsum("bsv,bsv->bs", lg, onehot)
        mask = (lb >= 0).astype(jnp.float32)
        nll = (lse - ll) * mask
        zl = z_loss * jnp.square(lse) * mask if z_loss else 0.0
        return jnp.sum(nll + zl) / jnp.maximum(jnp.sum(mask), 1.0)

    loss = ce(logits, labels)
    metrics = {"ce": loss}
    if mtp_logits is not None and mtp_labels is not None:
        mtp = ce(mtp_logits, mtp_labels)
        loss = loss + cfg.mtp_loss_weight * mtp
        metrics["mtp_ce"] = mtp
    if cfg.n_experts:
        loss = loss + cfg.router_aux_coef * aux
        metrics["moe_aux"] = aux
    metrics["loss"] = loss
    return loss, metrics
