"""Norms, activations, RoPE, embeddings."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.param import Spec


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# norms


def norm_specs(cfg: ModelConfig, axis: str = "embed", dim: int = 0) -> Dict[str, Spec]:
    d = dim or cfg.d_model
    out = {"scale": Spec((d,), (axis,), ("out",), init="ones")}
    if cfg.norm == "layernorm":
        out["bias"] = Spec((d,), (axis,), ("out",), init="zeros")
    return out


def norm_apply(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    return y.astype(dt)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE


def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D] (D even), positions broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # [D/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos = jnp.cos(ang)[..., None, :]  # [..., S, 1, D/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings


def embed_specs(cfg: ModelConfig) -> Dict[str, Spec]:
    out = {"tok": Spec((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"), ("-", "out"), init="embed")}
    if not cfg.tie_embeddings:
        out["head"] = Spec((cfg.d_model, cfg.padded_vocab), ("embed", "vocab"), ("in", "-"), init="fan_in")
    return out


def embed_tokens(p: Dict, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    w = p["tok"].astype(cfg.compute_dtype)
    return jnp.take(w, tokens, axis=0)


def unembed(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        w = p["tok"].astype(cfg.compute_dtype)
        return jnp.einsum("bse,ve->bsv", x, w)
    w = p["head"].astype(cfg.compute_dtype)
    return jnp.einsum("bse,ev->bsv", x, w)


def pos_embed_specs(max_seq: int, cfg: ModelConfig, axis: str = "seq") -> Dict[str, Spec]:
    return {"pos": Spec((max_seq, cfg.d_model), (axis, "embed"), ("-", "out"), init="normal", scale=0.02)}
