"""Attention: GQA (opt. qk-norm), MLA (DeepSeek-V3, absorbed decode), cross-attn.

Three core computations:
  * ``plain_attention``    - materialized scores (decode / small seq)
  * ``blockwise_attention``- online-softmax scan over KV blocks (O(S) memory;
                             rectangular work, also for non-causal)
  * ``pairs_attention``    - causal, FLOP-exact: scans only the lower-triangular
                             (q-block, k-block) pairs.  Used for long prefill and
                             available for training (perf lever, see EXPERIMENTS).

``attn_impl="pallas"`` additionally dispatches train/prefill attention through
the kernel registry (repro.kernels.dispatch) to the Pallas flash kernels --
forward AND backward (custom VJP) -- with the XLA flash recipe below as the
fallback for shapes the tiling cannot cover.  Both flash paths assume query
positions 0..S-1 (train/prefill); decode uses plain attention.

All attention math runs in fp32 softmax with bf16 matmul inputs (TPU MXU style).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed import shard_l
from repro.kernels import dispatch as kdispatch
from repro.layers.basic import apply_rope, rms_norm
from repro.param import Spec

NEG_INF = -1e30


def paged_write(pages: jax.Array, new: jax.Array, positions: jax.Array,
                block_tables: jax.Array) -> jax.Array:
    """Scatter ``new`` [B,S,...] into ``pages`` [N,P,...] at absolute
    ``positions`` [B,S] routed through per-sequence ``block_tables`` [B,M].

    Touches only the pages the written tokens land in -- admission/decode
    cost scales with the request, not with the pool.  Position -1 marks a
    padding slot (bucketed extend steps left-pad); its write is routed to
    page 0, the pool's reserved null page that no request ever owns.
    """
    P = pages.shape[1]
    valid = positions >= 0
    pos = jnp.maximum(positions, 0)
    page_ix = jnp.minimum(pos // P, block_tables.shape[1] - 1)
    pid = jnp.take_along_axis(block_tables, page_ix, axis=1)
    pid = jnp.where(valid, pid, 0)
    off = jnp.where(valid, pos % P, 0)
    return pages.at[pid, off].set(new.astype(pages.dtype))


def seq_masked_write(cache: jax.Array, new: jax.Array, pos: jax.Array) -> jax.Array:
    """Write ``new`` [B,1,...] into ``cache`` [B,T,...] at per-example ``pos``.

    A dynamic_update_slice at a data-dependent index on the SEQUENCE-SHARDED
    cache axis forces GSPMD to all-gather the whole cache every decode step
    (the baseline deepseek-v3 decode_32k bottleneck: 161 GB/step of AG --
    EXPERIMENTS.md §Perf).  A masked select is elementwise, so every shard
    updates (or not) its own slice locally: zero collectives, one local
    read+write pass over the cache shard.
    """
    T = cache.shape[1]
    mask = jnp.arange(T)[None, :] == pos[:, None]  # [B,T]
    mask = mask.reshape(mask.shape + (1,) * (cache.ndim - 2))
    return jnp.where(mask, new.astype(cache.dtype), cache)


# ---------------------------------------------------------------------------
# core attention computations


def _mask(qp: jax.Array, tp: jax.Array, causal: bool) -> jax.Array:
    """qp: [B,S] query positions, tp: [T] key positions -> [B,S,T] bool."""
    if not causal:
        return jnp.ones(qp.shape + (tp.shape[0],), bool)
    return tp[None, None, :] <= qp[:, :, None]


def plain_attention(q, k, v, *, causal: bool, scale: float, q_positions=None) -> jax.Array:
    """q: [B,S,KH,G,Dq], k: [B,T,KH,Dq], v: [B,T,KH,Dv] -> [B,S,KH,G,Dv]."""
    B, S, KH, G, Dq = q.shape
    T = k.shape[1]
    s = jnp.einsum("bskgd,btkd->bkgst", q, k, preferred_element_type=jnp.float32) * scale
    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    m = _mask(q_positions, jnp.arange(T), causal)  # [B,S,T]
    s = jnp.where(m[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgst,btkv->bskgv", p.astype(v.dtype), v)


def blockwise_attention(q, k, v, *, causal: bool, scale: float, block_k: int,
                        q_positions=None) -> jax.Array:
    """Online-softmax over KV blocks (rectangular; works for any mask)."""
    B, S, KH, G, Dq = q.shape
    T = k.shape[1]
    bk = min(block_k, T)
    if T % bk:  # pad keys to a multiple of bk; padded keys are masked out
        pad = bk - T % bk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nT = k.shape[1]
    nb = nT // bk
    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    kb = k.reshape(B, nb, bk, KH, Dq).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, bk, KH, -1).transpose(1, 0, 2, 3, 4)
    t0s = jnp.arange(nb) * bk

    qf = q.astype(jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        kblk, vblk, t0 = xs
        s = jnp.einsum("bskgd,btkd->bskgt", qf, kblk.astype(jnp.float32)) * scale
        tp = t0 + jnp.arange(bk)
        valid = tp[None, None, :] < T
        if causal:
            valid = valid & (tp[None, None, :] <= q_positions[:, :, None])
        s = jnp.where(valid[:, :, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        corr = jnp.exp(m - m_new)
        l_new = corr * l + jnp.sum(p, axis=-1)
        acc_new = corr[..., None] * acc + jnp.einsum(
            "bskgt,btkv->bskgv", p, vblk.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    Dv = v.shape[-1]
    init = (
        jnp.full((B, S, KH, G), NEG_INF, jnp.float32),
        jnp.zeros((B, S, KH, G), jnp.float32),
        jnp.zeros((B, S, KH, G, Dv), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(body, init, (kb, vb, t0s))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def pairs_attention(q, k, v, *, scale: float, block: int) -> jax.Array:
    """Causal FLOP-exact attention: scan over lower-triangular block pairs.

    Requires S == T and S % block == 0 (configs guarantee it for train/prefill).
    """
    B, S, KH, G, Dq = q.shape
    T = k.shape[1]
    assert S == T and S % block == 0, (S, T, block)
    nq = S // block
    Dv = v.shape[-1]
    qc = q.reshape(B, nq, block, KH, G, Dq).astype(jnp.float32)
    kc = k.reshape(B, nq, block, KH, Dq)
    vc = v.reshape(B, nq, block, KH, Dv)
    qi = jnp.concatenate([jnp.full((i + 1,), i, jnp.int32) for i in range(nq)])
    ki = jnp.concatenate([jnp.arange(i + 1, dtype=jnp.int32) for i in range(nq)])

    pos_in_block = jnp.arange(block)

    def body(carry, xs):
        m, l, acc = carry  # m,l: [B,nq,block,KH,G]; acc: [...,Dv]
        i, j = xs
        qi_blk = jax.lax.dynamic_index_in_dim(qc, i, axis=1, keepdims=False)
        ki_blk = jax.lax.dynamic_index_in_dim(kc, j, axis=1, keepdims=False)
        vi_blk = jax.lax.dynamic_index_in_dim(vc, j, axis=1, keepdims=False)
        s = jnp.einsum("bskgd,btkd->bskgt", qi_blk, ki_blk.astype(jnp.float32)) * scale
        # mask only needed on the diagonal block (i == j)
        diag = (i == j)
        qp = i * block + pos_in_block
        tp = j * block + pos_in_block
        allow = jnp.where(diag, tp[None, :] <= qp[:, None], True)
        s = jnp.where(allow[None, :, None, None, :], s, -jnp.inf)
        m_i = jax.lax.dynamic_index_in_dim(m, i, axis=1, keepdims=False)
        l_i = jax.lax.dynamic_index_in_dim(l, i, axis=1, keepdims=False)
        a_i = jax.lax.dynamic_index_in_dim(acc, i, axis=1, keepdims=False)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        corr = jnp.exp(m_i - m_new)
        l_new = corr * l_i + jnp.sum(p, axis=-1)
        a_new = corr[..., None] * a_i + jnp.einsum(
            "bskgt,btkv->bskgv", p, vi_blk.astype(jnp.float32))
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, axis=1)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, axis=1)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, i, axis=1)
        return (m, l, acc), None

    init = (
        jnp.full((B, nq, block, KH, G), NEG_INF, jnp.float32),
        jnp.zeros((B, nq, block, KH, G), jnp.float32),
        jnp.zeros((B, nq, block, KH, G, Dv), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(body, init, (qi, ki))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, S, KH, G, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# custom-VJP flash-style attention for the XLA path
#
# A plain differentiated blockwise/pairs scan stores (or carries cotangents
# for) O(S^2)-adjacent intermediates; the baseline dry-run measured 15-60 GB
# of per-device temp on every train_4k cell from exactly this (EXPERIMENTS.md
# §Perf iter.1).  The custom VJP saves only (q, k, v, out, lse) and recomputes
# probabilities per KV block in the backward -- the flash-attention recipe,
# expressed in jnp so it lowers for any backend (the Pallas kernel is the TPU
# runtime fast path; this is the same algorithm at the XLA level).


def _fa_fwd_scan(q, k, v, *, causal: bool, scale: float, block_k: int):
    """Returns (out [B,S,KH,G,Dv], lse [B,S,KH,G]).  Query positions are
    0..S-1 (train/prefill); decode uses plain attention."""
    B, S, KH, G, Dq = q.shape
    q_positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    T = k.shape[1]
    bk = min(block_k, T)
    pad = (-T) % bk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nb = k.shape[1] // bk
    kb = k.reshape(B, nb, bk, KH, Dq).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, bk, KH, -1).transpose(1, 0, 2, 3, 4)
    qf = q.astype(jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        kblk, vblk, t0 = xs
        s = jnp.einsum("bskgd,btkd->bskgt", qf, kblk.astype(jnp.float32)) * scale
        tp = t0 + jnp.arange(bk)
        valid = tp[None, None, :] < T
        if causal:
            valid = valid & (tp[None, None, :] <= q_positions[:, :, None])
        s = jnp.where(valid[:, :, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        corr = jnp.exp(m - m_new)
        l_new = corr * l + jnp.sum(p, axis=-1)
        acc_new = corr[..., None] * acc + jnp.einsum(
            "bskgt,btkv->bskgv", p, vblk.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    Dv = v.shape[-1]
    init = (jnp.full((B, S, KH, G), NEG_INF, jnp.float32),
            jnp.zeros((B, S, KH, G), jnp.float32),
            jnp.zeros((B, S, KH, G, Dv), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init, (kb, vb, jnp.arange(nb) * bk))
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_xla(q, k, v, causal: bool, scale: float, block_k: int):
    out, _ = _fa_fwd_scan(q, k, v, causal=causal, scale=scale, block_k=block_k)
    return out


def _flash_xla_fwd(q, k, v, causal, scale, block_k):
    out, lse = _fa_fwd_scan(q, k, v, causal=causal, scale=scale, block_k=block_k)
    return out, (q, k, v, out, lse)


def _flash_xla_bwd(causal, scale, block_k, res, do):
    q, k, v, out, lse = res
    B, S, KH, G, Dq = q.shape
    q_positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    T = k.shape[1]
    Dv = v.shape[-1]
    bk = min(block_k, T)
    pad = (-T) % bk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else v
    nb = kp.shape[1] // bk
    kb = kp.reshape(B, nb, bk, KH, Dq).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(B, nb, bk, KH, Dv).transpose(1, 0, 2, 3, 4)
    qf = q.astype(jnp.float32)
    dof = do.astype(jnp.float32)
    # D_i = sum_v do*out  (rowwise correction term of the flash backward)
    Dterm = jnp.sum(dof * out.astype(jnp.float32), axis=-1)  # [B,S,KH,G]

    def body(dq_acc, xs):
        kblk, vblk, t0 = xs
        s = jnp.einsum("bskgd,btkd->bskgt", qf, kblk.astype(jnp.float32)) * scale
        tp = t0 + jnp.arange(bk)
        valid = tp[None, None, :] < T
        if causal:
            valid = valid & (tp[None, None, :] <= q_positions[:, :, None])
        s = jnp.where(valid[:, :, None, None, :], s, -jnp.inf)
        p = jnp.exp(s - lse[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)  # [B,S,KH,G,bk]
        dv_b = jnp.einsum("bskgt,bskgv->btkv", p, dof)
        dp = jnp.einsum("bskgv,btkv->bskgt", dof, vblk.astype(jnp.float32))
        ds = p * (dp - Dterm[..., None]) * scale
        dq_acc = dq_acc + jnp.einsum("bskgt,btkd->bskgd", ds, kblk.astype(jnp.float32))
        dk_b = jnp.einsum("bskgt,bskgd->btkd", ds, qf)
        return dq_acc, (dk_b, dv_b)

    dq0 = jnp.zeros((B, S, KH, G, Dq), jnp.float32)
    dq, (dk_b, dv_b) = jax.lax.scan(body, dq0, (kb, vb, jnp.arange(nb) * bk))
    dk = dk_b.transpose(1, 0, 2, 3, 4).reshape(B, nb * bk, KH, Dq)[:, :T]
    dv = dv_b.transpose(1, 0, 2, 3, 4).reshape(B, nb * bk, KH, Dv)[:, :T]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_xla.defvjp(_flash_xla_fwd, _flash_xla_bwd)


def _largest_divisor(n: int, pref: int) -> int:
    b = min(pref, n)
    while n % b:
        b -= 1
    return b


def _flash_pallas(q, k, v, *, causal: bool, scale: float, bq: int, bk: int,
                  backend: str) -> jax.Array:
    """Adapter from the layer layout [B,S,KH,G,D] to the kernel's [B,H,S,D].

    GQA KV is broadcast over the query groups BEFORE the custom-VJP boundary:
    the kernel then sees matched head counts, and the group-sum of dk/dv falls
    out of the broadcast's own VJP (no GQA logic inside the kernel).
    """
    B, S, KH, G, Dq = q.shape
    T = k.shape[1]
    Dv = v.shape[-1]
    qh = q.transpose(0, 2, 3, 1, 4).reshape(B, KH * G, S, Dq)
    kh = jnp.broadcast_to(k.transpose(0, 2, 1, 3)[:, :, None],
                          (B, KH, G, T, Dq)).reshape(B, KH * G, T, Dq)
    vh = jnp.broadcast_to(v.transpose(0, 2, 1, 3)[:, :, None],
                          (B, KH, G, T, Dv)).reshape(B, KH * G, T, Dv)
    out = kdispatch.get_impl("flash_attention", backend)(
        qh, kh, vh, causal=causal, scale=scale, block_q=bq, block_k=bk)
    return out.reshape(B, KH, G, S, Dv).transpose(0, 3, 1, 2, 4)


def run_attention(q, k, v, cfg: ModelConfig, *, causal: bool, scale: float,
                  q_positions=None, decode: bool = False) -> jax.Array:
    S, T = q.shape[1], k.shape[1]
    impl = cfg.attn_impl
    if decode or S <= 128 or T <= cfg.attn_block_k:
        return plain_attention(q, k, v, causal=causal, scale=scale, q_positions=q_positions)
    if impl == "pairs" and causal and S == T and S % cfg.attn_block_k == 0:
        # FLOP-exact causal (lower-triangular block pairs); best for no-grad
        # prefill where the rectangular fwd would waste ~2x attention FLOPs.
        return pairs_attention(q, k, v, scale=scale, block=cfg.attn_block_k)
    if impl == "pallas":
        # genuine Pallas dispatch (fwd + custom-VJP bwd kernels): Mosaic on
        # TPU, the interpreter off-TPU unless the config/env pins "xla".
        backend = kdispatch.resolve_backend(
            "flash_attention", cfg.kernel_backend or None, default="pallas")
        bq = _largest_divisor(S, 128)
        bk = _largest_divisor(T, min(cfg.attn_block_k, 128))
        tileable = bq >= 8 and bk >= 8 and (not causal or S == T)
        if backend != "xla" and tileable:
            return _flash_pallas(q, k, v, causal=causal, scale=scale,
                                 bq=bq, bk=bk, backend=backend)
        # fall through: the XLA flash recipe below is the same algorithm
    if impl in ("blockwise", "pallas", "pairs"):
        # memory-optimal custom-VJP path (flash recipe at the XLA level)
        return flash_xla(q, k, v, causal, scale, cfg.attn_block_k)
    return plain_attention(q, k, v, causal=causal, scale=scale, q_positions=q_positions)


# ---------------------------------------------------------------------------
# GQA layer


def gqa_specs(cfg: ModelConfig) -> Dict[str, Spec]:
    E, H, KH, D = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    s = {
        "wq": Spec((E, H, D), ("embed", "heads", "head_dim"), ("in", "out", "-"), init="fan_in"),
        "wk": Spec((E, KH, D), ("embed", "kv_heads", "head_dim"), ("in", "out", "-"), init="fan_in"),
        "wv": Spec((E, KH, D), ("embed", "kv_heads", "head_dim"), ("in", "out", "-"), init="fan_in"),
        "wo": Spec((H, D, E), ("heads", "head_dim", "embed"), ("in", "-", "out"), init="fan_in"),
    }
    if cfg.qk_norm:
        s["q_norm"] = Spec((D,), ("head_dim",), ("-",), init="ones")
        s["k_norm"] = Spec((D,), ("head_dim",), ("-",), init="ones")
    if cfg.use_bias:
        s["bq"] = Spec((H, D), ("heads", "head_dim"), ("out", "-"), init="zeros")
        s["bk"] = Spec((KH, D), ("kv_heads", "head_dim"), ("out", "-"), init="zeros")
        s["bv"] = Spec((KH, D), ("kv_heads", "head_dim"), ("out", "-"), init="zeros")
        s["bo"] = Spec((E,), ("embed",), ("out",), init="zeros")
    return s


def gqa_cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> Dict[str, Spec]:
    KH, D = cfg.n_kv_heads, cfg.resolved_head_dim
    ax = ("batch", "cache_seq", "cache_kv_heads", "head_dim")
    dt = cfg.compute_dtype
    return {
        "k": Spec((batch, max_seq, KH, D), ax, init="zeros", dtype=dt),
        "v": Spec((batch, max_seq, KH, D), ax, init="zeros", dtype=dt),
    }


def gqa_paged_cache_specs(cfg: ModelConfig, n_pages: int, page_size: int) -> Dict[str, Spec]:
    """Page-pool K/V leaves: ``[n_pages, page_size, KH, D]`` shared across all
    sequences (block tables route each sequence to its pages)."""
    KH, D = cfg.n_kv_heads, cfg.resolved_head_dim
    ax = ("pages", "page_seq", "cache_kv_heads", "head_dim")
    dt = cfg.compute_dtype
    return {
        "k": Spec((n_pages, page_size, KH, D), ax, init="zeros", dtype=dt),
        "v": Spec((n_pages, page_size, KH, D), ax, init="zeros", dtype=dt),
    }


def _paged_gqa_attention(qg, cache_k, cache_v, cfg: ModelConfig, *,
                         positions: jax.Array, block_tables: jax.Array,
                         scale: float) -> jax.Array:
    """qg: [B,S,KH,G,D] against paged K/V [N,P,KH,D] -> [B,S,KH,G,D].

    S == 1 (decode) dispatches to the registered ``paged_attention_decode``
    op; S > 1 (prefix-extend prefill) gathers the table's pages and runs the
    plain masked attention -- either way, work scales with M*P (the pages the
    batch actually spans), not with the server-wide max_seq.
    """
    B, S = qg.shape[:2]
    P = cache_k.shape[1]
    M = block_tables.shape[1]
    if S == 1:
        lengths = positions[:, -1] + 1  # the just-written token is attendable
        backend = kdispatch.resolve_backend(
            "paged_attention_decode", cfg.kernel_backend or None,
            default="pallas" if cfg.attn_impl == "pallas" else None)
        out = kdispatch.get_impl("paged_attention_decode", backend)(
            qg[:, 0], cache_k, cache_v, block_tables, lengths, scale=scale)
        return out[:, None]
    k = cache_k[block_tables].reshape(B, M * P, *cache_k.shape[2:])
    v = cache_v[block_tables].reshape(B, M * P, *cache_v.shape[2:])
    return plain_attention(qg, k, v, causal=True, scale=scale,
                           q_positions=positions)


def gqa_apply(
    p: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,  # [B,S] absolute positions (rope + causal mask)
    causal: bool,
    use_rope: bool = True,
    cache: Optional[Dict] = None,
    block_tables: Optional[jax.Array] = None,  # [B,M]: cache is paged
) -> Tuple[jax.Array, Optional[Dict]]:
    B, S, E = x.shape
    H, KH, D = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    cdt = cfg.compute_dtype
    q = jnp.einsum("bse,ehd->bshd", x, p["wq"].astype(cdt))
    k = jnp.einsum("bse,ehd->bshd", x, p["wk"].astype(cdt))
    v = jnp.einsum("bse,ehd->bshd", x, p["wv"].astype(cdt))
    if cfg.use_bias:
        q = q + p["bq"].astype(cdt)
        k = k + p["bk"].astype(cdt)
        v = v + p["bv"].astype(cdt)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    # context parallelism: when heads don't divide the model axis (qwen3-14b:
    # 40 heads, whisper: 20), shard the query/output SEQUENCE instead -- each
    # shard attends to the full (replicated) K/V; no attention collectives.
    q_seq_ax = "attn_seq" if (cfg.attn_seq_shard and cache is None) else "seq"
    q = shard_l(q, ("batch", q_seq_ax, "act_heads", "head_dim"))
    k = shard_l(k, ("batch", "seq", "act_kv_heads", "head_dim"))
    v = shard_l(v, ("batch", "seq", "act_kv_heads", "head_dim"))

    new_cache = None
    if cache is not None and block_tables is not None:
        # paged decode/extend: write the new tokens' K/V into their pages,
        # then attend through the block table (single-host serving path --
        # the pool is not mesh-sharded, so no shard_l constraints here)
        ck = paged_write(cache["k"], k, positions, block_tables)
        cv = paged_write(cache["v"], v, positions, block_tables)
        qg = q.reshape(B, S, KH, H // KH, D)
        out = _paged_gqa_attention(qg, ck, cv, cfg, positions=positions,
                                   block_tables=block_tables, scale=D ** -0.5)
        out = out.reshape(B, S, H, D)
        y = jnp.einsum("bshd,hde->bse", out, p["wo"].astype(cdt))
        if cfg.use_bias:
            y = y + p["bo"].astype(cdt)
        return y, {"k": ck, "v": cv}
    if cache is not None:
        pos0 = positions[:, 0]  # [B] write offsets
        ck = seq_masked_write(cache["k"], k, pos0)
        cv = seq_masked_write(cache["v"], v, pos0)
        ck = shard_l(ck, ("batch", "cache_seq", "cache_kv_heads", "head_dim"))
        cv = shard_l(cv, ("batch", "cache_seq", "cache_kv_heads", "head_dim"))
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv

    qg = q.reshape(B, S, KH, H // KH, D)
    scale = D ** -0.5
    out = run_attention(qg, k, v, cfg, causal=causal, scale=scale,
                        q_positions=positions, decode=cache is not None)
    out = out.reshape(B, S, H, D)
    out = shard_l(out, ("batch", q_seq_ax, "act_heads", "head_dim"))
    y = jnp.einsum("bshd,hde->bse", out, p["wo"].astype(cdt))
    if cfg.use_bias:
        y = y + p["bo"].astype(cdt)
    y = shard_l(y, ("batch", "seq", "act_embed"))
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA layer (DeepSeek-V3)


def mla_specs(cfg: ModelConfig) -> Dict[str, Spec]:
    E, H = cfg.d_model, cfg.n_heads
    ql, kl = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope_d, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    return {
        "wq_a": Spec((E, ql), ("embed", "q_lora"), ("in", "out"), init="fan_in"),
        "q_norm": Spec((ql,), ("q_lora",), ("out",), init="ones"),
        "wq_b": Spec((ql, H, nope + rope_d), ("q_lora", "heads", "head_dim"),
                     ("in", "out", "-"), init="fan_in"),
        "wkv_a": Spec((E, kl), ("embed", "kv_lora"), ("in", "out"), init="fan_in"),
        "wk_rope": Spec((E, rope_d), ("embed", "rope_dim"), ("in", "-"), init="fan_in"),
        "kv_norm": Spec((kl,), ("kv_lora",), ("out",), init="ones"),
        "wkv_b": Spec((kl, H, nope + vd), ("kv_lora", "heads", "head_dim"),
                      ("in", "out", "-"), init="fan_in"),
        "wo": Spec((H, vd, E), ("heads", "v_head_dim", "embed"), ("in", "-", "out"),
                   init="fan_in"),
    }


def mla_cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> Dict[str, Spec]:
    dt = cfg.compute_dtype
    return {
        "ckv": Spec((batch, max_seq, cfg.kv_lora_rank), ("batch", "cache_seq", "kv_lora"),
                    init="zeros", dtype=dt),
        "kpe": Spec((batch, max_seq, cfg.qk_rope_head_dim), ("batch", "cache_seq", "rope_dim"),
                    init="zeros", dtype=dt),
    }


def mla_paged_cache_specs(cfg: ModelConfig, n_pages: int, page_size: int) -> Dict[str, Spec]:
    """Paged compressed-latent cache: the MLA analogue of the K/V page pool
    (the latent + rope strips are what absorbed decode actually reads)."""
    dt = cfg.compute_dtype
    return {
        "ckv": Spec((n_pages, page_size, cfg.kv_lora_rank),
                    ("pages", "page_seq", "kv_lora"), init="zeros", dtype=dt),
        "kpe": Spec((n_pages, page_size, cfg.qk_rope_head_dim),
                    ("pages", "page_seq", "rope_dim"), init="zeros", dtype=dt),
    }


def mla_apply(
    p: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    causal: bool = True,
    cache: Optional[Dict] = None,
    block_tables: Optional[jax.Array] = None,  # [B,M]: cache is paged
) -> Tuple[jax.Array, Optional[Dict]]:
    B, S, E = x.shape
    H = cfg.n_heads
    nope, rope_d, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    cdt = cfg.compute_dtype
    scale = (nope + rope_d) ** -0.5

    cq = rms_norm(jnp.einsum("bse,eq->bsq", x, p["wq_a"].astype(cdt)), p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsq,qhd->bshd", cq, p["wq_b"].astype(cdt))
    qn, qp = q[..., :nope], q[..., nope:]
    qp = apply_rope(qp, positions, cfg.rope_theta)
    # decode: the model axis belongs to the seq-sharded latent cache; sharding
    # q by heads too would force a 268MB/layer cache all-gather (the baseline
    # deepseek decode_32k bottleneck -- EXPERIMENTS.md §Perf).  Queries are
    # tiny; replicate them over model and let the scores/ctx contractions
    # reduce over the sharded cache sequence instead.
    head_ax = "seq" if cache is not None else "act_heads"
    q = shard_l(jnp.concatenate([qn, qp], -1), ("batch", "seq", head_ax, "head_dim"))

    ckv = rms_norm(jnp.einsum("bse,el->bsl", x, p["wkv_a"].astype(cdt)), p["kv_norm"], cfg.norm_eps)
    kpe = apply_rope(jnp.einsum("bse,er->bsr", x, p["wk_rope"].astype(cdt))[:, :, None, :],
                     positions, cfg.rope_theta)[:, :, 0, :]

    if cache is None:
        # training / prefill: expand per-head K,V and run standard attention
        kv = jnp.einsum("bsl,lhd->bshd", ckv, p["wkv_b"].astype(cdt))
        kn, v = kv[..., :nope], kv[..., nope:]
        k = jnp.concatenate([kn, jnp.broadcast_to(kpe[:, :, None, :], (B, S, H, rope_d))], -1)
        k = shard_l(k, ("batch", "seq", "act_heads", "head_dim"))
        v = shard_l(v, ("batch", "seq", "act_heads", "head_dim"))
        qg = q[:, :, :, None, :]  # KH == H, G == 1
        out = run_attention(qg, k, v, cfg, causal=causal, scale=scale, q_positions=positions)
        out = out[:, :, :, 0, :]
        new_cache = None
    else:
        # absorbed decode: score and combine in the compressed latent space
        if block_tables is not None:
            # paged: latent/rope strips live in a shared page pool; reassemble
            # this batch's rows by gathering through the block table.  tp below
            # is then the logical position (table slot i covers [i*P,(i+1)*P)),
            # so the existing position mask also hides table padding (page 0).
            # Single-host serving path -- no shard_l on the pool.
            cc = paged_write(cache["ckv"], ckv, positions, block_tables)
            ck = paged_write(cache["kpe"], kpe, positions, block_tables)
            new_cache = {"ckv": cc, "kpe": ck}
            M, P = block_tables.shape[1], cc.shape[1]
            cc = cc[block_tables].reshape(B, M * P, cc.shape[-1])
            ck = ck[block_tables].reshape(B, M * P, ck.shape[-1])
        else:
            pos0 = positions[:, 0]
            cc = seq_masked_write(cache["ckv"], ckv, pos0)
            ck = seq_masked_write(cache["kpe"], kpe, pos0)
            cc = shard_l(cc, ("batch", "cache_seq", "kv_lora"))
            ck = shard_l(ck, ("batch", "cache_seq", "rope_dim"))
            new_cache = {"ckv": cc, "kpe": ck}
        wk_b = p["wkv_b"].astype(cdt)[..., :nope]  # [kl,H,nope]
        wv_b = p["wkv_b"].astype(cdt)[..., nope:]  # [kl,H,vd]
        q_eff = jnp.einsum("bshn,lhn->bshl", qn, wk_b)
        s = jnp.einsum("bshl,btl->bhst", q_eff.astype(jnp.float32), cc.astype(jnp.float32))
        s = s + jnp.einsum("bshr,btr->bhst", qp.astype(jnp.float32), ck.astype(jnp.float32))
        s = s * scale
        tp = jnp.arange(cc.shape[1])
        mask = tp[None, None, :] <= positions[:, :, None]  # [B,S,T]
        s = jnp.where(mask[:, None, :, :], s, NEG_INF)
        prob = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bhst,btl->bshl", prob.astype(cdt), cc)
        out = jnp.einsum("bshl,lhv->bshv", ctx, wv_b)

    y = jnp.einsum("bshv,hve->bse", out, p["wo"].astype(cdt))
    y = shard_l(y, ("batch", "seq", "act_embed"))
    return y, new_cache


# ---------------------------------------------------------------------------
# cross attention (VLM image layers, enc-dec decoder)


def cross_attn_specs(cfg: ModelConfig, kv_axis: str = "embed", kv_dim: int = 0) -> Dict[str, Spec]:
    E, H, KH, D = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    kvd = kv_dim or E
    kv_role = "in" if kv_axis == "embed" else "-"
    return {
        "wq": Spec((E, H, D), ("embed", "heads", "head_dim"), ("in", "out", "-"), init="fan_in"),
        "wk": Spec((kvd, KH, D), (kv_axis, "kv_heads", "head_dim"), (kv_role, "out", "-"), init="fan_in"),
        "wv": Spec((kvd, KH, D), (kv_axis, "kv_heads", "head_dim"), (kv_role, "out", "-"), init="fan_in"),
        "wo": Spec((H, D, E), ("heads", "head_dim", "embed"), ("in", "-", "out"), init="fan_in"),
        "gate": Spec((1,), ("mtp",), ("-",), init="zeros"),  # tanh-gated residual (llama-vision)
    }


def cross_kv_cache_specs(cfg: ModelConfig, batch: int, n_kv_tokens: int) -> Dict[str, Spec]:
    KH, D = cfg.n_kv_heads, cfg.resolved_head_dim
    ax = ("batch", "img_seq", "cache_kv_heads", "head_dim")
    dt = cfg.compute_dtype
    return {
        "ck": Spec((batch, n_kv_tokens, KH, D), ax, init="zeros", dtype=dt),
        "cv": Spec((batch, n_kv_tokens, KH, D), ax, init="zeros", dtype=dt),
    }


def cross_attn_precompute(p: Dict, kv_src: jax.Array, cfg: ModelConfig) -> Dict:
    cdt = cfg.compute_dtype
    k = jnp.einsum("bte,ehd->bthd", kv_src, p["wk"].astype(cdt))
    v = jnp.einsum("bte,ehd->bthd", kv_src, p["wv"].astype(cdt))
    return {"ck": k, "cv": v}


def cross_attn_apply(
    p: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    kv_src: Optional[jax.Array] = None,  # [B,T,kv_dim] (train path)
    kv_cache: Optional[Dict] = None,  # precomputed k/v (decode path)
    gated: bool = True,
) -> jax.Array:
    B, S, E = x.shape
    H, KH, D = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    cdt = cfg.compute_dtype
    q = jnp.einsum("bse,ehd->bshd", x, p["wq"].astype(cdt))
    if kv_cache is not None:
        k, v = kv_cache["ck"], kv_cache["cv"]
    else:
        kv = cross_attn_precompute(p, kv_src, cfg)
        k, v = kv["ck"], kv["cv"]
    qg = q.reshape(B, S, KH, H // KH, D)
    out = run_attention(qg, k, v, cfg, causal=False, scale=D ** -0.5,
                        decode=kv_cache is not None)
    out = out.reshape(B, S, H, D)
    y = jnp.einsum("bshd,hde->bse", out, p["wo"].astype(cdt))
    if gated:
        y = jnp.tanh(p["gate"].astype(cdt)) * y
    return shard_l(y, ("batch", "seq", "act_embed"))
