"""Dense FFN (SwiGLU / GELU) and Mixture-of-Experts with GShard-style
capacity-based dispatch (pure jnp + sharding constraints: GSPMD inserts the
expert-parallel collectives; see DESIGN.md §3)."""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed import shard_l
from repro.layers.basic import act_fn
from repro.param import Spec


# ---------------------------------------------------------------------------
# dense FFN


def ffn_specs(cfg: ModelConfig, d_ff: int = 0, axis: str = "mlp") -> Dict[str, Spec]:
    E = cfg.d_model
    F = d_ff or cfg.d_ff
    s = {
        "w_gate": Spec((E, F), ("embed", axis), ("in", "out"), init="fan_in"),
        "w_up": Spec((E, F), ("embed", axis), ("in", "out"), init="fan_in"),
        "w_down": Spec((F, E), (axis, "embed"), ("in", "out"), init="fan_in"),
    }
    if cfg.act == "gelu":  # classic 2-matrix FFN (BERT/GPT/DeiT/Whisper)
        s.pop("w_gate")
    if cfg.use_bias:
        s["b_up"] = Spec((F,), (axis,), ("out",), init="zeros")
        s["b_down"] = Spec((E,), ("embed",), ("out",), init="zeros")
    return s


def ffn_apply(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    cdt = cfg.compute_dtype
    act = act_fn(cfg.act)
    h = jnp.einsum("bse,ef->bsf", x, p["w_up"].astype(cdt))
    if cfg.use_bias:
        h = h + p["b_up"].astype(cdt)
    if "w_gate" in p:
        g = jnp.einsum("bse,ef->bsf", x, p["w_gate"].astype(cdt))
        h = act(g) * h
    else:
        h = act(h)
    h = shard_l(h, ("batch", "seq", "act_mlp"))
    y = jnp.einsum("bsf,fe->bse", h, p["w_down"].astype(cdt))
    if cfg.use_bias:
        y = y + p["b_down"].astype(cdt)
    return shard_l(y, ("batch", "seq", "act_embed"))


# ---------------------------------------------------------------------------
# MoE


def moe_specs(cfg: ModelConfig) -> Dict[str, Spec]:
    E, X, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff or cfg.d_ff
    s = {
        "router": Spec((E, X), ("embed", "experts"), ("in", "-"), init="normal", scale=0.02),
        "w_gate": Spec((X, E, F), ("experts", "embed", "moe_mlp"), ("-", "in", "out"), init="fan_in"),
        "w_up": Spec((X, E, F), ("experts", "embed", "moe_mlp"), ("-", "in", "out"), init="fan_in"),
        "w_down": Spec((X, F, E), ("experts", "moe_mlp", "embed"), ("-", "in", "out"), init="fan_in"),
    }
    if cfg.n_shared_experts:
        Fs = cfg.n_shared_experts * (cfg.moe_d_ff or cfg.d_ff)
        s["shared"] = ffn_specs(cfg, d_ff=Fs, axis="shared_mlp")
    return s


def moe_capacity(cfg: ModelConfig, seq: int) -> int:
    X, k = cfg.n_experts, cfg.moe_top_k
    cap = int(math.ceil(seq * k * cfg.capacity_factor / X))
    return max(cap, 4)


def moe_apply(p: Dict, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Returns (output, aux_load_balance_loss).

    Group = one batch row (GShard grouping): position-in-expert is a cumsum
    along the sequence, so capacity bookkeeping never crosses shards.
    """
    B, S, E = x.shape
    X, k = cfg.n_experts, cfg.moe_top_k
    C = moe_capacity(cfg, S)
    cdt = cfg.compute_dtype
    act = act_fn(cfg.act)

    logits = jnp.einsum("bse,ex->bsx", x, p["router"].astype(cdt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # [B,S,X]
    gate_vals, idx = jax.lax.top_k(probs, k)  # [B,S,k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): X * sum_e f_e * p_e
    me = jnp.mean(probs, axis=(0, 1))  # [X]
    onehot_top1 = jax.nn.one_hot(idx[..., 0], X, dtype=jnp.float32)
    ce = jnp.mean(onehot_top1, axis=(0, 1))
    aux = X * jnp.sum(me * ce)

    # capacity-based dispatch: for each of the k slots, position-in-expert is a
    # cumulative count along S (per batch-row group).  The [B,S,X,C] combine
    # tensor is kept in compute dtype (values are exact gate weights / zeros);
    # position bookkeeping stays in f32 (counts up to S exceed bf16 integers).
    combine = jnp.zeros((B, S, X, C), cdt)
    prior = jnp.zeros((B, X), jnp.float32)  # tokens already assigned per expert
    for slot in range(k):
        oh = jax.nn.one_hot(idx[..., slot], X, dtype=jnp.float32)  # [B,S,X]
        pos = jnp.cumsum(oh, axis=1) - oh + prior[:, None, :]  # [B,S,X]
        prior = prior + jnp.sum(oh, axis=1)
        keep = (pos < C) & (oh > 0)
        w = jnp.where(keep, gate_vals[..., slot, None], 0.0).astype(cdt)  # [B,S,X]
        pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=cdt)  # [B,S,X,C]
        combine = combine + w[..., None] * pos_oh

    combine = shard_l(combine, ("batch", "seq", "act_experts", "capacity"))
    dispatch = (combine > 0).astype(cdt)

    xb = jnp.einsum("bsxc,bse->bxce", dispatch, x)
    # two-hop reshard: (B:data, X:model) first, then the full-EP layout --
    # gives GSPMD an all-to-all path instead of replicate-and-repartition
    xb = shard_l(xb, ("batch", "act_experts_mid", "capacity", "act_embed"))
    xb = shard_l(xb, ("moe_batch", "act_experts", "capacity", "act_embed"))
    g = jnp.einsum("bxce,xef->bxcf", xb, p["w_gate"].astype(cdt))
    u = jnp.einsum("bxce,xef->bxcf", xb, p["w_up"].astype(cdt))
    h = act(g) * u
    yb = jnp.einsum("bxcf,xfe->bxce", h, p["w_down"].astype(cdt))
    yb = shard_l(yb, ("moe_batch", "act_experts", "capacity", "act_embed"))
    yb = shard_l(yb, ("batch", "act_experts_mid", "capacity", "act_embed"))
    y = jnp.einsum("bsxc,bxce->bse", combine.astype(cdt), yb)

    if cfg.n_shared_experts:
        y = y + ffn_apply(p["shared"], x, cfg)
    return shard_l(y, ("batch", "seq", "act_embed")), aux
