"""Recurrent mixers: Mamba (selective SSM, Jamba-style) and xLSTM (sLSTM/mLSTM).

Width-coalescing compatibility: all hidden projections are *head-structured*
([..., heads, head_sub]) so the paper's whole-head merging applies; the
state-transition axes (d_state, conv taps, per-head matrix memory) are
protected from width coalescing (DESIGN.md §4).

Training uses ``lax.scan`` over time with per-step state materialization only
(never [B,S,d_inner,d_state]); decode is a single-step state update (O(1) per
token -> these are the `long_500k`-capable families).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed import shard_l
from repro.param import Spec


def chunked_scan(step, init, xs, chunk: int):
    """``lax.scan`` with per-chunk rematerialization.

    A plain differentiated scan stores every per-step residual: for Mamba at
    train_4k that is O(S * B * d_inner * d_state) -- terabytes per device (the
    xlstm/jamba baseline dry-run measured it; EXPERIMENTS.md §Perf).  Scanning
    checkpointed chunks stores only chunk-boundary states and recomputes the
    inner steps in the backward pass: memory / (S/chunk), +1 extra forward.
    """
    S = jax.tree.leaves(xs)[0].shape[0]
    if chunk <= 1 or S <= chunk or S % chunk:
        return jax.lax.scan(step, init, xs)
    n = S // chunk
    xs_c = jax.tree.map(lambda x: x.reshape((n, chunk) + x.shape[1:]), xs)

    @jax.checkpoint
    def chunk_body(carry, xc):
        return jax.lax.scan(step, carry, xc)

    carry, ys = jax.lax.scan(chunk_body, init, xs_c)
    ys = jax.tree.map(lambda y: y.reshape((S,) + y.shape[2:]), ys)
    return carry, ys


# ---------------------------------------------------------------------------
# Mamba (selective SSM)


def mamba_specs(cfg: ModelConfig) -> Dict[str, Spec]:
    E, di, ds = cfg.d_model, cfg.mamba_d_inner, cfg.mamba_d_state
    dk, dtr = cfg.mamba_d_conv, cfg.resolved_dt_rank
    return {
        "w_in_x": Spec((E, di), ("embed", "mamba_inner"), ("in", "out"), init="fan_in"),
        "w_in_z": Spec((E, di), ("embed", "mamba_inner"), ("in", "out"), init="fan_in"),
        "conv_w": Spec((dk, di), ("conv_k", "mamba_inner"), ("-", "out"), init="normal", scale=0.1),
        "conv_b": Spec((di,), ("mamba_inner",), ("out",), init="zeros"),
        "w_B": Spec((di, ds), ("mamba_inner", "mamba_state"), ("in", "-"), init="fan_in"),
        "w_C": Spec((di, ds), ("mamba_inner", "mamba_state"), ("in", "-"), init="fan_in"),
        "w_dt": Spec((di, dtr), ("mamba_inner", "dt_rank"), ("in", "out"), init="fan_in"),
        "dt_proj": Spec((dtr, di), ("dt_rank", "mamba_inner"), ("in", "out"), init="fan_in"),
        "dt_bias": Spec((di,), ("mamba_inner",), ("out",), init="mamba_dt"),
        "A_log": Spec((di, ds), ("mamba_inner", "mamba_state"), ("out", "-"), init="mamba_A"),
        "D": Spec((di,), ("mamba_inner",), ("out",), init="ones"),
        "w_out": Spec((di, E), ("mamba_inner", "embed"), ("in", "out"), init="fan_in"),
    }


def mamba_cache_specs(cfg: ModelConfig, batch: int) -> Dict[str, Spec]:
    di, ds, dk = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    import jax.numpy as _jnp
    return {
        "conv": Spec((batch, dk - 1, di), ("batch", "conv_k", "act_mamba"), init="zeros",
                     dtype=cfg.compute_dtype),
        "h": Spec((batch, di, ds), ("batch", "act_mamba", "mamba_state"), init="zeros",
                  dtype=_jnp.float32),
    }


def _mamba_inner(p: Dict, x_c, z, cfg: ModelConfig, h0):
    """x_c: [B,S,di] post-conv activations. Returns (y [B,S,di], h_last).

    Chunked selective scan: the discretized (dA, dBx) are precomputed PER
    CHUNK and fed to the inner scan as xs.  Two reasons (EXPERIMENTS.md §Perf
    jamba iterations):
      * memory: per-chunk remat keeps residuals at [B, chunk, di, ds] instead
        of [B, S, di, ds];
      * collectives: if ``A`` is closed over inside the step, its gradient
        contracts the data-sharded batch axis EVERY timestep -> one
        all-reduce per step (4.1M on jamba train_4k).  With chunk-level
        precompute the parameter-gradient reductions happen once per chunk.
    """
    B, S, di = x_c.shape
    ds = cfg.mamba_d_state
    cdt = cfg.compute_dtype
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # [di,ds]
    B_ = jnp.einsum("bsd,dn->bsn", x_c, p["w_B"].astype(cdt))
    C_ = jnp.einsum("bsd,dn->bsn", x_c, p["w_C"].astype(cdt))
    dt = jnp.einsum("bsd,dr->bsr", x_c, p["w_dt"].astype(cdt))
    dt = jnp.einsum("bsr,rd->bsd", dt, p["dt_proj"].astype(cdt)) + p["dt_bias"].astype(cdt)
    dt = jax.nn.softplus(dt.astype(jnp.float32))  # [B,S,di]

    state_ax = ("batch", "act_mamba", "mamba_state")

    def step(h, xs):
        dA_t, dBx_t = xs  # [B,di,ds],[B,di,ds]
        h = shard_l(dA_t * h + dBx_t, state_ax)
        return h, h

    def run_chunk(h, xs_chunk):
        # The y_t = <h_t, C_t> contraction happens PER CHUNK, not per step:
        # its backward reduces over the model-sharded d_inner axis, which as a
        # per-step op emitted one all-reduce per token (2.1M on jamba train).
        xc, dtc, Bc, Cc = xs_chunk  # [B,c,...]
        dA = jnp.exp(dtc[..., None] * A[None, None])  # [B,c,di,ds]
        dBx = (dtc * xc.astype(jnp.float32))[..., None] * Bc.astype(jnp.float32)[:, :, None, :]
        h, hs = jax.lax.scan(step, h, (dA.swapaxes(0, 1), dBx.swapaxes(0, 1)))
        yc = jnp.einsum("tbdn,btn->tbd", hs, Cc.astype(jnp.float32))  # [c,B,di]
        return h, yc.astype(cdt)

    c = cfg.ssm_chunk
    if c > 1 and S > c and S % c == 0:
        n = S // c
        xs_all = tuple(a.reshape((B, n, c) + a.shape[2:]).swapaxes(0, 1)
                       for a in (x_c, dt, B_, C_))
        h_last, ys = jax.lax.scan(jax.checkpoint(run_chunk), h0, xs_all)
        y = ys.reshape(S, B, di)  # [n,c,B,di] -> [S,B,di] (chunk-major order)
    else:
        h_last, ys = run_chunk(h0, (x_c, dt, B_, C_))
        y = ys
    y = y.swapaxes(0, 1)  # [B,S,di]
    y = y + p["D"].astype(cdt) * x_c
    y = y * jax.nn.silu(z)
    return y, h_last


def mamba_apply(
    p: Dict, x: jax.Array, cfg: ModelConfig, cache: Optional[Dict] = None,
    return_state: bool = False,
) -> Tuple[jax.Array, Optional[Dict]]:
    B, S, E = x.shape
    di, ds, dk = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    cdt = cfg.compute_dtype
    x_in = jnp.einsum("bse,ed->bsd", x, p["w_in_x"].astype(cdt))
    z = jnp.einsum("bse,ed->bsd", x, p["w_in_z"].astype(cdt))
    x_in = shard_l(x_in, ("batch", "seq", "act_mamba"))
    z = shard_l(z, ("batch", "seq", "act_mamba"))
    cw = p["conv_w"].astype(cdt)  # [dk, di]

    if cache is None:
        # causal depthwise conv over the sequence
        xp = jnp.pad(x_in, ((0, 0), (dk - 1, 0), (0, 0)))
        x_c = jax.lax.conv_general_dilated(
            xp, cw[:, None, :], window_strides=(1,), padding="VALID",
            dimension_numbers=("NWC", "WIO", "NWC"), feature_group_count=di)
        x_c = jax.nn.silu(x_c + p["conv_b"].astype(cdt))
        h0 = shard_l(jnp.zeros((B, di, ds), jnp.float32),
                     ("batch", "act_mamba", "mamba_state"))
        y, h_last = _mamba_inner(p, x_c, z, cfg, h0)
        new_cache = None
        if return_state:  # prefill: conv tail + final SSM state
            tail = xp[:, xp.shape[1] - (dk - 1):, :]
            new_cache = {"conv": tail, "h": h_last}
    else:
        # single-token decode: rolling conv window + one state update
        window = jnp.concatenate([cache["conv"].astype(cdt), x_in], axis=1)  # [B,dk,di]
        x_c = jnp.einsum("bkd,kd->bd", window, cw)[:, None, :]
        x_c = jax.nn.silu(x_c + p["conv_b"].astype(cdt))
        h0 = shard_l(cache["h"].astype(jnp.float32),
                     ("batch", "act_mamba", "mamba_state"))
        y, h_last = _mamba_inner(p, x_c, z, cfg, h0)
        new_cache = {"conv": window[:, 1:, :], "h": h_last}

    out = jnp.einsum("bsd,de->bse", y, p["w_out"].astype(cdt))
    return shard_l(out, ("batch", "seq", "act_embed")), new_cache


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory) and sLSTM (scalar memory with recurrence)


def _xlstm_dims(cfg: ModelConfig, kind: str) -> Tuple[int, int]:
    NH = cfg.n_heads
    if kind == "mlstm":
        d_in = int(cfg.xlstm_proj_factor * cfg.d_model)
    else:
        d_in = cfg.d_model
    return NH, d_in // NH


def mlstm_specs(cfg: ModelConfig) -> Dict[str, Spec]:
    E = cfg.d_model
    NH, dh = _xlstm_dims(cfg, "mlstm")
    hax = "xlstm_head"
    return {
        "w_up": Spec((E, NH, dh), ("embed", "heads", hax), ("in", "out", "-"), init="fan_in"),
        "w_z": Spec((E, NH, dh), ("embed", "heads", hax), ("in", "out", "-"), init="fan_in"),
        "wq": Spec((NH, dh, dh), ("heads", hax, hax), ("out", "-", "-"), init="fan_in"),
        "wk": Spec((NH, dh, dh), ("heads", hax, hax), ("out", "-", "-"), init="fan_in"),
        "wv": Spec((NH, dh, dh), ("heads", hax, hax), ("out", "-", "-"), init="fan_in"),
        "w_i": Spec((NH, dh), ("heads", hax), ("out", "-"), init="normal", scale=0.02),
        "w_f": Spec((NH, dh), ("heads", hax), ("out", "-"), init="normal", scale=0.02),
        "b_i": Spec((NH,), ("heads",), ("out",), init="zeros"),
        "b_f": Spec((NH,), ("heads",), ("out",), init="ones"),  # bias toward remembering
        "w_down": Spec((NH, dh, E), ("heads", hax, "embed"), ("in", "-", "out"), init="fan_in"),
    }


def mlstm_cache_specs(cfg: ModelConfig, batch: int) -> Dict[str, Spec]:
    NH, dh = _xlstm_dims(cfg, "mlstm")
    f32 = jnp.float32
    return {
        "C": Spec((batch, NH, dh, dh), ("batch", "act_xlstm", "xlstm_head", "xlstm_head"),
                  init="zeros", dtype=f32),
        "n": Spec((batch, NH, dh), ("batch", "act_xlstm", "xlstm_head"), init="zeros", dtype=f32),
        "m": Spec((batch, NH), ("batch", "act_xlstm"), init="zeros", dtype=f32),
    }


def mlstm_apply(
    p: Dict, x: jax.Array, cfg: ModelConfig, cache: Optional[Dict] = None,
    return_state: bool = False,
) -> Tuple[jax.Array, Optional[Dict]]:
    B, S, E = x.shape
    NH, dh = _xlstm_dims(cfg, "mlstm")
    cdt = cfg.compute_dtype
    xi = jnp.einsum("bse,ehd->bshd", x, p["w_up"].astype(cdt))  # [B,S,NH,dh]
    z = jnp.einsum("bse,ehd->bshd", x, p["w_z"].astype(cdt))
    q = jnp.einsum("bshd,hdk->bshk", xi, p["wq"].astype(cdt))
    k = jnp.einsum("bshd,hdk->bshk", xi, p["wk"].astype(cdt)) * (dh ** -0.5)
    v = jnp.einsum("bshd,hdk->bshk", xi, p["wv"].astype(cdt))
    ig = jnp.einsum("bshd,hd->bsh", xi, p["w_i"].astype(cdt)).astype(jnp.float32) + p["b_i"].astype(jnp.float32)
    fg = jnp.einsum("bshd,hd->bsh", xi, p["w_f"].astype(cdt)).astype(jnp.float32) + p["b_f"].astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(fg)  # stabilized exponential gating

    if cache is None:
        C0 = shard_l(jnp.zeros((B, NH, dh, dh), jnp.float32),
                     ("batch", "act_xlstm", "xlstm_head", "xlstm_head"))
        n0 = shard_l(jnp.zeros((B, NH, dh), jnp.float32),
                     ("batch", "act_xlstm", "xlstm_head"))
        m0 = jnp.full((B, NH), -1e30, jnp.float32)
    else:
        C0 = cache["C"].astype(jnp.float32)
        n0 = cache["n"].astype(jnp.float32)
        m0 = cache["m"].astype(jnp.float32)

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, it, lft = xs
        m_new = jnp.maximum(lft + m, it)
        i_p = jnp.exp(it - m_new)[..., None]  # [B,NH,1]
        f_p = jnp.exp(lft + m - m_new)[..., None]
        kf = kt.astype(jnp.float32)
        vf = vt.astype(jnp.float32)
        C = shard_l(f_p[..., None] * C + i_p[..., None] * (vf[..., :, None] * kf[..., None, :]),
                    ("batch", "act_xlstm", "xlstm_head", "xlstm_head"))
        n = shard_l(f_p * n + i_p * kf, ("batch", "act_xlstm", "xlstm_head"))
        qf = qt.astype(jnp.float32)
        num = jnp.einsum("bhvk,bhk->bhv", C, qf)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf)), 1.0)[..., None]
        h = (num / den).astype(cdt)
        return (C, n, m_new), h

    xs = tuple(a.swapaxes(0, 1) for a in (q, k, v, ig, log_f))
    (C, n, m), hs = chunked_scan(step, (C0, n0, m0), xs, cfg.ssm_chunk)
    h = hs.swapaxes(0, 1)  # [B,S,NH,dh]
    h = h * jax.nn.silu(z)
    y = jnp.einsum("bshd,hde->bse", h, p["w_down"].astype(cdt))
    new_cache = {"C": C, "n": n, "m": m} if (cache is not None or return_state) else None
    return shard_l(y, ("batch", "seq", "act_embed")), new_cache


def slstm_specs(cfg: ModelConfig) -> Dict[str, Spec]:
    E = cfg.d_model
    NH, dh = _xlstm_dims(cfg, "slstm")
    hax = "slstm_head"
    s = {}
    for g in ("z", "i", "f", "o"):
        s[f"w_{g}"] = Spec((E, NH, dh), ("embed", "heads", hax), ("in", "out", "-"), init="fan_in")
        s[f"r_{g}"] = Spec((NH, dh, dh), ("heads", hax, hax), ("out", "-", "-"), init="fan_in")
        s[f"b_{g}"] = Spec((NH, dh), ("heads", hax), ("out", "-"),
                           init="ones" if g == "f" else "zeros")
    s["w_down"] = Spec((NH, dh, E), ("heads", hax, "embed"), ("in", "-", "out"), init="fan_in")
    return s


def slstm_cache_specs(cfg: ModelConfig, batch: int) -> Dict[str, Spec]:
    NH, dh = _xlstm_dims(cfg, "slstm")
    ax = ("batch", "act_xlstm", "slstm_head")
    f32 = jnp.float32
    return {
        "c": Spec((batch, NH, dh), ax, init="zeros", dtype=f32),
        "n": Spec((batch, NH, dh), ax, init="zeros", dtype=f32),
        "h": Spec((batch, NH, dh), ax, init="zeros", dtype=f32),
        "m": Spec((batch, NH, dh), ax, init="zeros", dtype=f32),
    }


def slstm_apply(
    p: Dict, x: jax.Array, cfg: ModelConfig, cache: Optional[Dict] = None,
    return_state: bool = False,
) -> Tuple[jax.Array, Optional[Dict]]:
    B, S, E = x.shape
    NH, dh = _xlstm_dims(cfg, "slstm")
    cdt = cfg.compute_dtype
    pre = {g: jnp.einsum("bse,ehd->bshd", x, p[f"w_{g}"].astype(cdt)) for g in ("z", "i", "f", "o")}

    if cache is None:
        c0 = jnp.zeros((B, NH, dh), jnp.float32)
        n0 = jnp.zeros((B, NH, dh), jnp.float32)
        h0 = jnp.zeros((B, NH, dh), jnp.float32)
        m0 = jnp.full((B, NH, dh), -1e30, jnp.float32)
    else:
        c0, n0, h0, m0 = (cache[k].astype(jnp.float32) for k in ("c", "n", "h", "m"))

    r = {g: p[f"r_{g}"].astype(jnp.float32) for g in ("z", "i", "f", "o")}
    b = {g: p[f"b_{g}"].astype(jnp.float32) for g in ("z", "i", "f", "o")}

    def step(carry, xs):
        c, n, h, m = carry
        zx, ix, fx, ox = (t.astype(jnp.float32) for t in xs)

        def rec(g, inp):
            return inp + jnp.einsum("bhd,hdk->bhk", h, r[g]) + b[g]

        zt = jnp.tanh(rec("z", zx))
        it = rec("i", ix)
        ft = rec("f", fx)
        ot = jax.nn.sigmoid(rec("o", ox))
        log_f = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(log_f + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(log_f + m - m_new)
        c = shard_l(f_p * c + i_p * zt, ("batch", "act_xlstm", "slstm_head"))
        n = f_p * n + i_p
        h_new = ot * c / jnp.maximum(n, 1.0)
        return (c, n, h_new, m_new), h_new.astype(cdt)

    xs = tuple(pre[g].swapaxes(0, 1) for g in ("z", "i", "f", "o"))
    (c, n, h, m), hs = chunked_scan(step, (c0, n0, h0, m0), xs, cfg.ssm_chunk)
    hseq = hs.swapaxes(0, 1)  # [B,S,NH,dh]
    y = jnp.einsum("bshd,hde->bse", hseq, p["w_down"].astype(cdt))
    new_cache = ({"c": c, "n": n, "h": h, "m": m}
                 if (cache is not None or return_state) else None)
    return shard_l(y, ("batch", "seq", "act_embed")), new_cache
