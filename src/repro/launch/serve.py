"""Batched serving driver: prefill + decode with continuous batching (lite).

The driver is split into two orthogonal layers:

  * an **engine** owns the KV cache layout and the admission/placement of a
    request into it.  Two engines share one scheduler core (``EngineCore``:
    admit / run / reset / commit defined once):

      - ``slots`` -- the original fixed-width decode batch over dense
        ``[batch, max_seq]`` caches; per-admit splice into a free slot.  Kept
        as the equivalence oracle (greedy decode must match token-for-token).
      - ``paged`` -- vLLM-style paged KV: cache leaves are a shared
        ``[n_pages, page_size, ...]`` pool, each request holds a block table
        of page ids (``launch/paging.py``), admission is by free-page count,
        and decode reads K/V through the block table (the
        ``paged_attention_decode`` op in ``kernels/dispatch.py``) so per-step
        cost scales with the pages a request actually occupies, not
        ``max_seq``.  Prompt pages are keyed by a rolling blake2b digest, so
        requests sharing a prompt prefix reuse its (refcounted) pages and
        only prefill the non-shared tail.

  * a **DecodePolicy** decides how scheduler ticks become committed tokens:

      - ``GreedyPolicy`` -- one full-model argmax per tick (prior behavior,
        both engines).
      - ``SpeculativePolicy`` -- self-speculative decoding from the paper's
        Coalescing operator: the level-1 coalesced model (a deterministic
        *projection* of the serving params, ``core/operators.py``) drafts k
        tokens per tick, one batched full-model verify step scores all of
        them against the paged cache, and the agreeing prefix plus one
        full-model token is committed.  Lossless for greedy sampling: every
        emitted token is a full-model argmax, so output is token-for-token
        identical to GreedyPolicy regardless of draft quality -- a bad draft
        only costs accept rate, never correctness.

Two orthogonal production seams sit on top:

  * **live weight reload** -- a ``ManifestWatcher`` polls the checkpoint
    store's ``manifest.json`` (shared-dir or no-shared-FS KV mode), diffs the
    new step's per-leaf chunk digests against what it already landed, and
    ships ONLY the changed leaves; the engine stages the result
    (``request_reload``) and swaps via ``set_params`` at a tick boundary
    once every in-flight request has drained -- zero dropped requests, the
    speculative draft re-projects, and the prefix cache is invalidated.
  * **mesh-sharded paged decode** -- ``PagedServer(mesh=...)`` jits the SAME
    ``make_paged_decode_step`` the ``decode_*`` dry-run cells compile with
    explicit shardings: params laid out by the serve rules, K/V page pools
    model-sharded over the kv-head axis (GQA; MLA's latent pools carry no
    head axis and replicate), block tables/tokens/positions replicated.

See ``src/repro/launch/README.md`` for the architecture notes.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import (CheckpointManager, _flatten, _put,
                                      _unflatten_into)
from repro.config import MultiLevelConfig
from repro.configs import get_config
from repro.core import operators as ops
from repro.launch.paging import NULL_PAGE, BlockAllocator
from repro.models import lm as lm_lib
from repro.models.api import (build_model, make_paged_decode_step,
                              make_prefill_step, make_serve_step,
                              make_verify_step, serve_shardings)
from repro.param import Spec, is_spec


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S] int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)


def zeros_cache(cfg, batch: int, max_seq: int):
    cs = lm_lib.cache_specs(cfg, batch, max_seq)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype or cfg.compute_dtype),
                        cs, is_leaf=is_spec)


def zeros_paged_cache(cfg, n_pages: int, page_size: int):
    cs = lm_lib.paged_cache_specs(cfg, n_pages, page_size)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype or cfg.compute_dtype),
                        cs, is_leaf=is_spec)


def _bucket(n: int, cap: Optional[int] = None) -> int:
    """Next power of two >= n (bounds the jit retrace count for shapes that
    vary with load: decode table width, extend/verify tail length)."""
    b = 1
    while b < n:
        b *= 2
    return min(b, cap) if cap is not None else b


def make_write_prompt(page_size: int):
    """Scatter a prefill cache ([layers, 1, L, ...] leaves) into a page pool
    at ``page_ids`` ([n_pg] int32, logical page order).  Shared by the paged
    engine's cold-prompt path and the speculative draft cache."""

    def write_prompt(pages, prefill_cache, page_ids):
        n_pg = page_ids.shape[0]

        def one(pool, c):
            c = c[:, 0]  # [layers, L, ...]
            pad = [(0, 0)] * c.ndim
            pad[1] = (0, n_pg * page_size - c.shape[1])
            c = jnp.pad(c, pad)
            c = c.reshape(c.shape[0], n_pg, page_size, *c.shape[2:])
            return pool.at[:, page_ids].set(c.astype(pool.dtype))

        return jax.tree.map(one, pages, prefill_cache)

    return write_prompt


# ---------------------------------------------------------------------------
# decode policies


class DecodePolicy:
    """Strategy turning scheduler ticks into committed tokens.

    The scheduler (``EngineCore``) owns request lifecycle -- admission, the
    queue, retirement -- and calls ``tick`` once per scheduling round; the
    policy decides what to decode and hands accepted tokens back through
    ``eng.commit(row, tokens)``.  Hooks observe lifecycle events so a policy
    can keep per-row state (the speculative draft cache) in sync.
    """

    name = "base"

    def bind(self, eng: "EngineCore") -> None:
        """One-time attach to a constructed engine (build compiled steps,
        allocate policy-owned state).  Raise for unsupported engines."""

    def tick(self, eng: "EngineCore") -> None:
        raise NotImplementedError

    def on_admit(self, eng: "EngineCore", row: int, req: Request) -> None:
        pass

    def on_complete(self, eng: "EngineCore", row: int, req: Request) -> None:
        pass

    def on_reset(self, eng: "EngineCore") -> None:
        pass

    def on_params(self, eng: "EngineCore") -> None:
        """Serving params changed (hot reload); refresh derived state."""

    def stats(self) -> Dict[str, Any]:
        return {"policy": self.name}


class GreedyPolicy(DecodePolicy):
    """One full-model argmax token per tick (both engines)."""

    name = "greedy"

    def tick(self, eng: "EngineCore") -> None:
        act = [i for i, r in enumerate(eng.active) if r is not None]
        nxt = eng.decode_once()
        for i in act:
            eng.commit(i, [nxt[i]])


class SpeculativePolicy(DecodePolicy):
    """Self-speculative decoding from the coalesced level-1 draft model.

    Per tick and per active row: draft up to ``k`` tokens with the level-1
    model (its params are ``coalesce(serving params)`` -- always in sync,
    refreshed by ``on_params``), then score the run ``[last_tok, d_1..d_k]``
    in ONE batched full-model verify step at positions ``pos..pos+k``, and
    commit the longest agreeing prefix plus the first disagreeing (or bonus)
    full-model argmax -- always >= 1 token per tick, so progress matches
    greedy in the worst case and is up to k+1 tokens per full-model step in
    the best.

    Losslessness: every committed token is ``argmax(verify logits)``; the
    draft only chooses *which* positions the verify step gets to score, so
    output is token-for-token identical to greedy decode by construction.

    Rollback: the verify step eagerly writes K/V for all k+1 positions.
    Rejected positions are rewound in the host-side length bookkeeping only
    (``BlockAllocator.mark_written`` / ``rollback``) -- the stale K/V needs
    no physical erase because attention reads are position-masked and the
    next committed token overwrites the slot.  The draft cache is rewound
    the same way via ``draft_pos``.

    Paged engine only: the draft runs over its own page pool with the same
    block-table discipline; the slots oracle stays greedy.
    """

    name = "speculative"

    def __init__(self, k: int = 4, ml: Optional[MultiLevelConfig] = None,
                 draft_width: bool = True, draft_depth: bool = True):
        if k < 1:
            raise ValueError(f"speculative draft length k must be >= 1, got {k}")
        self.k = k
        self.ml = ml or MultiLevelConfig()
        self.draft_width = draft_width
        self.draft_depth = draft_depth
        self._zero_stats()

    def _zero_stats(self) -> None:
        self.rounds = 0
        self.drafted_tokens = 0
        self.accepted_tokens = 0
        self.draft_time_s = 0.0
        self.verify_time_s = 0.0

    def bind(self, eng: "EngineCore") -> None:
        if not isinstance(eng, PagedServer):
            raise NotImplementedError(
                "speculative decoding requires the paged engine "
                "(engine='paged'); the slots oracle stays greedy-only")
        self.draft_cfg, self._project = ops.make_draft_projection(
            eng.model.specs(), eng.cfg, self.ml,
            width=self.draft_width, depth=self.draft_depth)
        self.draft_model = build_model(self.draft_cfg)
        self.draft_params = self._project(eng.params)
        self.draft_prefill = jax.jit(make_prefill_step(self.draft_model))
        self.draft_step = jax.jit(make_paged_decode_step(self.draft_model),
                                  donate_argnums=(1,))
        self.verify = jax.jit(make_verify_step(eng.model), donate_argnums=(1,))
        self._write_draft = jax.jit(make_write_prompt(eng.page_size),
                                    donate_argnums=(0,))
        # the draft cache gets its own pool, sized one worst-case table per
        # batch row (+ null page) so draft admission can never fail while a
        # row is free -- no un-admit path to maintain
        self._n_draft_pages = eng.batch * eng.max_pages_per_req + 1
        self._fresh(eng)

    def _fresh(self, eng: "PagedServer") -> None:
        self.draft_pages = zeros_paged_cache(self.draft_cfg,
                                             self._n_draft_pages, eng.page_size)
        self.draft_alloc = BlockAllocator(self._n_draft_pages, eng.page_size,
                                          prefix_reuse=False)
        self.draft_tables: List[Optional[List[int]]] = [None] * eng.batch
        self.draft_pos = np.zeros((eng.batch,), np.int32)
        # committed token at every position 0..pos, per row: the draft's
        # catch-up feed after a rejection re-reads history the main engine
        # no longer materializes anywhere else
        self.hist: List[Optional[List[int]]] = [None] * eng.batch

    # -- lifecycle hooks ----------------------------------------------------
    def on_admit(self, eng: "PagedServer", row: int, req: Request) -> None:
        L = len(req.prompt)
        total = min(L + req.max_new, eng.max_seq)
        got = self.draft_alloc.admit(req.rid, req.prompt, total)
        assert got is not None, "draft pool is sized for one table per row"
        table, _ = got
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        _, pc = self.draft_prefill(self.draft_params, toks, None, None)
        n_pg = -(-L // eng.page_size)
        self.draft_pages = self._write_draft(
            self.draft_pages, pc, jnp.asarray(table[:n_pg], jnp.int32))
        self.draft_tables[row] = table
        self.draft_pos[row] = L
        self.hist[row] = [int(t) for t in req.prompt] + [int(eng.last_tok[row])]

    def on_complete(self, eng: "PagedServer", row: int, req: Request) -> None:
        self.draft_alloc.complete(req.rid)
        self.draft_tables[row] = None
        self.draft_pos[row] = 0
        self.hist[row] = None

    def on_reset(self, eng: "PagedServer") -> None:
        self._fresh(eng)
        self._zero_stats()

    def on_params(self, eng: "PagedServer") -> None:
        # re-project: the draft is a pure function of the serving params
        self.draft_params = self._project(eng.params)

    # -- the speculative tick ----------------------------------------------
    def _draft_argmax(self, logits) -> np.ndarray:
        """Draft proposals from draft-step logits ([B, V] -> [B] int32).
        A seam for tests: monkeypatching this to emit wrong tokens forces
        rejection without touching the verify path."""
        return np.asarray(jnp.argmax(logits, -1), np.int32)

    def _feed_token(self, eng: "PagedServer", i: int, p: int,
                    proposals: List[int]) -> int:
        """Token occupying position ``p`` for row ``i``: committed history up
        to ``pos`` (catch-up after acceptance/rejection), the row's own
        earlier proposal beyond it."""
        pos = int(eng.pos[i])
        if p <= pos:
            return self.hist[i][p]
        return proposals[p - pos - 1]

    def tick(self, eng: "PagedServer") -> None:
        act = [i for i, r in enumerate(eng.active) if r is not None]
        if not act:
            return
        self.rounds += 1
        # per-row speculation window: never draft past the request's token
        # budget or the last valid cache index, so the verify write stays
        # within the admission reserve (mark_written would raise otherwise)
        k_i = {i: max(0, min(self.k,
                             eng.active[i].max_new - len(eng.active[i].out) - 1,
                             eng.max_seq - 1 - int(eng.pos[i])))
               for i in act}
        drafts: Dict[int, List[int]] = {i: [] for i in act}
        # --- draft phase: batched S=1 level-1 steps.  Row i feeds positions
        # draft_pos[i] .. pos[i]+k_i[i]-1: committed catch-up tokens first
        # (they overwrite any rejected leftovers in the draft cache before a
        # later query could attend them), then its own fresh proposals.
        t0 = time.time()
        starts = {i: int(self.draft_pos[i]) for i in act}
        ends = {i: int(eng.pos[i]) + k_i[i] for i in act}
        M_b = _bucket(max(len(self.draft_tables[i]) for i in act),
                      cap=eng.max_pages_per_req)
        for j in range(max(ends[i] - starts[i] for i in act)):
            rows = [i for i in act if starts[i] + j < ends[i]]
            if not rows:
                break
            toks = np.zeros((eng.batch, 1), np.int32)
            poss = np.full((eng.batch, 1), -1, np.int32)  # idle row: null page
            bt = np.full((eng.batch, M_b), NULL_PAGE, np.int32)
            for i in rows:
                p = starts[i] + j
                toks[i, 0] = self._feed_token(eng, i, p, drafts[i])
                poss[i, 0] = p
                bt[i, :len(self.draft_tables[i])] = self.draft_tables[i]
            logits, self.draft_pages = self.draft_step(
                self.draft_params, self.draft_pages, jnp.asarray(toks),
                jnp.asarray(poss), jnp.asarray(bt))
            nxt = self._draft_argmax(logits)
            for i in rows:
                if starts[i] + j >= int(eng.pos[i]):  # predicts position > pos
                    drafts[i].append(int(nxt[i]))
        for i in act:
            self.draft_pos[i] = ends[i]
        self.draft_time_s += time.time() - t0
        self.drafted_tokens += sum(k_i.values())
        # --- verify phase: ONE batched full-model step scores the whole run
        # [last_tok, d_1..d_k] at positions pos..pos+k through the block
        # tables (right-padded rows: positions == -1 -> null-page writes,
        # masked attention, unread logits)
        t0 = time.time()
        S_b = _bucket(max(k_i[i] for i in act) + 1)
        toks = np.zeros((eng.batch, S_b), np.int32)
        poss = np.full((eng.batch, S_b), -1, np.int32)
        M_b = _bucket(max(len(eng.tables[i]) for i in act),
                      cap=eng.max_pages_per_req)
        bt = np.full((eng.batch, M_b), NULL_PAGE, np.int32)
        for i in act:
            n = k_i[i] + 1
            toks[i, :n] = [int(eng.last_tok[i])] + drafts[i]
            poss[i, :n] = np.arange(int(eng.pos[i]), int(eng.pos[i]) + n,
                                    dtype=np.int32)
            bt[i, :len(eng.tables[i])] = eng.tables[i]
            eng.alloc.mark_written(eng.active[i].rid, int(eng.pos[i]) + n)
        logits, eng.pages = self.verify(
            eng.params, eng.pages, jnp.asarray(toks), jnp.asarray(poss),
            jnp.asarray(bt))
        full = np.asarray(jnp.argmax(logits, -1), np.int32)  # [B, S_b]
        self.verify_time_s += time.time() - t0
        # --- acceptance: longest agreeing prefix + one full-model token
        for i in act:
            req = eng.active[i]
            g, d = full[i], drafts[i]
            m = 0
            while m < k_i[i] and g[m] == d[m]:
                m += 1
            # g[:m] matched the draft, g[m] is the bonus (full accept) or the
            # correction token -- all of them full-model argmaxes
            emitted = [int(t) for t in g[:m + 1]]
            self.accepted_tokens += m
            eng.commit(i, emitted)
            if eng.active[i] is req:  # still running: rewind speculation
                self.hist[i].extend(emitted)
                # rejected positions: rewind the main allocator's written
                # high-water to the committed length, and the draft cursor so
                # catch-up overwrites the draft cache's wrong tail
                eng.alloc.rollback(req.rid)
                self.draft_pos[i] = min(int(self.draft_pos[i]), int(eng.pos[i]))

    def stats(self) -> Dict[str, Any]:
        return {
            "policy": self.name,
            "draft_k": self.k,
            "spec_rounds": self.rounds,
            "drafted_tokens": self.drafted_tokens,
            "accepted_tokens": self.accepted_tokens,
            "accept_rate": (self.accepted_tokens / self.drafted_tokens
                            if self.drafted_tokens else 0.0),
            "draft_time_s": round(self.draft_time_s, 4),
            "verify_time_s": round(self.verify_time_s, 4),
        }


# ---------------------------------------------------------------------------
# live weight reload


class ManifestWatcher:
    """Polls a checkpoint store's ``manifest.json`` and lands new serving
    weights by digest diff -- the train->serve hand-off channel.

    Per :meth:`poll`:

      1. ``mgr.latest()`` reads the store's current manifest -- a cheap
         atomic-file read in shared-dir mode, the coordinated candidate
         election in no-shared-FS (``local=True``) KV mode.  In KV mode both
         ``latest`` and the object gather are collectives, so every process
         of a multi-process serving job must drive its watcher at the same
         tick (``EngineCore.attach_watcher`` does).
      2. Steps already examined are skipped, as are steps whose ``params``
         tree does not structurally match the serving model: a mid-V-cycle
         checkpoint carries COALESCED (smaller-shape) params -- only
         level-0-shaped weights are servable.
      3. Each leaf's chunk-digest tuple is diffed against what the watcher
         landed last time; only CHANGED leaves are assembled and device_put
         (``CheckpointManager.assemble_diff``).  Unchanged leaves return the
         previously landed arrays -- zero bytes read, zero bytes shipped
         (``tests/test_reload.py`` pins object identity).

    The result is handed to ``EngineCore.request_reload``, which swaps at a
    tick boundary without dropping in-flight requests.
    """

    def __init__(self, mgr: CheckpointManager, like, shardings=None,
                 key: str = "params"):
        self.mgr = mgr
        self.key = key
        self.like = like
        self._flat_like = _flatten(like)
        self._flat_sh = _flatten(shardings) if shardings is not None else {}
        self.last_step = -1                # newest step actually landed
        self._seen = -1                    # newest step examined (incl. skips)
        self._sig: Dict[str, Tuple[str, ...]] = {}
        self._landed: Dict[str, Any] = {}
        self.steps_seen: List[int] = []
        self.steps_skipped: List[int] = []
        self.reload_history: List[Dict[str, Any]] = []
        self.last_reload_stats: Dict[str, Any] = {}
        self.poll_errors = 0

    def _shapes_match(self, entries) -> bool:
        if set(entries) != set(self._flat_like):
            return False
        return all(tuple(entries[k]["shape"]) ==
                   tuple(np.shape(self._flat_like[k])) for k in entries)

    def poll(self) -> Optional[Tuple[int, Any]]:
        """``(step, params)`` when new weights landed, else None."""
        m = self.mgr.latest()
        if m is None or int(m["step"]) <= self._seen:
            return None
        step = int(m["step"])
        try:
            trees = self.mgr.step_manifest(m)
            if trees is None:
                raise ValueError(
                    "live reload needs the content-addressed (v3) checkpoint "
                    "layout; this step publishes no digest manifest to diff "
                    "(saved with dedup=False?)")
            entries = trees.get(self.key, {})
            if not self._shapes_match(entries):
                self._seen = step
                self.steps_skipped.append(step)
                return None
            sig = {k: tuple(ch["digest"] for ch in rec["chunks"])
                   for k, rec in entries.items()}
            changed = sorted(k for k in sig if self._sig.get(k) != sig[k])
            flat_new = self.mgr.assemble_diff(trees, self.key, changed)
        except FileNotFoundError:
            # racing the trainer's keep-last GC: the step dir or one of its
            # objects vanished between the manifest read and assembly.  A
            # newer publish exists by definition -- catch it next poll.
            self.poll_errors += 1
            return None
        for k in changed:
            self._landed[k] = _put(flat_new[k], self._flat_like[k],
                                   self._flat_sh.get(k))
        self._sig = sig
        self._seen = self.last_step = step
        self.steps_seen.append(step)
        self.last_reload_stats = {
            "step": step, "leaves": len(sig), "changed": len(changed),
            "reused": len(sig) - len(changed),
            **{f"gather_{k}": v
               for k, v in self.mgr.last_gather_stats.items()}}
        self.reload_history.append(self.last_reload_stats)
        return step, _unflatten_into(dict(self._landed), self.like)


# ---------------------------------------------------------------------------
# scheduler core + engines


class EngineCore:
    """Engine-agnostic scheduler: request queue, admission, token commit and
    retirement are defined HERE, once.  Engines supply cache placement
    (``_place`` / ``_retire`` / ``decode_once``); the bound ``DecodePolicy``
    decides what each tick decodes."""

    engine_name = "base"

    def __init__(self, cfg, batch: int, max_seq: int,
                 policy: Optional[DecodePolicy] = None):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.batch = batch
        self.max_seq = max_seq
        self.params = self.model.init(jax.random.PRNGKey(0))
        self.prefill = jax.jit(make_prefill_step(self.model))
        self.pos = np.zeros((batch,), np.int32)
        self.last_tok = np.zeros((batch,), np.int32)
        self.active: List[Optional[Request]] = [None] * batch
        self.done: List[Request] = []
        self.rejected: List[Request] = []  # oversized prompts (see admit)
        self.policy = policy or GreedyPolicy()
        # hot-reload state: staged weights swap at a tick boundary once every
        # in-flight request drains (see request_reload / maybe_swap)
        self._pending_params = None
        self.reloads = 0
        self._watcher: Optional[ManifestWatcher] = None
        self._watch_every = 1
        # subclasses call self.policy.bind(self) once fully constructed

    # -- engine hooks (overridden) ------------------------------------------
    def _fits_engine(self, req: Request) -> bool:
        return True

    def _place(self, row: int, req: Request) -> Optional[int]:
        """Reserve cache space for ``req`` in ``row`` and prefill; returns the
        first generated token, or None when resources are busy right now."""
        raise NotImplementedError

    def _retire(self, row: int, req: Request) -> None:
        pass

    def _reset_engine(self) -> None:
        pass

    def _place_params(self, params):
        """Engine hook: commit reloaded params to the engine's device layout
        (the mesh-sharded paged engine device_puts onto its param
        shardings; host trees land as-is everywhere else)."""
        return params

    def _on_params_engine(self) -> None:
        """Engine hook: serving params changed.  The paged engine wipes its
        prefix cache here -- cached prompt K/V was computed under the old
        weights, and a digest commits to token content, not to the weights
        that encoded it."""

    def decode_once(self) -> np.ndarray:
        """One full-model decode step over all rows -> next-token argmaxes
        ([batch] int32; inactive rows carry garbage the caller ignores)."""
        raise NotImplementedError

    def _admit_error(self, req: Request) -> str:
        return (f"prompt of length {len(req.prompt)} cannot be admitted: "
                f"max_seq={self.max_seq} leaves no room to decode "
                f"(need len(prompt) <= max_seq - 1)")

    # -- continuous batching (shared) ---------------------------------------
    def fits(self, req: Request) -> bool:
        """The admission invariant, in ONE place: decode must be able to
        write at least one token at a valid cache index (plus any
        engine-specific capacity check)."""
        return len(req.prompt) <= self.max_seq - 1 and self._fits_engine(req)

    def admit(self, req: Request) -> bool:
        """Place ``req`` into a free row; False when rows/resources are busy
        right now.  Raises ``ValueError`` for prompts that can never fit: a
        prompt needs ``len(prompt) <= max_seq - 1`` so decode can write at
        least one token -- longer ones used to crash in cache placement
        (negative pad) or, worse, run with ``pos >= max_seq`` so the cache
        write silently dropped and decoded garbage."""
        if not self.fits(req):
            raise ValueError(self._admit_error(req))
        if self._pending_params is not None:
            # a staged weight swap drains the engine first: admitting now
            # would start this request on the OLD weights, breaking the
            # reload contract (post-reload admissions == fresh server on the
            # new weights).  The request waits at the queue head; the swap
            # happens at the next drained tick and admission resumes.
            return False
        row = next((i for i, r in enumerate(self.active) if r is None), None)
        if row is None:
            return False
        first = self._place(row, req)
        if first is None:
            return False
        self.active[row] = req
        self.pos[row] = len(req.prompt)
        self.last_tok[row] = first
        self.policy.on_admit(self, row, req)
        return True

    def commit(self, row: int, toks) -> None:
        """Append policy-accepted tokens to ``row``'s request, advancing the
        decode cursor and retiring the request the moment it is finished
        (remaining tokens, if any, are dropped -- the request is done)."""
        req = self.active[row]
        for t in toks:
            req.out.append(int(t))
            # cap at the last valid cache index: a row freed this tick must
            # never carry a pos the decode cache write would silently drop
            self.pos[row] = min(self.pos[row] + 1, self.max_seq - 1)
            self.last_tok[row] = int(t)
            self._on_token(row, req)
            if len(req.out) >= req.max_new or self.pos[row] >= self.max_seq - 1:
                self.done.append(req)
                self.active[row] = None
                self._retire(row, req)
                self.policy.on_complete(self, row, req)
                break

    def _on_token(self, row: int, req: Request) -> None:
        pass

    def step(self) -> None:
        # the tick boundary: a staged reload lands the moment the engine is
        # drained -- BEFORE the idle early-out, or a pending swap with an
        # empty engine and a waiting queue would never resolve
        self.maybe_swap()
        if not any(r is not None for r in self.active):
            return
        self.policy.tick(self)

    def run(self, requests: List[Request], max_ticks: int = 10_000) -> List[Request]:
        """Drain ``requests``: admit into free rows, decode, recycle rows.

        Oversized prompts (see :meth:`admit`) are rejected up front into
        ``self.rejected`` instead of wedging the queue head forever; a
        request that merely lacks resources *now* waits at the queue head
        for completions to free them.  An attached :class:`ManifestWatcher`
        is polled once per tick (``attach_watcher(poll_every=...)`` thins
        this): new weights are staged via :meth:`request_reload` and swap in
        at the drain boundary while the queue keeps feeding."""
        queue = list(requests)
        ticks = 0
        while (queue or any(self.active)) and ticks < max_ticks:
            if (self._watcher is not None and not self.reload_pending()
                    and ticks % self._watch_every == 0):
                got = self._watcher.poll()
                if got is not None:
                    self.request_reload(got[1])
            while queue:
                if not self.fits(queue[0]):
                    req = queue.pop(0)
                    self.rejected.append(req)
                    print(f"[serve] rejected req {req.rid}: prompt length "
                          f"{len(req.prompt)} > max_seq-1 = {self.max_seq - 1}")
                    continue
                if not self.admit(queue[0]):
                    break
                queue.pop(0)
            self.step()
            ticks += 1
        # a reload staged on the final tick still lands: the next run()
        # starts on the newest published weights
        self.maybe_swap()
        return self.done

    def reset(self) -> None:
        """Clear request state but keep params + compiled steps (bench
        reuse).  Stale cache contents are safe: every admit overwrites its
        row's range before it is read, and decode reads are position-masked."""
        self.pos[:] = 0
        self.last_tok[:] = 0
        self.active = [None] * self.batch
        self.done, self.rejected = [], []
        self._reset_engine()
        self.policy.on_reset(self)

    def set_params(self, params) -> None:
        """Hot weight swap, IMMEDIATE: in-flight rows decode their next token
        under the new weights.  The engine re-places the tree onto its device
        layout and invalidates weight-derived caches (prefix pages), then the
        policy refreshes anything derived from the serving params (the
        speculative draft projection re-runs here).  Live serving goes
        through :meth:`request_reload` instead, which defers this call to a
        drained tick boundary."""
        self.params = self._place_params(params)
        self._on_params_engine()
        self.policy.on_params(self)

    # -- live weight reload ---------------------------------------------------
    def request_reload(self, params) -> bool:
        """Stage ``params`` for a tick-boundary swap; True when the engine
        was already drained and the swap happened immediately.

        In-flight requests finish token-for-token under the weights they
        started on; new admissions wait (see :meth:`admit`) until the swap,
        so every request runs under exactly one set of weights and nothing
        is ever dropped.  Re-staging before the swap lands just replaces the
        staged tree -- only the newest weights ever swap in."""
        self._pending_params = params
        return self.maybe_swap()

    def reload_pending(self) -> bool:
        return self._pending_params is not None

    def maybe_swap(self) -> bool:
        """Land a staged reload if the engine is drained; True on swap."""
        if self._pending_params is None or any(
                r is not None for r in self.active):
            return False
        params, self._pending_params = self._pending_params, None
        self.set_params(params)
        self.reloads += 1
        return True

    def attach_watcher(self, watcher: ManifestWatcher,
                       poll_every: int = 1) -> None:
        """Drive ``watcher`` from the scheduler loop: :meth:`run` polls it
        every ``poll_every`` ticks and stages whatever it lands.  In
        no-shared-FS KV mode the poll is a collective, so every process of a
        multi-process serving job must attach with the same cadence."""
        self._watcher = watcher
        self._watch_every = max(1, poll_every)

    def stats(self) -> Dict[str, Any]:
        return dict(self.policy.stats())


class Server(EngineCore):
    """Fixed-slot engine (dense caches) -- the equivalence oracle."""

    engine_name = "slots"

    def __init__(self, cfg, batch: int = 4, max_seq: int = 128,
                 policy: Optional[DecodePolicy] = None):
        super().__init__(cfg, batch, max_seq, policy)
        self.decode = jax.jit(make_serve_step(self.model), donate_argnums=(1,))
        self.cache = zeros_cache(cfg, batch, max_seq)
        self.policy.bind(self)

    def _place(self, row: int, req: Request) -> Optional[int]:
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        extras = {}
        if self.cfg.family == "vlm":
            extras["img_embeds"] = jnp.ones(
                (1, self.cfg.n_image_tokens, self.cfg.vision_dim or self.cfg.d_model),
                self.cfg.compute_dtype)
        if self.cfg.family == "audio":
            extras["enc_frames"] = jnp.ones(
                (1, self.cfg.encoder_seq, self.cfg.d_model), self.cfg.compute_dtype)
        logits, pc = self.prefill(self.params, toks,
                                  extras.get("img_embeds"), extras.get("enc_frames"))
        # pad the single-sequence cache seq dim up to max_seq and splice
        self.cache = self._splice(pc, row, len(req.prompt))
        return int(jnp.argmax(logits[0]))

    def _splice(self, prefill_cache, slot: int, prompt_len: int):
        # leaves layout: [layers, batch, ...] after scan stacking -> axis0=layers
        def one_stacked(b, s):
            if b.ndim < 3:
                return b
            if s.shape[2] != b.shape[2] and s.ndim == b.ndim and b.ndim >= 3 \
                    and s.shape[3:] == b.shape[3:]:
                pad = [(0, 0)] * s.ndim
                pad[2] = (0, b.shape[2] - s.shape[2])
                s = jnp.pad(s, pad)
            return b.at[:, slot].set(s[:, 0].astype(b.dtype))

        return jax.tree.map(one_stacked, self.cache, prefill_cache)

    def decode_once(self) -> np.ndarray:
        toks = jnp.asarray(self.last_tok)[:, None]
        pos = jnp.asarray(self.pos)
        logits, self.cache = self.decode(self.params, self.cache, toks, pos)
        return np.asarray(jnp.argmax(logits, -1), np.int32)


class PagedServer(EngineCore):
    """Paged-KV engine: block tables over a shared page pool + prefix reuse.

    Admission reserves the request's worst-case page count up front
    (``ceil(min(len(prompt)+max_new, max_seq) / page_size)``), so an admitted
    request never stalls on allocation mid-decode -- and a speculative burst
    of k+1 writes always lands inside the reserve.  Cache-hit prompts run a
    bucketed "extend" step over just the non-shared tail.
    """

    engine_name = "paged"

    def __init__(self, cfg, batch: int = 4, max_seq: int = 128,
                 page_size: int = 16, n_pages: Optional[int] = None,
                 prefix_reuse: bool = True,
                 policy: Optional[DecodePolicy] = None,
                 mesh=None, shard_rules: Optional[Dict[str, Any]] = None):
        super().__init__(cfg, batch, max_seq, policy)
        self.page_size = page_size
        self.max_pages_per_req = -(-max_seq // page_size)
        if n_pages is None:
            # default: page-count parity with the slot engine's dense cache
            # (+1 for the reserved null page) -- admission then slot-bound
            n_pages = batch * self.max_pages_per_req + 1
        self.n_pages = n_pages
        self.paged_step = jax.jit(make_paged_decode_step(self.model),
                                  donate_argnums=(1,))
        self._write_prompt = jax.jit(make_write_prompt(page_size),
                                     donate_argnums=(0,))
        self.pages = zeros_paged_cache(cfg, n_pages, page_size)
        self.alloc = BlockAllocator(n_pages, page_size, prefix_reuse=prefix_reuse)
        self.tables: List[Optional[List[int]]] = [None] * batch
        self.prefill_tokens_computed = 0
        self.mesh = mesh
        self._param_shardings = None
        if mesh is not None:
            # the serve step becomes the SAME sharded function the decode_*
            # dry-run cells compile: params on the serve layout, page pools
            # model-sharded over the kv-head axis (GQA; MLA latent pools
            # carry no head axis and replicate), tables/tokens/positions
            # replicated.  Host-side scheduling is unchanged -- only the
            # compiled step's layout is.
            from jax.sharding import NamedSharding, PartitionSpec

            from repro.distributed import put_global_tree

            psh, csh, _ = serve_shardings(self.model, mesh, n_pages=n_pages,
                                          page_size=page_size,
                                          rules=shard_rules)
            repl = NamedSharding(mesh, PartitionSpec())
            self.paged_step = jax.jit(make_paged_decode_step(self.model),
                                      in_shardings=(psh, csh, repl, repl, repl),
                                      out_shardings=(repl, csh),
                                      donate_argnums=(1,))
            self._param_shardings = psh
            self.params = put_global_tree(self.params, psh)
            self.pages = put_global_tree(self.pages, csh)
        self.policy.bind(self)

    # -- stats ---------------------------------------------------------------
    @property
    def prefill_tokens_saved(self) -> int:
        return self.alloc.reused_tokens_total

    @property
    def pages_in_use_peak(self) -> int:
        return self.alloc.pool.in_use_peak

    def stats(self) -> Dict[str, Any]:
        return {
            "pages_in_use_peak": self.pages_in_use_peak,
            "pages_capacity": self.alloc.pool.capacity,
            "prefill_tokens_saved": self.prefill_tokens_saved,
            "prefill_tokens_computed": self.prefill_tokens_computed,
            "rolled_back_positions": self.alloc.rolled_back_total,
            **self.policy.stats(),
        }

    # -- engine hooks --------------------------------------------------------
    def _fits_engine(self, req: Request) -> bool:
        """Admissible-ever: a worst-case block table the pool could hold."""
        total = min(len(req.prompt) + req.max_new, self.max_seq)
        return self.alloc.pages_needed(total) <= self.alloc.pool.capacity

    def _admit_error(self, req: Request) -> str:
        return (f"prompt of length {len(req.prompt)} cannot be admitted: "
                f"max_seq={self.max_seq} leaves no room to decode "
                f"(need len(prompt) <= max_seq - 1 and a block table "
                f"<= {self.alloc.pool.capacity} pages)")

    def _place(self, row: int, req: Request) -> Optional[int]:
        L = len(req.prompt)
        total_positions = min(L + req.max_new, self.max_seq)
        got = self.alloc.admit(req.rid, req.prompt, total_positions)
        if got is None:
            return None
        table, reuse_len = got
        if reuse_len == 0:
            # cold prompt: the SAME prefill step as the slot engine (first
            # token bitwise-identical), then scatter its cache into our pages
            toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
            logits, pc = self.prefill(self.params, toks, None, None)
            n_pg = -(-L // self.page_size)
            ids = jnp.asarray(table[:n_pg], jnp.int32)
            self.pages = self._write_prompt(self.pages, pc, ids)
            first = int(jnp.argmax(logits[0]))
            self.prefill_tokens_computed += L
        else:
            # warm prompt: run only the tail through a bucketed extend step;
            # reused pages are read through the block table (never rewritten)
            tail = np.asarray(req.prompt[reuse_len:], np.int32)
            S = len(tail)
            S_b = _bucket(S)
            toks = np.zeros((S_b,), np.int32)
            toks[S_b - S:] = tail
            positions = np.full((S_b,), -1, np.int32)  # left-pad -> null page
            positions[S_b - S:] = np.arange(reuse_len, L, dtype=np.int32)
            M_b = _bucket(len(table), cap=self.max_pages_per_req)
            bt = np.full((M_b,), NULL_PAGE, np.int32)
            bt[:len(table)] = table
            logits, self.pages = self.paged_step(
                self.params, self.pages, jnp.asarray(toks)[None],
                jnp.asarray(positions)[None], jnp.asarray(bt)[None])
            first = int(jnp.argmax(logits[0]))
            self.prefill_tokens_computed += S
        self.tables[row] = table
        return first

    def _on_token(self, row: int, req: Request) -> None:
        self.alloc.advance(req.rid)

    def _retire(self, row: int, req: Request) -> None:
        self.tables[row] = None
        self.alloc.complete(req.rid)

    def decode_once(self) -> np.ndarray:
        act = [i for i, r in enumerate(self.active) if r is not None]
        M_b = _bucket(max(len(self.tables[i]) for i in act),
                      cap=self.max_pages_per_req)
        bt = np.full((self.batch, M_b), NULL_PAGE, np.int32)
        positions = np.full((self.batch, 1), -1, np.int32)  # idle row: len 0
        toks = np.zeros((self.batch, 1), np.int32)
        for i in act:
            bt[i, :len(self.tables[i])] = self.tables[i]
            positions[i, 0] = self.pos[i]
            toks[i, 0] = self.last_tok[i]
        logits, self.pages = self.paged_step(
            self.params, self.pages, jnp.asarray(toks),
            jnp.asarray(positions), jnp.asarray(bt))
        return np.asarray(jnp.argmax(logits, -1), np.int32)

    def _reset_engine(self) -> None:
        """Stale page contents are safe: decode reads are length-masked and
        every admit writes the prompt range of its fresh pages first."""
        self.alloc = BlockAllocator(self.n_pages, self.page_size,
                                    prefix_reuse=self.alloc.prefix is not None)
        self.tables = [None] * self.batch
        self.prefill_tokens_computed = 0

    def _place_params(self, params):
        if self._param_shardings is None:
            return params
        from repro.distributed import put_global_tree

        return put_global_tree(params, self._param_shardings)

    def _on_params_engine(self) -> None:
        self.alloc.invalidate_prefix()


POLICIES = ("greedy", "speculative")
ENGINES = ("paged", "slots")


def make_server(cfg, engine: str = "paged", batch: int = 4, max_seq: int = 128,
                page_size: int = 16, n_pages: Optional[int] = None,
                prefix_reuse: bool = True,
                policy: "str | DecodePolicy" = "greedy",
                draft_k: int = 4,
                draft_ml: Optional[MultiLevelConfig] = None,
                mesh=None):
    if isinstance(policy, str):
        if policy == "greedy":
            pol: DecodePolicy = GreedyPolicy()
        elif policy == "speculative":
            pol = SpeculativePolicy(k=draft_k, ml=draft_ml)
        else:
            raise ValueError(f"unknown policy {policy!r}; expected one of "
                             f"{POLICIES} or a DecodePolicy instance")
    elif isinstance(policy, DecodePolicy):
        pol = policy
    else:
        raise TypeError(f"policy must be one of {POLICIES} or a DecodePolicy "
                        f"instance, got {type(policy).__name__}")
    if engine == "slots":
        if mesh is not None:
            raise ValueError("mesh-sharded decode requires the paged engine "
                             "(--engine paged); the slots oracle stays "
                             "single-device")
        return Server(cfg, batch=batch, max_seq=max_seq, policy=pol)
    if engine == "paged":
        return PagedServer(cfg, batch=batch, max_seq=max_seq,
                           page_size=page_size, n_pages=n_pages,
                           prefix_reuse=prefix_reuse, policy=pol, mesh=mesh)
    raise ValueError(f"unknown engine {engine!r}; expected one of {ENGINES}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--engine", choices=ENGINES, default="paged")
    ap.add_argument("--policy", choices=POLICIES, default="greedy")
    ap.add_argument("--draft-k", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--no-prefix-reuse", action="store_true")
    ap.add_argument("--mesh", default="",
                    help="DxM ('data','model') serving mesh, e.g. 1x2 -- "
                         "paged engine only; host CPU devices are forced "
                         "when the platform has fewer (smoke/tests)")
    ap.add_argument("--reload-from", default="",
                    help="checkpoint dir to poll for live weight reloads "
                         "(a trainer's --ckpt-dir); new steps swap in at "
                         "tick boundaries without dropping in-flight "
                         "requests")
    ap.add_argument("--reload-local", action="store_true",
                    help="treat --reload-from as a per-host local dir "
                         "(no shared FS; objects gather over the KV store)")
    ap.add_argument("--poll-every", type=int, default=1,
                    help="poll the reload manifest every N scheduler ticks")
    args = ap.parse_args()

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_cli_mesh

        mesh = make_cli_mesh(args.mesh)
    cfg = get_config(args.arch, smoke=args.smoke)
    srv = make_server(cfg, engine=args.engine, batch=args.batch,
                      max_seq=args.max_seq, page_size=args.page_size,
                      prefix_reuse=not args.no_prefix_reuse,
                      policy=args.policy, draft_k=args.draft_k, mesh=mesh)
    watcher = None
    if args.reload_from:
        mgr = CheckpointManager(args.reload_from, local=args.reload_local)
        watcher = ManifestWatcher(mgr, like=srv.params,
                                  shardings=getattr(srv, "_param_shardings",
                                                    None))
        srv.attach_watcher(watcher, poll_every=args.poll_every)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=rng.integers(4, 12)),
                    max_new=args.max_new) for i in range(args.requests)]
    t0 = time.time()
    done = srv.run(reqs)
    dt = time.time() - t0
    tok = sum(len(r.out) for r in done)
    print(f"[serve] engine={args.engine} policy={args.policy}: {len(done)} "
          f"requests, {tok} tokens in {dt:.1f}s "
          f"({tok/max(dt,1e-9):.1f} tok/s, batch={args.batch})")
    print(f"[serve] {srv.stats()}")
    if watcher is not None:
        print(f"[serve] reloads={srv.reloads} steps_seen={watcher.steps_seen} "
              f"steps_skipped={watcher.steps_skipped} "
              f"last={watcher.last_reload_stats}")
    for r in done[:3]:
        print(f"  req {r.rid}: prompt[:4]={r.prompt[:4].tolist()} -> out[:8]={r.out[:8]}")


if __name__ == "__main__":
    main()
