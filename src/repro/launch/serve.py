"""Batched serving driver: prefill + decode with continuous batching (lite).

A request queue feeds a fixed-width decode batch; finished sequences (EOS or
length budget) free their slot, the next request is prefilled into that slot
(per-slot KV-cache splice), and decode resumes -- the standard production
serving loop, at smoke scale on CPU and mesh-sharded on real hardware (the
decode step is exactly the function the decode_* dry-run cells compile).
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm as lm_lib
from repro.models.api import build_model, make_prefill_step, make_serve_step
from repro.param import Spec, is_spec


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S] int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)


def zeros_cache(cfg, batch: int, max_seq: int):
    cs = lm_lib.cache_specs(cfg, batch, max_seq)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype or cfg.compute_dtype),
                        cs, is_leaf=is_spec)


def splice_slot(batch_cache, slot_cache, slot: int):
    """Write a single-sequence prefill cache into slot ``slot`` of the batch cache."""
    return jax.tree.map(
        lambda b, s: b.at[:, slot].set(s[:, 0].astype(b.dtype)) if b.ndim >= 2 else b,
        batch_cache, slot_cache)


class Server:
    def __init__(self, cfg, batch: int = 4, max_seq: int = 128):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.batch = batch
        self.max_seq = max_seq
        self.params = self.model.init(jax.random.PRNGKey(0))
        self.prefill = jax.jit(make_prefill_step(self.model))
        self.decode = jax.jit(make_serve_step(self.model), donate_argnums=(1,))
        self.cache = zeros_cache(cfg, batch, max_seq)
        self.pos = np.zeros((batch,), np.int32)
        self.last_tok = np.zeros((batch,), np.int32)
        self.active: List[Optional[Request]] = [None] * batch
        self.done: List[Request] = []
        self.rejected: List[Request] = []  # oversized prompts (see admit)

    # -- continuous batching ------------------------------------------------
    def fits(self, req: Request) -> bool:
        """The admission invariant, in ONE place: decode must be able to
        write at least one token at a valid cache index."""
        return len(req.prompt) <= self.max_seq - 1

    def admit(self, req: Request) -> bool:
        """Prefill ``req`` into a free slot; False when all slots are busy.

        Raises ``ValueError`` for prompts that can never fit: a prompt needs
        ``len(prompt) <= max_seq - 1`` so decode can write at least one token
        -- longer ones used to crash in ``_splice`` (negative pad) or, worse,
        run with ``pos >= max_seq`` so the cache ``.at[pos].set`` silently
        dropped every out-of-range write and decoded garbage.
        """
        if not self.fits(req):
            raise ValueError(
                f"prompt of length {len(req.prompt)} cannot be admitted: "
                f"max_seq={self.max_seq} leaves no room to decode "
                f"(need len(prompt) <= max_seq - 1)")
        for slot in range(self.batch):
            if self.active[slot] is None:
                toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
                extras = {}
                if self.cfg.family == "vlm":
                    extras["img_embeds"] = jnp.ones(
                        (1, self.cfg.n_image_tokens, self.cfg.vision_dim or self.cfg.d_model),
                        self.cfg.compute_dtype)
                if self.cfg.family == "audio":
                    extras["enc_frames"] = jnp.ones(
                        (1, self.cfg.encoder_seq, self.cfg.d_model), self.cfg.compute_dtype)
                logits, pc = self.prefill(self.params, toks,
                                          extras.get("img_embeds"), extras.get("enc_frames"))
                # pad the single-sequence cache seq dim up to max_seq and splice
                self.cache = self._splice(pc, slot, len(req.prompt))
                self.active[slot] = req
                self.pos[slot] = len(req.prompt)
                self.last_tok[slot] = int(jnp.argmax(logits[0]))
                return True
        return False

    def _splice(self, prefill_cache, slot: int, prompt_len: int):
        def one(b, s):
            if b.ndim < 2:
                return b
            # seq-sized leaves: pad prefill cache (seq=prompt_len) to max_seq
            if s.shape[2:] == b.shape[2:] and s.shape[1] != b.shape[1] and s.ndim == b.ndim:
                pad = [(0, 0)] * s.ndim
                pad[1] = (0, b.shape[1] - s.shape[1])
                s = jnp.pad(s, pad)
            return b.at[slot].set(s[0].astype(b.dtype))

        # leaves layout: [layers, batch, ...] after scan stacking -> axis0=layers
        def one_stacked(b, s):
            if b.ndim < 3:
                return b
            if s.shape[2] != b.shape[2] and s.ndim == b.ndim and b.ndim >= 3 \
                    and s.shape[3:] == b.shape[3:]:
                pad = [(0, 0)] * s.ndim
                pad[2] = (0, b.shape[2] - s.shape[2])
                s = jnp.pad(s, pad)
            return b.at[:, slot].set(s[:, 0].astype(b.dtype))

        return jax.tree.map(one_stacked, self.cache, prefill_cache)

    def step(self) -> None:
        toks = jnp.asarray(self.last_tok)[:, None]
        pos = jnp.asarray(self.pos)
        logits, self.cache = self.decode(self.params, self.cache, toks, pos)
        nxt = np.asarray(jnp.argmax(logits, -1), np.int32)
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            req.out.append(int(nxt[slot]))
            # cap at the last valid cache index: a slot freed this tick must
            # never carry a pos the decode cache write would silently drop
            self.pos[slot] = min(self.pos[slot] + 1, self.max_seq - 1)
            self.last_tok[slot] = nxt[slot]
            if len(req.out) >= req.max_new or self.pos[slot] >= self.max_seq - 1:
                self.done.append(req)
                self.active[slot] = None

    def run(self, requests: List[Request], max_ticks: int = 10_000) -> List[Request]:
        """Drain ``requests``: admit into free slots, decode, recycle slots.

        Oversized prompts (see :meth:`admit`) are rejected up front into
        ``self.rejected`` instead of wedging the queue head forever.
        """
        queue = list(requests)
        ticks = 0
        while (queue or any(self.active)) and ticks < max_ticks:
            while queue:
                if not self.fits(queue[0]):
                    req = queue.pop(0)
                    self.rejected.append(req)
                    print(f"[serve] rejected req {req.rid}: prompt length "
                          f"{len(req.prompt)} > max_seq-1 = {self.max_seq - 1}")
                    continue
                if not self.admit(queue[0]):
                    break
                queue.pop(0)
            if any(a is not None for a in self.active):
                self.step()
            ticks += 1
        return self.done


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    srv = Server(cfg, batch=args.batch, max_seq=args.max_seq)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=rng.integers(4, 12)),
                    max_new=args.max_new) for i in range(args.requests)]
    t0 = time.time()
    done = srv.run(reqs)
    dt = time.time() - t0
    tok = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)} requests, {tok} tokens in {dt:.1f}s "
          f"({tok/max(dt,1e-9):.1f} tok/s, batch={args.batch})")
    for r in done[:3]:
        print(f"  req {r.rid}: prompt[:4]={r.prompt[:4].tolist()} -> out[:8]={r.out[:8]}")


if __name__ == "__main__":
    main()
