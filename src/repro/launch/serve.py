"""Batched serving driver: prefill + decode with continuous batching (lite).

Two engines share the request/queue semantics:

  * ``slots`` -- the original fixed-width decode batch over dense
    ``[batch, max_seq]`` caches; per-admit splice into a free slot.  Kept as
    the equivalence oracle (greedy decode must match token-for-token).
  * ``paged`` -- vLLM-style paged KV: cache leaves are a shared
    ``[n_pages, page_size, ...]`` pool, each request holds a block table of
    page ids (``launch/paging.py``), admission is by free-page count, and
    decode reads K/V through the block table (the ``paged_attention_decode``
    op in ``kernels/dispatch.py``) so per-step cost scales with the pages a
    request actually occupies, not ``max_seq``.  Prompt pages are keyed by a
    rolling blake2b digest, so requests sharing a prompt prefix reuse its
    (refcounted) pages and only prefill the non-shared tail.

See ``src/repro/launch/README.md`` for the architecture notes.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.paging import NULL_PAGE, BlockAllocator
from repro.models import lm as lm_lib
from repro.models.api import (build_model, make_paged_decode_step,
                              make_prefill_step, make_serve_step)
from repro.param import Spec, is_spec


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S] int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)


def zeros_cache(cfg, batch: int, max_seq: int):
    cs = lm_lib.cache_specs(cfg, batch, max_seq)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype or cfg.compute_dtype),
                        cs, is_leaf=is_spec)


def zeros_paged_cache(cfg, n_pages: int, page_size: int):
    cs = lm_lib.paged_cache_specs(cfg, n_pages, page_size)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype or cfg.compute_dtype),
                        cs, is_leaf=is_spec)


def _bucket(n: int, cap: Optional[int] = None) -> int:
    """Next power of two >= n (bounds the jit retrace count for shapes that
    vary with load: decode table width, extend tail length)."""
    b = 1
    while b < n:
        b *= 2
    return min(b, cap) if cap is not None else b


class Server:
    """Fixed-slot engine (dense caches) -- the equivalence oracle."""

    def __init__(self, cfg, batch: int = 4, max_seq: int = 128):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.batch = batch
        self.max_seq = max_seq
        self.params = self.model.init(jax.random.PRNGKey(0))
        self.prefill = jax.jit(make_prefill_step(self.model))
        self.decode = jax.jit(make_serve_step(self.model), donate_argnums=(1,))
        self.cache = zeros_cache(cfg, batch, max_seq)
        self.pos = np.zeros((batch,), np.int32)
        self.last_tok = np.zeros((batch,), np.int32)
        self.active: List[Optional[Request]] = [None] * batch
        self.done: List[Request] = []
        self.rejected: List[Request] = []  # oversized prompts (see admit)

    # -- continuous batching ------------------------------------------------
    def fits(self, req: Request) -> bool:
        """The admission invariant, in ONE place: decode must be able to
        write at least one token at a valid cache index."""
        return len(req.prompt) <= self.max_seq - 1

    def admit(self, req: Request) -> bool:
        """Prefill ``req`` into a free slot; False when all slots are busy.

        Raises ``ValueError`` for prompts that can never fit: a prompt needs
        ``len(prompt) <= max_seq - 1`` so decode can write at least one token
        -- longer ones used to crash in ``_splice`` (negative pad) or, worse,
        run with ``pos >= max_seq`` so the cache ``.at[pos].set`` silently
        dropped every out-of-range write and decoded garbage.
        """
        if not self.fits(req):
            raise ValueError(
                f"prompt of length {len(req.prompt)} cannot be admitted: "
                f"max_seq={self.max_seq} leaves no room to decode "
                f"(need len(prompt) <= max_seq - 1)")
        for slot in range(self.batch):
            if self.active[slot] is None:
                toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
                extras = {}
                if self.cfg.family == "vlm":
                    extras["img_embeds"] = jnp.ones(
                        (1, self.cfg.n_image_tokens, self.cfg.vision_dim or self.cfg.d_model),
                        self.cfg.compute_dtype)
                if self.cfg.family == "audio":
                    extras["enc_frames"] = jnp.ones(
                        (1, self.cfg.encoder_seq, self.cfg.d_model), self.cfg.compute_dtype)
                logits, pc = self.prefill(self.params, toks,
                                          extras.get("img_embeds"), extras.get("enc_frames"))
                # pad the single-sequence cache seq dim up to max_seq and splice
                self.cache = self._splice(pc, slot, len(req.prompt))
                self.active[slot] = req
                self.pos[slot] = len(req.prompt)
                self.last_tok[slot] = int(jnp.argmax(logits[0]))
                return True
        return False

    def _splice(self, prefill_cache, slot: int, prompt_len: int):
        # leaves layout: [layers, batch, ...] after scan stacking -> axis0=layers
        def one_stacked(b, s):
            if b.ndim < 3:
                return b
            if s.shape[2] != b.shape[2] and s.ndim == b.ndim and b.ndim >= 3 \
                    and s.shape[3:] == b.shape[3:]:
                pad = [(0, 0)] * s.ndim
                pad[2] = (0, b.shape[2] - s.shape[2])
                s = jnp.pad(s, pad)
            return b.at[:, slot].set(s[:, 0].astype(b.dtype))

        return jax.tree.map(one_stacked, self.cache, prefill_cache)

    def step(self) -> None:
        toks = jnp.asarray(self.last_tok)[:, None]
        pos = jnp.asarray(self.pos)
        logits, self.cache = self.decode(self.params, self.cache, toks, pos)
        nxt = np.asarray(jnp.argmax(logits, -1), np.int32)
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            req.out.append(int(nxt[slot]))
            # cap at the last valid cache index: a slot freed this tick must
            # never carry a pos the decode cache write would silently drop
            self.pos[slot] = min(self.pos[slot] + 1, self.max_seq - 1)
            self.last_tok[slot] = nxt[slot]
            if len(req.out) >= req.max_new or self.pos[slot] >= self.max_seq - 1:
                self.done.append(req)
                self.active[slot] = None

    def run(self, requests: List[Request], max_ticks: int = 10_000) -> List[Request]:
        """Drain ``requests``: admit into free slots, decode, recycle slots.

        Oversized prompts (see :meth:`admit`) are rejected up front into
        ``self.rejected`` instead of wedging the queue head forever.
        """
        queue = list(requests)
        ticks = 0
        while (queue or any(self.active)) and ticks < max_ticks:
            while queue:
                if not self.fits(queue[0]):
                    req = queue.pop(0)
                    self.rejected.append(req)
                    print(f"[serve] rejected req {req.rid}: prompt length "
                          f"{len(req.prompt)} > max_seq-1 = {self.max_seq - 1}")
                    continue
                if not self.admit(queue[0]):
                    break
                queue.pop(0)
            if any(a is not None for a in self.active):
                self.step()
            ticks += 1
        return self.done

    def reset(self) -> None:
        """Clear request state but keep params + compiled steps (bench reuse).
        Stale cache contents are safe: every admit overwrites its slot's rows
        and decode reads are position-masked."""
        self.pos[:] = 0
        self.last_tok[:] = 0
        self.active = [None] * self.batch
        self.done, self.rejected = [], []


class PagedServer:
    """Paged-KV engine: block tables over a shared page pool + prefix reuse.

    Admission reserves the request's worst-case page count up front
    (``ceil(min(len(prompt)+max_new, max_seq) / page_size)``), so an admitted
    request never stalls on allocation mid-decode.  Cache-hit prompts run a
    bucketed "extend" step over just the non-shared tail.
    """

    def __init__(self, cfg, batch: int = 4, max_seq: int = 128,
                 page_size: int = 16, n_pages: Optional[int] = None,
                 prefix_reuse: bool = True):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.batch = batch
        self.max_seq = max_seq
        self.page_size = page_size
        self.max_pages_per_req = -(-max_seq // page_size)
        if n_pages is None:
            # default: page-count parity with the slot engine's dense cache
            # (+1 for the reserved null page) -- admission then slot-bound
            n_pages = batch * self.max_pages_per_req + 1
        self.n_pages = n_pages
        self.params = self.model.init(jax.random.PRNGKey(0))
        self.prefill = jax.jit(make_prefill_step(self.model))
        self.paged_step = jax.jit(make_paged_decode_step(self.model),
                                  donate_argnums=(1,))
        self._write_prompt = jax.jit(self._write_prompt_impl, donate_argnums=(0,))
        self.pages = zeros_paged_cache(cfg, n_pages, page_size)
        self.alloc = BlockAllocator(n_pages, page_size, prefix_reuse=prefix_reuse)
        self.tables: List[Optional[List[int]]] = [None] * batch
        self.pos = np.zeros((batch,), np.int32)
        self.last_tok = np.zeros((batch,), np.int32)
        self.active: List[Optional[Request]] = [None] * batch
        self.done: List[Request] = []
        self.rejected: List[Request] = []
        self.prefill_tokens_computed = 0

    # -- stats ---------------------------------------------------------------
    @property
    def prefill_tokens_saved(self) -> int:
        return self.alloc.reused_tokens_total

    @property
    def pages_in_use_peak(self) -> int:
        return self.alloc.pool.in_use_peak

    def stats(self) -> Dict[str, Any]:
        return {
            "pages_in_use_peak": self.pages_in_use_peak,
            "pages_capacity": self.alloc.pool.capacity,
            "prefill_tokens_saved": self.prefill_tokens_saved,
            "prefill_tokens_computed": self.prefill_tokens_computed,
        }

    # -- continuous batching ------------------------------------------------
    def fits(self, req: Request) -> bool:
        """Admissible-ever check: room to decode one token (same invariant as
        the slot engine) AND a worst-case block table the pool could hold."""
        if len(req.prompt) > self.max_seq - 1:
            return False
        total = min(len(req.prompt) + req.max_new, self.max_seq)
        return self.alloc.pages_needed(total) <= self.alloc.pool.capacity

    def admit(self, req: Request) -> bool:
        """Reserve pages + prefill; False when no batch row / too few free
        pages right now.  Raises ``ValueError`` for never-admissible prompts
        (same contract as the slot engine's admit)."""
        if not self.fits(req):
            raise ValueError(
                f"prompt of length {len(req.prompt)} cannot be admitted: "
                f"max_seq={self.max_seq} leaves no room to decode "
                f"(need len(prompt) <= max_seq - 1 and a block table "
                f"<= {self.alloc.pool.capacity} pages)")
        row = next((i for i, r in enumerate(self.active) if r is None), None)
        if row is None:
            return False
        L = len(req.prompt)
        total_positions = min(L + req.max_new, self.max_seq)
        got = self.alloc.admit(req.rid, req.prompt, total_positions)
        if got is None:
            return False
        table, reuse_len = got
        if reuse_len == 0:
            # cold prompt: the SAME prefill step as the slot engine (first
            # token bitwise-identical), then scatter its cache into our pages
            toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
            logits, pc = self.prefill(self.params, toks, None, None)
            n_pg = -(-L // self.page_size)
            ids = jnp.asarray(table[:n_pg], jnp.int32)
            self.pages = self._write_prompt(self.pages, pc, ids)
            first = int(jnp.argmax(logits[0]))
            self.prefill_tokens_computed += L
        else:
            # warm prompt: run only the tail through a bucketed extend step;
            # reused pages are read through the block table (never rewritten)
            tail = np.asarray(req.prompt[reuse_len:], np.int32)
            S = len(tail)
            S_b = _bucket(S)
            toks = np.zeros((S_b,), np.int32)
            toks[S_b - S:] = tail
            positions = np.full((S_b,), -1, np.int32)  # left-pad -> null page
            positions[S_b - S:] = np.arange(reuse_len, L, dtype=np.int32)
            M_b = _bucket(len(table), cap=self.max_pages_per_req)
            bt = np.full((M_b,), NULL_PAGE, np.int32)
            bt[:len(table)] = table
            logits, self.pages = self.paged_step(
                self.params, self.pages, jnp.asarray(toks)[None],
                jnp.asarray(positions)[None], jnp.asarray(bt)[None])
            first = int(jnp.argmax(logits[0]))
            self.prefill_tokens_computed += S
        self.tables[row] = table
        self.active[row] = req
        self.pos[row] = L
        self.last_tok[row] = first
        return True

    def _write_prompt_impl(self, pages, prefill_cache, page_ids):
        """Scatter a prefill cache ([layers, 1, L, ...] leaves) into the page
        pool at ``page_ids`` ([n_pg] int32, logical page order)."""
        P = self.page_size
        n_pg = page_ids.shape[0]

        def one(pool, c):
            c = c[:, 0]  # [layers, L, ...]
            pad = [(0, 0)] * c.ndim
            pad[1] = (0, n_pg * P - c.shape[1])
            c = jnp.pad(c, pad)
            c = c.reshape(c.shape[0], n_pg, P, *c.shape[2:])
            return pool.at[:, page_ids].set(c.astype(pool.dtype))

        return jax.tree.map(one, pages, prefill_cache)

    def step(self) -> None:
        act = [i for i, r in enumerate(self.active) if r is not None]
        if not act:
            return
        M_b = _bucket(max(len(self.tables[i]) for i in act),
                      cap=self.max_pages_per_req)
        bt = np.full((self.batch, M_b), NULL_PAGE, np.int32)
        positions = np.full((self.batch, 1), -1, np.int32)  # idle row: len 0
        toks = np.zeros((self.batch, 1), np.int32)
        for i in act:
            bt[i, :len(self.tables[i])] = self.tables[i]
            positions[i, 0] = self.pos[i]
            toks[i, 0] = self.last_tok[i]
        logits, self.pages = self.paged_step(
            self.params, self.pages, jnp.asarray(toks),
            jnp.asarray(positions), jnp.asarray(bt))
        nxt = np.asarray(jnp.argmax(logits, -1), np.int32)
        for i in act:
            req = self.active[i]
            req.out.append(int(nxt[i]))
            self.pos[i] = min(self.pos[i] + 1, self.max_seq - 1)
            self.last_tok[i] = nxt[i]
            if len(req.out) >= req.max_new or self.pos[i] >= self.max_seq - 1:
                self.done.append(req)
                self.active[i] = None
                self.tables[i] = None
                self.alloc.complete(req.rid)

    def run(self, requests: List[Request], max_ticks: int = 10_000) -> List[Request]:
        """Same queue semantics as the slot engine: drain, rejecting
        never-admissible prompts up front; a request that merely lacks free
        pages *now* waits at the queue head for completions to free pages."""
        queue = list(requests)
        ticks = 0
        while (queue or any(self.active)) and ticks < max_ticks:
            while queue:
                if not self.fits(queue[0]):
                    req = queue.pop(0)
                    self.rejected.append(req)
                    print(f"[serve] rejected req {req.rid}: prompt length "
                          f"{len(req.prompt)} > max_seq-1 = {self.max_seq - 1}")
                    continue
                if not self.admit(queue[0]):
                    break
                queue.pop(0)
            if any(a is not None for a in self.active):
                self.step()
            ticks += 1
        return self.done

    def reset(self) -> None:
        """Clear pool/request state, keep params + compiled steps.  Stale page
        contents are safe: decode reads are length-masked and every admit
        writes the prompt range of its fresh pages before they are read."""
        self.alloc = BlockAllocator(self.n_pages, self.page_size,
                                    prefix_reuse=self.alloc.prefix is not None)
        self.tables = [None] * self.batch
        self.pos[:] = 0
        self.last_tok[:] = 0
        self.active = [None] * self.batch
        self.done, self.rejected = [], []
        self.prefill_tokens_computed = 0


def make_server(cfg, engine: str = "paged", batch: int = 4, max_seq: int = 128,
                page_size: int = 16, n_pages: Optional[int] = None,
                prefix_reuse: bool = True):
    if engine == "slots":
        return Server(cfg, batch=batch, max_seq=max_seq)
    if engine == "paged":
        return PagedServer(cfg, batch=batch, max_seq=max_seq,
                           page_size=page_size, n_pages=n_pages,
                           prefix_reuse=prefix_reuse)
    raise ValueError(f"unknown engine {engine!r}; expected 'paged' or 'slots'")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--engine", choices=("paged", "slots"), default="paged")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--no-prefix-reuse", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    srv = make_server(cfg, engine=args.engine, batch=args.batch,
                      max_seq=args.max_seq, page_size=args.page_size,
                      prefix_reuse=not args.no_prefix_reuse)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=rng.integers(4, 12)),
                    max_new=args.max_new) for i in range(args.requests)]
    t0 = time.time()
    done = srv.run(reqs)
    dt = time.time() - t0
    tok = sum(len(r.out) for r in done)
    print(f"[serve] engine={args.engine}: {len(done)} requests, {tok} tokens "
          f"in {dt:.1f}s ({tok/max(dt,1e-9):.1f} tok/s, batch={args.batch})")
    if isinstance(srv, PagedServer):
        print(f"[serve] {srv.stats()}")
    for r in done[:3]:
        print(f"  req {r.rid}: prompt[:4]={r.prompt[:4].tolist()} -> out[:8]={r.out[:8]}")


if __name__ == "__main__":
    main()
