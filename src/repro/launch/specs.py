"""ShapeDtypeStruct stand-ins for every (arch x shape) cell: weak-type-correct,
shardable, no device allocation.  Also centralizes the per-arch training
hyperparameters used by the dry-run (microbatch/grad-accum, optimizer dtype,
mixed-precision policy for the 100B+ models)."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig, TrainConfig
from repro.models import lm as lm_lib
from repro.param import Spec

# grad-accum per arch for the train_4k cell: keeps per-device microbatch
# activations (and the MoE dispatch tensors) inside HBM.
TRAIN_ACCUM: Dict[str, int] = {
    "deepseek-v3-671b": 8,
    "jamba-1.5-large-398b": 8,
    "command-r-35b": 4,
    "qwen3-14b": 4,
    "phi3.5-moe-42b-a6.6b": 4,
    "llama-3.2-vision-11b": 4,
    "whisper-large-v3": 2,
    "qwen3-4b": 2,
    "tinyllama-1.1b": 2,
    "xlstm-125m": 1,
}

# >=100B params: bf16 parameters + bf16 Adam moments (DESIGN.md §8.3);
# everything else keeps f32 master params / moments.
BF16_STATE = ("deepseek-v3-671b", "jamba-1.5-large-398b")


def train_config_for(cfg: ModelConfig, shape: ShapeConfig) -> TrainConfig:
    accum = TRAIN_ACCUM.get(cfg.name, 1) if shape.kind == "train" else 1
    opt_dtype = jnp.bfloat16 if cfg.name in BF16_STATE else jnp.float32
    # per-step weight pre-gather was measured on qwen3-14b train_4k: it cuts
    # all-gather OP COUNT 3.2x (latency win at 1000+ nodes) but adds gathered-
    # copy HBM traffic that worsens the 16x16 memory-bound step (19.5->25.9s)
    # -- refuted as a default; kept as an option (EXPERIMENTS.md §Perf q.3).
    return TrainConfig(steps=10000, warmup_steps=500, grad_accum=accum,
                       opt_dtype=opt_dtype, batch_size=shape.global_batch,
                       seq_len=shape.seq_len, pregather_params=False)


def model_config_for(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    if cfg.name in BF16_STATE and cfg.param_dtype != jnp.bfloat16:
        cfg = cfg.replace(param_dtype=jnp.bfloat16)
    if shape.kind == "prefill" and cfg.causal:
        # no-grad forward: the triangular pairs path is FLOP-exact (the
        # rectangular flash forward would waste ~2x attention FLOPs).
        # Context-parallel attention is disabled here: the pairs scan
        # dynamic-slices q blocks along the sequence, which under a
        # seq-sharded constraint gathers per block pair (measured 5x
        # regression on qwen3-14b/whisper prefill).
        cfg = cfg.replace(attn_impl="pairs", attn_seq_shard=False)
    return cfg


def _tok(shape: Tuple[int, ...]):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def train_inputs(cfg: ModelConfig, shape: ShapeConfig, accum: int):
    """Returns (struct_tree, axes_tree) for the training batch.

    With accum > 1 the global batch is split into ``accum`` leading
    microbatches (scanned in the step function)."""
    B, S = shape.global_batch, shape.seq_len
    lead: Tuple[int, ...] = (accum, B // accum) if accum > 1 else (B,)
    lax: Tuple[str, ...] = ("accum", "batch") if accum > 1 else ("batch",)
    batch = {"tokens": _tok(lead + (S,)), "labels": _tok(lead + (S,))}
    axes = {"tokens": lax + ("seq",), "labels": lax + ("seq",)}
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.ShapeDtypeStruct(
            lead + (cfg.n_image_tokens, cfg.vision_dim or cfg.d_model), jnp.bfloat16)
        axes["img_embeds"] = lax + ("img_seq", "vision_embed")
    if cfg.family == "audio":
        batch["enc_frames"] = jax.ShapeDtypeStruct(
            lead + (cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        axes["enc_frames"] = lax + ("enc_seq", "act_embed")
    return batch, axes


def prefill_inputs(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    batch: Dict[str, Any] = {"tokens": _tok((B, S))}
    axes: Dict[str, Any] = {"tokens": ("batch", "seq")}
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_image_tokens, cfg.vision_dim or cfg.d_model), jnp.bfloat16)
        axes["img_embeds"] = ("batch", "img_seq", "vision_embed")
    if cfg.family == "audio":
        batch["enc_frames"] = jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        axes["enc_frames"] = ("batch", "enc_seq", "act_embed")
    return batch, axes


def decode_inputs(cfg: ModelConfig, shape: ShapeConfig):
    """(tokens, pos) structs + the cache Spec tree (specs carry axes/dtypes)."""
    B = shape.global_batch
    toks = _tok((B, 1))
    pos = _tok((B,))
    cache_specs = lm_lib.cache_specs(cfg, B, shape.seq_len)
    return toks, pos, cache_specs
