"""Compiled-artifact analysis: HLO collective parsing + three-term roofline.

The SPMD module is the *per-device* program, so ``cost_analysis()`` FLOPs /
bytes and the parsed collective bytes are per-device quantities; the roofline
terms below follow the assignment formulas with global = per_device x chips
(the chips cancel: term = per_device / per-chip-rate).

Collective byte model (per device, ring algorithms, group size g):
  all-reduce       2 * B * (g-1)/g      (RS + AG phases)
  all-gather           B * (g-1)/g      (B = gathered output)
  reduce-scatter   B_out * (g-1)        (input = B_out * g)
  all-to-all           B * (g-1)/g
  collective-permute   B
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+ = (?P<result>.*?) "
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")


def _result_bytes(result: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(result):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return 2


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-op-kind counts and per-device ICI bytes from compiled HLO text."""
    stats: Dict[str, Dict[str, float]] = {}
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        op = m.group("op")
        if "-done(" in line:  # async pairs: count the -start only
            continue
        B = _result_bytes(m.group("result"))
        g = _group_size(line)
        if g <= 1:
            continue
        frac = (g - 1) / g
        if op == "all-reduce":
            moved = 2 * B * frac
        elif op == "all-gather":
            moved = B * frac
        elif op == "reduce-scatter":
            moved = B * (g - 1)
        elif op == "all-to-all":
            moved = B * frac
        else:  # collective-permute
            moved = B
        s = stats.setdefault(op, {"count": 0, "bytes": 0.0})
        s["count"] += 1
        s["bytes"] += moved
    stats["total"] = {"count": sum(s["count"] for k, s in stats.items() if k != "total"),
                      "bytes": sum(s["bytes"] for k, s in stats.items() if k != "total")}
    return stats


@dataclasses.dataclass
class Roofline:
    """Three-term roofline (seconds) for one compiled step on the target mesh."""

    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    n_devices: int
    model_flops: float  # 6*N*D reference (global)

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / global HLO FLOPs (catches remat/redundancy waste)."""
        hlo_global = self.flops_per_device * self.n_devices
        return self.model_flops / hlo_global if hlo_global else float("nan")

    @property
    def step_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of peak at the roofline-modelled step time."""
        useful = self.model_flops / self.n_devices / PEAK_FLOPS_BF16
        return useful / self.step_time if self.step_time else float("nan")

    def to_dict(self) -> Dict[str, float]:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "n_devices": self.n_devices,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def analyze_compiled(compiled, n_devices: int, model_flops: float) -> Tuple[Roofline, Dict]:
    """Roofline terms from the compiled artifact.

    Uses the trip-count-aware HLO walk (launch/hlo_cost.py) because XLA's own
    ``cost_analysis()`` counts ``while`` bodies once -- wrong for every
    scan-based model.  The raw XLA numbers ride along for reference.
    """
    from repro.launch.hlo_cost import analyze_text

    t = analyze_text(compiled.as_text())
    colls = t["collectives"]
    rl = Roofline(flops_per_device=float(t["flops"]), bytes_per_device=float(t["bytes"]),
                  coll_bytes_per_device=colls.get("total", {}).get("bytes", 0.0),
                  n_devices=n_devices, model_flops=model_flops)
    return rl, colls


def memory_summary(compiled) -> Dict[str, float]:
    m = compiled.memory_analysis()
    return {
        "argument_bytes": getattr(m, "argument_size_in_bytes", 0),
        "output_bytes": getattr(m, "output_size_in_bytes", 0),
        "temp_bytes": getattr(m, "temp_size_in_bytes", 0),
        "alias_bytes": getattr(m, "alias_size_in_bytes", 0),
        "peak_bytes_est": (getattr(m, "argument_size_in_bytes", 0)
                           + getattr(m, "output_size_in_bytes", 0)
                           + getattr(m, "temp_size_in_bytes", 0)
                           - getattr(m, "alias_size_in_bytes", 0)),
        "code_bytes": getattr(m, "generated_code_size_in_bytes", 0),
    }
