"""Production-shaped training driver.

Runs real training (proxy/smoke scale on this CPU container; the same code
path drives a sharded mesh via ``--mesh DxM``), with:

* V-cycle multi-level schedule (``--vcycle``) or plain from-scratch,
* mesh parallelism: ``--mesh 2x4`` builds a ("data", "model") mesh (host CPU
  devices are forced when needed, so the flag works on a laptop), enters the
  sharding-rules context, and jits every train step -- per V-cycle level --
  with explicit ``in_shardings``/``out_shardings`` derived from the level's
  Spec tree, donation included; level transitions (coalesce /
  de-coalesce+interpolate) project sharded-in, sharded-out onto the target
  level's layout,
* fault tolerance: atomic async checkpointing every ``--ckpt-every`` steps
  with auto-resume; V-cycle runs save and restore the full mid-cycle state
  (phase, level, step-within-segment, FLOPs history, interpolation stashes),
  so a SIGKILL at any point -- including mid-upward-sweep -- resumes
  equivalently to an uninterrupted run (scripts/smoke_resume.sh drills this),
* elastic re-shard on restore: checkpoints store logical (unsharded) arrays,
  so a run saved under ``--mesh 1x2`` resumes under ``--mesh 2x1`` (or no
  mesh at all) -- including mid-upward-sweep with the ``params_before_*``
  stashes re-sharded (tests/test_distributed.py pins the equivalence),
* multi-process (multi-host) training: ``--coordinator ADDR
  --num-processes N --process-id I`` runs ``jax.distributed.initialize``
  (CPU-portable: gloo collectives + forced host devices, so CI drills the
  same path as a real slice) and the ``--mesh`` then SPANS processes.
  Process roles are explicit -- logging, the watchdog and the checkpoint
  manifest publish live on process 0 only; every process feeds its own data
  shard and writes only its addressable checkpoint shards (coordinated save
  with a barrier before publish, see ``repro.checkpoint``); checkpoints stay
  logical, so a run saved by 2 processes resumes under 1 (and vice versa),
* preemption awareness: SIGTERM on ANY ONE process propagates through an
  all-reduced drain flag, so every process runs the SAME final blocking
  checkpoint at one agreed step boundary and exits 0, instead of hoping the
  cadence saved recently (scripts/smoke_resume.sh acts 2+3 drill this),
* deterministic host-sharded synthetic data keyed on
  ``repro.distributed.data_shard_index`` (any host can regenerate any
  shard -> straggler/elastic-safe; a data-parallel process's shard is its
  slice of the process-count-invariant global batch, so runs agree across
  process counts),
* a step-time watchdog that flags stragglers (steps slower than ``factor`` x
  the median of PRIOR step times are logged) on both drivers.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b --smoke \
      --steps 50 --ckpt-dir /tmp/ck
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b --smoke \
      --vcycle --mesh 1x2 --steps 20 --ckpt-dir /tmp/ck
  # multi-process (run one per host / terminal; same args except --process-id)
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b --smoke \
      --vcycle --mesh 2x1 --steps 20 --ckpt-dir /tmp/ck \
      --coordinator 127.0.0.1:9876 --num-processes 2 --process-id 0
"""
from __future__ import annotations

import argparse
import contextlib
import json
import signal
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.config import SHAPES, MultiLevelConfig, TrainConfig
from repro.configs import get_config
from repro.core import flops as flops_lib
from repro.core import operators as ops
from repro.core.vcycle import History, VCycleOutput, VCycleRunner, VCycleState
from repro.data import MarkovLM, lm_batch, masked_lm_batch, vision_batch
from repro.distributed import (any_process_flag, as_global_batch_fn,
                               batch_like, batch_shardings, data_shard_index,
                               is_primary, mesh_ctx, put_global_tree)
from repro.launch.mesh import init_distributed, make_cli_mesh, parse_mesh_arg
from repro.models.api import (build_model, init_train_state, make_train_step,
                              train_state_shardings, zero_train_state)
from repro.optim import adamw_init


def make_batch_fn(cfg, tc: TrainConfig, shard: int = 0):
    if cfg.family == "vit":
        from repro.models.vit import n_patches, patch_dim

        return lambda step: vision_batch(tc.seed, step, tc.batch_size, n_patches(cfg),
                                         patch_dim(cfg), cfg.n_classes, shard)
    chain = MarkovLM(cfg.vocab_size)
    if cfg.family == "encoder":
        mask_id = cfg.vocab_size - 1
        return lambda step: masked_lm_batch(chain, tc.seed, step, tc.batch_size,
                                            tc.seq_len, mask_id, shard=shard)

    def fn(step):
        b = lm_batch(chain, tc.seed, step, tc.batch_size, tc.seq_len, shard)
        if cfg.family == "vlm":
            b["img_embeds"] = jnp.ones(
                (tc.batch_size, cfg.n_image_tokens, cfg.vision_dim or cfg.d_model),
                cfg.compute_dtype)
        if cfg.family == "audio":
            b["enc_frames"] = jnp.ones((tc.batch_size, cfg.encoder_seq, cfg.d_model),
                                       cfg.compute_dtype)
        return b

    return fn


def make_driver_batch_fn(cfg, tc: TrainConfig, mesh):
    """The launcher's per-process batch stream.

    Single-process: the canonical shard named by ``data_shard_index`` (0).
    Multi-process: every process regenerates the SAME canonical global batch
    (``data/synthetic`` batches are pure functions of (seed, step, shard), so
    any host can) and materializes only the rows its data-axis coordinate --
    ``data_shard_index(mesh)`` -- addresses.  The global data stream is
    therefore invariant to the process count, which is what makes the
    2-process-vs-1-process equivalence and cross-process-count resume
    well-posed (tests/test_multiprocess.py pins both).
    """
    if jax.process_count() > 1:
        return as_global_batch_fn(make_batch_fn(cfg, tc, shard=0), mesh)
    return make_batch_fn(cfg, tc, shard=data_shard_index(mesh))


class Watchdog:
    """Step-time straggler detector (multi-host analogue: per-host heartbeat)."""

    def __init__(self, factor: float = 3.0):
        self.times: list = []
        self.factor = factor
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        # median over PRIOR samples only: appending first let the straggler
        # dilute its own baseline (a spike entering the window shifts the
        # median up and can mask itself right at the flagging threshold).
        # Only the trailing window is ever read, so don't grow unbounded
        # over multi-day runs.
        prior = self.times[-50:]
        self.times = prior + [dt]
        if len(prior) >= 10:
            med = float(np.median(prior))
            if dt > self.factor * med:
                self.flagged += 1
                print(f"[watchdog] slow step: {dt*1e3:.0f}ms vs median {med*1e3:.0f}ms")
                return True
        return False


class PreemptionGuard:
    """SIGTERM-aware preemption notice, coordinated across processes.

    The handler only sets a flag (async-signal-safe); the training loops poll
    :meth:`should_stop` exactly once per step and run ONE final *blocking*
    checkpoint before exiting 0 -- preempted pods save at the notice instead
    of waiting for the ``--ckpt-every`` cadence.

    In multi-process runs ``should_stop`` reduces the flag across processes,
    so a SIGTERM delivered to ANY ONE process drains the whole job: every
    process sees the notice at the same step boundary, runs the same
    coordinated final save, and exits 0 together.  Because the poll is a
    collective, the drivers call it unconditionally each step on every
    process.

    The reduction itself is FUSED into the compiled train step when a
    ``distributed.FusedDrainFlag`` is attached (both drivers do, on
    multi-process meshes): the flag enters the step as one int32 element per
    device and comes back as a replicated ``metrics["drain"]`` scalar, so the
    cross-process OR rides the step's existing collective schedule instead of
    a dedicated per-step ``process_allgather``.  Without one attached,
    ``should_stop`` falls back to the explicit allgather.
    """

    def __init__(self):
        self.triggered = False
        self.fused = None  # a FusedDrainFlag once attach() is called

    def attach(self, drain_flag):
        """Bind a ``FusedDrainFlag``: ``should_stop`` reads the last fused
        step's replicated drain scalar instead of all-gathering."""
        self.fused = drain_flag
        drain_flag.guard = self
        return drain_flag

    def install(self, signals=(signal.SIGTERM,)) -> "PreemptionGuard":
        for s in signals:
            try:
                signal.signal(s, self._handler)
            except ValueError:  # not the main thread (e.g. embedded in a test)
                break
        return self

    def _handler(self, signum, frame):
        self.triggered = True
        print(f"[preempt] caught signal {signum}; will checkpoint and exit at "
              "the next step boundary", flush=True)

    def should_stop(self) -> bool:
        """True when ANY process holds a preemption notice (collective in
        multi-process runs -- call symmetrically, once per step)."""
        if self.fused is not None:
            # the OR already ran inside the step; local flag covers the
            # pre-first-step window
            return self.fused.last() or (jax.process_count() == 1
                                         and self.triggered)
        return any_process_flag(self.triggered)


def _report_reduce_probe(tc: TrainConfig, verbose: bool) -> None:
    """Assert the compressed path actually ran (trace-time call probe), not
    just that the flag was set -- and say so, greppable, for the CLI drills."""
    if tc.grad_compression != "int8_ef":
        return
    from repro.distributed.compression import ef_psum_calls

    n = ef_psum_calls()
    if n <= 0:
        raise RuntimeError(
            "--grad-compression int8_ef was requested but ef_int8_psum was "
            "never traced into a compiled step")
    if verbose:
        print(f"[reduce] probe: ef_int8_psum traced into {n} compiled step(s)",
              flush=True)


def train_plain(cfg, tc: TrainConfig, *, ckpt: Optional[CheckpointManager],
                ckpt_every: int, verbose: bool = True, mesh=None,
                preempt: Optional[PreemptionGuard] = None):
    model = build_model(cfg)
    batch_fn = make_driver_batch_fn(cfg, tc, mesh)
    params, opt = init_train_state(model, tc, jax.random.PRNGKey(tc.seed))
    psh = osh = bsh = efsh = None
    gr = ef = None
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec

        from repro.distributed import make_grad_reduce

        gr = make_grad_reduce(tc.grad_compression, mesh)
        psh, osh = train_state_shardings(model, tc, mesh)
        if gr is not None and gr.stateful:
            efsh = gr.state_shardings(psh, mesh)
        # put_global_tree: plain device_put when the mesh is local, shard-wise
        # landing when it spans processes (init is deterministic, every
        # process holds the full value)
        params = put_global_tree(params, psh)
        opt = put_global_tree(opt, osh)
        if efsh is not None:
            ef = put_global_tree(gr.init_state(params), efsh)
        bsh = batch_shardings(batch_like(batch_fn), mesh)
        metrics_sh = NamedSharding(mesh, PartitionSpec())  # host-readable everywhere
    start = 0
    if ckpt is not None:
        # elastic restore: the checkpoint holds logical arrays, so target
        # shardings may describe a different mesh (or process count) than the
        # one that saved
        has_ef = bool((ckpt.latest() or {}).get("meta", {}).get("has_ef"))
        if has_ef and efsh is None:
            raise ValueError(
                "checkpoint carries grad-reduction (EF) state; resume with "
                "--grad-compression int8_ef on the same mesh shape")
        like = {"params": params, "opt": opt}
        sh = None if mesh is None else {"params": psh, "opt": osh}
        if has_ef:
            like["ef"], sh["ef"] = ef, efsh
        restored, meta = ckpt.restore(like, shardings=sh)
        if restored is not None:
            params, opt = restored["params"], restored["opt"]
            if has_ef:
                ef = restored["ef"]
            start = int(meta.get("step", 0))
            if verbose:
                print(f"[train] resumed from step {start}")
    if mesh is None:
        step_fn = jax.jit(make_train_step(model, tc), donate_argnums=(0, 1))
    else:
        drain = None
        if preempt is not None and jax.process_count() > 1:
            from repro.distributed import FusedDrainFlag

            drain = preempt.attach(FusedDrainFlag(mesh, guard=preempt))
        base_step = make_train_step(model, tc, grad_reduce=gr,
                                    mesh=mesh if gr is not None else None)
        if gr is not None:
            # 4-ary (params, opt, ef, batch) step with the reduction strategy
            # injected; wrapped back to the loop's 3-ary shape below
            if drain is not None:
                fn4 = drain.wrap_step(
                    base_step,
                    in_shardings=(psh, osh, efsh, bsh),
                    out_shardings=(psh, osh, efsh, metrics_sh),
                    donate_argnums=(0, 1, 2))
            else:
                fn4 = jax.jit(base_step,
                              in_shardings=(psh, osh, efsh, bsh),
                              out_shardings=(psh, osh, efsh, metrics_sh),
                              donate_argnums=(0, 1, 2))

            def step_fn(p, o, b):
                nonlocal ef
                p, o, ef, m = fn4(p, o, ef, b)
                return p, o, m
        elif drain is not None:
            step_fn = drain.wrap_step(base_step,
                                      in_shardings=(psh, osh, bsh),
                                      out_shardings=(psh, osh, metrics_sh))
        else:
            step_fn = jax.jit(base_step,
                              in_shardings=(psh, osh, bsh),
                              out_shardings=(psh, osh, metrics_sh),
                              donate_argnums=(0, 1))
    def _snapshot(step):
        payload = {"params": params, "opt": opt}
        if ef is not None:
            payload["ef"] = ef  # EF residuals resume with the run (unbiasedness)
        return payload, {"step": step, "has_ef": ef is not None}

    # the watchdog is a process-0 role (single-process runs are process 0)
    wd = Watchdog() if is_primary() else None
    for i in range(start, tc.steps):
        t0 = time.time()
        params, opt, metrics = step_fn(params, opt, batch_fn(i))
        # heartbeat EVERY step (a straggler on a non-log step must be seen);
        # block on device completion only -- the host metric fetch stays on
        # log steps
        jax.block_until_ready(metrics["loss"])
        if wd is not None:
            wd.observe(time.time() - t0)
        # coordinated drain: polled unconditionally once per step on every
        # process (it is a collective), so a SIGTERM on any ONE process makes
        # ALL processes save the same step and exit 0 together
        if preempt is not None and preempt.should_stop():
            if ckpt is not None:
                payload, meta = _snapshot(i + 1)
                ckpt.save(i + 1, payload, meta=meta, blocking=True)
                print(f"[preempt] SIGTERM: final checkpoint at step {i + 1}; "
                      "exiting", flush=True)
            raise SystemExit(0)
        if i % tc.log_every == 0:
            loss = float(metrics["loss"])
            if verbose:
                print(f"[train] step {i} loss {loss:.4f} lr {float(metrics['lr']):.2e}")
        if ckpt is not None and ckpt_every and i and i % ckpt_every == 0:
            payload, meta = _snapshot(i + 1)
            ckpt.save(i, payload, meta=meta, blocking=False)
    if ckpt is not None:
        payload, meta = _snapshot(tc.steps)
        ckpt.save(tc.steps, payload, meta=meta)
    _report_reduce_probe(tc, verbose)
    return params


def _schedule_meta(plan) -> list:
    """JSON form of a segment schedule, stored with every mid-cycle
    checkpoint so restore can refuse a mismatched (phase, level, step)
    addressing instead of silently training the wrong schedule."""
    return [[p.phase, p.level, p.steps] for p in plan]


def make_vcycle_save_cb(ckpt: CheckpointManager, schedule=None):
    """A ``VCycleRunner`` checkpoint hook writing the full resumable state.

    Array payload: the in-segment ``params`` + ``opt`` plus every stashed
    ``params_before_<level>`` tree (needed by Interpolation on the upward
    sweep).  Manifest metadata: (phase, level, seg_index, seg_step,
    global_step, cum_flops, stashed_levels, history) plus the segment
    ``schedule`` (pass the runner's ``plan``) that anchors those indices.
    Saves are async -- ``CheckpointManager`` snapshots to host before the
    training loop mutates anything.
    """
    sched = _schedule_meta(schedule) if schedule is not None else None

    def save_cb(state: VCycleState, params, opt_state, blocking: bool = False) -> None:
        stashed = sorted(state.params_before)
        payload = {"params": params, "opt": opt_state,
                   **{f"params_before_{l}": state.params_before[l] for l in stashed}}
        if state.ef is not None:
            # carried EF residuals: resuming without them would re-bias the
            # first post-restore steps (the unbiasedness guarantee is exactly
            # that transmitted + carried == true gradient over time)
            payload["ef"] = state.ef
        meta = {
            "step": state.global_step, "phase": state.phase, "level": state.level,
            "seg_index": state.seg_index, "seg_step": state.seg_step,
            "global_step": state.global_step, "cum_flops": state.cum_flops,
            "stashed_levels": stashed, "history": state.history.to_dict(),
            "has_ef": state.ef is not None}
        if sched is not None:
            meta["schedule"] = sched
        ckpt.save(state.global_step, payload, meta=meta, blocking=blocking)

    return save_cb


def restore_vcycle_state(ckpt: CheckpointManager, runner: VCycleRunner,
                         tc: TrainConfig):
    """(state, params, opt_state) from the newest mid-cycle checkpoint.

    Inverse of :func:`make_vcycle_save_cb`: like-trees come from
    ``zero_train_state`` of the checkpointed level's model, so no RNG or
    training work happens before the arrays land.  When ``runner`` carries a
    mesh, every restored tree -- the in-segment params/opt AND each
    ``params_before_<level>`` stash -- is device_put straight onto that
    runner's per-level layouts, so a checkpoint written under mesh A resumes
    under mesh B (elastic mid-V-cycle re-shard).  Raises ``ValueError`` if
    the checkpoint's segment schedule (or position) does not fit ``runner``'s
    -- resuming a checkpoint under different ``--steps``/``--levels`` would
    otherwise silently train the wrong schedule.
    """
    m = ckpt.latest()
    meta = m["meta"]
    current = _schedule_meta(runner.plan)
    saved = meta.get("schedule")
    if saved is not None and [list(s) for s in saved] != current:
        raise ValueError(
            f"checkpoint was written under a different V-cycle schedule "
            f"({saved} vs current {current}); restart with the original "
            f"--steps/--levels or use a fresh --ckpt-dir")
    seg_index = int(meta["seg_index"])
    if (seg_index >= len(runner.plan)
            or int(meta["seg_step"]) > runner.plan[seg_index].steps):
        raise ValueError(
            f"checkpoint position (seg_index={seg_index}, "
            f"seg_step={meta['seg_step']}) lies outside the current schedule "
            f"{current}; restart with the original --steps/--levels")
    level = int(meta["level"])
    has_ef = bool(meta.get("has_ef"))
    gr = runner.grad_reduce
    if has_ef and (gr is None or not gr.stateful):
        raise ValueError(
            "checkpoint carries grad-reduction (EF) state; resume with "
            "--grad-compression int8_ef on the same mesh shape")
    like_p, like_o = zero_train_state(runner.models[level], tc)
    like = {"params": like_p, "opt": like_o}
    if has_ef:
        like["ef"] = zero_train_state(runner.models[level], tc,
                                      grad_reduce=gr)[2]
    stashed = [int(l) for l in meta.get("stashed_levels", [])]
    for l in stashed:
        like[f"params_before_{l}"] = zero_train_state(runner.models[l], tc)[0]
    shardings = None
    if runner.mesh is not None:
        psh, osh = runner.level_shardings(level)
        shardings = {"params": psh, "opt": osh}
        if has_ef:
            shardings["ef"] = runner.ef_shardings(level)
        for l in stashed:
            shardings[f"params_before_{l}"] = runner.level_shardings(l)[0]
    restored, meta = ckpt.restore(like, shardings=shardings)
    state = VCycleState(
        phase=meta["phase"], level=level,
        seg_index=int(meta["seg_index"]), seg_step=int(meta["seg_step"]),
        global_step=int(meta["global_step"]), cum_flops=float(meta["cum_flops"]),
        history=History(**{k: list(v) for k, v in meta["history"].items()}),
        params_before={l: restored[f"params_before_{l}"] for l in stashed},
        ef=restored.get("ef"))
    return state, restored["params"], restored["opt"]


def train_vcycle_ckpt(cfg, ml: MultiLevelConfig, tc: TrainConfig, *,
                      ckpt: Optional[CheckpointManager], ckpt_every: int,
                      verbose: bool = True, mesh=None,
                      preempt: Optional[PreemptionGuard] = None):
    """V-cycle with real (phase, level, step) checkpoint/resume.

    Every ``ckpt_every`` global steps the runner's hook saves
    ``{params, opt, params_before_*}`` + V-cycle state metadata (async,
    atomic).  On restart this function restores the newest checkpoint and
    re-enters ``VCycleRunner.run`` at the exact (phase, level, seg_step) --
    including mid-upward-sweep, where the pending de-coalesce/interpolate
    transition is replayed deterministically from the in-segment params.
    Deterministic ``batch_fn(global_step)`` data order makes the resumed run
    equivalent to an uninterrupted one (tests/test_resume.py asserts
    allclose on final params and History).  A terminal "phase=done"
    checkpoint makes re-invocation after completion a no-op.

    ``mesh`` shards the whole cycle (per-level explicit-sharding train steps
    and sharded level transitions); because checkpoints store logical arrays,
    the mesh -- and the PROCESS COUNT -- at restore time may differ from the
    one that saved (a 2-process save resumes under 1 process and vice versa).
    The runner's per-step hook carries the straggler watchdog heartbeat and
    the coordinated preemption poll: a SIGTERM on any one process drains ALL
    processes through one final BLOCKING checkpoint at the same global step,
    followed by a clean exit 0.
    """
    batch_fn = make_driver_batch_fn(cfg, tc, mesh)
    drain = None
    if mesh is not None and preempt is not None and jax.process_count() > 1:
        from repro.distributed import FusedDrainFlag

        drain = preempt.attach(FusedDrainFlag(mesh, guard=preempt))
    runner = VCycleRunner(cfg, ml, tc, batch_fn, seed=tc.seed, verbose=verbose,
                          mesh=mesh, drain_flag=drain)
    state = params = opt = None
    if ckpt is not None:
        m = ckpt.latest()
        meta = (m or {}).get("meta", {})
        if "phase" in meta:
            if meta["phase"] == "done":
                like_p, _ = zero_train_state(runner.models[0], tc)
                restored, _ = ckpt.restore(
                    {"params": like_p},
                    shardings=(None if mesh is None
                               else {"params": runner.level_shardings(0)[0]}))
                if verbose:
                    print("[vcycle] checkpoint already complete; returning saved params")
                return VCycleOutput(
                    params=restored["params"],
                    history=History(**{k: list(v) for k, v in
                                       meta.get("history", {}).items()}),
                    configs=runner.cfgs,
                    total_flops=float(meta.get("cum_flops", 0.0)))
            state, params, opt = restore_vcycle_state(ckpt, runner, tc)
            if verbose:
                print(f"[vcycle] resumed at phase={state.phase} level={state.level} "
                      f"seg_step={state.seg_step} global_step={state.global_step}")
    save_cb = (make_vcycle_save_cb(ckpt, schedule=runner.plan)
               if ckpt is not None else None)
    # one watchdog PER LEVEL: a half-width level's steps are ~8x cheaper, so a
    # shared median would flag every full-size step of the upward sweep; the
    # watchdog is a process-0 role (single-process runs are process 0)
    wds: Optional[Dict[int, Watchdog]] = {} if is_primary() else None

    def on_step(st: VCycleState, p, o, stopping: bool, dt: float) -> None:
        # dt is the runner-measured, device-blocked step time, so checkpoint
        # snapshots and level transitions never read as stragglers; each
        # segment's first step is skipped too -- it may carry the level's
        # one-time jit compile inside the timed step call
        if wds is not None and st.seg_step > 1:
            wds.setdefault(st.level, Watchdog()).observe(dt)
        # coordinated drain: the poll is a collective, so it runs
        # unconditionally once per step on every process; a stopping step is
        # never persisted (see VCycleRunner.run), so a preemption on it just
        # lets the normal completion path finish
        drain = preempt is not None and preempt.should_stop()
        if drain and not stopping:
            if save_cb is not None:
                save_cb(st, p, o, blocking=True)
                print(f"[preempt] SIGTERM: blocking V-cycle checkpoint at "
                      f"global_step {st.global_step}; exiting", flush=True)
            raise SystemExit(0)

    out = runner.run(state=state, params=params, opt_state=opt,
                     ckpt_cb=save_cb, ckpt_every=ckpt_every, on_step=on_step)
    if ckpt is not None:
        gs = runner.state.global_step
        ckpt.save(gs, {"params": out.params},
                  meta={"step": gs, "phase": "done", "level": 0,
                        "global_step": gs, "cum_flops": out.total_flops,
                        "history": out.history.to_dict()})
    _report_reduce_probe(tc, verbose)
    if verbose:
        print(f"[vcycle] total training FLOPs: {out.total_flops:.3e}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true", help="reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--vcycle", action="store_true")
    ap.add_argument("--levels", type=int, default=2)
    ap.add_argument("--alpha", type=float, default=0.25)
    ap.add_argument("--mesh", default="",
                    help="DxM ('data','model') mesh, e.g. 2x4, or PxDxM "
                         "('pod','data','model') with a leading DCN axis, e.g. "
                         "2x1x1; host CPU devices are forced when the platform "
                         "has fewer (smoke/tests); with --num-processes > 1 "
                         "the mesh spans processes")
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "dense", "int8_ef"],
                    help="gradient-reduction strategy (distributed/reduce.py): "
                         "'none' keeps pjit's implicit reduction; 'dense' runs "
                         "the explicit shard_map'd full-precision reduction; "
                         "'int8_ef' reduces dense within ICI and int8+error-"
                         "feedback across the DCN ('pod') axis. Needs --mesh")
    ap.add_argument("--coordinator", default="127.0.0.1:9876",
                    help="jax.distributed coordinator host:port (multi-process "
                         "runs; process 0's address)")
    ap.add_argument("--num-processes", type=int, default=1,
                    help="total process count for jax.distributed; every "
                         "process runs this same command with its own "
                         "--process-id and a shared --ckpt-dir")
    ap.add_argument("--process-id", type=int, default=0)
    ap.add_argument("--f32", action="store_true",
                    help="force float32 compute (tight cross-mesh resume "
                         "equivalence; default keeps the config's dtype)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-local-dir", default="",
                    help="per-host LOCAL checkpoint dir for clusters without "
                         "a shared filesystem: each process passes its OWN "
                         "path; chunks stay on the local disk, manifests and "
                         "missing objects travel over the coordination "
                         "service (overrides --ckpt-dir)")
    ap.add_argument("--ckpt-dedup", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="content-addressed v3 checkpoint layout: unchanged "
                         "leaves cost no I/O across consecutive saves "
                         "(--no-ckpt-dedup writes the v2 whole-file layout)")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--describe-plans", action="store_true",
                    help="print each V-cycle level transition's ProjectionPlan "
                         "(family hooks, coalesced/protected axes, carried "
                         "fields) and exit without training")
    args = ap.parse_args()

    # multi-process bring-up, then the mesh, must both happen before ANY
    # device-touching jax call: distributed init selects the gloo CPU
    # collectives and both may need to force the host device count, which
    # only works pre-backend-init
    if args.grad_compression != "none" and not args.mesh:
        ap.error("--grad-compression needs --mesh (the reduction axes live "
                 "on the mesh; use e.g. --mesh 2x1 or --mesh 2x1x1)")
    if args.num_processes > 1:
        if not args.mesh:
            args.mesh = f"{args.num_processes}x1"  # pure data-parallel default
        dims = parse_mesh_arg(args.mesh)
        total = 1
        for d in dims:
            total *= d
        init_distributed(args.coordinator, args.num_processes, args.process_id,
                         local_devices=total // args.num_processes)
    mesh = (make_cli_mesh(args.mesh, num_processes=args.num_processes)
            if args.mesh else None)
    primary = is_primary()
    if args.num_processes > 1 and args.ckpt_dir:
        print(f"[launch] process {jax.process_index()}/{jax.process_count()} "
              f"up; data shard {data_shard_index(mesh)}", flush=True)

    try:
        cfg = get_config(args.arch, smoke=args.smoke)
    except KeyError:
        from repro.configs import paper_models

        cfg = {"gpt-proxy": paper_models.gpt_proxy(), "bert-proxy": paper_models.bert_proxy(),
               "deit-proxy": paper_models.deit_proxy()}[args.arch]
    if args.f32:
        cfg = cfg.replace(compute_dtype=jnp.float32)
    if args.describe_plans:
        from repro.core import plans as plans_lib

        ml = MultiLevelConfig(n_levels=args.levels, alpha=args.alpha)
        c = cfg
        for _ in range(ml.n_levels - 1):
            p = plans_lib.build_plan(c, ml)
            print(p.describe())
            c = p.small_cfg
        return
    tc = TrainConfig(steps=args.steps, warmup_steps=max(args.steps // 20, 1),
                     peak_lr=args.lr, batch_size=args.batch, seq_len=args.seq,
                     seed=args.seed, grad_compression=args.grad_compression)
    if args.grad_compression != "none" and primary:
        print(f"[reduce] grad-compression={args.grad_compression} over mesh "
              f"{args.mesh} (axes {mesh.axis_names})", flush=True)
    if args.ckpt_local_dir:
        if not args.ckpt_dedup:
            # the no-shared-FS protocol exchanges digests, which only exist
            # in the content-addressed layout -- don't silently ignore the
            # explicitly requested v2 layout
            ap.error("--no-ckpt-dedup is incompatible with --ckpt-local-dir "
                     "(the per-host store is content-addressed by design)")
        ckpt = CheckpointManager(args.ckpt_local_dir, local=True)
    elif args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir, dedup=args.ckpt_dedup)
    else:
        ckpt = None
    preempt = PreemptionGuard().install() if ckpt is not None else None
    with (mesh_ctx(mesh) if mesh is not None else contextlib.nullcontext()):
        if args.vcycle:
            ml = MultiLevelConfig(n_levels=args.levels, alpha=args.alpha)
            train_vcycle_ckpt(cfg, ml, tc, ckpt=ckpt, ckpt_every=args.ckpt_every,
                              mesh=mesh, preempt=preempt, verbose=primary)
        else:
            train_plain(cfg, tc, ckpt=ckpt, ckpt_every=args.ckpt_every,
                        mesh=mesh, preempt=preempt, verbose=primary)


if __name__ == "__main__":
    main()
