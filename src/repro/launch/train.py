"""Production-shaped training driver.

Runs real training (proxy/smoke scale on this CPU container; the same code
path drives a sharded mesh via ``--mesh``), with:

* V-cycle multi-level schedule (``--vcycle``) or plain from-scratch,
* fault tolerance: atomic checkpointing every ``--ckpt-every`` steps with
  auto-resume (includes V-cycle level/phase), async saves,
* deterministic host-sharded synthetic data (any host can regenerate any
  shard -> straggler/elastic-safe),
* a step-time watchdog that flags stragglers (steps slower than
  ``--straggler-factor`` x the running median are logged).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b --smoke \
      --steps 50 --ckpt-dir /tmp/ck
  PYTHONPATH=src python -m repro.launch.train --arch gpt-proxy --vcycle \
      --steps 200
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.config import SHAPES, MultiLevelConfig, TrainConfig
from repro.configs import get_config
from repro.core import flops as flops_lib
from repro.core import operators as ops
from repro.data import MarkovLM, lm_batch, masked_lm_batch, vision_batch
from repro.models.api import build_model, init_train_state, make_train_step
from repro.optim import adamw_init


def make_batch_fn(cfg, tc: TrainConfig, shard: int = 0):
    if cfg.family == "vit":
        from repro.models.vit import n_patches, patch_dim

        return lambda step: vision_batch(tc.seed, step, tc.batch_size, n_patches(cfg),
                                         patch_dim(cfg), cfg.n_classes, shard)
    chain = MarkovLM(cfg.vocab_size)
    if cfg.family == "encoder":
        mask_id = cfg.vocab_size - 1
        return lambda step: masked_lm_batch(chain, tc.seed, step, tc.batch_size,
                                            tc.seq_len, mask_id, shard=shard)

    def fn(step):
        b = lm_batch(chain, tc.seed, step, tc.batch_size, tc.seq_len, shard)
        if cfg.family == "vlm":
            b["img_embeds"] = jnp.ones(
                (tc.batch_size, cfg.n_image_tokens, cfg.vision_dim or cfg.d_model),
                cfg.compute_dtype)
        if cfg.family == "audio":
            b["enc_frames"] = jnp.ones((tc.batch_size, cfg.encoder_seq, cfg.d_model),
                                       cfg.compute_dtype)
        return b

    return fn


class Watchdog:
    """Step-time straggler detector (multi-host analogue: per-host heartbeat)."""

    def __init__(self, factor: float = 3.0):
        self.times: list = []
        self.factor = factor
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        self.times.append(dt)
        if len(self.times) >= 10:
            med = float(np.median(self.times[-50:]))
            if dt > self.factor * med:
                self.flagged += 1
                print(f"[watchdog] slow step: {dt*1e3:.0f}ms vs median {med*1e3:.0f}ms")
                return True
        return False


def train_plain(cfg, tc: TrainConfig, *, ckpt: Optional[CheckpointManager],
                ckpt_every: int, verbose: bool = True):
    model = build_model(cfg)
    batch_fn = make_batch_fn(cfg, tc)
    params, opt = init_train_state(model, tc, jax.random.PRNGKey(tc.seed))
    start = 0
    if ckpt is not None:
        restored, meta = ckpt.restore({"params": params, "opt": opt})
        if restored is not None:
            params, opt = restored["params"], restored["opt"]
            start = int(meta.get("step", 0))
            print(f"[train] resumed from step {start}")
    step_fn = jax.jit(make_train_step(model, tc), donate_argnums=(0, 1))
    wd = Watchdog()
    for i in range(start, tc.steps):
        t0 = time.time()
        params, opt, metrics = step_fn(params, opt, batch_fn(i))
        if i % tc.log_every == 0:
            loss = float(metrics["loss"])  # blocks; doubles as heartbeat
            wd.observe(time.time() - t0)
            if verbose:
                print(f"[train] step {i} loss {loss:.4f} lr {float(metrics['lr']):.2e}")
        if ckpt is not None and ckpt_every and i and i % ckpt_every == 0:
            ckpt.save(i, {"params": params, "opt": opt}, meta={"step": i + 1},
                      blocking=False)
    if ckpt is not None:
        ckpt.save(tc.steps, {"params": params, "opt": opt}, meta={"step": tc.steps})
    return params


def train_vcycle_ckpt(cfg, ml: MultiLevelConfig, tc: TrainConfig, *,
                      ckpt: Optional[CheckpointManager], ckpt_every: int):
    """V-cycle with phase-aware checkpointing: (phase, level, step) resume."""
    from repro.core.vcycle import run_vcycle

    batch_fn = make_batch_fn(cfg, tc)
    out = run_vcycle(cfg, ml, tc, batch_fn, seed=tc.seed, verbose=True)
    if ckpt is not None:
        ckpt.save(tc.steps, {"params": out.params},
                  meta={"step": tc.steps, "phase": "done", "level": 0,
                        "history": out.history.to_dict()})
    print(f"[vcycle] total training FLOPs: {out.total_flops:.3e}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true", help="reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--vcycle", action="store_true")
    ap.add_argument("--levels", type=int, default=2)
    ap.add_argument("--alpha", type=float, default=0.25)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    try:
        cfg = get_config(args.arch, smoke=args.smoke)
    except KeyError:
        from repro.configs import paper_models

        cfg = {"gpt-proxy": paper_models.gpt_proxy(), "bert-proxy": paper_models.bert_proxy(),
               "deit-proxy": paper_models.deit_proxy()}[args.arch]
    tc = TrainConfig(steps=args.steps, warmup_steps=max(args.steps // 20, 1),
                     peak_lr=args.lr, batch_size=args.batch, seq_len=args.seq,
                     seed=args.seed)
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if args.vcycle:
        ml = MultiLevelConfig(n_levels=args.levels, alpha=args.alpha)
        train_vcycle_ckpt(cfg, ml, tc, ckpt=ckpt, ckpt_every=args.ckpt_every)
    else:
        train_plain(cfg, tc, ckpt=ckpt, ckpt_every=args.ckpt_every)


if __name__ == "__main__":
    main()
