"""Page-pool bookkeeping for the paged KV serving engine (host-side, pure
Python -- no jax).

``PagePool`` owns the page ids of the shared ``[n_pages, page_size, ...]``
cache leaves; ``BlockAllocator`` turns prompts into per-request block tables
(page-id lists), reusing refcounted prompt pages across requests that share a
prefix.  Prefix pages are keyed by a rolling blake2b digest of their token
blocks -- the same content-addressing discipline as ``checkpoint/store.py``,
applied to prompts: the digest of page ``i`` commits to *all* tokens up to
``(i+1)*page_size``, so equal digests imply the causal K/V content of the
page is identical and may be shared.

Invariants (pinned by tests/test_property.py):
  * a page is either free or held by >= 1 live request -- never both,
  * no page is handed to two requests except through refcounted reuse,
  * a shared prefix page is freed exactly when its last holder completes.
"""
from __future__ import annotations

import hashlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

NULL_PAGE = 0  # reserved: never allocated; padding/inactive writes land here


class PagePool:
    """Free-list + refcounts over page ids ``1..n_pages-1`` (page 0 is the
    reserved null page that bucketed/inactive writes are routed to)."""

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError(f"need n_pages >= 2 (one null + one usable), got {n_pages}")
        self.n_pages = n_pages
        self._free: List[int] = list(range(n_pages - 1, 0, -1))  # pop() -> ascending
        self._ref: Dict[int, int] = {}
        self.in_use_peak = 0

    @property
    def capacity(self) -> int:
        return self.n_pages - 1

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.capacity - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """All-or-nothing: ``n`` fresh pages at refcount 1, or None."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for pid in pages:
            self._ref[pid] = 1
        self.in_use_peak = max(self.in_use_peak, self.n_used)
        return pages

    def incref(self, pid: int) -> None:
        if pid not in self._ref:
            raise ValueError(f"incref on free page {pid}")
        self._ref[pid] += 1
        self.in_use_peak = max(self.in_use_peak, self.n_used)

    def decref(self, pid: int) -> bool:
        """Drop one reference; True when this freed the page."""
        if pid not in self._ref:
            raise ValueError(f"decref on free page {pid}")
        self._ref[pid] -= 1
        if self._ref[pid] == 0:
            del self._ref[pid]
            self._free.append(pid)
            return True
        return False

    def refcount(self, pid: int) -> int:
        return self._ref.get(pid, 0)


def page_digests(tokens: Sequence[int], page_size: int) -> List[str]:
    """Rolling blake2b chain over full ``page_size`` token blocks.

    ``d_i = blake2b(d_{i-1} || block_i)`` -- page i's key commits to the whole
    prefix, so two prompts share a digest iff they share all tokens through
    that page.  Only full pages get a digest (a partial tail page is never
    shareable: its remaining slots will be filled by request-specific tokens).
    """
    toks = np.ascontiguousarray(np.asarray(tokens, np.int32))
    out: List[str] = []
    d = b"prompt-page-v1"
    for i in range(len(toks) // page_size):
        h = hashlib.blake2b(d, digest_size=20)
        h.update(toks[i * page_size:(i + 1) * page_size].tobytes())
        d = h.digest()
        out.append(d.hex())
    return out


class PrefixCache:
    """digest -> live page id (valid only while the page's refcount > 0;
    ``BlockAllocator.complete`` evicts entries as their pages free)."""

    def __init__(self):
        self._by_digest: Dict[str, int] = {}
        self._by_page: Dict[int, str] = {}

    def lookup(self, digests: Sequence[str]) -> List[int]:
        """Page ids for the longest consecutive prefix of ``digests`` present."""
        pages: List[int] = []
        for d in digests:
            pid = self._by_digest.get(d)
            if pid is None:
                break
            pages.append(pid)
        return pages

    def insert(self, digest: str, pid: int) -> None:
        if digest in self._by_digest:  # first writer wins; content is identical
            return
        self._by_digest[digest] = pid
        self._by_page[pid] = digest

    def evict_page(self, pid: int) -> None:
        d = self._by_page.pop(pid, None)
        if d is not None:
            del self._by_digest[d]

    def clear(self) -> int:
        """Drop every entry; returns how many were dropped.

        The pages themselves stay live (their holders keep reading them) --
        they just stop being discoverable by new arrivals.
        """
        n = len(self._by_digest)
        self._by_digest.clear()
        self._by_page.clear()
        return n

    def __len__(self) -> int:
        return len(self._by_digest)


class BlockAllocator:
    """Admission bookkeeping: prompt -> block table, with prefix reuse.

    ``admit`` reserves the request's *worst-case* page count up front
    (``ceil(total_positions / page_size)``), so decode never allocates
    mid-flight and a admitted request can always run to completion.
    """

    def __init__(self, n_pages: int, page_size: int, prefix_reuse: bool = True):
        self.pool = PagePool(n_pages)
        self.page_size = page_size
        self.prefix: Optional[PrefixCache] = PrefixCache() if prefix_reuse else None
        self.live: Dict[int, List[int]] = {}  # rid -> block table
        self.reused_tokens_total = 0
        # per-request length bookkeeping for speculative decode (see
        # advance/mark_written/rollback): committed positions vs the
        # written high-water mark of in-flight (unverified) draft positions
        self.lengths: Dict[int, int] = {}      # rid -> committed positions
        self.written: Dict[int, int] = {}      # rid -> written high-water
        self.reserved: Dict[int, int] = {}     # rid -> worst-case positions
        self._prompt_len: Dict[int, int] = {}
        self.rolled_back_total = 0             # positions rewound across rollbacks
        self.invalidations_total = 0           # prefix-cache wipes (weight swaps)

    def pages_needed(self, total_positions: int) -> int:
        return -(-total_positions // self.page_size)

    def admit(self, rid: int, tokens: Sequence[int],
              total_positions: int) -> Optional[Tuple[List[int], int]]:
        """Reserve pages for a request; ``(block_table, reuse_len)`` or None
        when the pool can't cover the non-shared need right now.

        ``reuse_len`` tokens at the head of the prompt are served from shared
        (refcounted) pages and never re-prefilled.  Reuse is capped one token
        short of the prompt so the model still runs >= 1 fresh position (the
        last prompt token's logits seed decode).
        """
        if rid in self.live:
            raise ValueError(f"request {rid} already admitted")
        if total_positions < len(tokens):
            raise ValueError("total_positions must cover the prompt")
        P = self.page_size
        total_pages = self.pages_needed(total_positions)
        digests = page_digests(tokens, P)
        reused: List[int] = []
        if self.prefix is not None:
            cap = (len(tokens) - 1) // P  # leave >= 1 token of fresh tail
            reused = self.prefix.lookup(digests[:cap])
        new = self.pool.alloc(total_pages - len(reused))
        if new is None:
            return None
        for pid in reused:
            self.pool.incref(pid)
        table = reused + new
        if self.prefix is not None:
            # publish this prompt's own full pages for later arrivals
            for i in range(len(reused), len(tokens) // P):
                self.prefix.insert(digests[i], table[i])
        self.live[rid] = table
        self.reused_tokens_total += len(reused) * P
        self.lengths[rid] = len(tokens)
        self.written[rid] = len(tokens)
        self.reserved[rid] = total_positions
        self._prompt_len[rid] = len(tokens)
        return table, len(reused) * P

    # -- speculative-decode length protocol ---------------------------------
    # Committed positions only ever grow via ``advance`` (verified tokens);
    # speculation first raises the ``written`` high-water with
    # ``mark_written`` (the verify step writes k+1 unverified positions),
    # then ``rollback`` rewinds ``written`` to the committed length once the
    # accepted prefix is known.  The rejected positions' stale K/V needs no
    # physical erase: reads are position-masked (queries only attend
    # positions <= their own) and the next committed write at that position
    # overwrites it.  Shared prefix pages can never be touched: every
    # speculative write lands at a position >= the prompt length, while
    # prefix reuse is capped at ``(len(prompt)-1) // page_size`` pages --
    # so rollback cannot poison the PrefixCache.

    def advance(self, rid: int, n: int = 1) -> int:
        """Commit ``n`` more positions (verified/emitted tokens)."""
        new = self.lengths[rid] + n
        if new > self.reserved[rid]:
            raise ValueError(
                f"request {rid}: committing {new} positions exceeds the "
                f"admission reserve of {self.reserved[rid]}")
        self.lengths[rid] = new
        self.written[rid] = max(self.written[rid], new)
        return new

    def mark_written(self, rid: int, upto: int) -> None:
        """Record that positions ``[0, upto)`` now hold K/V, committed or not
        (the speculative verify step writes drafted positions eagerly)."""
        if upto > self.reserved[rid]:
            raise ValueError(
                f"request {rid}: speculative write through position {upto} "
                f"exceeds the admission reserve of {self.reserved[rid]}")
        self.written[rid] = max(self.written[rid], upto)

    def rollback(self, rid: int) -> int:
        """Rewind the written high-water to the committed length, i.e. drop
        the rejected drafted positions; returns how many were rolled back."""
        rolled = self.written[rid] - self.lengths[rid]
        assert rolled >= 0 and self.lengths[rid] >= self._prompt_len[rid]
        self.written[rid] = self.lengths[rid]
        self.rolled_back_total += rolled
        return rolled

    def invalidate_prefix(self) -> int:
        """Wipe the prefix cache after a weight swap; returns entries dropped.

        Cached prompt pages hold K/V computed under the *old* params, so a
        post-swap arrival must never match them: a digest commits to the
        token content of a prefix, not to the weights that encoded it.  Pages
        held by in-flight requests keep their refcounts (those requests
        finish under the old weights and still read them) -- the entries just
        leave the cache, exactly as ``complete`` would evict them one by one.
        """
        if self.prefix is None:
            return 0
        self.invalidations_total += 1
        return self.prefix.clear()

    def complete(self, rid: int) -> None:
        """Release the request's pages; a shared page survives until its last
        holder completes, and leaves the prefix cache the moment it frees."""
        for pid in self.live.pop(rid):
            if self.pool.decref(pid) and self.prefix is not None:
                self.prefix.evict_page(pid)
        for d in (self.lengths, self.written, self.reserved, self._prompt_len):
            d.pop(rid, None)
