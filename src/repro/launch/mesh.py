"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state -- required for the dry-run's placeholder-device
bootstrap ordering.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Target topology: one TPU v5e pod = 16x16 = 256 chips, ("data","model");
    two pods = (2,16,16) with a leading "pod" axis (DP across pods over DCN).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


# hardware constants for the roofline (TPU v5e per chip)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link
