"""Production mesh construction + multi-process bring-up.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state -- required for the dry-run's placeholder-device
bootstrap ordering, and for ``init_distributed``'s (flags, collectives,
``jax.distributed.initialize``) sequence, all of which must run before the
first backend-initializing call.

Launching multi-process runs (one process per host; CPU-portable, so CI and
laptops drill the exact same path as a real slice)::

    # terminal 1                                 # terminal 2
    python -m repro.launch.train \\
        --arch tinyllama-1.1b --smoke --vcycle \\
        --mesh 2x1 --coordinator 127.0.0.1:9876 \\
        --num-processes 2 --process-id 0 ...     # ... --process-id 1 ...

The ("data","model") mesh then spans all processes' devices; each process
feeds its own data shard, process 0 owns logging and the checkpoint manifest,
and every process writes only its addressable checkpoint shards (see
``repro.checkpoint``).

The same ``--mesh DxM`` flag (and the same axis names) drives the serving
side: ``launch/serve.py``'s ``make_server(cfg, mesh=...)`` places the paged
K/V page pool model-sharded along ``"model"`` with replicated block tables,
so a decode fleet reuses this module's mesh construction unchanged (see
launch/README.md, "Mesh-sharded paged decode").
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax


def parse_mesh_arg(spec: str) -> Tuple[int, ...]:
    """``"DxM"`` -> (data, model); ``"PxDxM"`` -> (pod, data, model).

    The 3-dim form adds a leading DCN "pod" axis (data parallelism across
    pods), which is what the hierarchical gradient-reduction strategies key
    on: dense within ("data",) ICI, compressed across "pod".
    """
    try:
        dims = tuple(int(p) for p in spec.lower().split("x"))
    except ValueError:
        raise ValueError(
            f"--mesh expects DxM or PxDxM (e.g. 2x4 or 2x2x1), "
            f"got {spec!r}") from None
    if len(dims) not in (2, 3) or any(d < 1 for d in dims):
        raise ValueError(
            f"--mesh expects 2 or 3 axes >= 1 (DxM or PxDxM), got {spec!r}")
    return dims


def _force_host_device_flag(n: int) -> None:
    """Env-only half of :func:`ensure_host_devices`: set (or raise) the
    ``--xla_force_host_platform_device_count`` flag without touching jax
    device state, so it can run before ``jax.distributed.initialize``."""
    flags = os.environ.get("XLA_FLAGS", "")
    marker = "--xla_force_host_platform_device_count="
    if n <= 1:
        return
    if marker in flags:
        # raise an existing, too-small count instead of refusing
        head, _, rest = flags.partition(marker)
        val, _, tail = rest.partition(" ")
        try:
            have_flag = int(val)
        except ValueError:
            have_flag = 0
        if have_flag < n:
            os.environ["XLA_FLAGS"] = f"{head}{marker}{n} {tail}".strip()
    else:
        os.environ["XLA_FLAGS"] = f"{flags} {marker}{n}".strip()


def ensure_host_devices(n: int) -> None:
    """Force the host (CPU) platform to expose >= ``n`` LOCAL devices.

    Must run before jax initializes its backends (i.e. before the first
    device-touching call -- the launcher calls it straight after arg parsing,
    which is why this module never creates device state at import time).
    A no-op when enough devices already exist (a real accelerator platform, or
    XLA_FLAGS already set by the caller); raises when the backend is already
    live with fewer devices than requested.
    """
    _force_host_device_flag(n)
    have = jax.local_device_count()
    if have < n:
        raise RuntimeError(
            f"mesh needs {n} local devices but jax sees {have} (backend "
            f"already initialized?); export "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} before "
            f"launch")


def init_distributed(coordinator: str, num_processes: int, process_id: int,
                     *, local_devices: Optional[int] = None) -> None:
    """Bring up ``jax.distributed`` for a multi-process run (CPU-portable).

    Must run before ANY backend-initializing jax call.  Order matters and is
    encapsulated here: (1) force the host-platform device count this process
    must contribute (env only), (2) select the gloo CPU collectives
    implementation -- the default CPU backend refuses multi-process
    computations outright -- then (3) connect to the coordinator.  On an
    accelerator platform (2) is a harmless no-op: collectives ride the
    accelerator fabric and the forced CPU devices are never part of the mesh.

    Idempotent: a second call (e.g. a library test re-entering the launcher)
    is ignored once the distributed client is live.
    """
    from jax._src import distributed as _dist

    if getattr(_dist.global_state, "client", None) is not None:
        return
    if local_devices and local_devices > 1:
        _force_host_device_flag(local_devices)
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except (AttributeError, ValueError):  # jax build without gloo / renamed
        pass
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=num_processes,
                               process_id=process_id)


def make_cli_mesh(spec: str, *, num_processes: int = 1):
    """Mesh for the launcher's ``--mesh`` flag: ("data", "model") for ``DxM``,
    ("pod", "data", "model") for ``PxDxM`` (a leading DCN axis for the
    hierarchical gradient-reduction strategies).

    CPU-backed for tests/smoke: each process's host devices are forced to its
    d*m/num_processes share before the first backend initialization, so
    ``--mesh 2x4`` works on a laptop exactly like on a slice (the per-device
    arrays are just tiny).  With ``num_processes > 1`` the caller must have
    run :func:`init_distributed` first; the mesh then spans every process's
    devices (process-major device order, so a 2x1 mesh puts process 0 at data
    coordinate 0).
    """
    dims = parse_mesh_arg(spec)
    total = 1
    for d in dims:
        total *= d
    if total % num_processes:
        raise ValueError(
            f"--mesh {spec} has {total} devices, not divisible over "
            f"{num_processes} processes")
    ensure_host_devices(total // num_processes)
    if jax.device_count() < total:
        raise RuntimeError(
            f"mesh {spec} needs {total} devices but jax sees "
            f"{jax.device_count()} across {jax.process_count()} processes")
    axes = ("pod", "data", "model") if len(dims) == 3 else ("data", "model")
    return jax.make_mesh(dims, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Target topology: one TPU v5e pod = 16x16 = 256 chips, ("data","model");
    two pods = (2,16,16) with a leading "pod" axis (DP across pods over DCN).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


# hardware constants for the roofline (TPU v5e per chip)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link
