"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state -- required for the dry-run's placeholder-device
bootstrap ordering.
"""
from __future__ import annotations

import os
from typing import Tuple

import jax


def parse_mesh_arg(spec: str) -> Tuple[int, int]:
    """``"DxM"`` -> (data, model), e.g. ``"2x4"`` -> (2, 4)."""
    try:
        d, m = (int(p) for p in spec.lower().split("x"))
    except ValueError:
        raise ValueError(
            f"--mesh expects DxM (e.g. 2x4), got {spec!r}") from None
    if d < 1 or m < 1:
        raise ValueError(f"--mesh axes must be >= 1, got {spec!r}")
    return d, m


def ensure_host_devices(n: int) -> None:
    """Force the host (CPU) platform to expose >= ``n`` devices.

    Must run before jax initializes its backends (i.e. before the first
    device-touching call -- the launcher calls it straight after arg parsing,
    which is why this module never creates device state at import time).
    A no-op when enough devices already exist (a real accelerator platform, or
    XLA_FLAGS already set by the caller); raises when the backend is already
    live with fewer devices than requested.
    """
    flags = os.environ.get("XLA_FLAGS", "")
    marker = "--xla_force_host_platform_device_count="
    if n > 1:
        if marker in flags:
            # raise an existing, too-small count instead of refusing
            head, _, rest = flags.partition(marker)
            val, _, tail = rest.partition(" ")
            try:
                have_flag = int(val)
            except ValueError:
                have_flag = 0
            if have_flag < n:
                os.environ["XLA_FLAGS"] = f"{head}{marker}{n} {tail}".strip()
        else:
            os.environ["XLA_FLAGS"] = f"{flags} {marker}{n}".strip()
    have = jax.device_count()
    if have < n:
        raise RuntimeError(
            f"mesh needs {n} devices but jax sees {have} (backend already "
            f"initialized?); export "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} before "
            f"launch")


def make_cli_mesh(spec: str):
    """("data", "model") mesh for the launcher's ``--mesh DxM`` flag.

    CPU-backed for tests/smoke: host devices are forced to d*m before the
    first backend initialization, so ``--mesh 2x4`` works on a laptop exactly
    like on a slice (the per-device arrays are just tiny).
    """
    d, m = parse_mesh_arg(spec)
    ensure_host_devices(d * m)
    return jax.make_mesh((d, m), ("data", "model"))


def make_production_mesh(*, multi_pod: bool = False):
    """Target topology: one TPU v5e pod = 16x16 = 256 chips, ("data","model");
    two pods = (2,16,16) with a leading "pod" axis (DP across pods over DCN).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


# hardware constants for the roofline (TPU v5e per chip)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link
