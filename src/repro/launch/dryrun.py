import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent without real
hardware.

For every (architecture x input-shape) cell and each production mesh
(single-pod 16x16 and multi-pod 2x16x16 = 512 chips), this lowers and compiles
the real step function -- full ``train_step`` (grads + AdamW + grad-accum) for
train shapes, ``prefill_step`` / ``serve_step`` for inference shapes -- against
ShapeDtypeStruct stand-ins (no allocation: the 671B models never materialize),
prints ``memory_analysis()`` (proves it fits) and ``cost_analysis()`` (FLOPs /
bytes for the roofline), parses the collective schedule out of the compiled
HLO, and appends everything to a resumable JSON used by EXPERIMENTS.md
SDry-run / SRoofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
      [--mesh single|multi|both] [--force] [--out benchmarks/results]
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import SHAPES, ModelConfig, ShapeConfig
from repro.configs import ASSIGNED, cell_is_skipped, get_config
from repro.core import flops as flops_lib
from repro.distributed import param_shardings, set_mesh_ctx
from repro.distributed.sharding import SERVE_RULES, logical_spec
from repro.launch import specs as specs_lib
from repro.launch.analysis import analyze_compiled, memory_summary
from repro.launch.mesh import make_production_mesh
from repro.models.api import build_model, make_prefill_step, make_serve_step, make_train_step
from repro.optim import adamw_init_specs
from repro.param import struct_tree


def dict_or_none(rules):
    if rules is None:
        return None
    from repro.distributed.sharding import RULES

    return dict(RULES, **rules)


def batch_shardings(batch_axes: Dict[str, Any], batch_structs, mesh):
    return jax.tree.map(
        lambda s, ax: NamedSharding(mesh, logical_spec(s.shape, ax, mesh)),
        batch_structs, batch_axes)


def lower_cell(arch: str, shape: ShapeConfig, mesh, *, verbose: bool = True) -> Dict[str, Any]:
    cfg = specs_lib.model_config_for(get_config(arch), shape)
    tc = specs_lib.train_config_for(cfg, shape)
    model = build_model(cfg)
    pspecs = model.specs()
    n_dev = mesh.devices.size
    # decode uses the serving sharding rules: read-only params are never
    # FSDP-gathered; experts spread over the full device set (256-way EP).
    # Prefill keeps the training rules: its 32k-token batches make the
    # EP token-replication layout catastrophic (measured: 308 GB/device
    # temp on deepseek multi-pod prefill -- EXPERIMENTS.md §Perf notes).
    rules = SERVE_RULES if shape.kind == "decode" else None
    set_mesh_ctx(mesh, rules)

    p_structs = struct_tree(pspecs, dtype=cfg.param_dtype)
    p_shard = param_shardings(pspecs, mesh, rules=dict_or_none(rules))
    t0 = time.time()

    if shape.kind == "train":
        o_specs = adamw_init_specs(pspecs, tc)
        o_structs = struct_tree(o_specs, dtype=tc.opt_dtype)
        o_shard = param_shardings(o_specs, mesh)
        batch, axes = specs_lib.train_inputs(cfg, shape, tc.grad_accum)
        b_shard = batch_shardings(axes, batch, mesh)
        step = make_train_step(model, tc)
        lowered = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                          donate_argnums=(0, 1)).lower(p_structs, o_structs, batch)
        tokens = shape.global_batch * shape.seq_len
        model_flops = flops_lib.model_flops_reference(cfg, pspecs, tokens, train=True)
    elif shape.kind == "prefill":
        batch, axes = specs_lib.prefill_inputs(cfg, shape)
        b_shard = batch_shardings(axes, batch, mesh)
        step = make_prefill_step(model)
        lowered = jax.jit(step, in_shardings=(p_shard, b_shard["tokens"],
                                              b_shard.get("img_embeds"),
                                              b_shard.get("enc_frames"))).lower(
            p_structs, batch["tokens"], batch.get("img_embeds"), batch.get("enc_frames"))
        tokens = shape.global_batch * shape.seq_len
        model_flops = flops_lib.model_flops_reference(cfg, pspecs, tokens, train=False)
    else:  # decode
        toks, pos, cache_specs = specs_lib.decode_inputs(cfg, shape)
        c_structs = struct_tree(cache_specs)
        c_shard = param_shardings(cache_specs, mesh, rules=dict_or_none(rules))
        t_shard = NamedSharding(mesh, logical_spec(toks.shape, ("batch", "seq"), mesh))
        pos_shard = NamedSharding(mesh, logical_spec(pos.shape, ("batch",), mesh))
        step = make_serve_step(model)
        lowered = jax.jit(step, in_shardings=(p_shard, c_shard, t_shard, pos_shard),
                          donate_argnums=(1,)).lower(p_structs, c_structs, toks, pos)
        tokens = shape.global_batch  # one new token per sequence
        model_flops = flops_lib.model_flops_reference(cfg, pspecs, tokens, train=False)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    rl, colls = analyze_compiled(compiled, n_dev, model_flops)
    mem = memory_summary(compiled)
    rec = {
        "arch": arch, "shape": shape.name, "mesh": f"{n_dev}dev",
        "status": "ok", "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem, "collectives": colls, "roofline": rl.to_dict(),
        "params": flops_lib.total_params(pspecs),
    }
    if verbose:
        print(f"  memory_analysis: {compiled.memory_analysis()}")
        ca = compiled.cost_analysis() or {}
        print(f"  cost_analysis: flops={ca.get('flops', 0):.3e} "
              f"bytes={ca.get('bytes accessed', 0):.3e}")
        print(f"  collectives: { {k: (v['count'], f'{v['bytes']:.2e}B') for k, v in colls.items()} }")
        print(f"  roofline: compute={rl.t_compute*1e3:.1f}ms memory={rl.t_memory*1e3:.1f}ms "
              f"collective={rl.t_collective*1e3:.1f}ms -> {rl.bottleneck}-bound, "
              f"useful={rl.useful_flops_ratio:.2f} frac={rl.roofline_fraction:.2f}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="benchmarks/results")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, "dryrun.json")
    results: Dict[str, Any] = {}
    if os.path.exists(path):
        # always load: --force re-runs the SELECTED cells but must never
        # discard other cells' records
        with open(path) as f:
            results = json.load(f)

    archs = ASSIGNED if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_ok = n_skip = n_fail = 0
    for multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mesh_name = "2x16x16" if multi_pod else "16x16"
        for arch in archs:
            for sname in shapes:
                key = f"{arch}|{sname}|{mesh_name}"
                skip = cell_is_skipped(arch, sname)
                if skip:
                    results[key] = {"status": "skipped", "reason": skip}
                    n_skip += 1
                    continue
                if key in results and results[key].get("status") == "ok" and not args.force:
                    n_ok += 1
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                try:
                    rec = lower_cell(arch, SHAPES[sname], mesh)
                    rec["mesh"] = mesh_name
                    results[key] = rec
                    n_ok += 1
                    print(f"[dryrun] {key} OK (lower {rec['lower_s']}s, "
                          f"compile {rec['compile_s']}s)", flush=True)
                except Exception as e:  # noqa: BLE001 -- failures ARE the signal here
                    results[key] = {"status": "fail", "error": f"{type(e).__name__}: {e}",
                                    "traceback": traceback.format_exc()[-2000:]}
                    n_fail += 1
                    print(f"[dryrun] {key} FAIL: {e}", flush=True)
                with open(path, "w") as f:
                    json.dump(results, f, indent=1)
    print(f"[dryrun] done: ok={n_ok} skip={n_skip} fail={n_fail} -> {path}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
