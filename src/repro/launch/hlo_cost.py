"""Trip-count-aware cost analysis over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of
trip count (verified in tests/test_hlo_cost.py), which under-counts every
``lax.scan``-based model (layer stacks, grad-accum, blockwise attention,
recurrent mixers) by orders of magnitude.  This module re-derives the three
roofline inputs from ``compiled.as_text()`` with loop multipliers:

  * FLOPs           -- dot ops: 2 * prod(result) * prod(contracting dims);
                       convolutions: 2 * prod(result) * kernel/output-feature.
  * bytes accessed  -- XLA's convention: per top-level instruction,
                       sum(operand bytes) + result bytes (fusion internals are
                       separate computations and are not walked).
  * collective bytes-- per-op ring model (see launch/analysis.py), multiplied
                       by the enclosing loops' trip counts.

Trip counts: jax scans lower to ``while`` whose condition compares the loop
counter against a constant; we take the largest s32/u32 constant in the
condition computation.  Non-scan whiles do not occur in this codebase.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_TOKEN = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+) = (.*?) ([\w\-]+)\((.*)$")
# computation signatures contain nested parens: `%body (p: (s32[], f32[2,2])) -> ... {`
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?(%?[\w$.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_OPERAND = re.compile(r"(%[\w.\-]+)")
_CONST_INT = re.compile(r"=\s*[su]32\[\]\s*constant\((\d+)\)")


def _shape_dims(result: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_TOKEN.findall(result):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _nbytes(result: str) -> float:
    total = 0.0
    for dt, dims in _shape_dims(result):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    result: str
    op: str
    rest: str  # operand list + attributes (raw tail of the line)

    def operands(self) -> List[str]:
        # operands are the %refs inside the first balanced paren group
        depth, ops, buf = 0, [], self.rest
        end = 0
        for i, ch in enumerate(buf):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    end = i
                    break
                depth -= 1
        return _OPERAND.findall(buf[:end])

    def attr(self, key: str) -> Optional[str]:
        m = re.search(key + r"=([\w.\-%]+)", self.rest)
        return m.group(1) if m else None

    def dims_attr(self, key: str) -> List[int]:
        m = re.search(key + r"=\{([0-9,]*)\}", self.rest)
        if not m:
            return []
        return [int(x) for x in m.group(1).split(",") if x]


def parse_hlo(text: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HEADER.match(line.strip())
            if m and "->" in line:
                cur = m.group(1).lstrip("%")
                comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            comps[cur].append(Instr(name=m.group(1), result=m.group(2),
                                    op=m.group(3), rest=m.group(4)))
    return comps


_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
_SKIP_BYTES = ("parameter", "constant", "tuple", "get-tuple-element", "bitcast",
               "after-all", "iota")


class HloCost:
    def __init__(self, text: str):
        self.comps = parse_hlo(text)
        self.entry = self._find_entry(text)
        self._types: Dict[str, Dict[str, str]] = {
            c: {i.name: i.result for i in instrs} for c, instrs in self.comps.items()}
        self._memo: Dict[str, Tuple[float, float, Dict[str, Dict[str, float]]]] = {}

    def _find_entry(self, text: str) -> str:
        m = re.search(r"^ENTRY\s+(%?[\w.\-]+)", text, re.M)
        return m.group(1).lstrip("%") if m else next(iter(self.comps))

    # ---- helpers -------------------------------------------------------
    def _operand_dims(self, comp: str, ref: str) -> List[int]:
        t = self._types.get(comp, {}).get(ref)
        if t is None:
            return []
        sd = _shape_dims(t)
        return sd[0][1] if sd else []

    def _trip_count(self, cond_comp: str) -> int:
        """jax scans: condition is `lt(counter, N)`; take the largest integer
        scalar constant in the condition computation (counter starts at 0)."""
        best = 1
        for i in self.comps.get(cond_comp, []):
            if i.op == "constant" and i.result.strip() in ("s32[]", "u32[]"):
                m = re.match(r"\s*(\d+)", i.rest.rstrip(") "))
                if m:
                    best = max(best, int(m.group(1)))
        return best

    def _dot_flops(self, comp: str, ins: Instr) -> float:
        res = _shape_dims(ins.result)
        if not res:
            return 0.0
        out_n = 1
        for d in res[0][1]:
            out_n *= d
        ops = ins.operands()
        lhs_dims = self._operand_dims(comp, ops[0]) if ops else []
        contract = ins.dims_attr("lhs_contracting_dims")
        k = 1
        for d in contract:
            if d < len(lhs_dims):
                k *= lhs_dims[d]
        return 2.0 * out_n * max(k, 1)

    def _conv_flops(self, comp: str, ins: Instr) -> float:
        res = _shape_dims(ins.result)
        if not res:
            return 0.0
        out_n = 1
        for d in res[0][1]:
            out_n *= d
        ops = ins.operands()
        kdims = self._operand_dims(comp, ops[1]) if len(ops) > 1 else []
        kn = 1
        for d in kdims:
            kn *= d
        # kernel output-feature size ~ last dim under jax's WIO convention
        out_f = kdims[-1] if kdims else 1
        return 2.0 * out_n * max(kn // max(out_f, 1), 1)

    # ---- main walk ------------------------------------------------------
    def _walk(self, comp: str) -> Tuple[float, float, Dict[str, Dict[str, float]]]:
        if comp in self._memo:
            return self._memo[comp]
        flops = 0.0
        byts = 0.0
        colls: Dict[str, Dict[str, float]] = {}
        for ins in self.comps.get(comp, []):
            opk = ins.op
            if opk == "while":
                body = ins.attr("body")
                cond = ins.attr("condition")
                trips = self._trip_count(cond.lstrip("%")) if cond else 1
                bf, bb, bc = self._walk(body.lstrip("%")) if body else (0, 0, {})
                cf, cb, cc = self._walk(cond.lstrip("%")) if cond else (0, 0, {})
                flops += trips * (bf + cf)
                byts += trips * (bb + cb)
                for src in (bc, cc):
                    for k, v in src.items():
                        t = colls.setdefault(k, {"count": 0.0, "bytes": 0.0})
                        t["count"] += trips * v["count"]
                        t["bytes"] += trips * v["bytes"]
                continue
            if opk in ("conditional", "call", "async-start"):
                for ref in re.findall(r"(?:branch_computations=\{([^}]*)\}|to_apply=(%[\w.\-]+)|called_computations=\{([^}]*)\})", ins.rest):
                    for grp in ref:
                        for name in _OPERAND.findall(grp or ""):
                            sf, sb, sc = self._walk(name.lstrip("%"))
                            flops += sf
                            byts += sb
                            for k, v in sc.items():
                                t = colls.setdefault(k, {"count": 0.0, "bytes": 0.0})
                                t["count"] += v["count"]
                                t["bytes"] += v["bytes"]
            if opk == "dot":
                flops += self._dot_flops(comp, ins)
            elif opk == "convolution":
                flops += self._conv_flops(comp, ins)
            elif opk.startswith(_COLL_OPS) or opk in _COLL_OPS or \
                    any(opk == c + s for c in _COLL_OPS for s in ("-start",)):
                base = None
                for c in _COLL_OPS:
                    if opk == c or opk == c + "-start":
                        base = c
                if base is not None:
                    B = _nbytes(ins.result)
                    g = self._coll_group_size(ins.rest)
                    if g > 1:
                        frac = (g - 1) / g
                        moved = {"all-reduce": 2 * B * frac, "all-gather": B * frac,
                                 "reduce-scatter": B * (g - 1), "all-to-all": B * frac,
                                 "collective-permute": B}[base]
                        t = colls.setdefault(base, {"count": 0.0, "bytes": 0.0})
                        t["count"] += 1
                        t["bytes"] += moved
            if opk not in _SKIP_BYTES and not opk.endswith("-done"):
                byts += self._instr_bytes(comp, ins)
        out = (flops, byts, colls)
        self._memo[comp] = out
        return out

    def _instr_bytes(self, comp: str, ins: Instr) -> float:
        """XLA bytes-accessed convention (operands + result), with the
        in-place cases XLA itself special-cases:

        * dynamic-update-slice: only the updated region moves (2x update).
        * dynamic-slice: only the slice moves (2x result).
        * fusions whose root is a dynamic-update-slice (scan carries, KV-cache
          writes): the aliased big operand is NOT re-read/re-written; count
          2x the update + the other (small) operands.
        """
        opk = ins.op
        ops = ins.operands()
        if opk == "dynamic-update-slice":
            upd = self._types.get(comp, {}).get(ops[1]) if len(ops) > 1 else None
            return 2.0 * _nbytes(upd) if upd else _nbytes(ins.result)
        if opk == "dynamic-slice":
            return 2.0 * _nbytes(ins.result)
        if opk == "fusion":
            called = ins.attr("calls")
            root = None
            if called:
                body = self.comps.get(called.lstrip("%"), [])
                root = body[-1] if body else None
            if root is not None and root.op == "dynamic-update-slice":
                rops = root.operands()
                upd_t = self._types.get(called.lstrip("%"), {}).get(rops[1]) if len(rops) > 1 else None
                small = 0.0
                # other fusion operands (indices, scalars) are negligible but
                # include any non-aliased tensor operands conservatively
                return (2.0 * _nbytes(upd_t) if upd_t else _nbytes(ins.result)) + small
        b = _nbytes(ins.result)
        for ref in ops:
            t = self._types.get(comp, {}).get(ref)
            if t:
                b += _nbytes(t)
        return b

    @staticmethod
    def _coll_group_size(rest: str) -> int:
        m = re.search(r"replica_groups=\{\{([0-9,]+)\}", rest)
        if m:
            return len(m.group(1).split(","))
        m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[", rest)
        if m:
            return int(m.group(2))
        return 2

    def totals(self) -> Dict[str, object]:
        flops, byts, colls = self._walk(self.entry)
        total = {"count": sum(v["count"] for v in colls.values()),
                 "bytes": sum(v["bytes"] for v in colls.values())}
        colls = dict(colls)
        colls["total"] = total
        return {"flops": flops, "bytes": byts, "collectives": colls}


def analyze_text(text: str) -> Dict[str, object]:
    return HloCost(text).totals()
