"""Configuration system: model / blocks / shapes / training / multilevel / mesh.

One ``ModelConfig`` covers every assigned architecture family (dense, MoE, MLA,
hybrid Mamba+attention, xLSTM, VLM cross-attention, encoder-decoder audio).
Depth is described by *stages*: each stage is a short heterogeneous ``pattern``
of blocks scanned over ``repeats`` (compact HLO for 61-72 layer dry-runs, and
the axis along which the paper's depth-coalescing operator acts).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One block in a stage pattern."""

    mixer: str = "attn"  # attn | cross_attn | enc_attn | mamba | mlstm | slstm
    ffn: str = "dense"  # dense | moe | none

    @property
    def tag(self) -> str:
        return f"{self.mixer}.{self.ffn}"


@dataclasses.dataclass(frozen=True)
class Stage:
    pattern: Tuple[BlockSpec, ...]
    repeats: int

    @property
    def n_layers(self) -> int:
        return len(self.pattern) * self.repeats


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio | vit | encoder
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    stages: Tuple[Stage, ...]
    head_dim: int = 0  # 0 -> d_model // n_heads

    # attention
    attn_type: str = "gqa"  # gqa | mla
    causal: bool = True
    qk_norm: bool = False
    rope_theta: float = 10000.0
    attn_logit_softcap: float = 0.0

    # MLA (deepseek-v3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # Mamba (jamba)
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0  # 0 -> ceil(d_model/16)

    # xLSTM
    xlstm_proj_factor: float = 2.0

    # encoder-decoder (whisper)
    n_encoder_layers: int = 0
    encoder_seq: int = 0  # e.g. 1500 mel frames after conv frontend (stub)

    # VLM cross attention
    n_image_tokens: int = 0
    cross_attn_period: int = 0  # informational; pattern encodes positions
    vision_dim: int = 0  # stub frontend feature dim (0 -> d_model); NOT coalesced

    # ViT
    image_size: int = 224
    patch_size: int = 16
    n_classes: int = 1000

    # embeddings / head
    tie_embeddings: bool = True
    vocab_pad_to: int = 128
    mtp_depth: int = 0  # deepseek multi-token prediction heads
    mtp_loss_weight: float = 0.3

    # numerics
    act: str = "silu"  # silu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-5
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    use_bias: bool = False

    # performance knobs (hillclimbing levers)
    ssm_chunk: int = 128  # recurrent-scan remat chunk (memory / (S/chunk))
    attn_seq_shard: bool = False  # shard attn activations along seq (context
    # parallelism) when the head count does not divide the model axis
    attn_impl: str = "blockwise"  # plain | blockwise | pallas
    attn_block_k: int = 512
    kernel_backend: str = ""  # "" = auto; else pallas | pallas-interpret | xla
    # (per-op resolution lives in repro.kernels.dispatch; REPRO_KERNEL_BACKEND
    # env overrides the auto default, this field overrides both)
    remat: str = "full"  # none | full | dots
    scan_layers: bool = True
    seq_shard_cache: bool = True  # shard decode KV/latent cache seq over "model"
    coalesce_experts: bool = False  # beyond-paper: pair-merge experts too

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def n_layers(self) -> int:
        return sum(s.n_layers for s in self.stages)

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return ((self.vocab_size + p - 1) // p) * p

    @property
    def resolved_dt_rank(self) -> int:
        return self.mamba_dt_rank or -(-self.d_model // 16)

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def uniform_stages(n_layers: int, block: BlockSpec) -> Tuple[Stage, ...]:
    return (Stage(pattern=(block,), repeats=n_layers),)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 300
    warmup_steps: int = 20
    peak_lr: float = 1e-3
    end_lr_frac: float = 0.1
    schedule: str = "cosine"  # cosine | linear | constant
    weight_decay: float = 0.01
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    grad_accum: int = 1
    opt_dtype: Any = jnp.float32  # adam moment dtype (bf16 for giant dry-runs)
    seed: int = 0
    batch_size: int = 8
    seq_len: int = 64
    log_every: int = 10
    grad_compression: str = "none"  # none | int8_ef (shard_map DP all-reduce)
    z_loss: float = 0.0
    pregather_params: bool = False  # per-step FSDP weight gather (vs per-layer
    # per-microbatch); opt-in where total_bf16/model_shard fits HBM


@dataclasses.dataclass(frozen=True)
class MultiLevelConfig:
    """Paper Algorithm 1 hyper-parameters (fractions of total step budget)."""

    n_levels: int = 2
    alpha: float = 0.25  # interpolation ratio (paper: 0.25 GPT/DeiT, 0.5 BERT)
    e_a_frac: float = 0.033  # E_a: init steps per level before coalescing (10K/300K)
    e_small_frac: float = 0.5  # E_small: small-model steps (one half of full cycle)
    width_variant: str = "stack"  # stack | adj  (Appendix E)
    depth_variant: str = "adj"  # adj | stack   (Appendix E)
    reset_opt: bool = True  # paper re-inits optimizer at transitions
    coalesce_experts: bool = False


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n
