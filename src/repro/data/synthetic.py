"""Deterministic synthetic corpora (container has no internet).

* ``MarkovLM``  -- a sparse first-order Markov chain over the vocabulary with a
  known stationary entropy: loss curves are meaningful (models genuinely learn
  the transition structure) and the achievable-loss floor is computable, so
  V-cycle vs from-scratch FLOPs-saving comparisons are well-posed.
* ``vision_batch`` -- class-conditional Gaussian patch patterns for the DeiT
  proxy (images are linearly separable given enough training, mimicking a
  learnable classification task).

Batches are a pure function of (seed, step, shard) => any host can regenerate
any shard: deterministic, host-count-independent data sharding (straggler /
elastic-restart friendly; see DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class MarkovLM:
    """Sparse Markov chain: each token has ``branch`` likely successors."""

    vocab: int
    branch: int = 4
    seed: int = 1234

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        succ = rng.integers(0, self.vocab, size=(self.vocab, self.branch))
        logits = rng.normal(size=(self.vocab, self.branch)) * 1.0
        probs = np.exp(logits) / np.exp(logits).sum(-1, keepdims=True)
        self.succ = jnp.asarray(succ, jnp.int32)
        self.probs = jnp.asarray(probs, jnp.float32)

    def entropy(self) -> float:
        p = np.asarray(self.probs)
        return float(-(p * np.log(p)).sum(-1).mean())

    def sample(self, key: jax.Array, batch: int, seq: int) -> jax.Array:
        k0, k1 = jax.random.split(key)
        tok0 = jax.random.randint(k0, (batch,), 0, self.vocab)

        def step(tok, k):
            choice = jax.random.categorical(k, jnp.log(self.probs[tok]))
            nxt = self.succ[tok, choice]
            return nxt, nxt

        keys = jax.random.split(k1, seq)
        _, toks = jax.lax.scan(step, tok0, keys)
        return jnp.concatenate([tok0[None], toks], 0).T[:, : seq + 1]  # [B, seq+1]


def chain_entropy(vocab: int, branch: int = 4, seed: int = 1234) -> float:
    return MarkovLM(vocab, branch, seed).entropy()


def _batch_key(seed: int, step: int, shard: int) -> jax.Array:
    return jax.random.fold_in(jax.random.fold_in(jax.random.PRNGKey(seed), step), shard)


def lm_batch(chain: MarkovLM, seed: int, step: int, batch: int, seq: int,
             shard: int = 0) -> Dict[str, jax.Array]:
    """Causal LM batch: tokens + next-token labels."""
    toks = chain.sample(_batch_key(seed, step, shard), batch, seq)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def masked_lm_batch(chain: MarkovLM, seed: int, step: int, batch: int, seq: int,
                    mask_id: int, mask_rate: float = 0.15, shard: int = 0) -> Dict[str, jax.Array]:
    """BERT-style MLM batch: 15% positions replaced by [MASK]; labels=-1 elsewhere."""
    key = _batch_key(seed, step, shard)
    k0, k1 = jax.random.split(key)
    toks = chain.sample(k0, batch, seq)[:, :seq]
    mask = jax.random.bernoulli(k1, mask_rate, toks.shape)
    inputs = jnp.where(mask, mask_id, toks)
    labels = jnp.where(mask, toks, -1)
    return {"tokens": inputs, "labels": labels}


def vision_batch(seed: int, step: int, batch: int, n_patches: int, patch_dim: int,
                 n_classes: int, shard: int = 0) -> Dict[str, jax.Array]:
    """Class-conditional Gaussian patch patterns (learnable classification)."""
    key = _batch_key(seed, step, shard)
    k0, k1, k2 = jax.random.split(key, 3)
    proto_key = jax.random.PRNGKey(seed + 77)  # class prototypes fixed across steps
    protos = jax.random.normal(proto_key, (n_classes, n_patches, patch_dim)) * 0.5
    labels = jax.random.randint(k0, (batch,), 0, n_classes)
    noise = jax.random.normal(k1, (batch, n_patches, patch_dim))
    patches = protos[labels] + noise
    return {"patches": patches.astype(jnp.float32), "labels": labels}
