from repro.data.synthetic import (  # noqa: F401
    MarkovLM,
    lm_batch,
    masked_lm_batch,
    vision_batch,
    chain_entropy,
)
