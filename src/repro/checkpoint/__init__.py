from repro.checkpoint.store import ObjectStore, leaf_digest  # noqa: F401
from repro.checkpoint.manager import (  # noqa: F401
    CheckpointManager,
    restore_tree,
    save_tree,
)
