"""Fault-tolerant checkpointing.

Design (scaled-down but faithful to multi-host practice):

* **Atomic**: each save writes into ``step_XXXXXXXX.tmp/`` then ``os.rename``s
  to ``step_XXXXXXXX/`` and finally rewrites ``manifest.json`` -- a crash at
  any point leaves the previous checkpoint fully intact (preemption-safe).
* **Sharded layout**: in single-process runs leaves are stored as one
  ``.npy`` per leaf path inside the step directory.  In multi-process runs
  (``jax.process_count() > 1``) saves are COORDINATED: each process writes
  only the array chunks it addressably owns (replica 0 of each unique shard)
  into ``step_XXXXXXXX.tmp/shard_<pid>/<tree>/...`` plus a per-process
  ``index.json`` recording global shapes and chunk offsets; a barrier
  precedes the process-0 publish (rename + manifest), so a crash on ANY
  process before the barrier leaves the previous checkpoint fully intact.
  ``save_tree`` (the single-process path) refuses leaves that are not fully
  addressable -- ``jax.device_get`` on those would gather garbage.
* **Elastic restore**: checkpoints store *logical* (unsharded) arrays --
  whole-leaf files and shard chunks reassemble to the same logical value --
  so a checkpoint written under mesh A (and any process count) restores onto
  mesh B (and any other process count) by passing target ``shardings``;
  re-sharding happens in ``jax.device_put`` / ``make_array_from_callback``.
* **Async**: ``save(..., blocking=False)`` snapshots to host memory
  synchronously (cheap) and writes files on a background thread, overlapping
  I/O with the next training steps.  Coordinated multi-process saves are
  always synchronous: the publish barrier must not run collectives/RPCs on a
  background thread while the training loop is mid-collective.
* **V-cycle aware**: arbitrary JSON metadata rides along in the manifest.
  ``launch/train.py`` stores the full ``VCycleState`` addressing -- phase,
  level, segment index, step-within-segment, global step, cumulative FLOPs,
  the FLOPs-indexed history and which ``params_before`` stashes are present
  (saved as extra ``params_before_<level>`` trees) -- so the launcher resumes
  mid-V-cycle, including mid-upward-sweep, and replays the pending level
  transition deterministically.
* **Collision-free leaf names**: leaf paths are percent-encoded into file
  names (v2 layout, flagged by a ``leafenc.json`` marker); a path component
  containing a literal ``__`` (e.g. a ``w__gate`` leaf) round-trips exactly.
  Pre-v2 directories (no marker; ``/`` encoded as ``__``) are still readable.
* **keep_last**: old steps are garbage-collected after a successful save; the
  directory the manifest currently references is never collected, whatever
  its step number.
"""
from __future__ import annotations

import glob
import json
import os
import shutil
import threading
from typing import Any, Dict, Optional
from urllib.parse import quote, unquote

import jax
import numpy as np

# v2 layout marker written into every tree dir: leaf paths are percent-encoded
# ("/" -> "%2F", "%" -> "%25"), which is injective -- unlike the legacy
# "/" -> "__" scheme that corrupted any leaf containing a literal "__".
_LAYOUT_MARKER = "leafenc.json"
_LAYOUT_VERSION = 2
# per-process chunk index written into every shard_<pid>/ dir of a
# coordinated (multi-process) save
_SHARD_INDEX = "index.json"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix.rstrip("/")] = tree
    return out


def _unflatten_into(flat: Dict[str, np.ndarray], like):
    def rec(t, prefix):
        if isinstance(t, dict):
            return {k: rec(v, f"{prefix}{k}/") for k, v in t.items()}
        if isinstance(t, (list, tuple)):
            vals = [rec(v, f"{prefix}{i}/") for i, v in enumerate(t)]
            return type(t)(vals)
        return flat[prefix.rstrip("/")]

    return rec(like, "")


def _host_leaf(x) -> np.ndarray:
    """Fetch one leaf to host, refusing to gather garbage.

    A leaf sharded across processes is NOT fully addressable here;
    ``jax.device_get`` on it either raises or (for some layouts) silently
    returns only the local portion -- either way the single-process save path
    must not be fed one.  Multi-process runs go through the coordinated
    chunked writer (``CheckpointManager._save_coordinated``) instead.
    """
    if getattr(x, "is_fully_addressable", True) is False:
        raise ValueError(
            "cannot save a leaf that is not fully addressable from this "
            "process (it is sharded across processes); use "
            "CheckpointManager.save under jax.distributed -- the coordinated "
            "path writes per-process shard files -- instead of save_tree")
    return np.asarray(jax.device_get(x))


def save_tree(path: str, tree) -> None:
    """Single-process whole-leaf layout (one ``.npy`` per leaf path)."""
    os.makedirs(path, exist_ok=True)
    flat = _flatten(jax.tree.map(_host_leaf, tree))
    for k, v in flat.items():
        fn = os.path.join(path, quote(k, safe="") + ".npy")
        np.save(fn, np.asarray(v), allow_pickle=False)
    with open(os.path.join(path, _LAYOUT_MARKER), "w") as f:
        json.dump({"version": _LAYOUT_VERSION, "encoding": "percent"}, f)


def _write_tree_chunks(tree_dir: str, tree) -> Dict[str, Any]:
    """One process's share of a coordinated save: write the chunks this
    process owns (replica 0 of each unique shard, so every unique piece of
    data is written exactly once globally) and return the index entries.

    Leaves that are not jax Arrays spanning processes (host scalars, numpy,
    single-process arrays) are identical on every process by construction --
    process 0 writes them whole.
    """
    os.makedirs(tree_dir, exist_ok=True)
    index: Dict[str, Any] = {}
    for k, v in _flatten(tree).items():
        enc = quote(k, safe="")
        chunks = []
        if getattr(v, "is_fully_addressable", True) is False:
            for j, sh in enumerate(v.addressable_shards):
                if sh.replica_id != 0:
                    continue
                data = np.asarray(sh.data)
                start = [sl.indices(dim)[0]
                         for sl, dim in zip(sh.index, v.shape)]
                fn = f"{enc}.c{j}.npy"
                np.save(os.path.join(tree_dir, fn), data, allow_pickle=False)
                chunks.append({"file": fn, "start": start,
                               "shape": list(data.shape)})
        elif jax.process_index() == 0:
            data = _host_leaf(v)
            fn = f"{enc}.c0.npy"
            np.save(os.path.join(tree_dir, fn), data, allow_pickle=False)
            chunks.append({"file": fn, "start": [0] * data.ndim,
                           "shape": list(data.shape)})
        if chunks:
            index[k] = {"shape": list(np.shape(v)), "chunks": chunks}
    return index


def _read_leaves(path: str) -> Dict[str, np.ndarray]:
    """All leaves of one tree dir as logical host arrays.

    Understands every on-disk generation: whole-leaf files in ``path`` (v2
    percent-encoded and the legacy ``__`` scheme) AND coordinated-save chunk
    files in sibling ``shard_<pid>/`` dirs, which are reassembled into full
    logical arrays regardless of how many processes wrote them.
    """
    flat: Dict[str, np.ndarray] = {}
    if os.path.isdir(path):
        if os.path.exists(os.path.join(path, _LAYOUT_MARKER)):
            decode = unquote
        else:  # legacy layout: "/" was stored as "__" (lossy for literal "__")
            decode = lambda s: s.replace("__", "/")
        for fn in os.listdir(path):
            if fn.endswith(".npy"):
                flat[decode(fn[:-4])] = np.load(os.path.join(path, fn),
                                                allow_pickle=False)
    step_dir, tree_key = os.path.split(os.path.normpath(path))
    for sd in sorted(glob.glob(os.path.join(step_dir, "shard_*"))):
        idx_path = os.path.join(sd, _SHARD_INDEX)
        if not os.path.exists(idx_path):
            continue
        with open(idx_path) as f:
            trees = json.load(f)["trees"]
        for k, rec in trees.get(tree_key, {}).items():
            for ch in rec["chunks"]:
                data = np.load(os.path.join(sd, tree_key, ch["file"]),
                               allow_pickle=False)
                if k not in flat:
                    flat[k] = np.empty(rec["shape"], dtype=data.dtype)
                sl = tuple(slice(st, st + sz)
                           for st, sz in zip(ch["start"], ch["shape"]))
                flat[k][sl] = data
    return flat


def _put(x, like, sharding):
    """Land one restored logical leaf, casting to the like-leaf dtype.  When
    the target sharding spans processes, ``device_put`` of host data is
    impossible -- build the global array from addressable pieces instead."""
    host = np.asarray(x).astype(
        like.dtype if hasattr(like, "dtype") else x.dtype)
    if sharding is None:
        return jax.device_put(host)
    if getattr(sharding, "is_fully_addressable", True):
        return jax.device_put(host, sharding)
    return jax.make_array_from_callback(host.shape, sharding,
                                        lambda idx: host[idx])


def restore_tree(path: str, like, shardings=None):
    tree = _unflatten_into(_read_leaves(path), like)
    if shardings is not None:
        # elastic re-shard: checkpoints hold logical (unsharded) arrays, so a
        # save from mesh A (any process count) lands on mesh B here
        return jax.tree.map(_put, tree, like, shardings)
    return jax.tree.map(lambda x, l: _put(x, l, None), tree, like)


class CheckpointManager:
    """Atomic, mesh- and process-count-elastic checkpoint store.

    Single-process: whole-leaf files, optional async writes.  Multi-process
    (``jax.process_count() > 1``): every process participates in ``save`` --
    each writes only its addressable shard chunks, all meet at a barrier, and
    ONLY process 0 publishes (rename + manifest + GC), so the manifest flips
    exactly once and a crash anywhere before the barrier leaves the previous
    checkpoint referenced and intact.  ``restore`` reassembles logical arrays
    from whichever layout was written, onto whatever mesh and process count
    the restoring run uses.
    """

    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._save_seq = 0  # barrier-name uniquifier (same sequence on every process)

    # ---- manifest ----------------------------------------------------
    @property
    def manifest_path(self) -> str:
        return os.path.join(self.dir, "manifest.json")

    def latest(self) -> Optional[Dict[str, Any]]:
        if not os.path.exists(self.manifest_path):
            return None
        with open(self.manifest_path) as f:
            m = json.load(f)
        step_dir = os.path.join(self.dir, m["dir"])
        if not os.path.isdir(step_dir):  # torn manifest: fall back to scan
            return self._scan_fallback()
        return m

    def _step_dirs(self) -> list:
        """Published step dirs, oldest-publish first.

        Ordered by mtime (name as tie-break), NOT by step number: a restarted
        run with a shorter schedule publishes *smaller* step numbers than
        stale dirs left by a longer previous schedule, and both GC and the
        torn-manifest fallback must treat recency as publish order.
        """

        def key(d):
            try:
                mt = os.path.getmtime(os.path.join(self.dir, d))
            except OSError:
                mt = 0.0
            return (mt, d)

        return sorted((d for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp")
                       and os.path.isdir(os.path.join(self.dir, d))), key=key)

    def _scan_fallback(self) -> Optional[Dict[str, Any]]:
        cands = self._step_dirs()
        if not cands:
            return None
        d = cands[-1]
        meta_p = os.path.join(self.dir, d, "meta.json")
        meta = json.load(open(meta_p)) if os.path.exists(meta_p) else {}
        return {"dir": d, "step": int(d.split("_")[1]), "meta": meta}

    # ---- save ---------------------------------------------------------
    def save(self, step: int, state: Dict[str, Any], meta: Optional[Dict] = None,
             blocking: bool = True) -> None:
        """state: dict of named pytrees, e.g. {"params":…, "opt":…}.

        In multi-process runs every process MUST call this at the same step
        (the drivers do -- the cadence is deterministic); the save is then
        coordinated and always synchronous, whatever ``blocking`` says.
        """
        self.wait()
        if jax.process_count() > 1:
            self._save_coordinated(step, state, meta)
            return
        host_state = jax.tree.map(_host_leaf, state)  # synchronous snapshot

        def _write():
            name = f"step_{step:08d}"
            tmp = os.path.join(self.dir, name + ".tmp")
            final = os.path.join(self.dir, name)
            if os.path.isdir(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            for key, tree in host_state.items():
                save_tree(os.path.join(tmp, key), tree)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta or {}, f)
            if os.path.isdir(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic publish
            with open(self.manifest_path + ".tmp", "w") as f:
                json.dump({"dir": name, "step": step, "meta": meta or {}}, f)
            os.replace(self.manifest_path + ".tmp", self.manifest_path)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def _save_coordinated(self, step: int, state: Dict[str, Any],
                          meta: Optional[Dict]) -> None:
        """Multi-process save: per-process shard chunks, barrier, then a
        process-0-only publish.  Assumes the checkpoint directory is shared
        (the standard multi-host arrangement; on this container: localhost)."""
        from repro.distributed import barrier

        pid = jax.process_index()
        self._save_seq += 1
        tag = f"ckpt-{os.path.basename(self.dir)}-{self._save_seq}"
        name = f"step_{step:08d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        final = os.path.join(self.dir, name)
        if pid == 0:
            if os.path.isdir(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
        barrier(f"{tag}-prep")
        shard_dir = os.path.join(tmp, f"shard_{pid:03d}")
        os.makedirs(shard_dir, exist_ok=True)
        index = {key: _write_tree_chunks(os.path.join(shard_dir, key), tree)
                 for key, tree in state.items()}
        with open(os.path.join(shard_dir, _SHARD_INDEX), "w") as f:
            json.dump({"process": pid, "trees": index}, f)
        # every process's chunks are on disk before anyone publishes; a crash
        # before this point leaves only a .tmp dir -- the previous checkpoint
        # (and the manifest pointing at it) stays fully intact
        barrier(f"{tag}-written")
        if pid == 0:
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta or {}, f)
            if os.path.isdir(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic publish
            with open(self.manifest_path + ".tmp", "w") as f:
                json.dump({"dir": name, "step": step, "meta": meta or {}}, f)
            os.replace(self.manifest_path + ".tmp", self.manifest_path)
            self._gc()
        # nobody returns (and e.g. restores, or exits on a preemption drain)
        # until the manifest references the new step
        barrier(f"{tag}-published")

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        # Keep the keep_last most recently *published* dirs (mtime order, so
        # stale higher-numbered dirs from a longer previous schedule are
        # reclaimed, not shielded by their names).  The manifest's current dir
        # is sacrosanct regardless: it is the only checkpoint restore
        # references.
        current = None
        try:
            with open(self.manifest_path) as f:
                current = json.load(f).get("dir")
        except (OSError, ValueError):
            pass
        for d in self._step_dirs()[:-self.keep_last]:
            if d == current:
                continue
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)
        # stale .tmp dirs from a crashed earlier run: _gc only runs inside a
        # publish, at which point no save (local thread or peer process -- all
        # are past the write barrier) can still be filling one
        for d in os.listdir(self.dir):
            if d.endswith(".tmp") and os.path.isdir(os.path.join(self.dir, d)):
                shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # ---- restore --------------------------------------------------------
    def restore(self, like_state: Dict[str, Any], shardings: Optional[Dict] = None):
        """Returns (state, meta) from the newest valid checkpoint, or (None, None)."""
        m = self.latest()
        if m is None:
            return None, None
        base = os.path.join(self.dir, m["dir"])
        out = {}
        for key, like in like_state.items():
            sh = shardings.get(key) if shardings else None
            out[key] = restore_tree(os.path.join(base, key), like, sh)
        return out, m.get("meta", {})
