"""Fault-tolerant checkpointing.

Design (scaled-down but faithful to multi-host practice):

* **Atomic**: each save writes into ``step_XXXXXXXX.tmp/`` then ``os.rename``s
  to ``step_XXXXXXXX/`` and finally rewrites ``manifest.json`` -- a crash at
  any point leaves the previous checkpoint fully intact (preemption-safe).
* **Sharded layout**: leaves are stored as one ``.npy`` per leaf path inside
  the step directory (at real multi-host scale one file per host-shard; here
  one process owns all shards).  Arrays are fetched from device with
  ``jax.device_get`` -- works for sharded arrays on any mesh.
* **Elastic restore**: checkpoints store *logical* (unsharded) arrays, so a
  checkpoint written under mesh A restores onto mesh B by passing target
  ``shardings`` -- re-sharding happens in ``jax.device_put``.
* **Async**: ``save(..., blocking=False)`` snapshots to host memory
  synchronously (cheap) and writes files on a background thread, overlapping
  I/O with the next training steps.
* **V-cycle aware**: arbitrary JSON metadata rides along in the manifest.
  ``launch/train.py`` stores the full ``VCycleState`` addressing -- phase,
  level, segment index, step-within-segment, global step, cumulative FLOPs,
  the FLOPs-indexed history and which ``params_before`` stashes are present
  (saved as extra ``params_before_<level>`` trees) -- so the launcher resumes
  mid-V-cycle, including mid-upward-sweep, and replays the pending level
  transition deterministically.
* **Collision-free leaf names**: leaf paths are percent-encoded into file
  names (v2 layout, flagged by a ``leafenc.json`` marker); a path component
  containing a literal ``__`` (e.g. a ``w__gate`` leaf) round-trips exactly.
  Pre-v2 directories (no marker; ``/`` encoded as ``__``) are still readable.
* **keep_last**: old steps are garbage-collected after a successful save; the
  directory the manifest currently references is never collected, whatever
  its step number.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional
from urllib.parse import quote, unquote

import jax
import numpy as np

# v2 layout marker written into every tree dir: leaf paths are percent-encoded
# ("/" -> "%2F", "%" -> "%25"), which is injective -- unlike the legacy
# "/" -> "__" scheme that corrupted any leaf containing a literal "__".
_LAYOUT_MARKER = "leafenc.json"
_LAYOUT_VERSION = 2


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix.rstrip("/")] = tree
    return out


def _unflatten_into(flat: Dict[str, np.ndarray], like):
    def rec(t, prefix):
        if isinstance(t, dict):
            return {k: rec(v, f"{prefix}{k}/") for k, v in t.items()}
        if isinstance(t, (list, tuple)):
            vals = [rec(v, f"{prefix}{i}/") for i, v in enumerate(t)]
            return type(t)(vals)
        return flat[prefix.rstrip("/")]

    return rec(like, "")


def save_tree(path: str, tree) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(jax.device_get(tree))
    for k, v in flat.items():
        fn = os.path.join(path, quote(k, safe="") + ".npy")
        np.save(fn, np.asarray(v), allow_pickle=False)
    with open(os.path.join(path, _LAYOUT_MARKER), "w") as f:
        json.dump({"version": _LAYOUT_VERSION, "encoding": "percent"}, f)


def restore_tree(path: str, like, shardings=None):
    if os.path.exists(os.path.join(path, _LAYOUT_MARKER)):
        decode = unquote
    else:  # legacy layout: "/" was stored as "__" (lossy for literal "__")
        decode = lambda s: s.replace("__", "/")
    flat = {}
    for fn in os.listdir(path):
        if fn.endswith(".npy"):
            key = decode(fn[:-4])
            flat[key] = np.load(os.path.join(path, fn), allow_pickle=False)
    tree = _unflatten_into(flat, like)
    if shardings is not None:
        # elastic re-shard: checkpoints hold logical (unsharded) arrays, so a
        # save from mesh A lands on mesh B here; cast to the like-tree dtype
        # exactly as the unsharded branch does
        tree = jax.tree.map(
            lambda x, l, s: jax.device_put(np.asarray(x).astype(
                l.dtype if hasattr(l, "dtype") else x.dtype), s),
            tree, like, shardings)
    else:
        tree = jax.tree.map(
            lambda x, l: jax.device_put(np.asarray(x).astype(
                l.dtype if hasattr(l, "dtype") else x.dtype)), tree, like)
    return tree


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ---- manifest ----------------------------------------------------
    @property
    def manifest_path(self) -> str:
        return os.path.join(self.dir, "manifest.json")

    def latest(self) -> Optional[Dict[str, Any]]:
        if not os.path.exists(self.manifest_path):
            return None
        with open(self.manifest_path) as f:
            m = json.load(f)
        step_dir = os.path.join(self.dir, m["dir"])
        if not os.path.isdir(step_dir):  # torn manifest: fall back to scan
            return self._scan_fallback()
        return m

    def _step_dirs(self) -> list:
        """Published step dirs, oldest-publish first.

        Ordered by mtime (name as tie-break), NOT by step number: a restarted
        run with a shorter schedule publishes *smaller* step numbers than
        stale dirs left by a longer previous schedule, and both GC and the
        torn-manifest fallback must treat recency as publish order.
        """

        def key(d):
            try:
                mt = os.path.getmtime(os.path.join(self.dir, d))
            except OSError:
                mt = 0.0
            return (mt, d)

        return sorted((d for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp")
                       and os.path.isdir(os.path.join(self.dir, d))), key=key)

    def _scan_fallback(self) -> Optional[Dict[str, Any]]:
        cands = self._step_dirs()
        if not cands:
            return None
        d = cands[-1]
        meta_p = os.path.join(self.dir, d, "meta.json")
        meta = json.load(open(meta_p)) if os.path.exists(meta_p) else {}
        return {"dir": d, "step": int(d.split("_")[1]), "meta": meta}

    # ---- save ---------------------------------------------------------
    def save(self, step: int, state: Dict[str, Any], meta: Optional[Dict] = None,
             blocking: bool = True) -> None:
        """state: dict of named pytrees, e.g. {"params":…, "opt":…}."""
        self.wait()
        host_state = jax.device_get(state)  # synchronous snapshot

        def _write():
            name = f"step_{step:08d}"
            tmp = os.path.join(self.dir, name + ".tmp")
            final = os.path.join(self.dir, name)
            if os.path.isdir(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            for key, tree in host_state.items():
                save_tree(os.path.join(tmp, key), tree)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta or {}, f)
            if os.path.isdir(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic publish
            with open(self.manifest_path + ".tmp", "w") as f:
                json.dump({"dir": name, "step": step, "meta": meta or {}}, f)
            os.replace(self.manifest_path + ".tmp", self.manifest_path)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        # Keep the keep_last most recently *published* dirs (mtime order, so
        # stale higher-numbered dirs from a longer previous schedule are
        # reclaimed, not shielded by their names).  The manifest's current dir
        # is sacrosanct regardless: it is the only checkpoint restore
        # references.
        current = None
        try:
            with open(self.manifest_path) as f:
                current = json.load(f).get("dir")
        except (OSError, ValueError):
            pass
        for d in self._step_dirs()[:-self.keep_last]:
            if d == current:
                continue
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # ---- restore --------------------------------------------------------
    def restore(self, like_state: Dict[str, Any], shardings: Optional[Dict] = None):
        """Returns (state, meta) from the newest valid checkpoint, or (None, None)."""
        m = self.latest()
        if m is None:
            return None, None
        base = os.path.join(self.dir, m["dir"])
        out = {}
        for key, like in like_state.items():
            sh = shardings.get(key) if shardings else None
            out[key] = restore_tree(os.path.join(base, key), like, sh)
        return out, m.get("meta", {})
