"""Fault-tolerant checkpointing.

Design (scaled-down but faithful to multi-host practice):

* **Atomic**: each save writes into ``step_XXXXXXXX.tmp/`` then ``os.rename``s
  to ``step_XXXXXXXX/`` and finally rewrites ``manifest.json`` -- a crash at
  any point leaves the previous checkpoint fully intact (preemption-safe).
* **Content-addressed (layout v3, the default)**: leaves/chunks are hashed
  (blake2b over dtype + shape + bytes) and written once into a shared
  ``objects/`` pool (``repro.checkpoint.store``); the step directory is a
  small ``objects.json`` manifest mapping leaf paths to digests, so
  consecutive saves rewrite only leaves whose content changed (optimizer
  hyper-state, frozen embeddings and the V-cycle ``params_before_*`` stashes
  dedup to ~zero bytes), and GC is manifest-driven refcounting.  Dedup is
  measurable: ``last_save_stats`` reports bytes written vs reused per save.
  ``dedup=False`` writes the v2 whole-file layout; v1/v2 directories stay
  readable either way.
* **Sharded layout**: in multi-process runs (``jax.process_count() > 1``)
  saves are COORDINATED: each process writes only the array chunks it
  addressably owns (replica 0 of each unique shard) -- as pool objects (v3)
  or ``shard_<pid>/`` chunk files (v2) -- and a barrier precedes the
  process-0 publish, so a crash on ANY process before the barrier leaves the
  previous checkpoint fully intact.  ``save_tree`` (the single-process path)
  refuses leaves that are not fully addressable.
* **Per-host LOCAL dirs (no shared filesystem)**: ``local=True`` makes the
  manager treat ``directory`` as THIS process's private root.  Coordinated
  saves then exchange *digests* (not bytes) through the jax coordination
  service: every process pools its own chunks locally, process 0 merges the
  per-process manifests, and every process publishes the merged manifest +
  ``manifest.json`` into its own dir (each surviving host is
  self-describing).  On restore, missing objects are gathered from whichever
  peer holds them (coordination-service KV transfer), or read from
  ``peer_dirs`` pools directly (e.g. the process-0 dir of a previous run)
  when restoring with fewer processes.  See ``checkpoint/README.md``.
* **Elastic restore**: checkpoints store *logical* (unsharded) arrays --
  whole-leaf files, chunk files and pool objects reassemble to the same
  logical value -- so a checkpoint written under mesh A (and any process
  count) restores onto mesh B (and any other process count) by passing target
  ``shardings``; re-sharding happens in ``jax.device_put`` /
  ``make_array_from_callback``.
* **Async**: ``save(..., blocking=False)`` snapshots to host memory
  synchronously (cheap) and writes files on a background thread, overlapping
  I/O with the next training steps.  Coordinated multi-process saves are
  always synchronous: the publish barrier must not run collectives/RPCs on a
  background thread while the training loop is mid-collective.
* **V-cycle aware**: arbitrary JSON metadata rides along in the manifest
  (``launch/train.py`` stores the full ``VCycleState`` addressing).
* **Collision-free leaf names**: v2+ layouts percent-encode leaf paths (v3
  keeps them only inside JSON); a path component containing a literal ``__``
  round-trips exactly.  Pre-v2 directories (no marker; ``/`` encoded as
  ``__``) are still readable.
* **keep_last**: old steps are garbage-collected after a successful save; the
  directory the manifest currently references is never collected, whatever
  its step number; pool objects are reclaimed exactly when no kept step
  manifest references their digest (so a crash between object write and
  publish strands orphans that the next successful save's GC sweeps up).
"""
from __future__ import annotations

import glob
import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional
from urllib.parse import quote, unquote

import jax
import numpy as np

from repro.checkpoint import store as store_lib
from repro.checkpoint.store import ObjectStore

# v2 layout marker written into every tree dir: leaf paths are percent-encoded
# ("/" -> "%2F", "%" -> "%25"), which is injective -- unlike the legacy
# "/" -> "__" scheme that corrupted any leaf containing a literal "__".
_LAYOUT_MARKER = "leafenc.json"
_LAYOUT_VERSION = 2
# per-process chunk index written into every shard_<pid>/ dir of a
# coordinated (multi-process) v2 save
_SHARD_INDEX = "index.json"

# per-process instance counter: scopes coordination-service keys/barriers so
# concurrent managers never collide.  Multi-process runs must construct their
# CheckpointManagers in the same order on every process (they run the same
# program), which keeps the scope names aligned across ranks.
_MANAGER_COUNT = 0


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix.rstrip("/")] = tree
    return out


def _unflatten_into(flat: Dict[str, np.ndarray], like):
    def rec(t, prefix):
        if isinstance(t, dict):
            return {k: rec(v, f"{prefix}{k}/") for k, v in t.items()}
        if isinstance(t, (list, tuple)):
            vals = [rec(v, f"{prefix}{i}/") for i, v in enumerate(t)]
            return type(t)(vals)
        return flat[prefix.rstrip("/")]

    return rec(like, "")


def _host_leaf(x) -> np.ndarray:
    """Fetch one leaf to host, refusing to gather garbage.

    A leaf sharded across processes is NOT fully addressable here;
    ``jax.device_get`` on it either raises or (for some layouts) silently
    returns only the local portion -- either way the single-process save path
    must not be fed one.  Multi-process runs go through the coordinated
    chunked writer instead.
    """
    if getattr(x, "is_fully_addressable", True) is False:
        raise ValueError(
            "cannot save a leaf that is not fully addressable from this "
            "process (it is sharded across processes); use "
            "CheckpointManager.save under jax.distributed -- the coordinated "
            "path writes per-process shard files -- instead of save_tree")
    return np.asarray(jax.device_get(x))


def save_tree(path: str, tree) -> None:
    """Whole-leaf v2 layout (one ``.npy`` per leaf path), single-process."""
    os.makedirs(path, exist_ok=True)
    flat = _flatten(jax.tree.map(_host_leaf, tree))
    for k, v in flat.items():
        fn = os.path.join(path, quote(k, safe="") + ".npy")
        np.save(fn, np.asarray(v), allow_pickle=False)
    with open(os.path.join(path, _LAYOUT_MARKER), "w") as f:
        json.dump({"version": _LAYOUT_VERSION, "encoding": "percent"}, f)


def _write_tree_chunks(tree_dir: str, tree) -> Dict[str, Any]:
    """One process's share of a coordinated v2 save: write the chunks this
    process owns (replica 0 of each unique shard, so every unique piece of
    data is written exactly once globally) and return the index entries.

    Leaves that are not jax Arrays spanning processes (host scalars, numpy,
    single-process arrays) are identical on every process by construction --
    process 0 writes them whole.
    """
    os.makedirs(tree_dir, exist_ok=True)
    index: Dict[str, Any] = {}
    for k, v in _flatten(tree).items():
        enc = quote(k, safe="")
        chunks = []
        if getattr(v, "is_fully_addressable", True) is False:
            for j, sh in enumerate(v.addressable_shards):
                if sh.replica_id != 0:
                    continue
                data = np.asarray(sh.data)
                start = [sl.indices(dim)[0]
                         for sl, dim in zip(sh.index, v.shape)]
                fn = f"{enc}.c{j}.npy"
                np.save(os.path.join(tree_dir, fn), data, allow_pickle=False)
                chunks.append({"file": fn, "start": start,
                               "shape": list(data.shape)})
        elif jax.process_index() == 0:
            data = _host_leaf(v)
            fn = f"{enc}.c0.npy"
            np.save(os.path.join(tree_dir, fn), data, allow_pickle=False)
            chunks.append({"file": fn, "start": [0] * data.ndim,
                           "shape": list(data.shape)})
        if chunks:
            index[k] = {"shape": list(np.shape(v)), "chunks": chunks}
    return index


def _read_leaves(path: str, pools: Optional[List[ObjectStore]] = None
                 ) -> Dict[str, np.ndarray]:
    """All leaves of one tree dir as logical host arrays.

    Understands every on-disk generation: v3 step manifests (digests resolved
    through ``pools``, defaulting to the checkpoint root's own ``objects/``
    pool), whole-leaf files in ``path`` (v2 percent-encoded and the legacy
    ``__`` scheme) AND coordinated-save v2 chunk files in sibling
    ``shard_<pid>/`` dirs -- all reassembled into full logical arrays
    regardless of how many processes wrote them.
    """
    step_dir, tree_key = os.path.split(os.path.normpath(path))
    trees = store_lib.read_step_manifest(step_dir) if step_dir else None
    if trees is not None:
        if pools is None:
            pools = [ObjectStore(os.path.dirname(step_dir))]
        return store_lib.assemble_tree(trees.get(tree_key, {}), pools)
    flat: Dict[str, np.ndarray] = {}
    if os.path.isdir(path):
        if os.path.exists(os.path.join(path, _LAYOUT_MARKER)):
            decode = unquote
        else:  # legacy layout: "/" was stored as "__" (lossy for literal "__")
            decode = lambda s: s.replace("__", "/")
        for fn in os.listdir(path):
            if fn.endswith(".npy"):
                flat[decode(fn[:-4])] = np.load(os.path.join(path, fn),
                                                allow_pickle=False)
    for sd in sorted(glob.glob(os.path.join(step_dir, "shard_*"))):
        idx_path = os.path.join(sd, _SHARD_INDEX)
        if not os.path.exists(idx_path):
            continue
        with open(idx_path) as f:
            trees = json.load(f)["trees"]
        for k, rec in trees.get(tree_key, {}).items():
            for ch in rec["chunks"]:
                data = np.load(os.path.join(sd, tree_key, ch["file"]),
                               allow_pickle=False)
                if k not in flat:
                    flat[k] = np.empty(rec["shape"], dtype=data.dtype)
                sl = tuple(slice(st, st + sz)
                           for st, sz in zip(ch["start"], ch["shape"]))
                flat[k][sl] = data
    return flat


def _put(x, like, sharding):
    """Land one restored logical leaf, casting to the like-leaf dtype.  When
    the target sharding spans processes, ``device_put`` of host data is
    impossible -- build the global array from addressable pieces instead."""
    host = np.asarray(x)
    if (host.dtype.kind == "V" and hasattr(like, "dtype")
            and np.dtype(like.dtype).itemsize == host.dtype.itemsize):
        # np.save round-trips ml_dtypes leaves (bfloat16) as raw void bytes;
        # the like-tree knows the true dtype, so view them back
        host = host.view(like.dtype)
    host = host.astype(like.dtype if hasattr(like, "dtype") else host.dtype)
    if sharding is None:
        return jax.device_put(host)
    if getattr(sharding, "is_fully_addressable", True):
        return jax.device_put(host, sharding)
    return jax.make_array_from_callback(host.shape, sharding,
                                        lambda idx: host[idx])


def _land_tree(flat: Dict[str, np.ndarray], like, shardings=None):
    """Unflatten restored logical leaves into ``like``'s structure and land
    them on devices.  With ``shardings``, this is the elastic re-shard:
    checkpoints hold logical (unsharded) arrays, so a save from mesh A (any
    process count) lands on mesh B here."""
    tree = _unflatten_into(flat, like)
    if shardings is not None:
        return jax.tree.map(_put, tree, like, shardings)
    return jax.tree.map(lambda x, l: _put(x, l, None), tree, like)


def restore_tree(path: str, like, shardings=None,
                 pools: Optional[List[ObjectStore]] = None):
    return _land_tree(_read_leaves(path, pools=pools), like, shardings)


class CheckpointManager:
    """Atomic, mesh- and process-count-elastic, content-addressed checkpoints.

    Single-process: pool objects + a step manifest (v3; ``dedup=False`` falls
    back to v2 whole-leaf files), optional async writes.  Multi-process
    (``jax.process_count() > 1``): every process participates in ``save`` --
    each writes only its addressable shard chunks, all meet at a barrier, and
    ONLY process 0 publishes (rename + manifest + GC) -- unless ``local=True``
    (no shared filesystem), where every process pools chunks in its OWN
    ``directory``, manifests travel through the coordination-service KV store,
    and every process publishes locally.  ``restore`` reassembles logical
    arrays from whichever layout was written, onto whatever mesh and process
    count the restoring run uses, gathering missing pool objects from peers
    (coordination KV) or from ``peer_dirs`` (directly-readable foreign pools,
    e.g. another host's recovered local dir).
    """

    def __init__(self, directory: str, keep_last: int = 3, *,
                 dedup: bool = True, local: bool = False, peer_dirs=()):
        global _MANAGER_COUNT
        _MANAGER_COUNT += 1
        self._scope = f"ckptmgr{_MANAGER_COUNT}"
        self.dir = directory
        self.keep_last = keep_last
        self.local = bool(local)
        self.dedup = bool(dedup) or self.local  # local mode is v3-only
        self.store = ObjectStore(directory)
        self.peer_pools = [ObjectStore(d) for d in peer_dirs]
        #: per-save dedup accounting of THIS process's most recent v3 save:
        #: {bytes,objects}_{written,reused} (reused = content-addressed hits)
        self.last_save_stats: Dict[str, int] = {}
        self.last_gather_stats: Dict[str, int] = {}
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._save_seq = 0  # barrier-name uniquifier (same sequence on every process)
        self._kv_seq = 0  # coordination-KV key uniquifier (ditto)
        self._remote_trees: Dict[str, Any] = {}  # step dir -> KV-broadcast manifest

    def _pools(self) -> List[ObjectStore]:
        return [self.store, *self.peer_pools]

    # ---- manifest ----------------------------------------------------
    @property
    def manifest_path(self) -> str:
        return os.path.join(self.dir, "manifest.json")

    def latest(self) -> Optional[Dict[str, Any]]:
        """Newest valid checkpoint's manifest record, or None.

        In local-dir multi-process runs this is COORDINATED (process 0 reads
        its dir and broadcasts over the coordination KV, so every process --
        including ones with a fresh/empty local dir -- agrees on the same
        answer); call it symmetrically on every process, like ``save``.
        """
        if self.local and jax.process_count() > 1:
            return self._latest_coordinated()
        return self._latest_uncoordinated()

    def _latest_uncoordinated(self) -> Optional[Dict[str, Any]]:
        if not os.path.exists(self.manifest_path):
            return None
        with open(self.manifest_path) as f:
            m = json.load(f)
        step_dir = os.path.join(self.dir, m["dir"])
        if not os.path.isdir(step_dir):  # torn manifest: fall back to scan
            return self._scan_fallback()
        return m

    def _latest_coordinated(self) -> Optional[Dict[str, Any]]:
        """Newest checkpoint across EVERY process's local dir.

        All ranks exchange their local candidate and deterministically pick
        the max (step, dir) -- so the answer survives any subset of local
        dirs being lost or fresh (a rank 0 restarted on an empty disk must
        not make the whole job silently forget a checkpoint that a surviving
        host still publishes).  Whether the winning checkpoint's OBJECTS are
        all still held somewhere is ``_gather_objects``' job, which fails
        loudly rather than restarting from scratch.
        """
        from repro.distributed import (barrier, kv_delete_stream,
                                       kv_fetch_stream, kv_json_allgather,
                                       kv_put_stream)

        pid, n = jax.process_index(), jax.process_count()
        self._kv_seq += 1
        tag = f"{self._scope}-latest-{self._kv_seq}"
        # round 1: tiny candidates only -- the full step manifest is shipped
        # by the elected winner alone (N-1 broadcast copies would be dead
        # weight in coordinator RAM)
        m = self._latest_uncoordinated()
        cands = kv_json_allgather(f"{tag}-cand", m)
        ranked = [(c["step"], c["dir"], r) for r, c in enumerate(cands)
                  if c is not None]
        if not ranked:
            return None
        step, d, winner = max(ranked)
        best = cands[winner]
        # round 2: the winner ships its manifest (streamed -- a large model's
        # manifest is itself MBs of digests); everyone else fetches
        if pid == winner:
            trees = store_lib.read_step_manifest(os.path.join(self.dir, d))
            kv_put_stream(f"{tag}-best", json.dumps(trees).encode())
        else:
            trees = json.loads(kv_fetch_stream(f"{tag}-best"))
        barrier(f"{tag}-done")
        if pid == 0:
            kv_delete_stream(f"{tag}-best")
        if trees is not None:
            # processes without the step dir on local disk (fresh dir, fewer
            # or more hosts than at save time) restore from this broadcast
            self._remote_trees[d] = trees
        return best

    def _step_trees(self, m: Dict[str, Any]) -> Optional[Dict[str, Any]]:
        """v3 manifest of the step ``m`` references (disk, then KV broadcast
        cache); None when the step was written in a v1/v2 layout."""
        trees = store_lib.read_step_manifest(os.path.join(self.dir, m["dir"]))
        if trees is None:
            trees = self._remote_trees.get(m["dir"])
        return trees

    def step_manifest(self, m: Dict[str, Any]) -> Optional[Dict[str, Any]]:
        """Public accessor for the content-addressed (v3) manifest of the
        step ``m`` (a :meth:`latest` result) references: ``{tree_key ->
        {leaf_path -> {shape, dtype, chunks:[{digest, ...}]}}}``.

        This is the digest-level view live consumers diff against what they
        already hold (``launch/serve.ManifestWatcher``).  Returns None for
        steps written in a pre-content-addressed (v1/v2) layout, which carry
        no digests to diff.
        """
        return self._step_trees(m)

    def assemble_diff(self, trees: Dict[str, Any], key: str,
                      leaves) -> Dict[str, np.ndarray]:
        """Host arrays for exactly ``leaves`` of tree ``key`` -- the
        digest-diff restore behind live weight reload.

        The caller (``ManifestWatcher``) has already diffed the manifest's
        per-leaf chunk digests against what it holds and passes only the
        CHANGED leaf paths; unchanged leaves ship zero bytes because they are
        simply never read.  In no-shared-FS (``local=True``) multi-process
        mode the peer gather is pruned to the changed digests, so only those
        cross the wire (``last_gather_stats`` records the split); in
        shared-dir mode the stats are synthesized with the same shape so
        consumers can assert the diff either way.  Every process of a
        multi-process serving job must call this collectively.
        """
        entries = {k: trees[key][k] for k in leaves}
        needed = {ch["digest"] for rec in entries.values()
                  for ch in rec["chunks"]}
        if self.local and jax.process_count() > 1:
            self._gather_objects(trees, needed=needed)
        else:
            pools = self._pools()
            all_digests = sorted(set(store_lib.manifest_digests(trees)))
            have = [d for d in all_digests if any(p.has(d) for p in pools)]
            self.last_gather_stats = {
                "manifest": len(all_digests), "needed": len(needed),
                "skipped": len(all_digests) - len(needed), "held": len(have),
                "fetched": len(needed - set(have)), "served": 0}
        # assemble from a FILTERED manifest rather than assemble_tree's
        # ``needed=`` pruning: the latter still materializes every leaf
        # (unfetched regions as garbage), while reload must only ever touch
        # the changed ones
        return store_lib.assemble_tree(entries, self._pools())

    def _step_dirs(self) -> list:
        """Published step dirs, oldest-publish first.

        Ordered by mtime (name as tie-break), NOT by step number: a restarted
        run with a shorter schedule publishes *smaller* step numbers than
        stale dirs left by a longer previous schedule, and both GC and the
        torn-manifest fallback must treat recency as publish order.
        """

        def key(d):
            try:
                mt = os.path.getmtime(os.path.join(self.dir, d))
            except OSError:
                mt = 0.0
            return (mt, d)

        return sorted((d for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp")
                       and os.path.isdir(os.path.join(self.dir, d))), key=key)

    def _scan_fallback(self) -> Optional[Dict[str, Any]]:
        cands = self._step_dirs()
        if not cands:
            return None
        d = cands[-1]
        meta_p = os.path.join(self.dir, d, "meta.json")
        meta = json.load(open(meta_p)) if os.path.exists(meta_p) else {}
        return {"dir": d, "step": int(d.split("_")[1]), "meta": meta}

    # ---- save ---------------------------------------------------------
    def save(self, step: int, state: Dict[str, Any], meta: Optional[Dict] = None,
             blocking: bool = True) -> None:
        """state: dict of named pytrees, e.g. {"params":…, "opt":…}.

        In multi-process runs every process MUST call this at the same step
        (the drivers do -- the cadence is deterministic); the save is then
        coordinated and always synchronous, whatever ``blocking`` says.
        """
        self.wait()
        if jax.process_count() > 1:
            if self.local:
                self._save_local_coordinated(step, state, meta)
            else:
                self._save_coordinated(step, state, meta)
            return
        host_state = jax.tree.map(_host_leaf, state)  # synchronous snapshot

        def _write():
            name = f"step_{step:08d}"
            tmp = os.path.join(self.dir, name + ".tmp")
            if os.path.isdir(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            if self.dedup:
                before = self.store.stats()
                trees = {key: self._pool_whole_tree(tree)
                         for key, tree in host_state.items()}
                store_lib.write_step_manifest(tmp, trees)
                self._set_save_stats(before)
            else:
                for key, tree in host_state.items():
                    save_tree(os.path.join(tmp, key), tree)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta or {}, f)
            self._publish(name, tmp, step, meta)

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def _set_save_stats(self, before: Dict[str, int]) -> None:
        after = self.store.stats()
        self.last_save_stats = {k: after[k] - before[k] for k in after}

    def _pool_whole_tree(self, tree) -> Dict[str, Any]:
        """Pool every leaf of one host tree whole; returns manifest entries."""
        entries: Dict[str, Any] = {}
        for k, v in _flatten(tree).items():
            v = store_lib.as_host_leaf(v)
            d = store_lib.leaf_digest(v)
            self.store.put(d, v)
            entries[k] = store_lib.whole_leaf_entry(d, v)
        return entries

    def _pool_chunk_entries(self, tree) -> Dict[str, Any]:
        """One process's share of a coordinated v3 save: pool the chunks this
        process addressably owns (replica 0 of each unique shard) and return
        the partial manifest entries (merged across processes by the
        publisher).  Fully-addressable leaves are identical on every process
        by construction -- process 0 pools them whole."""
        entries: Dict[str, Any] = {}
        for k, v in _flatten(tree).items():
            if getattr(v, "is_fully_addressable", True) is False:
                chunks = []
                dtype = None
                for sh in v.addressable_shards:
                    if sh.replica_id != 0:
                        continue
                    data = store_lib.as_host_leaf(sh.data)
                    dtype = str(data.dtype)
                    dig = store_lib.leaf_digest(data)
                    self.store.put(dig, data)
                    start = [sl.indices(dim)[0]
                             for sl, dim in zip(sh.index, v.shape)]
                    chunks.append({"digest": dig, "start": start,
                                   "shape": list(data.shape)})
                if chunks:
                    entries[k] = {"shape": list(v.shape), "dtype": dtype,
                                  "chunks": chunks}
            elif jax.process_index() == 0:
                data = store_lib.as_host_leaf(_host_leaf(v))
                dig = store_lib.leaf_digest(data)
                self.store.put(dig, data)
                entries[k] = store_lib.whole_leaf_entry(dig, data)
        return entries

    def _publish(self, name: str, tmp: str, step: int,
                 meta: Optional[Dict]) -> None:
        """Atomic publish: rename the staged step dir, flip ``manifest.json``,
        GC.  Everything before this point is crash-safe by construction (a
        torn save leaves only a .tmp dir and unreferenced pool objects)."""
        final = os.path.join(self.dir, name)
        if os.path.isdir(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        with open(self.manifest_path + ".tmp", "w") as f:
            json.dump({"dir": name, "step": step, "meta": meta or {}}, f)
        os.replace(self.manifest_path + ".tmp", self.manifest_path)
        self._gc()

    def _save_coordinated(self, step: int, state: Dict[str, Any],
                          meta: Optional[Dict]) -> None:
        """Multi-process save into a SHARED checkpoint directory: per-process
        shard chunks, barrier, then a process-0-only publish."""
        if self.dedup:
            self._save_coordinated_v3(step, state, meta)
            return
        from repro.distributed import barrier

        pid = jax.process_index()
        self._save_seq += 1
        tag = f"{self._scope}-{self._save_seq}"
        name = f"step_{step:08d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        if pid == 0:
            if os.path.isdir(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
        barrier(f"{tag}-prep")
        shard_dir = os.path.join(tmp, f"shard_{pid:03d}")
        os.makedirs(shard_dir, exist_ok=True)
        index = {key: _write_tree_chunks(os.path.join(shard_dir, key), tree)
                 for key, tree in state.items()}
        with open(os.path.join(shard_dir, _SHARD_INDEX), "w") as f:
            json.dump({"process": pid, "trees": index}, f)
        # every process's chunks are on disk before anyone publishes; a crash
        # before this point leaves only a .tmp dir -- the previous checkpoint
        # (and the manifest pointing at it) stays fully intact
        barrier(f"{tag}-written")
        if pid == 0:
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta or {}, f)
            self._publish(name, tmp, step, meta)
        # nobody returns (and e.g. restores, or exits on a preemption drain)
        # until the manifest references the new step
        barrier(f"{tag}-published")

    def _save_coordinated_v3(self, step: int, state: Dict[str, Any],
                             meta: Optional[Dict]) -> None:
        """Coordinated save through the shared object pool: each process pools
        its addressable chunks (content-addressed, so unchanged chunks cost no
        I/O) and stages a partial manifest; process 0 merges and publishes."""
        from repro.distributed import barrier

        pid = jax.process_index()
        self._save_seq += 1
        tag = f"{self._scope}-{self._save_seq}"
        name = f"step_{step:08d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        if pid == 0:
            if os.path.isdir(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
        barrier(f"{tag}-prep")
        before = self.store.stats()
        index = {key: self._pool_chunk_entries(tree)
                 for key, tree in state.items()}
        self._set_save_stats(before)
        with open(os.path.join(tmp, f"index_{pid:03d}.json"), "w") as f:
            json.dump(index, f)
        # all pool objects + partial manifests are durable before anyone
        # publishes; a crash before this point strands only orphan objects
        # (reclaimed by the next successful save's refcount GC)
        barrier(f"{tag}-written")
        if pid == 0:
            parts = []
            for fn in sorted(os.listdir(tmp)):
                if fn.startswith("index_") and fn.endswith(".json"):
                    with open(os.path.join(tmp, fn)) as f:
                        parts.append(json.load(f))
                    os.remove(os.path.join(tmp, fn))
            trees = {key: store_lib.merge_tree_entries(
                         [p.get(key, {}) for p in parts]) for key in state}
            store_lib.write_step_manifest(tmp, trees)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta or {}, f)
            self._publish(name, tmp, step, meta)
        barrier(f"{tag}-published")

    def _save_local_coordinated(self, step: int, state: Dict[str, Any],
                                meta: Optional[Dict]) -> None:
        """Coordinated save WITHOUT a shared filesystem: chunks go to this
        process's own pool, only digests cross the network.  Every process
        publishes the merged manifest into its own dir, so any surviving host
        is self-describing and per-host refcount GC stays local."""
        from repro.distributed import barrier, kv_json_allgather

        self._kv_seq += 1
        tag = f"{self._scope}-save-{self._kv_seq}"
        name = f"step_{step:08d}"
        before = self.store.stats()
        index = {key: self._pool_chunk_entries(tree)
                 for key, tree in state.items()}
        self._set_save_stats(before)
        # each rank puts its index only after its objects are durable, so the
        # allgather doubles as the write barrier; the merge is deterministic
        # (rank-ordered parts), so every rank computes the identical manifest
        parts = kv_json_allgather(f"{tag}-idx", index)
        trees = {key: store_lib.merge_tree_entries(
                     [p.get(key, {}) for p in parts]) for key in state}
        tmp = os.path.join(self.dir, name + ".tmp")
        if os.path.isdir(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        store_lib.write_step_manifest(tmp, trees)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta or {}, f)
        self._publish(name, tmp, step, meta)
        # nobody returns (and e.g. exits on a preemption drain) until every
        # host's local dir references the new step
        barrier(f"{tag}-published")

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        # Keep the keep_last most recently *published* dirs (mtime order, so
        # stale higher-numbered dirs from a longer previous schedule are
        # reclaimed, not shielded by their names).  The manifest's current dir
        # is sacrosanct regardless: it is the only checkpoint restore
        # references.
        current = None
        try:
            with open(self.manifest_path) as f:
                current = json.load(f).get("dir")
        except (OSError, ValueError):
            pass
        for d in self._step_dirs()[:-self.keep_last]:
            if d == current:
                continue
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)
        # stale .tmp dirs from a crashed earlier run: _gc only runs inside a
        # publish, at which point no save (local thread or peer process -- all
        # are past the write barrier) can still be filling one
        for d in os.listdir(self.dir):
            if d.endswith(".tmp") and os.path.isdir(os.path.join(self.dir, d)):
                shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)
        # manifest-driven refcount GC of the object pool: an object is live
        # iff some kept step manifest references its digest.  Orphans from a
        # crash between object write and publish are unreferenced by
        # construction and get reclaimed here, on the next successful save.
        live = set()
        for d in self._step_dirs():
            trees = store_lib.read_step_manifest(os.path.join(self.dir, d))
            if trees is not None:
                live.update(store_lib.manifest_digests(trees))
        for dig in list(self.store.digests()):
            if dig not in live:
                self.store.delete(dig)

    # ---- restore --------------------------------------------------------
    def restore(self, like_state: Dict[str, Any], shardings: Optional[Dict] = None):
        """Returns (state, meta) from the newest valid checkpoint, or (None, None).

        Multi-process local-dir runs gather missing pool objects from peers
        first (coordination-KV transfer; see ``checkpoint/README.md``) --
        like ``save``, call symmetrically on every process.
        """
        m = self.latest()
        if m is None:
            return None, None
        trees = self._step_trees(m)
        needed = self._needed_digests(trees, like_state, shardings)
        if trees is not None and self.local and jax.process_count() > 1:
            self._gather_objects(trees, needed=needed)
        base = os.path.join(self.dir, m["dir"])
        out = {}
        for key, like in like_state.items():
            sh = shardings.get(key) if shardings else None
            if trees is not None:
                # the manifest may have arrived over the KV broadcast (local
                # dirs), so resolve digests directly rather than via a path
                out[key] = _land_tree(
                    store_lib.assemble_tree(trees.get(key, {}), self._pools(),
                                            needed=needed),
                    like, sh)
            else:
                out[key] = restore_tree(os.path.join(base, key), like, sh,
                                        pools=self._pools())
        return out, m.get("meta", {})

    def _needed_digests(self, trees, like_state, shardings):
        """Digest set this rank's restore actually touches, or None (= all).

        Sharding-aware pruning: a leaf restored into a sharded target only
        reads the chunks intersecting slices this process's devices address
        (``make_array_from_callback`` never reads the rest), so peers don't
        ship them and ``assemble_tree`` doesn't fetch them.  Leaves restored
        WITHOUT a sharding (plain ``device_put``) read their full extent and
        stay fully needed -- as do fully-addressable targets, where every
        slice is local anyway.
        """
        if trees is None or not shardings:
            return None
        needed: set = set()
        for key in like_state:
            entries = trees.get(key, {})
            sh = shardings.get(key)
            flat_sh = _flatten(sh) if sh is not None else {}
            # only prune leaves landing on multi-process shardings; a
            # fully-addressable sharding device_puts the whole host array
            flat_sh = {k: s for k, s in flat_sh.items()
                       if getattr(s, "is_fully_addressable", True) is False}
            needed |= store_lib.needed_digests(entries, flat_sh)
        return needed

    def _gather_objects(self, trees: Dict[str, Any],
                        needed: Optional[set] = None) -> None:
        """No-shared-FS restore protocol: fetch the manifest digests this
        process needs but is missing from whichever peer holds them.

        Rounds (all over the coordination-service KV store, tiny JSON +
        chunked object streams): (1) every process publishes its have/want
        lists -- have covers ALL held manifest digests (so it can serve any
        peer), want is the digests it needs (``needed``, when given, prunes
        this to the slices the rank's restore shardings address) and lacks;
        (2) each wanted digest is served by the LOWEST rank holding it
        (deterministic single writer), streamed in bounded chunks so a big
        leaf never lands in coordinator RAM whole; (3) wanters fetch and
        cache the bytes into their own pool (so the next save dedups against
        them).  Raises if a wanted digest is held by no process.
        """
        from repro.distributed import (barrier, kv_delete_stream,
                                       kv_fetch_stream, kv_json_allgather,
                                       kv_put_stream)

        pid, n = jax.process_index(), jax.process_count()
        self._kv_seq += 1
        tag = f"{self._scope}-gather-{self._kv_seq}"
        pools = self._pools()
        all_digests = sorted(set(store_lib.manifest_digests(trees)))
        have = [d for d in all_digests if any(p.has(d) for p in pools)]
        mine = all_digests if needed is None else sorted(
            set(all_digests) & set(needed))
        want = sorted(set(mine) - set(have))
        lists = kv_json_allgather(f"{tag}-lists",
                                  {"have": have, "want": want})
        haves = {r: set(lists[r]["have"]) for r in range(n)}
        wanted = sorted(set().union(*[set(lists[r]["want"])
                                      for r in range(n)]))
        served = 0
        for d in wanted:
            owner = next((r for r in range(n) if d in haves[r]), None)
            if owner is None:
                raise FileNotFoundError(
                    f"checkpoint object {d} is referenced by the manifest "
                    f"but held by no process; the checkpoint is incomplete "
                    f"(a writer host's local dir is gone?)")
            if owner == pid:
                payload = next(p.get_bytes(d) for p in pools if p.has(d))
                kv_put_stream(f"{tag}-obj-{d}", payload)
                served += 1
        # the manifest knows each digest's true dtype (npy round-trips
        # ml_dtypes as raw void bytes, which would re-hash differently)
        dtype_of = {ch["digest"]: rec.get("dtype")
                    for entries in trees.values()
                    for rec in entries.values() for ch in rec["chunks"]}
        for d in want:
            payload = kv_fetch_stream(f"{tag}-obj-{d}")
            # verify BEFORE caching: a content-addressed pool that trusts
            # transferred bytes makes a corrupt transfer sticky -- every
            # later save would dedup against the poisoned object
            got = store_lib.payload_digest(payload, dtype_of.get(d))
            if got != d:
                raise IOError(
                    f"checkpoint object {d} arrived corrupt from its peer "
                    f"(payload hashes to {got}); refusing to cache it")
            self.store.put_bytes(d, payload)
        self.last_gather_stats = {
            "manifest": len(all_digests), "needed": len(mine),
            "skipped": len(all_digests) - len(mine), "held": len(have),
            "fetched": len(want), "served": served}
        barrier(f"{tag}-done")
        if pid == 0:
            # the object payloads are the big entries -- a full elastic
            # restore parks the whole checkpoint in coordinator RAM until
            # this sweep reclaims it
            for d in wanted:
                kv_delete_stream(f"{tag}-obj-{d}")
