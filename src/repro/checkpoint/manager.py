"""Fault-tolerant checkpointing.

Design (scaled-down but faithful to multi-host practice):

* **Atomic**: each save writes into ``step_XXXXXXXX.tmp/`` then ``os.rename``s
  to ``step_XXXXXXXX/`` and finally rewrites ``manifest.json`` -- a crash at
  any point leaves the previous checkpoint fully intact (preemption-safe).
* **Sharded layout**: leaves are stored as one ``.npy`` per leaf path inside
  the step directory (at real multi-host scale one file per host-shard; here
  one process owns all shards).  Arrays are fetched from device with
  ``jax.device_get`` -- works for sharded arrays on any mesh.
* **Elastic restore**: checkpoints store *logical* (unsharded) arrays, so a
  checkpoint written under mesh A restores onto mesh B by passing target
  ``shardings`` -- re-sharding happens in ``jax.device_put``.
* **Async**: ``save(..., blocking=False)`` snapshots to host memory
  synchronously (cheap) and writes files on a background thread, overlapping
  I/O with the next training steps.
* **V-cycle aware**: arbitrary JSON metadata (level, phase, step, config hash)
  rides along in the manifest; the launcher resumes mid-V-cycle.
* **keep_last**: old steps are garbage-collected after a successful save.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix.rstrip("/")] = tree
    return out


def _unflatten_into(flat: Dict[str, np.ndarray], like):
    def rec(t, prefix):
        if isinstance(t, dict):
            return {k: rec(v, f"{prefix}{k}/") for k, v in t.items()}
        if isinstance(t, (list, tuple)):
            vals = [rec(v, f"{prefix}{i}/") for i, v in enumerate(t)]
            return type(t)(vals)
        return flat[prefix.rstrip("/")]

    return rec(like, "")


def save_tree(path: str, tree) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(jax.device_get(tree))
    for k, v in flat.items():
        fn = os.path.join(path, k.replace("/", "__") + ".npy")
        np.save(fn, np.asarray(v), allow_pickle=False)


def restore_tree(path: str, like, shardings=None):
    flat = {}
    for fn in os.listdir(path):
        if fn.endswith(".npy"):
            key = fn[:-4].replace("__", "/")
            flat[key] = np.load(os.path.join(path, fn), allow_pickle=False)
    tree = _unflatten_into(flat, like)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    else:
        tree = jax.tree.map(
            lambda x, l: jax.device_put(np.asarray(x).astype(
                l.dtype if hasattr(l, "dtype") else x.dtype)), tree, like)
    return tree


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ---- manifest ----------------------------------------------------
    @property
    def manifest_path(self) -> str:
        return os.path.join(self.dir, "manifest.json")

    def latest(self) -> Optional[Dict[str, Any]]:
        if not os.path.exists(self.manifest_path):
            return None
        with open(self.manifest_path) as f:
            m = json.load(f)
        step_dir = os.path.join(self.dir, m["dir"])
        if not os.path.isdir(step_dir):  # torn manifest: fall back to scan
            return self._scan_fallback()
        return m

    def _scan_fallback(self) -> Optional[Dict[str, Any]]:
        cands = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp")
                       and os.path.isdir(os.path.join(self.dir, d)))
        if not cands:
            return None
        d = cands[-1]
        meta_p = os.path.join(self.dir, d, "meta.json")
        meta = json.load(open(meta_p)) if os.path.exists(meta_p) else {}
        return {"dir": d, "step": int(d.split("_")[1]), "meta": meta}

    # ---- save ---------------------------------------------------------
    def save(self, step: int, state: Dict[str, Any], meta: Optional[Dict] = None,
             blocking: bool = True) -> None:
        """state: dict of named pytrees, e.g. {"params":…, "opt":…}."""
        self.wait()
        host_state = jax.device_get(state)  # synchronous snapshot

        def _write():
            name = f"step_{step:08d}"
            tmp = os.path.join(self.dir, name + ".tmp")
            final = os.path.join(self.dir, name)
            if os.path.isdir(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            for key, tree in host_state.items():
                save_tree(os.path.join(tmp, key), tree)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta or {}, f)
            if os.path.isdir(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic publish
            with open(self.manifest_path + ".tmp", "w") as f:
                json.dump({"dir": name, "step": step, "meta": meta or {}}, f)
            os.replace(self.manifest_path + ".tmp", self.manifest_path)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # ---- restore --------------------------------------------------------
    def restore(self, like_state: Dict[str, Any], shardings: Optional[Dict] = None):
        """Returns (state, meta) from the newest valid checkpoint, or (None, None)."""
        m = self.latest()
        if m is None:
            return None, None
        base = os.path.join(self.dir, m["dir"])
        out = {}
        for key, like in like_state.items():
            sh = shardings.get(key) if shardings else None
            out[key] = restore_tree(os.path.join(base, key), like, sh)
        return out, m.get("meta", {})
