"""Content-addressed checkpoint object store (layout v3).

Orbax-style incremental storage: every leaf (or shard chunk, in coordinated
multi-process saves) is serialized once into a shared ``objects/`` pool keyed
by a blake2b digest of its dtype + shape + raw bytes; a step directory is then
just a small JSON manifest (``objects.json``) mapping ``tree -> leaf path ->
{shape, dtype, chunks: [{digest, start, shape}]}``.  Consecutive saves
therefore rewrite only the leaves whose *content* changed -- optimizer
hyper-state, frozen embeddings and the V-cycle ``params_before_*`` stashes
dedup to ~zero bytes after the first save -- and garbage collection becomes
manifest-driven refcounting (an object is live iff some published step
manifest references its digest) instead of directory deletion.

The pool is crash-safe by construction:

* ``put`` writes through a unique temp file and ``os.replace``s into place --
  concurrent writers of the same digest converge on identical bytes, and a
  torn write never leaves a partial object under its final name;
* objects are written *before* the step manifest publishes, so a crash
  between write and publish strands only unreferenced (orphan) objects,
  which the next successful save's refcount GC reclaims;
* objects are immutable once written (content-addressed), so readers never
  race writers.

``repro.checkpoint.manager`` owns the orchestration (atomic step-dir publish,
barriers, the no-shared-FS gather protocol); this module is pure local I/O.
"""
from __future__ import annotations

import hashlib
import io
import json
import os
from typing import Any, Dict, Iterable, Iterator, List, Optional

import numpy as np

# per-step manifest file marking a v3 (content-addressed) step directory
OBJECTS_JSON = "objects.json"
V3_VERSION = 3


def as_host_leaf(x) -> np.ndarray:
    """C-contiguous host view of one leaf.  NOT ``np.ascontiguousarray``,
    which silently promotes 0-d scalars to 1-d and would corrupt their
    checkpointed shape."""
    arr = np.asarray(x)
    return arr if arr.flags.c_contiguous else np.ascontiguousarray(arr)


def leaf_digest(arr: np.ndarray) -> str:
    """Content digest of one host array: blake2b over (dtype, shape, bytes).

    ``str(dtype)`` (not ``dtype.str``) so ml_dtypes extension types hash
    distinctly -- ``bfloat16`` and any other 2-byte void type must not
    collide.
    """
    arr = as_host_leaf(arr)
    h = hashlib.blake2b(digest_size=20)
    h.update(str(arr.dtype).encode())
    h.update(repr(tuple(arr.shape)).encode())
    h.update(arr.tobytes())
    return h.hexdigest()


def _decode_npy(payload: bytes) -> np.ndarray:
    return np.load(io.BytesIO(payload), allow_pickle=False)


def payload_digest(payload: bytes, dtype: Optional[str] = None) -> str:
    """Digest of a serialized pool object (``dtype`` = the manifest's true
    dtype name, needed because npy stores ml_dtypes as raw void bytes).

    Used to verify network transfers before caching: a content-addressed
    store that trusts fetched bytes would make a corrupt transfer STICKY --
    every later save dedups against the poisoned object."""
    return leaf_digest(_restore_dtype(_decode_npy(payload), dtype))


def _restore_dtype(arr: np.ndarray, dtype_name: Optional[str]) -> np.ndarray:
    """Undo numpy's lossy round-trip of extension dtypes.

    ``np.save`` stores ml_dtypes leaves (e.g. bfloat16) as raw void bytes
    (``|V2``); the manifest carries the true dtype name, so view the bytes
    back.  Plain dtypes pass through untouched.
    """
    if dtype_name is None or str(arr.dtype) == dtype_name:
        return arr
    if arr.dtype.kind == "V":
        return arr.view(np.dtype(dtype_name))
    return arr


class ObjectStore:
    """One directory's content-addressed pool (``<root>/objects/<dd>/<digest>.npy``).

    Tracks ``bytes_written`` / ``objects_written`` / ``bytes_reused`` /
    ``objects_reused`` so dedup is *measurable*, not assumed
    (tests/test_ckpt_store.py asserts on these).
    """

    def __init__(self, root: str):
        self.root = root
        self.pool = os.path.join(root, "objects")
        self.bytes_written = 0
        self.objects_written = 0
        self.bytes_reused = 0
        self.objects_reused = 0

    def path(self, digest: str) -> str:
        return os.path.join(self.pool, digest[:2], digest + ".npy")

    def has(self, digest: str) -> bool:
        return os.path.exists(self.path(digest))

    def put(self, digest: str, arr: np.ndarray) -> int:
        """Write ``arr`` under ``digest`` unless already present.

        Returns bytes actually written (0 on a dedup hit).  The hit check
        runs BEFORE serialization, so unchanged leaves -- the store's whole
        reason to exist -- cost neither the npy encode nor the bytes copy.
        Atomic: a unique temp name + ``os.replace``, so concurrent
        same-digest writers (shared pools under coordinated saves) and
        crashes are both safe.
        """
        if self.has(digest):
            self.objects_reused += 1
            self.bytes_reused += int(arr.nbytes)
            return 0
        buf = io.BytesIO()
        np.save(buf, as_host_leaf(arr), allow_pickle=False)
        return self.put_bytes(digest, buf.getvalue())

    def put_bytes(self, digest: str, payload: bytes) -> int:
        p = self.path(digest)
        if os.path.exists(p):
            self.objects_reused += 1
            self.bytes_reused += len(payload)
            return 0
        os.makedirs(os.path.dirname(p), exist_ok=True)
        tmp = f"{p}.tmp.{os.getpid()}"
        with open(tmp, "wb") as f:
            f.write(payload)
        os.replace(tmp, p)
        self.bytes_written += len(payload)
        self.objects_written += 1
        return len(payload)

    def get_bytes(self, digest: str) -> bytes:
        with open(self.path(digest), "rb") as f:
            return f.read()

    def get(self, digest: str, dtype: Optional[str] = None) -> np.ndarray:
        return _restore_dtype(_decode_npy(self.get_bytes(digest)), dtype)

    def delete(self, digest: str) -> None:
        try:
            os.remove(self.path(digest))
        except OSError:
            pass

    def digests(self) -> Iterator[str]:
        if not os.path.isdir(self.pool):
            return
        for sub in os.listdir(self.pool):
            d = os.path.join(self.pool, sub)
            if not os.path.isdir(d):
                continue
            for fn in os.listdir(d):
                if fn.endswith(".npy"):
                    yield fn[:-4]

    def stats(self) -> Dict[str, int]:
        return {"bytes_written": self.bytes_written,
                "objects_written": self.objects_written,
                "bytes_reused": self.bytes_reused,
                "objects_reused": self.objects_reused}


# ---------------------------------------------------------------------------
# v3 step manifests


def whole_leaf_entry(digest: str, arr: np.ndarray) -> Dict[str, Any]:
    """Manifest record for an unsharded leaf: one chunk covering everything."""
    return {"shape": list(arr.shape), "dtype": str(arr.dtype),
            "chunks": [{"digest": digest, "start": [0] * arr.ndim,
                        "shape": list(arr.shape)}]}


def merge_tree_entries(parts: Iterable[Dict[str, Dict[str, Any]]]
                       ) -> Dict[str, Dict[str, Any]]:
    """Merge per-process partial manifests of ONE tree (coordinated saves):
    chunk lists concatenate, global shape/dtype must agree."""
    out: Dict[str, Dict[str, Any]] = {}
    for part in parts:
        for leaf, rec in part.items():
            got = out.get(leaf)
            if got is None:
                out[leaf] = {"shape": rec["shape"], "dtype": rec["dtype"],
                             "chunks": list(rec["chunks"])}
            else:
                if got["shape"] != rec["shape"] or got["dtype"] != rec["dtype"]:
                    raise ValueError(
                        f"coordinated save disagrees on leaf {leaf!r}: "
                        f"{got['shape']}/{got['dtype']} vs "
                        f"{rec['shape']}/{rec['dtype']}")
                got["chunks"].extend(rec["chunks"])
    return out


def write_step_manifest(step_dir: str, trees: Dict[str, Dict[str, Any]]) -> None:
    with open(os.path.join(step_dir, OBJECTS_JSON), "w") as f:
        json.dump({"version": V3_VERSION, "trees": trees}, f)


def read_step_manifest(step_dir: str) -> Optional[Dict[str, Dict[str, Any]]]:
    """The ``trees`` map of a v3 step dir, or None for v1/v2 layouts."""
    p = os.path.join(step_dir, OBJECTS_JSON)
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)["trees"]


def manifest_digests(trees: Dict[str, Dict[str, Any]]) -> Iterator[str]:
    for entries in trees.values():
        for rec in entries.values():
            for ch in rec["chunks"]:
                yield ch["digest"]


def np_dtype(name: Optional[str]) -> np.dtype:
    """np.dtype for a manifest dtype name, resolving ml_dtypes extension
    types (``"bfloat16"``) that ``np.dtype`` alone rejects."""
    if name is None:
        return np.dtype(np.float32)
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def chunk_intersects(start, shape, indices, global_shape) -> bool:
    """True when the chunk hyperrect ``[start, start+shape)`` overlaps ANY of
    the index tuples in ``indices`` (tuples of slices into ``global_shape``,
    as returned by ``Sharding.addressable_devices_indices_map``).

    The geometry behind sharding-aware restore: a rank only needs the chunks
    whose bytes land inside some slice its devices address.
    """
    for idx in indices:
        hit = True
        for sl, st, sz, dim in zip(idx, start, shape, global_shape):
            lo, hi, _ = sl.indices(dim)
            if hi <= st or lo >= st + sz:
                hit = False
                break
        if hit:  # 0-d leaves have empty index tuples and always intersect
            return True
    return False


def needed_digests(entries: Dict[str, Dict[str, Any]],
                   leaf_shardings: Dict[str, Any]) -> set:
    """Digests of the chunks whose slices this process's shardings address.

    ``leaf_shardings`` maps leaf path -> target jax Sharding (missing leaves
    are treated as fully needed).  This is what lets a no-shared-FS restore
    fetch only a rank's own slices instead of every manifest digest.
    """
    need: set = set()
    for leaf, rec in entries.items():
        sh = leaf_shardings.get(leaf)
        if sh is None:
            need.update(ch["digest"] for ch in rec["chunks"])
            continue
        shape = tuple(rec["shape"])
        try:
            idxs = list(sh.addressable_devices_indices_map(shape).values())
        except Exception:  # unknown sharding type: fall back to everything
            need.update(ch["digest"] for ch in rec["chunks"])
            continue
        for ch in rec["chunks"]:
            if chunk_intersects(ch["start"], ch["shape"], idxs, shape):
                need.add(ch["digest"])
    return need


def fetch_object(digest: str, pools: List[ObjectStore],
                 dtype: Optional[str] = None) -> np.ndarray:
    """Resolve ``digest`` through an ordered pool list (own dir first, then
    peer dirs / gathered caches)."""
    for pool in pools:
        if pool.has(digest):
            return pool.get(digest, dtype)
    raise FileNotFoundError(
        f"checkpoint object {digest} not found in any pool "
        f"({[p.pool for p in pools]}); the object pool and the step manifest "
        "referencing it have diverged")


def assemble_tree(entries: Dict[str, Dict[str, Any]],
                  pools: List[ObjectStore],
                  needed: Optional[set] = None) -> Dict[str, np.ndarray]:
    """Logical host arrays of one tree from its manifest entries + pools
    (inverse of chunking, whatever mesh/process count wrote the chunks).

    With ``needed`` (a digest set from :func:`needed_digests`), chunks
    outside the set are never fetched; their regions of the host array stay
    uninitialized.  Only valid when the caller lands the result through the
    same shardings the set was computed from -- ``make_array_from_callback``
    then reads exactly the addressable slices, which the set covers.
    """
    flat: Dict[str, np.ndarray] = {}
    for leaf, rec in entries.items():
        chunks = rec["chunks"]
        if needed is not None:
            chunks = [ch for ch in chunks if ch["digest"] in needed]
        if not chunks:  # no slice of this leaf is addressable here
            flat[leaf] = np.empty(tuple(rec["shape"]),
                                  dtype=np_dtype(rec.get("dtype")))
            continue
        first = fetch_object(chunks[0]["digest"], pools, rec.get("dtype"))
        if len(chunks) == 1 and list(first.shape) == list(rec["shape"]):
            flat[leaf] = first
            continue
        out = np.empty(tuple(rec["shape"]), dtype=first.dtype)
        for ch in chunks:
            data = fetch_object(ch["digest"], pools, rec.get("dtype"))
            sl = tuple(slice(st, st + sz)
                       for st, sz in zip(ch["start"], ch["shape"]))
            out[sl] = data
        flat[leaf] = out
    return flat
