"""Data pipeline: determinism, host-shard independence, learnability floor."""
import numpy as np

from repro.data import MarkovLM, chain_entropy, lm_batch, masked_lm_batch, vision_batch


def test_batches_deterministic():
    c = MarkovLM(128)
    b1 = lm_batch(c, seed=7, step=3, batch=4, seq=16)
    b2 = lm_batch(c, seed=7, step=3, batch=4, seq=16)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))


def test_shards_differ_but_are_reproducible():
    """Any host can regenerate any shard (straggler/elastic recovery)."""
    c = MarkovLM(128)
    a = lm_batch(c, 0, 0, 4, 16, shard=0)
    b = lm_batch(c, 0, 0, 4, 16, shard=1)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    b_again = lm_batch(c, 0, 0, 4, 16, shard=1)
    np.testing.assert_array_equal(np.asarray(b["tokens"]), np.asarray(b_again["tokens"]))


def test_make_batch_fn_wires_shard():
    """Launcher-level: make_batch_fn(shard=...) must thread the shard into the
    generator -- callers used to hardcode shard 0, giving every data-parallel
    host an identical batch stream."""
    from helpers import fast_tc, tiny_dense
    from repro.launch.train import make_batch_fn

    cfg, tc = tiny_dense(), fast_tc()
    b0 = make_batch_fn(cfg, tc, shard=0)(0)
    b1 = make_batch_fn(cfg, tc, shard=1)(0)
    b0_again = make_batch_fn(cfg, tc, shard=0)(0)
    assert not np.array_equal(np.asarray(b0["tokens"]), np.asarray(b1["tokens"]))
    np.testing.assert_array_equal(np.asarray(b0["tokens"]),
                                  np.asarray(b0_again["tokens"]))


def test_labels_are_next_tokens():
    c = MarkovLM(64)
    b = lm_batch(c, 0, 0, 2, 8)
    # labels[t] is a valid successor of tokens[t] in the chain
    toks = np.asarray(b["tokens"])
    labs = np.asarray(b["labels"])
    succ = np.asarray(c.succ)
    for i in range(toks.shape[0]):
        for t in range(toks.shape[1]):
            assert labs[i, t] in succ[toks[i, t]]


def test_chain_entropy_is_floor():
    h = chain_entropy(128)
    assert 0.3 < h < 1.4  # branch=4 chain: ~log(4) max


def test_mlm_masking():
    c = MarkovLM(128)
    b = masked_lm_batch(c, 0, 0, 4, 32, mask_id=127, mask_rate=0.25)
    toks = np.asarray(b["tokens"])
    labs = np.asarray(b["labels"])
    masked = labs >= 0
    assert 0.05 < masked.mean() < 0.5
    assert (toks[masked] == 127).all()


def test_vision_batch_shapes():
    b = vision_batch(0, 0, 4, n_patches=16, patch_dim=192, n_classes=10)
    assert b["patches"].shape == (4, 16, 192)
    assert b["labels"].shape == (4,)
    assert int(b["labels"].max()) < 10
