"""Per-kernel sweeps: shapes x dtypes x registry backends, assert_allclose vs
the ref.py oracles through the one dispatch entry point (interpret mode
executes the kernel body on CPU; TPU is the target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch, ops, ref

# every backend that resolves to itself on this host ("pallas" downgrades to
# the interpreter off-TPU -- skip the duplicate sweep)
RESOLVABLE = tuple(b for b in dispatch.BACKENDS
                   if dispatch.resolve_backend("coalesce_pair", b) == b)


@pytest.mark.parametrize("shape", [
    (1, 2, 128, 128, 64), (2, 4, 128, 128, 32), (1, 2, 256, 256, 64),
    (2, 2, 128, 256, 64),  # cross-length (non-causal only)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(shape, dtype, causal):
    B, H, S, T, D = shape
    if causal and S != T:
        pytest.skip("causal requires S == T in this kernel")
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), dtype)
    k = jax.random.normal(ks[1], (B, H, T, D), dtype)
    v = jax.random.normal(ks[2], (B, H, T, D), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    want = ref.naive_attention(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("block", [64, 128])
def test_flash_attention_block_invariance(block):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 256, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 256, 64), jnp.float32)
    a = ops.flash_attention(q, k, v, block_q=block, block_k=block)
    b = ref.naive_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("backend", RESOLVABLE)
@pytest.mark.parametrize("shape", [(8, 8), (512, 384), (64, 640), (768, 64)])
@pytest.mark.parametrize("axis", [0, 1])
@pytest.mark.parametrize("w0", [0.5, 1.0])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_coalesce_pair_sweep(backend, shape, axis, w0, dtype):
    w = jax.random.normal(jax.random.PRNGKey(2), shape, dtype)
    got = dispatch.dispatch("coalesce_pair", w, axis=axis, w0=w0, block=128,
                            backend=backend)
    want = ref.coalesce_pair_ref(w, axis=axis, w0=w0)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_coalesce_pair_matches_paper_operator():
    """Kernel == the actual projections used by core (F_out 'stack' variant)."""
    from repro.core import projections as proj

    n = 128
    w = jax.random.normal(jax.random.PRNGKey(3), (n, 96), jnp.float32)
    m = proj.width_mats(n, "stack")
    want = jnp.asarray(m.F_in, jnp.float32) @ w  # in-axis: F_in (weights 1.0)
    got = ops.coalesce_pair(w, axis=0, w0=1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    want2 = w.T @ jnp.asarray(m.F_out, jnp.float32)  # out-axis on dim1
    got2 = ops.coalesce_pair(w.T, axis=1, w0=0.5)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(want2), atol=1e-5)


@pytest.mark.parametrize("backend", RESOLVABLE)
@pytest.mark.parametrize("shape", [(33,), (1000, 37), (16, 16, 16)])
@pytest.mark.parametrize("alpha", [0.0, 0.25, 1.0])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_interp_axpy_sweep(backend, shape, alpha, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    a = jax.random.normal(ks[0], shape, dtype)
    b = jax.random.normal(ks[1], shape, dtype)
    got = dispatch.dispatch("interp_axpy", a, b, alpha, backend=backend)
    want = ref.interp_axpy_ref(a, b, alpha)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("backend", RESOLVABLE)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_backends_sweep(backend, causal):
    """Every registered flash_attention backend vs the naive oracle."""
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 128, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 128, 32), jnp.float32)
    got = dispatch.dispatch("flash_attention", q, k, v, causal=causal,
                            block_q=64, block_k=64, backend=backend)
    want = ref.naive_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("backend", RESOLVABLE)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_matches_dense_reassembly(backend, dtype):
    """Block-table decode == dense attention over the contiguously reassembled
    cache, for full pages, a partial tail page, and out-of-order page ids."""
    B, KH, G, D, N, P, M = 2, 2, 3, 32, 10, 8, 3
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, KH, G, D), dtype)
    k_pages = jax.random.normal(ks[1], (N, P, KH, D), dtype)
    v_pages = jax.random.normal(ks[2], (N, P, KH, D), dtype)
    bt = jnp.array([[7, 2, 9], [4, 1, 0]], jnp.int32)  # row 1: padded tail
    lengths = jnp.array([3 * P, P + 5, ], jnp.int32)
    got = dispatch.dispatch("paged_attention_decode", q, k_pages, v_pages,
                            bt, lengths, backend=backend)
    # dense oracle: gather each row's pages contiguously, run naive attention
    # with the padding masked by truncating to length
    outs = []
    for b in range(B):
        L = int(lengths[b])
        k = k_pages[bt[b]].reshape(M * P, KH, D)[:L]
        v = v_pages[bt[b]].reshape(M * P, KH, D)[:L]
        # [1, KH, G, D] x [1, KH, L, D] via the naive oracle's B,H,S,T layout
        o = ref.naive_attention(q[b][None].reshape(1, KH * G, 1, D).astype(jnp.float32),
                                jnp.repeat(k.transpose(1, 0, 2), G, axis=0)[None].astype(jnp.float32),
                                jnp.repeat(v.transpose(1, 0, 2), G, axis=0)[None].astype(jnp.float32),
                                causal=False)
        outs.append(o.reshape(KH, G, D))
    want = jnp.stack(outs)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("backend", RESOLVABLE)
def test_paged_attention_table_padding_ignored(backend):
    """Padding entries (null page 0) past ceil(len/P) must not affect the
    output: growing the table with null pages is a no-op."""
    B, KH, G, D, N, P = 1, 2, 2, 16, 6, 4
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    q = jax.random.normal(ks[0], (B, KH, G, D), jnp.float32)
    k_pages = jax.random.normal(ks[1], (N, P, KH, D), jnp.float32)
    v_pages = jax.random.normal(ks[2], (N, P, KH, D), jnp.float32)
    lengths = jnp.array([2 * P - 1], jnp.int32)
    narrow = dispatch.dispatch("paged_attention_decode", q, k_pages, v_pages,
                               jnp.array([[3, 5]], jnp.int32), lengths, backend=backend)
    wide = dispatch.dispatch("paged_attention_decode", q, k_pages, v_pages,
                             jnp.array([[3, 5, 0, 0]], jnp.int32), lengths, backend=backend)
    np.testing.assert_allclose(np.asarray(narrow), np.asarray(wide), atol=1e-6)


def test_paged_attention_ops_wrapper():
    """The jit'd public wrapper resolves interpret mode off-TPU and agrees
    with the gather reference."""
    q, kp, vp = (jax.random.normal(k, s, jnp.float32) for k, s in zip(
        jax.random.split(jax.random.PRNGKey(9), 3),
        [(2, 2, 2, 16), (8, 4, 2, 16), (8, 4, 2, 16)]))
    bt = jnp.array([[1, 2], [3, 0]], jnp.int32)
    lengths = jnp.array([7, 4], jnp.int32)
    got = ops.paged_attention_decode(q, kp, vp, bt, lengths)
    want = ref.paged_attention_ref(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_flash_attention_vjp_bf16():
    """The differentiable kernel wrapper holds bf16 inputs to bf16 tolerance."""
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 32), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 2, 128, 32), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 2, 128, 32), jnp.bfloat16)
    got = ops.flash_attention_vjp(q, k, v, causal=True, block_q=64, block_k=64)
    want = ref.naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=2e-2, rtol=2e-2)
    grads = jax.grad(lambda q, k, v: jnp.sum(ops.flash_attention_vjp(
        q, k, v, causal=True, block_q=64, block_k=64).astype(jnp.float32)),
        argnums=(0, 1, 2))(q, k, v)
    assert all(g.dtype == jnp.bfloat16 for g in grads)
