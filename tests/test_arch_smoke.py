"""Per-assigned-architecture smoke tests: instantiate the REDUCED same-family
config, run one forward/train step on CPU, assert output shapes + no NaNs;
plus one decode step against a small cache (the serve path of the decode
cells).  FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import fast_tc
from repro.configs import ASSIGNED, get_config
from repro.models import lm as lm_lib
from repro.models.api import build_model, init_train_state, make_serve_step, make_train_step
from repro.param import is_spec


def smoke_batch(cfg, B=2, S=16):
    b = {"tokens": jnp.ones((B, S), jnp.int32) * 3,
         "labels": jnp.ones((B, S), jnp.int32) * 5}
    if cfg.family == "vlm":
        b["img_embeds"] = 0.1 * jnp.ones((B, cfg.n_image_tokens, cfg.vision_dim or cfg.d_model),
                                         jnp.float32)
    if cfg.family == "audio":
        b["enc_frames"] = 0.1 * jnp.ones((B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.name == get_config(arch).name  # same family/identity
    tc = fast_tc()
    model = build_model(cfg)
    params, opt = init_train_state(model, tc, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, tc))
    batch = smoke_batch(cfg)
    params, opt, metrics = step(params, opt, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch}: non-finite loss"
    logits = model.forward_logits(params, batch)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), f"{arch}: NaN logits"


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, T = 2, 24
    cs = lm_lib.cache_specs(cfg, B, T)
    caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype or cfg.compute_dtype),
                          cs, is_leaf=is_spec)
    serve = jax.jit(make_serve_step(model))
    logits, new_caches = serve(params, caches, jnp.ones((B, 1), jnp.int32),
                               jnp.full((B,), 4, jnp.int32))
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), f"{arch}: NaN decode"
    assert jax.tree.structure(new_caches) == jax.tree.structure(caches)


def test_full_configs_match_assignment():
    """Pin the exact assigned hyperparameters (source-of-truth table)."""
    want = {
        "deepseek-v3-671b": dict(n_layers=61, d_model=7168, n_heads=128,
                                 vocab_size=129280, n_experts=256, moe_top_k=8,
                                 moe_d_ff=2048),
        "phi3.5-moe-42b-a6.6b": dict(n_layers=32, d_model=4096, n_heads=32,
                                     n_kv_heads=8, vocab_size=32064, n_experts=16,
                                     moe_top_k=2),
        "tinyllama-1.1b": dict(n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4,
                               d_ff=5632, vocab_size=32000),
        "qwen3-4b": dict(n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8,
                         d_ff=9728, vocab_size=151936, qk_norm=True),
        "qwen3-14b": dict(n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
                          d_ff=17408, vocab_size=151936, qk_norm=True),
        "command-r-35b": dict(n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8,
                              d_ff=22528, vocab_size=256000, use_bias=False),
        "jamba-1.5-large-398b": dict(n_layers=72, d_model=8192, n_heads=64,
                                     n_kv_heads=8, vocab_size=65536, n_experts=16,
                                     moe_top_k=2),
        "xlstm-125m": dict(n_layers=12, d_model=768, n_heads=4, d_ff=0,
                           vocab_size=50304),
        "llama-3.2-vision-11b": dict(n_layers=40, d_model=4096, n_heads=32,
                                     n_kv_heads=8, d_ff=14336, vocab_size=128256),
        "whisper-large-v3": dict(n_layers=32, d_model=1280, n_heads=20,
                                 n_kv_heads=20, d_ff=5120, vocab_size=51866,
                                 n_encoder_layers=32),
    }
    for arch, fields in want.items():
        cfg = get_config(arch)
        for k, v in fields.items():
            got = getattr(cfg, k)
            assert got == v, f"{arch}.{k}: {got} != {v}"


def test_param_counts_plausible():
    """Total parameter counts must land near the advertised sizes."""
    from repro.core.flops import total_params

    expect = {"deepseek-v3-671b": (600e9, 740e9), "phi3.5-moe-42b-a6.6b": (38e9, 46e9),
              "tinyllama-1.1b": (0.9e9, 1.3e9), "qwen3-4b": (3e9, 5e9),
              "qwen3-14b": (12e9, 17e9), "command-r-35b": (30e9, 40e9),
              "jamba-1.5-large-398b": (350e9, 440e9), "xlstm-125m": (0.08e9, 0.2e9),
              "llama-3.2-vision-11b": (8e9, 13e9), "whisper-large-v3": (1.2e9, 2.0e9)}
    for arch, (lo, hi) in expect.items():
        model = build_model(get_config(arch))
        n = total_params(model.specs())
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params outside [{lo/1e9},{hi/1e9}]B"
