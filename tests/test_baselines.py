"""The paper's comparison baselines (core/baselines.py): every method runs at
proxy scale and -- the part savings computations hinge on -- charges FLOPs on
the SAME accounting basis as the V-cycle (small-model training included, LiGO
operator fits and KI teacher forwards charged explicitly)."""
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import fast_tc, tiny_dense
from repro.config import MultiLevelConfig
from repro.core import baselines
from repro.core import flops as flops_lib
from repro.core import operators as ops
from repro.core.vcycle import VCycleRunner
from repro.models.api import build_model


def _arena():
    cfg = tiny_dense(d_model=32, d_ff=64, vocab_size=128,
                     compute_dtype=jnp.float32)
    tc = fast_tc(steps=4, batch_size=2, seq_len=16, log_every=1)
    ml = MultiLevelConfig(n_levels=2, alpha=0.25, e_a_frac=0.25,
                          e_small_frac=0.5)
    from repro.launch.train import make_batch_fn

    return cfg, tc, ml, make_batch_fn(cfg, tc)


def _fps(cfg, tc):
    return flops_lib.train_step_flops(cfg, build_model(cfg).specs(),
                                      tc.batch_size, tc.seq_len)


def test_registry_is_complete_and_callable():
    assert set(baselines.BASELINES) == {
        "stackbert", "bert2bert", "ligo", "network_expansion", "ki"}
    for fn in baselines.BASELINES.values():
        assert callable(fn)


def test_bert2bert_flops_accounting_per_phase():
    """Width-only grow: small-phase increments charge the SMALL model's step
    cost, final-phase increments the FULL model's -- and the small phase is
    included in the total (paper §4.1 fairness)."""
    cfg, tc, ml, bf = _arena()
    hist = baselines.run_bert2bert(cfg, ml, tc, bf, small_steps=3, final_steps=3)
    small_cfg = ops.coalesce_config(cfg, ml, width=True, depth=False)
    small_fps, big_fps = _fps(small_cfg, tc), _fps(cfg, tc)
    assert 0 < small_fps < big_fps
    assert np.all(np.diff(hist.flops) > 0)  # cumulative axis is monotone
    # log_every=1: the first entry lands after exactly one small step...
    assert hist.flops[0] == pytest.approx(small_fps, rel=1e-9)
    # ...the small phase is levelled 1, the final phase levelled 0
    assert hist.level[0] == 1 and hist.level[-1] == 0
    # per-step increments match the per-phase step cost exactly
    diffs = np.diff(hist.flops)
    assert diffs[0] == pytest.approx(small_fps, rel=1e-9)
    assert diffs[-1] == pytest.approx(big_fps, rel=1e-9)
    # total = 3 small + 3 big steps, nothing dropped, nothing double-charged
    assert hist.flops[-1] == pytest.approx(3 * small_fps + 3 * big_fps,
                                           rel=1e-9)


def test_stackbert_depth_only_costs_half_model():
    cfg, tc, ml, bf = _arena()
    hist = baselines.run_stackbert(cfg, ml, tc, bf, small_steps=2, final_steps=2)
    small_cfg = ops.coalesce_config(cfg, ml, width=False, depth=True)
    small_fps = _fps(small_cfg, tc)
    assert hist.flops[0] == pytest.approx(small_fps, rel=1e-9)
    assert hist.flops[-1] == pytest.approx(2 * small_fps + 2 * _fps(cfg, tc),
                                           rel=1e-9)


def test_network_expansion_charges_ema_phase():
    cfg, tc, ml, bf = _arena()
    hist = baselines.run_network_expansion(cfg, ml, tc, bf, small_steps=2,
                                           final_steps=2)
    small_fps = _fps(ops.coalesce_config(cfg, ml), tc)
    assert np.all(np.diff(hist.flops) > 0)
    # the EMA-maintaining small phase is charged like plain small training
    assert hist.flops[0] == pytest.approx(small_fps, rel=1e-9)
    assert hist.flops[-1] == pytest.approx(2 * small_fps + 2 * _fps(cfg, tc),
                                           rel=1e-9)


def test_ligo_charges_operator_fit_at_full_model_cost():
    cfg, tc, ml, bf = _arena()
    hist = baselines.run_ligo(cfg, ml, tc, bf, small_steps=2, final_steps=2,
                              fit_steps=2)
    small_fps = _fps(ops.coalesce_config(cfg, ml), tc)
    big_fps = _fps(cfg, tc)
    # 2 small steps + 2 operator-fit steps (charged at the mapped FULL
    # model's step cost) + 2 full steps
    assert hist.flops[-1] == pytest.approx(2 * small_fps + 4 * big_fps,
                                           rel=1e-9)
    assert np.all(np.diff(hist.flops) > 0)


def test_ki_charges_teacher_forward_every_step():
    cfg, tc, ml, bf = _arena()
    hist = baselines.run_ki(cfg, ml, tc, bf, small_steps=2, final_steps=2)
    small_cfg = ops.coalesce_config(cfg, ml)
    small = build_model(small_cfg)
    model = build_model(cfg)
    kd_fps = (_fps(cfg, tc)
              + flops_lib.forward_flops(cfg, model.specs(), tc.batch_size, tc.seq_len)
              + flops_lib.forward_flops(small_cfg, small.specs(), tc.batch_size,
                                        tc.seq_len))
    assert kd_fps > _fps(cfg, tc)  # distillation is NOT free
    diffs = np.diff(hist.flops)
    # final-phase increments carry the full student+teacher cost
    assert diffs[-1] == pytest.approx(kd_fps, rel=1e-9)


def test_vcycle_and_baselines_share_one_accounting_basis():
    """The savings tables divide baseline FLOPs by V-cycle FLOPs; both sides
    must price a step of the same (level) model identically, and the V-cycle
    total must equal its schedule priced step by step."""
    cfg, tc, ml, bf = _arena()
    runner = VCycleRunner(cfg, ml, tc, bf, seed=0)
    # level-1 pricing == the baselines' small-model pricing (same coalesce)
    assert flops_lib.train_step_flops(
        runner.cfgs[1], runner.specs[1], tc.batch_size, tc.seq_len) == \
        pytest.approx(_fps(ops.coalesce_config(cfg, ml), tc), rel=1e-12)
    out = runner.run()
    expect = sum(
        p.steps * flops_lib.train_step_flops(
            runner.cfgs[p.level], runner.specs[p.level], tc.batch_size,
            tc.seq_len)
        for p in runner.plan)
    assert out.total_flops == pytest.approx(expect, rel=1e-9)
    assert hist_monotone(out.history)


def hist_monotone(h):
    return bool(np.all(np.diff(h.flops) > 0))


def test_flops_accounting_basis_is_pinned():
    """The energy layer (ISSUE 9) is strictly additive: the FLOPs numbers the
    existing dense arms produce are frozen here to literal values so any
    accounting change (not just a relative drift) trips loudly."""
    from helpers import tiny_moe

    cfg = tiny_dense(d_model=32, d_ff=64, vocab_size=128)
    model = build_model(cfg)
    n_active = flops_lib.active_matmul_params(cfg, model.specs())
    # embed 128*32 + 3 layers x (qkvo 32*96 + gated mlp 3*32*64 + norm/qk
    # scale leaves 80) -- 2-D-or-higher leaves all count, 1-D norms don't
    assert n_active == 31984.0
    # MoE: expert weights charge at the top_k/n_experts active fraction
    mcfg = tiny_moe(d_model=32, d_ff=64, vocab_size=128)
    mmodel = build_model(mcfg)
    full = flops_lib.total_params(mmodel.specs())
    act = flops_lib.active_matmul_params(mcfg, mmodel.specs())
    assert act < full  # 4 experts top-2 => expert leaves charged at 1/2
    dense_fps = _fps(cfg, fast_tc(steps=1, batch_size=2, seq_len=16))
    # 3x backward convention x (matmuls on 32 tokens + causal attention term)
    attn = 32 * 3 * 2.0 * 4 * (8 + 8) * (16 / 2)
    assert dense_fps == pytest.approx(3.0 * (2.0 * n_active * 32 + attn),
                                      rel=1e-9)
    assert dense_fps == 6435840.0
