"""The trip-count-aware HLO cost parser vs known ground truths (and vs the
XLA limitation that motivated it)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyze_text


def test_single_matmul_exact():
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    t = jax.jit(lambda w: w @ w).lower(w).compile().as_text()
    a = analyze_text(t)
    assert a["flops"] == pytest.approx(2 * 256 ** 3, rel=0.01)


def test_scan_multiplies_trip_count():
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def scanned(w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, w, None, length=7)
        return out

    compiled = jax.jit(scanned).lower(w).compile()
    a = analyze_text(compiled.as_text())
    assert a["flops"] == pytest.approx(7 * 2 * 128 ** 3, rel=0.01)
    # ...and document why this module exists: XLA counts the body once
    xla = compiled.cost_analysis()
    if isinstance(xla, (list, tuple)):  # jax<=0.4.x returns [dict]
        xla = xla[0]
    assert xla["flops"] < a["flops"] / 2


def test_nested_scan():
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def nested(w):
        def inner(c, _):
            return c @ w, None

        def outer(c, _):
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None

        out, _ = jax.lax.scan(outer, w, None, length=5)
        return out

    t = jax.jit(nested).lower(w).compile().as_text()
    a = analyze_text(t)
    assert a["flops"] == pytest.approx(15 * 2 * 64 ** 3, rel=0.01)


def test_dus_bytes_not_full_buffer():
    """In-place cache updates must count the slice, not the whole buffer."""
    big = jax.ShapeDtypeStruct((4096, 512), jnp.float32)
    upd = jax.ShapeDtypeStruct((1, 512), jnp.float32)

    def f(b, u):
        def body(c, i):
            return jax.lax.dynamic_update_slice(c, u, (i, 0)), None
        out, _ = jax.lax.scan(body, b, jnp.arange(100))
        return out

    t = jax.jit(f, donate_argnums=(0,)).lower(big, upd).compile().as_text()
    a = analyze_text(t)
    full = 100 * 4096 * 512 * 4
    assert a["bytes"] < full / 10  # slice-sized, not buffer-sized


def test_grad_flops_roughly_triple():
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 128), jnp.float32)

    def loss(w, x):
        return jnp.sum((x @ w) ** 2)

    fwd = analyze_text(jax.jit(loss).lower(w, x).compile().as_text())["flops"]
    bwd = analyze_text(jax.jit(jax.grad(loss)).lower(w, x).compile().as_text())["flops"]
    assert 1.8 * fwd < bwd < 4.0 * fwd
