"""Fault tolerance: atomic save/restore, async, keep-last GC, torn-write
recovery, elastic re-shard, train-resume continuity."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import batch_for, fast_tc, tiny_dense
from repro.checkpoint import CheckpointManager
from repro.models.api import build_model, init_train_state, make_train_step


def make_state():
    return {"params": {"a": jnp.arange(6.0).reshape(2, 3), "n": {"b": jnp.ones((4,))}},
            "opt": {"count": jnp.zeros((), jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    st = make_state()
    cm.save(5, st, meta={"step": 5, "level": 1})
    like = jax.tree.map(jnp.zeros_like, st)
    out, meta = cm.restore(like)
    assert meta["level"] == 1
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(st)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_keep_last(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep_last=2)
    st = make_state()
    for s in (1, 2, 3, 4):
        cm.save(s, st, meta={"step": s}, blocking=False)
    cm.wait()
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000003", "step_00000004"]
    assert cm.latest()["step"] == 4


def test_leaf_names_with_literal_double_underscore(tmp_path):
    """v2 layout: leaf paths are percent-encoded, so a literal ``__`` in a
    leaf name no longer collides with the path separator (the legacy scheme
    mapped both ``w/gate`` and ``w__gate`` to the same file)."""
    cm = CheckpointManager(str(tmp_path))
    st = {"params": {"w__gate": jnp.arange(4.0),
                     "w": {"gate": jnp.full((4,), 7.0)}}}
    cm.save(1, st, meta={"step": 1})
    out, _ = cm.restore(jax.tree.map(jnp.zeros_like, st))
    np.testing.assert_array_equal(np.asarray(out["params"]["w__gate"]),
                                  np.arange(4.0))
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]["gate"]),
                                  np.full((4,), 7.0))


def test_restore_legacy_leaf_layout(tmp_path):
    """Pre-v2 checkpoints ('/' stored as '__', no leafenc marker) stay
    readable."""
    d = tmp_path / "step_00000001" / "params"
    os.makedirs(d)
    np.save(str(d / "a__b.npy"), np.arange(3.0))
    with open(tmp_path / "step_00000001" / "meta.json", "w") as f:
        json.dump({"step": 1}, f)
    with open(tmp_path / "manifest.json", "w") as f:
        json.dump({"dir": "step_00000001", "step": 1, "meta": {"step": 1}}, f)
    cm = CheckpointManager(str(tmp_path))
    out, meta = cm.restore({"params": {"a": {"b": jnp.zeros(3)}}})
    assert meta["step"] == 1
    np.testing.assert_array_equal(np.asarray(out["params"]["a"]["b"]),
                                  np.arange(3.0))


def test_gc_never_removes_manifest_dir(tmp_path):
    """Regression: a resumed run can publish a smaller step number than stale
    dirs from a longer previous schedule.  keep-last GC must never collect the
    directory the manifest references -- and must reclaim the stale
    higher-numbered dirs rather than shield them by name."""
    import time

    cm = CheckpointManager(str(tmp_path), keep_last=1)
    st = make_state()
    cm.save(5, st, meta={"step": 5})
    time.sleep(0.02)  # distinct publish mtimes
    cm.save(3, st, meta={"step": 3})  # lexicographically older than step_5
    m = cm.latest()
    assert m["step"] == 3
    assert os.path.isdir(os.path.join(str(tmp_path), m["dir"]))
    assert not os.path.isdir(os.path.join(str(tmp_path), "step_00000005"))
    out, meta = cm.restore(jax.tree.map(jnp.zeros_like, st))
    assert meta["step"] == 3


def test_torn_manifest_recovery(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    st = make_state()
    cm.save(1, st, meta={"step": 1})
    cm.save(2, st, meta={"step": 2})
    # simulate crash: manifest points at a deleted dir
    with open(cm.manifest_path, "w") as f:
        json.dump({"dir": "step_00000099", "step": 99, "meta": {}}, f)
    m = cm.latest()
    assert m["step"] == 2  # falls back to newest intact step dir


def test_preemption_resume_continuity(tmp_path):
    """Kill training mid-flight; resume must continue bit-identically."""
    cfg = tiny_dense(compute_dtype=jnp.float32)
    tc = fast_tc(steps=6)
    model = build_model(cfg)
    batch = batch_for(cfg)
    step = jax.jit(make_train_step(model, tc))

    params, opt = init_train_state(model, tc, jax.random.PRNGKey(0))
    # uninterrupted run
    p_ref, o_ref = params, opt
    for _ in range(4):
        p_ref, o_ref, _ = step(p_ref, o_ref, batch)

    # interrupted run: 2 steps, checkpoint, "crash", restore, 2 more steps
    cm = CheckpointManager(str(tmp_path))
    p, o = params, opt
    for _ in range(2):
        p, o, _ = step(p, o, batch)
    cm.save(2, {"params": p, "opt": o}, meta={"step": 2})
    del p, o  # crash
    like = {"params": jax.tree.map(jnp.zeros_like, params),
            "opt": jax.tree.map(jnp.zeros_like, opt)}
    restored, meta = cm.restore(like)
    p, o = restored["params"], restored["opt"]
    assert meta["step"] == 2
    for _ in range(2):
        p, o, _ = step(p, o, batch)
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(p_ref)):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   atol=1e-6)


def test_elastic_restore_onto_mesh(tmp_path):
    """Checkpoints hold logical arrays; restore re-shards onto a target mesh
    (different topology than at save time)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    cm = CheckpointManager(str(tmp_path))
    st = {"params": {"w": jnp.arange(16.0).reshape(4, 4)}}
    cm.save(1, st, meta={"step": 1})
    mesh = jax.make_mesh((1, 1), ("data", "model"))  # 1-device container
    sh = {"params": {"w": NamedSharding(mesh, P("data", None))}}
    out, _ = cm.restore(jax.tree.map(jnp.zeros_like, st), shardings=sh)
    assert out["params"]["w"].sharding == sh["params"]["w"]
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(st["params"]["w"]))
