"""End-to-end behaviour tests for the paper's system: the V-cycle actually
saves compute on a learnable task; the paper's key ablation directions hold
(Appendix D/F/G at proxy scale); serving works; the launcher resumes (plain
and mid-V-cycle, including after SIGKILL); the watchdog sees every step."""
import signal
import subprocess
import sys
import os
import time

import jax
import numpy as np
import pytest

from helpers import fast_tc, tiny_dense
from repro.config import MultiLevelConfig
from repro.core.vcycle import run_scratch, run_vcycle, saving_vs_baseline
from repro.data import MarkovLM, lm_batch


@pytest.fixture(scope="module")
def arena():
    cfg = tiny_dense(d_model=48, d_ff=96, vocab_size=128,
                     stages=tiny_dense().stages)
    tc = fast_tc(steps=60, batch_size=8, seq_len=24, log_every=2, peak_lr=3e-3)
    chain = MarkovLM(128)
    bf = lambda step: lm_batch(chain, 0, step, tc.batch_size, tc.seq_len)
    _, base = run_scratch(cfg, tc, bf, seed=0)
    return cfg, tc, bf, base


@pytest.mark.slow
def test_vcycle_saves_flops(arena):
    """The headline claim at proxy scale: the V-cycle reaches the baseline's
    final quality with fewer training FLOPs."""
    cfg, tc, bf, base = arena
    ml = MultiLevelConfig(n_levels=2, alpha=0.25, e_a_frac=0.05, e_small_frac=0.5)
    target = float(base.smoothed(5)[1][-1])
    out = run_vcycle(cfg, ml, tc, bf, seed=0, target_loss=target)
    s = saving_vs_baseline(base, out.history)
    assert np.isfinite(s["flops_saving"])
    assert s["flops_saving"] > 0.0, f"no saving: {s}"


@pytest.mark.slow
def test_alpha_one_locks_symmetric_neurons(arena):
    """The MECHANISM behind paper Table 5(C)/App. G: with alpha=1.0 (pure
    de-coalescing, no Interpolation) mirrored neuron pairs receive identical
    gradients forever, so the model trains with only half its effective
    width; alpha<1 breaks the tie immediately.

    (The end-to-end FLOPs-saving ordering of alpha=1.0 vs 0.25 is
    scale-dependent and does not reliably reproduce on a 48-dim/60-step
    proxy -- the capacity ceiling only binds for larger models; the
    quantitative ablation lives in benchmarks/table5.  The gradient-tie
    mechanism is exact at any scale and is what we pin here.)"""
    import jax.numpy as jnp

    from repro.core import operators as ops
    from repro.models.api import build_model, init_train_state, make_train_step

    cfg, tc, bf, base = arena
    cfg = cfg.replace(compute_dtype=jnp.float32, qk_norm=False, tie_embeddings=False)
    ml = MultiLevelConfig(n_levels=2)
    small_cfg = ops.coalesce_config(cfg, ml, width=True, depth=False)
    model, small = build_model(cfg), build_model(small_cfg)
    p_small = small.init(jax.random.PRNGKey(7))
    de = ops.make_decoalesce_fn(model.specs(), cfg, ml, width=True, depth=False)(p_small)

    def train_n(params, n=4):
        _, opt = init_train_state(model, tc, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(model, tc))
        for i in range(n):
            params, opt, _ = step(params, opt, bf(i))
        return params

    def pair_gap(params):
        w = np.asarray(params["stages"]["stage_0"]["b0"]["ffn"]["w_up"], np.float32)
        F = w.shape[-1]
        return float(np.abs(w[..., : F // 2] - w[..., F // 2:]).max())

    # alpha = 1.0: the de-coalesced model trains but mirrored pairs stay tied
    locked = train_n(de)
    assert pair_gap(locked) < 1e-5, "mirrored neurons must stay identical"
    # alpha = 0.25: interpolation with an independently-initialized large model
    p_large = model.init(jax.random.PRNGKey(8))
    mixed = ops.make_interpolate_fn(0.25)(p_large, de)
    broken = train_n(mixed)
    assert pair_gap(broken) > 1e-3, "interpolation must break the symmetry"


def test_serve_continuous_batching():
    from repro.launch.serve import Request, Server
    from repro.configs import get_config

    cfg = get_config("tinyllama-1.1b", smoke=True)
    srv = Server(cfg, batch=2, max_seq=48)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, 100, size=5), max_new=4)
            for i in range(4)]
    done = srv.run(reqs)
    assert len(done) == 4
    assert all(len(r.out) == 4 for r in done)


@pytest.mark.slow
def test_train_launcher_resumes(tmp_path):
    env = dict(os.environ, PYTHONPATH="src")
    root = os.path.join(os.path.dirname(__file__), "..")
    args = [sys.executable, "-m", "repro.launch.train", "--arch", "tinyllama-1.1b",
            "--smoke", "--steps", "8", "--batch", "2", "--seq", "16",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "4"]
    r1 = subprocess.run(args, capture_output=True, text=True, env=env, cwd=root, timeout=300)
    assert r1.returncode == 0, r1.stderr[-1500:]
    # second invocation resumes from the final checkpoint
    r2 = subprocess.run(args + ["--steps", "10"], capture_output=True, text=True,
                        env=env, cwd=root, timeout=300)
    assert r2.returncode == 0, r2.stderr[-1500:]
    assert "resumed from step" in r2.stdout


def test_watchdog_observes_slow_step():
    from repro.launch.train import Watchdog

    wd = Watchdog(factor=3.0)
    assert not any(wd.observe(0.01) for _ in range(10))
    assert wd.observe(0.1) is True  # 10x the median -> flagged
    assert wd.flagged == 1


def test_watchdog_median_excludes_current_sample():
    """Regression: the baseline median must be computed over PRIOR samples
    only.  With a bimodal window (25x10ms + 25x50ms, prior median 30ms) a
    100ms spike is > 3x the baseline -- but appending it first shifted the
    window median to 50ms, and the straggler masked itself."""
    from repro.launch.train import Watchdog

    wd = Watchdog(factor=3.0)
    for _ in range(25):
        wd.observe(0.01)
    for _ in range(25):
        wd.observe(0.05)
    assert wd.observe(0.1) is True


def test_vcycle_driver_heartbeats_every_step():
    """The module docstring promises the straggler watchdog on BOTH drivers;
    the V-cycle driver hangs it on the runner's per-step hook.  Every step is
    observed except each segment's first (its dt may carry the level's
    one-time jit compile, which is not a straggler signal)."""
    import repro.launch.train as T
    from repro.core.vcycle import segments

    seen = []
    orig = T.Watchdog.observe
    T.Watchdog.observe = lambda self, dt: (seen.append(dt), orig(self, dt))[1]
    try:
        cfg = tiny_dense(d_model=32, d_ff=64, vocab_size=128)
        tc = fast_tc(steps=6, log_every=10)
        ml = MultiLevelConfig(n_levels=2)
        T.train_vcycle_ckpt(cfg, ml, tc, ckpt=None, ckpt_every=0, verbose=False)
    finally:
        T.Watchdog.observe = orig
    plan = segments(cfg, ml, tc)
    assert len(seen) == sum(p.steps for p in plan) - len(plan)


def test_train_plain_heartbeats_every_step(monkeypatch):
    """Regression: with log_every > 1 the watchdog used to see only every
    log_every-th step, hiding most stragglers."""
    import repro.launch.train as T

    seen = []
    orig = T.Watchdog.observe

    def spying(self, dt):
        seen.append(dt)
        return orig(self, dt)

    monkeypatch.setattr(T.Watchdog, "observe", spying)
    cfg = tiny_dense(d_model=32, d_ff=64, vocab_size=128)
    tc = fast_tc(steps=5, log_every=10)
    T.train_plain(cfg, tc, ckpt=None, ckpt_every=0, verbose=False)
    assert len(seen) == 5


@pytest.mark.slow
def test_vcycle_launcher_sigterm_checkpoints(tmp_path):
    """Preemption awareness: SIGTERM must trigger ONE final blocking
    checkpoint and a clean exit 0, even though the --ckpt-every cadence
    (1000) would never fire; the restart resumes from that save."""
    env = dict(os.environ, PYTHONPATH="src")
    root = os.path.join(os.path.dirname(__file__), "..")
    args = [sys.executable, "-m", "repro.launch.train", "--arch", "tinyllama-1.1b",
            "--smoke", "--vcycle", "--levels", "2", "--steps", "40",
            "--batch", "2", "--seq", "16",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "1000"]
    log = os.path.join(str(tmp_path), "run.log")
    with open(log, "w") as lf:
        p = subprocess.Popen(args, env=env, cwd=root, stdout=lf,
                             stderr=subprocess.STDOUT)
        deadline = time.time() + 240
        stepping = False
        while time.time() < deadline and p.poll() is None and not stepping:
            with open(log) as f:
                stepping = "coalescing" in f.read()  # past the first segment
            time.sleep(0.05)
        assert stepping, "run never reached the first transition"
        p.send_signal(signal.SIGTERM)
        assert p.wait(timeout=240) == 0, "SIGTERM exit was not clean"
    out = open(log).read()
    assert "[preempt] SIGTERM: blocking V-cycle checkpoint" in out, out[-1500:]
    manifest = os.path.join(str(tmp_path), "manifest.json")
    assert os.path.exists(manifest), "preemption save never published"
    r = subprocess.run(args, capture_output=True, text=True, env=env, cwd=root,
                       timeout=300)
    assert r.returncode == 0, r.stderr[-1500:]
    assert "resumed at phase=" in r.stdout, r.stdout[-1500:]


def _load_final_params(ckpt_dir: str):
    import json

    from repro.checkpoint.manager import _read_leaves

    m = json.load(open(os.path.join(ckpt_dir, "manifest.json")))
    assert m["meta"].get("phase") == "done", m["meta"]
    # layout-agnostic: v3 manifests resolve through the object pool, v2 dirs
    # through whole-leaf files
    return _read_leaves(os.path.join(ckpt_dir, m["dir"], "params"))


@pytest.mark.slow
def test_vcycle_launcher_mesh_kill_resume_cross_mesh(tmp_path):
    """The acceptance drill: a --mesh 1x2 V-cycle run SIGKILLed
    mid-upward-sweep resumes under --mesh 2x1 and reproduces the
    uninterrupted run's final params (the launcher forces CPU host devices
    itself, so no XLA_FLAGS in the parent)."""
    import json

    env = dict(os.environ, PYTHONPATH="src")
    root = os.path.join(os.path.dirname(__file__), "..")
    common = [sys.executable, "-m", "repro.launch.train", "--arch",
              "tinyllama-1.1b", "--smoke", "--vcycle", "--levels", "2",
              "--steps", "20", "--batch", "4", "--seq", "16", "--f32",
              "--ckpt-every", "2"]
    ref_dir, ck_dir = str(tmp_path / "ref"), str(tmp_path / "ck")

    r = subprocess.run(common + ["--mesh", "1x2", "--ckpt-dir", ref_dir],
                       capture_output=True, text=True, env=env, cwd=root,
                       timeout=480)
    assert r.returncode == 0, r.stderr[-1500:]

    p = subprocess.Popen(common + ["--mesh", "1x2", "--ckpt-dir", ck_dir],
                         env=env, cwd=root, stdout=subprocess.DEVNULL,
                         stderr=subprocess.DEVNULL)
    manifest = os.path.join(ck_dir, "manifest.json")
    deadline = time.time() + 240
    phase = None
    try:
        while time.time() < deadline and p.poll() is None and phase != "up":
            try:
                phase = json.load(open(manifest))["meta"].get("phase")
            except (OSError, ValueError):
                pass
            time.sleep(0.02)
        assert phase == "up", f"never saw an upward-sweep checkpoint ({phase})"
    finally:
        if p.poll() is None:
            os.kill(p.pid, signal.SIGKILL)
        p.wait(timeout=60)

    r2 = subprocess.run(common + ["--mesh", "2x1", "--ckpt-dir", ck_dir],
                        capture_output=True, text=True, env=env, cwd=root,
                        timeout=480)
    assert r2.returncode == 0, r2.stderr[-1500:]
    assert "resumed at phase=up" in r2.stdout, r2.stdout[-1500:]

    ref, got = _load_final_params(ref_dir), _load_final_params(ck_dir)
    assert ref.keys() == got.keys()
    for k in ref:
        np.testing.assert_allclose(got[k].astype(np.float64),
                                   ref[k].astype(np.float64), atol=1e-3,
                                   err_msg=k)


@pytest.mark.slow
def test_vcycle_launcher_sigkill_resume(tmp_path):
    """The real CLI path: start a V-cycle run, SIGKILL it once the first
    checkpoint lands, restart with identical args and require the
    (phase, level, step) resume line."""
    env = dict(os.environ, PYTHONPATH="src")
    root = os.path.join(os.path.dirname(__file__), "..")
    args = [sys.executable, "-m", "repro.launch.train", "--arch", "tinyllama-1.1b",
            "--smoke", "--vcycle", "--levels", "2", "--steps", "40",
            "--batch", "2", "--seq", "16",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "3"]
    p = subprocess.Popen(args, env=env, cwd=root, stdout=subprocess.DEVNULL,
                         stderr=subprocess.DEVNULL)
    manifest = os.path.join(str(tmp_path), "manifest.json")
    deadline = time.time() + 240
    try:
        while (time.time() < deadline and p.poll() is None
               and not os.path.exists(manifest)):
            time.sleep(0.05)
        assert os.path.exists(manifest), "no checkpoint before timeout/exit"
    finally:
        if p.poll() is None:
            os.kill(p.pid, signal.SIGKILL)
        p.wait(timeout=60)
    r = subprocess.run(args, capture_output=True, text=True, env=env, cwd=root,
                       timeout=300)
    assert r.returncode == 0, r.stderr[-1500:]
    assert "resumed at phase=" in r.stdout, r.stdout[-1500:]


@pytest.mark.slow
def test_serve_soak_live_trainer_reloads(tmp_path):
    """The train->serve soak drill: a REAL ``python -m repro.launch.train
    --vcycle`` run publishes a checkpoint every 2 global steps while an
    in-process paged server with an attached ManifestWatcher serves
    continuous traffic from the same directory.  The server must swap
    multiple published steps in publish order, skip any coalesced
    mid-V-cycle publishes it examines, drop zero requests (every request
    completes its full token budget), and land reloads by digest diff
    (``last_gather_stats`` shows pruned transfers)."""
    from repro.checkpoint import CheckpointManager
    from repro.configs import get_config
    from repro.launch.serve import ManifestWatcher, Request, make_server

    ckpt = str(tmp_path / "ckpt")
    env = dict(os.environ, PYTHONPATH="src")
    root = os.path.join(os.path.dirname(__file__), "..")
    args = [sys.executable, "-m", "repro.launch.train", "--arch",
            "tinyllama-1.1b", "--smoke", "--vcycle", "--levels", "2",
            "--steps", "24", "--batch", "2", "--seq", "16",
            "--ckpt-dir", ckpt, "--ckpt-every", "2"]

    cfg = get_config("tinyllama-1.1b", smoke=True)
    srv = make_server(cfg, engine="paged", batch=3, max_seq=48, page_size=8)
    watcher = ManifestWatcher(CheckpointManager(ckpt), like=srv.params)
    srv.attach_watcher(watcher)

    rng = np.random.default_rng(0)
    rid = 0

    def wave():
        nonlocal rid
        reqs = [Request(rid=rid + i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            size=int(rng.integers(4, 12))),
                        max_new=4) for i in range(3)]
        rid += 3
        srv.run(reqs)

    log = str(tmp_path / "train.log")
    with open(log, "w") as lf:
        trainer = subprocess.Popen(args, env=env, cwd=root, stdout=lf,
                                   stderr=subprocess.STDOUT)
        try:
            deadline = time.time() + 600
            while trainer.poll() is None and time.time() < deadline:
                wave()  # continuous traffic while the trainer publishes
        finally:
            if trainer.poll() is None:
                trainer.kill()
        assert trainer.wait(timeout=60) == 0, open(log).read()[-1500:]
    wave()  # one more wave to land the trainer's terminal save

    # zero dropped requests: everything admitted, everything completed full
    assert srv.rejected == []
    assert len(srv.done) == rid
    assert all(len(r.out) == 4 for r in srv.done)

    # the server really followed the trainer: >= 2 live swaps, publish order
    assert srv.reloads == len(watcher.steps_seen), \
        (srv.reloads, watcher.steps_seen)
    assert len(watcher.steps_seen) >= 2, watcher.steps_seen
    assert watcher.steps_seen == sorted(set(watcher.steps_seen)), \
        "manifest steps landed out of order"
    # skipped (coalesced-shape) steps never served, never landed
    assert not set(watcher.steps_skipped) & set(watcher.steps_seen)
    # digest-diff transfers: the gathers were pruned to the needed digests
    assert any(r["gather_skipped"] > 0 for r in watcher.reload_history), \
        watcher.reload_history
    assert watcher.poll_errors == 0 or watcher.steps_seen, \
        "poll errors without a single landed step"
