"""Kill-and-resume equivalence: a 2-level V-cycle interrupted at an arbitrary
step (here: mid-upward-sweep, so the de-coalesce/interpolate transition is
replayed after restore) must produce final params and a FLOPs-indexed History
identical to the uninterrupted run; and each level's train step is compiled at
most once per run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import fast_tc, tiny_dense
from repro.checkpoint import CheckpointManager
from repro.config import MultiLevelConfig
from repro.core.vcycle import SegmentPlan, VCycleRunner, segments
from repro.data import MarkovLM, lm_batch
from repro.launch.train import make_vcycle_save_cb, restore_vcycle_state


class Preempted(RuntimeError):
    pass


def arena():
    cfg = tiny_dense(d_model=32, d_ff=64, vocab_size=128,
                     compute_dtype=jnp.float32)
    tc = fast_tc(steps=12, batch_size=4, seq_len=16, log_every=2, peak_lr=3e-3)
    ml = MultiLevelConfig(n_levels=2, alpha=0.25, e_a_frac=0.25, e_small_frac=0.5)
    chain = MarkovLM(128)
    bf = lambda step: lm_batch(chain, 0, step, tc.batch_size, tc.seq_len)
    return cfg, ml, tc, bf


def test_segments_schedule():
    cfg, ml, tc, _ = arena()
    ml3 = MultiLevelConfig(n_levels=3, e_a_frac=0.25, e_small_frac=0.5)
    plan = segments(cfg, ml3, tc, final_steps=7)
    assert plan == [SegmentPlan("down", 0, 3), SegmentPlan("down", 1, 3),
                    SegmentPlan("up", 2, 6), SegmentPlan("up", 1, 6),
                    SegmentPlan("final", 0, 7)]


def test_kill_and_resume_equivalence(tmp_path):
    cfg, ml, tc, bf = arena()
    # schedule: down L0 for 3 steps (g 1..3), up L1 for 6 (g 4..9), final 12
    ref = VCycleRunner(cfg, ml, tc, bf, seed=0).run()

    # interrupted run: checkpoint every 2 global steps, die right after the
    # save at global step 6 -- the middle of the upward sweep
    cm = CheckpointManager(str(tmp_path))
    runner = VCycleRunner(cfg, ml, tc, bf, seed=0)
    save_cb = make_vcycle_save_cb(cm, schedule=runner.plan)

    def killing_cb(state, params, opt_state):
        save_cb(state, params, opt_state)
        if state.global_step == 6:
            raise Preempted

    with pytest.raises(Preempted):
        runner.run(ckpt_cb=killing_cb, ckpt_every=2)
    cm.wait()  # the real crash path relies on atomic publish instead

    # "new process": fresh runner, restore, run to completion
    runner2 = VCycleRunner(cfg, ml, tc, bf, seed=0)
    state, params, opt = restore_vcycle_state(cm, runner2, tc)
    assert (state.phase, state.level, state.global_step) == ("up", 1, 6)
    assert state.seg_step == 3 and state.seg_index == 1
    assert list(state.params_before) == [0]  # stash survives the crash
    out = runner2.run(state=state, params=params, opt_state=opt,
                      ckpt_cb=make_vcycle_save_cb(cm, schedule=runner2.plan),
                      ckpt_every=2)

    for a, b in zip(jax.tree.leaves(out.params), jax.tree.leaves(ref.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)
    assert out.history.step == ref.history.step
    assert out.history.level == ref.history.level
    np.testing.assert_allclose(out.history.flops, ref.history.flops, rtol=1e-12)
    np.testing.assert_allclose(out.history.loss, ref.history.loss, atol=1e-5)
    np.testing.assert_allclose(out.total_flops, ref.total_flops, rtol=1e-12)
    # resumed process compiled each visited level at most once
    assert runner2.n_compiles == 2


def test_restore_unsharded_save_onto_mesh(tmp_path):
    """Elastic re-shard, in-process flavor: a checkpoint written by an
    UNSHARDED run restores onto a mesh-carrying runner (1x1 fits the test
    process's single CPU device) -- params, opt and the mid-upward-sweep
    ``params_before_*`` stash all land as NamedSharding arrays, and the
    resumed sharded run matches the uninterrupted unsharded reference.
    (The multi-device 1x1 <-> 2x2 version lives in test_distributed.py.)"""
    from jax.sharding import NamedSharding

    cfg, ml, tc, bf = arena()
    ref = VCycleRunner(cfg, ml, tc, bf, seed=0).run()

    cm = CheckpointManager(str(tmp_path))
    runner = VCycleRunner(cfg, ml, tc, bf, seed=0)
    save_cb = make_vcycle_save_cb(cm, schedule=runner.plan)

    def killing_cb(state, params, opt_state):
        save_cb(state, params, opt_state)
        if state.global_step == 6:
            raise Preempted

    with pytest.raises(Preempted):
        runner.run(ckpt_cb=killing_cb, ckpt_every=2)
    cm.wait()

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    runner2 = VCycleRunner(cfg, ml, tc, bf, seed=0, mesh=mesh)
    state, params, opt = restore_vcycle_state(cm, runner2, tc)
    for tree in (params, opt, state.params_before[0]):
        for leaf in jax.tree.leaves(tree):
            assert isinstance(leaf.sharding, NamedSharding)
    out = runner2.run(state=state, params=params, opt_state=opt)
    for a, b in zip(jax.tree.leaves(out.params), jax.tree.leaves(ref.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)
    assert out.history.step == ref.history.step


def test_resume_rejects_schedule_mismatch(tmp_path):
    """Restarting under different --steps/--levels must fail loudly, not
    silently train the wrong schedule from the restored (seg_index, seg_step)."""
    cfg, ml, tc, bf = arena()
    cm = CheckpointManager(str(tmp_path))
    runner = VCycleRunner(cfg, ml, tc, bf, seed=0)
    save_cb = make_vcycle_save_cb(cm, schedule=runner.plan)

    def killing_cb(state, params, opt_state):
        save_cb(state, params, opt_state)
        if state.global_step == 4:
            raise Preempted

    with pytest.raises(Preempted):
        runner.run(ckpt_cb=killing_cb, ckpt_every=2)
    cm.wait()

    tc2 = fast_tc(steps=30, batch_size=4, seq_len=16, log_every=2, peak_lr=3e-3)
    runner2 = VCycleRunner(cfg, ml, tc2, bf, seed=0)
    with pytest.raises(ValueError, match="schedule"):
        restore_vcycle_state(cm, runner2, tc2)


def test_no_checkpoint_on_early_stop_step(tmp_path):
    """A target-loss early exit is not persisted state, so the stopping step
    must never be checkpointed (a restart from it would train past the exit)."""
    cfg, ml, tc, bf = arena()
    cm = CheckpointManager(str(tmp_path))
    runner = VCycleRunner(cfg, ml, tc, bf, seed=0, target_loss=1e9)
    runner.run(ckpt_cb=make_vcycle_save_cb(cm, schedule=runner.plan),
               ckpt_every=1)
    cm.wait()
    # target trivially satisfied at the final segment's first log step (g=10);
    # every prior step checkpointed, the stopping step not
    assert runner.state.global_step == 10
    assert cm.latest()["step"] == 9


def test_per_level_step_compiled_once(monkeypatch):
    """The docstring promise: per-level compiled steps are built once and
    cached, even though levels below the top are visited twice."""
    import repro.core.vcycle as vc

    cfg, ml, tc, bf = arena()
    calls = []
    real = vc.make_train_step

    def counting(model, tc_):
        calls.append(model.cfg.d_model)
        return real(model, tc_)

    monkeypatch.setattr(vc, "make_train_step", counting)
    runner = VCycleRunner(cfg, ml, tc, bf, seed=0, final_steps=4)
    runner.run()
    assert runner.n_compiles == ml.n_levels
    assert sorted(calls) == sorted({cfg.d_model, cfg.d_model // 2})
