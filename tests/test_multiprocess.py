"""Multi-process (multi-host) training tests.

The slow tests spawn N real local CPU processes against a localhost
coordinator (tests/helpers.py ``run_multiprocess``) -- the CI-drillable
stand-in for an N-host launch -- and pin the three advertised behaviors that
used to be dead or wrong:

* a 2-process ``(2,1)``-mesh V-cycle run consumes the same global data stream
  as a 1-process run and lands allclose final params (f32),
* coordinated checkpoints are process-count-elastic: save with 2 processes,
  resume with 1 (and vice versa), mid-upward-sweep with a live
  ``params_before`` stash,
* SIGTERM on any ONE process drains ALL processes through the same final save
  step and a clean exit 0 (cross-host preemption propagation).
"""
import json
import os
import re
import signal
import subprocess
import sys
import time

import jax
import numpy as np
import pytest

from helpers import free_port, mp_arena, run_multiprocess

ROOT = os.path.join(os.path.dirname(__file__), "..")


# ---------------------------------------------------------------------------
# fast single-process guarantees


def test_single_process_helpers_degrade_to_noops():
    from repro.distributed import any_process_flag, as_global_batch_fn, barrier

    barrier("noop")  # must not require jax.distributed
    assert any_process_flag(True) is True
    assert any_process_flag(False) is False
    bf = lambda step: {"x": np.zeros((4, 2))}
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert as_global_batch_fn(bf, mesh) is bf  # identity, not a wrapper
    assert as_global_batch_fn(bf, None) is bf


def test_preemption_guard_should_stop_single_process():
    from repro.launch.train import PreemptionGuard

    g = PreemptionGuard()
    assert g.should_stop() is False
    g.triggered = True
    assert g.should_stop() is True


class _NotAddressable:
    """Stub for an array sharded across processes (can't build a real one in
    a single-process test)."""

    is_fully_addressable = False
    shape = (2,)


def test_save_tree_raises_on_non_addressable(tmp_path):
    """The old path silently jax.device_get'ed every leaf ("one process owns
    all shards"); feeding it a cross-process-sharded leaf must raise loudly
    instead of gathering garbage."""
    from repro.checkpoint import save_tree

    with pytest.raises(ValueError, match="not fully addressable"):
        save_tree(str(tmp_path / "t"), {"w": _NotAddressable()})


def test_manager_save_raises_on_non_addressable(tmp_path):
    from repro.checkpoint import CheckpointManager

    cm = CheckpointManager(str(tmp_path))
    with pytest.raises(ValueError, match="not fully addressable"):
        cm.save(1, {"params": {"w": _NotAddressable()}})
    assert cm.latest() is None  # nothing was published


def test_fused_drain_flag_single_mesh_mechanics():
    """The fused drain path end to end on a 1-device mesh: the flag array is
    authored per process, the in-step reduce replicates it, and the guard
    reads the fused scalar instead of all-gathering."""
    import jax.numpy as jnp

    from repro.distributed import FusedDrainFlag
    from repro.launch.train import PreemptionGuard

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    g = PreemptionGuard()
    drain = g.attach(FusedDrainFlag(mesh, guard=g))
    assert g.should_stop() is False  # nothing observed yet

    step = jax.jit(lambda flag: FusedDrainFlag.reduce(flag))
    drain.observe(step(drain.device_flag()))
    assert drain.last() is False and g.should_stop() is False
    g.triggered = True
    drain.observe(step(drain.device_flag()))
    assert drain.last() is True and g.should_stop() is True
    # un-attached guards keep the explicit allgather fallback
    g2 = PreemptionGuard()
    g2.triggered = True
    assert g2.should_stop() is True


def test_fused_drain_guard_local_flag_before_first_step():
    """Single-process safety net: a SIGTERM caught before the first fused
    step is observed must still stop at the next poll."""
    from repro.distributed import FusedDrainFlag
    from repro.launch.train import PreemptionGuard

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    g = PreemptionGuard()
    g.attach(FusedDrainFlag(mesh, guard=g))
    g.triggered = True
    assert g.should_stop() is True


def test_make_cli_mesh_rejects_indivisible_process_count():
    from repro.launch.mesh import make_cli_mesh

    with pytest.raises(ValueError, match="not divisible"):
        make_cli_mesh("3x1", num_processes=2)


# ---------------------------------------------------------------------------
# real 2-process drills


def _final_params(ckdir: str, step_dir: str = None):
    """Reassembled logical final params from a checkpoint dir, whatever
    layout (whole-leaf or coordinated shard chunks) wrote it."""
    from repro.checkpoint.manager import _read_leaves

    if step_dir is None:
        m = json.load(open(os.path.join(ckdir, "manifest.json")))
        step_dir = m["dir"]
    return _read_leaves(os.path.join(ckdir, step_dir, "params"))


def _flat_params(tree):
    from repro.checkpoint.manager import _flatten

    return _flatten(jax.device_get(tree))


def _assert_allclose_trees(a, b, atol):
    assert a.keys() == b.keys(), (sorted(a)[:3], sorted(b)[:3])
    for k in a:
        np.testing.assert_allclose(np.asarray(a[k], np.float64),
                                   np.asarray(b[k], np.float64),
                                   atol=atol, err_msg=k)


@pytest.mark.slow
def test_two_process_vcycle_matches_single_process(tmp_path):
    """The acceptance drill: a 2-process (2,1)-mesh V-cycle through the real
    driver (train_vcycle_ckpt + coordinated checkpointing) reproduces the
    single-process run's final params.  f32; the 1e-2 atol is a gross-error
    guard -- per-step drift is pure data-parallel reduction roundoff (~1e-6
    measured) that Adam amplifies, while a wrong shard/slice lands O(1e-1)."""
    res = run_multiprocess("""
        import os
        import jax
        from helpers import mp_arena
        from repro.checkpoint import CheckpointManager
        from repro.distributed import mesh_ctx
        from repro.launch.train import train_vcycle_ckpt

        cfg, tc, ml = mp_arena()
        mesh = jax.make_mesh((2, 1), ("data", "model"))
        cm = CheckpointManager(os.environ["CK"])
        with mesh_ctx(mesh):
            out = train_vcycle_ckpt(cfg, ml, tc, ckpt=cm, ckpt_every=4,
                                    mesh=mesh,
                                    verbose=jax.process_index() == 0)
        print("MP_VCYCLE_OK", flush=True)
    """, n=2, env={"CK": str(tmp_path)})
    for rc, out in res:
        assert rc == 0, out[-3000:]
        assert "MP_VCYCLE_OK" in out
    # single-process reference, same global data stream by construction
    from repro.core.vcycle import VCycleRunner
    from repro.launch.train import make_batch_fn

    cfg, tc, ml = mp_arena()
    ref = VCycleRunner(cfg, ml, tc, make_batch_fn(cfg, tc, shard=0),
                       seed=tc.seed).run()
    m = json.load(open(os.path.join(str(tmp_path), "manifest.json")))
    assert m["meta"].get("phase") == "done"
    _assert_allclose_trees(_final_params(str(tmp_path)),
                           _flat_params(ref.params), atol=1e-2)
    np.testing.assert_allclose(m["meta"]["history"]["loss"],
                               ref.history.loss, atol=1e-2)


@pytest.mark.slow
def test_checkpoint_crosses_process_counts_both_ways(tmp_path):
    """Elastic restore across PROCESS COUNTS, mid-upward-sweep (live
    ``params_before`` stash): a checkpoint coordinated-saved by 2 processes
    resumes under 1 process, and a 1-process save resumes under 2 processes
    -- both runs land allclose to the uninterrupted single-process
    reference."""
    from repro.checkpoint import CheckpointManager
    from repro.core.vcycle import VCycleRunner
    from repro.launch.train import (make_batch_fn, make_vcycle_save_cb,
                                    restore_vcycle_state)

    cfg, tc, ml = mp_arena()
    bf = make_batch_fn(cfg, tc, shard=0)
    ref = VCycleRunner(cfg, ml, tc, bf, seed=0).run()

    # --- 2-process save, killed right after the global_step-6 checkpoint ----
    ck2 = str(tmp_path / "two_to_one")
    res = run_multiprocess("""
        import os
        import jax
        from helpers import mp_arena
        from repro.checkpoint import CheckpointManager
        from repro.core.vcycle import VCycleRunner
        from repro.distributed import as_global_batch_fn
        from repro.launch.train import make_batch_fn, make_vcycle_save_cb

        class Preempted(RuntimeError):
            pass

        cfg, tc, ml = mp_arena()
        mesh = jax.make_mesh((2, 1), ("data", "model"))
        bf = as_global_batch_fn(make_batch_fn(cfg, tc, shard=0), mesh)
        runner = VCycleRunner(cfg, ml, tc, bf, seed=0, mesh=mesh)
        cm = CheckpointManager(os.environ["CK"])
        save_cb = make_vcycle_save_cb(cm, schedule=runner.plan)

        def killing_cb(state, params, opt_state):
            save_cb(state, params, opt_state)
            if state.global_step == 6:  # mid-upward-sweep: stash is live
                raise Preempted

        try:
            runner.run(ckpt_cb=killing_cb, ckpt_every=2)
            raise AssertionError("kill never fired")
        except Preempted:
            print("MP_KILLED_OK", flush=True)
    """, n=2, env={"CK": ck2})
    for rc, out in res:
        assert rc == 0, out[-3000:]
        assert "MP_KILLED_OK" in out

    # ...resumed by ONE process, no mesh at all
    runner1 = VCycleRunner(cfg, ml, tc, bf, seed=0)
    state, params, opt = restore_vcycle_state(CheckpointManager(ck2), runner1, tc)
    assert (state.phase, state.level, state.global_step) == ("up", 1, 6)
    assert list(state.params_before) == [0]
    out1 = runner1.run(state=state, params=params, opt_state=opt)
    assert out1.history.step == ref.history.step
    _assert_allclose_trees(_flat_params(out1.params), _flat_params(ref.params),
                           atol=1e-2)

    # --- 1-process save killed at the same point, resumed by 2 processes ----
    ck1 = str(tmp_path / "one_to_two")

    class Preempted(RuntimeError):
        pass

    runner_s = VCycleRunner(cfg, ml, tc, bf, seed=0)
    cm_s = CheckpointManager(ck1)
    save_cb = make_vcycle_save_cb(cm_s, schedule=runner_s.plan)

    def killing_cb(state, p, o):
        save_cb(state, p, o, blocking=True)
        if state.global_step == 6:
            raise Preempted

    with pytest.raises(Preempted):
        runner_s.run(ckpt_cb=killing_cb, ckpt_every=2)

    res = run_multiprocess("""
        import os
        import jax
        from helpers import mp_arena
        from repro.checkpoint import CheckpointManager
        from repro.core.vcycle import VCycleRunner
        from repro.distributed import as_global_batch_fn
        from repro.launch.train import make_batch_fn, restore_vcycle_state

        cfg, tc, ml = mp_arena()
        mesh = jax.make_mesh((2, 1), ("data", "model"))
        bf = as_global_batch_fn(make_batch_fn(cfg, tc, shard=0), mesh)
        runner = VCycleRunner(cfg, ml, tc, bf, seed=0, mesh=mesh)
        cm = CheckpointManager(os.environ["CK"])
        state, params, opt = restore_vcycle_state(cm, runner, tc)
        assert (state.phase, state.level, state.global_step) == ("up", 1, 6)
        # the restored stash really spans the 2-process mesh
        leaf = jax.tree.leaves(state.params_before[0])[0]
        assert leaf.sharding.mesh.devices.size == 2
        out = runner.run(state=state, params=params, opt_state=opt)
        cm.save(999, {"params": out.params}, meta={"step": 999})
        print("MP_RESUMED_OK", flush=True)
    """, n=2, env={"CK": ck1})
    for rc, out in res:
        assert rc == 0, out[-3000:]
        assert "MP_RESUMED_OK" in out
    _assert_allclose_trees(_final_params(ck1, "step_00000999"),
                           _flat_params(ref.params), atol=1e-2)


@pytest.mark.slow
def test_v2_coordinated_save_writes_meta_for_scan_fallback(tmp_path):
    """Regression: the v2 (``dedup=False``) coordinated save must write
    ``meta.json`` into the step dir -- the torn-manifest ``_scan_fallback``
    recovers metadata from it, and losing it silently drops the VCycleState
    addressing on recovery."""
    res = run_multiprocess("""
        import os
        import jax, jax.numpy as jnp
        from repro.checkpoint import CheckpointManager

        cm = CheckpointManager(os.environ["CK"], dedup=False)
        cm.save(7, {"params": {"w": jnp.arange(4.0)}},
                meta={"step": 7, "phase": "up"})
        print("MP_V2_SAVED", flush=True)
    """, n=2, env={"CK": str(tmp_path)})
    for rc, out in res:
        assert rc == 0, out[-3000:]
        assert "MP_V2_SAVED" in out
    assert os.path.exists(os.path.join(str(tmp_path), "step_00000007",
                                       "meta.json"))
    # torn manifest: points at a dir that no longer exists -> scan fallback
    with open(os.path.join(str(tmp_path), "manifest.json"), "w") as f:
        json.dump({"dir": "step_00000099", "step": 99, "meta": {}}, f)
    from repro.checkpoint import CheckpointManager

    m = CheckpointManager(str(tmp_path)).latest()
    assert m["step"] == 7 and m["meta"]["phase"] == "up"


@pytest.mark.slow
def test_fused_drain_no_dedicated_allgather(tmp_path):
    """ROADMAP open item closed: the per-step drain poll must run ZERO
    dedicated ``process_allgather`` calls (the OR is fused into the compiled
    step), while a flag raised on ONE process still drains BOTH at the same
    agreed global step."""
    res = run_multiprocess("""
        import jax
        from jax.experimental import multihost_utils as mh
        calls = {"n": 0}
        orig = mh.process_allgather
        def counting(*a, **k):
            calls["n"] += 1
            return orig(*a, **k)
        mh.process_allgather = counting

        from helpers import mp_arena
        from repro.core.vcycle import VCycleRunner
        from repro.distributed import FusedDrainFlag, as_global_batch_fn
        from repro.launch.train import PreemptionGuard, make_batch_fn

        cfg, tc, ml = mp_arena()
        mesh = jax.make_mesh((2, 1), ("data", "model"))
        bf = as_global_batch_fn(make_batch_fn(cfg, tc, shard=0), mesh)
        guard = PreemptionGuard()
        drain = guard.attach(FusedDrainFlag(mesh, guard=guard))
        runner = VCycleRunner(cfg, ml, tc, bf, seed=0, mesh=mesh,
                              drain_flag=drain)

        def on_step(st, p, o, stopping, dt):
            if jax.process_index() == 1 and st.global_step == 5:
                guard.triggered = True  # the notice lands on ONE process only
            if guard.should_stop() and not stopping:
                print("DRAIN_AT", st.global_step, "ALLGATHERS", calls["n"],
                      flush=True)
                raise SystemExit(0)

        runner.run(on_step=on_step)
        raise AssertionError("drain never fired")
    """, n=2)
    steps = []
    for rc, out in res:
        assert rc == 0, out[-3000:]
        m = re.search(r"DRAIN_AT (\d+) ALLGATHERS (\d+)", out)
        assert m is not None, out[-2000:]
        steps.append(m.group(1))
        assert m.group(2) == "0", out[-2000:]
    assert steps[0] == steps[1]  # one agreed final step on both processes


@pytest.mark.slow
def test_sigterm_on_one_process_drains_all(tmp_path):
    """Cross-host preemption through the real CLI: SIGTERM delivered to
    process 1 ONLY must drain BOTH processes through the same final-save step
    and exit 0, and the checkpoint must resume under a single process."""
    port = free_port()
    common = [sys.executable, "-m", "repro.launch.train", "--arch",
              "tinyllama-1.1b", "--smoke", "--vcycle", "--levels", "2",
              "--steps", "40", "--batch", "4", "--seq", "16", "--f32",
              "--ckpt-dir", str(tmp_path), "--ckpt-every", "1000"]
    mp = ["--mesh", "2x1", "--coordinator", f"127.0.0.1:{port}",
          "--num-processes", "2"]
    env = dict(os.environ, PYTHONPATH="src")
    logs = [os.path.join(str(tmp_path), f"rank{i}.log") for i in (0, 1)]
    procs = []
    for i in (0, 1):
        with open(logs[i], "w") as lf:
            procs.append(subprocess.Popen(
                common + mp + ["--process-id", str(i)], env=env, cwd=ROOT,
                stdout=lf, stderr=subprocess.STDOUT))
    try:
        deadline = time.time() + 300
        stepping = False
        while time.time() < deadline and not stepping:
            if any(p.poll() is not None for p in procs):
                break
            stepping = "coalescing" in open(logs[0]).read()
            time.sleep(0.1)
        assert stepping, (open(logs[0]).read()[-2000:],
                          open(logs[1]).read()[-2000:])
        procs[1].send_signal(signal.SIGTERM)  # ONE process gets the notice
        for p in procs:
            assert p.wait(timeout=300) == 0, "drain exit was not clean"
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
                p.wait()
    outs = [open(l).read() for l in logs]
    steps = [re.search(r"blocking V-cycle checkpoint at global_step (\d+)", o)
             for o in outs]
    assert all(s is not None for s in steps), (outs[0][-1500:], outs[1][-1500:])
    # ...at the SAME agreed step on both processes
    assert steps[0].group(1) == steps[1].group(1)
    assert "caught signal" in outs[1] and "caught signal" not in outs[0]
    assert os.path.exists(os.path.join(str(tmp_path), "manifest.json"))
    # the 2-process drain checkpoint resumes under ONE process
    r = subprocess.run(common, capture_output=True, text=True, env=env,
                       cwd=ROOT, timeout=480)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "resumed at phase=" in r.stdout, r.stdout[-1500:]
