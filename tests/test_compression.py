"""Gradient compression: quantization error bounds + error-feedback property
+ the shard_map all-reduce path."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.compression import (dequantize_int8, ef_compress,
                                           ef_int8_psum, init_ef_state, quantize_int8)


def test_quantization_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (512,)) * 3.0
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-6  # half-ULP symmetric rounding


def test_error_feedback_unbiased_over_time():
    """EF: the accumulated transmitted signal converges to the true sum."""
    key = jax.random.PRNGKey(1)
    xs = jax.random.normal(key, (50, 256)) * 0.01  # small grads: worst case
    ef = jnp.zeros((256,), jnp.float32)
    sent = jnp.zeros((256,), jnp.float32)
    for i in range(50):
        q, s, ef = ef_compress(xs[i], ef)
        sent = sent + dequantize_int8(q, s)
    true = xs.sum(0)
    # residual error is bounded by the final carried error (not accumulated)
    np.testing.assert_allclose(np.asarray(sent + ef), np.asarray(true), atol=1e-4)


def test_shardmap_psum_single_device():
    mesh = jax.make_mesh((1,), ("data",))
    grads = {"w": jnp.ones((8, 8)) * 0.5}
    ef = init_ef_state(grads)

    # jax.shard_map landed after 0.4.37; use the experimental home it has there
    from jax.experimental.shard_map import shard_map

    @jax.jit
    def run(g, e):
        return shard_map(
            lambda g, e: ef_int8_psum(g, e, "data"), mesh=mesh,
            in_specs=(jax.sharding.PartitionSpec(), jax.sharding.PartitionSpec()),
            out_specs=(jax.sharding.PartitionSpec(), jax.sharding.PartitionSpec()),
        )(g, e)

    out, new_ef = run(grads, ef)
    np.testing.assert_allclose(np.asarray(out["w"]), 0.5 * np.ones((8, 8)), atol=0.01)
