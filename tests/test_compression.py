"""Gradient compression: quantization error bounds + error-feedback property
+ the shard_map all-reduce path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import (dequantize_int8, ef_compress,
                                           ef_int8_psum, init_ef_state, quantize_int8)


def test_quantization_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (512,)) * 3.0
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-6  # half-ULP symmetric rounding


@pytest.mark.parametrize("mag", [1e-8, 1e-3, 1.0, 1e3, 1e6])
def test_quantization_error_bound_across_magnitudes(mag):
    """The half-scale bound is scale-invariant: the quantizer normalizes by
    max|x|, so tiny and huge gradients round-trip with the same RELATIVE
    error -- err <= max|x| / 254."""
    x = jax.random.normal(jax.random.PRNGKey(1), (256,)) * mag
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    bound = float(np.abs(np.asarray(x)).max()) / 254.0
    assert err.max() <= bound * (1 + 1e-5)
    assert float(s) == pytest.approx(bound * 2, rel=1e-6)


def test_quantization_payload_is_really_int8():
    q, s = quantize_int8(jax.random.normal(jax.random.PRNGKey(2), (128,)) * 9.0)
    assert q.dtype == jnp.int8  # 4x fewer DCN bytes than f32, the whole point
    qn = np.asarray(q)
    assert qn.min() >= -127 and qn.max() <= 127  # symmetric, no -128
    assert qn.max() == 127 or qn.min() == -127  # max|x| maps to full scale


def test_quantization_of_zeros_is_exact():
    q, s = quantize_int8(jnp.zeros((32,)))
    np.testing.assert_array_equal(np.asarray(q), np.zeros(32, np.int8))
    np.testing.assert_array_equal(np.asarray(dequantize_int8(q, s)),
                                  np.zeros(32, np.float32))
    assert float(s) > 0  # the 1e-12 floor keeps x/scale finite


def test_ef_compress_conserves_signal_exactly():
    """EF bookkeeping identity: transmitted + carried == input + carry-in,
    to f32 roundoff -- nothing is ever lost, only delayed."""
    x = jax.random.normal(jax.random.PRNGKey(3), (128,)) * 0.3
    ef = jax.random.normal(jax.random.PRNGKey(4), (128,)) * 0.01
    q, s, new_ef = ef_compress(x, ef)
    sent = dequantize_int8(q, s)
    np.testing.assert_allclose(np.asarray(sent + new_ef), np.asarray(x + ef),
                               atol=1e-6)
    # and the carried error is itself bounded by the quantization step
    assert np.abs(np.asarray(new_ef)).max() <= float(s) / 2 + 1e-6


def test_error_feedback_unbiased_over_time():
    """EF: the accumulated transmitted signal converges to the true sum."""
    key = jax.random.PRNGKey(1)
    xs = jax.random.normal(key, (50, 256)) * 0.01  # small grads: worst case
    ef = jnp.zeros((256,), jnp.float32)
    sent = jnp.zeros((256,), jnp.float32)
    for i in range(50):
        q, s, ef = ef_compress(xs[i], ef)
        sent = sent + dequantize_int8(q, s)
    true = xs.sum(0)
    # residual error is bounded by the final carried error (not accumulated)
    np.testing.assert_allclose(np.asarray(sent + ef), np.asarray(true), atol=1e-4)


def test_shardmap_psum_single_device():
    mesh = jax.make_mesh((1,), ("data",))
    grads = {"w": jnp.ones((8, 8)) * 0.5}
    ef = init_ef_state(grads)

    # jax.shard_map landed after 0.4.37; use the experimental home it has there
    from jax.experimental.shard_map import shard_map

    @jax.jit
    def run(g, e):
        return shard_map(
            lambda g, e: ef_int8_psum(g, e, "data"), mesh=mesh,
            in_specs=(jax.sharding.PartitionSpec(), jax.sharding.PartitionSpec()),
            out_specs=(jax.sharding.PartitionSpec(), jax.sharding.PartitionSpec()),
        )(g, e)

    out, new_ef = run(grads, ef)
    np.testing.assert_allclose(np.asarray(out["w"]), 0.5 * np.ones((8, 8)), atol=0.01)
