"""Hypothesis property tests on the system's invariants."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev-only dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import projections as proj
from repro.core.vcycle import History, flops_to_reach

even = st.integers(min_value=1, max_value=64).map(lambda k: 2 * k)


@settings(max_examples=30, deadline=None)
@given(n=even, variant=st.sampled_from(["stack", "adj"]))
def test_width_inverse_properties(n, variant):
    m = proj.width_mats(n, variant)
    np.testing.assert_allclose(m.T_out @ m.F_out, np.eye(n // 2), atol=1e-10)
    np.testing.assert_allclose(m.F_in @ m.T_in, np.eye(n // 2), atol=1e-10)
    # D∘C projection is an idempotent averaging map (symmetric-neuron structure)
    P = m.F_out @ m.T_out  # [n, n]
    np.testing.assert_allclose(P @ P, P, atol=1e-10)


@settings(max_examples=30, deadline=None)
@given(L=st.integers(min_value=1, max_value=100), variant=st.sampled_from(["adj", "stack"]))
def test_depth_inverse_properties(L, variant):
    d = proj.depth_mats(L, variant)
    np.testing.assert_allclose(d.G @ d.R, np.eye(d.R.shape[1]), atol=1e-10)
    np.testing.assert_allclose((d.R @ d.G).sum(0), np.ones(L), atol=1e-10)


@settings(max_examples=20, deadline=None)
@given(n=even, c=st.integers(min_value=1, max_value=32),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_general_F_normalization(n, c, seed):
    """Paper §3.1: F_out may be ANY full-column-rank matrix; the derived
    T/F_in normalizations must still invert on the small side."""
    rng = np.random.default_rng(seed)
    F = rng.normal(size=(n, n // 2))
    # ensure strictly positive diagonal energy so colsums are non-degenerate
    F += np.vstack([np.eye(n // 2), np.eye(n // 2)])
    m = proj.derive_width(F)
    # value-scale stability: colsum normalization makes T_out F_out row sums finite
    assert np.all(np.isfinite(m.T_out)) and np.all(np.isfinite(m.T_in))


@settings(max_examples=20, deadline=None)
@given(alpha=st.floats(min_value=0.0, max_value=1.0),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_interpolation_convexity(alpha, seed):
    from repro.core.operators import interpolate

    rng = np.random.default_rng(seed)
    a = {"w": jnp.asarray(rng.normal(size=(6, 6)), jnp.float32)}
    b = {"w": jnp.asarray(rng.normal(size=(6, 6)), jnp.float32)}
    out = np.asarray(interpolate(a, b, float(alpha))["w"])
    lo = np.minimum(np.asarray(a["w"]), np.asarray(b["w"]))
    hi = np.maximum(np.asarray(a["w"]), np.asarray(b["w"]))
    assert (out >= lo - 1e-5).all() and (out <= hi + 1e-5).all()


@settings(max_examples=20, deadline=None)
@given(losses=st.lists(st.floats(min_value=0.1, max_value=10.0), min_size=6, max_size=40))
def test_flops_to_reach_monotone(losses):
    h = History()
    for i, l in enumerate(losses):
        h.log(float(i + 1), l, i, 0)
    _, sm = h.smoothed(5)
    t1 = flops_to_reach(h, float(min(sm)) + 1e-9)
    t2 = flops_to_reach(h, float(min(sm)) + 1.0)
    if t1 is not None and t2 is not None:
        assert t2 <= t1  # easier targets are reached no later


# ---------------------------------------------------------------------------
# checkpoint round-trips: arbitrary leaf names, dtypes and layouts


def _bf16():
    import ml_dtypes

    return ml_dtypes.bfloat16


# any character except the tree separator "/" (and surrogates, which cannot
# encode); exercises unicode, "%", spaces, dots -- the v2 percent-encoding
# and the v3 JSON-only names must both be injective over all of these
leaf_names = st.text(
    alphabet=st.characters(blacklist_characters="/",
                           blacklist_categories=("Cs",)),
    min_size=1, max_size=8)

_DTYPES = [np.float32, np.float16, np.int32, np.int8, np.uint16, np.bool_]


@st.composite
def leaf_arrays(draw):
    dtype = np.dtype(draw(st.sampled_from(_DTYPES + [_bf16()])))
    shape = tuple(draw(st.lists(st.integers(1, 4), min_size=0, max_size=3)))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    if dtype == np.bool_:
        return rng.integers(0, 2, size=shape).astype(bool)
    if dtype.kind in "fV" or str(dtype) == "bfloat16":
        return rng.normal(size=shape).astype(dtype)
    return rng.integers(-100, 100, size=shape).astype(dtype)


@settings(max_examples=20, deadline=None)
@given(leaves=st.dictionaries(leaf_names, leaf_arrays(), min_size=1, max_size=4),
       dedup=st.booleans(), step=st.integers(1, 10**6))
def test_checkpoint_roundtrip_bit_exact(leaves, dedup, step):
    """Arbitrary leaf names (unicode, "%", literal "__"), dtypes (incl.
    bfloat16) and shapes (incl. 0-d) survive save -> restore bit-exactly, in
    BOTH the v2 whole-file layout and the content-addressed v3 layout."""
    from repro.checkpoint import CheckpointManager

    # always include the historically-corrupting names alongside the drawn
    # ones: a literal "__" (the pre-v2 separator), a raw "%", and unicode
    leaves = dict(leaves)
    leaves["w__gate"] = np.arange(3, dtype=np.float32)
    leaves["100% ünïcode"] = np.float32(7.5).reshape(())
    tree = {"params": leaves, "nested": {"inner": dict(leaves)}}
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, dedup=dedup)
        cm.save(step, tree, meta={"step": step})
        like = jax.tree.map(lambda v: jnp.zeros(v.shape, v.dtype), tree)
        out, meta = cm.restore(like)
        assert meta["step"] == step
        flat_in, flat_out = jax.tree.leaves(tree), jax.tree.leaves(out)
        assert len(flat_in) == len(flat_out)
        for a, b in zip(flat_in, flat_out):
            got = np.asarray(jax.device_get(b))
            assert got.dtype == a.dtype, (got.dtype, a.dtype)
            np.testing.assert_array_equal(got, np.asarray(a))


@settings(max_examples=10, deadline=None)
@given(dedup=st.booleans(), rows=st.integers(1, 4),
       seed=st.integers(0, 2**31 - 1))
def test_checkpoint_roundtrip_across_shard_layouts(dedup, rows, seed):
    """Restoring onto an explicit mesh sharding (the elastic re-shard path)
    is still bit-exact for either layout -- checkpoints are logical."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.checkpoint import CheckpointManager

    rng = np.random.default_rng(seed)
    w = rng.normal(size=(2 * rows, 4)).astype(np.float32)
    tree = {"params": {"w": w, "b": rng.normal(size=(4,)).astype(np.float16)}}
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = {"params": {"w": NamedSharding(mesh, P("data", None)),
                     "b": NamedSharding(mesh, P())}}
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, dedup=dedup)
        cm.save(1, tree, meta={"step": 1})
        like = jax.tree.map(lambda v: jnp.zeros(v.shape, v.dtype), tree)
        out, _ = cm.restore(like, shardings=sh)
        assert out["params"]["w"].sharding == sh["params"]["w"]
        np.testing.assert_array_equal(np.asarray(out["params"]["w"]), w)
        assert out["params"]["b"].dtype == np.float16


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       n=st.sampled_from([8, 16, 32]))
def test_cd_identity_random_tensors(seed, n):
    """C∘D == id on arbitrary tensors for any (axes, roles) combination."""
    from repro.core.operators import LevelMaps, _project_tree
    from repro.param import Spec

    rng = np.random.default_rng(seed)
    maps = LevelMaps(width={"embed": proj.width_mats(n, "stack"),
                            "mlp": proj.width_mats(2 * n, "adj")},
                     depth={"stage_0": proj.depth_mats(5, "adj")}).as_jnp()
    spec = Spec((5, n, 2 * n), ("layers", "embed", "mlp"), ("-", "in", "out"))
    small = jnp.asarray(rng.normal(size=(3, n // 2, n)), jnp.float32)
    specs = {"stage_0": {"w": spec}}
    de = _project_tree({"stage_0": {"w": small}}, specs, maps, "decoalesce", False)
    rt = _project_tree(de, specs, maps, "coalesce", False)
    np.testing.assert_allclose(np.asarray(rt["stage_0"]["w"]), np.asarray(small), atol=1e-5)


# ---------------------------------------------------------------------------
# serving page allocator (launch/paging.py)


def _allocator_invariants(alloc, live):
    """The pinned pool invariants: full free/held accounting, no page in two
    live tables except via refcounted sharing, refcount == holder count."""
    pool = alloc.pool
    free = set(pool._free)
    held = {}
    for table in live.values():
        assert len(set(table)) == len(table), "page assigned twice in one table"
        for pid in table:
            held[pid] = held.get(pid, 0) + 1
    for pid, n in held.items():
        assert pid != 0, "null page handed to a request"
        assert pid not in free, "page simultaneously free and held"
        assert pool.refcount(pid) == n, "refcount != number of live holders"
    assert set(pool._ref) == set(held), "allocated page held by no request (leak)"
    assert len(free) + len(pool._ref) == pool.capacity


@settings(max_examples=50, deadline=None)
@given(data=st.data())
def test_page_allocator_admit_complete_interleavings(data):
    """Arbitrary admit/complete/denied interleavings: never leak a page,
    never double-assign, shared prefix pages freed exactly when the last
    referencing request completes, pool empty after a full drain."""
    from repro.launch.paging import BlockAllocator

    P = data.draw(st.sampled_from([2, 4]), label="page_size")
    n_pages = data.draw(st.integers(min_value=4, max_value=24), label="n_pages")
    reuse = data.draw(st.booleans(), label="prefix_reuse")
    alloc = BlockAllocator(n_pages, P, prefix_reuse=reuse)
    live = {}
    rid = 0
    for _ in range(data.draw(st.integers(min_value=1, max_value=30), label="n_ops")):
        if data.draw(st.booleans(), label="admit?") or not live:
            # tiny alphabet + optional common stem -> frequent shared prefixes
            body = data.draw(st.lists(st.integers(0, 3), min_size=1, max_size=10),
                             label="prompt")
            if data.draw(st.booleans(), label="stem?"):
                body = [1, 2, 3, 4, 1, 2, 3, 4] + body
            total = len(body) + data.draw(st.integers(1, 8), label="max_new")
            got = alloc.admit(rid, body, total)
            if got is not None:
                table, reuse_len = got
                assert len(table) == alloc.pages_needed(total)
                assert reuse_len <= len(body) - 1  # >= 1 fresh tail token
                assert reuse_len % P == 0
                live[rid] = table
            else:
                # denied admit must not have touched any state
                _allocator_invariants(alloc, live)
            rid += 1
        else:
            victim = data.draw(st.sampled_from(sorted(live)), label="complete")
            alloc.complete(victim)
            del live[victim]
        _allocator_invariants(alloc, live)
    for r in sorted(live):
        alloc.complete(r)
        del live[r]
        _allocator_invariants(alloc, live)
    assert alloc.pool.n_used == 0
    assert alloc.prefix is None or len(alloc.prefix) == 0


@settings(max_examples=30, deadline=None)
@given(stem_pages=st.integers(min_value=1, max_value=3),
       tail_a=st.integers(min_value=1, max_value=5),
       tail_b=st.integers(min_value=1, max_value=5))
def test_shared_prefix_page_freed_on_last_release(stem_pages, tail_a, tail_b):
    """Two prompts sharing a stem share its full pages; those pages survive
    the first completion and free exactly at the second."""
    from repro.launch.paging import BlockAllocator, page_digests

    P = 4
    alloc = BlockAllocator(32, P)
    stem = list(range(stem_pages * P))
    ta, _ = alloc.admit(0, stem + [7] * tail_a, stem_pages * P + tail_a + 2)
    tb, reused = alloc.admit(1, stem + [9] * tail_b, stem_pages * P + tail_b + 2)
    assert reused == stem_pages * P
    shared = ta[:stem_pages]
    assert tb[:stem_pages] == shared
    assert all(alloc.pool.refcount(p) == 2 for p in shared)
    alloc.complete(0)
    assert all(alloc.pool.refcount(p) == 1 for p in shared)  # still referenced
    # digests still served from the survivor's pages
    assert len(alloc.prefix.lookup(page_digests(stem, P))) == stem_pages
    alloc.complete(1)
    assert all(alloc.pool.refcount(p) == 0 for p in shared)  # last ref freed
    assert alloc.pool.n_used == 0
    assert len(alloc.prefix) == 0


@settings(max_examples=50, deadline=None)
@given(data=st.data())
def test_speculative_length_protocol_invariants(data):
    """The speculative advance/mark_written/rollback protocol over arbitrary
    interleavings: committed length never exceeds the written high-water,
    written never exceeds the admission reserve (page-safety of speculative
    bursts), rollback always rewinds written to exactly the committed length
    and accounts every rewound position, and over-reserve writes raise
    instead of silently landing outside the block table."""
    from repro.launch.paging import BlockAllocator

    P = 4
    alloc = BlockAllocator(64, P, prefix_reuse=False)
    L = data.draw(st.integers(min_value=1, max_value=10), label="prompt_len")
    max_new = data.draw(st.integers(min_value=1, max_value=12), label="max_new")
    reserve = L + max_new
    assert alloc.admit(0, [1] * L, reserve) is not None
    rolled_expect = 0
    for _ in range(data.draw(st.integers(1, 25), label="n_ops")):
        op = data.draw(st.sampled_from(["advance", "mark", "rollback"]), label="op")
        if op == "advance":
            n = data.draw(st.integers(1, 4), label="n")
            if alloc.lengths[0] + n > reserve:
                with pytest.raises(ValueError, match="exceeds the admission reserve"):
                    alloc.advance(0, n)
            else:
                alloc.advance(0, n)
        elif op == "mark":
            k = data.draw(st.integers(1, 6), label="k")
            upto = alloc.lengths[0] + k
            if upto > reserve:
                with pytest.raises(ValueError, match="exceeds the admission reserve"):
                    alloc.mark_written(0, upto)
            else:
                alloc.mark_written(0, upto)
        else:
            rolled_expect += alloc.written[0] - alloc.lengths[0]
            alloc.rollback(0)
            assert alloc.written[0] == alloc.lengths[0]
        assert L <= alloc.lengths[0] <= alloc.written[0] <= reserve
    rolled_expect += alloc.written[0] - alloc.lengths[0]
    alloc.rollback(0)
    assert alloc.rolled_back_total == rolled_expect
    alloc.complete(0)
    assert 0 not in alloc.lengths and 0 not in alloc.written
    assert alloc.pool.n_used == 0


@settings(max_examples=50, deadline=None)
@given(data=st.data())
def test_reload_interleaving_allocator_invariants(data):
    """Live weight reloads interleaved with admits/ticks/completions at the
    allocator level: the pool invariants hold after every op, a weight swap's
    ``invalidate_prefix`` empties the cache WITHOUT touching pages still held
    by in-flight requests, no admit ever reuses a prefix page written under
    pre-swap weights (stale K/V), and the speculative draft pool -- sized one
    worst-case table per row -- never denies an admit the main pool granted."""
    from repro.launch.paging import BlockAllocator

    P = 4
    B = data.draw(st.integers(min_value=2, max_value=4), label="batch")
    MAX_TOTAL = 24
    max_pages = -(-MAX_TOTAL // P)
    n_pages = data.draw(st.integers(min_value=8, max_value=32), label="n_pages")
    alloc = BlockAllocator(n_pages, P, prefix_reuse=True)
    draft = BlockAllocator(B * max_pages + 1, P, prefix_reuse=False)
    live, dlive, reserve = {}, {}, {}
    page_epoch, epoch, rid = {}, 0, 0
    for _ in range(data.draw(st.integers(min_value=1, max_value=40),
                             label="n_ops")):
        op = data.draw(st.sampled_from(["admit", "tick", "complete", "reload"]),
                       label="op")
        if op == "admit" and len(live) < B:
            body = data.draw(st.lists(st.integers(0, 3), min_size=1,
                                      max_size=10), label="prompt")
            if data.draw(st.booleans(), label="stem?"):
                body = [1, 2, 3, 4, 1, 2, 3, 4] + body
            total = min(len(body) + data.draw(st.integers(1, 8),
                                              label="max_new"), MAX_TOTAL)
            if total <= len(body):
                total = len(body) + 1
            got = alloc.admit(rid, body, total)
            if got is not None:
                table, reuse_len = got
                n_reused = reuse_len // P
                for pid in table[:n_reused]:
                    # a prefix hit must come from pages admitted SINCE the
                    # last swap: stale K/V from old weights never serves
                    assert page_epoch[pid] == epoch, \
                        "stale prefix page reused across a weight swap"
                for pid in table[n_reused:]:
                    page_epoch[pid] = epoch
                live[rid] = table
                reserve[rid] = total
                dgot = draft.admit(rid, body, total)
                assert dgot is not None, \
                    "draft pool (one worst-case table per row) denied an admit"
                dlive[rid] = dgot[0]
            rid += 1
        elif op == "tick" and live:
            row = data.draw(st.sampled_from(sorted(live)), label="tick_row")
            if alloc.lengths[row] < reserve[row]:
                alloc.advance(row, 1)
                draft.advance(row, 1)
        elif op == "complete" and live:
            victim = data.draw(st.sampled_from(sorted(live)), label="complete")
            alloc.complete(victim)
            draft.complete(victim)
            for d in (live, dlive, reserve):
                del d[victim]
        elif op == "reload":
            # the engine swaps weights: prefix entries derived from the old
            # weights are dropped; holders keep their pages untouched
            n_held_before = alloc.pool.n_used
            alloc.invalidate_prefix()
            epoch += 1
            assert len(alloc.prefix) == 0
            assert alloc.pool.n_used == n_held_before  # in-flight unharmed
        _allocator_invariants(alloc, live)
        _allocator_invariants(draft, dlive)
    assert alloc.invalidations_total == epoch
    for r in sorted(live):
        alloc.complete(r)
        draft.complete(r)
        del live[r], dlive[r]
        _allocator_invariants(alloc, live)
        _allocator_invariants(draft, dlive)
    assert alloc.pool.n_used == 0 and draft.pool.n_used == 0
