"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev-only dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import projections as proj
from repro.core.vcycle import History, flops_to_reach

even = st.integers(min_value=1, max_value=64).map(lambda k: 2 * k)


@settings(max_examples=30, deadline=None)
@given(n=even, variant=st.sampled_from(["stack", "adj"]))
def test_width_inverse_properties(n, variant):
    m = proj.width_mats(n, variant)
    np.testing.assert_allclose(m.T_out @ m.F_out, np.eye(n // 2), atol=1e-10)
    np.testing.assert_allclose(m.F_in @ m.T_in, np.eye(n // 2), atol=1e-10)
    # D∘C projection is an idempotent averaging map (symmetric-neuron structure)
    P = m.F_out @ m.T_out  # [n, n]
    np.testing.assert_allclose(P @ P, P, atol=1e-10)


@settings(max_examples=30, deadline=None)
@given(L=st.integers(min_value=1, max_value=100), variant=st.sampled_from(["adj", "stack"]))
def test_depth_inverse_properties(L, variant):
    d = proj.depth_mats(L, variant)
    np.testing.assert_allclose(d.G @ d.R, np.eye(d.R.shape[1]), atol=1e-10)
    np.testing.assert_allclose((d.R @ d.G).sum(0), np.ones(L), atol=1e-10)


@settings(max_examples=20, deadline=None)
@given(n=even, c=st.integers(min_value=1, max_value=32),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_general_F_normalization(n, c, seed):
    """Paper §3.1: F_out may be ANY full-column-rank matrix; the derived
    T/F_in normalizations must still invert on the small side."""
    rng = np.random.default_rng(seed)
    F = rng.normal(size=(n, n // 2))
    # ensure strictly positive diagonal energy so colsums are non-degenerate
    F += np.vstack([np.eye(n // 2), np.eye(n // 2)])
    m = proj.derive_width(F)
    # value-scale stability: colsum normalization makes T_out F_out row sums finite
    assert np.all(np.isfinite(m.T_out)) and np.all(np.isfinite(m.T_in))


@settings(max_examples=20, deadline=None)
@given(alpha=st.floats(min_value=0.0, max_value=1.0),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_interpolation_convexity(alpha, seed):
    from repro.core.operators import interpolate

    rng = np.random.default_rng(seed)
    a = {"w": jnp.asarray(rng.normal(size=(6, 6)), jnp.float32)}
    b = {"w": jnp.asarray(rng.normal(size=(6, 6)), jnp.float32)}
    out = np.asarray(interpolate(a, b, float(alpha))["w"])
    lo = np.minimum(np.asarray(a["w"]), np.asarray(b["w"]))
    hi = np.maximum(np.asarray(a["w"]), np.asarray(b["w"]))
    assert (out >= lo - 1e-5).all() and (out <= hi + 1e-5).all()


@settings(max_examples=20, deadline=None)
@given(losses=st.lists(st.floats(min_value=0.1, max_value=10.0), min_size=6, max_size=40))
def test_flops_to_reach_monotone(losses):
    h = History()
    for i, l in enumerate(losses):
        h.log(float(i + 1), l, i, 0)
    _, sm = h.smoothed(5)
    t1 = flops_to_reach(h, float(min(sm)) + 1e-9)
    t2 = flops_to_reach(h, float(min(sm)) + 1.0)
    if t1 is not None and t2 is not None:
        assert t2 <= t1  # easier targets are reached no later


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       n=st.sampled_from([8, 16, 32]))
def test_cd_identity_random_tensors(seed, n):
    """C∘D == id on arbitrary tensors for any (axes, roles) combination."""
    from repro.core.operators import LevelMaps, _project_tree
    from repro.param import Spec

    rng = np.random.default_rng(seed)
    maps = LevelMaps(width={"embed": proj.width_mats(n, "stack"),
                            "mlp": proj.width_mats(2 * n, "adj")},
                     depth={"stage_0": proj.depth_mats(5, "adj")}).as_jnp()
    spec = Spec((5, n, 2 * n), ("layers", "embed", "mlp"), ("-", "in", "out"))
    small = jnp.asarray(rng.normal(size=(3, n // 2, n)), jnp.float32)
    specs = {"stage_0": {"w": spec}}
    de = _project_tree({"stage_0": {"w": small}}, specs, maps, "decoalesce", False)
    rt = _project_tree(de, specs, maps, "coalesce", False)
    np.testing.assert_allclose(np.asarray(rt["stage_0"]["w"]), np.asarray(small), atol=1e-5)
