"""Serving-loop tests, run against BOTH engines (slots oracle + paged KV):
the continuous-batching lifecycle (admit -> decode -> slot/pages free on
length budget -> re-prefill into the freed capacity), the oversized-prompt
guards, and the paged engine's extra contracts -- token-for-token greedy
equivalence with the slot oracle (prefix reuse on and off), page-pool
admission/exhaustion behavior, and zero leaked pages after a drain.

The decode-policy suite at the bottom pins the speculative contract: the
coalesced level-1 draft may be arbitrarily wrong (random weights, or a
sabotaged draft that disagrees on the first token of every round) and the
emitted stream must STILL be token-for-token identical to greedy decode,
with rejected positions rewound through the allocator's rollback protocol.

The mesh-sharded smoke at the bottom runs in a subprocess (2 forced host
devices): --mesh 1x2 paged decode must emit the unsharded engine's exact
stream, with the K/V page pools genuinely model-sharded, across a hot weight
swap."""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from helpers import tiny_dense, tiny_mla
from repro.config import MultiLevelConfig
from repro.configs import get_config
from repro.core import operators as ops
from repro.launch.serve import (EngineCore, PagedServer, Request, Server,
                                SpeculativePolicy, make_server)
from repro.models.api import build_model


@pytest.fixture(scope="module")
def server_cfg():
    return get_config("tinyllama-1.1b", smoke=True)


@pytest.fixture(params=["slots", "paged"])
def engine(request):
    return request.param


def _server(cfg, engine, batch, max_seq, **kw):
    return make_server(cfg, engine=engine, batch=batch, max_seq=max_seq,
                       page_size=8, **kw)


# ---------------------------------------------------------------------------
# lifecycle (both engines)


def test_continuous_batching_recycles_slots(server_cfg, engine):
    """More requests than slots: finished sequences must free their capacity
    and the next request must prefill into it (the core of continuous
    batching) -- identical contract for both engines."""
    srv = _server(server_cfg, engine, batch=2, max_seq=48)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, 100, size=int(rng.integers(4, 9))),
                    max_new=3) for i in range(5)]
    done = srv.run(reqs)
    assert sorted(r.rid for r in done) == [0, 1, 2, 3, 4]
    assert all(len(r.out) == 3 for r in done)  # length budget frees the slot
    assert srv.rejected == []
    assert all(a is None for a in srv.active)  # every slot recycled and freed
    # slot recycling really happened: 5 requests through 2 slots
    assert len(done) > srv.batch


def test_admit_rejects_oversized_prompt(server_cfg, engine):
    """len(prompt) > max_seq - 1 used to crash _splice with a negative pad (or
    silently drop cache writes once pos ran past max_seq); admit must refuse
    -- in both engines, with the same error contract."""
    srv = _server(server_cfg, engine, batch=2, max_seq=16)
    with pytest.raises(ValueError, match="cannot be admitted"):
        srv.admit(Request(rid=0, prompt=np.arange(16, dtype=np.int64), max_new=4))
    with pytest.raises(ValueError, match="cannot be admitted"):
        srv.admit(Request(rid=1, prompt=np.arange(40, dtype=np.int64), max_new=4))
    # boundary: max_seq - 1 tokens still fit (one decode step, then freed)
    assert srv.admit(Request(rid=2, prompt=np.arange(15, dtype=np.int64), max_new=4))


def test_run_drops_oversized_instead_of_wedging(server_cfg, engine):
    """An oversized request at the queue head must be routed to ``rejected``;
    the well-formed requests behind it must still complete."""
    srv = _server(server_cfg, engine, batch=2, max_seq=16)
    reqs = [Request(rid=0, prompt=np.arange(20, dtype=np.int64), max_new=2),
            Request(rid=1, prompt=np.arange(4, dtype=np.int64), max_new=2),
            Request(rid=2, prompt=np.arange(5, dtype=np.int64), max_new=2)]
    done = srv.run(reqs)
    assert [r.rid for r in srv.rejected] == [0]
    assert sorted(r.rid for r in done) == [1, 2]
    assert all(len(r.out) == 2 for r in done)


def test_pos_capped_at_last_cache_index(server_cfg, engine):
    """A sequence admitted near the budget edge frees after one token and its
    pos never exceeds max_seq - 1 (decode cache writes past that are silently
    dropped by jax's out-of-range .at[].set semantics)."""
    srv = _server(server_cfg, engine, batch=1, max_seq=12)
    done = srv.run([Request(rid=0, prompt=np.arange(11, dtype=np.int64),
                            max_new=50)])
    assert len(done) == 1 and len(done[0].out) >= 1
    assert int(srv.pos[0]) <= srv.max_seq - 1


# ---------------------------------------------------------------------------
# paged-vs-slots greedy equivalence (the acceptance oracle)


def _request_mix(vocab: int, seed: int = 1):
    """Mixed lengths + a shared-prefix cohort + one oversized prompt."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, vocab, size=20)
    reqs = [Request(rid=i, prompt=rng.integers(0, vocab, size=int(rng.integers(4, 14))),
                    max_new=6) for i in range(5)]
    for i in range(5, 8):
        tail = rng.integers(0, vocab, size=3 + i)
        reqs.append(Request(rid=i, prompt=np.concatenate([shared, tail]), max_new=6))
    reqs.append(Request(rid=99, prompt=rng.integers(0, vocab, size=64), max_new=4))
    return reqs


@pytest.mark.parametrize("prefix_reuse", [True, False])
def test_paged_matches_slots_token_for_token(prefix_reuse):
    """Same request list through both engines -> identical greedy outputs per
    request AND identical rejections, with prefix reuse on and off.  f32
    compute so bf16 argmax ties can't flake the comparison."""
    cfg = tiny_dense(compute_dtype="float32")
    results = {}
    for engine in ("slots", "paged"):
        srv = make_server(cfg, engine=engine, batch=3, max_seq=48, page_size=8,
                          prefix_reuse=prefix_reuse)
        done = srv.run(_request_mix(cfg.vocab_size))
        results[engine] = ({r.rid: r.out for r in done},
                           sorted(r.rid for r in srv.rejected))
    assert results["paged"][1] == results["slots"][1] == [99]
    assert results["paged"][0] == results["slots"][0]


def test_paged_matches_slots_mla():
    """Equivalence also holds for the MLA (compressed-latent) cache layout."""
    cfg = tiny_mla(compute_dtype="float32")
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=n) for n in (5, 9, 12)]
    results = {}
    for engine in ("slots", "paged"):
        srv = make_server(cfg, engine=engine, batch=2, max_seq=32, page_size=4)
        done = srv.run([Request(rid=i, prompt=p, max_new=4)
                        for i, p in enumerate(prompts)])
        results[engine] = {r.rid: r.out for r in done}
    assert results["paged"] == results["slots"]


def test_prefix_reuse_saves_prefill_and_stays_exact():
    """The shared-prefix cohort must actually skip prefill work (saved > 0)
    while still emitting the slot oracle's exact tokens (covered above); here
    we pin the accounting: saved tokens only with reuse on, and the computed
    count shrinks by exactly the saved amount."""
    cfg = tiny_dense(compute_dtype="float32")
    reqs = _request_mix(cfg.vocab_size)
    total_prompt = sum(len(r.prompt) for r in reqs if len(r.prompt) <= 47)
    on = make_server(cfg, engine="paged", batch=3, max_seq=48, page_size=8)
    on.run(_request_mix(cfg.vocab_size))
    off = make_server(cfg, engine="paged", batch=3, max_seq=48, page_size=8,
                      prefix_reuse=False)
    off.run(_request_mix(cfg.vocab_size))
    assert on.prefill_tokens_saved > 0
    assert off.prefill_tokens_saved == 0
    assert off.prefill_tokens_computed == total_prompt
    assert on.prefill_tokens_computed == total_prompt - on.prefill_tokens_saved


# ---------------------------------------------------------------------------
# page-pool admission behavior


def test_pool_exhaustion_queues_until_pages_free():
    """A pool too small for all requests at once must make later requests
    wait for completions (not crash, not reject), and still finish them all."""
    cfg = tiny_dense(compute_dtype="float32")
    rng = np.random.default_rng(7)
    # each request needs ceil(min(10+4, 32)/4) = 4 pages; pool holds 8 ->
    # at most 2 in flight though batch would allow 4
    srv = make_server(cfg, engine="paged", batch=4, max_seq=32, page_size=4,
                      n_pages=9, prefix_reuse=False)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=10),
                    max_new=4) for i in range(5)]
    done = srv.run(reqs)
    assert sorted(r.rid for r in done) == [0, 1, 2, 3, 4]
    assert srv.rejected == []
    assert srv.pages_in_use_peak <= 8
    assert srv.alloc.pool.n_used == 0  # every page returned


def test_never_admittable_block_table_rejected():
    """A prompt whose worst-case block table exceeds the whole pool can never
    admit and must be rejected up front (not wedge the queue)."""
    cfg = tiny_dense(compute_dtype="float32")
    srv = make_server(cfg, engine="paged", batch=2, max_seq=64, page_size=4,
                      n_pages=5)  # capacity 4 pages = 16 positions
    reqs = [Request(rid=0, prompt=np.arange(30, dtype=np.int64), max_new=8),
            Request(rid=1, prompt=np.arange(6, dtype=np.int64), max_new=4)]
    done = srv.run(reqs)
    assert [r.rid for r in srv.rejected] == [0]
    assert [r.rid for r in done] == [1]


def test_pool_fully_free_after_drain():
    cfg = tiny_dense(compute_dtype="float32")
    srv = make_server(cfg, engine="paged", batch=3, max_seq=48, page_size=8)
    srv.run(_request_mix(cfg.vocab_size))
    assert srv.alloc.pool.n_used == 0
    assert srv.pages_in_use_peak > 0
    assert len(srv.alloc.live) == 0
    # prefix cache must not outlive its pages
    assert srv.alloc.prefix is None or len(srv.alloc.prefix) == 0


def test_reset_reuses_compiled_steps():
    """reset() must clear request/pool state but keep the compiled steps
    usable (the bench warmup contract)."""
    cfg = tiny_dense(compute_dtype="float32")
    srv = make_server(cfg, engine="paged", batch=2, max_seq=32, page_size=8)
    first = srv.run([Request(rid=0, prompt=np.arange(6, dtype=np.int64), max_new=3)])
    out0 = list(first[0].out)
    srv.reset()
    assert srv.done == [] and srv.alloc.pool.n_used == 0
    again = srv.run([Request(rid=1, prompt=np.arange(6, dtype=np.int64), max_new=3)])
    assert again[0].out == out0  # same prompt, same params -> same tokens


# ---------------------------------------------------------------------------
# decode policies: scheduler/policy split + speculative losslessness


def test_engines_share_scheduler_core():
    """The refactor's structural contract: admission, the run loop, token
    commit and reset live on ``EngineCore`` ONCE -- neither engine overrides
    them (engines only customize placement/retirement/decode hooks)."""
    for meth in ("fits", "admit", "run", "reset", "commit", "step", "set_params"):
        assert getattr(Server, meth) is getattr(EngineCore, meth)
        assert getattr(PagedServer, meth) is getattr(EngineCore, meth)


def test_make_server_rejects_unknown_engine_and_policy():
    cfg = tiny_dense(compute_dtype="float32")
    with pytest.raises(ValueError, match="unknown engine"):
        make_server(cfg, engine="vllm")
    with pytest.raises(ValueError, match="unknown policy"):
        make_server(cfg, engine="paged", policy="beam")
    with pytest.raises(TypeError, match="policy must be"):
        make_server(cfg, engine="paged", policy=42)
    with pytest.raises(NotImplementedError, match="paged engine"):
        make_server(cfg, engine="slots", policy="speculative")


def _greedy_oracle(cfg, reqs, **kw):
    srv = make_server(cfg, engine="paged", policy="greedy", **kw)
    done = srv.run(reqs)
    return {r.rid: r.out for r in done}


@pytest.mark.parametrize("prefix_reuse", [True, False])
def test_speculative_matches_greedy_token_for_token(prefix_reuse):
    """Random-init weights: the coalesced draft is essentially an unrelated
    model (accept rate ~0), the hardest losslessness stress -- every emitted
    token must still be the full model's argmax, so the stream is identical
    to greedy decode and to the slots oracle.  Rollback fires constantly and
    the pool must still drain clean."""
    cfg = tiny_dense(compute_dtype="float32")
    kw = dict(batch=3, max_seq=48, page_size=8, prefix_reuse=prefix_reuse)
    greedy = _greedy_oracle(cfg, _request_mix(cfg.vocab_size), **kw)
    srv = make_server(cfg, engine="paged", policy="speculative", draft_k=3, **kw)
    done = srv.run(_request_mix(cfg.vocab_size))
    assert {r.rid: r.out for r in done} == greedy
    st = srv.stats()
    assert st["drafted_tokens"] > 0
    assert st["rolled_back_positions"] > 0  # rejections actually rolled back
    assert srv.alloc.pool.n_used == 0  # drained clean despite rollbacks
    if prefix_reuse:
        assert srv.prefill_tokens_saved > 0  # reuse intact under speculation


def test_speculative_matches_greedy_mla():
    """Losslessness holds for the MLA (compressed-latent) paged layout too."""
    cfg = tiny_mla(compute_dtype="float32")
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=n) for n in (5, 9, 12)]
    reqs = lambda: [Request(rid=i, prompt=p, max_new=5)
                    for i, p in enumerate(prompts)]
    kw = dict(batch=2, max_seq=32, page_size=4)
    greedy = _greedy_oracle(cfg, reqs(), **kw)
    srv = make_server(cfg, engine="paged", policy="speculative", draft_k=3, **kw)
    assert {r.rid: r.out for r in srv.run(reqs())} == greedy


def _width_consistent_params(cfg, ml):
    """decoalesce(width-only)(level-1 init): serving weights whose coalesced
    draft is function-identical to the full model (tests/test_operators.py
    pins the exact preservation)."""
    model = build_model(cfg)
    small_cfg = ops.coalesce_config(cfg, ml, width=True, depth=False)
    p_small = build_model(small_cfg).init(jax.random.PRNGKey(3))
    return ops.make_decoalesce_fn(model.specs(), cfg, ml,
                                  width=True, depth=False)(p_small)


def test_speculative_full_accept_on_consistent_params():
    """Projection-consistent weights via ``set_params`` (the hot-reload +
    draft-refresh path): the width-only draft agrees with the full model, so
    near-all drafted tokens are accepted, nothing rolls back, and the stream
    still matches greedy on the same weights."""
    cfg = tiny_dense(compute_dtype="float32", qk_norm=False, tie_embeddings=False)
    ml = MultiLevelConfig()
    p = _width_consistent_params(cfg, ml)
    rng = np.random.default_rng(11)
    reqs = lambda: [Request(rid=i, prompt=rng2, max_new=8)
                    for i, rng2 in enumerate(
                        rng.integers(0, cfg.vocab_size, size=(4, 7)))]
    fixed = reqs()
    kw = dict(batch=2, max_seq=48, page_size=8)
    gsrv = make_server(cfg, engine="paged", **kw)
    gsrv.set_params(p)
    greedy = {r.rid: r.out for r in gsrv.run([Request(r.rid, r.prompt, r.max_new)
                                              for r in fixed])}
    pol = SpeculativePolicy(k=4, ml=ml, draft_width=True, draft_depth=False)
    srv = make_server(cfg, engine="paged", policy=pol, **kw)
    srv.set_params(p)  # must re-project the draft (on_params), or accept ~0
    done = srv.run([Request(r.rid, r.prompt, r.max_new) for r in fixed])
    assert {r.rid: r.out for r in done} == greedy
    st = srv.stats()
    assert st["accept_rate"] > 0.9
    assert st["accepted_tokens"] > 0


def test_speculative_forced_rejection_rolls_back():
    """Sabotage the draft so it disagrees with the full model on the FIRST
    drafted token of every round (consistent weights make the honest draft
    argmax equal the full model's; +1 mod vocab then guarantees mismatch).
    Every round must reject at token 1, rewind its drafted positions through
    ``BlockAllocator.rollback``, and still emit the exact greedy stream."""
    cfg = tiny_dense(compute_dtype="float32", qk_norm=False, tie_embeddings=False)
    ml = MultiLevelConfig()
    p = _width_consistent_params(cfg, ml)
    rng = np.random.default_rng(13)
    prompts = rng.integers(0, cfg.vocab_size, size=(3, 6))
    reqs = lambda: [Request(rid=i, prompt=pr, max_new=6)
                    for i, pr in enumerate(prompts)]
    kw = dict(batch=2, max_seq=32, page_size=8)
    gsrv = make_server(cfg, engine="paged", **kw)
    gsrv.set_params(p)
    greedy = {r.rid: r.out for r in gsrv.run(reqs())}
    pol = SpeculativePolicy(k=3, ml=ml, draft_width=True, draft_depth=False)
    honest = pol._draft_argmax
    pol._draft_argmax = lambda logits: (honest(logits) + 1) % cfg.vocab_size
    srv = make_server(cfg, engine="paged", policy=pol, **kw)
    srv.set_params(p)
    done = srv.run(reqs())
    assert {r.rid: r.out for r in done} == greedy  # lossless under 100% rejection
    st = srv.stats()
    assert st["drafted_tokens"] > 0
    assert st["accept_rate"] <= 0.05  # near-ties may flake a single argmax
    assert srv.alloc.rolled_back_total > 0
    assert srv.alloc.pool.n_used == 0


def test_speculative_reset_and_reuse():
    """reset() must rebuild the draft pool/allocator alongside the main one
    and keep the compiled draft/verify steps usable (bench warmup contract)."""
    cfg = tiny_dense(compute_dtype="float32")
    srv = make_server(cfg, engine="paged", policy="speculative", draft_k=2,
                      batch=2, max_seq=32, page_size=8)
    first = srv.run([Request(rid=0, prompt=np.arange(6, dtype=np.int64), max_new=3)])
    out0 = list(first[0].out)
    srv.reset()
    assert srv.stats()["spec_rounds"] == 0  # policy stats cleared too
    again = srv.run([Request(rid=1, prompt=np.arange(6, dtype=np.int64), max_new=3)])
    assert again[0].out == out0


# ---------------------------------------------------------------------------
# mesh-sharded paged decode


def test_make_server_rejects_mesh_on_slots_engine():
    cfg = tiny_dense(compute_dtype="float32")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="paged engine"):
        make_server(cfg, engine="slots", mesh=mesh)


@pytest.mark.slow
def test_mesh_sharded_paged_decode_matches_unsharded():
    """--mesh 1x2 smoke: the model-sharded paged decode step emits the
    unsharded engine's EXACT greedy stream (f32), the K/V page pools really
    are sharded over the "model" axis (not silently replicated), and a hot
    weight swap on the mesh server stays stream-identical.  Runs in a
    subprocess with 2 forced host devices (this process must keep its single
    real CPU device)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import jax
        import numpy as np
        from helpers import tiny_dense
        from repro.launch.serve import Request, make_server
        from repro.models.api import build_model

        cfg = tiny_dense(compute_dtype="float32")
        rng = np.random.default_rng(1)
        shared = rng.integers(0, cfg.vocab_size, size=16)
        prompts = [rng.integers(0, cfg.vocab_size, size=int(n))
                   for n in rng.integers(4, 14, size=4)]
        prompts += [np.concatenate([shared,
                                    rng.integers(0, cfg.vocab_size, size=3 + i)])
                    for i in range(2)]
        reqs = lambda base: [Request(rid=base + i, prompt=p, max_new=6)
                             for i, p in enumerate(prompts)]

        kw = dict(engine="paged", batch=3, max_seq=48, page_size=8)
        ref = make_server(cfg, **kw)
        mesh = jax.make_mesh((1, 2), ("data", "model"))
        srv = make_server(cfg, mesh=mesh, **kw)

        # the page pools are genuinely model-sharded, not replicated
        specs = {str(leaf.sharding.spec) for leaf in jax.tree.leaves(srv.pages)}
        assert any("model" in s for s in specs), specs

        a = {r.rid: r.out for r in ref.run(reqs(0))}
        b = {r.rid: r.out for r in srv.run(reqs(0))}
        assert a == b, "sharded decode diverged from unsharded"

        # hot weight swap on the mesh server: still stream-identical
        p_new = build_model(cfg).init(jax.random.PRNGKey(42))
        ref.set_params(p_new)
        srv.set_params(p_new)
        a2 = {r.rid: r.out for r in ref.run(reqs(100))}
        b2 = {r.rid: r.out for r in srv.run(reqs(100))}
        assert {k: v for k, v in a2.items() if k >= 100} \\
            == {k: v for k, v in b2.items() if k >= 100}
        assert srv.params is not p_new  # re-placed onto the mesh sharding
        print("SHARDED_SERVE_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src" + os.pathsep + "tests")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env,
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARDED_SERVE_OK" in out.stdout
