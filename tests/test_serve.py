"""Serving-loop tests: the continuous-batching lifecycle (admit -> decode ->
slot frees on length budget -> re-prefill into the freed slot) and the
oversized-prompt guards -- serving previously had zero dedicated tests."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.serve import Request, Server


@pytest.fixture(scope="module")
def server_cfg():
    return get_config("tinyllama-1.1b", smoke=True)


def test_continuous_batching_recycles_slots(server_cfg):
    """More requests than slots: finished sequences must free their slot and
    the next request must prefill into it (the core of continuous batching)."""
    srv = Server(server_cfg, batch=2, max_seq=48)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, 100, size=int(rng.integers(4, 9))),
                    max_new=3) for i in range(5)]
    done = srv.run(reqs)
    assert sorted(r.rid for r in done) == [0, 1, 2, 3, 4]
    assert all(len(r.out) == 3 for r in done)  # length budget frees the slot
    assert srv.rejected == []
    assert all(a is None for a in srv.active)  # every slot recycled and freed
    # slot recycling really happened: 5 requests through 2 slots
    assert len(done) > srv.batch


def test_admit_rejects_oversized_prompt(server_cfg):
    """len(prompt) > max_seq - 1 used to crash _splice with a negative pad (or
    silently drop cache writes once pos ran past max_seq); admit must refuse."""
    srv = Server(server_cfg, batch=2, max_seq=16)
    with pytest.raises(ValueError, match="cannot be admitted"):
        srv.admit(Request(rid=0, prompt=np.arange(16, dtype=np.int64), max_new=4))
    with pytest.raises(ValueError, match="cannot be admitted"):
        srv.admit(Request(rid=1, prompt=np.arange(40, dtype=np.int64), max_new=4))
    # boundary: max_seq - 1 tokens still fit (one decode step, then freed)
    assert srv.admit(Request(rid=2, prompt=np.arange(15, dtype=np.int64), max_new=4))


def test_run_drops_oversized_instead_of_wedging(server_cfg):
    """An oversized request at the queue head must be routed to ``rejected``;
    the well-formed requests behind it must still complete."""
    srv = Server(server_cfg, batch=2, max_seq=16)
    reqs = [Request(rid=0, prompt=np.arange(20, dtype=np.int64), max_new=2),
            Request(rid=1, prompt=np.arange(4, dtype=np.int64), max_new=2),
            Request(rid=2, prompt=np.arange(5, dtype=np.int64), max_new=2)]
    done = srv.run(reqs)
    assert [r.rid for r in srv.rejected] == [0]
    assert sorted(r.rid for r in done) == [1, 2]
    assert all(len(r.out) == 2 for r in done)


def test_pos_capped_at_last_cache_index(server_cfg):
    """A sequence admitted near the budget edge frees after one token and its
    pos never exceeds max_seq - 1 (decode cache writes past that are silently
    dropped by jax's out-of-range .at[].set semantics)."""
    srv = Server(server_cfg, batch=1, max_seq=12)
    done = srv.run([Request(rid=0, prompt=np.arange(11, dtype=np.int64),
                            max_new=50)])
    assert len(done) == 1 and len(done[0].out) >= 1
    assert int(srv.pos[0]) <= srv.max_seq - 1
