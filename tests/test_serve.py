"""Serving-loop tests, run against BOTH engines (slots oracle + paged KV):
the continuous-batching lifecycle (admit -> decode -> slot/pages free on
length budget -> re-prefill into the freed capacity), the oversized-prompt
guards, and the paged engine's extra contracts -- token-for-token greedy
equivalence with the slot oracle (prefix reuse on and off), page-pool
admission/exhaustion behavior, and zero leaked pages after a drain."""
import numpy as np
import pytest

from helpers import tiny_dense, tiny_mla
from repro.configs import get_config
from repro.launch.serve import PagedServer, Request, Server, make_server


@pytest.fixture(scope="module")
def server_cfg():
    return get_config("tinyllama-1.1b", smoke=True)


@pytest.fixture(params=["slots", "paged"])
def engine(request):
    return request.param


def _server(cfg, engine, batch, max_seq, **kw):
    return make_server(cfg, engine=engine, batch=batch, max_seq=max_seq,
                       page_size=8, **kw)


# ---------------------------------------------------------------------------
# lifecycle (both engines)


def test_continuous_batching_recycles_slots(server_cfg, engine):
    """More requests than slots: finished sequences must free their capacity
    and the next request must prefill into it (the core of continuous
    batching) -- identical contract for both engines."""
    srv = _server(server_cfg, engine, batch=2, max_seq=48)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, 100, size=int(rng.integers(4, 9))),
                    max_new=3) for i in range(5)]
    done = srv.run(reqs)
    assert sorted(r.rid for r in done) == [0, 1, 2, 3, 4]
    assert all(len(r.out) == 3 for r in done)  # length budget frees the slot
    assert srv.rejected == []
    assert all(a is None for a in srv.active)  # every slot recycled and freed
    # slot recycling really happened: 5 requests through 2 slots
    assert len(done) > srv.batch


def test_admit_rejects_oversized_prompt(server_cfg, engine):
    """len(prompt) > max_seq - 1 used to crash _splice with a negative pad (or
    silently drop cache writes once pos ran past max_seq); admit must refuse
    -- in both engines, with the same error contract."""
    srv = _server(server_cfg, engine, batch=2, max_seq=16)
    with pytest.raises(ValueError, match="cannot be admitted"):
        srv.admit(Request(rid=0, prompt=np.arange(16, dtype=np.int64), max_new=4))
    with pytest.raises(ValueError, match="cannot be admitted"):
        srv.admit(Request(rid=1, prompt=np.arange(40, dtype=np.int64), max_new=4))
    # boundary: max_seq - 1 tokens still fit (one decode step, then freed)
    assert srv.admit(Request(rid=2, prompt=np.arange(15, dtype=np.int64), max_new=4))


def test_run_drops_oversized_instead_of_wedging(server_cfg, engine):
    """An oversized request at the queue head must be routed to ``rejected``;
    the well-formed requests behind it must still complete."""
    srv = _server(server_cfg, engine, batch=2, max_seq=16)
    reqs = [Request(rid=0, prompt=np.arange(20, dtype=np.int64), max_new=2),
            Request(rid=1, prompt=np.arange(4, dtype=np.int64), max_new=2),
            Request(rid=2, prompt=np.arange(5, dtype=np.int64), max_new=2)]
    done = srv.run(reqs)
    assert [r.rid for r in srv.rejected] == [0]
    assert sorted(r.rid for r in done) == [1, 2]
    assert all(len(r.out) == 2 for r in done)


def test_pos_capped_at_last_cache_index(server_cfg, engine):
    """A sequence admitted near the budget edge frees after one token and its
    pos never exceeds max_seq - 1 (decode cache writes past that are silently
    dropped by jax's out-of-range .at[].set semantics)."""
    srv = _server(server_cfg, engine, batch=1, max_seq=12)
    done = srv.run([Request(rid=0, prompt=np.arange(11, dtype=np.int64),
                            max_new=50)])
    assert len(done) == 1 and len(done[0].out) >= 1
    assert int(srv.pos[0]) <= srv.max_seq - 1


# ---------------------------------------------------------------------------
# paged-vs-slots greedy equivalence (the acceptance oracle)


def _request_mix(vocab: int, seed: int = 1):
    """Mixed lengths + a shared-prefix cohort + one oversized prompt."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, vocab, size=20)
    reqs = [Request(rid=i, prompt=rng.integers(0, vocab, size=int(rng.integers(4, 14))),
                    max_new=6) for i in range(5)]
    for i in range(5, 8):
        tail = rng.integers(0, vocab, size=3 + i)
        reqs.append(Request(rid=i, prompt=np.concatenate([shared, tail]), max_new=6))
    reqs.append(Request(rid=99, prompt=rng.integers(0, vocab, size=64), max_new=4))
    return reqs


@pytest.mark.parametrize("prefix_reuse", [True, False])
def test_paged_matches_slots_token_for_token(prefix_reuse):
    """Same request list through both engines -> identical greedy outputs per
    request AND identical rejections, with prefix reuse on and off.  f32
    compute so bf16 argmax ties can't flake the comparison."""
    cfg = tiny_dense(compute_dtype="float32")
    results = {}
    for engine in ("slots", "paged"):
        srv = make_server(cfg, engine=engine, batch=3, max_seq=48, page_size=8,
                          prefix_reuse=prefix_reuse)
        done = srv.run(_request_mix(cfg.vocab_size))
        results[engine] = ({r.rid: r.out for r in done},
                           sorted(r.rid for r in srv.rejected))
    assert results["paged"][1] == results["slots"][1] == [99]
    assert results["paged"][0] == results["slots"][0]


def test_paged_matches_slots_mla():
    """Equivalence also holds for the MLA (compressed-latent) cache layout."""
    cfg = tiny_mla(compute_dtype="float32")
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=n) for n in (5, 9, 12)]
    results = {}
    for engine in ("slots", "paged"):
        srv = make_server(cfg, engine=engine, batch=2, max_seq=32, page_size=4)
        done = srv.run([Request(rid=i, prompt=p, max_new=4)
                        for i, p in enumerate(prompts)])
        results[engine] = {r.rid: r.out for r in done}
    assert results["paged"] == results["slots"]


def test_prefix_reuse_saves_prefill_and_stays_exact():
    """The shared-prefix cohort must actually skip prefill work (saved > 0)
    while still emitting the slot oracle's exact tokens (covered above); here
    we pin the accounting: saved tokens only with reuse on, and the computed
    count shrinks by exactly the saved amount."""
    cfg = tiny_dense(compute_dtype="float32")
    reqs = _request_mix(cfg.vocab_size)
    total_prompt = sum(len(r.prompt) for r in reqs if len(r.prompt) <= 47)
    on = make_server(cfg, engine="paged", batch=3, max_seq=48, page_size=8)
    on.run(_request_mix(cfg.vocab_size))
    off = make_server(cfg, engine="paged", batch=3, max_seq=48, page_size=8,
                      prefix_reuse=False)
    off.run(_request_mix(cfg.vocab_size))
    assert on.prefill_tokens_saved > 0
    assert off.prefill_tokens_saved == 0
    assert off.prefill_tokens_computed == total_prompt
    assert on.prefill_tokens_computed == total_prompt - on.prefill_tokens_saved


# ---------------------------------------------------------------------------
# page-pool admission behavior


def test_pool_exhaustion_queues_until_pages_free():
    """A pool too small for all requests at once must make later requests
    wait for completions (not crash, not reject), and still finish them all."""
    cfg = tiny_dense(compute_dtype="float32")
    rng = np.random.default_rng(7)
    # each request needs ceil(min(10+4, 32)/4) = 4 pages; pool holds 8 ->
    # at most 2 in flight though batch would allow 4
    srv = make_server(cfg, engine="paged", batch=4, max_seq=32, page_size=4,
                      n_pages=9, prefix_reuse=False)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=10),
                    max_new=4) for i in range(5)]
    done = srv.run(reqs)
    assert sorted(r.rid for r in done) == [0, 1, 2, 3, 4]
    assert srv.rejected == []
    assert srv.pages_in_use_peak <= 8
    assert srv.alloc.pool.n_used == 0  # every page returned


def test_never_admittable_block_table_rejected():
    """A prompt whose worst-case block table exceeds the whole pool can never
    admit and must be rejected up front (not wedge the queue)."""
    cfg = tiny_dense(compute_dtype="float32")
    srv = make_server(cfg, engine="paged", batch=2, max_seq=64, page_size=4,
                      n_pages=5)  # capacity 4 pages = 16 positions
    reqs = [Request(rid=0, prompt=np.arange(30, dtype=np.int64), max_new=8),
            Request(rid=1, prompt=np.arange(6, dtype=np.int64), max_new=4)]
    done = srv.run(reqs)
    assert [r.rid for r in srv.rejected] == [0]
    assert [r.rid for r in done] == [1]


def test_pool_fully_free_after_drain():
    cfg = tiny_dense(compute_dtype="float32")
    srv = make_server(cfg, engine="paged", batch=3, max_seq=48, page_size=8)
    srv.run(_request_mix(cfg.vocab_size))
    assert srv.alloc.pool.n_used == 0
    assert srv.pages_in_use_peak > 0
    assert len(srv.alloc.live) == 0
    # prefix cache must not outlive its pages
    assert srv.alloc.prefix is None or len(srv.alloc.prefix) == 0


def test_reset_reuses_compiled_steps():
    """reset() must clear request/pool state but keep the compiled steps
    usable (the bench warmup contract)."""
    cfg = tiny_dense(compute_dtype="float32")
    srv = make_server(cfg, engine="paged", batch=2, max_seq=32, page_size=8)
    first = srv.run([Request(rid=0, prompt=np.arange(6, dtype=np.int64), max_new=3)])
    out0 = list(first[0].out)
    srv.reset()
    assert srv.done == [] and srv.alloc.pool.n_used == 0
    again = srv.run([Request(rid=1, prompt=np.arange(6, dtype=np.int64), max_new=3)])
    assert again[0].out == out0  # same prompt, same params -> same tokens
