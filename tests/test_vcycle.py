"""Integration: Algorithm 1 end-to-end, baselines, savings metric."""
import jax
import numpy as np
import pytest

from helpers import fast_tc, tiny_dense
from repro.config import MultiLevelConfig
from repro.core.vcycle import History, flops_to_reach, run_scratch, run_vcycle, saving_vs_baseline
from repro.data import MarkovLM, lm_batch


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_dense(d_model=32, d_ff=64, vocab_size=128)
    tc = fast_tc(steps=30, batch_size=4, seq_len=16, log_every=2, peak_lr=3e-3)
    chain = MarkovLM(128)
    bf = lambda step: lm_batch(chain, 0, step, tc.batch_size, tc.seq_len)
    return cfg, tc, bf


def test_vcycle_runs_and_loss_decreases(setup):
    cfg, tc, bf = setup
    ml = MultiLevelConfig(n_levels=2, alpha=0.25, e_a_frac=0.1, e_small_frac=0.5)
    out = run_vcycle(cfg, ml, tc, bf, seed=0)
    assert len(out.configs) == 2
    assert out.configs[1].d_model == cfg.d_model // 2
    assert out.history.loss[-1] < out.history.loss[0]
    assert out.total_flops > 0
    # level trace covers both levels
    assert set(out.history.level) == {0, 1}
    # small-model steps are cheaper per step (fewer FLOPs per history interval)
    fl = np.asarray(out.history.flops)
    lv = np.asarray(out.history.level)
    d_small = np.diff(fl)[lv[1:] == 1].mean()
    d_large = np.diff(fl)[lv[1:] == 0].mean()
    assert d_small < d_large / 4  # ~8x param reduction -> >>4x cheaper


def test_three_level_vcycle(setup):
    cfg, tc, bf = setup
    ml = MultiLevelConfig(n_levels=3, alpha=0.25, e_a_frac=0.1, e_small_frac=0.3)
    out = run_vcycle(cfg, ml, tc, bf, seed=0, final_steps=10)
    assert len(out.configs) == 3
    assert out.configs[2].d_model == cfg.d_model // 4
    assert np.isfinite(out.history.loss[-1])


def test_target_loss_window_is_segment_local(setup):
    """Regression: the target-loss early stop must smooth over the CURRENT
    segment's entries only.  Stale losses logged by the previous (smaller)
    level used to leak into the 5-wide window at the level boundary and could
    fire a spurious exit on the final segment's first log step."""
    from repro.core.vcycle import train_segment
    from repro.models.api import build_model

    cfg, _, bf = setup
    model = build_model(cfg)
    hist = History()
    for k in range(5):  # previous level's trace: absurdly low losses
        hist.log(float(k), -100.0, k, 1)
    tc2 = fast_tc(steps=6, batch_size=4, seq_len=16, log_every=1, peak_lr=3e-3)
    # real losses are positive, so target 0.0 is unreachable this segment --
    # only the poisoned history could trip the stop
    _, _, hist, _, g = train_segment(model, tc2, bf, tc2.steps, history=hist,
                                     start_step=5, target_loss=0.0)
    assert g == 5 + tc2.steps, "early stop fired from the previous level's losses"
    assert len(hist.loss) == 5 + tc2.steps


def test_target_loss_window_survives_resume(setup):
    """The segment-local window must be recovered from history.step, not from
    the loop entry point: a mid-segment resume has this segment's pre-crash
    entries already in the history, and excluding them would make the early
    stop diverge from an uninterrupted run."""
    import jax

    from repro.core.vcycle import _train_loop
    from repro.models.api import build_model, init_train_state, make_train_step
    from repro.optim import adamw_init

    cfg, _, bf = setup
    model = build_model(cfg)
    tc2 = fast_tc(steps=6, batch_size=4, seq_len=16, log_every=1, peak_lr=3e-3)
    params, opt = init_train_state(model, tc2, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(model, tc2))
    hist = History()
    hist.log(0.0, -100.0, 3, 1)  # previous segment (g <= 5): excluded
    for k in range(3):           # this segment's pre-crash entries (g=6..8)
        hist.log(float(k), -100.0, 6 + k, 0)
    # resume at seg_step=3 (segment started at g=5); after one more step the
    # window [-100,-100,-100,loss] stays <= 0 -> must stop immediately
    _, _, _, g = _train_loop(step_fn, bf, tc2.steps, 3, params, opt, hist,
                             0.0, 8, 0, 1.0, tc2.log_every, target_loss=0.0)
    assert g == 9, "resume dropped this segment's pre-crash window entries"


def test_savings_metric(setup):
    cfg, tc, bf = setup
    _, base = run_scratch(cfg, tc, bf, seed=0)
    s = saving_vs_baseline(base, base)
    assert abs(s["flops_saving"]) < 1e-6  # identical run saves nothing


@pytest.mark.parametrize("name", ["stackbert", "bert2bert", "network_expansion"])
def test_growth_baselines_run(setup, name):
    from repro.core.baselines import BASELINES

    cfg, tc, bf = setup
    ml = MultiLevelConfig(n_levels=2)
    hist = BASELINES[name](cfg, ml, tc, bf, small_steps=6, final_steps=6)
    assert len(hist.loss) > 0 and np.isfinite(hist.loss[-1])


def test_ligo_and_ki_run(setup):
    from repro.core.baselines import run_ki, run_ligo

    cfg, tc, bf = setup
    ml = MultiLevelConfig(n_levels=2)
    h1 = run_ligo(cfg, ml, tc, bf, small_steps=4, final_steps=4, fit_steps=3)
    h2 = run_ki(cfg, ml, tc, bf, small_steps=4, final_steps=4)
    assert np.isfinite(h1.loss[-1]) and np.isfinite(h2.loss[-1])
