"""Content-addressed checkpoint store (layout v3): measured dedup, refcount
GC under interleaved saves/restores/crashes, and pool/manifest unit behavior.

The headline test is the acceptance drill: THREE consecutive mid-upward-sweep
V-cycle checkpoints (live ``params_before_*`` stashes) written through the
same training run into a v3 store and a v2 store, asserting that unchanged
leaves cost ~zero bytes after the first save and that the v3 sequence lands
at less than half the v2 on-disk footprint.  Dedup is measured, not assumed.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import fast_tc, tiny_dense
from repro.checkpoint import CheckpointManager, ObjectStore, leaf_digest
from repro.checkpoint import store as store_lib
from repro.config import BlockSpec, MultiLevelConfig, uniform_stages
from repro.core.vcycle import VCycleRunner


def _du(path: str) -> int:
    total = 0
    for root, _, files in os.walk(path):
        for fn in files:
            total += os.path.getsize(os.path.join(root, fn))
    return total


def _step_manifest(ckdir: str, step: int):
    return store_lib.read_step_manifest(
        os.path.join(ckdir, f"step_{step:08d}"))


# ---------------------------------------------------------------------------
# pool + manifest units


def test_leaf_digest_separates_dtype_and_shape():
    z32 = np.zeros(4, np.float32)
    assert leaf_digest(z32) == leaf_digest(np.zeros(4, np.float32))
    # identical bytes, different dtype / shape must not collide
    assert leaf_digest(z32) != leaf_digest(z32.view(np.int32))
    assert leaf_digest(z32) != leaf_digest(z32.reshape(2, 2))
    assert leaf_digest(np.float32(1.0).reshape(())) != leaf_digest(
        np.float32(2.0).reshape(()))


def test_object_store_put_is_idempotent_and_measured(tmp_path):
    store = ObjectStore(str(tmp_path))
    arr = np.arange(32, dtype=np.float32)
    d = leaf_digest(arr)
    n = store.put(d, arr)
    assert n > 0 and store.has(d)
    assert store.put(d, arr) == 0  # content-addressed hit: no bytes written
    s = store.stats()
    assert s["objects_written"] == 1 and s["objects_reused"] == 1
    # hits are accounted at payload size (nbytes: the hit path skips the npy
    # encode entirely, so there is no file image to measure)
    assert s["bytes_written"] == n and s["bytes_reused"] == arr.nbytes
    np.testing.assert_array_equal(store.get(d), arr)
    assert list(store.digests()) == [d]
    store.delete(d)
    assert not store.has(d)
    store.delete(d)  # deleting a missing object is a no-op


def test_fetch_object_resolves_through_pool_order(tmp_path):
    own = ObjectStore(str(tmp_path / "own"))
    peer = ObjectStore(str(tmp_path / "peer"))
    arr = np.arange(6, dtype=np.int32)
    d = leaf_digest(arr)
    peer.put(d, arr)
    np.testing.assert_array_equal(store_lib.fetch_object(d, [own, peer]), arr)
    with pytest.raises(FileNotFoundError, match="not found in any pool"):
        store_lib.fetch_object("0" * 40, [own, peer])


def test_payload_digest_detects_corruption(tmp_path):
    """Transfer verification: a flipped byte in a serialized object must hash
    to a different digest (incl. for bfloat16, whose npy image is raw void
    bytes that only re-hash correctly with the manifest's dtype name)."""
    import ml_dtypes

    store = ObjectStore(str(tmp_path))
    for arr, dtype in ((np.arange(16, dtype=np.float32), "float32"),
                       (np.arange(8).astype(ml_dtypes.bfloat16), "bfloat16")):
        d = leaf_digest(arr)
        store.put(d, arr)
        payload = store.get_bytes(d)
        assert store_lib.payload_digest(payload, dtype) == d
        corrupt = bytearray(payload)
        corrupt[-1] ^= 0xFF
        assert store_lib.payload_digest(bytes(corrupt), dtype) != d


def test_merge_tree_entries_rejects_shape_disagreement():
    a = {"w": {"shape": [4], "dtype": "float32",
               "chunks": [{"digest": "x", "start": [0], "shape": [2]}]}}
    b = {"w": {"shape": [6], "dtype": "float32",
               "chunks": [{"digest": "y", "start": [2], "shape": [2]}]}}
    with pytest.raises(ValueError, match="disagrees"):
        store_lib.merge_tree_entries([a, b])
    merged = store_lib.merge_tree_entries(
        [a, {"w": {"shape": [4], "dtype": "float32",
                   "chunks": [{"digest": "y", "start": [2], "shape": [2]}]}}])
    assert [c["digest"] for c in merged["w"]["chunks"]] == ["x", "y"]


def test_assemble_tree_reassembles_chunks(tmp_path):
    store = ObjectStore(str(tmp_path))
    lo, hi = np.arange(6.0).reshape(2, 3), np.arange(6.0, 12.0).reshape(2, 3)
    dl, dh = leaf_digest(lo), leaf_digest(hi)
    store.put(dl, lo)
    store.put(dh, hi)
    entries = {"w": {"shape": [4, 3], "dtype": "float64",
                     "chunks": [{"digest": dl, "start": [0, 0], "shape": [2, 3]},
                                {"digest": dh, "start": [2, 0], "shape": [2, 3]}]}}
    out = store_lib.assemble_tree(entries, [store])
    np.testing.assert_array_equal(out["w"], np.arange(12.0).reshape(4, 3))


def test_v3_scalar_and_bfloat16_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    st = {"params": {"s": jnp.float32(4.0),
                     "bf": jnp.arange(6).astype(jnp.bfloat16) * 0.5,
                     "i": jnp.zeros((), jnp.int32)}}
    cm.save(1, st, meta={"step": 1})
    out, _ = cm.restore(jax.tree.map(jnp.zeros_like, st))
    assert out["params"]["bf"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(out["params"]["bf"]),
                                  np.asarray(st["params"]["bf"]))
    assert float(out["params"]["s"]) == 4.0


# ---------------------------------------------------------------------------
# the acceptance drill: measured dedup over consecutive V-cycle checkpoints


def test_vcycle_dedup_bytes_measured(tmp_path):
    """>=3 consecutive mid-upward-sweep checkpoints (live ``params_before_0``
    and ``params_before_1`` stashes): after the first save, unchanged leaves
    (the stashes) cost ~zero bytes, and the v3 sequence lands at <50% of the
    v2 on-disk footprint."""
    cfg = tiny_dense(n_kv_heads=4,
                     stages=uniform_stages(4, BlockSpec("attn", "dense")),
                     compute_dtype=jnp.float32)
    tc = fast_tc(steps=8, batch_size=2, seq_len=16)
    ml = MultiLevelConfig(n_levels=3, alpha=0.25, e_a_frac=0.25,
                          e_small_frac=0.5)
    from repro.launch.train import make_batch_fn, make_vcycle_save_cb

    d3, d2 = str(tmp_path / "v3"), str(tmp_path / "v2")
    cm3 = CheckpointManager(d3, keep_last=100, dedup=True)
    cm2 = CheckpointManager(d2, keep_last=100, dedup=False)
    runner = VCycleRunner(cfg, ml, tc, make_batch_fn(cfg, tc), seed=0)
    cb3 = make_vcycle_save_cb(cm3, schedule=runner.plan)
    cb2 = make_vcycle_save_cb(cm2, schedule=runner.plan)
    stats = {}

    class Enough(Exception):
        pass

    def cb(state, p, o):
        # three consecutive saves inside the level-2 upward-sweep segment
        # (global steps 5..8), where BOTH full-size stashes are live
        if 6 <= state.global_step <= 8:
            assert state.phase == "up" and sorted(state.params_before) == [0, 1]
            cb3(state, p, o, blocking=True)
            cb2(state, p, o, blocking=True)
            stats[state.global_step] = dict(cm3.last_save_stats)
            if state.global_step == 8:
                raise Enough

    with pytest.raises(Enough):
        runner.run(ckpt_cb=cb, ckpt_every=1)

    # the stashes were frozen across the three saves: their digests are
    # bit-identical in every manifest, i.e. written once, referenced thrice
    trees = {g: _step_manifest(d3, g) for g in (6, 7, 8)}
    stash_keys = [k for k in trees[6] if k.startswith("params_before_")]
    assert len(stash_keys) == 2
    stash_bytes = 0
    for key in stash_keys:
        for leaf, rec in trees[6][key].items():
            stash_bytes += int(np.prod(rec["shape"]) or 1) * np.dtype(
                rec["dtype"]).itemsize
            for g in (7, 8):
                assert trees[g][key][leaf]["chunks"][0]["digest"] == \
                    rec["chunks"][0]["digest"], (key, leaf)

    # measured, not assumed: after the first save the unchanged leaves cost
    # ~zero bytes -- everything re-written is the (much smaller) level-2
    # params/opt, so bytes_written collapses vs the stash payload
    for g in (7, 8):
        assert stats[g]["bytes_reused"] >= stash_bytes, stats
        assert stats[g]["bytes_written"] < 0.2 * stats[6]["bytes_written"], stats

    # >50% total on-disk reduction vs the v2 layout for the same sequence
    size3, size2 = _du(d3), _du(d2)
    assert size3 < 0.5 * size2, (size3, size2)

    # and the v3 sequence actually restores: newest step, bit-equal params
    like = {"params": jax.tree.map(jnp.zeros_like,
                                   runner.models[2].init(jax.random.PRNGKey(0)))}
    out3, meta3 = cm3.restore({"params": like["params"]})
    out2, meta2 = cm2.restore({"params": like["params"]})
    assert meta3["global_step"] == meta2["global_step"] == 8
    for a, b in zip(jax.tree.leaves(out3), jax.tree.leaves(out2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# GC stress: interleaved saves / restores / keep-last GC / simulated crash


def test_gc_stress_no_live_object_collected_orphans_reclaimed(tmp_path):
    frozen = np.linspace(0.0, 1.0, 64, dtype=np.float32)
    frozen_digest = leaf_digest(frozen)

    def state_at(i: int):
        return {"params": {"frozen": jnp.asarray(frozen),
                           "hot": jnp.full((32,), float(i), jnp.float32)}}

    cm = CheckpointManager(str(tmp_path), keep_last=2)
    like = jax.tree.map(jnp.zeros_like, state_at(0))

    def check_live_objects_exist():
        """Invariant: every digest referenced by any published step manifest
        is present in the pool (GC never collects a live object)."""
        for d in cm._step_dirs():
            trees = store_lib.read_step_manifest(os.path.join(str(tmp_path), d))
            assert trees is not None
            for dig in store_lib.manifest_digests(trees):
                assert cm.store.has(dig), (d, dig)

    orphans = set()
    last_published = 0
    for step in range(1, 11):
        if step == 4:
            # simulated crash BETWEEN object write and publish: objects land
            # in the pool, the step dir stays .tmp, the manifest never flips
            before = set(cm.store.digests())
            real_publish = cm._publish
            cm._publish = lambda *a, **k: (_ for _ in ()).throw(
                RuntimeError("simulated crash"))
            with pytest.raises(RuntimeError, match="simulated crash"):
                cm.save(step, state_at(step), meta={"step": step})
            cm._publish = real_publish
            orphans = set(cm.store.digests()) - before
            assert orphans  # the crashed save really did strand objects
            # the previous checkpoint is fully intact and restorable
            out, meta = cm.restore(like)
            assert meta["step"] == last_published
            continue
        cm.save(step, state_at(step), meta={"step": step},
                blocking=(step % 2 == 0))
        cm.wait()
        last_published = step
        check_live_objects_exist()
        # the shared frozen leaf survives every keep-last sweep
        assert cm.store.has(frozen_digest)
        if step % 3 == 0:
            out, meta = cm.restore(like)
            assert meta["step"] == step
            np.testing.assert_array_equal(
                np.asarray(out["params"]["hot"]), np.full((32,), float(step)))
            np.testing.assert_array_equal(
                np.asarray(out["params"]["frozen"]), frozen)

    # keep-last GC pruned old dirs AND their now-unreferenced objects...
    dirs = cm._step_dirs()
    assert dirs == ["step_00000009", "step_00000010"]
    live = set()
    for d in dirs:
        live.update(store_lib.manifest_digests(
            store_lib.read_step_manifest(os.path.join(str(tmp_path), d))))
    assert set(cm.store.digests()) == live  # nothing extra, nothing missing
    # ...and the crash's orphans were eventually reclaimed (unless the same
    # content was legitimately re-referenced later -- content addressing)
    for dig in orphans - live:
        assert not cm.store.has(dig)
    # no stale .tmp dir survives either
    assert not [d for d in os.listdir(str(tmp_path)) if d.endswith(".tmp")]


def test_v2_dirs_in_v3_root_stay_readable_and_unswept(tmp_path):
    """A root upgraded mid-history: an old v2 step dir coexists with v3 dirs;
    restore reads whichever the manifest references and refcount GC must not
    touch (or be confused by) the manifest-less v2 dir."""
    st = {"params": {"w": jnp.arange(4.0)}}
    cm_old = CheckpointManager(str(tmp_path), keep_last=5, dedup=False)
    cm_old.save(1, st, meta={"step": 1})
    cm_new = CheckpointManager(str(tmp_path), keep_last=5, dedup=True)
    out, meta = cm_new.restore(jax.tree.map(jnp.zeros_like, st))  # reads v2
    assert meta["step"] == 1
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.arange(4.0))
    cm_new.save(2, st, meta={"step": 2})
    out, meta = cm_new.restore(jax.tree.map(jnp.zeros_like, st))  # reads v3
    assert meta["step"] == 2
    # the v2 dir is still there and still readable
    assert os.path.isdir(tmp_path / "step_00000001")
    from repro.checkpoint import restore_tree

    old = restore_tree(str(tmp_path / "step_00000001" / "params"),
                       {"w": jnp.zeros(4)})
    np.testing.assert_array_equal(np.asarray(old["w"]), np.arange(4.0))
