"""Live weight reload: the train->serve hand-off.

Pins the two halves of the reload contract:

* **Engine side** (``EngineCore.request_reload`` / ``maybe_swap``): a staged
  swap defers to a drained tick boundary -- in-flight requests complete
  token-for-token under the weights they started on, post-swap admissions are
  stream-identical to a FRESH server booted on the new weights, and nothing
  is ever dropped.  Holds for both engines, both cache layouts (GQA + MLA),
  and for the speculative policy, whose coalesced draft must re-project from
  the swapped params.

* **Watcher side** (``ManifestWatcher``): new checkpoint steps land by
  per-leaf chunk-digest diff -- unchanged leaves ship zero bytes (pinned by
  object identity), coalesced mid-V-cycle shapes are skipped, non-v3 layouts
  fail loudly, and the no-shared-FS KV mode prunes the peer gather to the
  changed digests.

All comparisons are exact (f32 compute), same discipline as test_serve.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import run_multiprocess, tiny_dense, tiny_mla
from repro.checkpoint import CheckpointManager
from repro.checkpoint.manager import _flatten
from repro.config import MultiLevelConfig
from repro.core import operators as ops
from repro.launch.serve import (ManifestWatcher, Request, SpeculativePolicy,
                                make_server)
from repro.models.api import build_model


# ---------------------------------------------------------------------------
# engine side: deferred tick-boundary swap


def _reqs(cfg, rids, seed, max_new=4):
    rng = np.random.default_rng(seed)
    return [Request(rid=r, prompt=rng.integers(0, cfg.vocab_size,
                                               size=int(rng.integers(5, 12))),
                    max_new=max_new) for r in rids]


def _stream(srv, reqs):
    return {r.rid: r.out for r in srv.run(reqs)}


@pytest.mark.parametrize("cfg_fn", [tiny_dense, tiny_mla],
                         ids=["gqa", "mla"])
@pytest.mark.parametrize("engine", ["slots", "paged"])
def test_reload_equivalence(engine, cfg_fn):
    """The reload contract, both engines x both cache layouts: in-flight
    requests finish under the OLD weights, post-swap admissions match a fresh
    server on the NEW weights, admission is gated while a swap is staged, and
    the paged prefix cache is invalidated on swap."""
    cfg = cfg_fn(compute_dtype="float32")
    kw = dict(engine=engine, batch=2, max_seq=48, page_size=8)
    p_new = build_model(cfg).init(jax.random.PRNGKey(42))

    old_oracle = _stream(make_server(cfg, **kw), _reqs(cfg, [0, 1], seed=7))
    new_srv = make_server(cfg, **kw)
    new_srv.set_params(p_new)
    new_oracle = _stream(new_srv, _reqs(cfg, [10, 11], seed=8))

    srv = make_server(cfg, **kw)
    for r in _reqs(cfg, [0, 1], seed=7):
        assert srv.admit(r)
    srv.step()  # both rows mid-flight
    assert not srv.request_reload(p_new)  # rows active -> staged, not swapped
    assert srv.reload_pending()
    # admission is gated: a request admitted now would run on OLD weights
    assert not srv.admit(_reqs(cfg, [50], seed=9)[0])
    while any(r is not None for r in srv.active):
        srv.step()
    assert srv.reloads == 0  # drain alone does not swap mid-list
    srv.step()  # first drained tick boundary lands the swap
    assert srv.reloads == 1 and not srv.reload_pending()
    if engine == "paged":
        assert srv.alloc.invalidations_total == 1  # old-weight prefixes gone

    # in-flight requests completed token-for-token under the old weights
    assert {r.rid: r.out for r in srv.done} == old_oracle
    # post-swap admissions are stream-identical to the fresh-on-new oracle
    done = _stream(srv, _reqs(cfg, [10, 11], seed=8))
    assert {k: v for k, v in done.items() if k >= 10} == new_oracle


def test_reload_immediate_when_drained():
    """request_reload on an idle engine swaps synchronously (True) -- the
    startup path: attach a watcher, land the first checkpoint, serve."""
    cfg = tiny_dense(compute_dtype="float32")
    srv = make_server(cfg, engine="paged", batch=2, max_seq=32, page_size=8)
    p_new = build_model(cfg).init(jax.random.PRNGKey(1))
    assert srv.request_reload(p_new)
    assert srv.reloads == 1 and not srv.reload_pending()
    fresh = make_server(cfg, engine="paged", batch=2, max_seq=32, page_size=8)
    fresh.set_params(p_new)
    assert _stream(srv, _reqs(cfg, [0, 1], seed=3)) \
        == _stream(fresh, _reqs(cfg, [0, 1], seed=3))


def test_reload_restaging_keeps_newest():
    """Re-staging before the swap lands replaces the staged tree: only the
    NEWEST published weights ever swap in (a slow drain must not serve a
    checkpoint the trainer already superseded)."""
    cfg = tiny_dense(compute_dtype="float32")
    srv = make_server(cfg, engine="paged", batch=2, max_seq=32, page_size=8)
    p1 = build_model(cfg).init(jax.random.PRNGKey(1))
    p2 = build_model(cfg).init(jax.random.PRNGKey(2))
    assert srv.admit(_reqs(cfg, [0], seed=4)[0])
    assert not srv.request_reload(p1)
    assert not srv.request_reload(p2)  # supersedes p1 while still staged
    srv.run([])  # drain; trailing maybe_swap lands the staged tree
    assert srv.reloads == 1
    leaf = lambda t: jax.tree.leaves(t)[0]
    np.testing.assert_array_equal(np.asarray(leaf(srv.params)),
                                  np.asarray(leaf(p2)))


def test_reload_speculative_reprojects_draft():
    """Speculative serving across a reload: the coalesced draft is a pure
    function of the serving params, so the swap must re-project it
    (``SpeculativePolicy.on_params``).  Swapping in width-consistent weights
    proves it end-to-end: the post-swap accept rate is near-1 (a stale draft
    would sit at chance, ~1/vocab) and the stream still matches greedy."""
    cfg = tiny_dense(compute_dtype="float32", qk_norm=False,
                     tie_embeddings=False)
    ml = MultiLevelConfig()
    model = build_model(cfg)
    small_cfg = ops.coalesce_config(cfg, ml, width=True, depth=False)
    p_new = ops.make_decoalesce_fn(model.specs(), cfg, ml,
                                   width=True, depth=False)(
        build_model(small_cfg).init(jax.random.PRNGKey(3)))

    kw = dict(batch=2, max_seq=48, page_size=8)
    gsrv = make_server(cfg, engine="paged", **kw)
    gsrv.set_params(p_new)
    greedy = _stream(gsrv, _reqs(cfg, [10, 11], seed=8, max_new=8))

    pol = SpeculativePolicy(k=4, ml=ml, draft_width=True, draft_depth=False)
    srv = make_server(cfg, engine="paged", policy=pol, **kw)
    srv.run(_reqs(cfg, [0, 1], seed=7))  # serve a round on the init weights
    assert srv.request_reload(p_new)  # drained -> swaps and re-projects

    # the draft IS coalesce(new serving params), not a stale projection
    want = _flatten(pol._project(srv.params))
    got = _flatten(pol.draft_params)
    assert got.keys() == want.keys()
    for k in want:
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(want[k]), err_msg=k)

    pol._zero_stats()  # measure acceptance on the post-swap traffic only
    done = _stream(srv, _reqs(cfg, [10, 11], seed=8, max_new=8))
    assert {k: v for k, v in done.items() if k >= 10} == greedy
    assert srv.stats()["accept_rate"] > 0.9


# ---------------------------------------------------------------------------
# watcher side: digest-diff landing


def _params_and_watcher(tmp_path, cfg):
    p = build_model(cfg).init(jax.random.PRNGKey(0))
    p = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32), p)
    mgr = CheckpointManager(str(tmp_path))
    like = jax.tree.map(jnp.zeros_like, p)
    return p, mgr, ManifestWatcher(mgr, like=like)


def test_watcher_diff_ships_zero_bytes_for_unchanged_leaves(tmp_path):
    """Second poll after a ONE-leaf change: exactly one leaf is re-assembled
    and the other landed leaves are the SAME objects as the first poll --
    unchanged weights never leave the store."""
    cfg = tiny_dense()
    p1, mgr, w = _params_and_watcher(tmp_path, cfg)
    mgr.save(1, {"params": p1}, meta={"step": 1})
    step, landed1 = w.poll()
    assert step == 1 and w.last_step == 1
    flat1 = _flatten(landed1)
    st1 = w.last_reload_stats
    assert st1["changed"] == len(flat1) and st1["reused"] == 0

    # change exactly one leaf and publish step 2
    leaves = jax.tree.leaves(p1)
    p2 = jax.tree.unflatten(jax.tree.structure(p1),
                            [leaves[0] * 2.0 + 1.0] + leaves[1:])
    mgr.save(2, {"params": p2}, meta={"step": 2})
    assert w.poll()[0] == 2
    st2 = w.last_reload_stats
    assert st2["changed"] == 1 and st2["reused"] == len(flat1) - 1
    # the diff pruned the gather: fewer digests read than the manifest holds
    assert st2["gather_needed"] < st2["gather_manifest"]
    assert st2["gather_skipped"] > 0

    same = sum(1 for k in flat1 if w._landed[k] is flat1[k])
    assert same == st2["reused"]  # unchanged leaves: identical objects
    assert w.steps_seen == [1, 2] and w.steps_skipped == []


def test_watcher_stale_and_missing_manifest(tmp_path):
    """No manifest -> None; an already-seen step -> None (poll is cheap in
    the steady state: one manifest read, no assembly)."""
    cfg = tiny_dense()
    p1, mgr, w = _params_and_watcher(tmp_path, cfg)
    assert w.poll() is None and w.poll_errors == 0
    mgr.save(1, {"params": p1}, meta={"step": 1})
    assert w.poll() is not None
    assert w.poll() is None  # same step again: nothing to do
    assert w.steps_seen == [1]


def test_watcher_skips_coalesced_checkpoints(tmp_path):
    """A mid-V-cycle publish carries COALESCED (smaller-shape) params; the
    watcher must skip it -- remembering it as examined so the poll stays
    cheap -- and land the next level-0-shaped step."""
    cfg = tiny_dense(compute_dtype="float32")
    ml = MultiLevelConfig()
    p1, mgr, w = _params_and_watcher(tmp_path, cfg)
    mgr.save(1, {"params": p1}, meta={"step": 1})
    assert w.poll()[0] == 1

    small_cfg = ops.coalesce_config(cfg, ml, width=True, depth=True)
    p_small = build_model(small_cfg).init(jax.random.PRNGKey(1))
    mgr.save(2, {"params": p_small}, meta={"step": 2})
    assert w.poll() is None
    assert w.steps_skipped == [2] and w.last_step == 1
    assert w.poll() is None  # the skip is remembered, not re-examined

    mgr.save(3, {"params": p1}, meta={"step": 3})
    assert w.poll()[0] == 3
    assert w.steps_seen == [1, 3]


def test_watcher_rejects_non_v3_layout(tmp_path):
    """dedup=False writes the whole-file v2 layout -- no digest manifest to
    diff.  The watcher must fail loudly, not serve garbage."""
    cfg = tiny_dense()
    p = build_model(cfg).init(jax.random.PRNGKey(0))
    CheckpointManager(str(tmp_path), dedup=False).save(
        1, {"params": p}, meta={"step": 1})
    w = ManifestWatcher(CheckpointManager(str(tmp_path), dedup=False),
                        like=jax.tree.map(jnp.zeros_like, p))
    with pytest.raises(ValueError, match="content-addressed"):
        w.poll()


def test_attached_watcher_swaps_during_run(tmp_path):
    """End-to-end through ``run()``: a server with an attached watcher picks
    up a published step at the tick boundary and the whole stream equals a
    fresh server booted on the published weights."""
    cfg = tiny_dense(compute_dtype="float32")
    p1, mgr, w = _params_and_watcher(tmp_path, cfg)
    mgr.save(1, {"params": p1}, meta={"step": 1})

    fresh = make_server(cfg, engine="paged", batch=2, max_seq=48, page_size=8)
    fresh.set_params(p1)
    oracle = _stream(fresh, _reqs(cfg, [0, 1, 2], seed=5))

    srv = make_server(cfg, engine="paged", batch=2, max_seq=48, page_size=8)
    srv.attach_watcher(w)
    assert _stream(srv, _reqs(cfg, [0, 1, 2], seed=5)) == oracle
    assert srv.reloads == 1 and srv.rejected == []
    assert w.steps_seen == [1]


@pytest.mark.slow
def test_watcher_two_process_kv_mode(tmp_path):
    """No-shared-FS serving (--ckpt-local-dir): rank 0 polls from an EMPTY
    local dir, so every object of the first landed step crosses the
    coordination KV from rank 1's pool; a one-leaf coordinated update then
    lands with the gather pruned to the changed digests."""
    cfg = tiny_dense()
    p1 = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32),
                      build_model(cfg).init(jax.random.PRNGKey(0)))
    survivor = str(tmp_path / "survivor")
    CheckpointManager(survivor, local=True).save(
        1, {"params": p1}, meta={"step": 1})

    res = run_multiprocess("""
        import os
        import jax, jax.numpy as jnp
        import numpy as np
        from helpers import tiny_dense
        from repro.checkpoint import CheckpointManager
        from repro.checkpoint.manager import _flatten
        from repro.launch.serve import ManifestWatcher
        from repro.models.api import build_model

        cfg = tiny_dense()
        p1 = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32),
                          build_model(cfg).init(jax.random.PRNGKey(0)))
        my_dir = (os.environ["FRESH"] if jax.process_index() == 0
                  else os.environ["SURVIVOR"])
        mgr = CheckpointManager(my_dir, local=True)
        w = ManifestWatcher(mgr, like=jax.tree.map(jnp.zeros_like, p1))
        step, landed = w.poll()  # collective: election + KV gather
        assert step == 1, step
        flat, ref = _flatten(landed), _flatten(p1)
        for k in ref:
            np.testing.assert_array_equal(np.asarray(flat[k]),
                                          np.asarray(ref[k]), err_msg=k)
        st1 = w.last_reload_stats
        if jax.process_index() == 0:
            assert st1["gather_fetched"] > 0, st1  # all over the wire
        else:
            assert st1["gather_served"] > 0, st1  # rank 1 fed the KV

        leaves = jax.tree.leaves(p1)
        p2 = jax.tree.unflatten(jax.tree.structure(p1),
                                [leaves[0] * 2.0 + 1.0] + leaves[1:])
        mgr.save(2, {"params": p2}, meta={"step": 2})  # coordinated save
        assert w.poll()[0] == 2
        st = w.last_reload_stats
        assert st["changed"] == 1 and st["reused"] == st["leaves"] - 1, st
        assert st["gather_needed"] < st["gather_manifest"], st
        print(f"MP_WATCHER_OK rank={jax.process_index()} "
              f"fetched1={st1['gather_fetched']} "
              f"needed2={st['gather_needed']}", flush=True)
    """, n=2, env={"FRESH": str(tmp_path / "fresh"), "SURVIVOR": survivor})
    for rc, out in res:
        assert rc == 0, out[-3000:]
        assert "MP_WATCHER_OK" in out
