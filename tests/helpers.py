"""Shared tiny configs for tests + a local multi-process launch harness."""
import os
import socket
import subprocess
import sys
import textwrap

import jax.numpy as jnp

from repro.config import BlockSpec, ModelConfig, Stage, TrainConfig, uniform_stages


def tiny_dense(**kw) -> ModelConfig:
    base = dict(name="t-dense", family="dense", d_model=64, n_heads=4, n_kv_heads=2,
                d_ff=128, vocab_size=256, stages=uniform_stages(3, BlockSpec("attn", "dense")),
                qk_norm=True, remat="none", attn_impl="plain")
    base.update(kw)
    return ModelConfig(**base)


def tiny_moe(**kw) -> ModelConfig:
    return tiny_dense(name="t-moe", family="moe", n_experts=4, moe_top_k=2, moe_d_ff=64,
                      n_shared_experts=1,
                      stages=(Stage((BlockSpec("attn", "dense"),), 1),
                              Stage((BlockSpec("attn", "moe"),), 2)), **kw)


def tiny_mla(**kw) -> ModelConfig:
    return tiny_dense(name="t-mla", family="moe", attn_type="mla", q_lora_rank=32,
                      kv_lora_rank=16, qk_nope_head_dim=16, qk_rope_head_dim=8,
                      v_head_dim=16, qk_norm=False, n_kv_heads=4, **kw)


def tiny_hybrid(**kw) -> ModelConfig:
    return tiny_dense(name="t-hyb", family="hybrid",
                      stages=(Stage((BlockSpec("mamba", "dense"), BlockSpec("attn", "dense")), 2),),
                      **kw)


def tiny_xlstm(**kw) -> ModelConfig:
    return tiny_dense(name="t-xl", family="ssm", n_kv_heads=4,
                      stages=(Stage((BlockSpec("mlstm", "none"), BlockSpec("slstm", "none")), 2),),
                      **kw)


def tiny_vlm(**kw) -> ModelConfig:
    return tiny_dense(name="t-vlm", family="vlm", n_image_tokens=8,
                      stages=(Stage((BlockSpec("cross_attn", "dense"),
                                     BlockSpec("attn", "dense")), 2),), **kw)


def tiny_audio(**kw) -> ModelConfig:
    return tiny_dense(name="t-audio", family="audio", n_encoder_layers=2, encoder_seq=12,
                      act="gelu", norm="layernorm", n_kv_heads=4, use_bias=True,
                      stages=uniform_stages(2, BlockSpec("dec_attn", "dense")), **kw)


def fast_tc(steps=5, **kw) -> TrainConfig:
    base = dict(steps=steps, warmup_steps=1, peak_lr=1e-3, batch_size=2, seq_len=16,
                log_every=1)
    base.update(kw)
    return TrainConfig(**base)


ALL_FAMILIES = {
    "dense": tiny_dense, "moe": tiny_moe, "mla": tiny_mla, "hybrid": tiny_hybrid,
    "xlstm": tiny_xlstm, "vlm": tiny_vlm, "audio": tiny_audio,
}


# ---------------------------------------------------------------------------
# multi-process harness: spawn N local CPU processes against a localhost
# coordinator (the CI-drillable stand-in for an N-host launch)

_REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")

# Prepended to every worker: brings up jax.distributed from the MP_* env vars
# the harness sets.  Workers import from `helpers` too (PYTHONPATH carries
# tests/), so the worker and the test build literally the same tiny configs.
MP_PRELUDE = textwrap.dedent("""
    import os
    from repro.launch.mesh import init_distributed
    init_distributed(os.environ["MP_COORD"], int(os.environ["MP_NPROCS"]),
                     int(os.environ["MP_RANK"]))
    import jax
    assert jax.process_count() == int(os.environ["MP_NPROCS"])
""")


def free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def run_multiprocess(body: str, n: int = 2, *, env=None, timeout: int = 600,
                     prelude: str = MP_PRELUDE):
    """Run ``prelude + body`` in ``n`` local processes under one coordinator.

    Each worker sees MP_RANK / MP_NPROCS / MP_COORD plus any ``env`` extras,
    with PYTHONPATH covering both ``src`` and ``tests``.  Returns a list of
    (returncode, combined_output) per rank; callers assert on both.
    """
    port = free_port()
    src = prelude + textwrap.dedent(body)
    procs = []
    for rank in range(n):
        wenv = dict(os.environ,
                    PYTHONPATH="src" + os.pathsep + "tests",
                    MP_COORD=f"127.0.0.1:{port}",
                    MP_NPROCS=str(n), MP_RANK=str(rank), **(env or {}))
        procs.append(subprocess.Popen(
            [sys.executable, "-c", src], env=wenv, cwd=_REPO_ROOT,
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True))
    results = []
    try:
        for p in procs:
            out, _ = p.communicate(timeout=timeout)
            results.append((p.returncode, out))
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
                p.wait()
    return results


def mp_arena():
    """The shared tiny V-cycle problem for the multi-process equivalence
    tests -- built identically by workers and by the asserting test process
    (f32 so cross-process reduction roundoff is the only drift source;
    batch 4 divides a 2-way data axis)."""
    from repro.config import MultiLevelConfig

    cfg = tiny_dense(d_model=32, d_ff=64, vocab_size=128,
                     compute_dtype=jnp.float32)
    tc = fast_tc(steps=12, batch_size=4, seq_len=16, log_every=2, peak_lr=3e-4)
    ml = MultiLevelConfig(n_levels=2, alpha=0.25, e_a_frac=0.25,
                          e_small_frac=0.5)
    return cfg, tc, ml


def batch_for(cfg: ModelConfig, B=2, S=16):
    import jax.numpy as jnp

    b = {"tokens": (jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) % 250),
         "labels": (jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) % 250)}
    if cfg.family == "vlm":
        b["img_embeds"] = jnp.ones((B, cfg.n_image_tokens, cfg.vision_dim or cfg.d_model),
                                   jnp.float32) * 0.1
    if cfg.family == "audio":
        b["enc_frames"] = jnp.ones((B, cfg.encoder_seq, cfg.d_model), jnp.float32) * 0.1
    return b
