"""Shared tiny configs for tests."""
import jax.numpy as jnp

from repro.config import BlockSpec, ModelConfig, Stage, TrainConfig, uniform_stages


def tiny_dense(**kw) -> ModelConfig:
    base = dict(name="t-dense", family="dense", d_model=64, n_heads=4, n_kv_heads=2,
                d_ff=128, vocab_size=256, stages=uniform_stages(3, BlockSpec("attn", "dense")),
                qk_norm=True, remat="none", attn_impl="plain")
    base.update(kw)
    return ModelConfig(**base)


def tiny_moe(**kw) -> ModelConfig:
    return tiny_dense(name="t-moe", family="moe", n_experts=4, moe_top_k=2, moe_d_ff=64,
                      n_shared_experts=1,
                      stages=(Stage((BlockSpec("attn", "dense"),), 1),
                              Stage((BlockSpec("attn", "moe"),), 2)), **kw)


def tiny_mla(**kw) -> ModelConfig:
    return tiny_dense(name="t-mla", family="moe", attn_type="mla", q_lora_rank=32,
                      kv_lora_rank=16, qk_nope_head_dim=16, qk_rope_head_dim=8,
                      v_head_dim=16, qk_norm=False, n_kv_heads=4, **kw)


def tiny_hybrid(**kw) -> ModelConfig:
    return tiny_dense(name="t-hyb", family="hybrid",
                      stages=(Stage((BlockSpec("mamba", "dense"), BlockSpec("attn", "dense")), 2),),
                      **kw)


def tiny_xlstm(**kw) -> ModelConfig:
    return tiny_dense(name="t-xl", family="ssm", n_kv_heads=4,
                      stages=(Stage((BlockSpec("mlstm", "none"), BlockSpec("slstm", "none")), 2),),
                      **kw)


def tiny_vlm(**kw) -> ModelConfig:
    return tiny_dense(name="t-vlm", family="vlm", n_image_tokens=8,
                      stages=(Stage((BlockSpec("cross_attn", "dense"),
                                     BlockSpec("attn", "dense")), 2),), **kw)


def tiny_audio(**kw) -> ModelConfig:
    return tiny_dense(name="t-audio", family="audio", n_encoder_layers=2, encoder_seq=12,
                      act="gelu", norm="layernorm", n_kv_heads=4, use_bias=True,
                      stages=uniform_stages(2, BlockSpec("dec_attn", "dense")), **kw)


def fast_tc(steps=5, **kw) -> TrainConfig:
    base = dict(steps=steps, warmup_steps=1, peak_lr=1e-3, batch_size=2, seq_len=16,
                log_every=1)
    base.update(kw)
    return TrainConfig(**base)


ALL_FAMILIES = {
    "dense": tiny_dense, "moe": tiny_moe, "mla": tiny_mla, "hybrid": tiny_hybrid,
    "xlstm": tiny_xlstm, "vlm": tiny_vlm, "audio": tiny_audio,
}


def batch_for(cfg: ModelConfig, B=2, S=16):
    import jax.numpy as jnp

    b = {"tokens": (jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) % 250),
         "labels": (jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) % 250)}
    if cfg.family == "vlm":
        b["img_embeds"] = jnp.ones((B, cfg.n_image_tokens, cfg.vision_dim or cfg.d_model),
                                   jnp.float32) * 0.1
    if cfg.family == "audio":
        b["enc_frames"] = jnp.ones((B, cfg.encoder_seq, cfg.d_model), jnp.float32) * 0.1
    return b
