"""Pluggable gradient reduction (distributed/reduce.py) end to end.

Fast single-device tests pin the mechanics: the packed ef_int8_psum payload
(ONE pmax + ONE psum for the whole tree), the dense shard_map step's
equivalence to the legacy pjit step, the strategy factory, wire-bytes
accounting, the EF-state lifecycle through V-cycle checkpoints (reset at
level transitions, restore-without-strategy fails loudly), the KV streaming
framing and the sharding-aware restore geometry.

Slow 2-process drills pin the acceptance criteria: an int8_ef V-cycle over a
real ("pod","data","model") mesh executes ef_int8_psum inside the compiled
step (call probe, not config), tracks the dense loss trajectory within
tolerance, and survives kill-and-resume with the EF residuals intact.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from helpers import mp_arena, run_multiprocess, tiny_dense, fast_tc, batch_for
from repro.distributed.compression import (dense_wire_bytes, ef_compress,
                                           ef_int8_psum, ef_psum_calls,
                                           init_ef_state, int8_wire_bytes,
                                           reset_ef_psum_probe)
from repro.distributed.reduce import (DenseReduce, HierarchicalInt8EF,
                                      make_grad_reduce)


@pytest.fixture(autouse=True)
def _fresh_probe():
    reset_ef_psum_probe()
    yield
    reset_ef_psum_probe()


def _flat(tree):
    from repro.checkpoint.manager import _flatten

    return _flatten(jax.device_get(tree))


def _assert_trees(a, b, atol, err=""):
    a, b = _flat(a), _flat(b)
    assert a.keys() == b.keys()
    for k in a:
        np.testing.assert_allclose(np.asarray(a[k], np.float64),
                                   np.asarray(b[k], np.float64),
                                   atol=atol, err_msg=f"{err}:{k}")


# ---------------------------------------------------------------------------
# packed compression payload


def _shardmap_psum(grads, ef):
    from jax.experimental.shard_map import shard_map

    mesh = jax.make_mesh((1,), ("pod",))
    return jax.jit(shard_map(
        lambda g, e: ef_int8_psum(g, e, "pod"), mesh=mesh,
        in_specs=(P(), P()), out_specs=(P(), P()),
        check_rep=False))(grads, ef)


def test_packed_psum_matches_per_leaf_reference():
    """On a 1-rank axis the packed path must agree leaf-for-leaf with the
    reference ``ef_compress`` (pmax of one rank == the local scale, so the
    quantization decisions are identical)."""
    key = jax.random.PRNGKey(0)
    grads = {"a": jax.random.normal(key, (16, 8)) * 0.3,
             "b": jax.random.normal(jax.random.PRNGKey(1), (32,)) * 2.0,
             "c": jax.random.normal(jax.random.PRNGKey(2), (4, 4, 4)) * 1e-3}
    ef = jax.tree.map(lambda g: jnp.abs(g) * 0.01, grads)
    out, new_ef = _shardmap_psum(grads, ef)
    for k in grads:
        q, s, ref_ef = ef_compress(grads[k], ef[k])
        np.testing.assert_allclose(np.asarray(out[k]),
                                   np.asarray(q, np.float32) * float(s),
                                   rtol=1e-5, atol=1e-6, err_msg=k)
        np.testing.assert_allclose(np.asarray(new_ef[k]), np.asarray(ref_ef),
                                   atol=1e-6, err_msg=k)


def test_packed_psum_conserves_signal():
    """EF identity through the packed path: sent + carried == grad + carry-in
    to f32 roundoff, for every leaf."""
    grads = {"w": jax.random.normal(jax.random.PRNGKey(3), (64,)) * 0.05,
             "v": jax.random.normal(jax.random.PRNGKey(4), (8, 8)) * 7.0}
    ef = init_ef_state(grads)
    out, new_ef = _shardmap_psum(grads, ef)
    for k in grads:
        np.testing.assert_allclose(np.asarray(out[k] + new_ef[k]),
                                   np.asarray(grads[k]), atol=1e-5, err_msg=k)


def test_packed_psum_is_two_collectives_total():
    """The whole point of packing: 2 collectives per step (one pmax over the
    stacked scales + one int32 psum over the concatenated payload) instead of
    2 per leaf."""
    from jax.experimental.shard_map import shard_map

    mesh = jax.make_mesh((1,), ("pod",))
    grads = {f"l{i}": jnp.ones((4, 4)) for i in range(5)}
    ef = init_ef_state(grads)
    f = shard_map(lambda g, e: ef_int8_psum(g, e, "pod"), mesh=mesh,
                  in_specs=(P(), P()), out_specs=(P(), P()), check_rep=False)
    text = str(jax.make_jaxpr(f)(grads, ef))
    assert text.count("psum") == 1, text
    assert text.count("pmax") == 1, text


def test_wire_bytes_ratio_at_least_3x():
    grads = {"emb": jnp.zeros((128, 32)), "w": jnp.zeros((32, 64)),
             "b": jnp.zeros((64,))}
    dense = DenseReduce(data_axes=("data",))
    comp = HierarchicalInt8EF(data_axes=("data",))
    assert dense.wire_bytes(grads) == dense_wire_bytes(grads)
    assert comp.wire_bytes(grads) == int8_wire_bytes(grads)
    ratio = dense.wire_bytes(grads) / comp.wire_bytes(grads)
    assert ratio >= 3.0  # f32 -> int8 is ~4x minus the per-leaf scale word


# ---------------------------------------------------------------------------
# strategy factory + mesh plumbing


def test_make_grad_reduce_factory():
    mesh2 = jax.make_mesh((1, 1), ("data", "model"))
    mesh3 = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    assert make_grad_reduce("none", mesh2) is None
    assert make_grad_reduce("", mesh2) is None
    assert make_grad_reduce(None, mesh2) is None

    d = make_grad_reduce("dense", mesh3)
    assert isinstance(d, DenseReduce) and d.data_axes == ("pod", "data")

    c3 = make_grad_reduce("int8_ef", mesh3)
    assert c3.dcn_axis == "pod" and c3.ici_axes == ("data",)
    assert c3.dcn_size == 1 and c3.stateful
    c2 = make_grad_reduce("int8_ef", mesh2)  # no pod axis: all of "data" is DCN
    assert c2.dcn_axis == "data" and c2.ici_axes == ()

    with pytest.raises(ValueError, match="unknown grad_compression"):
        make_grad_reduce("fp8", mesh2)
    model_only = jax.make_mesh((1,), ("model",))
    with pytest.raises(ValueError, match="no data-like axis"):
        make_grad_reduce("dense", model_only)


def test_parse_mesh_arg_pod_axis():
    from repro.launch.mesh import parse_mesh_arg

    assert parse_mesh_arg("2x4") == (2, 4)
    assert parse_mesh_arg("2x2x1") == (2, 2, 1)
    for bad in ("2", "2x2x2x2", "0x1", "axb"):
        with pytest.raises(ValueError):
            parse_mesh_arg(bad)


def test_ef_state_layout():
    """EF residuals: one [dcn_size, *param] f32 block per leaf, sharded over
    the DCN axis on dim 0 so each pod rank owns exactly its own residual."""
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    gr = HierarchicalInt8EF(data_axes=("pod", "data"), dcn_axis="pod",
                            ici_axes=("data",), dcn_size=2)
    params = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
    ef = gr.init_state(params)
    assert ef["w"].shape == (2, 8, 4) and ef["w"].dtype == jnp.float32
    assert ef["b"].shape == (2, 4)
    sh = gr.state_shardings(params, mesh)
    assert sh["w"].spec == P("pod")
    assert gr.state_specs() == P("pod")


# ---------------------------------------------------------------------------
# dense shard_map step == legacy pjit step


def test_dense_shardmap_step_matches_legacy():
    """DenseReduce's explicit shard_map reduction must reproduce the legacy
    pjit step bit-for-bit (up to f32 roundoff): same grads, same Adam math,
    only the reduction is spelled out."""
    from repro.models.api import init_train_state, make_train_step

    cfg = tiny_dense(d_model=32, d_ff=64, vocab_size=128,
                     compute_dtype=jnp.float32)
    tc = fast_tc(steps=4, batch_size=4, seq_len=16)
    from repro.models.api import build_model

    model = build_model(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    batch = batch_for(cfg, B=4, S=16)

    p0, o0 = init_train_state(model, tc, jax.random.PRNGKey(0))
    legacy = jax.jit(make_train_step(model, tc))
    p_l, o_l = p0, o0
    for _ in range(3):
        p_l, o_l, m_l = legacy(p_l, o_l, batch)

    gr = make_grad_reduce("dense", mesh)
    sm = jax.jit(make_train_step(model, tc, grad_reduce=gr, mesh=mesh))
    p_s, o_s = p0, o0
    for _ in range(3):
        p_s, o_s, _, m_s = sm(p_s, o_s, None, batch)

    _assert_trees(p_l, p_s, atol=1e-5, err="params")
    np.testing.assert_allclose(float(m_l["loss"]), float(m_s["loss"]),
                               atol=1e-5)
    assert ef_psum_calls() == 0  # dense never touches the compressed path


def test_int8ef_shardmap_step_tracks_dense():
    """On a 1-rank DCN axis the compressed step's only deviation from dense is
    quantization noise, which EF keeps bounded -- a few steps must stay close,
    and the probe must record the traced compression."""
    from repro.models.api import (build_model, init_train_state,
                                  make_train_step, zero_train_state)

    cfg = tiny_dense(d_model=32, d_ff=64, vocab_size=128,
                     compute_dtype=jnp.float32)
    tc = fast_tc(steps=4, batch_size=4, seq_len=16)
    model = build_model(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    batch = batch_for(cfg, B=4, S=16)
    p0, o0 = init_train_state(model, tc, jax.random.PRNGKey(0))

    dense = jax.jit(make_train_step(
        model, tc, grad_reduce=make_grad_reduce("dense", mesh), mesh=mesh))
    p_d, o_d = p0, o0
    for _ in range(4):
        p_d, o_d, _, _ = dense(p_d, o_d, None, batch)

    gr = make_grad_reduce("int8_ef", mesh)
    ef = gr.init_state(p0)
    comp = jax.jit(make_train_step(model, tc, grad_reduce=gr, mesh=mesh))
    p_c, o_c = p0, o0
    for _ in range(4):
        p_c, o_c, ef, _ = comp(p_c, o_c, ef, batch)

    assert ef_psum_calls() > 0  # the acceptance probe: traced, not configured
    _assert_trees(p_d, p_c, atol=1e-2, err="params")
    # the residual is alive (quantization really happened) and bounded
    ef_leaves = np.concatenate(
        [np.abs(np.asarray(l)).ravel() for l in jax.tree.leaves(ef)])
    assert ef_leaves.max() > 0.0


# ---------------------------------------------------------------------------
# EF-state lifecycle through the V-cycle (single device, mesh (1,1))


def _vcycle_pieces(compression):
    from repro.core.vcycle import VCycleRunner
    from repro.launch.train import make_batch_fn

    cfg, tc, ml = mp_arena()
    tc = dataclasses.replace(tc, grad_compression=compression)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    bf = make_batch_fn(cfg, tc, shard=0)
    return cfg, tc, ml, mesh, bf, VCycleRunner


def test_vcycle_int8ef_runs_and_resets_ef_per_level(monkeypatch):
    """The full V-cycle under int8_ef: the EF tree is (re)initialized once per
    SEGMENT (level transitions change the shapes, so residuals must not leak
    across), its shapes track the current level, and the loss trajectory stays
    within quantization noise of the dense V-cycle."""
    cfg, tc, ml, mesh, bf, VCycleRunner = _vcycle_pieces("int8_ef")
    ref = VCycleRunner(cfg, ml, dataclasses.replace(tc, grad_compression="dense"),
                       bf, seed=0, mesh=mesh).run()

    runner = VCycleRunner(cfg, ml, tc, bf, seed=0, mesh=mesh)
    inits = []
    orig = runner._init_ef

    def counting_init(level, params):
        inits.append(level)
        return orig(level, params)

    monkeypatch.setattr(runner, "_init_ef", counting_init)
    seen_shapes = {}

    def on_step(state, p, o, stopping, dt):
        leaf = jax.tree.leaves(state.ef)[0]
        seen_shapes.setdefault(state.seg_index, np.asarray(leaf).shape)

    out = runner.run(on_step=on_step)
    assert ef_psum_calls() > 0
    # one fresh EF init per segment: down(l0), up(l1), final(l0)
    assert inits == [p.level for p in runner.plan]
    # the residual block really tracks each segment's level shapes
    assert seen_shapes[0] != seen_shapes[1]  # l0 vs coalesced l1
    assert seen_shapes[0] == seen_shapes[2]  # final is back at l0
    assert len(out.history.loss) == len(ref.history.loss)
    np.testing.assert_allclose(out.history.loss, ref.history.loss, atol=5e-2)


def test_vcycle_ef_checkpoint_kill_and_resume(tmp_path):
    """EF-state lifecycle across save/kill/restore on one device: the residual
    tree rides the checkpoint, the restored run finishes identically to an
    uninterrupted one, and restoring WITHOUT the strategy fails loudly."""
    from repro.checkpoint import CheckpointManager
    from repro.launch.train import make_vcycle_save_cb, restore_vcycle_state

    cfg, tc, ml, mesh, bf, VCycleRunner = _vcycle_pieces("int8_ef")
    ref = VCycleRunner(cfg, ml, tc, bf, seed=0, mesh=mesh).run()

    class Preempted(RuntimeError):
        pass

    runner = VCycleRunner(cfg, ml, tc, bf, seed=0, mesh=mesh)
    cm = CheckpointManager(str(tmp_path))
    save_cb = make_vcycle_save_cb(cm, schedule=runner.plan)

    def killing_cb(state, p, o):
        save_cb(state, p, o, blocking=True)
        if state.global_step == 6:  # mid-upward-sweep: stash + EF both live
            raise Preempted

    with pytest.raises(Preempted):
        runner.run(ckpt_cb=killing_cb, ckpt_every=2)
    assert cm.latest()["meta"]["has_ef"] is True

    # restoring without the strategy must refuse, not silently drop residuals
    plain = VCycleRunner(cfg, ml,
                         dataclasses.replace(tc, grad_compression="none"),
                         bf, seed=0, mesh=mesh)
    with pytest.raises(ValueError, match="carries grad-reduction"):
        restore_vcycle_state(CheckpointManager(str(tmp_path)), plain, tc)

    resumed = VCycleRunner(cfg, ml, tc, bf, seed=0, mesh=mesh)
    state, params, opt = restore_vcycle_state(
        CheckpointManager(str(tmp_path)), resumed, tc)
    assert (state.phase, state.global_step) == ("up", 6)
    assert state.ef is not None
    # residuals survived the roundtrip intact (nonzero = quantization actually
    # carried error into the save)
    ef_abs = np.concatenate(
        [np.abs(np.asarray(l)).ravel() for l in jax.tree.leaves(state.ef)])
    assert ef_abs.max() > 0.0
    out = resumed.run(state=state, params=params, opt_state=opt)
    assert out.history.step == ref.history.step
    _assert_trees(out.params, ref.params, atol=1e-4, err="resumed")


# ---------------------------------------------------------------------------
# KV streaming framing (satellite: bounded chunks over the coordination KV)


def _fake_kv(monkeypatch):
    import repro.distributed.multiprocess as mp

    store = {}
    monkeypatch.setattr(mp, "kv_put", lambda k, v: store.__setitem__(k, v))

    def fetch(k, timeout_ms=0):
        if k not in store:
            raise KeyError(k)
        return store[k]

    monkeypatch.setattr(mp, "kv_fetch", fetch)
    monkeypatch.setattr(mp, "kv_delete", lambda k: store.pop(k, None))
    return mp, store


def test_kv_stream_roundtrip_and_chunking(monkeypatch):
    mp, store = _fake_kv(monkeypatch)
    monkeypatch.setenv("REPRO_KV_CHUNK_BYTES", "4")
    payload = bytes(range(11))
    mp.kv_put_stream("s", payload)
    assert store["s/meta"] == b"n=3"  # ceil(11/4) parts
    # the jaxlib coordination service segfaults on 1-byte values: every
    # message the stream layer emits must be >= 2 bytes
    assert all(len(v) >= 2 for v in store.values()), {
        k: v for k, v in store.items() if len(v) < 2}
    assert mp.kv_fetch_stream("s") == payload
    mp.kv_delete_stream("s")
    assert not store  # parts AND meta reclaimed


def test_kv_stream_empty_and_single_part(monkeypatch):
    mp, store = _fake_kv(monkeypatch)
    mp.kv_put_stream("e", b"")
    assert store["e/meta"] == b"n=1"
    assert all(len(v) >= 2 for v in store.values())
    assert mp.kv_fetch_stream("e") == b""
    mp.kv_put_stream("one", b"abc")  # fits one default-size chunk
    assert store["one/meta"] == b"n=1"
    assert mp.kv_fetch_stream("one") == b"abc"
    mp.kv_delete_stream("e")
    mp.kv_delete_stream("one")
    mp.kv_delete_stream("never-put")  # missing meta: silent no-op
    assert not store


# ---------------------------------------------------------------------------
# sharding-aware restore geometry (satellite: fetch only addressed slices)


def test_chunk_intersects_geometry():
    from repro.checkpoint.store import chunk_intersects

    full = (8, 4)
    top = (slice(0, 4), slice(0, 4))
    bottom = (slice(4, 8), slice(0, 4))
    assert chunk_intersects([0, 0], [4, 4], [top], full)
    assert not chunk_intersects([4, 0], [4, 4], [top], full)
    assert chunk_intersects([2, 0], [4, 4], [top], full)  # straddles the cut
    assert chunk_intersects([4, 0], [4, 4], [top, bottom], full)
    # 0-d leaves carry empty index tuples and are always needed
    assert chunk_intersects([], [], [()], ())
    # slices with None bounds cover the whole dim
    assert chunk_intersects([4, 0], [4, 4], [(slice(None), slice(0, 2))], full)


class _StubSharding:
    def __init__(self, *idx):
        self._idx = idx

    def addressable_devices_indices_map(self, shape):
        return dict(enumerate(self._idx))


def test_needed_digests_prunes_unaddressed_chunks():
    from repro.checkpoint.store import needed_digests

    entries = {
        "w": {"shape": [8, 4], "dtype": "float32", "chunks": [
            {"digest": "top", "start": [0, 0], "shape": [4, 4]},
            {"digest": "bot", "start": [4, 0], "shape": [4, 4]}]},
        "b": {"shape": [4], "dtype": "float32", "chunks": [
            {"digest": "whole", "start": [0], "shape": [4]}]},
    }
    sh_top = _StubSharding((slice(0, 4), slice(0, 4)))
    # leaf with a sharding: only intersecting chunks; leaf without: everything
    assert needed_digests(entries, {"w": sh_top}) == {"top", "whole"}
    assert needed_digests(entries, {}) == {"top", "bot", "whole"}
    sh_full = _StubSharding((slice(0, 8), slice(0, 4)))
    assert needed_digests(entries, {"w": sh_full}) == {"top", "bot", "whole"}


def test_assemble_tree_skips_unneeded_chunks(tmp_path):
    from repro.checkpoint import ObjectStore
    from repro.checkpoint import store as store_lib

    pool = ObjectStore(str(tmp_path))
    top = np.arange(16, dtype=np.float32).reshape(4, 4)
    d_top = store_lib.leaf_digest(top)
    pool.put(d_top, top)  # the bottom chunk is NOT in any pool
    entries = {"w": {"shape": [8, 4], "dtype": "float32", "chunks": [
        {"digest": d_top, "start": [0, 0], "shape": [4, 4]},
        {"digest": "deadbeef", "start": [4, 0], "shape": [4, 4]}]}}
    # without pruning the missing chunk is fatal
    with pytest.raises(FileNotFoundError):
        store_lib.assemble_tree(entries, [pool])
    out = store_lib.assemble_tree(entries, [pool], needed={d_top})
    assert out["w"].shape == (8, 4) and out["w"].dtype == np.float32
    np.testing.assert_array_equal(out["w"][:4], top)
    # a fully-unneeded leaf still lands as a right-shaped placeholder
    out2 = store_lib.assemble_tree(entries, [pool], needed=set())
    assert out2["w"].shape == (8, 4) and out2["w"].dtype == np.float32


def test_np_dtype_resolves_ml_dtypes():
    from repro.checkpoint.store import np_dtype

    assert np_dtype("float32") == np.float32
    assert np_dtype(None) == np.float32
    assert np_dtype("bfloat16").itemsize == 2


# ---------------------------------------------------------------------------
# slow 2-process drills (the acceptance criteria)


@pytest.mark.slow
def test_two_process_int8ef_vcycle_tracks_dense(tmp_path):
    """The tentpole acceptance drill: a 2-process V-cycle over a real
    ("pod","data","model") mesh with --grad-compression int8_ef executes
    ef_int8_psum inside the shard_map'd compiled step (call probe) and its
    loss trajectory matches the dense run within quantization tolerance."""
    res = run_multiprocess("""
        import dataclasses, json, os
        import jax
        import numpy as np
        from helpers import mp_arena
        from repro.core.vcycle import VCycleRunner
        from repro.distributed import as_global_batch_fn
        from repro.distributed.compression import ef_psum_calls
        from repro.launch.train import make_batch_fn

        cfg, tc, ml = mp_arena()
        mesh = jax.make_mesh((2, 1, 1), ("pod", "data", "model"))
        bf = as_global_batch_fn(make_batch_fn(cfg, tc, shard=0), mesh)

        dense = VCycleRunner(
            cfg, ml, dataclasses.replace(tc, grad_compression="dense"),
            bf, seed=0, mesh=mesh).run()
        assert ef_psum_calls() == 0  # dense never touches the probe
        comp = VCycleRunner(
            cfg, ml, dataclasses.replace(tc, grad_compression="int8_ef"),
            bf, seed=0, mesh=mesh).run()
        probe = ef_psum_calls()
        assert probe > 0, "compressed path never traced"
        dev = float(np.max(np.abs(np.asarray(dense.history.loss)
                                  - np.asarray(comp.history.loss))))
        print("MP_REDUCE", json.dumps({"probe": probe, "max_loss_dev": dev}),
              flush=True)
    """, n=2, env={"CK": str(tmp_path)})
    for rc, out in res:
        assert rc == 0, out[-3000:]
        line = [l for l in out.splitlines() if l.startswith("MP_REDUCE ")]
        assert line, out[-2000:]
        rep = json.loads(line[0].split(" ", 1)[1])
        assert rep["probe"] > 0
        # quantization noise only: a wrong shard/axis lands O(1) here
        assert rep["max_loss_dev"] < 5e-2, rep


@pytest.mark.slow
def test_two_process_ef_state_survives_kill_and_resume(tmp_path):
    """Kill-and-resume equivalence WITH live EF residuals: an int8_ef run
    killed mid-upward-sweep (SIGKILL semantics: the process dies right after
    a blocking coordinated save) resumes with the residual tree restored and
    finishes identically to the uninterrupted reference run."""
    ck_ref, ck = str(tmp_path / "ref"), str(tmp_path / "killed")
    res = run_multiprocess("""
        import dataclasses, os
        import jax
        from helpers import mp_arena
        from repro.checkpoint import CheckpointManager
        from repro.core.vcycle import VCycleRunner
        from repro.distributed import as_global_batch_fn
        from repro.launch.train import make_batch_fn, make_vcycle_save_cb

        class Preempted(RuntimeError):
            pass

        cfg, tc, ml = mp_arena()
        tc = dataclasses.replace(tc, grad_compression="int8_ef")
        mesh = jax.make_mesh((2, 1, 1), ("pod", "data", "model"))
        bf = as_global_batch_fn(make_batch_fn(cfg, tc, shard=0), mesh)

        # uninterrupted reference, final params published for the outer test
        ref = VCycleRunner(cfg, ml, tc, bf, seed=0, mesh=mesh).run()
        cm_ref = CheckpointManager(os.environ["CK_REF"])
        cm_ref.save(999, {"params": ref.params}, meta={"step": 999})

        # the killed run: blocking save at global step 6, then die
        runner = VCycleRunner(cfg, ml, tc, bf, seed=0, mesh=mesh)
        cm = CheckpointManager(os.environ["CK"])
        save_cb = make_vcycle_save_cb(cm, schedule=runner.plan)

        def killing_cb(state, p, o):
            save_cb(state, p, o, blocking=True)
            if state.global_step == 6:  # mid-upward-sweep: stash + EF live
                raise Preempted

        try:
            runner.run(ckpt_cb=killing_cb, ckpt_every=2)
            raise AssertionError("kill never fired")
        except Preempted:
            print("MP_KILLED_OK", flush=True)
    """, n=2, env={"CK_REF": ck_ref, "CK": ck})
    for rc, out in res:
        assert rc == 0, out[-3000:]
        assert "MP_KILLED_OK" in out

    res = run_multiprocess("""
        import dataclasses, os
        import jax
        import numpy as np
        from helpers import mp_arena
        from repro.checkpoint import CheckpointManager
        from repro.core.vcycle import VCycleRunner
        from repro.distributed import as_global_batch_fn
        from repro.launch.train import make_batch_fn, restore_vcycle_state

        cfg, tc, ml = mp_arena()
        tc = dataclasses.replace(tc, grad_compression="int8_ef")
        mesh = jax.make_mesh((2, 1, 1), ("pod", "data", "model"))
        bf = as_global_batch_fn(make_batch_fn(cfg, tc, shard=0), mesh)
        runner = VCycleRunner(cfg, ml, tc, bf, seed=0, mesh=mesh)
        cm = CheckpointManager(os.environ["CK"])
        state, params, opt = restore_vcycle_state(cm, runner, tc)
        assert (state.phase, state.global_step) == ("up", 6)
        assert state.ef is not None
        leaf = jax.tree.leaves(state.ef)[0]
        assert leaf.shape[0] == 2  # one residual block per DCN (pod) rank
        assert leaf.sharding.spec == jax.sharding.PartitionSpec("pod")
        ef_abs = np.concatenate([np.abs(np.asarray(s.data)).ravel()
                                 for l in jax.tree.leaves(state.ef)
                                 for s in l.addressable_shards])
        assert ef_abs.max() > 0.0, "restored EF residuals are all-zero"
        out = runner.run(state=state, params=params, opt_state=opt)
        cm.save(999, {"params": out.params}, meta={"step": 999})
        print("MP_EF_RESUMED_OK", flush=True)
    """, n=2, env={"CK": ck})
    for rc, out in res:
        assert rc == 0, out[-3000:]
        assert "MP_EF_RESUMED_OK" in out

    from repro.checkpoint.manager import _read_leaves

    got = _read_leaves(os.path.join(ck, "step_00000999", "params"))
    want = _read_leaves(os.path.join(ck_ref, "step_00000999", "params"))
    assert got.keys() == want.keys()
    for k in got:
        np.testing.assert_allclose(np.asarray(got[k], np.float64),
                                   np.asarray(want[k], np.float64),
                                   atol=1e-4, err_msg=k)


@pytest.mark.slow
def test_two_process_localdir_restore_fetches_only_addressed_slices(tmp_path):
    """Satellite acceptance: a same-sharding --ckpt-local-dir restore must
    fetch ZERO sharded-leaf chunks from peers (each rank already holds the
    slices its shardings address); only rank-0-pooled replicated leaves cross
    the wire, and the skipped peer-half chunks show up in the stats."""
    res = run_multiprocess("""
        import json, os
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.experimental import multihost_utils
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import CheckpointManager
        from repro.distributed import put_global_tree

        pid = jax.process_index()
        mesh = jax.make_mesh((2, 1), ("data", "model"))
        sh_w = NamedSharding(mesh, P("data"))
        sh_b = NamedSharding(mesh, P())
        w = np.arange(32, dtype=np.float32).reshape(4, 8)
        b = np.arange(8, dtype=np.float32) + 100.0
        state = {"params": put_global_tree(
            {"w": jnp.asarray(w), "b": jnp.asarray(b)},
            {"w": sh_w, "b": sh_b})}
        cm = CheckpointManager(os.environ["CK"] + f"/local{pid}", local=True)
        cm.save(3, state, meta={"step": 3})

        like = {"params": {"w": jnp.zeros((4, 8)), "b": jnp.zeros(8)}}
        out, meta = cm.restore(like, shardings={"params": {"w": sh_w,
                                                           "b": sh_b}})
        assert meta["step"] == 3
        got_w = np.asarray(multihost_utils.process_allgather(
            out["params"]["w"], tiled=True))
        np.testing.assert_array_equal(got_w, w)
        np.testing.assert_array_equal(np.asarray(out["params"]["b"]), b)
        print("MP_STATS", json.dumps(cm.last_gather_stats), flush=True)
    """, n=2, env={"CK": str(tmp_path)})
    stats = []
    for rc, out in res:
        assert rc == 0, out[-3000:]
        line = [l for l in out.splitlines() if l.startswith("MP_STATS ")]
        assert line, out[-2000:]
        stats.append(json.loads(line[0].split(" ", 1)[1]))
    # manifest: 2 w-halves + 1 replicated b = 3 objects.  Each rank needs its
    # own w-half (held) + b; the peer's w-half is pruned, never fetched.
    for s in stats:
        assert s["manifest"] == 3, s
        assert s["skipped"] == 1, s  # the peer's half of w
    assert stats[0]["fetched"] == 0, stats  # rank 0 pooled b itself
    assert stats[1]["fetched"] == 1, stats  # rank 1 pulls only b
