"""Kernel dispatch subsystem: registry resolution, Pallas flash attention
forward AND backward parity (interpret mode), end-to-end ``attn_impl="pallas"``
execution, and fused-vs-matrix equivalence of the level-transition operators
on a full parameter tree."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import tiny_dense
from repro.config import MultiLevelConfig
from repro.core import operators as ops
from repro.kernels import dispatch, ref
from repro.layers import attention as attn
from repro.models.api import build_model

ML = MultiLevelConfig(n_levels=2)


# ---------------------------------------------------------------------------
# registry / resolution


def test_registry_contents():
    assert dispatch.ops() == ("coalesce_pair", "flash_attention", "interp_axpy",
                              "paged_attention_decode")
    for op in dispatch.ops():
        assert dispatch.backends(op) == dispatch.BACKENDS


def test_resolution_order(monkeypatch):
    monkeypatch.delenv(dispatch.ENV_VAR, raising=False)
    assert dispatch.resolve_backend("interp_axpy") == dispatch.default_backend()
    monkeypatch.setenv(dispatch.ENV_VAR, "xla")
    assert dispatch.resolve_backend("interp_axpy") == "xla"
    # explicit argument beats the environment
    assert dispatch.resolve_backend("interp_axpy", "pallas-interpret") == "pallas-interpret"
    with pytest.raises(ValueError):
        dispatch.resolve_backend("interp_axpy", "cuda")
    with pytest.raises(KeyError):
        dispatch.resolve_backend("not_an_op", "xla")


@pytest.mark.skipif(jax.default_backend() == "tpu", reason="off-TPU behavior")
def test_pallas_downgrades_to_interpret_off_tpu():
    assert dispatch.resolve_backend("flash_attention", "pallas") == "pallas-interpret"
    assert dispatch.resolve_backend("paged_attention_decode", "pallas") == "pallas-interpret"


# ---------------------------------------------------------------------------
# paged_attention_decode: cross-backend agreement (xla gather oracle vs the
# Pallas kernel body in interpret mode)


def _paged_case(key=0, B=3, KH=2, G=2, D=16, N=12, P=8, M=3):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    q = jax.random.normal(ks[0], (B, KH, G, D), jnp.float32)
    k_pages = jax.random.normal(ks[1], (N, P, KH, D), jnp.float32)
    v_pages = jax.random.normal(ks[2], (N, P, KH, D), jnp.float32)
    # distinct pages per row; row 2 idle (length 0, table all null-page)
    bt = jnp.array([[1, 2, 3], [4, 5, 0], [0, 0, 0]], jnp.int32)
    lengths = jnp.array([3 * P, P + 3, 0], jnp.int32)  # full / partial / idle
    return q, k_pages, v_pages, bt, lengths


def test_paged_attention_backends_agree():
    q, k_pages, v_pages, bt, lengths = _paged_case()
    got = {b: dispatch.dispatch("paged_attention_decode", q, k_pages, v_pages,
                                bt, lengths, backend=b)
           for b in ("xla", "pallas-interpret")}
    np.testing.assert_allclose(np.asarray(got["pallas-interpret"]),
                               np.asarray(got["xla"]), atol=1e-5, rtol=1e-5)
    # idle row (length 0) is exactly zero in BOTH backends -- the pinned
    # convention that keeps inactive decode slots backend-invariant
    for b, out in got.items():
        assert not np.asarray(out[2]).any(), f"{b}: idle row not zero"


def test_build_model_rejects_bad_backend():
    with pytest.raises(ValueError):
        build_model(tiny_dense(kernel_backend="cuda"))
    build_model(tiny_dense(kernel_backend="xla"))  # valid names pass


# ---------------------------------------------------------------------------
# Pallas flash attention fwd + bwd vs the naive oracle (interpret mode)


@pytest.mark.parametrize("causal", [True, False])
def test_flash_vjp_grads_match_oracle(causal):
    B, H, S, D = 1, 2, 256, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, H, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, H, S, D), jnp.float32)
    ct = jax.random.normal(ks[3], (B, H, S, D), jnp.float32)
    impl = dispatch.get_impl("flash_attention", "pallas-interpret")

    out = impl(q, k, v, causal=causal, block_q=64, block_k=64)
    want = ref.naive_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-3, rtol=1e-3)

    g_pl = jax.grad(lambda q, k, v: jnp.sum(
        impl(q, k, v, causal=causal, block_q=64, block_k=64) * ct),
        argnums=(0, 1, 2))(q, k, v)
    g_rf = jax.grad(lambda q, k, v: jnp.sum(
        ref.naive_attention(q, k, v, causal=causal) * ct),
        argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_pl, g_rf):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# run_attention genuinely dispatches to the Pallas kernel


def _qkv(B=1, S=256, KH=2, G=2, D=16, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 4)
    q = jax.random.normal(ks[0], (B, S, KH, G, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KH, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KH, D), jnp.float32)
    ct = jax.random.normal(ks[3], (B, S, KH, G, D), jnp.float32)
    return q, k, v, ct


def test_run_attention_pallas_executes_kernel():
    calls = []
    orig = dispatch.get_impl("flash_attention", "pallas-interpret")
    dispatch.register("flash_attention", "pallas-interpret",
                      lambda *a, **kw: (calls.append(1), orig(*a, **kw))[1],
                      override=True)
    try:
        cfg = tiny_dense(attn_impl="pallas", attn_block_k=64)
        q, k, v, _ = _qkv()
        attn.run_attention(q, k, v, cfg, causal=True, scale=q.shape[-1] ** -0.5)
    finally:
        dispatch.register("flash_attention", "pallas-interpret", orig, override=True)
    assert calls, "attn_impl='pallas' did not reach the Pallas kernel"


def test_run_attention_pallas_grads_match_xla_flash():
    """Acceptance gate: pallas fwd+bwd vs the flash_xla path, <= 1e-3."""
    D = 16
    cfg_p = tiny_dense(attn_impl="pallas", attn_block_k=64)
    cfg_b = cfg_p.replace(attn_impl="blockwise")
    q, k, v, ct = _qkv(D=D)

    def loss(cfg):
        return lambda q, k, v: jnp.sum(
            attn.run_attention(q, k, v, cfg, causal=True, scale=D ** -0.5) * ct)

    o_p = attn.run_attention(q, k, v, cfg_p, causal=True, scale=D ** -0.5)
    o_b = attn.run_attention(q, k, v, cfg_b, causal=True, scale=D ** -0.5)
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_b), atol=1e-3)
    g_p = jax.grad(loss(cfg_p), argnums=(0, 1, 2))(q, k, v)
    g_b = jax.grad(loss(cfg_b), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_p, g_b):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)


def test_run_attention_pallas_fallback_on_untileable():
    """Shapes the tiling cannot cover (causal S != T) keep the XLA flash path
    rather than erroring."""
    cfg = tiny_dense(attn_impl="pallas", attn_block_k=64)
    B, S, T, KH, G, D = 1, 192, 256, 2, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, KH, G, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, T, KH, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, T, KH, D), jnp.float32)
    out = attn.run_attention(q, k, v, cfg, causal=True, scale=D ** -0.5)
    assert out.shape == (B, S, KH, G, D)


def test_run_attention_xla_backend_override():
    """kernel_backend='xla' pins the flash_xla path even under attn_impl='pallas'."""
    calls = []
    orig = dispatch.get_impl("flash_attention", "pallas-interpret")
    dispatch.register("flash_attention", "pallas-interpret",
                      lambda *a, **kw: (calls.append(1), orig(*a, **kw))[1],
                      override=True)
    try:
        cfg = tiny_dense(attn_impl="pallas", attn_block_k=64, kernel_backend="xla")
        q, k, v, _ = _qkv()
        attn.run_attention(q, k, v, cfg, causal=True, scale=q.shape[-1] ** -0.5)
    finally:
        dispatch.register("flash_attention", "pallas-interpret", orig, override=True)
    assert not calls


# ---------------------------------------------------------------------------
# fused (matrix-free) vs dense-matrix level transitions on a full model tree


def _tree_err(a, b):
    return max(float(jnp.max(jnp.abs(x.astype(jnp.float32) - y.astype(jnp.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _tinyllama_proxy():
    """The tinyllama-1.1b architecture at smoke width (same stage/leaf
    structure and axis roles; widths shrunk so CPU tests stay fast)."""
    from repro.configs.tinyllama_1_1b import smoke

    return smoke()


def test_fused_coalesce_matches_matrix_on_tinyllama():
    cfg = _tinyllama_proxy()
    model = build_model(cfg)
    specs = model.specs()
    params = model.init(jax.random.PRNGKey(0))
    fused = ops.make_coalesce_fn(specs, cfg, ML)(params)
    dense = ops.make_coalesce_fn(specs, cfg, ML, fused=False)(params)
    assert _tree_err(fused, dense) <= 1e-5


def test_fused_decoalesce_interpolate_match_matrix_on_tinyllama():
    cfg = _tinyllama_proxy()
    model = build_model(cfg)
    specs = model.specs()
    small = build_model(ops.coalesce_config(cfg, ML))
    p_small = small.init(jax.random.PRNGKey(1))
    de_f = ops.make_decoalesce_fn(specs, cfg, ML)(p_small)
    de_m = ops.make_decoalesce_fn(specs, cfg, ML, fused=False)(p_small)
    assert _tree_err(de_f, de_m) <= 1e-5
    p_large = model.init(jax.random.PRNGKey(2))
    mixed = ops.make_interpolate_fn(0.25)(p_large, de_f)
    want = jax.tree.map(lambda a, b: 0.75 * a + 0.25 * b, p_large, de_m)
    assert _tree_err(mixed, want) <= 1e-5


def test_fused_cd_identity_pallas_interpret(monkeypatch):
    """C(D(w)) == id with every stack leaf routed through the interpreted
    Pallas kernels end to end (the CPU validation backend)."""
    monkeypatch.setenv(dispatch.ENV_VAR, "pallas-interpret")
    cfg = tiny_dense(compute_dtype=jnp.float32)
    model = build_model(cfg)
    specs = model.specs()
    small = build_model(ops.coalesce_config(cfg, ML))
    p_small = small.init(jax.random.PRNGKey(3))
    de = ops.make_decoalesce_fn(specs, cfg, ML)(p_small)
    rt = ops.make_coalesce_fn(specs, cfg, ML)(de)
    assert _tree_err(rt, p_small) <= 1e-5


def test_coalesce_pair_degenerate_dims_fall_back_to_xla():
    """Odd/prime dims collapse divisor_block to 1; the pallas backends must
    hand those to the XLA implementation (and stay correct)."""
    w = jax.random.normal(jax.random.PRNGKey(4), (514, 6), jnp.float32)  # 257 prime
    got = dispatch.dispatch("coalesce_pair", w, axis=0, w0=0.5,
                            backend="pallas-interpret")
    want = ref.coalesce_pair_ref(w, axis=0, w0=0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    # prime non-projected dim takes the same guard
    w2 = jax.random.normal(jax.random.PRNGKey(5), (257, 8), jnp.float32)
    got2 = dispatch.dispatch("coalesce_pair", w2, axis=1, w0=1.0,
                             backend="pallas-interpret")
    np.testing.assert_allclose(np.asarray(got2),
                               np.asarray(ref.coalesce_pair_ref(w2, axis=1, w0=1.0)),
                               atol=1e-5)
