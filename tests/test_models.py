"""Model behaviour: train step finiteness per family, decode==forward
consistency (KV/state cache correctness), prefill cache correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import ALL_FAMILIES, batch_for, fast_tc
from repro.models import lm as lm_lib
from repro.models.api import (build_model, init_train_state, make_prefill_step,
                              make_serve_step, make_train_step)
from repro.param import is_spec


@pytest.mark.parametrize("fam", sorted(ALL_FAMILIES))
def test_train_step_finite(fam):
    cfg = ALL_FAMILIES[fam]()
    tc = fast_tc()
    model = build_model(cfg)
    params, opt = init_train_state(model, tc, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, tc))
    batch = batch_for(cfg)
    params, opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    for leaf in jax.tree.leaves(params):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


@pytest.mark.parametrize("fam", sorted(ALL_FAMILIES))
def test_decode_matches_forward(fam):
    """Prefill tokens[:T] then decode position T; logits must match the full
    forward at position T -- verifies every cache type (KV, MLA latent,
    mamba conv+ssm state, xLSTM matrix/scalar memory, cross K/V).

    capacity_factor is raised to the dropless regime for MoE configs: with
    tight capacity, prefill tokens can be dropped by popular experts while a
    lone decode token never is -- an inherent (and intended) property of
    GShard-style capacity dispatch, not a cache bug."""
    cfg = ALL_FAMILIES[fam](compute_dtype=jnp.float32, capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    batch = batch_for(cfg, B, S)
    full = model.forward_logits(params, batch)  # [B,S,V]

    prefill = make_prefill_step(model)
    serve = make_serve_step(model)
    T = S - 1
    pre_batch = {k: (v[:, :T] if k in ("tokens", "labels") else v) for k, v in batch.items()}
    lg_pre, caches = prefill(params, pre_batch["tokens"],
                             pre_batch.get("img_embeds"), pre_batch.get("enc_frames"))
    np.testing.assert_allclose(np.asarray(lg_pre, np.float32),
                               np.asarray(full[:, T - 1], np.float32), atol=3e-3, rtol=3e-3)

    # grow cache buffers from prefill length T to max_seq S
    cs = lm_lib.cache_specs(cfg, B, S)

    def grow(buf, spec):
        if buf.shape == tuple(spec.shape):
            return buf.astype(spec.dtype or buf.dtype)
        pads = [(0, t - s) for s, t in zip(buf.shape, spec.shape)]
        return jnp.pad(buf, pads).astype(spec.dtype or buf.dtype)

    caches = jax.tree.map(grow, caches, cs, is_leaf=lambda x: is_spec(x))
    lg_dec, _ = serve(params, caches, batch["tokens"][:, T:T + 1],
                      jnp.full((B,), T, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_dec, np.float32),
                               np.asarray(full[:, T], np.float32), atol=3e-3, rtol=3e-3)


def test_grad_accum_equivalence():
    """grad_accum=2 must equal one big batch step (same data)."""
    from helpers import tiny_dense

    cfg = tiny_dense(compute_dtype=jnp.float32)
    model = build_model(cfg)
    tc1 = fast_tc(grad_accum=1)
    tc2 = fast_tc(grad_accum=2)
    params, opt = init_train_state(model, tc1, jax.random.PRNGKey(0))
    batch = batch_for(cfg, B=4, S=16)
    s1 = jax.jit(make_train_step(model, tc1))
    s2 = jax.jit(make_train_step(model, tc2))
    p1, _, m1 = s1(params, opt, batch)
    micro = jax.tree.map(lambda x: x.reshape((2, 2) + x.shape[1:]), batch)
    p2, _, m2 = s2(params, opt, micro)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   atol=2e-5, rtol=2e-5)


def test_mtp_head_trains():
    from helpers import tiny_mla

    cfg = tiny_mla(mtp_depth=1)
    tc = fast_tc()
    model = build_model(cfg)
    params, opt = init_train_state(model, tc, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, tc))
    _, _, metrics = step(params, opt, batch_for(cfg))
    assert "mtp_ce" in metrics and np.isfinite(float(metrics["mtp_ce"]))


def test_moe_aux_loss_present():
    from helpers import tiny_moe

    cfg = tiny_moe()
    tc = fast_tc()
    model = build_model(cfg)
    params, opt = init_train_state(model, tc, jax.random.PRNGKey(0))
    _, _, metrics = jax.jit(make_train_step(model, tc))(params, opt, batch_for(cfg))
    assert float(metrics["moe_aux"]) > 0.0


def test_vit_trains():
    from repro.configs.paper_models import deit_proxy
    from repro.data import vision_batch
    from repro.models.vit import n_patches, patch_dim

    cfg = deit_proxy(d_model=64, n_layers=2)
    tc = fast_tc()
    model = build_model(cfg)
    params, opt = init_train_state(model, tc, jax.random.PRNGKey(0))
    vb = vision_batch(0, 0, 4, n_patches(cfg), patch_dim(cfg), cfg.n_classes)
    _, _, metrics = jax.jit(make_train_step(model, tc))(params, opt, vb)
    assert np.isfinite(float(metrics["loss"]))
