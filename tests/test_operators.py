"""Unit tests for the paper's three operators (Eqs. 1-13) and their invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import ALL_FAMILIES, batch_for, tiny_dense
from repro.config import MultiLevelConfig
from repro.core import operators as ops
from repro.core import projections as proj
from repro.models.api import build_model
from repro.param import struct_tree

ML = MultiLevelConfig(n_levels=2)


@pytest.mark.parametrize("n", [4, 8, 64, 768])
@pytest.mark.parametrize("variant", ["stack", "adj"])
def test_width_matrix_invariants(n, variant):
    m = proj.width_mats(n, variant)
    np.testing.assert_allclose(m.T_out @ m.F_out, np.eye(n // 2), atol=1e-12)
    np.testing.assert_allclose(m.F_in @ m.T_in, np.eye(n // 2), atol=1e-12)
    assert np.linalg.matrix_rank(m.F_out) == n // 2  # full column rank (paper req.)


@pytest.mark.parametrize("L", [1, 2, 3, 7, 58, 61])
@pytest.mark.parametrize("variant", ["adj", "stack"])
def test_depth_matrix_invariants(L, variant):
    d = proj.depth_mats(L, variant)
    L2 = d.R.shape[1]
    assert L2 == (L + 1) // 2
    np.testing.assert_allclose(d.G @ d.R, np.eye(L2), atol=1e-12)
    # paper Eq. 9 condition: column sums of R G equal 1 (value-scale stability)
    np.testing.assert_allclose((d.R @ d.G).sum(0), np.ones(L), atol=1e-12)


@pytest.mark.parametrize("fam", sorted(ALL_FAMILIES))
def test_coalesce_shapes_match_small_model(fam):
    cfg = ALL_FAMILIES[fam]()
    model = build_model(cfg)
    small = build_model(ops.coalesce_config(cfg, ML))
    params = model.init(jax.random.PRNGKey(0))
    co = ops.make_coalesce_fn(model.specs(), cfg, ML)(params)
    want = jax.tree.map(lambda s: tuple(s.shape), struct_tree(small.specs()))
    got = jax.tree.map(lambda x: tuple(x.shape), co)
    assert got == want


@pytest.mark.parametrize("fam", sorted(ALL_FAMILIES))
def test_cd_identity(fam):
    """C(D(w_small)) == w_small for the paper's averaging matrices."""
    cfg = ALL_FAMILIES[fam]()
    model = build_model(cfg)
    small = build_model(ops.coalesce_config(cfg, ML))
    small_params = small.init(jax.random.PRNGKey(1))
    de = ops.make_decoalesce_fn(model.specs(), cfg, ML)(small_params)
    rt = ops.make_coalesce_fn(model.specs(), cfg, ML)(de)
    for a, b in zip(jax.tree.leaves(rt), jax.tree.leaves(small_params)):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   atol=1e-5)


def test_width_decoalesce_function_preserving():
    """Paper Appendix G: width-only de-coalescing preserves the function
    (exactly, for untied embeddings)."""
    cfg = tiny_dense(compute_dtype=jnp.float32, qk_norm=False, tie_embeddings=False)
    small_cfg = ops.coalesce_config(cfg, ML, width=True, depth=False)
    model, small = build_model(cfg), build_model(small_cfg)
    p_small = small.init(jax.random.PRNGKey(2))
    p_large = ops.make_decoalesce_fn(model.specs(), cfg, ML, width=True, depth=False)(p_small)
    batch = batch_for(cfg)
    lg_small = small.forward_logits(p_small, batch)
    lg_large = model.forward_logits(p_large, batch)
    np.testing.assert_allclose(np.asarray(lg_large, np.float32),
                               np.asarray(lg_small, np.float32), atol=2e-4, rtol=2e-4)


def test_width_decoalesce_tied_embedding_scale():
    """Tied embeddings break exact preservation by exactly 2x at the logits:
    the embedding's width axis is 'out' for the lookup but 'in' for the tied
    unembed matmul (duplicated features double the inner product).  The paper
    does not discuss this; we pin the factor here and note it in DESIGN.md §4.
    """
    cfg = tiny_dense(compute_dtype=jnp.float32, qk_norm=False, tie_embeddings=True)
    small_cfg = ops.coalesce_config(cfg, ML, width=True, depth=False)
    model, small = build_model(cfg), build_model(small_cfg)
    p_small = small.init(jax.random.PRNGKey(2))
    p_large = ops.make_decoalesce_fn(model.specs(), cfg, ML, width=True, depth=False)(p_small)
    batch = batch_for(cfg)
    lg_small = np.asarray(small.forward_logits(p_small, batch), np.float32)
    lg_large = np.asarray(model.forward_logits(p_large, batch), np.float32)
    np.testing.assert_allclose(lg_large, 2.0 * lg_small, atol=2e-4, rtol=2e-4)


def test_symmetric_neuron_gradients():
    """Paper Appendix G: mirrored neuron pairs of a de-coalesced model receive
    identical gradients (the degeneracy Interpolation exists to break)."""
    cfg = tiny_dense(compute_dtype=jnp.float32, qk_norm=False)
    small_cfg = ops.coalesce_config(cfg, ML, width=True, depth=False)
    model, small = build_model(cfg), build_model(small_cfg)
    p_small = small.init(jax.random.PRNGKey(3))
    p_large = ops.make_decoalesce_fn(model.specs(), cfg, ML, width=True, depth=False)(p_small)
    batch = batch_for(cfg)
    g = jax.grad(lambda p: model.loss(p, batch)[0])(p_large)
    gw = np.asarray(g["stages"]["stage_0"]["b0"]["ffn"]["w_up"], np.float32)  # [L,E,F]
    F = gw.shape[-1]
    np.testing.assert_allclose(gw[..., : F // 2], gw[..., F // 2:], atol=1e-5)


def test_interpolation_eq13():
    a = {"w": jnp.ones((4, 4)), "b": jnp.zeros((3,))}
    b = {"w": jnp.zeros((4, 4)), "b": jnp.ones((3,))}
    out = ops.interpolate(a, b, 0.25)
    np.testing.assert_allclose(np.asarray(out["w"]), 0.75 * np.ones((4, 4)), atol=1e-7)
    np.testing.assert_allclose(np.asarray(out["b"]), 0.25 * np.ones((3,)), atol=1e-7)


def test_coalesce_config_halves_everything():
    from repro.configs import get_config

    cfg = get_config("deepseek-v3-671b")
    small = ops.coalesce_config(cfg, ML)
    assert small.d_model == cfg.d_model // 2
    assert small.n_heads == cfg.n_heads // 2
    assert small.q_lora_rank == cfg.q_lora_rank // 2
    assert small.kv_lora_rank == cfg.kv_lora_rank // 2
    assert small.moe_d_ff == cfg.moe_d_ff // 2
    assert small.n_experts == cfg.n_experts  # experts preserved by default
    assert small.stages[0].repeats == 2  # 3 -> 2 (odd tail)
    assert small.stages[1].repeats == 29  # 58 -> 29
    assert small.resolved_head_dim == cfg.resolved_head_dim  # whole-head merging


def test_expert_coalescing_beyond_paper():
    from helpers import tiny_moe

    cfg = tiny_moe(coalesce_experts=True)
    model = build_model(cfg)
    small_cfg = ops.coalesce_config(cfg, ML)
    assert small_cfg.n_experts == cfg.n_experts // 2
    params = model.init(jax.random.PRNGKey(0))
    co = ops.make_coalesce_fn(model.specs(), cfg, ML)(params)
    small = build_model(small_cfg)
    want = jax.tree.map(lambda s: tuple(s.shape), struct_tree(small.specs()))
    got = jax.tree.map(lambda x: tuple(x.shape), co)
    assert got == want


def test_draft_projection_is_the_level_transition():
    """``make_draft_projection`` (the serving-time self-speculative draft)
    must be exactly the level-1 Coalescing transition: same config as
    ``coalesce_config``, same projected params as ``make_coalesce_fn`` --
    and re-projecting after a weight change tracks the new weights (the
    hot-reload contract ``EngineCore.set_params`` relies on)."""
    cfg = tiny_dense(compute_dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(4))
    draft_cfg, project = ops.make_draft_projection(model.specs(), cfg, ML)
    assert draft_cfg == ops.coalesce_config(cfg, ML)
    want = ops.make_coalesce_fn(model.specs(), cfg, ML)(params)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                            np.asarray(b)),
                 project(params), want)
    # draft params are a pure function of the serving params: new weights in,
    # new draft out (no per-instance state to invalidate)
    p2 = jax.tree.map(lambda x: x * 2.0, params)
    got2 = project(p2)
    want2 = ops.make_coalesce_fn(model.specs(), cfg, ML)(p2)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                            np.asarray(b)),
                 got2, want2)
