"""Sharding rules + a reduced dry-run in a subprocess (8 placeholder devices)
-- proving the mesh/sharding machinery without pinning 512 devices into the
test process."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import RULES, logical_spec


class FakeMesh:
    """Duck-typed mesh for pure spec-rule tests (axis_names + shape only)."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


SINGLE = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_fsdp_tp_placement():
    # FFN weight: embed -> fsdp axes, mlp -> model
    assert logical_spec((7168, 2048), ("embed", "mlp"), SINGLE) == P("data", "model")
    assert logical_spec((7168, 2048), ("embed", "mlp"), MULTI) == P(("pod", "data"), "model")
    # expert weights: EP on model, embed FSDP'd
    assert logical_spec((256, 7168, 2048), ("experts", "embed", "moe_mlp"), SINGLE) == \
        P("model", "data", None)


def test_nondivisible_axes_replicate():
    # 40 heads on 16-way model axis -> replicated (documented in qwen3-14b)
    assert logical_spec((5120, 40, 128), ("embed", "heads", "head_dim"), SINGLE) == \
        P("data", None, None)
    # batch=1 long-context decode cannot shard batch
    assert logical_spec((1, 1), ("batch", "seq"), SINGLE) == P(None, None)


def test_no_mesh_axis_used_twice():
    spec = logical_spec((64, 64), ("vocab", "heads"), SINGLE)
    flat = [s for s in spec if s is not None]
    assert len(flat) == len(set(flat)) == 1  # "model" assigned once only


def test_cache_seq_sharding():
    assert logical_spec((128, 32768, 8, 128),
                        ("batch", "cache_seq", "cache_kv_heads", "head_dim"), SINGLE) == \
        P("data", "model", None, None)


def test_batch_axis_sharding_divisibility():
    """The launcher's batch shardings: leading dim over the data axes when
    divisible, replicated otherwise (ragged smoke batches must still lower)."""
    mesh = FakeMesh({"data": 2, "model": 2})
    assert logical_spec((8, 16), ("batch", "seq"), mesh) == P("data", None)
    assert logical_spec((3, 16), ("batch", "seq"), mesh) == P(None, None)


def test_batch_shardings_tree():
    from repro.distributed import batch_shardings

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    like = {"tokens": jax.ShapeDtypeStruct((4, 16), np.int32),
            "labels": jax.ShapeDtypeStruct((4, 16), np.int32)}
    sh = batch_shardings(like, mesh)
    assert set(sh) == {"tokens", "labels"}
    assert sh["tokens"].spec == P("data", None)


def test_data_shard_index_single_process():
    """One process owns every shard-0 batch regardless of mesh shape, so
    cross-mesh resume equivalence is well-posed on this container."""
    from repro.distributed import data_shard_index

    assert data_shard_index() == jax.process_index() == 0
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert data_shard_index(mesh) == 0


@pytest.mark.slow
def test_cross_mesh_vcycle_restore_equivalence(tmp_path):
    """Elastic mid-V-cycle re-shard: a run killed mid-upward-sweep under mesh
    A (so a ``params_before_*`` stash is live) restores under mesh B -- in
    BOTH directions, 1x1 <-> 2x2.  Pins three things: (1) the restored
    params/opt/stash values are EXACTLY the checkpoint's regardless of target
    mesh, (2) the resumed sharded run replays the exact segment schedule of
    an uninterrupted unsharded reference, (3) final params stay allclose to
    that reference.  (3) is tolerance-bound: a single cross-mesh step differs
    only by reduction-order roundoff (~3e-8 measured), but Adam's
    sign-normalized updates amplify it over the remaining steps, so the drift
    scales with lr -- the test trains at peak_lr=3e-4 and the 1e-2 atol is a
    gross-error guard (a wrong leaf/stash or a broken sharded projection --
    e.g. the concatenate-with-self GSPMD miscompile this test originally
    caught in ``_stack_decoalesce`` -- lands at the O(1e-1)+ scale); bitwise
    restore correctness is pinned by (1), not (3).  Runs in a subprocess with
    4 forced host devices (the test process must keep its single real CPU
    device)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from helpers import fast_tc, tiny_dense
        from repro.checkpoint import CheckpointManager
        from repro.config import MultiLevelConfig
        from repro.core.vcycle import VCycleRunner
        from repro.data import MarkovLM, lm_batch
        from repro.launch.train import make_vcycle_save_cb, restore_vcycle_state

        class Preempted(RuntimeError):
            pass

        cfg = tiny_dense(d_model=32, d_ff=64, vocab_size=128,
                         compute_dtype=jnp.float32)
        tc = fast_tc(steps=12, batch_size=4, seq_len=16, log_every=2,
                     peak_lr=3e-4)
        ml = MultiLevelConfig(n_levels=2, alpha=0.25, e_a_frac=0.25,
                              e_small_frac=0.5)
        chain = MarkovLM(128)
        bf = lambda s: lm_batch(chain, 0, s, tc.batch_size, tc.seq_len)
        ref = VCycleRunner(cfg, ml, tc, bf, seed=0).run()

        def exact_equal(ta, tb, name):
            for a, b in zip(jax.tree.leaves(ta), jax.tree.leaves(tb)):
                d = np.abs(np.asarray(jax.device_get(a), np.float64)
                           - np.asarray(jax.device_get(b), np.float64)).max()
                assert d == 0.0, (name, float(d))

        for k, (shape_a, shape_b) in enumerate([((1, 1), (2, 2)),
                                                ((2, 2), (1, 1))]):
            ckdir = f"{os.environ['CK_BASE']}/pair{k}"
            mesh_a = jax.make_mesh(shape_a, ("data", "model"))
            runner = VCycleRunner(cfg, ml, tc, bf, seed=0, mesh=mesh_a)
            cm = CheckpointManager(ckdir)
            save_cb = make_vcycle_save_cb(cm, schedule=runner.plan)

            def killing_cb(state, params, opt_state):
                save_cb(state, params, opt_state)
                if state.global_step == 6:  # mid-upward-sweep: stash is live
                    raise Preempted

            try:
                runner.run(ckpt_cb=killing_cb, ckpt_every=2)
                raise AssertionError("kill never fired")
            except Preempted:
                pass
            cm.wait()

            mesh_b = jax.make_mesh(shape_b, ("data", "model"))
            runner2 = VCycleRunner(cfg, ml, tc, bf, seed=0, mesh=mesh_b)
            state, params, opt = restore_vcycle_state(cm, runner2, tc)
            assert (state.phase, state.level, state.global_step) == ("up", 1, 6)
            assert list(state.params_before) == [0]
            # the stash really landed on mesh B...
            leaf = jax.tree.leaves(state.params_before[0])[0]
            assert leaf.sharding.mesh.shape == dict(zip(("data", "model"),
                                                        shape_b))
            # ...and re-sharding changed the VALUES not at all: an unsharded
            # restore of the same checkpoint must agree bit-for-bit
            r_plain = VCycleRunner(cfg, ml, tc, bf, seed=0)
            s0, p0, o0 = restore_vcycle_state(cm, r_plain, tc)
            exact_equal(p0, params, "params")
            exact_equal(o0, opt, "opt")
            exact_equal(s0.params_before[0], state.params_before[0], "stash")

            out = runner2.run(state=state, params=params, opt_state=opt)
            assert out.history.step == ref.history.step
            assert out.history.level == ref.history.level
            for a, b in zip(jax.tree.leaves(out.params),
                            jax.tree.leaves(ref.params)):
                np.testing.assert_allclose(np.asarray(a, np.float64),
                                           np.asarray(b, np.float64),
                                           atol=1e-2)
            np.testing.assert_allclose(out.history.loss, ref.history.loss,
                                       atol=1e-2)
            print(f"pair{k} OK")
        print("CROSS_MESH_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src" + os.pathsep + "tests",
               CK_BASE=str(tmp_path))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env,
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "CROSS_MESH_OK" in out.stdout


@pytest.mark.slow
def test_reduced_dryrun_subprocess(tmp_path):
    """Lower+compile a smoke config on an 8-device placeholder mesh in a
    subprocess (mirrors launch/dryrun.py's bootstrap ordering)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, json
        from repro.configs import get_config
        from repro.distributed import param_shardings, set_mesh_ctx
        from repro.launch.analysis import analyze_compiled, memory_summary
        from repro.models.api import build_model, make_train_step
        from repro.optim import adamw_init_specs
        from repro.param import struct_tree
        from repro.config import TrainConfig
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        set_mesh_ctx(mesh)
        cfg = get_config("tinyllama-1.1b", smoke=True).replace(
            d_model=64, vocab_size=512)
        tc = TrainConfig(steps=10, warmup_steps=1, batch_size=4, seq_len=32)
        model = build_model(cfg)
        specs = model.specs()
        p = struct_tree(specs, dtype=cfg.param_dtype)
        ps = param_shardings(specs, mesh)
        o_specs = adamw_init_specs(specs, tc)
        os_ = struct_tree(o_specs, dtype=tc.opt_dtype)
        osh = param_shardings(o_specs, mesh)
        batch = {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((4, 32), jnp.int32)}
        bsh = {k: NamedSharding(mesh, P("data", None)) for k in batch}
        step = make_train_step(model, tc)
        co = jax.jit(step, in_shardings=(ps, osh, bsh)).lower(p, os_, batch).compile()
        rl, colls = analyze_compiled(co, 8, 1.0)
        print(json.dumps({"flops": rl.flops_per_device,
                          "colls": colls["total"]["count"],
                          "mem": memory_summary(co)["peak_bytes_est"]}))
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True,
                         env=env, cwd=os.path.join(os.path.dirname(__file__), ".."),
                         timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["flops"] > 0 and rec["colls"] > 0 and rec["mem"] > 0
