"""Sharding rules + a reduced dry-run in a subprocess (8 placeholder devices)
-- proving the mesh/sharding machinery without pinning 512 devices into the
test process."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import RULES, logical_spec


class FakeMesh:
    """Duck-typed mesh for pure spec-rule tests (axis_names + shape only)."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


SINGLE = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_fsdp_tp_placement():
    # FFN weight: embed -> fsdp axes, mlp -> model
    assert logical_spec((7168, 2048), ("embed", "mlp"), SINGLE) == P("data", "model")
    assert logical_spec((7168, 2048), ("embed", "mlp"), MULTI) == P(("pod", "data"), "model")
    # expert weights: EP on model, embed FSDP'd
    assert logical_spec((256, 7168, 2048), ("experts", "embed", "moe_mlp"), SINGLE) == \
        P("model", "data", None)


def test_nondivisible_axes_replicate():
    # 40 heads on 16-way model axis -> replicated (documented in qwen3-14b)
    assert logical_spec((5120, 40, 128), ("embed", "heads", "head_dim"), SINGLE) == \
        P("data", None, None)
    # batch=1 long-context decode cannot shard batch
    assert logical_spec((1, 1), ("batch", "seq"), SINGLE) == P(None, None)


def test_no_mesh_axis_used_twice():
    spec = logical_spec((64, 64), ("vocab", "heads"), SINGLE)
    flat = [s for s in spec if s is not None]
    assert len(flat) == len(set(flat)) == 1  # "model" assigned once only


def test_cache_seq_sharding():
    assert logical_spec((128, 32768, 8, 128),
                        ("batch", "cache_seq", "cache_kv_heads", "head_dim"), SINGLE) == \
        P("data", "model", None, None)


@pytest.mark.slow
def test_reduced_dryrun_subprocess(tmp_path):
    """Lower+compile a smoke config on an 8-device placeholder mesh in a
    subprocess (mirrors launch/dryrun.py's bootstrap ordering)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, json
        from repro.configs import get_config
        from repro.distributed import param_shardings, set_mesh_ctx
        from repro.launch.analysis import analyze_compiled, memory_summary
        from repro.models.api import build_model, make_train_step
        from repro.optim import adamw_init_specs
        from repro.param import struct_tree
        from repro.config import TrainConfig
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        set_mesh_ctx(mesh)
        cfg = get_config("tinyllama-1.1b", smoke=True).replace(
            d_model=64, vocab_size=512)
        tc = TrainConfig(steps=10, warmup_steps=1, batch_size=4, seq_len=32)
        model = build_model(cfg)
        specs = model.specs()
        p = struct_tree(specs, dtype=cfg.param_dtype)
        ps = param_shardings(specs, mesh)
        o_specs = adamw_init_specs(specs, tc)
        os_ = struct_tree(o_specs, dtype=tc.opt_dtype)
        osh = param_shardings(o_specs, mesh)
        batch = {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((4, 32), jnp.int32)}
        bsh = {k: NamedSharding(mesh, P("data", None)) for k in batch}
        step = make_train_step(model, tc)
        co = jax.jit(step, in_shardings=(ps, osh, bsh)).lower(p, os_, batch).compile()
        rl, colls = analyze_compiled(co, 8, 1.0)
        print(json.dumps({"flops": rl.flops_per_device,
                          "colls": colls["total"]["count"],
                          "mem": memory_summary(co)["peak_bytes_est"]}))
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True,
                         env=env, cwd=os.path.join(os.path.dirname(__file__), ".."),
                         timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["flops"] > 0 and rec["colls"] > 0 and rec["mem"] > 0
