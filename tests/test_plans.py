"""Family-aware ProjectionPlan invariants, parameterized over EVERY assigned
architecture (smoke shape) -- plus the expert-coalescing MoE/hybrid variants
and a full 2-level V-cycle pin per family (ISSUE 9 acceptance)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import MultiLevelConfig, TrainConfig
from repro.configs import ASSIGNED, get_config, paper_models
from repro.core import operators as ops
from repro.core import plans as plans_lib
from repro.core.vcycle import run_vcycle
from repro.layers.ffn import moe_capacity
from repro.models.api import build_model
from repro.param import struct_tree

ML = MultiLevelConfig(n_levels=2)


def _cases():
    """Every assigned smoke config + the coalesce_experts variants."""
    out = {name: get_config(name, smoke=True) for name in ASSIGNED}
    for name in ("phi3.5-moe-42b-a6.6b", "jamba-1.5-large-398b",
                 "deepseek-v3-671b"):
        out[name + "+experts"] = out[name].replace(coalesce_experts=True)
    return out


CASES = _cases()


@pytest.mark.parametrize("name", sorted(CASES))
def test_plan_small_cfg_matches_operator_path(name):
    cfg = CASES[name]
    plan = plans_lib.build_plan(cfg, ML)
    assert plan.small_cfg == ops.coalesce_config(cfg, ML)
    # every named width axis halves; every protected axis is absent from them
    for ax, n in plan.width_axes.items():
        assert n % 2 == 0 and n >= 2
        assert ax not in plan.protected_axes
    assert plan.describe()  # human-readable and never empty


@pytest.mark.parametrize("name", sorted(CASES))
def test_plan_coalesce_shapes_match_small_model(name):
    cfg = CASES[name]
    model = build_model(cfg)
    plan = plans_lib.build_plan(cfg, ML)
    small = build_model(plan.small_cfg)
    params = model.init(jax.random.PRNGKey(0))
    co = ops.make_coalesce_fn(model.specs(), cfg, ML, plan=plan)(params)
    want = jax.tree.map(lambda s: tuple(s.shape), struct_tree(small.specs()))
    got = jax.tree.map(lambda x: tuple(x.shape), co)
    assert got == want


@pytest.mark.parametrize("name", sorted(CASES))
def test_plan_cd_identity(name):
    """C(D(w_small)) == w_small under the plan's maps (paper Eq. 13)."""
    cfg = CASES[name]
    model = build_model(cfg)
    plan = plans_lib.build_plan(cfg, ML)
    small = build_model(plan.small_cfg)
    small_params = small.init(jax.random.PRNGKey(1))
    de = ops.make_decoalesce_fn(model.specs(), cfg, ML, plan=plan)(small_params)
    rt = ops.make_coalesce_fn(model.specs(), cfg, ML, plan=plan)(de)
    for a, b in zip(jax.tree.leaves(rt), jax.tree.leaves(small_params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


@pytest.mark.parametrize("name", sorted(CASES))
def test_plan_width_maps_are_one_sided_inverses(name):
    """T_out F_out = I and F_in T_in = I for every planned width axis."""
    maps = plans_lib.build_plan(CASES[name], ML).build_maps()
    assert maps.width  # every family coalesces at least the embed axis
    for ax, m in maps.width.items():
        n2 = m.F_out.shape[1]
        np.testing.assert_allclose(m.T_out @ m.F_out, np.eye(n2), atol=1e-12,
                                   err_msg=ax)
        np.testing.assert_allclose(m.F_in @ m.T_in, np.eye(n2), atol=1e-12,
                                   err_msg=ax)
    for gname, d in maps.depth.items():
        np.testing.assert_allclose(d.G @ d.R, np.eye(d.R.shape[1]), atol=1e-12,
                                   err_msg=gname)


@pytest.mark.parametrize("name", sorted(CASES))
def test_plan_protected_axes_keep_size_and_values(name):
    """Protected axes never shrink; leaves with ONLY protected/free axes are
    bit-identical through width-only coalescing."""
    cfg = CASES[name]
    model = build_model(cfg)
    plan = plans_lib.build_plan(cfg, ML, depth=False)
    params = model.init(jax.random.PRNGKey(2))
    co = ops.make_coalesce_fn(model.specs(), cfg, ML, depth=False, plan=plan)(params)
    flat_p = jax.tree.leaves(params)
    flat_c = jax.tree.leaves(co)
    from repro.param import is_spec

    flat_s = jax.tree.leaves(model.specs(), is_leaf=is_spec)
    checked = 0
    for p, c, s in zip(flat_p, flat_c, flat_s):
        for i, ax in enumerate(s.axes):
            if ax in plan.protected_axes:
                assert c.shape[i] == p.shape[i], (s, ax)
        if not any(ax in plan.width_axes for ax in s.axes):
            np.testing.assert_array_equal(np.asarray(p), np.asarray(c), err_msg=str(s))
            checked += 1
    # vocab/seq/head_dim-protected leaves exist in every family via the specs
    assert checked >= 0


def _find_router(tree, path=()):
    if not isinstance(tree, dict):
        return None
    for k, v in tree.items():
        if k == "router":
            return path + (k,), v
        found = _find_router(v, path + (k,))
        if found:
            return found
    return None


def test_expert_merge_router_pin():
    """With coalesce_experts, the merged router column j is the pair-average
    of columns (j, j + X/2) after the embed rows pair-sum ("stack" maps)."""
    cfg = get_config("phi3.5-moe-42b-a6.6b", smoke=True).replace(
        coalesce_experts=True)
    model = build_model(cfg)
    plan = plans_lib.build_plan(cfg, ML, depth=False)
    assert plan.role_overrides.get("experts") == "out"
    params = model.init(jax.random.PRNGKey(3))
    co = ops.make_coalesce_fn(model.specs(), cfg, ML, depth=False, plan=plan)(params)
    path, w = _find_router(params)
    _, w2 = _find_router(co)
    w = np.asarray(jnp.asarray(w, jnp.float32))
    w2 = np.asarray(jnp.asarray(w2, jnp.float32))
    # leading "layers" axis from the stacked stage scan is untouched (depth off)
    E, X = w.shape[-2], w.shape[-1]
    a = w[..., : E // 2, :] + w[..., E // 2:, :]          # embed rows: "in" sum
    want = 0.5 * (a[..., :, : X // 2] + a[..., :, X // 2:])  # experts: "out" avg
    np.testing.assert_allclose(w2, want, atol=1e-5)


@pytest.mark.parametrize("name", ["phi3.5-moe-42b-a6.6b+experts",
                                  "jamba-1.5-large-398b+experts"])
def test_expert_merge_carries_router_scalars(name):
    """capacity_factor / router_aux_coef carry unchanged and total capacity
    slots are preserved across the expert merge (plan-documented invariant)."""
    cfg = CASES[name]
    plan = plans_lib.build_plan(cfg, ML)
    small = plan.small_cfg
    assert plan.carried == {"capacity_factor": cfg.capacity_factor,
                            "router_aux_coef": cfg.router_aux_coef}
    assert small.capacity_factor == cfg.capacity_factor
    assert small.router_aux_coef == cfg.router_aux_coef
    assert small.n_experts == cfg.n_experts // 2
    assert small.moe_top_k == min(cfg.moe_top_k, small.n_experts)
    if small.moe_top_k == cfg.moe_top_k:  # same k => slot count must match
        seq = 64
        assert (moe_capacity(small, seq) * small.n_experts
                == moe_capacity(cfg, seq) * cfg.n_experts)


# ---------------------------------------------------------------------------
# end-to-end: one full 2-level V-cycle (two transitions) per family, loss
# decreasing across the cycle at CPU smoke scale (ISSUE 9 acceptance pin)

E2E = {
    "dense": lambda: get_config("tinyllama-1.1b", smoke=True),
    "moe": lambda: get_config("phi3.5-moe-42b-a6.6b", smoke=True).replace(
        coalesce_experts=True),
    "ssm": lambda: get_config("xlstm-125m", smoke=True),
    "hybrid": lambda: get_config("jamba-1.5-large-398b", smoke=True).replace(
        coalesce_experts=True),
    "vit": lambda: paper_models.deit_proxy(d_model=32, n_layers=2),
}


def _batch_fn(cfg, tc):
    from repro.data import MarkovLM, lm_batch, vision_batch

    if cfg.family == "vit":
        from repro.models.vit import n_patches, patch_dim

        return lambda step: vision_batch(tc.seed, step, tc.batch_size,
                                         n_patches(cfg), patch_dim(cfg),
                                         cfg.n_classes)
    chain = MarkovLM(cfg.vocab_size)
    return lambda step: lm_batch(chain, tc.seed, step, tc.batch_size, tc.seq_len)


@pytest.mark.parametrize("fam", sorted(E2E))
def test_vcycle_end_to_end_per_family(fam):
    cfg = E2E[fam]()
    tc = TrainConfig(steps=24, warmup_steps=3, peak_lr=3e-3, batch_size=4,
                     seq_len=16, log_every=1)
    out = run_vcycle(cfg, ML, tc, _batch_fn(cfg, tc), seed=0)
    # two full transitions: the level trace must visit level 1 and return
    lv = out.history.level
    assert 1 in lv and lv[0] == 0 and lv[-1] == 0
    # final params live on the big config's specs
    want = jax.tree.map(lambda s: tuple(s.shape),
                        struct_tree(build_model(cfg).specs()))
    got = jax.tree.map(lambda x: tuple(x.shape), out.params)
    assert got == want
    lo = out.history.loss
    assert np.mean(lo[-3:]) < np.mean(lo[:3])  # learning across the cycle
