"""Pins for the energy/CO2 accounting layer (core/flops.py; DESIGN.md §7).

These pin the MODEL, not hardware: joules = seconds x watts x PUE with
seconds = flops/(util*peak) and watts linear between the idle floor and TDP.
The FLOPs functions underneath stay pinned by tests/test_baselines.py.
"""
import pytest

from repro.core import flops as flops_lib
from repro.core.flops import DEVICES, US_GRID_KGCO2_PER_KWH, DevicePower, EnergyModel


def test_seconds_is_flops_over_achieved_flops():
    em = EnergyModel(DEVICES["tpu-v4"], utilization=0.5)
    assert em.seconds(275e12) == pytest.approx(1.0 / 0.5, rel=1e-12)
    # full utilization: exactly flops / peak
    em1 = EnergyModel(DEVICES["tpu-v4"], utilization=1.0)
    assert em1.seconds(275e12) == pytest.approx(1.0, rel=1e-12)


def test_watts_interpolates_idle_floor_to_tdp():
    d = DEVICES["a100"]
    lo = EnergyModel(d, utilization=1e-9).watts()
    hi = EnergyModel(d, utilization=1.0).watts()
    assert lo == pytest.approx(d.tdp_watts * d.idle_frac, rel=1e-6)
    assert hi == pytest.approx(d.tdp_watts, rel=1e-12)
    mid = EnergyModel(d, utilization=0.4).watts()
    assert lo < mid < hi


def test_joules_identity_and_linearity():
    em = EnergyModel(DEVICES["h100"], utilization=0.4, pue=1.25)
    f = 1e18
    assert em.joules(f) == pytest.approx(em.seconds(f) * em.watts() * 1.25,
                                         rel=1e-12)
    # energy is linear in FLOPs => a FLOPs saving IS the energy saving
    assert em.joules(2 * f) == pytest.approx(2 * em.joules(f), rel=1e-12)
    assert em.kgco2e(f) == pytest.approx(
        em.joules(f) / 3.6e6 * US_GRID_KGCO2_PER_KWH, rel=1e-12)


def test_report_and_convenience_wrapper_agree():
    r = flops_lib.energy_report(1e15, "tpu-v4", utilization=0.3, pue=1.1)
    em = EnergyModel(DEVICES["tpu-v4"], utilization=0.3, pue=1.1)
    assert r["joules"] == pytest.approx(em.joules(1e15), rel=1e-12)
    assert r["kwh"] == pytest.approx(r["joules"] / 3.6e6, rel=1e-12)
    assert r["kgco2e"] == pytest.approx(r["kwh"] * US_GRID_KGCO2_PER_KWH,
                                        rel=1e-12)
    assert r["device"] == "tpu-v4" and r["flops"] == 1e15


def test_validation_rejects_nonsense():
    with pytest.raises(ValueError):
        DevicePower("bad", peak_flops=0.0, tdp_watts=100.0, idle_frac=0.1)
    with pytest.raises(ValueError):
        DevicePower("bad", peak_flops=1e12, tdp_watts=100.0, idle_frac=1.0)
    with pytest.raises(ValueError):
        EnergyModel(DEVICES["tpu-v4"], utilization=0.0)
    with pytest.raises(ValueError):
        EnergyModel(DEVICES["tpu-v4"], utilization=1.5)
    with pytest.raises(ValueError):
        EnergyModel(DEVICES["tpu-v4"], pue=0.9)
    with pytest.raises(ValueError):
        EnergyModel(DEVICES["tpu-v4"], grid_kgco2_per_kwh=-1.0)


def test_every_catalog_device_is_sane():
    for name, d in DEVICES.items():
        assert d.name == name
        r = flops_lib.energy_report(1e15, name)
        assert r["seconds"] > 0 and r["joules"] > 0 and r["kgco2e"] > 0
