"""Per-host LOCAL checkpoint dirs (clusters without a shared filesystem).

The acceptance drills: a V-cycle killed mid-upward-sweep (live
``params_before_0`` stash) whose checkpoints were coordinated-saved by 2
processes into two DISJOINT ``local=True`` dirs resumes on 1 process (reading
the peer dir as a recovered pool), and a 1-process local save resumes on 2
processes (the missing objects travel over the coordination-service KV) --
both land allclose to the uninterrupted single-process reference, and the
local-dir restore is BIT-identical to the shared-dir restore of the same run.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import mp_arena, run_multiprocess
from repro.checkpoint import CheckpointManager, ObjectStore
from repro.checkpoint.manager import _flatten, _read_leaves
from repro.core.vcycle import VCycleRunner
from repro.launch.train import (make_batch_fn, make_vcycle_save_cb,
                                restore_vcycle_state)


def _flat(tree):
    return _flatten(jax.device_get(tree))


def _assert_trees(a, b, atol, err=""):
    a, b = _flat(a), _flat(b)
    assert a.keys() == b.keys()
    for k in a:
        np.testing.assert_allclose(np.asarray(a[k], np.float64),
                                   np.asarray(b[k], np.float64),
                                   atol=atol, err_msg=f"{err}:{k}")


# ---------------------------------------------------------------------------
# fast single-process guarantees


def test_local_manager_single_process_is_plain_v3(tmp_path):
    cm = CheckpointManager(str(tmp_path), local=True)
    assert cm.dedup  # local mode is v3-only
    st = {"params": {"w": jnp.arange(6.0)}}
    cm.save(3, st, meta={"step": 3})
    out, meta = cm.restore(jax.tree.map(jnp.zeros_like, st))
    assert meta["step"] == 3
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.arange(6.0))


def test_peer_dirs_resolve_missing_objects(tmp_path):
    """An object held only by a peer's recovered dir is found at restore."""
    own, peer = str(tmp_path / "own"), str(tmp_path / "peer")
    cm_writer = CheckpointManager(peer, local=True)
    st = {"params": {"w": jnp.arange(8.0)}}
    cm_writer.save(1, st, meta={"step": 1})
    # move the published manifest (but not the pool) to the "own" dir,
    # simulating the process-0 dir of a host whose chunks lived elsewhere
    os.makedirs(own)
    os.rename(os.path.join(peer, "manifest.json"),
              os.path.join(own, "manifest.json"))
    os.rename(os.path.join(peer, "step_00000001"),
              os.path.join(own, "step_00000001"))
    cm = CheckpointManager(own, peer_dirs=[peer])
    out, meta = cm.restore(jax.tree.map(jnp.zeros_like, st))
    assert meta["step"] == 1
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.arange(8.0))
    # without the peer pool the same restore must fail loudly
    with pytest.raises(FileNotFoundError, match="not found in any pool"):
        CheckpointManager(own).restore(jax.tree.map(jnp.zeros_like, st))


# ---------------------------------------------------------------------------
# the acceptance drills (2 real processes)


@pytest.mark.slow
def test_two_process_local_dirs_resume_on_one_process(tmp_path):
    """2-process save into two disjoint --ckpt-local-dir style dirs, killed
    right after the mid-upward-sweep save at global step 6; a SINGLE process
    resumes from local0 + the recovered local1 pool.  The restored trees are
    bit-identical to the shared-dir restore of the very same run, and the
    finished resume lands allclose to the uninterrupted reference."""
    res = run_multiprocess("""
        import os
        import jax
        from helpers import mp_arena
        from repro.checkpoint import CheckpointManager
        from repro.core.vcycle import VCycleRunner
        from repro.distributed import as_global_batch_fn
        from repro.launch.train import make_batch_fn, make_vcycle_save_cb

        class Preempted(RuntimeError):
            pass

        cfg, tc, ml = mp_arena()
        mesh = jax.make_mesh((2, 1), ("data", "model"))
        bf = as_global_batch_fn(make_batch_fn(cfg, tc, shard=0), mesh)
        runner = VCycleRunner(cfg, ml, tc, bf, seed=0, mesh=mesh)
        # BOTH paths from the same run: a shared-dir manager (the reference
        # layout) and a per-process local-dir manager (the layout under test);
        # same construction order on every rank keeps KV scopes aligned
        cm_shared = CheckpointManager(os.environ["CK_SHARED"])
        cm_local = CheckpointManager(
            os.environ["CK_BASE"] + f"/local{jax.process_index()}", local=True)
        cb_shared = make_vcycle_save_cb(cm_shared, schedule=runner.plan)
        cb_local = make_vcycle_save_cb(cm_local, schedule=runner.plan)

        def killing_cb(state, params, opt_state):
            cb_shared(state, params, opt_state)
            cb_local(state, params, opt_state)
            if state.global_step == 6:  # mid-upward-sweep: stash is live
                raise Preempted

        try:
            runner.run(ckpt_cb=killing_cb, ckpt_every=2)
            raise AssertionError("kill never fired")
        except Preempted:
            print("MP_KILLED_OK", flush=True)
    """, n=2, env={"CK_SHARED": str(tmp_path / "shared"),
                   "CK_BASE": str(tmp_path)})
    for rc, out in res:
        assert rc == 0, out[-3000:]
        assert "MP_KILLED_OK" in out

    cfg, tc, ml = mp_arena()
    bf = make_batch_fn(cfg, tc, shard=0)
    ref = VCycleRunner(cfg, ml, tc, bf, seed=0).run()

    # single-process restore: local0 is the primary, local1 a recovered pool
    cm_local = CheckpointManager(str(tmp_path / "local0"),
                                 peer_dirs=[str(tmp_path / "local1")])
    runner_l = VCycleRunner(cfg, ml, tc, bf, seed=0)
    state_l, params_l, opt_l = restore_vcycle_state(cm_local, runner_l, tc)
    assert (state_l.phase, state_l.level, state_l.global_step) == ("up", 1, 6)
    assert list(state_l.params_before) == [0]

    # the local-dir restore is BIT-identical to the shared-dir restore
    cm_shared = CheckpointManager(str(tmp_path / "shared"))
    runner_s = VCycleRunner(cfg, ml, tc, bf, seed=0)
    state_s, params_s, opt_s = restore_vcycle_state(cm_shared, runner_s, tc)
    _assert_trees(params_l, params_s, atol=0, err="params")
    _assert_trees(opt_l, opt_s, atol=0, err="opt")
    _assert_trees(state_l.params_before[0], state_s.params_before[0],
                  atol=0, err="stash")

    # and the finished resume matches the uninterrupted reference
    out_l = runner_l.run(state=state_l, params=params_l, opt_state=opt_l)
    assert out_l.history.step == ref.history.step
    _assert_trees(out_l.params, ref.params, atol=1e-2, err="final")


@pytest.mark.slow
def test_latest_survives_rank0_dir_loss(tmp_path):
    """Losing rank 0's local dir -- the exact failure per-host dirs must
    tolerate -- must NOT make the job silently forget the checkpoint: the
    coordinated ``latest()`` picks the newest manifest across EVERY rank's
    dir, and the surviving rank serves all objects over the KV gather."""
    survivor = str(tmp_path / "survivor")
    # written by ONE process => the survivor's pool holds every object
    cm = CheckpointManager(survivor, local=True)
    cm.save(5, {"params": {"w": jnp.arange(8.0)}}, meta={"step": 5})

    res = run_multiprocess("""
        import os
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.checkpoint import CheckpointManager

        # rank 0 restarts on a FRESH (lost) dir; rank 1 has the survivor
        my_dir = (os.environ["FRESH"] if jax.process_index() == 0
                  else os.environ["SURVIVOR"])
        cm = CheckpointManager(my_dir, local=True)
        out, meta = cm.restore({"params": {"w": jnp.zeros(8)}})
        assert meta["step"] == 5, meta
        np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                      np.arange(8.0))
        print("MP_SURVIVED_OK", flush=True)
    """, n=2, env={"FRESH": str(tmp_path / "fresh"), "SURVIVOR": survivor})
    for rc, out in res:
        assert rc == 0, out[-3000:]
        assert "MP_SURVIVED_OK" in out


@pytest.mark.slow
def test_one_process_local_save_resumes_on_two_processes(tmp_path):
    """The reverse direction: a 1-process local-dir save killed at the same
    mid-upward-sweep point resumes under 2 processes -- rank 1 starts with an
    EMPTY local dir and gathers every object over the coordination KV."""
    cfg, tc, ml = mp_arena()
    bf = make_batch_fn(cfg, tc, shard=0)
    ref = VCycleRunner(cfg, ml, tc, bf, seed=0).run()

    class Preempted(RuntimeError):
        pass

    save_dir = str(tmp_path / "local0")
    runner = VCycleRunner(cfg, ml, tc, bf, seed=0)
    cm = CheckpointManager(save_dir, local=True)
    save_cb = make_vcycle_save_cb(cm, schedule=runner.plan)

    def killing_cb(state, p, o):
        save_cb(state, p, o, blocking=True)
        if state.global_step == 6:
            raise Preempted

    with pytest.raises(Preempted):
        runner.run(ckpt_cb=killing_cb, ckpt_every=2)

    res = run_multiprocess("""
        import os
        import jax
        from helpers import mp_arena
        from repro.checkpoint import CheckpointManager
        from repro.core.vcycle import VCycleRunner
        from repro.distributed import as_global_batch_fn
        from repro.launch.train import make_batch_fn, restore_vcycle_state

        cfg, tc, ml = mp_arena()
        mesh = jax.make_mesh((2, 1), ("data", "model"))
        bf = as_global_batch_fn(make_batch_fn(cfg, tc, shard=0), mesh)
        runner = VCycleRunner(cfg, ml, tc, bf, seed=0, mesh=mesh)
        # rank 0 owns the dir that saved; rank 1's dir is fresh and empty
        my_dir = (os.environ["CK0"] if jax.process_index() == 0
                  else os.environ["CK1"])
        cm = CheckpointManager(my_dir, local=True)
        state, params, opt = restore_vcycle_state(cm, runner, tc)
        assert (state.phase, state.level, state.global_step) == ("up", 1, 6)
        # the restored stash really spans the 2-process mesh
        leaf = jax.tree.leaves(state.params_before[0])[0]
        assert leaf.sharding.mesh.devices.size == 2
        out = runner.run(state=state, params=params, opt_state=opt)
        cm.save(999, {"params": out.params}, meta={"step": 999})
        print("MP_RESUMED_OK", flush=True)
    """, n=2, env={"CK0": save_dir, "CK1": str(tmp_path / "local1")})
    for rc, out in res:
        assert rc == 0, out[-3000:]
        assert "MP_RESUMED_OK" in out

    # the final coordinated local save: every rank published the manifest
    # into its own dir; chunks resolve across the two pools
    for d in (save_dir, str(tmp_path / "local1")):
        m = json.load(open(os.path.join(d, "manifest.json")))
        assert m["step"] == 999
    flat = _read_leaves(os.path.join(save_dir, "step_00000999", "params"),
                        pools=[ObjectStore(save_dir),
                               ObjectStore(str(tmp_path / "local1"))])
    ref_flat = _flat(ref.params)
    assert flat.keys() == ref_flat.keys()
    for k in flat:
        np.testing.assert_allclose(np.asarray(flat[k], np.float64),
                                   np.asarray(ref_flat[k], np.float64),
                                   atol=1e-2, err_msg=k)
